"""Chaos soak for the self-healing streaming runtime (DESIGN.md §14).

A fixed-seed fault-injection campaign over the supervised streaming
stack.  Five scenarios, every one with a hard pass condition:

  1. transient I/O faults — every stateful policy (bfjs, vqs, vqs-bf,
     bfjs-mr) x {scan, pallas-fallback}: ingestion raises OSError on a
     fixed schedule, the supervisor retries with backoff, and the
     recovered trajectory must be BIT-EXACT against the unperturbed run
     (with the invariant auditor on the whole way);
  2. SIGKILL + corruption — a child process is SIGKILLed mid-stream,
     the newest surviving checkpoint is truncated, and the supervised
     resume must roll back (counting it) and still bit-match;
  3. delayed host — a chunk source that stalls past the staging
     watchdog budget must escalate as a typed SupervisorTimeout;
  4. poison quarantine — a deterministically failing chunk must be
     quarantined with a manifest and the run must equal the same stream
     with that chunk absent;
  5. auditor tamper — a corrupted engine output must raise
     InvariantViolation naming the chunk and the counter.

Exits nonzero on ANY violation.  The quarantine directory (default
``./chaos_quarantine``, override with ``CHAOS_QUARANTINE_DIR``) is left
on disk on failure so CI can upload it as an artifact; it is removed on
a clean pass.
"""
import os
import shutil
import signal
import subprocess
import sys
import tempfile
import time
import warnings

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.core import trace as trace_mod
from repro.core.engine import (CheckpointRollbackWarning,
                               InvariantViolation, RetryPolicy,
                               Supervisor, SupervisorTimeout,
                               SupervisorWarning, iter_stream_chunks,
                               make_streams, stream_policy)
from repro.core.engine.streams import streams_from_trace

QUARANTINE_DIR = os.environ.get("CHAOS_QUARANTINE_DIR",
                                os.path.abspath("./chaos_quarantine"))

_TRAJ = ("queue_len", "occupancy", "departed", "dropped", "truncated",
         "preempted", "requeued", "lost")

FAILURES: list[str] = []


def check(ok: bool, what: str) -> None:
    tag = "ok  " if ok else "FAIL"
    print(f"  [{tag}] {what}")
    if not ok:
        FAILURES.append(what)


def bitmatch(a, b) -> bool:
    for f in _TRAJ:
        x, y = getattr(a, f), getattr(b, f)
        if (x is None) != (y is None):
            return False
        if x is not None and not np.array_equal(np.asarray(x),
                                                np.asarray(y)):
            return False
    return True


def synth_streams():
    return make_streams(
        jax.random.PRNGKey(7), lam=1.3, mu=0.08,
        sampler=lambda k, s: jax.random.uniform(k, s, minval=0.1,
                                                maxval=0.7),
        L=4, K=5, A_max=4, horizon=40)


def mr_streams():
    tr = trace_mod.synthesize_google_like_trace(120, 60, seed=3)
    return streams_from_trace(tr.arrival_slots,
                              np.stack([tr.cpu, tr.mem], 1),
                              np.minimum(tr.durations, 20), A_max=8)


# (policy, streams builder, config) — every stateful runner in the
# registry; vqs-family needs J, bfjs-mr needs the 2-resource trace.
CASES = [
    ("bfjs", synth_streams, dict(L=4, K=5, Qcap=48, A_max=4)),
    ("vqs", synth_streams, dict(L=4, K=5, Qcap=48, A_max=4, J=3)),
    ("vqs-bf", synth_streams, dict(L=4, K=5, Qcap=48, A_max=4, J=3)),
    ("bfjs-mr", mr_streams, dict(L=4, K=6, Qcap=64)),
]


def sup(**kw):
    kw.setdefault("retry", RetryPolicy(max_retries=3, base_delay=0.001,
                                       max_delay=0.01))
    return Supervisor(**kw)


class ChunkSource:
    """Idempotent-on-failure, index-addressed source with skip()."""

    def __init__(self, chunks, poison=(), transient=None, stall=None):
        self.chunks = list(chunks)
        self.i = 0
        self.poison = set(poison)
        self.transient = dict(transient or {})
        self.stall = dict(stall or {})

    def __iter__(self):
        return self

    def skip(self):
        self.i += 1

    def __next__(self):
        if self.i in self.stall:
            time.sleep(self.stall[self.i])
        if self.i in self.poison:
            raise OSError(f"poison chunk {self.i}")
        n = self.transient.get(self.i, 0)
        if n:
            self.transient[self.i] = n - 1
            raise OSError(f"transient fault on chunk {self.i}")
        if self.i >= len(self.chunks):
            raise StopIteration
        out = self.chunks[self.i]
        self.i += 1
        return out


def scenario_transient() -> None:
    print("scenario 1: transient I/O faults, retry/backoff, bit-exact")
    for policy, build, cfg in CASES:
        streams = build()
        chunks = list(iter_stream_chunks(streams, 13))
        for engine in ("scan", "pallas"):
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                ref = stream_policy(iter(chunks), policy=policy,
                                    engine=engine, **cfg)
                s = sup()
                res = stream_policy(
                    ChunkSource(chunks, transient={1: 2, 2: 1}),
                    policy=policy, engine=engine, supervisor=s,
                    audit=True, **cfg)
            check(bitmatch(ref, res) and res.retries == 3
                  and res.quarantined == 0,
                  f"{policy}/{engine}: recovered bit-exact "
                  f"(retries={res.retries})")


_CHILD = r"""
import os, signal, sys
import jax
from repro.core.engine import make_streams, stream_policy, \
    iter_stream_chunks
from repro.core.engine import streaming as streaming_mod

ckdir, kills_after = sys.argv[1], int(sys.argv[2])
streams = make_streams(
    jax.random.PRNGKey(7), lam=1.3, mu=0.08,
    sampler=lambda k, s: jax.random.uniform(k, s, minval=0.1, maxval=0.7),
    L=4, K=5, A_max=4, horizon=40)
saves = [0]
real = streaming_mod._save_step

def killing_save(checkpoint_dir, step, payload, extra):
    real(checkpoint_dir, step, payload, extra)
    saves[0] += 1
    if saves[0] >= kills_after:
        os.kill(os.getpid(), signal.SIGKILL)

streaming_mod._save_step = killing_save
stream_policy(iter_stream_chunks(streams, 7), policy="bfjs",
              checkpoint_dir=ckdir, L=4, K=5, Qcap=48, A_max=4)
sys.exit("survived past the kill point — harness broken")
"""


def scenario_sigkill() -> None:
    print("scenario 2: SIGKILL mid-stream + checkpoint corruption")
    streams = synth_streams()
    cfg = dict(L=4, K=5, Qcap=48, A_max=4)
    ref = stream_policy(iter_stream_chunks(streams, 7), policy="bfjs",
                        **cfg)
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep \
        + env.get("PYTHONPATH", "")
    with tempfile.TemporaryDirectory() as ck:
        proc = subprocess.run([sys.executable, "-c", _CHILD, ck, "3"],
                              env=env)
        check(proc.returncode == -signal.SIGKILL,
              f"child died by SIGKILL (rc={proc.returncode})")
        steps = ckpt.list_steps(ck)
        check(bool(steps), f"checkpoints survived the kill: {steps}")
        if steps:
            victim = os.path.join(ck, f"step_{steps[-1]:08d}",
                                  "arrays.npz")
            size = os.path.getsize(victim)
            with open(victim, "r+b") as f:
                f.truncate(max(size // 2, 1))
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                res = stream_policy(iter_stream_chunks(streams, 7),
                                    policy="bfjs", checkpoint_dir=ck,
                                    resume=True, supervisor=sup(),
                                    audit=True, **cfg)
            check(res.rollbacks == 1,
                  f"rollback over the corrupt step counted "
                  f"(rollbacks={res.rollbacks})")
            check(bitmatch(ref, res),
                  "post-rollback resume bit-matches the clean run")


def scenario_watchdog() -> None:
    print("scenario 3: delayed host escalates as SupervisorTimeout")
    streams = synth_streams()
    chunks = list(iter_stream_chunks(streams, 7))
    s = Supervisor(stage_timeout=0.2)
    try:
        stream_policy(ChunkSource(chunks, stall={2: 5.0}), policy="bfjs",
                      supervisor=s, L=4, K=5, Qcap=48, A_max=4)
        check(False, "stalled host escalated (no timeout raised)")
    except SupervisorTimeout as e:
        check(e.chunk_index == 2 and s.timeouts == 1,
              f"stalled host escalated as SupervisorTimeout "
              f"(chunk {e.chunk_index})")


def scenario_quarantine() -> None:
    print("scenario 4: poison chunk quarantined with manifest")
    streams = synth_streams()
    cfg = dict(L=4, K=5, Qcap=48, A_max=4)
    chunks = list(iter_stream_chunks(streams, 7))
    ref = stream_policy(iter(chunks[:2] + chunks[3:]), policy="bfjs",
                        **cfg)
    s = sup(retry=RetryPolicy(max_retries=2, base_delay=0.001),
            quarantine_dir=QUARANTINE_DIR)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        res = stream_policy(ChunkSource(chunks, poison={2}),
                            policy="bfjs", supervisor=s, audit=True,
                            **cfg)
    manifest = os.path.join(QUARANTINE_DIR, "chunk_00000002",
                            "manifest.json")
    check(res.quarantined == 1 and os.path.exists(manifest),
          "poison chunk skipped with manifest preserved")
    check(bitmatch(ref, res),
          "quarantined run equals the stream minus the poison chunk")


def scenario_auditor() -> None:
    print("scenario 5: invariant auditor catches a corrupted engine")
    from repro.core.engine import streaming as streaming_mod
    streams = synth_streams()
    real = streaming_mod._STATEFUL["bfjs"]

    def tampered(s, st, config):
        res, new_st = real(s, st, config)
        return res._replace(queue_len=res.queue_len - 1000), new_st

    streaming_mod._STATEFUL["bfjs"] = tampered
    try:
        stream_policy(iter_stream_chunks(streams, 7), policy="bfjs",
                      audit=True, L=4, K=5, Qcap=48, A_max=4)
        check(False, "auditor caught the tampered counter (no raise)")
    except InvariantViolation as e:
        check(e.invariant == "queue_nonneg" and e.chunk_index == 0,
              f"auditor raised {e.invariant!r} at chunk {e.chunk_index}")
    finally:
        streaming_mod._STATEFUL["bfjs"] = real


def main() -> None:
    shutil.rmtree(QUARANTINE_DIR, ignore_errors=True)
    t0 = time.time()
    scenario_transient()
    scenario_sigkill()
    scenario_watchdog()
    scenario_quarantine()
    scenario_auditor()
    dt = time.time() - t0
    if FAILURES:
        print(f"\nchaos soak FAILED ({len(FAILURES)} violation(s), "
              f"{dt:.0f}s):")
        for f in FAILURES:
            print(f"  - {f}")
        print(f"quarantine evidence (if any): {QUARANTINE_DIR}")
        sys.exit(1)
    shutil.rmtree(QUARANTINE_DIR, ignore_errors=True)
    print(f"\nchaos soak PASSED (5 scenarios, "
          f"{len(CASES) * 2} policy/engine cells, {dt:.0f}s)")


if __name__ == "__main__":
    main()
