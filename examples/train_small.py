"""End-to-end training driver: train a ~100M-param LLaMA-style model for a
few hundred steps with checkpointing and (optional) simulated preemption.

    PYTHONPATH=src python examples/train_small.py [--steps 300] [--preempt]

On this CPU container a ~10M-param reduced config keeps the example under a
few minutes; pass --full-100m on real hardware for the 100M variant.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.models.config import ModelConfig
from repro.train.trainer import PreemptionError, Trainer, TrainerConfig


def model_100m() -> ModelConfig:
    return ModelConfig(name="llama-100m", family="dense", num_layers=12,
                       d_model=768, num_heads=12, num_kv_heads=4,
                       d_ff=2048, vocab_size=32000, head_dim=64)


def model_10m() -> ModelConfig:
    return ModelConfig(name="llama-10m", family="dense", num_layers=4,
                       d_model=256, num_heads=8, num_kv_heads=4,
                       d_ff=1024, vocab_size=4096, head_dim=32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--preempt", action="store_true",
                    help="simulate a preemption at 60%% and auto-resume")
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_small")
    args = ap.parse_args()

    cfg = model_100m() if args.full_100m else model_10m()
    n = cfg.param_counts()["total"]
    print(f"model: {cfg.name} ({n/1e6:.1f}M params)")

    tcfg = TrainerConfig(seq_len=256, global_batch=8, steps=args.steps,
                         ckpt_every=50, ckpt_dir=args.ckpt_dir,
                         log_every=20, peak_lr=6e-4, warmup=20,
                         preempt_at_step=(int(args.steps * 0.6)
                                          if args.preempt else -1))
    trainer = Trainer(cfg, tcfg)
    try:
        state = trainer.run()
    except PreemptionError as e:
        print(f"\n!!! {e} — restarting from latest checkpoint ...\n")
        tcfg2 = TrainerConfig(**{**tcfg.__dict__, "preempt_at_step": -1})
        trainer = Trainer(cfg, tcfg2)
        state = trainer.run()

    hist = state.metrics["loss_history"]
    print(f"\nfinal loss {hist[-1]:.4f} (from {hist[0]:.4f}); "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
