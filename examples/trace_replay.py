"""Google-trace-style replay (paper Section VII.B, Fig. 5 shape).

Synthesizes a statistically Google-like trace (hundreds of distinct discrete
request sizes, diurnal arrivals, heavy-tailed durations), collapses cpu/mem
to max(cpu, mem) per the paper's preprocessing, and replays it at increasing
traffic scalings through

  * the event-driven numpy engine (BF-J/S and VQS-BF), and
  * the accelerator engine stack: the trace is packed into ``SchedStreams``
    (``streams_from_trace``) and replayed through
    ``run_policy_streams(..., policy="vqs"|"vqs-bf", engine="scan")`` —
    the same fixed-shape engines that run the Monte-Carlo stability
    studies, now driven by real-workload arrivals.  ``--check`` re-runs
    the numpy engines and asserts the queue trajectories are
    bit-identical.

The same trace also replays UNCOLLAPSED: ``streams_from_trace(trace,
collapse=False)`` keeps the (cpu, mem) vectors and drives
``run_policy_streams(policy="bfjs-mr")`` — the Section-VIII Tetris
alignment engine, no max-collapse preprocessing.  ``--check`` verifies a
prefix of the trajectory bit-matches the event-driven MultiResourceBFJS
oracle.

``--chunk N`` replays through the streaming driver instead: the jax rows
go through ``stream_policy(iter_stream_chunks(streams, N))`` with carried
state, and a final section replays ``tests/data/google_like_50.csv``
through the full ingestion pipeline — ``scan_trace_maxima`` →
``iter_trace_csv`` (chunked by rows, constant memory) →
``stream_chunks_from_trace`` (re-bucketed to N-slot windows) →
``stream_policy`` — without ever materializing the whole trace.  With
``--check`` every streamed trajectory is asserted bit-identical to its
one-shot ``run_policy_streams`` run.

    PYTHONPATH=src python examples/trace_replay.py [--tasks 50000] \
        [--chunk 512] [--check]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (BFJS, FIFOFF, VQS, VQSBF, collapse_resources,
                        empirical_size_stats, scale_arrivals, simulate_trace,
                        synthesize_google_like_trace)
from repro.core.engine import (iter_stream_chunks, run_policy_streams,
                               stream_policy, streams_from_trace)

# Partition parameter: VQs cover sizes > 2^-5.  (J=5 rather than the
# earlier numpy-only run's J=7 so the fixed-shape engine's K_SLOTS >= 2^J
# per-server packing bound stays small; the numpy rows use the same J for
# an apples-to-apples comparison.)
J = 5
K_SLOTS = 32   # >= 2^J jobs per server => no placement truncation


def _run(streams, chunk, **kw):
    """One-shot run, or — with ``--chunk N`` — the same trajectory
    through the streaming driver with carried state (bit-identical by the
    streaming contract)."""
    if chunk:
        return stream_policy(iter_stream_chunks(streams, chunk), **kw)
    return run_policy_streams(streams, **kw)


def replay_vqs_jax(scaled, sizes, L, horizon, check=False, chunk=0):
    """Replay the trace through the scan engine; returns a SimResult-like
    row (mean queue, utilization, departures) computed from the
    PolicyResult trajectory."""
    streams = streams_from_trace(scaled.arrival_slots, sizes,
                                 scaled.durations,
                                 horizon=horizon)
    res = _run(streams, chunk, policy="vqs", engine="scan",
               J=J, L=L, K=K_SLOTS, Qcap=1 << 15,
               A_max=int(streams.sizes.shape[1]))
    qlen = np.asarray(res.queue_len)
    row = {
        "mean_Q": float(qlen.mean()),
        "util": float(np.asarray(res.occupancy).mean()) / L,
        "done": int(res.departed[-1]),
        "trunc": int(res.truncated),
        "dropped": int(res.dropped),
    }
    if check:
        ref = simulate_trace(VQS(J=J), L=L,
                             arrival_slots=scaled.arrival_slots,
                             sizes=sizes, durations=scaled.durations,
                             horizon=horizon, seed=1, record_every=1)
        assert row["trunc"] == 0 and row["dropped"] == 0, row
        assert (qlen == ref.queue_lens).all(), \
            "scan engine diverged from the event-driven VQS engine"
        row["bitmatch"] = 1
    return row


def replay_vqs_bf_jax(scaled, sizes, L, horizon, check=False, chunk=0):
    """Replay through the VQS-BF scan engine (paper Section VI — the
    policy with the best queue tails, formerly event-driven-only here).
    One placement per work step, so the bound is sized to the burst."""
    streams = streams_from_trace(scaled.arrival_slots, sizes,
                                 scaled.durations, horizon=horizon)
    res = _run(streams, chunk, policy="vqs-bf", engine="scan",
               J=J, L=L, K=K_SLOTS, Qcap=1 << 15,
               A_max=int(streams.sizes.shape[1]), work_steps=64)
    qlen = np.asarray(res.queue_len)
    row = {
        "mean_Q": float(qlen.mean()),
        "util": float(np.asarray(res.occupancy).mean()) / L,
        "done": int(res.departed[-1]),
        "trunc": int(res.truncated),
        "dropped": int(res.dropped),
    }
    if check:
        ref = simulate_trace(VQSBF(J=J), L=L,
                             arrival_slots=scaled.arrival_slots,
                             sizes=sizes, durations=scaled.durations,
                             horizon=horizon, seed=1, record_every=1)
        assert row["trunc"] == 0 and row["dropped"] == 0, row
        assert (qlen == ref.queue_lens).all(), \
            "scan engine diverged from the event-driven VQS-BF engine"
        row["bitmatch"] = 1
    return row


def replay_mr_jax(scaled, L, horizon, check=False, engine="scan", chunk=0):
    """Replay the UNCOLLAPSED (cpu, mem) trace through the bfjs-mr scan
    engine or the fused Pallas kernel (``engine="pallas"``, interpret mode
    off-TPU); --check bit-matches a prefix against the event-driven
    oracle."""
    import jax

    streams = streams_from_trace(scaled, collapse=False, horizon=horizon,
                                 num_resources=2)
    res = _run(streams, chunk, policy="bfjs-mr", engine=engine,
               L=L, K=64, Qcap=1 << 13, work_steps=64)
    qlen = np.asarray(res.queue_len)
    occ = np.asarray(res.occupancy)
    row = {
        "mean_Q": float(qlen.mean()),
        "util": float(occ.mean()) / L,   # mean over resources and slots
        "done": int(res.departed[-1]),
        "trunc": int(res.truncated),
        "dropped": int(res.dropped),
    }
    if check:
        assert row["trunc"] == 0 and row["dropped"] == 0, row
        # trajectories are causal (slot t depends on slots <= t only), so
        # the first h slots of the full run ARE the prefix trajectory — no
        # second engine run needed, just the oracle on the prefix.
        h = min(horizon, 3_000)
        prefix = jax.tree.map(lambda x: x[:h], streams)
        ref = run_policy_streams(prefix, policy="bfjs-mr",
                                 engine="reference", L=L)
        assert (qlen[:h] == np.asarray(ref.queue_len)).all() \
            and (occ[:h] == np.asarray(ref.occupancy)).all(), \
            f"bfjs-mr {engine} diverged from the MultiResourceBFJS oracle"
        row["bitmatch"] = 1
    return row


def replay_csv_streaming(chunk, check=False):
    """tests/data/google_like_50.csv through the full streaming ingestion
    pipeline — two-pass column maxima, row-chunked CSV reader, slot-window
    re-bucketing, stateful driver — with --check asserting each streamed
    trajectory bit-matches the one-shot run."""
    from repro.core import iter_trace_csv, load_trace_csv, scan_trace_maxima
    from repro.core.engine import stream_chunks_from_trace

    path = os.path.join(os.path.dirname(__file__), "..", "tests", "data",
                        "google_like_50.csv")
    cpu_max, mem_max = scan_trace_maxima(path)
    print(f"\nstreaming: google_like_50.csv via iter_trace_csv(chunk_rows="
          f"16) -> {chunk}-slot windows -> stream_policy")
    print(f"{'policy':>12} {'mean_Q':>9} {'done':>8} {'behind':>7} "
          f"{'stall_us':>9}")
    for policy, collapse, extra in (("vqs", True, {"J": 3}),
                                    ("bfjs-mr", False, {})):
        n_res = None if collapse else 2
        one_streams = streams_from_trace(
            load_trace_csv(path, slot_seconds=10.0), collapse=collapse,
            num_resources=n_res)
        cfg = dict(L=4, K=5, Qcap=48,
                   A_max=int(one_streams.sizes.shape[1]), **extra)
        chunks = stream_chunks_from_trace(
            iter_trace_csv(path, chunk_rows=16, slot_seconds=10.0,
                           cpu_capacity=cpu_max, mem_capacity=mem_max),
            chunk_slots=chunk, A_max=cfg["A_max"], collapse=collapse,
            num_resources=n_res)
        res = stream_policy(chunks, policy=policy, **cfg)
        row = f"{policy:>12} {float(np.asarray(res.queue_len).mean()):>9.2f} " \
              f"{int(res.departed[-1]):>8} {res.chunks_behind:>7} " \
              f"{res.host_stall_us:>9.0f}"
        if check:
            one = run_policy_streams(one_streams, policy=policy,
                                     engine="scan", **cfg)
            for f in ("queue_len", "occupancy", "departed", "dropped",
                      "truncated", "preempted", "requeued", "lost"):
                a, b = getattr(res, f), getattr(one, f)
                assert (a is None) == (b is None) and \
                    (a is None or (np.asarray(a) == np.asarray(b)).all()), \
                    f"streamed {policy} diverged from one-shot on {f}"
            row += " bitmatch=1"
        print(row)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks", type=int, default=50_000)
    ap.add_argument("--servers", type=int, default=100)
    ap.add_argument("--check", action="store_true",
                    help="assert the jax replay bit-matches numpy VQS "
                         "(and, with --chunk, each streamed trajectory "
                         "bit-matches its one-shot run)")
    ap.add_argument("--chunk", type=int, default=0, metavar="N",
                    help="replay through core.engine.stream_policy in "
                         "N-slot chunks with carried state instead of "
                         "one-shot run_policy_streams (engine=pallas "
                         "degrades to the bit-identical scan path: the "
                         "fused kernel cannot export a cross-chunk "
                         "carry); also streams tests/data/"
                         "google_like_50.csv through iter_trace_csv -> "
                         "stream_chunks_from_trace -> stream_policy")
    ap.add_argument("--engine", choices=("scan", "pallas"), default="scan",
                    help="accelerator engine for the uncollapsed bfjs-mr "
                         "replay.  pallas = the fused kernels/bfjs_mr "
                         "ensemble kernel; off-TPU it runs in interpret "
                         "mode (correctness-grade, ~30x slower than scan "
                         "— pair it with a smaller --tasks)")
    args = ap.parse_args()

    horizon = args.tasks  # ~1 task/slot on average
    trace = synthesize_google_like_trace(args.tasks, horizon, seed=4)
    sizes = collapse_resources(trace)
    stats = empirical_size_stats(sizes)
    print(f"trace: {len(trace)} tasks, {stats['distinct_values']} distinct "
          f"sizes, mean {stats['mean']:.3f}, p99 {stats['p99']:.3f}\n")
    print(f"{'scaling':>8} {'policy':>12} {'mean_Q':>9} {'util':>6} "
          f"{'done':>8}")

    for scaling in (1.0, 1.3, 1.6):
        scaled = scale_arrivals(trace, scaling)
        h = int(horizon / scaling) + 500
        for name, mk in (("bf-js", BFJS), ("vqs-bf", lambda: VQSBF(J=J)),
                         ("fifo-ff", FIFOFF)):
            res = simulate_trace(
                mk(), L=args.servers,
                arrival_slots=scaled.arrival_slots, sizes=sizes,
                durations=scaled.durations, horizon=h, seed=1)
            print(f"{scaling:>8} {name:>12} {res.mean_queue:>9.1f} "
                  f"{res.utilization:>6.3f} {res.departed:>8}")
        row = replay_vqs_jax(scaled, sizes, args.servers, h,
                             check=args.check, chunk=args.chunk)
        extra = " bitmatch=1" if args.check else \
            f" trunc={row['trunc']} dropped={row['dropped']}"
        tag = "vqs[stream]" if args.chunk else "vqs[scan]"
        print(f"{scaling:>8} {tag:>12} {row['mean_Q']:>9.1f} "
              f"{row['util']:>6.3f} {row['done']:>8}{extra}")
        row = replay_vqs_bf_jax(scaled, sizes, args.servers, h,
                                check=args.check, chunk=args.chunk)
        extra = " bitmatch=1" if args.check else \
            f" trunc={row['trunc']} dropped={row['dropped']}"
        tag = "vqsbf[strm]" if args.chunk else "vqsbf[scan]"
        print(f"{scaling:>8} {tag:>12} {row['mean_Q']:>9.1f} "
              f"{row['util']:>6.3f} {row['done']:>8}{extra}")
        row = replay_mr_jax(scaled, args.servers, h, check=args.check,
                            engine=args.engine, chunk=args.chunk)
        extra = " bitmatch=1(prefix)" if args.check else \
            f" trunc={row['trunc']} dropped={row['dropped']}"
        tag = "mr[stream]" if args.chunk else "mr[" + args.engine + "]"
        print(f"{scaling:>8} {tag:>12} "
              f"{row['mean_Q']:>9.1f} "
              f"{row['util']:>6.3f} {row['done']:>8}{extra}")
    if args.chunk:
        replay_csv_streaming(args.chunk, check=args.check)


if __name__ == "__main__":
    main()
