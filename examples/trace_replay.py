"""Google-trace-style replay (paper Section VII.B, Fig. 5 shape).

Synthesizes a statistically Google-like trace (hundreds of distinct discrete
request sizes, diurnal arrivals, heavy-tailed durations), collapses cpu/mem
to max(cpu, mem) per the paper's preprocessing, and replays it through
BF-J/S, VQS-BF and FIFO-FF at increasing traffic scalings.

    PYTHONPATH=src python examples/trace_replay.py [--tasks 50000]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (BFJS, FIFOFF, VQSBF, collapse_resources,
                        empirical_size_stats, scale_arrivals, simulate_trace,
                        synthesize_google_like_trace)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks", type=int, default=50_000)
    ap.add_argument("--servers", type=int, default=100)
    args = ap.parse_args()

    horizon = args.tasks  # ~1 task/slot on average
    trace = synthesize_google_like_trace(args.tasks, horizon, seed=4)
    sizes = collapse_resources(trace)
    stats = empirical_size_stats(sizes)
    print(f"trace: {len(trace)} tasks, {stats['distinct_values']} distinct "
          f"sizes, mean {stats['mean']:.3f}, p99 {stats['p99']:.3f}\n")
    print(f"{'scaling':>8} {'policy':>8} {'mean_Q':>9} {'util':>6} {'done':>8}")

    for scaling in (1.0, 1.3, 1.6):
        scaled = scale_arrivals(trace, scaling)
        for name, mk in (("bf-js", BFJS), ("vqs-bf", lambda: VQSBF(J=7)),
                         ("fifo-ff", FIFOFF)):
            res = simulate_trace(
                mk(), L=args.servers,
                arrival_slots=scaled.arrival_slots, sizes=sizes,
                durations=scaled.durations,
                horizon=int(horizon / scaling) + 500, seed=1)
            print(f"{scaling:>8} {name:>8} {res.mean_queue:>9.1f} "
                  f"{res.utilization:>6.3f} {res.departed:>8}")


if __name__ == "__main__":
    main()
