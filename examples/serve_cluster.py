"""End-to-end serving driver: a small LM served with batched requests under
the paper's BF-J/S admission control.

Requests with random prompt/generation lengths are jobs with random KV-memory
requirements; replicas are the paper's unit-capacity servers.  The engine
prints queue/occupancy traces — the same observables as the paper's figures.

    PYTHONPATH=src python examples/serve_cluster.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine

cfg = get_smoke_config("llama3-8b")
params = M.init_params(cfg, jax.random.PRNGKey(0))

engine = ServingEngine(cfg, params, num_replicas=3, b_slots=4, c_max=96)
rng = np.random.default_rng(0)

# Three arrival waves with heavy-tailed lengths (the paper's point: the
# size distribution is unknown and effectively continuous).
rid = 0
for wave in range(3):
    n = int(rng.integers(6, 14))
    reqs = []
    for _ in range(n):
        plen = int(np.clip(rng.lognormal(2.5, 0.8), 4, 64))
        gen = int(np.clip(rng.lognormal(2.0, 0.7), 2, 24))
        reqs.append(Request(rid=rid,
                            prompt=rng.integers(1, cfg.vocab_size,
                                                size=plen).astype(np.int32),
                            max_new=gen))
        rid += 1
    engine.submit(reqs)
    print(f"wave {wave}: submitted {n} requests "
          f"(queued {engine.admission.queue_len()})")
    for _ in range(40):
        engine.step()

done = engine.run(max_steps=2000)
q = engine.stats["queue_len"]
print(f"\ncompleted {len(done)}/{rid} requests")
print(f"admission queue: max {max(q)}, final {q[-1]}")
print(f"batch-slot rejections (memory ok, no slot): "
      f"{engine.stats['rejected_slots']}")
print("sample output:", done[0].out[:8])
