"""Mesh-sharded + autotuned Monte-Carlo sweep (DESIGN.md §11).

The paper-scale workflow in one script, self-contained on a CPU host:

  1. re-exec with ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
     (the flag must be set before jax imports, so the script forks itself);
  2. autotune the launch shape into a throwaway tuning cache and show the
     cache entry that ``monte_carlo_policy`` will pick up automatically;
  3. run the same ensemble unsharded, on a 2-device mesh and on a 4-device
     mesh — and verify all three trajectories are BIT-IDENTICAL;
  4. run the sweep chunked + checkpointed on 4 devices, kill it after one
     chunk, and resume on 2 devices — bit-exact again: checkpoints never
     pin a device count.

    PYTHONPATH=src python examples/sharded_sweep.py
"""
import json
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# -- 1. force a 4-device CPU platform before jax loads ----------------------
if os.environ.get("_SHARDED_SWEEP_CHILD") != "1":
    env = dict(os.environ, _SHARDED_SWEEP_CHILD="1")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    os.execvpe(sys.executable, [sys.executable] + sys.argv, env)

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.engine import (Workload, autotune,  # noqa: E402
                               monte_carlo_policy, shape_key)

print(f"jax devices: {jax.device_count()} x {jax.devices()[0].platform}")

G = 8
CFG = dict(L=8, K=16, Qcap=256, A_max=6, horizon=600)
wl = Workload(lam=0.4, mu=0.02,
              sampler=lambda key, n: jax.random.uniform(
                  key, (n,), minval=0.1, maxval=0.6))
keys = jax.random.split(jax.random.PRNGKey(7), G)


def bitmatch(a, b):
    return all((np.asarray(getattr(a, f)) == np.asarray(getattr(b, f))).all()
               for f in a._fields)


# -- 2. autotune the shape into a throwaway cache ---------------------------
os.environ["REPRO_TUNING_CACHE"] = os.path.join(
    tempfile.mkdtemp(prefix="sharded-sweep-"), "tuning.json")
tuned = autotune(wl, keys, policy="bfjs", engine="scan", rounds=2, **CFG)
print(f"autotune: work_steps={tuned['work_steps']} "
      f"speedup={tuned['speedup']}x over default "
      f"({tuned['candidates']} candidates, {tuned['rejected']} rejected)")
print("cache entry:", json.dumps(
    {tuned["key"]: {"work_steps": tuned["work_steps"]}}))
assert tuned["key"] == shape_key("bfjs", "scan", L=8, K=16, R=1, Qcap=256,
                                 A_max=6)

# -- 3. unsharded vs 2-device vs 4-device: bit-identical --------------------
runs = {}
for d in (None, 2, 4):
    extra = {} if d is None else {"devices": d}
    runs[d] = monte_carlo_policy(wl, keys, policy="bfjs", engine="scan",
                                 **extra, **CFG)
    tail = float(np.asarray(runs[d].queue_len)[:, -150:].mean())
    print(f"devices={d or 1}: tail queue {tail:.2f} "
          f"(tuned work_steps injected from the cache)")
assert bitmatch(runs[2], runs[None]) and bitmatch(runs[4], runs[None]), \
    "sharded trajectories diverged from the single-device run"
print("unsharded == 2-device mesh == 4-device mesh: bit-identical")

# -- 4. checkpoint on 4 devices, resume on 2 --------------------------------
ckpt_dir = tempfile.mkdtemp(prefix="sharded-sweep-ckpt-")
monte_carlo_policy(wl, keys, policy="bfjs", engine="scan", devices=4,
                   chunk=200, checkpoint_dir=ckpt_dir, stop_after_chunks=1,
                   **CFG)
print(f"checkpointed 1/3 chunks on 4 devices -> {ckpt_dir}")
resumed = monte_carlo_policy(wl, keys, policy="bfjs", engine="scan",
                             devices=2, chunk=200, checkpoint_dir=ckpt_dir,
                             resume=True, **CFG)
assert bitmatch(resumed, runs[None]), \
    "cross-device-count resume diverged from the straight-through run"
print("resumed on 2 devices: bit-identical to the straight-through run")
