"""Quickstart: the paper's schedulers in 60 seconds.

Three stops:

  1. the event-driven numpy engine: a 5-server cluster under uniform random
     job sizes at 92% of the theoretical maximum load, all schedulers;
  2. the accelerator engine stack through the canonical ``Workload`` API —
     the same cluster as a typed workload spec dispatched to the
     policy-generic engines (``run_policy`` / ``monte_carlo_policy``);
  3. the paper's headline 2/3-tightness result (Fig. 3a), plus a taste of
     the Section-VIII multi-resource extension (``policy="bfjs-mr"``).

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import (BFJS, Discrete, FIFOFF, ServiceModel, Uniform, VQS,
                        VQSBF, rho_star_discrete, rho_star_upper_bound,
                        simulate)
from repro.core.engine import Workload, monte_carlo_policy, run_policy

# ---------------------------------------------------------------------------
# 1. A cluster under continuous (infinite-type) job sizes (numpy engine)
# ---------------------------------------------------------------------------
L, mu = 5, 0.01
dist = Uniform(0.1, 0.9)                      # job sizes: unknown to policies
alpha = 0.92                                   # traffic intensity
lam = alpha * L * mu / dist.mean()
svc = ServiceModel("geometric", 1 / mu)

print(f"rho* upper bound (Lemma 1): {rho_star_upper_bound(dist, L):.2f}")
print(f"simulating L={L}, alpha={alpha} ...\n")

for policy in (BFJS(), VQSBF(J=4), VQS(J=4), FIFOFF()):
    res = simulate(policy, L=L, lam=lam, dist=dist, service=svc,
                   horizon=60_000, seed=0)
    print(f"  {res.summary()}")

# ---------------------------------------------------------------------------
# 2. The same cluster on the accelerator stack: one Workload, any policy
# ---------------------------------------------------------------------------
# A Workload is the typed spec every engine entry point dispatches on:
# arrival rate, service rate, size sampler, resource count, capacity.
workload = Workload(
    lam=lam, mu=mu,
    sampler=lambda key, n: jax.random.uniform(key, (n,), minval=0.1,
                                              maxval=0.9))

print("\naccelerator engines (scan), same workload:")
for policy in ("bfjs", "vqs"):
    res = run_policy(workload, policy=policy, engine="scan",
                     key=jax.random.PRNGKey(0), L=L, K=16, Qcap=512,
                     A_max=8, horizon=20_000,
                     **({"J": 4} if policy == "vqs" else {}))
    tail_q = float(np.asarray(res.queue_len)[-5_000:].mean())
    print(f"  {policy:8s}: tail queue {tail_q:7.1f}  "
          f"(dropped={int(res.dropped)}, truncated={int(res.truncated)})")

# Monte-Carlo ensembles are one call: a batch of keys, one cluster each.
keys = jax.random.split(jax.random.PRNGKey(1), 8)
mc = monte_carlo_policy(workload, keys, policy="bfjs", engine="scan",
                        L=L, K=16, Qcap=512, A_max=8, horizon=5_000)
print(f"  bfjs x{len(keys)} ensembles: mean tail queue "
      f"{float(np.asarray(mc.queue_len)[:, -1_000:].mean()):.1f}")

# ---------------------------------------------------------------------------
# 3a. Paper Fig. 3a: the 2/3 bound of VQS is real
# ---------------------------------------------------------------------------
print("\nFig 3a: sizes {0.4, 0.6}, rate 0.014 > (2/3) * 0.02:")
d2 = Discrete([0.4, 0.6], [0.5, 0.5])
print(f"  rho* = {rho_star_discrete(np.array([0.4, 0.6]), np.array([0.5, 0.5]), L=1):.2f}"
      " (jobs per mean service time)")
for policy in (BFJS(), VQS(J=2), VQSBF(J=2)):
    res = simulate(policy, L=1, lam=0.014, dist=d2,
                   service=ServiceModel("geometric", 100.0),
                   horizon=150_000, seed=1)
    verdict = "UNSTABLE" if res.mean_queue_tail > 30 else "stable"
    print(f"  {policy.name:8s}: tail queue {res.mean_queue_tail:7.1f}  [{verdict}]")

# ---------------------------------------------------------------------------
# 3b. Section VIII: vector requirements — (cpu, mem) without max-collapse
# ---------------------------------------------------------------------------
mr = Workload(
    lam=0.3, mu=0.05, num_resources=2, capacity=(1.0, 1.0),
    sampler=lambda key, n: jax.random.uniform(key, (n, 2), minval=0.05,
                                              maxval=0.5))
res = run_policy(mr, policy="bfjs-mr", engine="scan",
                 key=jax.random.PRNGKey(2), L=4, K=16, Qcap=256, A_max=6,
                 horizon=5_000, work_steps=24)
occ = np.asarray(res.occupancy)[-1_000:].mean(axis=0)
print(f"\nbfjs-mr (Tetris alignment, R=2): tail queue "
      f"{float(np.asarray(res.queue_len)[-1_000:].mean()):.1f}, "
      f"per-resource occupancy cpu={occ[0]:.2f} mem={occ[1]:.2f} servers")
