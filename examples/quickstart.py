"""Quickstart: the paper's schedulers in 60 seconds.

Simulates a 5-server cluster under uniform random job sizes at 92% of the
theoretical maximum load and compares all five schedulers, then reproduces
the paper's headline stability result (Fig. 3a).

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (BFJS, Discrete, FIFOFF, ServiceModel, Uniform, VQS,
                        VQSBF, rho_star_discrete, rho_star_upper_bound,
                        simulate)

# ---------------------------------------------------------------------------
# 1. A cluster under continuous (infinite-type) job sizes
# ---------------------------------------------------------------------------
L, mu = 5, 0.01
dist = Uniform(0.1, 0.9)                      # job sizes: unknown to policies
alpha = 0.92                                   # traffic intensity
lam = alpha * L * mu / dist.mean()
svc = ServiceModel("geometric", 1 / mu)

print(f"rho* upper bound (Lemma 1): {rho_star_upper_bound(dist, L):.2f}")
print(f"simulating L={L}, alpha={alpha} ...\n")

for policy in (BFJS(), VQSBF(J=4), VQS(J=4), FIFOFF()):
    res = simulate(policy, L=L, lam=lam, dist=dist, service=svc,
                   horizon=60_000, seed=0)
    print(f"  {res.summary()}")

# ---------------------------------------------------------------------------
# 2. Paper Fig. 3a: the 2/3 bound of VQS is real
# ---------------------------------------------------------------------------
print("\nFig 3a: sizes {0.4, 0.6}, rate 0.014 > (2/3) * 0.02:")
d2 = Discrete([0.4, 0.6], [0.5, 0.5])
print(f"  rho* = {rho_star_discrete(np.array([0.4, 0.6]), np.array([0.5, 0.5]), L=1):.2f}"
      " (jobs per mean service time)")
for policy in (BFJS(), VQS(J=2), VQSBF(J=2)):
    res = simulate(policy, L=1, lam=0.014, dist=d2,
                   service=ServiceModel("geometric", 100.0),
                   horizon=150_000, seed=1)
    verdict = "UNSTABLE" if res.mean_queue_tail > 30 else "stable"
    print(f"  {policy.name:8s}: tail queue {res.mean_queue_tail:7.1f}  [{verdict}]")
