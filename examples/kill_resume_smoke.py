"""Kill-and-resume crash-safety smoke (DESIGN.md §9): SIGKILL a
checkpointed faulted sweep mid-run, resume it, and demand the resumed
trajectory is bit-identical to an uninterrupted one.

A child process runs ``run_policy_streams(..., checkpoint_dir=, chunk=)``
with the checkpoint writer wrapped to SIGKILL the process after N saves —
a hard crash at a chunk boundary, no atexit, no cleanup.  The parent then
resumes from the surviving checkpoints and compares every PolicyResult
field (queue_len/occupancy/departed plus the dropped/truncated and
preempted/requeued/lost counters) against the straight-through run.

Exits nonzero on any mismatch; CI runs this as the crash-safety gate.
"""
import os
import signal
import subprocess
import sys
import tempfile

import numpy as np

# Shared by parent and child so both build the SAME streams + config.
SETUP = """
import jax
from repro.core.engine.streams import make_streams

def build():
    def sampler(key, n):
        return jax.random.uniform(key, (n,), minval=0.1, maxval=0.6)
    return make_streams(jax.random.PRNGKey(5), 0.6, 0.5, sampler,
                        L=4, K=8, A_max=4, horizon=240,
                        fault_rate=0.02, repair_rate=0.3)

CFG = dict(policy="bfjs", engine="scan", L=4, K=8, Qcap=64, A_max=4)
CHUNK = 60
"""

# Child: run the chunked sweep, SIGKILL ourselves after `kill_after`
# checkpoint writes land on disk.  Reaching the end means the kill never
# fired — that is a failure of the harness, not a pass.
CHILD = SETUP + """
import os, signal, sys
import repro.core.engine.chunked as chunked
from repro.core.engine.api import run_policy_streams

kill_after, ckpt_dir = int(sys.argv[1]), sys.argv[2]
_real_save, _calls = chunked._save_step, 0

def _killing_save(*args, **kwargs):
    global _calls
    _real_save(*args, **kwargs)
    _calls += 1
    if _calls >= kill_after:
        os.kill(os.getpid(), signal.SIGKILL)

chunked._save_step = _killing_save
run_policy_streams(build(), checkpoint_dir=ckpt_dir, chunk=CHUNK, **CFG)
sys.exit("survived past the kill point — harness broken")
"""


def main() -> None:
    ns: dict = {}
    exec(SETUP, ns)
    streams, cfg, chunk = ns["build"](), ns["CFG"], ns["CHUNK"]

    from repro.core.engine.api import run_policy_streams

    full = run_policy_streams(streams, **cfg)
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep \
        + env.get("PYTHONPATH", "")

    for kill_after in (1, 2, 3):
        with tempfile.TemporaryDirectory() as ckpt_dir:
            proc = subprocess.run(
                [sys.executable, "-c", CHILD, str(kill_after), ckpt_dir],
                env=env)
            if proc.returncode != -signal.SIGKILL:
                raise SystemExit(
                    f"child exited {proc.returncode}, expected SIGKILL "
                    f"({-signal.SIGKILL})")
            res = run_policy_streams(streams, checkpoint_dir=ckpt_dir,
                                     chunk=chunk, resume=True, **cfg)
            for f in full._fields:
                a, b = np.asarray(getattr(res, f)), \
                    np.asarray(getattr(full, f))
                if a.shape != b.shape or not np.array_equal(a, b):
                    raise SystemExit(
                        f"resume after SIGKILL@save#{kill_after} diverged "
                        f"on {f!r}")
            print(f"SIGKILL after save #{kill_after}: resume bit-matches "
                  "the uninterrupted run")
    print("kill-and-resume smoke PASSED "
          f"(preempted={int(full.preempted)} requeued={int(full.requeued)} "
          f"lost={int(full.lost)})")


if __name__ == "__main__":
    main()
