"""Slotted-time discrete-event simulator (paper Section II queueing model).

Per slot t: (1) departures complete, (2) the arrival set A(t) joins the
queue, (3) the policy schedules D(t) jobs into servers — Eq. (2)/(3).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .base import Scheduler
from .cluster_state import Cluster, ServiceModel, poisson_arrivals
from .distributions import JobSizeDistribution
from .quantize import RES, to_grid


@dataclass
class SimResult:
    name: str
    horizon: int
    record_every: int
    queue_lens: np.ndarray
    arrived: int
    departed: int
    utilization: float            # mean fraction of total capacity occupied
    mean_queue: float             # time-average queue length (whole run)
    mean_queue_tail: float        # time-average over the last half (stationary-ish)
    final_queue: int
    extras: dict = field(default_factory=dict)

    def summary(self) -> str:
        return (f"{self.name}: mean_Q={self.mean_queue:.1f} "
                f"tail_Q={self.mean_queue_tail:.1f} final_Q={self.final_queue} "
                f"util={self.utilization:.3f} dep={self.departed}/{self.arrived}")


def simulate(policy: Scheduler,
             L: int,
             lam: float,
             dist: JobSizeDistribution,
             service: ServiceModel,
             horizon: int,
             seed: int = 0,
             capacities: np.ndarray | None = None,
             record_every: int = 1,
             check_invariants: bool = False) -> SimResult:
    """Run `policy` on Poisson(lam) arrivals with iid sizes ~ dist."""
    rng = np.random.Generator(np.random.Philox(seed))
    cluster = Cluster(L, capacities)
    policy.bind(cluster, service, rng)
    arrivals = poisson_arrivals(lam)

    records: list[int] = []
    qsum = 0.0
    qsum_tail = 0.0
    tail_start = horizon // 2
    arrived = 0
    jid = 0

    for t in range(horizon):
        freed, emptied = cluster.process_departures(t)
        n = arrivals(rng)
        if n > 0:
            sizes = to_grid(dist.sample(rng, n))
            jobs = [policy.make_job(jid + i, int(sizes[i]), t) for i in range(n)]
            jid += n
            arrived += n
        else:
            jobs = []
        policy.on_arrivals(t, jobs)
        policy.schedule(t, freed, emptied)
        cluster.accumulate_utilization()
        q = policy.queue_len()
        qsum += q
        if t >= tail_start:
            qsum_tail += q
        if t % record_every == 0:
            records.append(q)
        if check_invariants and t % 997 == 0:
            cluster.check_invariants()

    total_cap = float(cluster.capacity.sum())
    return SimResult(
        name=policy.name,
        horizon=horizon,
        record_every=record_every,
        queue_lens=np.asarray(records, dtype=np.int64),
        arrived=arrived,
        departed=cluster.departed_jobs,
        utilization=cluster.busy_area / (total_cap * horizon),
        mean_queue=qsum / horizon,
        mean_queue_tail=qsum_tail / max(horizon - tail_start, 1),
        final_queue=policy.queue_len(),
    )


def simulate_trace(policy: Scheduler,
                   L: int,
                   arrival_slots: np.ndarray,
                   sizes: np.ndarray,
                   durations: np.ndarray,
                   horizon: int | None = None,
                   seed: int = 0,
                   capacities: np.ndarray | None = None,
                   record_every: int = 100) -> SimResult:
    """Replay a trace: job i arrives at slot arrival_slots[i] with float size
    sizes[i] in (0,1] and fixed service duration durations[i] (slots)."""
    rng = np.random.Generator(np.random.Philox(seed))
    cluster = Cluster(L, capacities)
    service = ServiceModel("fixed", 1.0)  # unused: every job carries dur
    policy.bind(cluster, service, rng)

    order = np.argsort(arrival_slots, kind="stable")
    arrival_slots = np.asarray(arrival_slots)[order]
    sizes_int = to_grid(np.asarray(sizes)[order])
    durations = np.maximum(np.asarray(durations)[order].astype(np.int64), 1)
    n_jobs = len(arrival_slots)
    if horizon is None:
        horizon = int(arrival_slots[-1]) + 1

    records: list[int] = []
    qsum = 0.0
    qsum_tail = 0.0
    tail_start = horizon // 2
    ptr = 0
    for t in range(horizon):
        freed, emptied = cluster.process_departures(t)
        jobs = []
        while ptr < n_jobs and arrival_slots[ptr] <= t:
            jobs.append(policy.make_job(ptr, int(sizes_int[ptr]), t,
                                        dur=int(durations[ptr])))
            ptr += 1
        policy.on_arrivals(t, jobs)
        policy.schedule(t, freed, emptied)
        cluster.accumulate_utilization()
        q = policy.queue_len()
        qsum += q
        if t >= tail_start:
            qsum_tail += q
        if t % record_every == 0:
            records.append(q)

    total_cap = float(cluster.capacity.sum())
    return SimResult(
        name=policy.name,
        horizon=horizon,
        record_every=record_every,
        queue_lens=np.asarray(records, dtype=np.int64),
        arrived=ptr,
        departed=cluster.departed_jobs,
        utilization=cluster.busy_area / (total_cap * horizon),
        mean_queue=qsum / horizon,
        mean_queue_tail=qsum_tail / max(horizon - tail_start, 1),
        final_queue=policy.queue_len(),
    )
