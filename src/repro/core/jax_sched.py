"""Accelerator-resident scheduling engine (pure JAX).

The event-driven numpy engine (simulator.py) is exact and fast on hosts; this
module re-expresses the paper's BF-J/S scheduler as a fixed-shape, branch-free
``lax.scan`` program so it can run ON the accelerator:

  * Monte-Carlo stability studies: ``vmap`` over seeds/workloads gives
    thousands of independent cluster simulations per device;
  * on-device admission control: the serving engine calls
    ``best_fit_place`` / ``max_weight_config_jax`` inside jitted control
    loops (optionally via the Pallas kernel in kernels/best_fit).

Fixed-capacity redesign (documented deviation from the unbounded queueing
model): the queue is a ``Qcap``-slot buffer and arrivals beyond ``A_max`` per
slot are dropped AND COUNTED (``dropped`` in the result) — runs whose drop
count is nonzero must be treated as saturated, not stable.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .partition import k_red

INF_SLOT = jnp.iinfo(jnp.int32).max


# ---------------------------------------------------------------------------
# primitive scheduling ops (shared with the serving engine)
# ---------------------------------------------------------------------------
def best_fit_server(residuals: jax.Array, size: jax.Array) -> jax.Array:
    """Tightest feasible server for one job: argmin residual among residuals
    >= size; returns -1 if none fits. O(L) vectorized."""
    feasible = residuals >= size
    masked = jnp.where(feasible, residuals, jnp.inf)
    idx = jnp.argmin(masked)
    return jnp.where(feasible.any(), idx, -1)


def best_fit_place(residuals: jax.Array, sizes: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Sequentially Best-Fit place a batch of jobs (pure-jnp reference used by
    the serving engine; kernels/best_fit provides the Pallas TPU version).

    Returns (assignment (N,) int32 with -1 = rejected, new residuals)."""

    def body(resid, size):
        srv = best_fit_server(resid, size)
        ok = srv >= 0
        resid = jnp.where(ok, resid.at[srv].add(-size), resid)
        return resid, jnp.where(ok, srv, -1)

    new_resid, assign = jax.lax.scan(body, residuals, sizes)
    return assign.astype(jnp.int32), new_resid


def largest_fitting_job(queue: jax.Array, cap: jax.Array) -> jax.Array:
    """Index of the largest queued job with size <= cap (BF-S step);
    -1 if none. Zero entries mean empty queue slots."""
    fits = (queue > 0) & (queue <= cap)
    masked = jnp.where(fits, queue, -jnp.inf)
    idx = jnp.argmax(masked)
    return jnp.where(fits.any(), idx, -1)


def max_weight_config_jax(J: int, vq_sizes: jax.Array) -> tuple[jax.Array, jax.Array]:
    """argmax_{k in K_RED^{(J)}} <k, Q>  (paper Eq. 8), jit/vmap-friendly."""
    confs = jnp.asarray(k_red(J))
    w = confs @ vq_sizes.astype(jnp.int32)
    i = jnp.argmax(w)
    return i, confs[i]


def vq_type_of(sizes: jax.Array, J: int) -> jax.Array:
    """Partition-I type of float sizes in (0,1] (vectorized, jittable)."""
    m = jnp.clip(jnp.floor(-jnp.log2(jnp.maximum(sizes, 1e-9))), 0, J - 1)
    # size in (2^-(m+1), 2^-m]: fix boundary where size == 2^-m exactly
    upper = jnp.exp2(-m)
    m = jnp.where(sizes > upper, m - 1, m).astype(jnp.int32)
    upper = jnp.exp2(-m.astype(sizes.dtype))
    even = 3.0 * sizes > 2.0 * upper
    t = jnp.where(even, 2 * m, 2 * m + 1)
    return jnp.where(sizes <= 2.0 ** (-J), 2 * J - 1, t).astype(jnp.int32)


# ---------------------------------------------------------------------------
# BF-J/S cluster simulation as a lax.scan
# ---------------------------------------------------------------------------
class BFJSState(NamedTuple):
    srv: jax.Array       # (L, K) float32 job sizes in servers (0 = empty slot)
    dep: jax.Array       # (L, K) int32 departure slot (INF_SLOT when empty)
    queue: jax.Array     # (Qcap,) float32 queued sizes (0 = empty)
    dropped: jax.Array   # () int32 arrivals dropped by the fixed-size buffer
    key: jax.Array


class BFJSResult(NamedTuple):
    queue_len: jax.Array   # (T,) int32
    occupancy: jax.Array   # (T,) float32 total occupied capacity
    departed: jax.Array    # (T,) int32 cumulative departures
    dropped: jax.Array     # () int32


def _geometric(key: jax.Array, mu: float, shape=()) -> jax.Array:
    u = jax.random.uniform(key, shape, minval=1e-7, maxval=1.0)
    return jnp.maximum(jnp.ceil(jnp.log(u) / jnp.log1p(-mu)), 1.0).astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("sampler", "L", "K", "Qcap", "A_max", "horizon"),
)
def run_bfjs(key: jax.Array,
             lam: float,
             mu: float,
             sampler: Callable[[jax.Array, int], jax.Array],
             L: int = 8,
             K: int = 16,
             Qcap: int = 512,
             A_max: int = 8,
             horizon: int = 10_000) -> BFJSResult:
    """Simulate BF-J/S on L unit-capacity servers for `horizon` slots.

    sampler(key, n) -> (n,) float sizes in (0,1].  vmap over `key` for
    Monte-Carlo ensembles.
    """

    def place_in_server(srv_i, dep_i, size, dslot):
        slot = jnp.argmax(srv_i == 0.0)
        return srv_i.at[slot].set(size), dep_i.at[slot].set(dslot)

    def slot_step(state: BFJSState, t: jax.Array):
        srv, dep, queue, dropped, key = state
        key, k_arr, k_n, k_sizes, k_dur = jax.random.split(key, 5)

        # 1. departures
        leaving = dep == t
        freed = leaving.any(axis=1)
        n_dep = leaving.sum()
        srv = jnp.where(leaving, 0.0, srv)
        dep = jnp.where(leaving, INF_SLOT, dep)

        # 2. arrivals -> queue (record the slots they landed in)
        n = jnp.minimum(jax.random.poisson(k_n, lam), A_max)
        sizes = sampler(k_sizes, A_max)
        valid = jnp.arange(A_max) < n
        empty_slots = jnp.nonzero(queue == 0.0, size=A_max, fill_value=Qcap)[0]
        landed = valid & (empty_slots < Qcap)
        dropped = dropped + (valid & ~landed).sum()
        queue = queue.at[jnp.where(landed, empty_slots, Qcap)].set(
            jnp.where(landed, sizes, 0.0), mode="drop")
        new_pos = jnp.where(landed, empty_slots, -1)

        durs = _geometric(k_dur, mu, (L * K + A_max,))
        dcounter = 0

        # 3. BF-S over freed servers: fill each with the largest fitting job.
        def bfs_server(i, carry):
            srv, dep, queue, dc = carry

            def try_place(carry):
                srv, dep, queue, dc, go = carry
                resid = 1.0 - srv[i].sum()
                j = largest_fitting_job(queue, resid)
                ok = j >= 0

                def do(args):
                    srv, dep, queue, dc = args
                    size = queue[j]
                    s_i, d_i = place_in_server(srv[i], dep[i], size,
                                               t + durs[dc])
                    return (srv.at[i].set(s_i), dep.at[i].set(d_i),
                            queue.at[j].set(0.0), dc + 1)

                srv, dep, queue, dc = jax.lax.cond(
                    ok, do, lambda a: a, (srv, dep, queue, dc))
                return srv, dep, queue, dc, ok

            def fill(carry):
                srv, dep, queue, dc = carry
                out = jax.lax.while_loop(
                    lambda c: c[4],
                    try_place,
                    (srv, dep, queue, dc, True))
                return out[:4]

            return jax.lax.cond(freed[i], fill, lambda c: c,
                                (srv, dep, queue, dc))

        srv, dep, queue, dcounter = jax.lax.fori_loop(
            0, L, bfs_server, (srv, dep, queue, dcounter))

        # 4. BF-J over the new arrivals still in queue.
        def bfj_job(a, carry):
            srv, dep, queue, dc = carry
            pos = new_pos[a]
            size = jnp.where(pos >= 0, queue[jnp.maximum(pos, 0)], 0.0)
            resid = 1.0 - srv.sum(axis=1)
            s_idx = best_fit_server(resid, jnp.where(size > 0, size, jnp.inf))
            ok = (size > 0) & (s_idx >= 0)

            def do(args):
                srv, dep, queue, dc = args
                s_i, d_i = place_in_server(srv[s_idx], dep[s_idx], size,
                                           t + durs[L * K + a])
                return (srv.at[s_idx].set(s_i), dep.at[s_idx].set(d_i),
                        queue.at[pos].set(0.0), dc)

            return jax.lax.cond(ok, do, lambda x: x, (srv, dep, queue, dc))

        srv, dep, queue, dcounter = jax.lax.fori_loop(
            0, A_max, bfj_job, (srv, dep, queue, dcounter))

        out = (
            (queue > 0).sum().astype(jnp.int32),
            srv.sum(),
            n_dep.astype(jnp.int32),
        )
        return BFJSState(srv, dep, queue, dropped, key), out

    state0 = BFJSState(
        srv=jnp.zeros((L, K), jnp.float32),
        dep=jnp.full((L, K), INF_SLOT, jnp.int32),
        queue=jnp.zeros(Qcap, jnp.float32),
        dropped=jnp.zeros((), jnp.int32),
        key=key,
    )
    state, (qlen, occ, ndep) = jax.lax.scan(
        slot_step, state0, jnp.arange(horizon, dtype=jnp.int32))
    return BFJSResult(qlen, occ, jnp.cumsum(ndep), state.dropped)


def monte_carlo_bfjs(keys: jax.Array, lam: float, mu: float, sampler,
                     **kw) -> BFJSResult:
    """vmap over seeds: one simulated cluster per key."""
    fn = functools.partial(run_bfjs, lam=lam, mu=mu, sampler=sampler, **kw)
    return jax.vmap(fn)(keys)
