"""Back-compat shim: the PR 1 ``jax_sched`` monolith is now the
``repro.core.engine`` package (streams / ops / bfjs / vqs / api).

Every public name of the old module is re-exported here with identical
behaviour — ``run_bfjs`` / ``monte_carlo_bfjs`` keep their exact PR 1
signatures and trajectories (asserted by tests/test_jax_sched.py) — plus
the policy-generic entry points (``run_policy`` et al.) so existing
importers migrate incrementally.  New code should import from
``repro.core.engine`` directly.
"""
from __future__ import annotations

from .engine import (  # noqa: F401
    BFJSResult, BFJSState, BFJSStreams, ENGINES, INF_SLOT, PolicyResult,
    PolicySpec, SchedStreams, Workload, available_policies, best_fit_place,
    best_fit_server, get_policy, k_red_jnp, largest_fitting_job,
    make_streams, max_weight_config_jax, monte_carlo_bfjs,
    monte_carlo_policy, monte_carlo_vqs, register_policy,
    resolve_work_steps, run_bfjs, run_bfjs_mr_streams, run_bfjs_mr_trace,
    run_bfjs_streams, run_bfjs_trace, run_policy, run_policy_streams,
    run_vqs, run_vqs_streams, run_vqs_trace, streams_from_trace,
    vq_type_of, vq_type_of_grid,
)
from .engine.streams import _geometric, _resolve_work_steps  # noqa: F401
