"""Queue structures for the event-driven engine.

``SortedJobQueue``  — exact multiset of queued jobs keyed by grid size, with
O(log RES) largest-fitting-job queries (Best-Fit server perspective) and
FIFO order inside each size bucket.

``VirtualQueues``   — the paper's VQs under partition I: per-type FIFO order
(VQS schedules head-of-line) AND per-type sorted access (VQS-BF schedules
largest-fitting), plus the global sorted view BF-S needs in VQS-BF step (iii).

Jobs are identified by integer ids; sizes are grid ints (quantize.RES).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass

import numpy as np

from .fenwick import Fenwick
from .partition import PartitionI
from .quantize import RES


@dataclass(slots=True)
class Job:
    jid: int
    size: int        # actual grid size (occupies this much)
    eff_size: int    # occupancy size (== size except last-VQ round-up)
    vq: int          # virtual-queue index under partition I (or -1)
    arrival: int     # arrival slot
    dur: int = 0     # fixed service duration (0 => draw from ServiceModel)


class SortedJobQueue:
    """Multiset of jobs ordered by effective size; FIFO within equal sizes."""

    def __init__(self):
        self._fen = Fenwick(RES + 1)
        self._buckets: dict[int, deque[Job]] = {}
        self._count = 0

    def __len__(self) -> int:
        return self._count

    def push(self, job: Job) -> None:
        b = self._buckets.get(job.eff_size)
        if b is None:
            b = deque()
            self._buckets[job.eff_size] = b
        b.append(job)
        self._fen.add(job.eff_size, 1)
        self._count += 1

    def pop_largest_leq(self, cap: int) -> Job | None:
        """Remove and return the largest job with eff_size <= cap (FIFO among
        equals). None if nothing fits."""
        key = self._fen.max_leq(min(cap, RES))
        if key < 0:
            return None
        b = self._buckets[key]
        job = b.popleft()
        if not b:
            del self._buckets[key]
        self._fen.add(key, -1)
        self._count -= 1
        return job

    def peek_largest_leq(self, cap: int) -> int:
        """Largest eff_size <= cap present, or -1."""
        return self._fen.max_leq(min(cap, RES))

    def remove(self, job: Job) -> bool:
        """Remove a specific job (linear in its bucket — buckets are small)."""
        b = self._buckets.get(job.eff_size)
        if not b:
            return False
        try:
            b.remove(job)
        except ValueError:
            return False
        if not b:
            del self._buckets[job.eff_size]
        self._fen.add(job.eff_size, -1)
        self._count -= 1
        return True

    def total_size(self) -> int:
        # O(buckets); used by diagnostics only.
        return sum(k * len(v) for k, v in self._buckets.items())


class FIFOJobQueue:
    """Plain FIFO queue (the FIFO-FF baseline)."""

    def __init__(self):
        self._q: deque[Job] = deque()

    def __len__(self) -> int:
        return len(self._q)

    def push(self, job: Job) -> None:
        self._q.append(job)

    def head(self) -> Job | None:
        return self._q[0] if self._q else None

    def pop(self) -> Job:
        return self._q.popleft()


class VirtualQueues:
    """The 2J virtual queues of partition I.

    Each VQ keeps (a) FIFO order with lazy deletion (for VQS head-of-line
    scheduling) and (b) a sorted multiset (for VQS-BF largest-fit
    scheduling and the global BF-S sweep).
    """

    def __init__(self, J: int):
        self.part = PartitionI(J)
        self.J = J
        n = 2 * J
        self._fifo: list[deque[Job]] = [deque() for _ in range(n)]
        self._sorted: list[SortedJobQueue] = [SortedJobQueue() for _ in range(n)]
        self._removed: set[int] = set()
        self.sizes = np.zeros(n, dtype=np.int64)  # |VQ_j| vector Q

    def __len__(self) -> int:
        return int(self.sizes.sum())

    def classify(self, size_int: int) -> tuple[int, int]:
        vq = self.part.type_of_scalar(size_int)
        eff = max(size_int, self.part.min_grid_size) if vq == 2 * self.J - 1 else size_int
        return vq, eff

    def push(self, job: Job) -> None:
        self._fifo[job.vq].append(job)
        self._sorted[job.vq].push(job)
        self.sizes[job.vq] += 1

    def head(self, vq: int) -> Job | None:
        q = self._fifo[vq]
        while q and q[0].jid in self._removed:
            self._removed.discard(q[0].jid)
            q.popleft()
        return q[0] if q else None

    def pop_head(self, vq: int) -> Job | None:
        job = self.head(vq)
        if job is None:
            return None
        self._fifo[vq].popleft()
        self._sorted[vq].remove(job)
        self.sizes[vq] -= 1
        return job

    def pop_largest_leq(self, vq: int, cap: int) -> Job | None:
        job = self._sorted[vq].pop_largest_leq(cap)
        if job is None:
            return None
        self._removed.add(job.jid)  # lazy-delete from FIFO view
        self.sizes[vq] -= 1
        return job

    def remove_specific(self, job: Job) -> bool:
        """Remove a particular queued job (used by the arrival-side BF-J pass
        of VQS-BF)."""
        if self._sorted[job.vq].remove(job):
            self._removed.add(job.jid)
            self.sizes[job.vq] -= 1
            return True
        return False

    def pop_largest_leq_any(self, cap: int) -> Job | None:
        """Largest fitting job across ALL VQs (BF-S sweep in VQS-BF)."""
        best_vq, best_key = -1, -1
        for j in range(2 * self.J):
            if self.sizes[j] == 0:
                continue
            k = self._sorted[j].peek_largest_leq(cap)
            if k > best_key:
                best_key, best_vq = k, j
        if best_vq < 0:
            return None
        return self.pop_largest_leq(best_vq, cap)
