"""Best-Fit based schedulers (paper Section IV).

``BFJS``  — BF-J/S, the paper's main Best-Fit algorithm (Theorem 2: >= 1/2 rho*):
   step 1: BF-S over servers that had departures last slot (fill each with the
           largest queued job that fits, repeatedly);
   step 2: BF-J over newly arrived jobs not scheduled in step 1 (each goes to
           the tightest feasible server, else queues).

``BFJ`` / ``BFS`` — the standalone adaptations (Section IV.A), kept for
ablations; they rescan the whole queue / all servers each slot, so they are
O(Q)/O(L) per slot and intended for small experiments.
"""
from __future__ import annotations

from collections import deque

from .base import Scheduler
from .queues import Job, SortedJobQueue


class BFJS(Scheduler):
    """BF-J/S; with ``stall=True`` adds the Section-VIII stalling technique
    for general (non-geometric) service times: a server operating in an
    inefficient configuration (less than half full with nothing queued that
    restores efficiency) stops accepting jobs until it drains empty, which
    re-creates the renewal epochs the geometric analysis relies on."""

    name = "bf-js"

    def __init__(self, stall: bool = False):
        self.stall = stall
        if stall:
            self.name = "bf-js-stall"

    def bind(self, cluster, service, rng):
        super().bind(cluster, service, rng)
        self.queue = SortedJobQueue()
        self._new: list[Job] = []
        self._stalled: set[int] = set()
        return self

    def on_arrivals(self, t, jobs):
        for job in jobs:
            self.queue.push(job)
        self._new = jobs

    def _maybe_stall(self, server: int) -> None:
        """Stall when the server is inefficient (< half full) and the queue
        cannot top it up past half."""
        cl = self.cluster
        cap = int(cl.capacity[server])
        occ = cl.occupancy(server)
        if 0 < occ < cap // 2 and \
                self.queue.peek_largest_leq(int(cl.residual[server])) < 0:
            self._stalled.add(server)

    def schedule(self, t, freed, emptied):
        cl = self.cluster
        if self.stall:
            self._stalled -= emptied          # drained: back in service
        # Step 1: BF-S over servers freed by departures during this slot.
        for server in sorted(freed):
            if server in self._stalled:
                continue
            while True:
                job = self.queue.pop_largest_leq(int(cl.residual[server]))
                if job is None:
                    break
                self._place(t, server, job)
            if self.stall:
                self._maybe_stall(server)
        # Step 2: BF-J over the new arrivals that step 1 did not place.
        for job in self._new:
            server = self._tightest_unstalled(job.eff_size)
            if server >= 0 and self.queue.remove(job):
                self._place(t, server, job)
        self._new = []

    def _tightest_unstalled(self, size: int) -> int:
        cl = self.cluster
        if not self._stalled:
            return cl.tightest_feasible(size)
        best, best_r = -1, None
        for server in range(cl.L):
            if server in self._stalled:
                continue
            r = int(cl.residual[server])
            if r >= size and (best_r is None or r < best_r):
                best, best_r = server, r
        return best

    def queue_len(self):
        return len(self.queue)

    def queued_total_size(self):
        return self.queue.total_size()


class BFJ(Scheduler):
    """Best-Fit from the job's perspective, full rescan each slot."""

    name = "bf-j"

    def bind(self, cluster, service, rng):
        super().bind(cluster, service, rng)
        self.queue: deque[Job] = deque()
        return self

    def on_arrivals(self, t, jobs):
        self.queue.extend(jobs)

    def schedule(self, t, freed, emptied):
        cl = self.cluster
        remaining: deque[Job] = deque()
        while self.queue:
            job = self.queue.popleft()
            server = cl.tightest_feasible(job.eff_size)
            if server >= 0:
                self._place(t, server, job)
            else:
                remaining.append(job)
        self.queue = remaining

    def queue_len(self):
        return len(self.queue)


class BFS(Scheduler):
    """Best-Fit from the server's perspective, full rescan each slot."""

    name = "bf-s"

    def bind(self, cluster, service, rng):
        super().bind(cluster, service, rng)
        self.queue = SortedJobQueue()
        return self

    def on_arrivals(self, t, jobs):
        for job in jobs:
            self.queue.push(job)

    def schedule(self, t, freed, emptied):
        cl = self.cluster
        for server in range(cl.L):
            while True:
                job = self.queue.pop_largest_leq(int(cl.residual[server]))
                if job is None:
                    break
                self._place(t, server, job)

    def queue_len(self):
        return len(self.queue)

    def queued_total_size(self):
        return self.queue.total_size()
