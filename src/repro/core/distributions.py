"""Job-size distributions F_R over (0, 1].

Every distribution exposes sampling plus the analytic interface the
Theorem-1 machinery needs (cdf / quantile / mean / discrete atoms).
"""
from __future__ import annotations

import abc
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np


class JobSizeDistribution(abc.ABC):
    """cdf F_R: (0,1] -> [0,1]; sizes are normalized resource requirements."""

    @abc.abstractmethod
    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        ...

    @abc.abstractmethod
    def cdf(self, x: np.ndarray | float) -> np.ndarray | float:
        ...

    @abc.abstractmethod
    def quantile(self, q: np.ndarray | float) -> np.ndarray | float:
        ...

    @abc.abstractmethod
    def mean(self) -> float:
        ...

    def min_size(self) -> float:
        """Essential infimum of the support (paper's u)."""
        return float(self.quantile(0.0))

    def atoms(self) -> tuple[np.ndarray, np.ndarray]:
        """(locations, probabilities) of discrete atoms; empty if continuous."""
        return np.empty(0), np.empty(0)


@dataclass
class Uniform(JobSizeDistribution):
    """U[a, b] with 0 < a <= b <= 1 (paper Fig. 4 uses [0.01,0.19] / [0.1,0.9])."""

    a: float
    b: float

    def __post_init__(self):
        if not (0.0 < self.a <= self.b <= 1.0):
            raise ValueError(f"need 0 < a <= b <= 1, got [{self.a}, {self.b}]")

    def sample(self, rng, n):
        return rng.uniform(self.a, self.b, size=n)

    def cdf(self, x):
        x = np.asarray(x, dtype=np.float64)
        if self.b == self.a:
            return (x >= self.a).astype(np.float64)
        return np.clip((x - self.a) / (self.b - self.a), 0.0, 1.0)

    def quantile(self, q):
        q = np.asarray(q, dtype=np.float64)
        return self.a + q * (self.b - self.a)

    def mean(self):
        return 0.5 * (self.a + self.b)


@dataclass
class Discrete(JobSizeDistribution):
    """Finite-type distribution: P(R = sizes[i]) = probs[i]."""

    sizes: Sequence[float]
    probs: Sequence[float]
    _sizes: np.ndarray = field(init=False, repr=False)
    _probs: np.ndarray = field(init=False, repr=False)
    _cum: np.ndarray = field(init=False, repr=False)

    def __post_init__(self):
        s = np.asarray(self.sizes, dtype=np.float64)
        p = np.asarray(self.probs, dtype=np.float64)
        if np.any(s <= 0) or np.any(s > 1):
            raise ValueError("sizes must lie in (0, 1]")
        if abs(p.sum() - 1.0) > 1e-9:
            raise ValueError("probs must sum to 1")
        order = np.argsort(s)
        self._sizes, self._probs = s[order], p[order]
        self._cum = np.cumsum(self._probs)

    def sample(self, rng, n):
        idx = rng.choice(len(self._sizes), size=n, p=self._probs)
        return self._sizes[idx]

    def cdf(self, x):
        x = np.asarray(x, dtype=np.float64)
        idx = np.searchsorted(self._sizes, x, side="right")
        cum = np.concatenate([[0.0], self._cum])
        return cum[idx]

    def quantile(self, q):
        q = np.asarray(q, dtype=np.float64)
        idx = np.searchsorted(self._cum, q, side="left")
        idx = np.clip(idx, 0, len(self._sizes) - 1)
        return self._sizes[idx]

    def mean(self):
        return float(np.dot(self._sizes, self._probs))

    def min_size(self):
        return float(self._sizes[0])

    def atoms(self):
        return self._sizes.copy(), self._probs.copy()


@dataclass
class TruncatedPareto(JobSizeDistribution):
    """Heavy-tailed sizes on [a, 1]: pdf ~ x^-(alpha+1), truncated.

    Models the skewed memory-request distributions seen in the Google trace
    (many small tasks, a long tail of large ones).
    """

    a: float = 0.01
    alpha: float = 1.1

    def __post_init__(self):
        if not (0 < self.a < 1):
            raise ValueError("a in (0,1)")
        self._za = self.a**-self.alpha
        self._z1 = 1.0
        self._norm = self._za - self._z1

    def cdf(self, x):
        x = np.asarray(x, dtype=np.float64)
        x = np.clip(x, self.a, 1.0)
        return (self._za - x**-self.alpha) / self._norm

    def quantile(self, q):
        q = np.asarray(q, dtype=np.float64)
        return (self._za - q * self._norm) ** (-1.0 / self.alpha)

    def sample(self, rng, n):
        return self.quantile(rng.uniform(0.0, 1.0, size=n))

    def mean(self):
        al, a = self.alpha, self.a
        if abs(al - 1.0) < 1e-12:
            raw = np.log(1.0 / a)
        else:
            raw = al / (al - 1.0) * (a ** (1.0 - al) - 1.0) / (a**-al - 1.0)
            return float(raw)
        return float(raw / self._norm * al)


@dataclass
class Mixture(JobSizeDistribution):
    """Mixture of components — e.g. continuous body + discrete spikes,
    matching the 'general distribution' of Theorem 1's appendix."""

    components: Sequence[JobSizeDistribution]
    weights: Sequence[float]

    def __post_init__(self):
        w = np.asarray(self.weights, dtype=np.float64)
        if abs(w.sum() - 1.0) > 1e-9:
            raise ValueError("weights must sum to 1")
        self._w = w

    def sample(self, rng, n):
        which = rng.choice(len(self.components), size=n, p=self._w)
        out = np.empty(n, dtype=np.float64)
        for i, comp in enumerate(self.components):
            mask = which == i
            k = int(mask.sum())
            if k:
                out[mask] = comp.sample(rng, k)
        return out

    def cdf(self, x):
        return sum(w * np.asarray(c.cdf(x)) for w, c in zip(self._w, self.components))

    def quantile(self, q):
        # generic bisection on the mixture cdf
        q = np.atleast_1d(np.asarray(q, dtype=np.float64))
        lo = np.full_like(q, 1e-9)
        hi = np.ones_like(q)
        for _ in range(60):
            mid = 0.5 * (lo + hi)
            c = np.asarray(self.cdf(mid))
            lo = np.where(c < q, mid, lo)
            hi = np.where(c >= q, mid, hi)
        return hi if hi.shape else float(hi)

    def mean(self):
        return float(sum(w * c.mean() for w, c in zip(self._w, self.components)))

    def min_size(self):
        return min(c.min_size() for c in self.components)

    def atoms(self):
        locs, ps = [], []
        for w, c in zip(self._w, self.components):
            a_l, a_p = c.atoms()
            locs.append(a_l)
            ps.append(w * a_p)
        return np.concatenate(locs), np.concatenate(ps)


@dataclass
class Empirical(JobSizeDistribution):
    """Empirical distribution of observed sizes (trace replay / bootstrap)."""

    observations: np.ndarray

    def __post_init__(self):
        obs = np.asarray(self.observations, dtype=np.float64)
        obs = obs[(obs > 0) & (obs <= 1.0)]
        if len(obs) == 0:
            raise ValueError("no valid observations in (0,1]")
        self._sorted = np.sort(obs)

    def sample(self, rng, n):
        idx = rng.integers(0, len(self._sorted), size=n)
        return self._sorted[idx]

    def cdf(self, x):
        x = np.asarray(x, dtype=np.float64)
        return np.searchsorted(self._sorted, x, side="right") / len(self._sorted)

    def quantile(self, q):
        q = np.asarray(q, dtype=np.float64)
        idx = np.clip((q * len(self._sorted)).astype(int), 0, len(self._sorted) - 1)
        return self._sorted[idx]

    def mean(self):
        return float(self._sorted.mean())

    def min_size(self):
        return float(self._sorted[0])
