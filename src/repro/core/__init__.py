"""Core library: the paper's scheduling algorithms and stability theory.

Psychas & Ghaderi, "Scheduling Jobs with Random Resource Requirements in
Computing Clusters" (2019).
"""
from .base import Scheduler
from .best_fit import BFJ, BFJS, BFS
from .cluster_state import Cluster, ServiceModel, poisson_arrivals
from .distributions import (Discrete, Empirical, JobSizeDistribution, Mixture,
                            TruncatedPareto, Uniform)
from .fifo import FIFOFF
from .maxweight import MaxWeight
from .partition import PartitionI, k_red, k_red_is_feasible, max_weight_config
from .quantize import RES, TWO_THIRDS, from_grid, to_grid
from .queues import Job, SortedJobQueue, VirtualQueues
from .simulator import SimResult, simulate, simulate_trace
from .stability import (enumerate_configs, maximal_configs, rho_bounds,
                        rho_star_discrete, rho_star_upper_bound)
from .trace import (MachineEvents, Trace, collapse_resources,
                    empirical_size_stats, iter_trace_csv,
                    load_machine_events_csv, load_trace_csv,
                    scale_arrivals, scan_trace_maxima,
                    synthesize_google_like_trace)
from .vqs import VQS
from .vqs_bf import VQSBF

__all__ = [
    "Scheduler", "BFJ", "BFJS", "BFS", "Cluster", "ServiceModel",
    "poisson_arrivals", "Discrete", "Empirical", "JobSizeDistribution",
    "Mixture", "TruncatedPareto", "Uniform", "FIFOFF", "MaxWeight",
    "PartitionI", "k_red", "k_red_is_feasible", "max_weight_config",
    "RES", "TWO_THIRDS", "from_grid", "to_grid", "Job", "SortedJobQueue",
    "VirtualQueues", "SimResult", "simulate", "simulate_trace",
    "enumerate_configs", "maximal_configs", "rho_bounds",
    "rho_star_discrete", "rho_star_upper_bound", "MachineEvents", "Trace",
    "collapse_resources", "empirical_size_stats", "iter_trace_csv",
    "load_machine_events_csv", "load_trace_csv", "scale_arrivals",
    "scan_trace_maxima", "synthesize_google_like_trace", "VQS", "VQSBF",
]
