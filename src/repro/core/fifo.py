"""FIFO-FF baseline (paper Section VII.B).

Jobs are served strictly in arrival order: the head-of-line job is packed
into the FIRST server (lowest index) with sufficient residual capacity
(First-Fit); if it fits nowhere the queue blocks (head-of-line blocking) —
this is the paper's strengthened version of Hadoop's slot-based FIFO.
"""
from __future__ import annotations

from .base import Scheduler
from .queues import FIFOJobQueue


class FIFOFF(Scheduler):
    name = "fifo-ff"

    def bind(self, cluster, service, rng):
        super().bind(cluster, service, rng)
        self.queue = FIFOJobQueue()
        return self

    def on_arrivals(self, t, jobs):
        for job in jobs:
            self.queue.push(job)

    def schedule(self, t, freed, emptied):
        cl = self.cluster
        while True:
            job = self.queue.head()
            if job is None:
                return
            server = cl.first_fit(job.eff_size)
            if server < 0:
                return  # head-of-line blocking
            self.queue.pop()
            self._place(t, server, job)

    def queue_len(self):
        return len(self.queue)
