"""Maximum supportable workload rho* and the Theorem-1 machinery.

* ``enumerate_configs``  — all feasible configurations of a finite-type
  system (Definition 1), by bounded DFS.
* ``rho_star_discrete``  — Eq. (4): the LP
      max  t   s.t.  t * P_j <= L * sum_k p_k k_j,  sum_k p_k <= 1,  p >= 0
  solved with an in-repo dense simplex (Bland's rule; no scipy).
* ``quantile_partition`` / ``rounded_types`` / ``rho_bounds`` — the
  upper/lower-rounded virtual-queue bounds rho_bar*(X^(n)) / rho_lower*(X^(n))
  of Theorem 1 under the quantile partitions X^(n); both converge to rho*.
* ``rho_star_upper_bound`` — Lemma 1: rho* <= L / mean(R).
"""
from __future__ import annotations

import numpy as np

from .distributions import JobSizeDistribution
from .quantize import RES, to_grid

MAX_CONFIGS = 500_000


# ---------------------------------------------------------------------------
# feasible configuration enumeration
# ---------------------------------------------------------------------------
def enumerate_configs(sizes_int: np.ndarray, capacity: int = RES,
                      max_configs: int = MAX_CONFIGS) -> np.ndarray:
    """All maximal-or-smaller feasible configurations (including zero).

    Returns an int array (N, J). Raises if the count exceeds ``max_configs``
    (the paper's point: this explodes with the number of types).
    """
    sizes = np.asarray(sizes_int, dtype=np.int64)
    J = len(sizes)
    out: list[tuple[int, ...]] = []
    cur = [0] * J

    def rec(j: int, remaining: int) -> None:
        if len(out) > max_configs:
            raise RuntimeError(f"configuration count exceeds {max_configs}")
        if j == J:
            out.append(tuple(cur))
            return
        max_k = remaining // sizes[j] if sizes[j] > 0 else 0
        for k in range(int(max_k) + 1):
            cur[j] = k
            rec(j + 1, remaining - k * int(sizes[j]))
        cur[j] = 0

    rec(0, int(capacity))
    return np.array(out, dtype=np.int64)


def maximal_configs(configs: np.ndarray, sizes_int: np.ndarray,
                    capacity: int = RES) -> np.ndarray:
    """Filter to maximal configurations (no job of any type can be added)."""
    sizes = np.asarray(sizes_int, dtype=np.int64)
    used = configs @ sizes
    resid = capacity - used
    can_add = resid[:, None] >= sizes[None, :]
    return configs[~can_add.any(axis=1)]


# ---------------------------------------------------------------------------
# dense simplex (maximize c^T x, A x <= b, x >= 0), Bland's rule
# ---------------------------------------------------------------------------
def _simplex(c: np.ndarray, A: np.ndarray, b: np.ndarray,
             max_iter: int = 100_000) -> tuple[float, np.ndarray]:
    m, n = A.shape
    if np.any(b < -1e-12):
        raise ValueError("b must be >= 0 (slack basis start)")
    # tableau: [A | I | b], objective row: [-c | 0 | 0]
    T = np.zeros((m + 1, n + m + 1))
    T[:m, :n] = A
    T[:m, n : n + m] = np.eye(m)
    T[:m, -1] = b
    T[m, :n] = -c
    basis = list(range(n, n + m))

    basis_arr = np.asarray(basis)
    for _ in range(max_iter):
        # Bland: entering = smallest index with negative reduced cost
        neg = np.nonzero(T[m, :-1] < -1e-10)[0]
        if neg.size == 0:
            break  # optimal
        enter = int(neg[0])
        col = T[:m, enter]
        pos = col > 1e-10
        if not pos.any():
            raise RuntimeError("LP unbounded")
        ratios = np.where(pos, T[:m, -1] / np.where(pos, col, 1.0), np.inf)
        best = ratios.min()
        ties = np.nonzero(ratios <= best + 1e-12)[0]
        # Bland tie-break: smallest basis-variable index
        leave = int(ties[np.argmin(basis_arr[ties])])
        piv = T[leave, enter]
        T[leave] /= piv
        factors = T[:, enter].copy()
        factors[leave] = 0.0
        T -= np.outer(factors, T[leave])
        basis_arr[leave] = enter
    else:
        raise RuntimeError("simplex iteration limit")
    basis = basis_arr.tolist()

    x = np.zeros(n + m)
    for i, bi in enumerate(basis):
        x[bi] = T[i, -1]
    return float(T[m, -1]), x[:n]


def rho_star_discrete(sizes: np.ndarray, probs: np.ndarray, L: int = 1,
                      capacity: int = RES, configs: np.ndarray | None = None,
                      max_configs: int = MAX_CONFIGS) -> float:
    """Maximum supportable workload rho* (Eq. 4) for a finite-type system.

    ``sizes`` may be floats in (0,1] (quantized to the grid) or grid ints.
    """
    sizes = np.asarray(sizes)
    if sizes.dtype.kind == "f":
        sizes_int = to_grid(sizes)
    else:
        sizes_int = sizes.astype(np.int64)
    P = np.asarray(probs, dtype=np.float64)
    keep = P > 0
    sizes_int, P = sizes_int[keep], P[keep]
    if configs is None:
        configs = enumerate_configs(sizes_int, capacity, max_configs)
        configs = maximal_configs(configs, sizes_int, capacity)
    K, J = configs.shape
    # variables x = [t, p_1..p_K]
    # constraints: t P_j - L sum_k p_k k_j <= 0  (J rows);  sum p <= 1
    A = np.zeros((J + 1, K + 1))
    A[:J, 0] = P
    A[:J, 1:] = -float(L) * configs.T
    A[J, 1:] = 1.0
    b = np.zeros(J + 1)
    b[J] = 1.0
    c = np.zeros(K + 1)
    c[0] = 1.0
    val, _ = _simplex(c, A, b)
    return val


def rho_star_upper_bound(dist: JobSizeDistribution, L: int) -> float:
    """Lemma 1: rho* <= L / E[R]."""
    return L / dist.mean()


# ---------------------------------------------------------------------------
# Theorem 1: quantile partitions and rounded bounds
# ---------------------------------------------------------------------------
def quantile_partition(dist: JobSizeDistribution, n: int) -> np.ndarray:
    """Boundaries xi_0=0 < xi_1 < ... < xi_{2^{n+1}} = 1 with
    F_R(xi_i) = i / 2^{n+1} (continuous F_R)."""
    m = 1 << (n + 1)
    qs = np.arange(1, m) / m
    xs = np.asarray(dist.quantile(qs), dtype=np.float64)
    return np.concatenate([[0.0], xs, [1.0]])


def rounded_types(dist: JobSizeDistribution, boundaries: np.ndarray,
                  rounding: str) -> tuple[np.ndarray, np.ndarray]:
    """(sizes, probs) of the finite-type system with sizes rounded to the
    upper (sup) or lower (inf) edge of each partition interval.

    Lower-rounding drops types rounded to 0 (they consume no resource,
    paper Appendix A)."""
    lo, hi = boundaries[:-1], boundaries[1:]
    probs = np.asarray(dist.cdf(hi)) - np.asarray(dist.cdf(lo))
    if rounding == "upper":
        sizes = hi
    elif rounding == "lower":
        sizes = lo
    else:
        raise ValueError(rounding)
    keep = (probs > 1e-15) & (sizes > 0)
    return sizes[keep], probs[keep]


def rho_bounds(dist: JobSizeDistribution, n: int, L: int = 1,
               max_configs: int = MAX_CONFIGS) -> tuple[float, float]:
    """(rho_bar*(X^(n)), rho_lower*(X^(n))) — Theorem 1's two bounds; the true
    rho* lies between them and both converge as n grows."""
    bounds = quantile_partition(dist, n)
    up_s, up_p = rounded_types(dist, bounds, "upper")
    lo_s, lo_p = rounded_types(dist, bounds, "lower")
    upper = rho_star_discrete(up_s, up_p, L, max_configs=max_configs)
    lower = rho_star_discrete(lo_s, lo_p, L, max_configs=max_configs)
    return upper, lower
