"""Fenwick (binary indexed) tree over a fixed integer key range.

Used as an exact multiset over quantized job sizes / server residuals with
O(log n):
  * add/remove of a key,
  * ``count_leq(x)`` prefix counts,
  * ``max_leq(x)``: largest present key <= x   (Best-Fit "largest fitting job"),
  * ``min_geq(x)``: smallest present key >= x  (Best-Fit "tightest server").

Keys are ints in [0, size).  The descend operations exploit the implicit
binary structure of the tree, so no per-query scans over the key range.
"""
from __future__ import annotations

import numpy as np


class Fenwick:
    __slots__ = ("n", "_pow", "tree", "total")

    def __init__(self, size: int):
        self.n = int(size)
        self._pow = 1 << (self.n.bit_length() - (0 if self.n & (self.n - 1) else 1))
        if self._pow < self.n:
            self._pow <<= 1
        self.tree = np.zeros(self.n + 1, dtype=np.int64)
        self.total = 0

    def add(self, key: int, delta: int = 1) -> None:
        i = key + 1
        t = self.tree
        while i <= self.n:
            t[i] += delta
            i += i & (-i)
        self.total += delta

    def count_leq(self, key: int) -> int:
        """Number of stored items with value <= key."""
        if key < 0:
            return 0
        i = min(key + 1, self.n)
        s = 0
        t = self.tree
        while i > 0:
            s += t[i]
            i -= i & (-i)
        return int(s)

    def kth(self, k: int) -> int:
        """Smallest key such that count_leq(key) >= k (1-indexed k)."""
        pos = 0
        rem = k
        half = self._pow
        t = self.tree
        n = self.n
        while half > 0:
            nxt = pos + half
            if nxt <= n and t[nxt] < rem:
                pos = nxt
                rem -= t[nxt]
            half >>= 1
        return pos  # 0-indexed key

    def max_leq(self, key: int) -> int:
        """Largest present key <= key, or -1 if none."""
        c = self.count_leq(key)
        if c == 0:
            return -1
        return self.kth(c)

    def min_geq(self, key: int) -> int:
        """Smallest present key >= key, or -1 if none."""
        below = self.count_leq(key - 1)
        if below >= self.total:
            return -1
        return self.kth(below + 1)


class SegTreeMax:
    """Segment tree over server indices storing max residual capacity.

    Supports ``first_fit(size)``: the smallest server index whose residual is
    >= size (First-Fit), in O(log L); and point updates.
    """

    __slots__ = ("n", "size", "tree")

    def __init__(self, values: np.ndarray):
        self.n = len(values)
        size = 1
        while size < self.n:
            size <<= 1
        self.size = size
        self.tree = np.zeros(2 * size, dtype=np.int64)
        self.tree[size : size + self.n] = values
        for i in range(size - 1, 0, -1):
            self.tree[i] = max(self.tree[2 * i], self.tree[2 * i + 1])

    def update(self, idx: int, value: int) -> None:
        i = idx + self.size
        t = self.tree
        t[i] = value
        i >>= 1
        while i:
            v = max(t[2 * i], t[2 * i + 1])
            if t[i] == v:
                break
            t[i] = v
            i >>= 1

    def get(self, idx: int) -> int:
        return int(self.tree[idx + self.size])

    def first_fit(self, size: int) -> int:
        """Smallest index with value >= size, or -1."""
        t = self.tree
        if t[1] < size:
            return -1
        i = 1
        while i < self.size:
            i <<= 1
            if t[i] < size:
                i |= 1
        idx = i - self.size
        return idx if idx < self.n else -1
