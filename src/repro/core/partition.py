"""Universal partition I (paper Eq. 6) and the reduced configuration set
K_RED^(J) (paper Eq. 7, Definition 5).

Partition I of (1/2^J, 1] into 2J subintervals (m = 0..J-1):
    I_{2m}   = (2/3 * 2^-m , 2^-m]          "even" types
    I_{2m+1} = (1/2 * 2^-m , 2/3 * 2^-m]    "odd"  types
Jobs with size <= 2^-J map to the last type (2J-1) with size rounded UP to
2^-J (paper Section V.A).

All boundaries are evaluated in exact integer arithmetic on the quantize.RES
grid:  size in I_{2m}  <=>  3*s > 2*(RES >> m)  and  s <= (RES >> m).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache

import numpy as np

from .quantize import RES, TWO_THIRDS


@dataclass(frozen=True)
class PartitionI:
    """The paper's universal partition with parameter J > 1."""

    J: int

    def __post_init__(self):
        if self.J < 2:
            raise ValueError("J must be >= 2 (paper requires J > 1)")
        if (1 << self.J) > RES:
            raise ValueError("J too large for the integer grid")

    @property
    def num_types(self) -> int:
        return 2 * self.J

    @property
    def min_grid_size(self) -> int:
        """1/2^J on the grid — sizes at/below this join the last VQ."""
        return RES >> self.J

    def type_of(self, sizes_int: np.ndarray) -> np.ndarray:
        """Vectorized type index for grid sizes. Sizes must be in [1, RES]."""
        s = np.asarray(sizes_int, dtype=np.int64)
        # m = number of halvings: size in (RES>>(m+1), RES>>m]  =>  m
        # equivalently m = floor(log2(RES / s)) with the right-closed edges.
        # Use bit tricks: m = bit_length(RES-1) - bit_length(s-1) adjusted; do
        # it with a searchsorted over the J dyadic boundaries (J <= 16: cheap).
        bounds = RES >> np.arange(1, self.J + 1)  # RES/2, RES/4, ..., RES/2^J
        # m[i] = index of first bound < s  (s > RES>>(m+1))
        m = np.searchsorted(-bounds, -s, side="right")  # descending search
        m = np.minimum(m, self.J - 1)
        upper = RES >> m
        even = 3 * s > 2 * upper  # s > (2/3) * 2^-m
        t = np.where(even, 2 * m, 2 * m + 1)
        small = s <= self.min_grid_size
        return np.where(small, 2 * self.J - 1, t).astype(np.int64)

    def type_of_scalar(self, size_int: int) -> int:
        return int(self.type_of(np.array([size_int]))[0])

    def effective_size(self, sizes_int: np.ndarray) -> np.ndarray:
        """Size used for occupancy: actual size, except the last VQ rounds UP
        to 1/2^J (paper Section V.A)."""
        s = np.asarray(sizes_int, dtype=np.int64)
        return np.where(s <= self.min_grid_size, self.min_grid_size, s)

    def upper_bound_int(self, type_idx: int) -> int:
        """sup I_j on the grid (upper-rounded VQ size)."""
        j = int(type_idx)
        m, even = divmod(j, 2)
        if even == 0:
            return RES >> m
        # odd type: sup = 2/3 * 2^-m; the largest grid value classified into
        # I_{2m+1} satisfies 3*s <= 2*(RES>>m), i.e. floor division.
        return (2 * (RES >> m)) // 3

    def interval(self, type_idx: int) -> tuple[float, float]:
        """(inf, sup] of I_j in floats, for reporting."""
        j = int(type_idx)
        m, odd = divmod(j, 2)
        if odd == 0:
            return (2.0 / 3.0 * 0.5**m, 0.5**m)
        return (0.5 ** (m + 1), 2.0 / 3.0 * 0.5**m)


@lru_cache(maxsize=32)
def k_red(J: int) -> np.ndarray:
    """The reduced configuration set K_RED^(J): array (4J-4, 2J) of ints.

    Rows (paper Eq. 7):
        2^m e_{2m},                      m = 0..J-1
        3*2^{m-1} e_{2m+1},              m = 1..J-1
        e_1 + floor(2^m / 3) e_{2m},     m = 2..J-1
        e_1 + 2^{m-1} e_{2m+1},          m = 1..J-1
    """
    if J < 2:
        raise ValueError("J >= 2")
    rows = []
    n = 2 * J
    for m in range(J):
        v = np.zeros(n, dtype=np.int64)
        v[2 * m] = 1 << m
        rows.append(v)
    for m in range(1, J):
        v = np.zeros(n, dtype=np.int64)
        v[2 * m + 1] = 3 * (1 << (m - 1))
        rows.append(v)
    for m in range(2, J):
        v = np.zeros(n, dtype=np.int64)
        v[1] = 1
        v[2 * m] = (1 << m) // 3
        rows.append(v)
    for m in range(1, J):
        v = np.zeros(n, dtype=np.int64)
        v[1] = 1
        v[2 * m + 1] = 1 << (m - 1)
        rows.append(v)
    out = np.stack(rows)
    assert out.shape == (4 * J - 4, 2 * J)
    return out


def k_red_is_feasible(J: int) -> bool:
    """Sanity check: every configuration packs within capacity when each
    type-j job takes its upper-rounded size sup I_j."""
    part = PartitionI(J)
    confs = k_red(J)
    uppers = np.array([part.upper_bound_int(j) for j in range(2 * J)])
    tot = confs @ uppers
    return bool(np.all(tot <= RES + J))  # +J: integer rounding slack of the 2/3 bounds


def max_weight_config(J: int, vq_sizes: np.ndarray) -> tuple[int, np.ndarray]:
    """argmax_{k in K_RED} <k, Q> (paper Eq. 8). Returns (row index, config)."""
    confs = k_red(J)
    w = confs @ np.asarray(vq_sizes, dtype=np.int64)
    i = int(np.argmax(w))
    return i, confs[i]
