"""Integer size grid.

All capacity arithmetic in the event-driven engine is exact integer math on a
``RES = 2**16`` grid: a job of (normalized) size ``r`` occupies
``round(r * RES)`` units of a server whose capacity is ``capacity * RES``
units.  This removes float-precision artifacts from capacity constraints
(e.g. 0.4 + 0.6 == 1.0 exactly on the grid) and makes the sorted-queue /
Fenwick structures exact.  Max quantization error is ``2**-17 ~= 7.6e-6``.
"""
from __future__ import annotations

import numpy as np

RES: int = 1 << 16  # grid resolution (server capacity == 1.0 == RES units)

# 2/3 of a unit server, used by the VQS reservation rule.  round(2/3 * RES).
TWO_THIRDS: int = (2 * RES + 1) // 3  # 43691


def to_grid(sizes) -> np.ndarray:
    """Quantize float sizes in (0, 1] to the integer grid (>= 1)."""
    arr = np.asarray(sizes, dtype=np.float64)
    q = np.rint(arr * RES).astype(np.int64)
    return np.maximum(q, 1)


def from_grid(sizes_int) -> np.ndarray:
    return np.asarray(sizes_int, dtype=np.float64) / RES
