"""Cluster state for the event-driven engine: servers, placements, departures.

Design notes (performance):
  * Service durations are drawn at placement time (geometric sampling at
    placement is distributionally identical to per-slot memoryless coin
    flips) and placed into per-slot departure buckets => total departure
    processing is O(#jobs) over the whole run, never O(#in-service) per slot.
  * Best-Fit "tightest feasible server" queries use a Fenwick tree over the
    residual-capacity histogram + residual->server-id sets => O(log RES).
  * First-Fit "lowest-index feasible server" uses a max segment tree over
    server indices => O(log L).
Heterogeneous capacities are supported (capacity array in grid units).
"""
from __future__ import annotations

from typing import Callable

import numpy as np

from .fenwick import Fenwick, SegTreeMax
from .queues import Job
from .quantize import RES


class Cluster:
    def __init__(self, L: int, capacities: np.ndarray | None = None):
        self.L = L
        if capacities is None:
            capacities = np.full(L, RES, dtype=np.int64)
        self.capacity = np.asarray(capacities, dtype=np.int64)
        self.residual = self.capacity.copy()
        self.jobs: list[dict[int, Job]] = [dict() for _ in range(L)]
        # residual histogram structures for Best-Fit
        self._fen = Fenwick(RES + 1)
        self._by_resid: dict[int, set[int]] = {}
        for s in range(L):
            self._resid_add(s, int(self.residual[s]))
        # first-fit segment tree
        self._seg = SegTreeMax(self.residual)
        # departures: slot -> list[(server, jid)]
        self._dep_buckets: dict[int, list[tuple[int, int]]] = {}
        # cancelled pending departures (job evicted/re-placed): multiset
        self._cancelled: dict[tuple[int, int], int] = {}
        self.freed_last_slot: set[int] = set()
        self.emptied_last_slot: set[int] = set()
        self.departed_jobs = 0
        self.departed_size = 0
        self.busy_area = 0  # sum over slots of total occupied size (utilization)

    # -- residual index maintenance -------------------------------------
    def _resid_add(self, server: int, r: int) -> None:
        s = self._by_resid.get(r)
        if s is None:
            s = set()
            self._by_resid[r] = s
        if not s:
            self._fen.add(r, 1)
        s.add(server)

    def _resid_remove(self, server: int, r: int) -> None:
        s = self._by_resid[r]
        s.discard(server)
        if not s:
            self._fen.add(r, -1)

    def _set_residual(self, server: int, new_r: int) -> None:
        old = int(self.residual[server])
        if new_r == old:
            return
        self._resid_remove(server, old)
        self.residual[server] = new_r
        self._resid_add(server, new_r)
        self._seg.update(server, new_r)

    # -- queries ----------------------------------------------------------
    def tightest_feasible(self, size: int) -> int:
        """Best-Fit: server with the LEAST residual >= size; -1 if none."""
        r = self._fen.min_geq(size)
        if r < 0:
            return -1
        # deterministic tie-break: smallest id in the bucket
        return min(self._by_resid[r])

    def first_fit(self, size: int) -> int:
        """First-Fit: smallest-index server with residual >= size; -1 if none."""
        return self._seg.first_fit(size)

    def occupancy(self, server: int) -> int:
        return int(self.capacity[server] - self.residual[server])

    def num_jobs(self, server: int) -> int:
        return len(self.jobs[server])

    def total_occupied(self) -> int:
        return int((self.capacity - self.residual).sum())

    # -- placement / departures -------------------------------------------
    def place(self, server: int, job: Job, depart_slot: int) -> None:
        r = int(self.residual[server]) - job.eff_size
        if r < 0:
            raise RuntimeError(
                f"capacity violation: server {server} resid {self.residual[server]} "
                f"< job {job.eff_size}"
            )
        self.jobs[server][job.jid] = job
        self._set_residual(server, r)
        self._dep_buckets.setdefault(depart_slot, []).append((server, job.jid))

    def process_departures(self, t: int) -> tuple[set[int], set[int]]:
        """Apply all departures scheduled for slot t.

        Returns (freed_servers, emptied_servers): servers with >=1 departure,
        and the subset that became empty during this slot (the paper's
        configuration-renewal epochs tau_i^l).
        """
        freed: set[int] = set()
        emptied: set[int] = set()
        bucket = self._dep_buckets.pop(t, None)
        if bucket:
            for server, jid in bucket:
                key = (server, jid)
                n = self._cancelled.get(key, 0)
                if n:  # evicted / re-placed job: skip this stale entry
                    if n == 1:
                        del self._cancelled[key]
                    else:
                        self._cancelled[key] = n - 1
                    continue
                job = self.jobs[server].pop(jid)
                self._set_residual(server, int(self.residual[server]) + job.eff_size)
                freed.add(server)
                self.departed_jobs += 1
                self.departed_size += job.eff_size
            for server in freed:
                if not self.jobs[server]:
                    emptied.add(server)
        self.freed_last_slot = freed
        self.emptied_last_slot = emptied
        return freed, emptied

    def evict(self, server: int, jid: int) -> Job:
        """Remove a job before its departure (failure / preemption); the
        pending departure entry is cancelled."""
        job = self.jobs[server].pop(jid)
        self._set_residual(server, int(self.residual[server]) + job.eff_size)
        self._cancelled[(server, jid)] = \
            self._cancelled.get((server, jid), 0) + 1
        return job

    def accumulate_utilization(self) -> None:
        self.busy_area += self.total_occupied()

    def check_invariants(self) -> None:
        """Raise on bookkeeping corruption (an ``assert`` would vanish
        under ``python -O``).  Raises
        :class:`~repro.core.engine.supervisor.InvariantViolation` — a
        ``ValueError`` subclass — naming the failed counter and servers."""
        from repro.core.engine.supervisor import InvariantViolation
        occ = np.zeros(self.L, dtype=np.int64)
        for s in range(self.L):
            occ[s] = sum(j.eff_size for j in self.jobs[s].values())
        if not np.all(occ + self.residual == self.capacity):
            bad = np.flatnonzero(occ + self.residual != self.capacity)
            raise InvariantViolation(
                f"residual mismatch on server(s) {bad.tolist()}: "
                f"occupied {occ[bad].tolist()} + residual "
                f"{self.residual[bad].tolist()} != capacity "
                f"{np.broadcast_to(self.capacity, occ.shape)[bad].tolist()}",
                invariant="occupancy_capacity")
        if not np.all(self.residual >= 0):
            bad = np.flatnonzero(self.residual < 0)
            raise InvariantViolation(
                f"negative residual on server(s) {bad.tolist()}: "
                f"{self.residual[bad].tolist()}",
                invariant="queue_nonneg")


class ServiceModel:
    """Draws service durations (in slots) at placement time."""

    def __init__(self, kind: str = "geometric", mean: float = 100.0):
        if kind not in ("geometric", "fixed"):
            raise ValueError(kind)
        self.kind = kind
        self.mean = float(mean)
        self.mu = 1.0 / self.mean

    def draw(self, rng: np.random.Generator, n: int = 1) -> np.ndarray:
        if self.kind == "geometric":
            return rng.geometric(self.mu, size=n)
        return np.full(n, int(round(self.mean)), dtype=np.int64)


ArrivalProcess = Callable[[np.random.Generator], int]


def poisson_arrivals(lam: float) -> ArrivalProcess:
    def f(rng: np.random.Generator) -> int:
        return int(rng.poisson(lam))

    return f
