"""Google-cluster-like trace synthesis and preprocessing (paper Section VII.B).

The real 2011 Google trace is not shipped in this offline container, so
``synthesize_google_like_trace`` generates a statistically faithful stand-in
reproducing the features the paper leans on:
  * hundreds of distinct discrete request values (Fig. 1): a lognormal body
    quantized to a fine grid plus a handful of heavy spikes at round values;
  * two resources (cpu, mem) with positive correlation; the paper's
    preprocessing maps each task to max(cpu, mem) — ``collapse_resources``;
  * diurnal arrival-rate modulation;
  * heavy-tailed service durations.

``scale_arrivals`` implements the paper's "traffic scaling" 1/beta: arrival
times are multiplied by beta (larger 1/beta => more jobs per slot).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class Trace:
    arrival_slots: np.ndarray   # int64, sorted
    cpu: np.ndarray             # float in (0,1]
    mem: np.ndarray             # float in (0,1]
    durations: np.ndarray       # int64 slots

    def __len__(self) -> int:
        return len(self.arrival_slots)


def synthesize_google_like_trace(n_tasks: int,
                                 horizon_slots: int,
                                 seed: int = 0,
                                 spike_values=(0.125, 0.25, 0.5),
                                 spike_prob: float = 0.3,
                                 mean_duration: float = 100.0) -> Trace:
    rng = np.random.Generator(np.random.Philox(seed))

    # --- arrivals: inhomogeneous Poisson via thinning of a diurnal rate ----
    base = n_tasks / horizon_slots
    t = np.arange(horizon_slots)
    day = max(horizon_slots / 1.5, 1.0)  # ~1.5 "days" in the window
    rate = base * (1.0 + 0.35 * np.sin(2 * np.pi * t / day) ** 2)
    rate *= n_tasks / max(rate.sum(), 1e-9)
    counts = rng.poisson(rate)
    arrival_slots = np.repeat(t, counts)

    n = len(arrival_slots)
    # --- sizes: lognormal body quantized to 1/1000 + discrete spikes -------
    body = np.exp(rng.normal(np.log(0.04), 0.9, size=n))
    body = np.clip(body, 1e-3, 1.0)
    body = np.ceil(body * 1000) / 1000  # => hundreds of distinct values
    spikes = rng.choice(spike_values, size=n)
    is_spike = rng.uniform(size=n) < spike_prob
    mem = np.where(is_spike, spikes, body)
    # cpu positively correlated with mem, with its own quantization
    cpu_noise = np.exp(rng.normal(0.0, 0.5, size=n))
    cpu = np.clip(mem * 0.6 * cpu_noise, 1e-3, 1.0)
    cpu = np.ceil(cpu * 400) / 400

    # --- durations: heavy-tailed lognormal, >= 1 slot ----------------------
    dur = np.exp(rng.normal(np.log(mean_duration * 0.5), 1.0, size=n))
    dur = np.clip(dur, 1, mean_duration * 50).astype(np.int64)

    return Trace(arrival_slots.astype(np.int64), cpu, mem, dur)


def collapse_resources(trace: Trace) -> np.ndarray:
    """Paper preprocessing: single resource = max(cpu, mem)."""
    return np.maximum(trace.cpu, trace.mem)


def scale_arrivals(trace: Trace, traffic_scaling: float) -> Trace:
    """Traffic scaling 1/beta: multiply arrival times by beta = 1/scaling."""
    beta = 1.0 / traffic_scaling
    return Trace(
        arrival_slots=np.floor(trace.arrival_slots * beta).astype(np.int64),
        cpu=trace.cpu,
        mem=trace.mem,
        durations=trace.durations,
    )


def empirical_size_stats(sizes: np.ndarray) -> dict:
    """Fig. 1-style statistics: number of distinct discrete requirements."""
    vals, counts = np.unique(np.round(sizes, 6), return_counts=True)
    return {
        "distinct_values": int(len(vals)),
        "mean": float(sizes.mean()),
        "p50": float(np.quantile(sizes, 0.5)),
        "p99": float(np.quantile(sizes, 0.99)),
        "max": float(sizes.max()),
    }
