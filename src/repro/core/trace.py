"""Google-cluster-like trace synthesis and preprocessing (paper Section VII.B).

The real 2011 Google trace is not shipped in this offline container, so
``synthesize_google_like_trace`` generates a statistically faithful stand-in
reproducing the features the paper leans on:
  * hundreds of distinct discrete request values (Fig. 1): a lognormal body
    quantized to a fine grid plus a handful of heavy spikes at round values;
  * two resources (cpu, mem) with positive correlation; the paper's
    preprocessing maps each task to max(cpu, mem) — ``collapse_resources``;
  * diurnal arrival-rate modulation;
  * heavy-tailed service durations.

``scale_arrivals`` implements the paper's "traffic scaling" 1/beta: arrival
times are multiplied by beta (larger 1/beta => more jobs per slot).
"""
from __future__ import annotations

import csv
import warnings
from dataclasses import dataclass

import numpy as np


@dataclass
class Trace:
    arrival_slots: np.ndarray   # int64, sorted
    cpu: np.ndarray             # float in (0,1]
    mem: np.ndarray             # float in (0,1]
    durations: np.ndarray       # int64 slots
    skipped: int = 0            # malformed rows dropped by the loader

    def __len__(self) -> int:
        return len(self.arrival_slots)


def synthesize_google_like_trace(n_tasks: int,
                                 horizon_slots: int,
                                 seed: int = 0,
                                 spike_values=(0.125, 0.25, 0.5),
                                 spike_prob: float = 0.3,
                                 mean_duration: float = 100.0) -> Trace:
    rng = np.random.Generator(np.random.Philox(seed))

    # --- arrivals: inhomogeneous Poisson via thinning of a diurnal rate ----
    base = n_tasks / horizon_slots
    t = np.arange(horizon_slots)
    day = max(horizon_slots / 1.5, 1.0)  # ~1.5 "days" in the window
    rate = base * (1.0 + 0.35 * np.sin(2 * np.pi * t / day) ** 2)
    rate *= n_tasks / max(rate.sum(), 1e-9)
    counts = rng.poisson(rate)
    arrival_slots = np.repeat(t, counts)

    n = len(arrival_slots)
    # --- sizes: lognormal body quantized to 1/1000 + discrete spikes -------
    body = np.exp(rng.normal(np.log(0.04), 0.9, size=n))
    body = np.clip(body, 1e-3, 1.0)
    body = np.ceil(body * 1000) / 1000  # => hundreds of distinct values
    spikes = rng.choice(spike_values, size=n)
    is_spike = rng.uniform(size=n) < spike_prob
    mem = np.where(is_spike, spikes, body)
    # cpu positively correlated with mem, with its own quantization
    cpu_noise = np.exp(rng.normal(0.0, 0.5, size=n))
    cpu = np.clip(mem * 0.6 * cpu_noise, 1e-3, 1.0)
    cpu = np.ceil(cpu * 400) / 400

    # --- durations: heavy-tailed lognormal, >= 1 slot ----------------------
    dur = np.exp(rng.normal(np.log(mean_duration * 0.5), 1.0, size=n))
    dur = np.clip(dur, 1, mean_duration * 50).astype(np.int64)

    return Trace(arrival_slots.astype(np.int64), cpu, mem, dur)


#: Accepted spellings per column, lowercase (Google-2019 / Alibaba style).
#: A job-id column may be present (it is ignored — arrival order is the
#: identity the engines use) but is not required.
_COLUMN_ALIASES = {
    "submit_time": ("submit_time", "submit", "time", "arrival_time",
                    "start_time"),
    "cpu": ("cpu", "cpu_request", "request_cpu", "plan_cpu", "cpus"),
    "mem": ("mem", "memory", "mem_request", "request_mem", "plan_mem"),
    "duration": ("duration", "runtime", "duration_slots", "run_time"),
}


def _resolve_columns(path, names: list[str], aliases: dict) -> dict:
    """Map canonical field names to header indices, or raise naming every
    accepted spelling (shared by the one-shot and streaming readers)."""
    cols = {}
    for field, spellings in aliases.items():
        for a in spellings:
            if a in names:
                cols[field] = names.index(a)
                break
        else:
            raise ValueError(
                f"{path}: no column for {field!r} (looked for "
                f"{', '.join(spellings)}; header: {', '.join(names)})")
    return cols


class _TraceRowParser:
    """The row-parsing core shared by ``load_trace_csv`` (one-shot) and
    ``iter_trace_csv`` (streaming) — one implementation of field parsing,
    domain checks and malformed-row accounting, so both readers accept and
    reject EXACTLY the same rows.

    ``parse(ln, rec)`` returns ``(submit, cpu, mem, duration)`` for a good
    row, ``None`` for a blank or malformed one.  Malformed rows are counted
    in ``skipped`` (``strict=False``) or raise ``ValueError`` naming the
    file and 1-based row — plus the chunk index when ``chunk_of`` is set by
    the streaming reader, so a bad row deep in a multi-GB file is located
    as ``file:row (chunk N)``.
    """

    def __init__(self, path, cols: dict, *, strict: bool = False,
                 chunk_of=None):
        self.path = path
        self.cols = cols
        self.strict = strict
        self.skipped = 0
        self.prev_s = -np.inf
        #: Callable returning the CURRENT chunk index (streaming reader
        #: only) — late-bound so the parser needn't know chunk boundaries.
        self.chunk_of = chunk_of

    def _bad(self, ln: int, why: str, rec) -> None:
        if self.strict:
            where = "" if self.chunk_of is None \
                else f" (chunk {self.chunk_of()})"
            raise ValueError(f"{self.path}:{ln}{where}: {why}: {rec!r}")
        self.skipped += 1

    def parse(self, ln: int, rec) -> tuple | None:
        if not rec or not "".join(rec).strip():
            return None
        cols = self.cols
        try:
            s = float(rec[cols["submit_time"]])
            c = float(rec[cols["cpu"]])
            m = float(rec[cols["mem"]])
            d = float(rec[cols["duration"]])
        except (ValueError, IndexError):
            self._bad(ln, "bad row (unparseable field)", rec)
            return None
        if not all(np.isfinite(v) for v in (s, c, m, d)):
            self._bad(ln, "bad row (non-finite field)", rec)
            return None
        if c < 0 or m < 0 or (c <= 0 and m <= 0):
            self._bad(ln, "bad row (non-positive resource request)", rec)
            return None
        if d <= 0:
            self._bad(ln, "bad row (non-positive duration)", rec)
            return None
        if s < self.prev_s:
            self._bad(ln, "bad row (non-monotone submit time "
                          f"{s:g} after {self.prev_s:g})", rec)
            return None
        self.prev_s = s
        return s, c, m, d


def load_trace_csv(path, *, slot_seconds: float = 1.0,
                   normalize: bool = True, strict: bool = False) -> Trace:
    """Load a Google-2019 / Alibaba-style CSV into a :class:`Trace`.

    Expects a header row naming (in any order, any of the usual spellings)
    submit time, cpu, mem and duration columns — see ``_COLUMN_ALIASES``;
    a job-id column may be present but is ignored (arrival order is the
    identity the engines use).  Submit times and durations are in seconds
    and land on the slot grid via ``slot_seconds`` (floor for arrivals,
    ceil with a 1-slot minimum for durations — a job never serves zero
    slots).  Arrival slots are re-based so the earliest job arrives at
    slot 0, and jobs are stably sorted (submit order preserved within a
    slot).

    ``normalize=True`` (default) rescales cpu/mem to machine fractions by
    their column maxima when any value exceeds 1 (public traces report
    absolute core counts / bytes); values are then clipped into (0, 1] —
    the engines' job-size domain.  ``normalize=False`` takes the values as
    already-normalized fractions and REJECTS anything outside (0, 1]
    instead of silently saturating it.

    Malformed rows — unparseable fields, NaN/inf values, negative cpu or
    mem, non-positive (cpu AND mem) or duration, and submit times that go
    BACKWARDS relative to the previous accepted row — are never consumed
    silently: under ``strict=False`` (default) each is skipped and
    counted (``Trace.skipped``, plus one summary warning); under
    ``strict=True`` the first one raises ``ValueError`` naming the file
    and 1-based row number.

    Returns the trace sorted by arrival slot — directly consumable by
    ``streams_from_trace(trace, collapse=False)`` (uncollapsed (cpu, mem)
    for ``policy="bfjs-mr"``) or with the paper's max-collapse.
    """
    with open(path, newline="") as f:
        reader = csv.reader(f)
        try:
            header = next(reader)
        except StopIteration:
            raise ValueError(f"{path}: empty trace file") from None
        names = [h.strip().lower() for h in header]
        parser = _TraceRowParser(path, _resolve_columns(path, names,
                                                        _COLUMN_ALIASES),
                                 strict=strict)
        submit, cpu, mem, dur = [], [], [], []
        for ln, rec in enumerate(reader, start=2):
            parsed = parser.parse(ln, rec)
            if parsed is None:
                continue
            s, c, m, d = parsed
            submit.append(s)
            cpu.append(c)
            mem.append(m)
            dur.append(d)
    skipped = parser.skipped
    if not submit:
        detail = f" ({skipped} malformed row(s) skipped)" if skipped else ""
        raise ValueError(f"{path}: no usable rows{detail}")
    if skipped:
        warnings.warn(
            f"{path}: skipped {skipped} malformed row(s) — pass "
            "strict=True to fail on the first instead", stacklevel=2)

    submit = np.asarray(submit)
    cpu = np.asarray(cpu)
    mem = np.asarray(mem)
    dur = np.asarray(dur)
    if normalize:
        if cpu.max() > 1.0:
            cpu = cpu / cpu.max()
        if mem.max() > 1.0:
            mem = mem / mem.max()
        cpu = np.clip(cpu, 1e-6, 1.0)
        mem = np.clip(mem, 1e-6, 1.0)
    elif cpu.max() > 1.0 or mem.max() > 1.0:
        raise ValueError(
            f"{path}: cpu/mem values exceed 1 (max cpu={cpu.max():g}, "
            f"mem={mem.max():g}) but normalize=False — these look like "
            "absolute units; pass normalize=True or rescale first")
    else:
        cpu = np.maximum(cpu, 1e-6)
        mem = np.maximum(mem, 1e-6)
    slots = np.floor((submit - submit.min()) / slot_seconds).astype(np.int64)
    dur_slots = np.maximum(np.ceil(dur / slot_seconds), 1).astype(np.int64)
    order = np.argsort(slots, kind="stable")
    return Trace(slots[order], cpu[order], mem[order], dur_slots[order],
                 skipped=skipped)


def scan_trace_maxima(path) -> tuple[float, float]:
    """One constant-memory pass over a trace CSV returning
    ``(cpu_max, mem_max)`` over its parseable rows.

    A streaming reader cannot normalize by column maxima the way
    ``load_trace_csv(normalize=True)`` does — it never holds the whole
    column.  The two-pass recipe for a file in absolute units::

        cpu_cap, mem_cap = scan_trace_maxima(path)
        chunks = iter_trace_csv(path, chunk_rows=100_000,
                                cpu_capacity=cpu_cap, mem_capacity=mem_cap)

    reproduces the one-shot normalization exactly.  Malformed rows are
    skipped silently here (they are accounted for by the reader proper).
    """
    with open(path, newline="") as f:
        reader = csv.reader(f)
        try:
            header = next(reader)
        except StopIteration:
            raise ValueError(f"{path}: empty trace file") from None
        names = [h.strip().lower() for h in header]
        parser = _TraceRowParser(path, _resolve_columns(path, names,
                                                        _COLUMN_ALIASES))
        cpu_max = mem_max = 0.0
        for ln, rec in enumerate(reader, start=2):
            parsed = parser.parse(ln, rec)
            if parsed is None:
                continue
            _, c, m, _ = parsed
            cpu_max = max(cpu_max, c)
            mem_max = max(mem_max, m)
    if cpu_max == 0.0 and mem_max == 0.0:
        raise ValueError(f"{path}: no usable rows")
    return cpu_max, mem_max


def iter_trace_csv(path, *, chunk_rows: int,
                   slot_seconds: float = 1.0,
                   normalize: bool = True,
                   strict: bool = False,
                   cpu_capacity: float | None = None,
                   mem_capacity: float | None = None,
                   machine_events: "MachineEvents | None" = None):
    """Stream a trace CSV as :class:`Trace` chunks of ``<= chunk_rows``
    accepted rows each — constant host memory for multi-GB files.

    Column handling, row validation and malformed-row accounting are the
    SAME code as :func:`load_trace_csv` (``_TraceRowParser``): both
    readers accept and reject exactly the same rows.  Differences forced
    by streaming:

    * **Normalization** cannot use global column maxima (never all in
      memory).  Pass explicit ``cpu_capacity=``/``mem_capacity=``
      divisors — e.g. from :func:`scan_trace_maxima` (two-pass recipe,
      bit-identical to one-shot ``normalize=True``) or from a
      ``machine_events=`` fleet (per-machine capacity normalization:
      the divisor is the fleet's max capacity, so a full request of the
      biggest machine maps to 1.0).  With ``normalize=True`` and no
      divisors, values are taken as machine fractions already and any
      value > 1 raises (rather than mis-scaling a chunk by its local
      max, which would silently break cross-chunk comparability).
    * **Slot re-basing** uses the FIRST accepted row's submit time as
      t0 (the one-shot reader uses the global min — identical for any
      monotone-submit-time file, which validation enforces up to
      skipped rows).
    * ``strict=True`` errors name ``file:row (chunk N)`` so a bad row
      deep in a huge file is located without re-reading it.

    Each yielded chunk is a :class:`Trace` (sorted, slot-rebased to the
    SHARED t0, per-chunk ``skipped`` count).  Chunks never split a slot's
    jobs ACROSS slot boundaries — rows land in a chunk purely by count,
    so a slot's arrivals may span two chunks; downstream re-bucketing
    (``stream_chunks_from_trace``) handles that.  A summary warning on
    exhaustion reports the total skipped (mirroring ``load_trace_csv``).
    """
    if chunk_rows < 1:
        raise ValueError(f"chunk_rows must be >= 1, got {chunk_rows}")
    if machine_events is not None:
        if cpu_capacity is not None or mem_capacity is not None:
            raise ValueError(
                "pass machine_events= OR explicit cpu_capacity/"
                "mem_capacity, not both")
        cpu_capacity = float(machine_events.cpu_capacity.max())
        mem_capacity = float(machine_events.mem_capacity.max())
    if (cpu_capacity is None) != (mem_capacity is None):
        raise ValueError(
            "cpu_capacity and mem_capacity must be passed together")
    if cpu_capacity is not None and (cpu_capacity <= 0 or mem_capacity <= 0):
        raise ValueError(
            f"capacities must be positive, got cpu_capacity={cpu_capacity!r} "
            f"mem_capacity={mem_capacity!r}")

    chunk_idx = 0
    with open(path, newline="") as f:
        reader = csv.reader(f)
        try:
            header = next(reader)
        except StopIteration:
            raise ValueError(f"{path}: empty trace file") from None
        names = [h.strip().lower() for h in header]
        parser = _TraceRowParser(path, _resolve_columns(path, names,
                                                        _COLUMN_ALIASES),
                                 strict=strict,
                                 chunk_of=lambda: chunk_idx)
        t0 = None
        skipped_at_chunk_start = 0
        submit, cpu, mem, dur = [], [], [], []

        def emit() -> Trace:
            nonlocal submit, cpu, mem, dur, skipped_at_chunk_start
            s = np.asarray(submit)
            c = np.asarray(cpu)
            m = np.asarray(mem)
            d = np.asarray(dur)
            if cpu_capacity is not None:
                c = c / cpu_capacity
                m = m / mem_capacity
                c = np.clip(c, 1e-6, 1.0)
                m = np.clip(m, 1e-6, 1.0)
            elif normalize:
                if c.max() > 1.0 or m.max() > 1.0:
                    raise ValueError(
                        f"{path}: cpu/mem values exceed 1 (max "
                        f"cpu={c.max():g}, mem={m.max():g}) but no "
                        "capacities were given — a streaming reader cannot "
                        "normalize by global column maxima; pass "
                        "cpu_capacity=/mem_capacity= (e.g. from "
                        "scan_trace_maxima) or machine_events=")
                c = np.clip(c, 1e-6, 1.0)
                m = np.clip(m, 1e-6, 1.0)
            elif c.max() > 1.0 or m.max() > 1.0:
                raise ValueError(
                    f"{path}: cpu/mem values exceed 1 (max cpu={c.max():g}, "
                    f"mem={m.max():g}) but normalize=False — these look "
                    "like absolute units; pass capacities or rescale first")
            else:
                c = np.maximum(c, 1e-6)
                m = np.maximum(m, 1e-6)
            slots = np.floor((s - t0) / slot_seconds).astype(np.int64)
            d_slots = np.maximum(np.ceil(d / slot_seconds), 1).astype(np.int64)
            order = np.argsort(slots, kind="stable")
            chunk_skipped = parser.skipped - skipped_at_chunk_start
            skipped_at_chunk_start = parser.skipped
            submit, cpu, mem, dur = [], [], [], []
            return Trace(slots[order], c[order], m[order], d_slots[order],
                         skipped=chunk_skipped)

        for ln, rec in enumerate(reader, start=2):
            parsed = parser.parse(ln, rec)
            if parsed is None:
                continue
            s, c, m, d = parsed
            if t0 is None:
                t0 = s
            submit.append(s)
            cpu.append(c)
            mem.append(m)
            dur.append(d)
            if len(submit) >= chunk_rows:
                yield emit()
                chunk_idx += 1
        if submit:
            yield emit()
    if parser.skipped:
        warnings.warn(
            f"{path}: skipped {parser.skipped} malformed row(s) — pass "
            "strict=True to fail on the first instead", stacklevel=2)
    if t0 is None:
        detail = (f" ({parser.skipped} malformed row(s) skipped)"
                  if parser.skipped else "")
        raise ValueError(f"{path}: no usable rows{detail}")


class ResumableTraceReader:
    """Re-openable :func:`iter_trace_csv` for supervised streaming.

    A plain generator dies on the first exception it raises — a retried
    ``next()`` then yields ``StopIteration``, which reads as end-of-stream
    and would silently truncate the trace.  This wrapper makes the reader
    actually retryable: after an attempt fails, the NEXT ``next()`` call
    re-opens the file from scratch and fast-forwards past the chunks
    already emitted, so the supervisor's retry-with-backoff
    (``core.engine.supervisor``) sees each chunk until it either parses or
    exhausts its retries.  ``reopens`` counts the recoveries.

    Fast-forwarding re-parses the file head — O(file) per recovery, the
    price of supporting plain (non-seekable-safe) CSV sources.  Determinism
    holds because :func:`iter_trace_csv` is a pure function of the file
    contents: the re-read emits bit-identical chunks.

    ``_open`` is the injection seam the chaos harness uses to interpose
    flaky transports; production code never overrides it.
    """

    def __init__(self, path, **kwargs):
        self.path = path
        self.kwargs = kwargs
        self.reopens = 0
        self._emitted = 0
        self._gen = None

    def _open(self):
        return iter_trace_csv(self.path, **self.kwargs)

    def __iter__(self):
        return self

    def __next__(self) -> Trace:
        if self._gen is None:
            gen = self._open()
            if self._emitted:
                self.reopens += 1
                with warnings.catch_warnings():
                    # the skipped-rows summary already fired on the first
                    # pass; don't duplicate it while fast-forwarding
                    warnings.simplefilter("ignore")
                    for k in range(self._emitted):
                        try:
                            next(gen)
                        except StopIteration:
                            raise OSError(
                                f"{self.path}: shrank between reopens — "
                                f"only {k} chunk(s) left of the "
                                f"{self._emitted} already emitted; the "
                                "file changed underneath the stream"
                            ) from None
            self._gen = gen
        try:
            out = next(self._gen)
        except StopIteration:
            raise
        except BaseException:
            # drop the dead generator; the retry re-opens + fast-forwards
            self._gen = None
            raise
        self._emitted += 1
        return out


# ---------------------------------------------------------------------------
# Google-2019 machine-events schema adapter
# ---------------------------------------------------------------------------

#: Google-2019 machine-events type codes.
MACHINE_ADD, MACHINE_REMOVE, MACHINE_UPDATE = 1, 2, 3

_MACHINE_COLUMN_ALIASES = {
    "time": ("time", "timestamp", "event_time"),
    "machine_id": ("machine_id", "machineid", "machine"),
    "type": ("type", "event_type", "event"),
    "cpu": ("cpus", "cpu", "cpu_capacity", "capacity_cpu"),
    "mem": ("memory", "mem", "mem_capacity", "capacity_memory"),
}


@dataclass
class MachineEvents:
    """Fleet capacities + up/down event schedule from a Google-2019
    machine-events table.

    ``machine_ids`` maps server index -> original machine id (index order
    = first-appearance order, the identity the engines' ``(T, L)`` fault
    plane uses).  ``cpu_capacity``/``mem_capacity`` are each machine's
    ABSOLUTE capacity (max over its ADD/UPDATE events) — their fleet
    maxima are the per-machine normalization divisors
    ``iter_trace_csv(machine_events=...)`` uses.  ``events`` is a list of
    ``(slot, server_idx, up)`` suitable for
    ``core.engine.fault_plane_from_events``.
    """
    machine_ids: np.ndarray     # (L,) int64, first-appearance order
    cpu_capacity: np.ndarray    # (L,) float, absolute units
    mem_capacity: np.ndarray    # (L,) float, absolute units
    events: list                # [(slot, server_idx, up), ...] time-sorted

    @property
    def num_servers(self) -> int:
        return len(self.machine_ids)


def load_machine_events_csv(path, *, slot_seconds: float = 1.0,
                            strict: bool = False) -> MachineEvents:
    """Load a Google-2019 machine-events CSV (time, machine_id, type
    ADD=1/REMOVE=2/UPDATE=3, cpus, memory — usual alias spellings).

    ADD/UPDATE mark a machine up (and refresh its capacity); REMOVE marks
    it down.  Slots are re-based to the earliest event.  Malformed rows
    follow the trace-reader contract: skip-and-count by default,
    ``strict=True`` raises naming file:row.
    """
    with open(path, newline="") as f:
        reader = csv.reader(f)
        try:
            header = next(reader)
        except StopIteration:
            raise ValueError(f"{path}: empty machine-events file") from None
        names = [h.strip().lower() for h in header]
        cols = _resolve_columns(path, names, _MACHINE_COLUMN_ALIASES)
        skipped = 0

        def bad(ln, why, rec):
            nonlocal skipped
            if strict:
                raise ValueError(f"{path}:{ln}: {why}: {rec!r}")
            skipped += 1

        ids: list = []           # first-appearance order
        index: dict = {}
        cpu_cap: list = []
        mem_cap: list = []
        raw_events = []          # (time, server_idx, up)
        for ln, rec in enumerate(reader, start=2):
            if not rec or not "".join(rec).strip():
                continue
            try:
                t = float(rec[cols["time"]])
                mid = int(float(rec[cols["machine_id"]]))
                etype = int(float(rec[cols["type"]]))
            except (ValueError, IndexError):
                bad(ln, "bad row (unparseable field)", rec)
                continue
            if etype not in (MACHINE_ADD, MACHINE_REMOVE, MACHINE_UPDATE):
                bad(ln, f"bad row (unknown event type {etype})", rec)
                continue
            up = etype != MACHINE_REMOVE
            c = m = 0.0
            if up:
                try:
                    c = float(rec[cols["cpu"]])
                    m = float(rec[cols["mem"]])
                except (ValueError, IndexError):
                    bad(ln, "bad row (unparseable capacity)", rec)
                    continue
                if not (np.isfinite(c) and np.isfinite(m)) \
                        or c <= 0 or m <= 0:
                    bad(ln, "bad row (non-positive capacity)", rec)
                    continue
            if mid not in index:
                index[mid] = len(ids)
                ids.append(mid)
                cpu_cap.append(0.0)
                mem_cap.append(0.0)
            si = index[mid]
            if up:
                cpu_cap[si] = max(cpu_cap[si], c)
                mem_cap[si] = max(mem_cap[si], m)
            raw_events.append((t, si, up))
    if not raw_events:
        detail = f" ({skipped} malformed row(s) skipped)" if skipped else ""
        raise ValueError(f"{path}: no usable rows{detail}")
    if skipped:
        warnings.warn(
            f"{path}: skipped {skipped} malformed row(s) — pass "
            "strict=True to fail on the first instead", stacklevel=2)
    never_up = [ids[i] for i in range(len(ids)) if cpu_cap[i] <= 0]
    if never_up:
        raise ValueError(
            f"{path}: machine(s) {never_up} only ever REMOVEd — no "
            "capacity to normalize against")
    raw_events.sort(key=lambda e: e[0])
    t0 = raw_events[0][0]
    events = [(int(np.floor((t - t0) / slot_seconds)), si, up)
              for t, si, up in raw_events]
    return MachineEvents(
        machine_ids=np.asarray(ids, dtype=np.int64),
        cpu_capacity=np.asarray(cpu_cap),
        mem_capacity=np.asarray(mem_cap),
        events=events,
    )


def collapse_resources(trace: Trace) -> np.ndarray:
    """Paper preprocessing: single resource = max(cpu, mem)."""
    return np.maximum(trace.cpu, trace.mem)


def scale_arrivals(trace: Trace, traffic_scaling: float) -> Trace:
    """Traffic scaling 1/beta: multiply arrival times by beta = 1/scaling."""
    beta = 1.0 / traffic_scaling
    return Trace(
        arrival_slots=np.floor(trace.arrival_slots * beta).astype(np.int64),
        cpu=trace.cpu,
        mem=trace.mem,
        durations=trace.durations,
    )


def empirical_size_stats(sizes: np.ndarray) -> dict:
    """Fig. 1-style statistics: number of distinct discrete requirements."""
    vals, counts = np.unique(np.round(sizes, 6), return_counts=True)
    return {
        "distinct_values": int(len(vals)),
        "mean": float(sizes.mean()),
        "p50": float(np.quantile(sizes, 0.5)),
        "p99": float(np.quantile(sizes, 0.99)),
        "max": float(sizes.max()),
    }
