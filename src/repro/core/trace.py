"""Google-cluster-like trace synthesis and preprocessing (paper Section VII.B).

The real 2011 Google trace is not shipped in this offline container, so
``synthesize_google_like_trace`` generates a statistically faithful stand-in
reproducing the features the paper leans on:
  * hundreds of distinct discrete request values (Fig. 1): a lognormal body
    quantized to a fine grid plus a handful of heavy spikes at round values;
  * two resources (cpu, mem) with positive correlation; the paper's
    preprocessing maps each task to max(cpu, mem) — ``collapse_resources``;
  * diurnal arrival-rate modulation;
  * heavy-tailed service durations.

``scale_arrivals`` implements the paper's "traffic scaling" 1/beta: arrival
times are multiplied by beta (larger 1/beta => more jobs per slot).
"""
from __future__ import annotations

import csv
import warnings
from dataclasses import dataclass

import numpy as np


@dataclass
class Trace:
    arrival_slots: np.ndarray   # int64, sorted
    cpu: np.ndarray             # float in (0,1]
    mem: np.ndarray             # float in (0,1]
    durations: np.ndarray       # int64 slots
    skipped: int = 0            # malformed rows dropped by the loader

    def __len__(self) -> int:
        return len(self.arrival_slots)


def synthesize_google_like_trace(n_tasks: int,
                                 horizon_slots: int,
                                 seed: int = 0,
                                 spike_values=(0.125, 0.25, 0.5),
                                 spike_prob: float = 0.3,
                                 mean_duration: float = 100.0) -> Trace:
    rng = np.random.Generator(np.random.Philox(seed))

    # --- arrivals: inhomogeneous Poisson via thinning of a diurnal rate ----
    base = n_tasks / horizon_slots
    t = np.arange(horizon_slots)
    day = max(horizon_slots / 1.5, 1.0)  # ~1.5 "days" in the window
    rate = base * (1.0 + 0.35 * np.sin(2 * np.pi * t / day) ** 2)
    rate *= n_tasks / max(rate.sum(), 1e-9)
    counts = rng.poisson(rate)
    arrival_slots = np.repeat(t, counts)

    n = len(arrival_slots)
    # --- sizes: lognormal body quantized to 1/1000 + discrete spikes -------
    body = np.exp(rng.normal(np.log(0.04), 0.9, size=n))
    body = np.clip(body, 1e-3, 1.0)
    body = np.ceil(body * 1000) / 1000  # => hundreds of distinct values
    spikes = rng.choice(spike_values, size=n)
    is_spike = rng.uniform(size=n) < spike_prob
    mem = np.where(is_spike, spikes, body)
    # cpu positively correlated with mem, with its own quantization
    cpu_noise = np.exp(rng.normal(0.0, 0.5, size=n))
    cpu = np.clip(mem * 0.6 * cpu_noise, 1e-3, 1.0)
    cpu = np.ceil(cpu * 400) / 400

    # --- durations: heavy-tailed lognormal, >= 1 slot ----------------------
    dur = np.exp(rng.normal(np.log(mean_duration * 0.5), 1.0, size=n))
    dur = np.clip(dur, 1, mean_duration * 50).astype(np.int64)

    return Trace(arrival_slots.astype(np.int64), cpu, mem, dur)


#: Accepted spellings per column, lowercase (Google-2019 / Alibaba style).
#: A job-id column may be present (it is ignored — arrival order is the
#: identity the engines use) but is not required.
_COLUMN_ALIASES = {
    "submit_time": ("submit_time", "submit", "time", "arrival_time",
                    "start_time"),
    "cpu": ("cpu", "cpu_request", "request_cpu", "plan_cpu", "cpus"),
    "mem": ("mem", "memory", "mem_request", "request_mem", "plan_mem"),
    "duration": ("duration", "runtime", "duration_slots", "run_time"),
}


def load_trace_csv(path, *, slot_seconds: float = 1.0,
                   normalize: bool = True, strict: bool = False) -> Trace:
    """Load a Google-2019 / Alibaba-style CSV into a :class:`Trace`.

    Expects a header row naming (in any order, any of the usual spellings)
    submit time, cpu, mem and duration columns — see ``_COLUMN_ALIASES``;
    a job-id column may be present but is ignored (arrival order is the
    identity the engines use).  Submit times and durations are in seconds
    and land on the slot grid via ``slot_seconds`` (floor for arrivals,
    ceil with a 1-slot minimum for durations — a job never serves zero
    slots).  Arrival slots are re-based so the earliest job arrives at
    slot 0, and jobs are stably sorted (submit order preserved within a
    slot).

    ``normalize=True`` (default) rescales cpu/mem to machine fractions by
    their column maxima when any value exceeds 1 (public traces report
    absolute core counts / bytes); values are then clipped into (0, 1] —
    the engines' job-size domain.  ``normalize=False`` takes the values as
    already-normalized fractions and REJECTS anything outside (0, 1]
    instead of silently saturating it.

    Malformed rows — unparseable fields, NaN/inf values, negative cpu or
    mem, non-positive (cpu AND mem) or duration, and submit times that go
    BACKWARDS relative to the previous accepted row — are never consumed
    silently: under ``strict=False`` (default) each is skipped and
    counted (``Trace.skipped``, plus one summary warning); under
    ``strict=True`` the first one raises ``ValueError`` naming the file
    and 1-based row number.

    Returns the trace sorted by arrival slot — directly consumable by
    ``streams_from_trace(trace, collapse=False)`` (uncollapsed (cpu, mem)
    for ``policy="bfjs-mr"``) or with the paper's max-collapse.
    """
    with open(path, newline="") as f:
        reader = csv.reader(f)
        try:
            header = next(reader)
        except StopIteration:
            raise ValueError(f"{path}: empty trace file") from None
        names = [h.strip().lower() for h in header]
        cols = {}
        for field, aliases in _COLUMN_ALIASES.items():
            for a in aliases:
                if a in names:
                    cols[field] = names.index(a)
                    break
            else:
                raise ValueError(
                    f"{path}: no column for {field!r} (looked for "
                    f"{', '.join(aliases)}; header: {', '.join(names)})")
        submit, cpu, mem, dur = [], [], [], []
        skipped = 0
        prev_s = -np.inf

        def bad(ln: int, why: str, rec) -> None:
            nonlocal skipped
            if strict:
                raise ValueError(f"{path}:{ln}: {why}: {rec!r}")
            skipped += 1

        for ln, rec in enumerate(reader, start=2):
            if not rec or not "".join(rec).strip():
                continue
            try:
                s = float(rec[cols["submit_time"]])
                c = float(rec[cols["cpu"]])
                m = float(rec[cols["mem"]])
                d = float(rec[cols["duration"]])
            except (ValueError, IndexError):
                bad(ln, "bad row (unparseable field)", rec)
                continue
            if not all(np.isfinite(v) for v in (s, c, m, d)):
                bad(ln, "bad row (non-finite field)", rec)
                continue
            if c < 0 or m < 0 or (c <= 0 and m <= 0):
                bad(ln, "bad row (non-positive resource request)", rec)
                continue
            if d <= 0:
                bad(ln, "bad row (non-positive duration)", rec)
                continue
            if s < prev_s:
                bad(ln, "bad row (non-monotone submit time "
                        f"{s:g} after {prev_s:g})", rec)
                continue
            prev_s = s
            submit.append(s)
            cpu.append(c)
            mem.append(m)
            dur.append(d)
    if not submit:
        detail = f" ({skipped} malformed row(s) skipped)" if skipped else ""
        raise ValueError(f"{path}: no usable rows{detail}")
    if skipped:
        warnings.warn(
            f"{path}: skipped {skipped} malformed row(s) — pass "
            "strict=True to fail on the first instead", stacklevel=2)

    submit = np.asarray(submit)
    cpu = np.asarray(cpu)
    mem = np.asarray(mem)
    dur = np.asarray(dur)
    if normalize:
        if cpu.max() > 1.0:
            cpu = cpu / cpu.max()
        if mem.max() > 1.0:
            mem = mem / mem.max()
        cpu = np.clip(cpu, 1e-6, 1.0)
        mem = np.clip(mem, 1e-6, 1.0)
    elif cpu.max() > 1.0 or mem.max() > 1.0:
        raise ValueError(
            f"{path}: cpu/mem values exceed 1 (max cpu={cpu.max():g}, "
            f"mem={mem.max():g}) but normalize=False — these look like "
            "absolute units; pass normalize=True or rescale first")
    else:
        cpu = np.maximum(cpu, 1e-6)
        mem = np.maximum(mem, 1e-6)
    slots = np.floor((submit - submit.min()) / slot_seconds).astype(np.int64)
    dur_slots = np.maximum(np.ceil(dur / slot_seconds), 1).astype(np.int64)
    order = np.argsort(slots, kind="stable")
    return Trace(slots[order], cpu[order], mem[order], dur_slots[order],
                 skipped=skipped)


def collapse_resources(trace: Trace) -> np.ndarray:
    """Paper preprocessing: single resource = max(cpu, mem)."""
    return np.maximum(trace.cpu, trace.mem)


def scale_arrivals(trace: Trace, traffic_scaling: float) -> Trace:
    """Traffic scaling 1/beta: multiply arrival times by beta = 1/scaling."""
    beta = 1.0 / traffic_scaling
    return Trace(
        arrival_slots=np.floor(trace.arrival_slots * beta).astype(np.int64),
        cpu=trace.cpu,
        mem=trace.mem,
        durations=trace.durations,
    )


def empirical_size_stats(sizes: np.ndarray) -> dict:
    """Fig. 1-style statistics: number of distinct discrete requirements."""
    vals, counts = np.unique(np.round(sizes, 6), return_counts=True)
    return {
        "distinct_values": int(len(vals)),
        "mean": float(sizes.mean()),
        "p50": float(np.quantile(sizes, 0.5)),
        "p99": float(np.quantile(sizes, 0.99)),
        "max": float(sizes.max()),
    }
