"""Scheduler interface for the event-driven engine."""
from __future__ import annotations

import abc

import numpy as np

from .cluster_state import Cluster, ServiceModel
from .queues import Job


class Scheduler(abc.ABC):
    """A scheduling policy.

    Per-slot protocol (driven by core.simulator.Simulator):
      1. cluster.process_departures(t)   -> freed, emptied
      2. policy.on_arrivals(t, jobs)     -> enqueue new jobs
      3. policy.schedule(t, freed, emptied) -> placements via self._place
    """

    name: str = "scheduler"

    def bind(self, cluster: Cluster, service: ServiceModel, rng: np.random.Generator):
        self.cluster = cluster
        self.service = service
        self.rng = rng
        self._t = 0
        return self

    # -- job classification (subclasses may attach VQ types) --------------
    def make_job(self, jid: int, size_int: int, t: int, dur: int = 0) -> Job:
        return Job(jid, size_int, size_int, -1, t, dur)

    @abc.abstractmethod
    def on_arrivals(self, t: int, jobs: list[Job]) -> None:
        ...

    @abc.abstractmethod
    def schedule(self, t: int, freed: set[int], emptied: set[int]) -> None:
        ...

    @abc.abstractmethod
    def queue_len(self) -> int:
        ...

    def queued_total_size(self) -> int:
        return 0  # optional diagnostic

    # -- helpers -----------------------------------------------------------
    def _place(self, t: int, server: int, job: Job) -> None:
        dur = job.dur if job.dur > 0 else int(self.service.draw(self.rng, 1)[0])
        self.cluster.place(server, job, t + dur)

    def on_place(self, server: int, job: Job) -> None:  # subclass hook
        ...
