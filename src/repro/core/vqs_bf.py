"""VQS-BF — VQS configuration selection + Best-Fit packing (paper Section VI,
Theorem 4: same 2/3 guarantee as VQS, BF-like delay in practice).

Differences from VQS in the job-scheduling step (paper (i)-(iii)):
  (i)   with k_1 = 1 the server schedules the LARGEST VQ_1 job that fits and
        reserves exactly that job's size (no 2/3 reservation when none fits);
  (ii)  the other type j* is served LARGEST-fit-first and stops at k_{j*}
        jobs of that type (or when VQ_{j*} empties / nothing fits);
  (iii) the remaining capacity is filled by BF-S over ALL virtual queues.

Event-driven wake-ups as in VQS, plus an arrival-side BF-J pass: a newly
arrived job that no visited server consumed is offered to the tightest
feasible server (the job-perspective equivalent of step (iii)).
"""
from __future__ import annotations

from .queues import Job
from .vqs import VQS


class VQSBF(VQS):
    name = "vqs-bf"

    def on_arrivals(self, t, jobs):
        super().on_arrivals(t, jobs)
        self._new: list[Job] = list(jobs)

    def schedule(self, t, freed, emptied):
        super().schedule(t, freed, emptied)
        # Arrival-side BF-J pass over jobs still queued.
        cl = self.cluster
        for job in self._new:
            server = cl.tightest_feasible(job.eff_size)
            if server >= 0 and self.vqs.remove_specific(job):
                self._place(t, server, job)
                self._empty.discard(server)
        self._new = []

    def _serve(self, t, server):
        if not self._has_cfg[server]:
            self._renew(server)
        cl = self.cluster
        jobs_in = cl.jobs[server]
        k1 = bool(self._k1[server])
        jstar = int(self._jstar[server])
        kstar = int(self._kstar[server])

        # (i) largest fitting VQ_1 job, reserving exactly its size.
        if k1 and not any(j.vq == 1 for j in jobs_in.values()):
            job = self.vqs.pop_largest_leq(1, int(cl.residual[server]))
            if job is not None:
                self._place(t, server, job)
                self._empty.discard(server)
            elif self.vqs.sizes[1] == 0:
                self._want[1].add(server)

        # (ii) largest-fit-first from VQ_{j*}, stopping at k_{j*} jobs.
        if jstar >= 0:
            count = sum(1 for j in jobs_in.values() if j.vq == jstar)
            while count < kstar:
                job = self.vqs.pop_largest_leq(jstar, int(cl.residual[server]))
                if job is None:
                    if self.vqs.sizes[jstar] == 0:
                        self._want[jstar].add(server)
                    break
                self._place(t, server, job)
                self._empty.discard(server)
                count += 1

        # (iii) BF-S sweep over all VQs into the remaining capacity.
        while True:
            job = self.vqs.pop_largest_leq_any(int(cl.residual[server]))
            if job is None:
                break
            self._place(t, server, job)
            self._empty.discard(server)

    def queue_len(self):
        return len(self.vqs)
