"""VQS — Virtual Queue Scheduling (paper Section V, Theorem 3: >= 2/3 rho*).

Every server holds an *active configuration* from the reduced set K_RED^(J)
(4J-4 configurations), renewed ONLY when the server is empty (the paper's
tau_i^l epochs, non-preemptive like [6],[9]) to the max-weight configuration
<k, Q> over the VQ-size vector Q.  Scheduling under an active configuration:

  (i)  if k_1 = 1 the server reserves 2/3 of its capacity for a single VQ_1
       job (type 1 = sizes in (1/2, 2/3]) and schedules one when missing;
  (ii) the (at most one) other type j* is served from the HEAD of VQ_{j*}
       until the head no longer fits in the unreserved capacity — actual
       (unrounded) sizes are used, so more than k_{j*} jobs may be packed.

The implementation is event-driven: a server is (re)visited only when it had
departures, became/stays empty while work is queued, or a VQ it is starving
on receives an arrival (subscription wake-ups) — O(events), not O(L) per slot.
"""
from __future__ import annotations

import numpy as np

from .base import Scheduler
from .partition import PartitionI, k_red
from .queues import Job, VirtualQueues
from .quantize import RES, TWO_THIRDS


class VQS(Scheduler):
    name = "vqs"

    def __init__(self, J: int):
        self.J = J
        self.part = PartitionI(J)
        self._kred = k_red(J)

    def bind(self, cluster, service, rng):
        super().bind(cluster, service, rng)
        L = cluster.L
        self.vqs = VirtualQueues(self.J)
        # per-server active configuration, compact: (k1, jstar, kstar)
        self._k1 = np.zeros(L, dtype=bool)
        self._jstar = np.full(L, -1, dtype=np.int64)
        self._kstar = np.zeros(L, dtype=np.int64)
        self._has_cfg = np.zeros(L, dtype=bool)
        self._empty: set[int] = set(range(L))
        self._want: list[set[int]] = [set() for _ in range(2 * self.J)]
        return self

    # -- job classification -------------------------------------------------
    def make_job(self, jid, size_int, t, dur=0):
        vq, eff = self.vqs.classify(size_int) if hasattr(self, "vqs") else (-1, size_int)
        return Job(jid, size_int, eff, vq, t, dur)

    def on_arrivals(self, t, jobs):
        self._arrived_types: set[int] = set()
        for job in jobs:
            self.vqs.push(job)
            self._arrived_types.add(job.vq)

    # -- configuration management -------------------------------------------
    def _renew(self, server: int) -> None:
        w = self._kred @ self.vqs.sizes
        row = self._kred[int(np.argmax(w))]
        self._set_config(server, row)

    def _set_config(self, server: int, row: np.ndarray) -> None:
        k1 = row[1] > 0
        nz = np.nonzero(row)[0]
        other = [j for j in nz if j != 1]
        self._k1[server] = k1
        self._jstar[server] = other[0] if other else -1
        self._kstar[server] = row[other[0]] if other else 0
        self._has_cfg[server] = True

    # -- scheduling -----------------------------------------------------------
    def schedule(self, t, freed, emptied):
        woken: set[int] = set()
        for j in getattr(self, "_arrived_types", set()):
            woken |= self._want[j]
            self._want[j].clear()
        self._arrived_types = set()

        visit: set[int] = set(freed) | set(emptied) | woken
        if len(self.vqs) > 0 and self._empty:
            visit |= self._empty
        for server in sorted(visit):
            if self.cluster.num_jobs(server) == 0:
                self._renew(server)
                self._empty.add(server)
            self._serve(t, server)

    def _serve(self, t: int, server: int) -> None:
        if not self._has_cfg[server]:
            self._renew(server)
        cl = self.cluster
        jobs_in = cl.jobs[server]
        k1 = bool(self._k1[server])
        jstar = int(self._jstar[server])

        cap = int(cl.capacity[server])
        reserve = (2 * cap + 1) // 3  # 2/3 of this server, grid-rounded

        if k1:
            has_vq1 = any(j.vq == 1 for j in jobs_in.values())
            if not has_vq1:
                head = self.vqs.head(1)
                if head is not None and head.eff_size <= int(cl.residual[server]):
                    self.vqs.pop_head(1)
                    self._place(t, server, head)
                    self._empty.discard(server)
                elif head is None:
                    self._want[1].add(server)

        if jstar >= 0:
            other_cap = cap - reserve if k1 else cap
            vq1_occ = sum(j.eff_size for j in jobs_in.values() if j.vq == 1)
            other_occ = cl.occupancy(server) - vq1_occ
            while True:
                head = self.vqs.head(jstar)
                if head is None:
                    self._want[jstar].add(server)
                    break
                if other_occ + head.eff_size > other_cap:
                    break  # unblocks on this server's own departures
                self.vqs.pop_head(jstar)
                self._place(t, server, head)
                other_occ += head.eff_size
                self._empty.discard(server)

    def queue_len(self):
        return len(self.vqs)
