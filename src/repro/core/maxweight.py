"""Non-preemptive MaxWeight oracle for FINITE-type systems ([6],[8],[9]).

Requires the discrete type set up front (sizes + enumeration of ALL feasible
configurations) — exactly the knowledge/complexity the paper's oblivious
algorithms avoid.  Used as the throughput oracle in tests and figure
benchmarks.  Configurations are renewed at server-empty epochs (like VQS).
"""
from __future__ import annotations

from collections import deque

import numpy as np

from .base import Scheduler
from .queues import Job
from .quantize import RES, to_grid
from .stability import enumerate_configs, maximal_configs


class MaxWeight(Scheduler):
    name = "maxweight"

    def __init__(self, type_sizes, capacity: int = RES, max_configs: int = 500_000):
        sizes = np.asarray(type_sizes)
        self.type_sizes = to_grid(sizes) if sizes.dtype.kind == "f" else sizes.astype(np.int64)
        self.configs = maximal_configs(
            enumerate_configs(self.type_sizes, capacity, max_configs),
            self.type_sizes, capacity)
        self.J = len(self.type_sizes)

    def bind(self, cluster, service, rng):
        super().bind(cluster, service, rng)
        L = cluster.L
        self.queues: list[deque[Job]] = [deque() for _ in range(self.J)]
        self.qsizes = np.zeros(self.J, dtype=np.int64)
        self._cfg = np.zeros((L, self.J), dtype=np.int64)
        self._has_cfg = np.zeros(L, dtype=bool)
        self._empty: set[int] = set(range(L))
        self._want: list[set[int]] = [set() for _ in range(self.J)]
        return self

    def _type_of(self, size_int: int) -> int:
        j = int(np.argmin(np.abs(self.type_sizes - size_int)))
        if abs(int(self.type_sizes[j]) - size_int) > 2:
            raise ValueError(f"job size {size_int} is not one of the declared types")
        return j

    def make_job(self, jid, size_int, t, dur=0):
        j = self._type_of(size_int)
        return Job(jid, int(self.type_sizes[j]), int(self.type_sizes[j]), j, t, dur)

    def on_arrivals(self, t, jobs):
        self._arrived: set[int] = set()
        for job in jobs:
            self.queues[job.vq].append(job)
            self.qsizes[job.vq] += 1
            self._arrived.add(job.vq)

    def schedule(self, t, freed, emptied):
        woken: set[int] = set()
        for j in self._arrived:
            woken |= self._want[j]
            self._want[j].clear()
        self._arrived = set()
        visit = set(freed) | set(emptied) | woken
        if self.qsizes.sum() > 0 and self._empty:
            visit |= self._empty
        for server in sorted(visit):
            if self.cluster.num_jobs(server) == 0:
                w = self.configs @ self.qsizes
                self._cfg[server] = self.configs[int(np.argmax(w))]
                self._has_cfg[server] = True
                self._empty.add(server)
            self._serve(t, server)

    def _serve(self, t, server):
        if not self._has_cfg[server]:
            w = self.configs @ self.qsizes
            self._cfg[server] = self.configs[int(np.argmax(w))]
            self._has_cfg[server] = True
        cfg = self._cfg[server]
        counts = np.zeros(self.J, dtype=np.int64)
        for job in self.cluster.jobs[server].values():
            counts[job.vq] += 1
        for j in range(self.J):
            while counts[j] < cfg[j]:
                if not self.queues[j]:
                    self._want[j].add(server)
                    break
                job = self.queues[j].popleft()
                self.qsizes[j] -= 1
                self._place(t, server, job)
                self._empty.discard(server)
                counts[j] += 1

    def queue_len(self):
        return int(self.qsizes.sum())
