"""Multi-resource Best-Fit (paper Section VIII, future-work item).

The paper's preprocessing collapses (cpu, mem) to max(cpu, mem); Section
VIII suggests instead extending BF-J/S with a Best-Fit score that is "a
linear combination of per-resource occupancies ... the inner product of the
job's resource-requirement vector and the server's occupied-resource vector"
(the Tetris alignment score [14]).  This module implements exactly that:

  score(job, server) = <job_demand, server_available>   (Tetris alignment)
  place the job on the FEASIBLE server with the LOWEST score — the
  multi-dimensional "tightest server": least leftover room in exactly the
  dimensions the job needs (reduces to Best-Fit in one dimension).
  (Grandl et al. use argmax-of-availability for makespan; for queueing
  stability the Best-Fit direction — argmin — is the natural analogue of
  the paper's tightest-server rule, and measurably beats both argmax and
  the max-collapse preprocessing on anti-correlated workloads.)

Event-driven engine mirroring core.simulator at O(L) per placement — the
multi-dimensional score has no total order to index, so no Fenwick fast
path; L up to a few thousand is fine.

This module is also the behavioural ORACLE of the accelerator-resident
``policy="bfjs-mr"`` scan engine (``core/engine/bfjs_mr.py``).  To make
bit-match testable across numpy and XLA, the alignment score is EXACT
arithmetic rather than rounded float32: on grid-quantized demands every
product ``avail_r * demand_r`` is an integer multiple of ``2**-32`` that
float64 represents exactly (``alignment_scores``), so the score — and
therefore every argmin tie-break — is independent of accumulation order,
vectorization width and backend.  (An earlier float32 formulation was NOT
portable: XLA contracts ``mul+add`` into an FMA in some lowerings but not
others, observed to flip placements with vmap batch width on CPU.)  The
jnp engines compare the same scores as an exact int32 ``(hi, lo)`` pair
(``engine.ops.alignment_score_pair_jnp``).  Feasibility and job-size
comparisons stay exact too: on grid-quantized demands
(``simulate_mr_trace``, ``quantize.to_grid``) every occupancy is a dyadic
rational ``k/2**16`` that float64 adds and compares without rounding.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


def alignment_scores(avail: np.ndarray, demand: np.ndarray) -> np.ndarray:
    """Tetris alignment <demand, avail> per server, exact float64 form.

    ``avail`` is (L, R), ``demand`` is (R,).  On grid-quantized values
    every product is an integer multiple of ``2**-32`` with magnitude
    below R — at most ~34 of float64's 53 mantissa bits — so each product
    AND every partial sum is exact, making the result independent of
    accumulation order, SIMD width and backend.  The jnp engines compare
    the identical scores as an exact int32 pair
    (``engine.ops.alignment_score_pair_jnp``), so argmin tie-breaks
    bit-match across numpy and XLA.
    """
    prods = avail.astype(np.float64) * demand.astype(np.float64)[None, :]
    return prods.sum(axis=1)


@dataclass
class MRJob:
    jid: int
    demand: np.ndarray        # (R,) in (0, 1]^R
    arrival: int
    dur: int = 0
    tries: int = 0            # completed requeue attempts (fault preemption)
    dep_time: int = -1        # scheduled departure slot while in service
    seq: int = -1             # queue-ordering id; refreshed on each requeue


@dataclass
class MRResult:
    queue_lens: np.ndarray
    arrived: int
    departed: int
    mean_queue: float
    mean_queue_tail: float
    final_queue: int
    utilization: np.ndarray    # per-resource mean occupancy fraction
    extras: dict = field(default_factory=dict)


class MultiResourceBFJS:
    """BF-J/S with the alignment score over R resources.

    BF-S step (freed servers): repeatedly place the queued job with the
    highest alignment that fits.  BF-J step (new jobs): place on the
    highest-alignment feasible server.
    """

    name = "mr-bf-js"

    def __init__(self, L: int, num_resources: int,
                 capacity: float | tuple[float, ...] = 1.0):
        self.L = L
        self.R = num_resources
        self.capacity = np.broadcast_to(
            np.asarray(capacity, dtype=np.float64), (num_resources,)).copy()
        self.occupied = np.zeros((L, num_resources))
        self.jobs: list[dict[int, MRJob]] = [dict() for _ in range(L)]
        self.queue: dict[int, MRJob] = {}
        self._dep: dict[int, list[tuple[int, int]]] = {}
        # fault-preemption accounting (invariant: preempted == requeued
        # + lost) and the queue-ordering seq counter: every queue
        # insertion — arrival or requeue — takes the next seq, so dict
        # iteration order is always ascending seq (what the scan engine's
        # qseq tie-breaks reproduce).
        self.preempted = 0
        self.requeued = 0
        self.lost = 0
        self._seq = 0
        self._down_last = np.zeros(L, dtype=bool)

    # -- scores -------------------------------------------------------------
    def _feasible(self, demand: np.ndarray) -> np.ndarray:
        return (self.occupied + demand[None, :]
                <= self.capacity[None, :] + 1e-12).all(axis=1)

    def _best_server(self, demand: np.ndarray,
                     down: np.ndarray | None = None) -> int:
        feas = self._feasible(demand)
        if down is not None:
            feas = feas & ~down
        if not feas.any():
            return -1
        avail = self.capacity[None, :] - self.occupied
        # tightest-in-needed-dims = argmin of the exact alignment score
        # (order-independent — see alignment_scores)
        scores = alignment_scores(avail, demand)
        scores[~feas] = np.inf
        return int(np.argmin(scores))

    def _best_job(self, server: int) -> MRJob | None:
        """BF-S: the LARGEST queued job (by total demand) that fits —
        the multi-resource analogue of largest-fitting-first."""
        if not self.queue:
            return None
        occ = self.occupied[server]
        best, best_s = None, -np.inf
        for job in self.queue.values():
            if np.all(occ + job.demand <= self.capacity + 1e-12):
                s = float(job.demand.sum())
                if s > best_s:
                    best, best_s = job, s
        return best

    # -- engine ---------------------------------------------------------------
    def _place(self, t: int, server: int, job: MRJob) -> None:
        self.occupied[server] += job.demand
        self.jobs[server][job.jid] = job
        job.dep_time = t + max(job.dur, 1)
        self._dep.setdefault(job.dep_time, []).append((server, job.jid))

    def step(self, t: int, new_jobs: list[MRJob],
             down: np.ndarray | None = None,
             max_requeue: int = 2) -> None:
        """One slot: departures, fault preemption, arrivals, BF-S, BF-J.

        ``down`` marks servers whose capacity is lost this slot (fault
        plane); every job in service there is preempted — requeued with
        its REMAINING duration while ``tries < max_requeue``, counted
        ``lost`` otherwise.  Victims are processed in ascending ``seq``
        order so requeues re-enter the queue exactly where the scan
        engine's fresh-seq scatter puts them.  Down servers never receive
        placements; a server recovering (down last slot, up now) rejoins
        the BF-S freed set."""
        freed = set()
        for server, jid in self._dep.pop(t, []):
            job = self.jobs[server].pop(jid)
            self.occupied[server] -= job.demand
            freed.add(server)
        self.occupied = np.clip(self.occupied, 0.0, None)
        down = (np.zeros(self.L, dtype=bool) if down is None
                else np.asarray(down, dtype=bool))
        victims = []
        for server in np.flatnonzero(down):
            for jid, job in self.jobs[server].items():
                victims.append((job.seq, int(server), jid))
        for _, server, jid in sorted(victims):
            job = self.jobs[server].pop(jid)
            self.occupied[server] -= job.demand
            self._dep[job.dep_time].remove((server, jid))
            self.preempted += 1
            if job.tries < max_requeue:
                job.tries += 1
                job.dur = max(job.dep_time - t, 1)
                job.seq = self._seq
                self._seq += 1
                self.queue[jid] = job
                self.requeued += 1
            else:
                self.lost += 1
        if victims:
            self.occupied = np.clip(self.occupied, 0.0, None)
        recovered = self._down_last & ~down
        freed |= {int(s) for s in np.flatnonzero(recovered)}
        freed -= {int(s) for s in np.flatnonzero(down)}
        self._down_last = down
        for job in new_jobs:
            job.seq = self._seq
            self._seq += 1
            self.queue[job.jid] = job
        # BF-S over freed (and just-recovered) servers
        for server in sorted(freed):
            while True:
                job = self._best_job(server)
                if job is None:
                    break
                del self.queue[job.jid]
                self._place(t, server, job)
        # BF-J over new arrivals still queued
        for job in new_jobs:
            if job.jid in self.queue:
                server = self._best_server(job.demand, down)
                if server >= 0:
                    del self.queue[job.jid]
                    self._place(t, server, job)

    def queue_len(self) -> int:
        return len(self.queue)


def simulate_mr(policy: MultiResourceBFJS, lam: float,
                demand_sampler, mean_service: float, horizon: int,
                seed: int = 0, record_every: int = 10) -> MRResult:
    """demand_sampler(rng, n) -> (n, R) demands in (0,1]^R."""
    rng = np.random.Generator(np.random.Philox(seed))
    jid = 0
    arrived = 0
    qsum = qsum_tail = 0.0
    tail = horizon // 2
    occ_sum = np.zeros(policy.R)
    records = []
    for t in range(horizon):
        n = int(rng.poisson(lam))
        jobs = []
        if n:
            demands = demand_sampler(rng, n)
            durs = rng.geometric(1.0 / mean_service, size=n)
            for i in range(n):
                jobs.append(MRJob(jid, np.asarray(demands[i]), t,
                                  int(durs[i])))
                jid += 1
            arrived += n
        policy.step(t, jobs)
        q = policy.queue_len()
        qsum += q
        if t >= tail:
            qsum_tail += q
        occ_sum += policy.occupied.mean(axis=0)
        if t % record_every == 0:
            records.append(q)
    in_service = sum(len(s) for s in policy.jobs)
    return MRResult(
        queue_lens=np.asarray(records),
        arrived=arrived,
        departed=arrived - in_service - policy.queue_len(),
        mean_queue=qsum / horizon,
        mean_queue_tail=qsum_tail / max(horizon - tail, 1),
        final_queue=policy.queue_len(),
        utilization=occ_sum / horizon,
    )


def simulate_mr_trace(policy: MultiResourceBFJS, arrival_slots, demands,
                      durations, horizon: int | None = None,
                      record_every: int = 1) -> MRResult:
    """Replay a trace of (R,)-vector demands through the event-driven
    oracle — the parity bridge for the ``policy="bfjs-mr"`` scan engine.

    Mirrors ``simulator.simulate_trace`` preprocessing: stable sort by
    arrival slot, demands quantized to the ``quantize.RES`` grid (the
    replayed values are the exact dyadics ``g / RES``, so every occupancy
    comparison is exact in float64), durations clamped to >= 1.  Records
    the queue length every ``record_every`` slots and the per-resource
    occupancy plane every slot (``extras["occupancy"]``, shape (T, R), in
    servers) plus cumulative departures (``extras["departed_cum"]``).
    """
    from .quantize import RES, to_grid

    arrival_slots = np.asarray(arrival_slots)
    order = np.argsort(arrival_slots, kind="stable")
    arrival_slots = arrival_slots[order].astype(np.int64)
    demands = np.asarray(demands)[order]
    if demands.ndim != 2 or demands.shape[1] != policy.R:
        raise ValueError(
            f"demands must be (N, R={policy.R}), got {demands.shape}")
    dem_g = to_grid(demands).astype(np.float64) / RES
    durations = np.maximum(np.asarray(durations)[order].astype(np.int64), 1)
    n_jobs = len(arrival_slots)
    if horizon is None:
        horizon = int(arrival_slots[-1]) + 1

    records: list[int] = []
    occ_plane = np.zeros((horizon, policy.R))
    dep_cum = np.zeros(horizon, dtype=np.int64)
    qsum = qsum_tail = 0.0
    tail = horizon // 2
    ptr = 0
    for t in range(horizon):
        jobs = []
        while ptr < n_jobs and arrival_slots[ptr] <= t:
            jobs.append(MRJob(ptr, dem_g[ptr], t, int(durations[ptr])))
            ptr += 1
        policy.step(t, jobs)
        q = policy.queue_len()
        qsum += q
        if t >= tail:
            qsum_tail += q
        in_service = sum(len(s) for s in policy.jobs)
        dep_cum[t] = ptr - in_service - q
        occ_plane[t] = policy.occupied.sum(axis=0)
        if t % record_every == 0:
            records.append(q)

    return MRResult(
        queue_lens=np.asarray(records),
        arrived=ptr,
        departed=int(dep_cum[-1]) if horizon else 0,
        mean_queue=qsum / max(horizon, 1),
        mean_queue_tail=qsum_tail / max(horizon - tail, 1),
        final_queue=policy.queue_len(),
        utilization=occ_plane.mean(axis=0) / max(policy.L, 1),
        extras={"occupancy": occ_plane, "departed_cum": dep_cum},
    )


class CollapsedMaxBFJS(MultiResourceBFJS):
    """Baseline: the paper's max-collapse preprocessing inside the same
    engine — every job's demand is replaced by max(demand) * 1_R, so
    resources are over-reserved (what Section VIII improves upon)."""

    name = "mr-max-collapse"

    def step(self, t, new_jobs, down=None, max_requeue=2):
        for job in new_jobs:
            job.demand = np.full(self.R, float(job.demand.max()))
        super().step(t, new_jobs, down=down, max_requeue=max_requeue)
