"""Policy-generic entry points for the accelerator engines.

One registry maps policy names to their engine implementations so every
caller — serving capacity planner, benchmarks, examples, later sharded /
multi-resource fleets — dispatches through the same three calls:

    run_policy(key, lam, mu, sampler, policy="vqs", engine="scan", ...)
    run_policy_streams(streams, policy="vqs", engine="scan", ...)   # traces
    monte_carlo_policy(keys, ..., policy="bfjs", engine="pallas")

``engine`` is always one of ``"reference" | "scan" | "pallas"`` with the
same contract as PR 1's BF-J/S stack: "scan" bit-matches "reference" while
``truncated == 0``, and "pallas" bit-matches "scan".  Policy-specific
configuration (``J`` for VQS, ``work_steps`` bounds, ...) passes through as
keyword arguments; unknown keys are rejected by the policy's runner.

New policies register with ``register_policy`` — the hook the roadmap's
multi-resource and admission-control engines plug into.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax

from .bfjs import monte_carlo_bfjs, run_bfjs, run_bfjs_trace
from .streams import PolicyResult, SchedStreams
from .vqs import monte_carlo_vqs, run_vqs, run_vqs_trace

ENGINES = ("reference", "scan", "pallas")


@dataclass(frozen=True)
class PolicySpec:
    """Engine implementations of one scheduling policy."""
    name: str
    run: Callable[..., PolicyResult]          # (key, lam, mu, sampler, ...)
    run_streams: Callable[..., PolicyResult]  # (streams, ...)
    monte_carlo: Callable[..., PolicyResult]  # (keys, lam, mu, sampler, ...)


_POLICIES: dict[str, PolicySpec] = {}


def register_policy(spec: PolicySpec) -> PolicySpec:
    if spec.name in _POLICIES:
        raise ValueError(f"policy {spec.name!r} already registered")
    _POLICIES[spec.name] = spec
    return spec


def available_policies() -> tuple[str, ...]:
    return tuple(sorted(_POLICIES))


def get_policy(policy: str) -> PolicySpec:
    try:
        return _POLICIES[policy]
    except KeyError:
        raise ValueError(
            f"unknown policy {policy!r}; registered: "
            f"{', '.join(available_policies())}") from None


def _check_engine(engine: str) -> None:
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; expected one of "
                         f"{', '.join(ENGINES)}")


register_policy(PolicySpec(
    name="bfjs",
    run=run_bfjs,
    run_streams=run_bfjs_trace,
    monte_carlo=monte_carlo_bfjs,
))

register_policy(PolicySpec(
    name="vqs",
    run=run_vqs,
    run_streams=run_vqs_trace,
    monte_carlo=monte_carlo_vqs,
))


def run_policy(key: jax.Array, lam: float, mu: float, sampler,
               *, policy: str = "bfjs", engine: str = "scan",
               **config) -> PolicyResult:
    """Simulate one cluster under ``policy`` with the chosen ``engine``.

    ``sampler(key, n) -> (n,)`` float job sizes in (0, 1].  ``config``
    passes through to the policy runner (``L``, ``K``, ``Qcap``, ``A_max``,
    ``horizon``, ``work_steps``; ``J``/``drain`` for VQS).
    """
    _check_engine(engine)
    return get_policy(policy).run(key, lam, mu, sampler, engine=engine,
                                  **config)


def run_policy_streams(streams: SchedStreams, *, policy: str = "bfjs",
                       engine: str = "scan", **config) -> PolicyResult:
    """Replay explicit streams (e.g. ``streams_from_trace``) through a
    policy engine — the trace-driven path of the stack."""
    _check_engine(engine)
    return get_policy(policy).run_streams(streams, engine=engine, **config)


def monte_carlo_policy(keys: jax.Array, lam: float, mu: float, sampler,
                       *, policy: str = "bfjs", engine: str = "scan",
                       **config) -> PolicyResult:
    """One simulated cluster per key; "pallas" runs the ensemble as the
    kernel grid, other engines vmap."""
    _check_engine(engine)
    return get_policy(policy).monte_carlo(keys, lam, mu, sampler,
                                          engine=engine, **config)
