"""Workload-first policy-generic entry points for the accelerator engines.

One registry maps policy names to their engine implementations so every
caller — serving capacity planner, benchmarks, examples, later sharded /
admission-control fleets — dispatches through the same three calls, all
keyed on a first-class :class:`~repro.core.engine.workload.Workload`:

    wl = Workload(lam=1.5, mu=0.01, sampler=sampler)        # R = 1
    run_policy(wl, policy="vqs", engine="scan", key=key, L=8, ...)
    run_policy_streams(streams, policy="vqs", engine="scan", ...)  # traces
    monte_carlo_policy(wl, keys, policy="bfjs", engine="pallas", ...)

``engine`` is always one of ``"reference" | "scan" | "pallas"`` with the
same contract as the BF-J/S stack: "scan" bit-matches "reference" while
``truncated == 0``, and "pallas" bit-matches "scan".  Policy-specific
configuration (``J`` for VQS, ``work_steps`` bounds, ...) passes through as
keyword arguments; unknown keys are rejected by the policy's runner.

Multi-resource workloads (``num_resources=R > 1``, per-resource
``capacity``) route to ``policy="bfjs-mr"`` — the Tetris-alignment BF-J/S
of paper Section VIII; the single-resource policies reject them loudly.

The PR 2 loose-argument signatures, ``run_policy(key, lam, mu, sampler,
...)`` / ``monte_carlo_policy(keys, lam, mu, sampler, ...)``, remain as
deprecation shims that build a ``Workload`` internally — bit-match
regression tested (``tests/test_workload_api.py``), so existing callers
keep their exact trajectories while migrating.

New policies register with ``register_policy`` — the hook the roadmap's
sharded-ensemble and admission-control engines plug into.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Callable

import jax

from .bfjs import (monte_carlo_bfjs_workload, run_bfjs_trace,
                   run_bfjs_workload)
from .bfjs_mr import (monte_carlo_bfjs_mr_workload, run_bfjs_mr_trace,
                      run_bfjs_mr_workload)
from .streams import PolicyResult, SchedStreams
from .vqs import monte_carlo_vqs_workload, run_vqs_trace, run_vqs_workload
from .vqs_bf import (monte_carlo_vqs_bf_workload, run_vqs_bf_trace,
                     run_vqs_bf_workload)
from .workload import Workload

ENGINES = ("reference", "scan", "pallas")


@dataclass(frozen=True)
class PolicySpec:
    """Engine implementations of one scheduling policy.

    ``run``/``monte_carlo`` are workload-first: they take a ``Workload``
    and the PRNG key(s); ``run_streams`` takes pre-materialized
    ``SchedStreams`` (randomness already drawn or trace-built), so it needs
    no workload.
    """
    name: str
    run: Callable[..., PolicyResult]          # (workload, key, ...)
    run_streams: Callable[..., PolicyResult]  # (streams, ...)
    monte_carlo: Callable[..., PolicyResult]  # (workload, keys, ...)


_POLICIES: dict[str, PolicySpec] = {}


def register_policy(spec: PolicySpec) -> PolicySpec:
    if spec.name in _POLICIES:
        raise ValueError(f"policy {spec.name!r} already registered")
    _POLICIES[spec.name] = spec
    return spec


def available_policies() -> tuple[str, ...]:
    return tuple(sorted(_POLICIES))


def get_policy(policy: str) -> PolicySpec:
    try:
        return _POLICIES[policy]
    except KeyError:
        raise ValueError(
            f"unknown policy {policy!r}; registered: "
            f"{', '.join(available_policies())}") from None


def _check_engine(engine: str) -> None:
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; expected one of "
                         f"{', '.join(ENGINES)}")


register_policy(PolicySpec(
    name="bfjs",
    run=run_bfjs_workload,
    run_streams=run_bfjs_trace,
    monte_carlo=monte_carlo_bfjs_workload,
))

register_policy(PolicySpec(
    name="vqs",
    run=run_vqs_workload,
    run_streams=run_vqs_trace,
    monte_carlo=monte_carlo_vqs_workload,
))

register_policy(PolicySpec(
    name="bfjs-mr",
    run=run_bfjs_mr_workload,
    run_streams=run_bfjs_mr_trace,
    monte_carlo=monte_carlo_bfjs_mr_workload,
))

register_policy(PolicySpec(
    name="vqs-bf",
    run=run_vqs_bf_workload,
    run_streams=run_vqs_bf_trace,
    monte_carlo=monte_carlo_vqs_bf_workload,
))


def _legacy_workload(fn_name: str, legacy: tuple) -> Workload:
    """Build a Workload from the deprecated (lam, mu, sampler) tail."""
    if len(legacy) != 3:
        raise TypeError(
            f"{fn_name} takes a Workload (new API) or the deprecated "
            f"(key, lam, mu, sampler) form; got {1 + len(legacy)} "
            "positional arguments")
    lam, mu, sampler = legacy
    warnings.warn(
        f"{fn_name}(key, lam, mu, sampler, ...) is deprecated; pass a "
        f"Workload: {fn_name}(Workload(lam=lam, mu=mu, sampler=sampler), "
        "key=key, ...)", DeprecationWarning, stacklevel=3)
    return Workload(lam=float(lam), mu=float(mu), sampler=sampler)


def run_policy(workload, *legacy, policy: str = "bfjs",
               engine: str = "scan", key: jax.Array | None = None,
               **config) -> PolicyResult:
    """Simulate one cluster under ``policy`` with the chosen ``engine``.

    ``workload`` is a :class:`Workload` (arrival rate, size sampler,
    service rate, resource count, per-resource capacity); ``key`` — passed
    positionally (``run_policy(wl, key, ...)``, mirroring
    ``monte_carlo_policy``) or as ``key=`` — seeds the pre-generated
    randomness streams (default ``PRNGKey(0)``).  ``config`` passes
    through to the policy runner (``L``, ``K``, ``Qcap``, ``A_max``,
    ``horizon``, ``work_steps``; ``J``/``drain`` for VQS).

    The deprecated positional form ``run_policy(key, lam, mu, sampler,
    ...)`` builds the same Workload internally (bit-identical results) and
    emits a ``DeprecationWarning``.
    """
    _check_engine(engine)
    if not isinstance(workload, Workload):
        legacy_key = workload
        workload = _legacy_workload("run_policy", legacy)
        return get_policy(policy).run(workload, legacy_key, engine=engine,
                                      **config)
    if legacy:
        if len(legacy) != 1 or key is not None:
            raise TypeError(
                "run_policy(workload, key, ...) takes exactly one extra "
                "positional argument (the PRNG key)")
        key = legacy[0]
    if key is None:
        key = jax.random.PRNGKey(0)
    from .tuning import apply_tuned
    apply_tuned(policy, engine, config, workload.num_resources)
    return get_policy(policy).run(workload, key, engine=engine, **config)


def run_policy_streams(streams: SchedStreams, *, policy: str = "bfjs",
                       engine: str = "scan",
                       checkpoint_dir: str | None = None,
                       chunk: int | None = None, resume: bool = False,
                       stop_after_chunks: int | None = None,
                       mesh=None, devices=None, audit: bool = False,
                       **config) -> PolicyResult:
    """Replay explicit streams (e.g. ``streams_from_trace``) through a
    policy engine — the trace-driven path of the stack.  Multi-resource
    streams (``(T, A_max, R)`` sizes, e.g. ``streams_from_trace(trace,
    collapse=False)``) replay through ``policy="bfjs-mr"``.

    ``chunk=``/``checkpoint_dir=`` turn the sweep crash-safe: the scan
    engine runs in ``chunk``-slot pieces, persisting its complete carry at
    every boundary (atomic rename) so ``resume=True`` continues a killed
    sweep BIT-EXACTLY where it stopped (see ``core.engine.chunked``).
    Only ``engine="scan"`` supports this — reference keeps host-side
    state, pallas keeps VMEM-resident state; both are rejected loudly.
    Ensemble-batched streams (leading G axis) may add ``mesh=``/
    ``devices=`` to shard the ensemble over devices per chunk
    (``core.engine.sharding``).

    For streams that are NOT fully materialized — an unbounded arrival
    iterator, a multi-GB trace read chunk-by-chunk — use
    ``core.engine.stream_policy``, which threads the same carried state
    through any chunk iterator, double-buffers host ingestion against
    device compute, and bit-matches this function on any finite trace.

    ``audit=True`` runs the runtime invariant auditor over the finished
    result (``core.engine.supervisor.audit_result`` — job conservation,
    capacity bounds, fault accounting) and raises a typed
    ``InvariantViolation`` naming the failed counter; it needs explicit
    ``L=``/``K=`` in the config.
    """
    _check_engine(engine)
    from .sharding import resolve_mesh
    from .tuning import apply_tuned
    mesh = resolve_mesh(mesh, devices)
    n_res = 1 if streams.sizes.ndim == streams.durs.ndim \
        else int(streams.sizes.shape[-1])
    apply_tuned(policy, engine, config, n_res)
    audit_cfg = dict(config)

    def _audited(res: PolicyResult) -> PolicyResult:
        if audit:
            from .supervisor import audit_result
            audit_result(streams, res, policy=policy, config=audit_cfg)
        return res

    if chunk is not None or checkpoint_dir is not None or resume:
        if engine != "scan":
            raise ValueError(
                f'checkpointed chunked sweeps need engine="scan" (its '
                f"carry is the entire simulation state); got "
                f"engine={engine!r}")
        if chunk is None:
            raise ValueError("checkpoint_dir=/resume= need chunk= (the "
                             "boundary interval, in slots)")
        from .chunked import run_chunked
        config.pop("strict", None)
        config.pop("window", None)
        return _audited(run_chunked(
            streams, policy=policy, chunk=chunk,
            checkpoint_dir=checkpoint_dir, resume=resume,
            stop_after_chunks=stop_after_chunks, mesh=mesh, **config))
    if mesh is not None:
        raise ValueError(
            "mesh=/devices= on run_policy_streams needs the chunked path "
            "(chunk=); for straight sharded Monte-Carlo use "
            "monte_carlo_policy(..., mesh=)")
    return _audited(get_policy(policy).run_streams(streams, engine=engine,
                                                   **config))


def monte_carlo_policy(workload, *legacy, policy: str = "bfjs",
                       engine: str = "scan",
                       keys: jax.Array | None = None,
                       mesh=None, devices=None,
                       chunk: int | None = None,
                       checkpoint_dir: str | None = None,
                       resume: bool = False,
                       stop_after_chunks: int | None = None,
                       **config) -> PolicyResult:
    """One simulated cluster per key; "pallas" runs the ensemble as the
    kernel grid, other engines vmap (the host-side oracles loop).

    New API: ``monte_carlo_policy(workload, keys, policy=..., ...)`` (or
    ``keys=`` by keyword).  The deprecated ``monte_carlo_policy(keys, lam,
    mu, sampler, ...)`` form is a bit-match shim.

    ``mesh=`` (a 1-D ``jax.sharding.Mesh``) or ``devices=`` (an int or
    device list) shards the ensemble dimension over devices — bit-identical
    to the single-device run, one G/D shard per device
    (``core.engine.sharding``; ``engine="reference"`` is host-side and
    ignores the mesh).  ``chunk=``/``checkpoint_dir=``/``resume=`` run the
    sweep crash-safe in T-chunks (scan engine only), composing with the
    mesh; checkpoints never pin a device count, so a sweep may resume on a
    different mesh size.
    """
    _check_engine(engine)
    if not isinstance(workload, Workload):
        legacy_keys = workload
        workload = _legacy_workload("monte_carlo_policy", legacy)
        return get_policy(policy).monte_carlo(workload, legacy_keys,
                                              engine=engine, **config)
    if legacy:
        if len(legacy) != 1 or keys is not None:
            raise TypeError(
                "monte_carlo_policy(workload, keys, ...) takes exactly one "
                "extra positional argument (the key batch)")
        keys = legacy[0]
    if keys is None:
        raise TypeError("monte_carlo_policy needs keys= (one PRNG key per "
                        "ensemble member)")
    from .sharding import (monte_carlo_chunked, resolve_mesh,
                           sharded_monte_carlo)
    from .tuning import apply_tuned
    mesh = resolve_mesh(mesh, devices)
    apply_tuned(policy, engine, config, workload.num_resources)
    if chunk is not None or checkpoint_dir is not None or resume:
        if engine != "scan":
            raise ValueError(
                f'checkpointed chunked sweeps need engine="scan" (its '
                f"carry is the entire simulation state); got "
                f"engine={engine!r}")
        if chunk is None:
            raise ValueError("checkpoint_dir=/resume= need chunk= (the "
                             "boundary interval, in slots)")
        config.pop("strict", None)
        config.pop("window", None)
        return monte_carlo_chunked(workload, keys, policy=policy,
                                   chunk=chunk, mesh=mesh,
                                   checkpoint_dir=checkpoint_dir,
                                   resume=resume,
                                   stop_after_chunks=stop_after_chunks,
                                   **config)
    if mesh is not None:
        return sharded_monte_carlo(workload, keys, policy=policy,
                                   mesh=mesh, engine=engine, **config)
    return get_policy(policy).monte_carlo(workload, keys, engine=engine,
                                          **config)
