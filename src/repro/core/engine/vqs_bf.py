"""VQS-BF accelerator engines (paper Section VI, Theorem 4: the VQS 2/3
throughput guarantee with BF-like delay).

Re-expresses the event-driven ``core/vqs_bf.py`` scheduler as fixed-shape
JAX programs on the ``SchedStreams`` stack.  VQS-BF keeps VQS's
configuration machinery (max-weight renewal at server-empty epochs,
subscription wake-ups) but replaces head-of-queue FIFO service with
LARGEST-fit-first pops and adds two Best-Fit passes:

  (i)   with k_1 = 1 the server takes the largest fitting VQ_1 job,
        reserving exactly that job's size (no blanket 2/3 reservation);
  (ii)  the other configured type j* is served largest-fit-first from the
        FULL residual, stopping at k_{j*} resident jobs of that type;
  (iii) the remaining capacity is swept BF-S style: keep taking the
        largest fitting job over ALL virtual queues until nothing fits;
  (iv)  an arrival-side BF-J pass offers every still-queued arrival of the
        slot to the tightest feasible server.

The largest-fit-first multiset is per-VQ size-bucketed rings: one
``(2J, Qcap)`` effective-size plane bucketed by VQ type with first-empty-
slot allocation (pops punch holes; pushes fill the lowest hole), plus a
monotone arrival-sequence plane so "pop the largest job <= cap" is a pure
masked lexicographic reduction — maximum effective size, then lowest VQ
index (the ascending strict-improvement scan of
``VirtualQueues.pop_largest_leq_any``), then smallest sequence stamp
(FIFO among equals, exactly ``SortedJobQueue``'s deque order).

Engines:

  * ``engine="reference"`` — nested ``fori/while/cond`` transcription of
    the numpy scheduler, the behavioural oracle (on trace streams it
    reproduces ``simulate_trace(VQSBF(J), ...)`` bit-for-bit);
  * ``engine="scan"``      — branch-free bounded work list.  Each step
    advances past every pending visited server that cannot place (shared
    max-weight renewal, subscription mask writes) and serves the first
    server that can with ONE pop-and-place (largest-fit depends on the
    post-placement residual, so placements cannot be batched the way
    VQS's head-of-queue prefix-fit can) — a slot costs (#placements + 1)
    early-exit iterations;
  * ``engine="pallas"``    — the fused kernel in ``kernels/vqs_bf`` (rings
    and configurations resident in VMEM, Monte-Carlo ensemble as the grid).

Fixed-shape deviations are counted, never silent: ring overflow in
``dropped``, per-server K-slot overflow and lazily-finished slots in
``truncated`` (``truncated == 0`` is the bit-match precondition).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from ..quantize import RES
from .bfjs import DEFAULT_MAX_REQUEUE
from .ops import k_red_jnp, vq_type_of_grid
from .streams import (INF_SLOT, PolicyResult, SchedStreams, make_streams,
                      resolve_work_steps)

CAP = RES
_INF32 = jnp.iinfo(jnp.int32).max


def _decode_config_bf(row: jax.Array, J: int):
    """(k1, jstar, kstar) of a K_RED row — ``VQS._set_config`` plus the
    k_{j*} cap that VQS-BF's step (ii) enforces."""
    nvq = 2 * J
    j_iota = jnp.arange(nvq)
    k1 = row[1] > 0
    js = jnp.min(jnp.where((row > 0) & (j_iota != 1), j_iota, nvq))
    jsx = jnp.minimum(js, nvq - 1)
    ks = jnp.where(js < nvq, row[jsx], 0).astype(jnp.int32)
    return k1, jnp.where(js == nvq, -1, js).astype(jnp.int32), ks


def _mw_config_bf(confs: jax.Array, qcnt: jax.Array, J: int):
    """First-index max-weight row over K_RED (paper Eq. 8, np.argmax ties)."""
    w = confs @ qcnt
    c_iota = jnp.arange(confs.shape[0])
    i = jnp.min(jnp.where(w == w.max(), c_iota, confs.shape[0]))
    row = confs[jnp.minimum(i, confs.shape[0] - 1)]
    return _decode_config_bf(row, J)


def _pop_largest(ring_eff, ring_seq, rows_ok, cap):
    """Locate the pop of ``VirtualQueues.pop_largest_leq_any`` restricted to
    ``rows_ok``: maximum effective size <= cap, ties to the lowest VQ index,
    FIFO among equals via the smallest sequence stamp.  Returns
    ``(found, vq, pos)`` with clamped-in-range indices when not found."""
    nvq, Qcap = ring_eff.shape
    j_iota = jnp.arange(nvq)
    q_iota = jnp.arange(Qcap)
    elig = (ring_eff > 0) & rows_ok[:, None] & (ring_eff <= cap)
    best_eff = jnp.max(jnp.where(elig, ring_eff, 0))
    cand = elig & (ring_eff == best_eff)
    vq = jnp.min(jnp.where(cand.any(axis=1), j_iota, nvq))
    found = vq < nvq
    vqc = jnp.minimum(vq, nvq - 1)
    row_cand = cand[vqc]
    seq_row = ring_seq[vqc]
    best_seq = jnp.min(jnp.where(row_cand, seq_row, _INF32))
    pos = jnp.min(jnp.where(row_cand & (seq_row == best_seq), q_iota, Qcap))
    return found, vqc, jnp.minimum(pos, Qcap - 1)


def _push_arrivals_bf(ring_eff, ring_dur, ring_seq, qcnt, dropped, seq_ctr,
                      n_t, sizes_t, durs_t, *, J, Qcap, A_max,
                      ring_try=None):
    """Classify + bucket one slot's arrivals (vectorized, order-exact).

    Every arrival lands in the lowest empty slot of its VQ's bucket ring
    (lane order within the slot — the rank-into-empty-slots scatter below
    is exactly A_max sequential first-empty pushes) and is stamped with a
    monotone sequence number so largest-fit pops stay FIFO among equals.
    Arrivals whose bucket is full are dropped and counted.  Returns the
    per-lane ``(vq, pos, seq, eff, dur, landed)`` records the slot's
    arrival-side BF-J pass keys on.
    """
    nvq = 2 * J
    a_iota = jnp.arange(A_max)
    j_iota = jnp.arange(nvq)
    q_iota = jnp.arange(Qcap)
    dur_off = durs_t.shape[0] - A_max
    g = jnp.maximum(jnp.round(sizes_t * RES), 1.0).astype(jnp.int32)
    vq = vq_type_of_grid(g, J)
    eff = jnp.where(vq == nvq - 1, jnp.maximum(g, RES >> J), g)
    dur = durs_t[dur_off + a_iota]
    valid = a_iota < n_t
    oh = (vq[:, None] == j_iota[None, :]) & valid[:, None]      # (A, 2J)
    rank = ((jnp.cumsum(oh.astype(jnp.int32), axis=0) - 1) * oh).sum(1)
    emp = ring_eff == 0
    erank = jnp.cumsum(emp.astype(jnp.int32), axis=1) - 1       # (2J, Qcap)
    empty_cnt = emp.sum(axis=1)
    land = valid & (rank < empty_cnt[vq])
    sel = emp[vq] & (erank[vq] == rank[:, None])                # (A, Qcap)
    pos = jnp.minimum(jnp.min(jnp.where(sel, q_iota[None, :], Qcap), axis=1),
                      Qcap - 1)
    seq = seq_ctr + a_iota
    vq_w = jnp.where(land, vq, nvq)
    ring_eff = ring_eff.at[vq_w, pos].set(eff, mode="drop")
    ring_dur = ring_dur.at[vq_w, pos].set(dur, mode="drop")
    ring_seq = ring_seq.at[vq_w, pos].set(seq, mode="drop")
    if ring_try is not None:
        ring_try = ring_try.at[vq_w, pos].set(0, mode="drop")
    qcnt = qcnt + (oh & land[:, None]).sum(0).astype(jnp.int32)
    dropped = dropped + (valid & ~land).sum()
    arrived = oh.any(0)
    lanes = (vq, pos, seq, eff, dur, land)
    return (ring_eff, ring_dur, ring_seq, qcnt, dropped, seq_ctr + A_max,
            arrived, ring_try, lanes)


def _preempt_rings_bf(srv, dep, vqof, ring_eff, ring_dur, ring_seq, ring_try,
                      qcnt, seq_ctr, srv_try, up_t, t, max_requeue,
                      *, J, Qcap):
    """Evict every job resident on a down server (DESIGN.md §9), VQS-BF
    form: victims below the retry bound re-enter their own bucket ring in
    row-major ``(server, k-slot)`` order — first-empty slots, fresh
    sequence stamps (so they queue behind every already-waiting equal-size
    job, the same tail-append rule as the VQS rings) — with their
    REMAINING duration and ``tries + 1``; victims past the bound or whose
    bucket is full are lost.  Shared verbatim by the scan engine and the
    reference oracle."""
    nvq = 2 * J
    L, K = srv.shape
    j_iota = jnp.arange(nvq)
    q_iota = jnp.arange(Qcap)
    victim = (~up_t)[:, None] & (srv > 0)                       # (L, K)
    elig = (victim & (srv_try < max_requeue)).reshape(-1)       # (L*K,)
    vq = jnp.where(elig, vqof.reshape(-1), nvq)
    vqc = jnp.minimum(vq, nvq - 1)
    oh = vq[:, None] == j_iota[None, :]                         # (L*K, 2J)
    rank = ((jnp.cumsum(oh.astype(jnp.int32), axis=0) - 1) * oh).sum(1)
    emp = ring_eff == 0
    erank = jnp.cumsum(emp.astype(jnp.int32), axis=1) - 1
    empty_cnt = emp.sum(axis=1)
    land = elig & (rank < empty_cnt[vqc])
    sel = emp[vqc] & (erank[vqc] == rank[:, None])              # (L*K, Qcap)
    pos = jnp.minimum(jnp.min(jnp.where(sel, q_iota[None, :], Qcap), axis=1),
                      Qcap - 1)
    rem = jnp.maximum(dep.reshape(-1) - t, 1)   # remaining service slots
    vq_w = jnp.where(land, vq, nvq)
    ring_eff = ring_eff.at[vq_w, pos].set(srv.reshape(-1), mode="drop")
    ring_dur = ring_dur.at[vq_w, pos].set(rem, mode="drop")
    ring_seq = ring_seq.at[vq_w, pos].set(seq_ctr + jnp.arange(L * K),
                                          mode="drop")
    ring_try = ring_try.at[vq_w, pos].set(srv_try.reshape(-1) + 1,
                                          mode="drop")
    qcnt = qcnt + (oh & land[:, None]).sum(0).astype(jnp.int32)
    re_arrived = (oh & land[:, None]).any(0)
    n_vict = victim.sum().astype(jnp.int32)
    n_req = land.sum().astype(jnp.int32)
    srv = jnp.where(victim, 0, srv)
    dep = jnp.where(victim, INF_SLOT, dep)
    vqof = jnp.where(victim, -1, vqof)
    srv_try = jnp.where(victim, 0, srv_try)
    return (srv, dep, vqof, ring_eff, ring_dur, ring_seq, ring_try, qcnt,
            seq_ctr + L * K, srv_try, n_vict, n_req, n_vict - n_req,
            re_arrived)


def _arrival_bf_pass(srv, dep, vqof, ring_eff, ring_seq, qcnt, in_empty,
                     srv_try, trunc, t, lanes, up_t, *, L, K, A_max,
                     faulted):
    """The slot's closing BF-J pass (``VQSBF.schedule`` tail): each arrival
    still sitting in its bucket (its sequence stamp survived the serve
    pass) goes to the tightest feasible server — minimum residual >= size,
    ties to the smallest server id, exactly ``Cluster.tightest_feasible``.
    Shared verbatim by the reference oracle and the scan engine (the pass
    is already sequential in the numpy scheduler, so an unrolled A_max
    loop IS the branch-free form)."""
    a_vq, a_pos, a_seq, a_eff, a_dur, a_land = lanes
    nvq = ring_eff.shape[0]
    l_iota = jnp.arange(L)
    k_iota = jnp.arange(K)
    for a in range(A_max):
        vq_a, pos_a = a_vq[a], a_pos[a]
        queued = a_land[a] & (ring_eff[vq_a, pos_a] > 0) \
            & (ring_seq[vq_a, pos_a] == a_seq[a])
        resid = CAP - srv.sum(axis=1)
        cand = resid >= a_eff[a]
        if faulted:
            cand = cand & up_t
        rbest = jnp.min(jnp.where(cand, resid, _INF32))
        s = jnp.min(jnp.where(cand & (resid == rbest), l_iota, L))
        do = queued & (s < L)
        sc = jnp.minimum(s, L - 1)
        kfree = jnp.min(jnp.where(srv[sc] == 0, k_iota, K))
        ok = kfree < K
        kw = jnp.where(do & ok, jnp.minimum(kfree, K - 1), K)
        srv = srv.at[sc, kw].set(a_eff[a], mode="drop")
        dep = dep.at[sc, kw].set(t + a_dur[a], mode="drop")
        vqof = vqof.at[sc, kw].set(vq_a, mode="drop")
        if faulted:  # fresh arrivals carry zero retries
            srv_try = srv_try.at[sc, kw].set(0, mode="drop")
        cvq = jnp.where(do, vq_a, nvq)
        ring_eff = ring_eff.at[cvq, pos_a].set(0, mode="drop")
        qcnt = qcnt.at[cvq].add(-1, mode="drop")
        trunc = trunc + (do & ~ok).astype(jnp.int32)
        in_empty = in_empty & ~((l_iota == s) & do)
    return srv, dep, vqof, ring_eff, qcnt, in_empty, srv_try, trunc


def _init_state(J: int, L: int, K: int, Qcap: int):
    nvq = 2 * J
    zero = jnp.zeros((), jnp.int32)
    return (
        jnp.zeros((L, K), jnp.int32),              # srv (eff sizes)
        jnp.full((L, K), INF_SLOT, jnp.int32),     # dep
        jnp.full((L, K), -1, jnp.int32),           # vqof
        jnp.zeros((nvq, Qcap), jnp.int32),         # ring_eff (0 == empty)
        jnp.ones((nvq, Qcap), jnp.int32),          # ring_dur
        jnp.zeros((nvq, Qcap), jnp.int32),         # ring_seq
        jnp.zeros((nvq,), jnp.int32),              # qcnt
        zero,                                      # seq_ctr
        jnp.zeros((L,), bool),                     # cfg_k1
        jnp.full((L,), -1, jnp.int32),             # cfg_js
        jnp.zeros((L,), jnp.int32),                # cfg_ks
        jnp.zeros((L,), bool),                     # has_cfg
        jnp.ones((L,), bool),                      # in_empty (all start empty)
        jnp.zeros((L, nvq), bool),                 # want
        zero, zero, zero,                          # t, dropped, truncated
        # fault-injection planes (zeros/ones when fault-free):
        jnp.zeros((nvq, Qcap), jnp.int32),         # ring_try
        jnp.zeros((L, K), jnp.int32),              # srv_try
        zero, zero, zero,                          # preempted, requeued, lost
        jnp.ones((L,), bool),                      # up_last
    )


@functools.partial(
    jax.jit, static_argnames=("J", "L", "K", "Qcap", "A_max", "max_requeue"))
def _run_vqs_bf_reference_streams(streams: SchedStreams, J: int, L: int,
                                  K: int, Qcap: int, A_max: int,
                                  max_requeue: int = DEFAULT_MAX_REQUEUE
                                  ) -> PolicyResult:
    """Nested-loop VQS-BF oracle over pre-generated streams.

    A control-flow-faithful transcription of ``core/vqs_bf.py`` +
    ``core/simulator.py``: sorted visit order via ``fori`` over servers,
    per-server renewal ``cond``, the (i) single largest-VQ_1 ``cond``, the
    (ii) capped largest-fit ``while``, the (iii) BF-S ``while`` and the
    closing arrival-side BF-J pass.  Serial and branch-heavy — the
    behavioural anchor the scan engine is tested against (and, through
    trace streams, the bridge to the numpy engine)."""
    nvq = 2 * J
    confs = k_red_jnp(J)
    j_iota = jnp.arange(nvq)
    k_iota = jnp.arange(K)
    faulted = streams.up is not None

    def slot_step(state, inp):
        (srv, dep, vqof, ring_eff, ring_dur, ring_seq, qcnt, seq_ctr,
         cfg_k1, cfg_js, cfg_ks, has_cfg, in_empty, want, t, dropped, trunc,
         ring_try, srv_try, preempted, requeued, lost, up_last) = state
        if faulted:
            n_t, sizes_t, durs_t, up_t = inp
        else:
            n_t, sizes_t, durs_t = inp
            up_t = None

        # 1. departures
        leaving = dep == t
        freed = leaving.any(axis=1)
        n_dep = leaving.sum()
        srv = jnp.where(leaving, 0, srv)
        vqof = jnp.where(leaving, -1, vqof)
        dep = jnp.where(leaving, INF_SLOT, dep)

        # 1b. capacity shocks (shared _preempt_rings_bf rule)
        re_arrived = None
        if faulted:
            srv_try = jnp.where(leaving, 0, srv_try)
            (srv, dep, vqof, ring_eff, ring_dur, ring_seq, ring_try, qcnt,
             seq_ctr, srv_try, n_p, n_r, n_l, re_arrived) = _preempt_rings_bf(
                srv, dep, vqof, ring_eff, ring_dur, ring_seq, ring_try, qcnt,
                seq_ctr, srv_try, up_t, t, max_requeue, J=J, Qcap=Qcap)
            preempted = preempted + n_p
            requeued = requeued + n_r
            lost = lost + n_l
            freed = (freed | (up_t & ~up_last)) & up_t
            up_last = up_t
        empty_now = (srv > 0).sum(axis=1) == 0

        # 2. arrivals
        (ring_eff, ring_dur, ring_seq, qcnt, dropped, seq_ctr, arrived, rt,
         lanes) = _push_arrivals_bf(
            ring_eff, ring_dur, ring_seq, qcnt, dropped, seq_ctr,
            n_t, sizes_t, durs_t, J=J, Qcap=Qcap, A_max=A_max,
            ring_try=ring_try if faulted else None)
        if faulted:
            ring_try = rt
            arrived = arrived | re_arrived

        # 3. visit set
        woken = (want & arrived[None, :]).any(axis=1)
        want = want & ~arrived[None, :]
        visit = freed | woken | (in_empty & (qcnt.sum() > 0))
        if faulted:
            visit = visit & up_t

        # 4. serve visited servers in ascending order
        def visit_server(i, carry):
            def place_from(rows_ok, c):
                (srv, dep, vqof, ring_eff, qcnt, in_empty, srv_try,
                 trunc) = c
                resid = CAP - srv[i].sum()
                _, pvq, ppos = _pop_largest(ring_eff, ring_seq, rows_ok,
                                            resid)
                eff_p = ring_eff[pvq, ppos]
                dur_p = ring_dur[pvq, ppos]
                kfree = jnp.min(jnp.where(srv[i] == 0, k_iota, K))
                ok = kfree < K
                kw = jnp.where(ok, jnp.minimum(kfree, K - 1), K)
                srv = srv.at[i, kw].set(eff_p, mode="drop")
                dep = dep.at[i, kw].set(t + dur_p, mode="drop")
                vqof = vqof.at[i, kw].set(pvq, mode="drop")
                if faulted:  # retry count rides with the job
                    srv_try = srv_try.at[i, kw].set(ring_try[pvq, ppos],
                                                    mode="drop")
                ring_eff = ring_eff.at[pvq, ppos].set(0)
                qcnt = qcnt.at[pvq].add(-1)
                trunc = trunc + (~ok).astype(jnp.int32)
                in_empty = in_empty.at[i].set(False)
                return (srv, dep, vqof, ring_eff, qcnt, in_empty, srv_try,
                        trunc)

            def serve(carry):
                (srv, dep, vqof, ring_eff, qcnt, cfg_k1, cfg_js, cfg_ks,
                 has_cfg, in_empty, want, srv_try, trunc) = carry
                need = empty_now[i] | ~has_cfg[i]
                r_k1, r_js, r_ks = _mw_config_bf(confs, qcnt, J)
                k1 = jnp.where(need, r_k1, cfg_k1[i])
                js = jnp.where(need, r_js, cfg_js[i])
                ks = jnp.where(need, r_ks, cfg_ks[i])
                cfg_k1 = cfg_k1.at[i].set(k1)
                cfg_js = cfg_js.at[i].set(js)
                cfg_ks = cfg_ks.at[i].set(ks)
                has_cfg = has_cfg.at[i].set(True)
                in_empty = in_empty.at[i].set(in_empty[i] | empty_now[i])

                # (i) one largest fitting VQ_1 job, exact reservation
                resid = CAP - srv[i].sum()
                has_vq1 = ((vqof[i] == 1) & (srv[i] > 0)).any()
                fit1 = ((ring_eff[1] > 0) & (ring_eff[1] <= resid)).any()
                do1 = k1 & ~has_vq1 & fit1
                want = want.at[i, 1].set(
                    want[i, 1] | (k1 & ~has_vq1 & (qcnt[1] == 0)))
                c = (srv, dep, vqof, ring_eff, qcnt, in_empty, srv_try,
                     trunc)
                c = jax.lax.cond(do1,
                                 functools.partial(place_from, j_iota == 1),
                                 lambda c: c, c)

                # (ii) largest-fit-first from VQ_{j*}, capped at k_{j*}
                jsx = jnp.maximum(js, 0)
                rows_j = j_iota == jsx

                def jcond(c):
                    srv, _, vqof, ring_eff, *_ = c
                    resid = CAP - srv[i].sum()
                    cnt = ((vqof[i] == jsx) & (srv[i] > 0)).sum()
                    fitj = ((ring_eff > 0) & rows_j[:, None]
                            & (ring_eff <= resid)).any()
                    return (js >= 0) & (cnt < ks) & fitj

                c = jax.lax.while_loop(
                    jcond, functools.partial(place_from, rows_j), c)
                srv, dep, vqof, ring_eff, qcnt, in_empty, srv_try, trunc = c
                cnt_end = ((vqof[i] == jsx) & (srv[i] > 0)).sum()
                subj = (js >= 0) & (cnt_end < ks) & (qcnt[jsx] == 0)
                want = want.at[i, jnp.where(subj, jsx, nvq)].set(
                    True, mode="drop")

                # (iii) BF-S sweep over all VQs
                all_rows = jnp.ones((nvq,), bool)

                def acond(c):
                    srv, _, _, ring_eff, *_ = c
                    resid = CAP - srv[i].sum()
                    return ((ring_eff > 0) & (ring_eff <= resid)).any()

                c = (srv, dep, vqof, ring_eff, qcnt, in_empty, srv_try,
                     trunc)
                c = jax.lax.while_loop(
                    acond, functools.partial(place_from, all_rows), c)
                srv, dep, vqof, ring_eff, qcnt, in_empty, srv_try, trunc = c
                return (srv, dep, vqof, ring_eff, qcnt, cfg_k1, cfg_js,
                        cfg_ks, has_cfg, in_empty, want, srv_try, trunc)

            return jax.lax.cond(visit[i], serve, lambda c: c, carry)

        carry = (srv, dep, vqof, ring_eff, qcnt, cfg_k1, cfg_js, cfg_ks,
                 has_cfg, in_empty, want, srv_try, trunc)
        carry = jax.lax.fori_loop(0, L, visit_server, carry)
        (srv, dep, vqof, ring_eff, qcnt, cfg_k1, cfg_js, cfg_ks,
         has_cfg, in_empty, want, srv_try, trunc) = carry

        # 5. arrival-side BF-J pass over jobs still queued
        (srv, dep, vqof, ring_eff, qcnt, in_empty, srv_try,
         trunc) = _arrival_bf_pass(
            srv, dep, vqof, ring_eff, ring_seq, qcnt, in_empty, srv_try,
            trunc, t, lanes, up_t, L=L, K=K, A_max=A_max, faulted=faulted)

        out = (qcnt.sum().astype(jnp.int32),
               srv.sum().astype(jnp.float32) / RES,
               n_dep.astype(jnp.int32))
        state = (srv, dep, vqof, ring_eff, ring_dur, ring_seq, qcnt,
                 seq_ctr, cfg_k1, cfg_js, cfg_ks, has_cfg, in_empty, want,
                 t + 1, dropped, trunc, ring_try, srv_try, preempted,
                 requeued, lost, up_last)
        return state, out

    state0 = _init_state(J, L, K, Qcap)
    xs = (streams.n, streams.sizes, streams.durs)
    if faulted:
        xs = xs + (streams.up,)
    state, (qlen, occ, ndep) = jax.lax.scan(slot_step, state0, xs)
    return PolicyResult(qlen, occ, jnp.cumsum(ndep), state[15], state[16],
                        state[19], state[20], state[21])


@functools.partial(
    jax.jit,
    static_argnames=("J", "L", "K", "Qcap", "A_max", "work_steps",
                     "max_requeue", "return_state"))
def run_vqs_bf_streams(streams: SchedStreams, J: int, L: int, K: int,
                       Qcap: int, A_max: int, work_steps: int | None = None,
                       max_requeue: int = DEFAULT_MAX_REQUEUE,
                       state: tuple | None = None,
                       return_state: bool = False):
    """Branch-free VQS-BF slot engine over pre-generated streams.

    One ``lax.scan`` over slots; the per-slot serve pass is a work list of
    at most ``work_steps + 1`` masked-select steps (early-exit bounded
    loop).  Each step:

      1. evaluates, for every still-pending visited server, whether it
         could place a job under its effective configuration — step (i)
         when a VQ_1 job fits and none is resident, step (ii) when a
         VQ_{j*} job fits below the k_{j*} cap, step (iii) when ANY queued
         job fits (existence tests are per-bucket minimum queued sizes
         against the server residual);
      2. advances past all pending servers below the first placer,
         applying renewals / ``_empty`` membership / subscriptions as one
         vectorized mask write (order-exact vs the numpy engine);
      3. serves the placer with ONE largest-fit pop-and-place — the pop
         target is re-staged every step from the post-placement state,
         which is exactly the numpy engine's sequential (i) -> (ii) ->
         (iii) order because each stage's predicate is monotone under
         placements (the residual only shrinks and the buckets only drain
         while a server is being served).  The placer stays current until
         nothing fits.

    Unlike VQS's head-of-queue prefix-fit, largest-fit placements cannot
    be batched (each pop depends on the residual the previous pop left),
    so a slot costs one step per placement: size ``work_steps`` to the
    burst you expect (``truncated`` counts the slots finished lazily, and
    the autotuner sweeps the bound per shape).  After the work list, the
    slot closes with the arrival-side BF-J pass shared with the oracle.

    Streams carrying a fault plane run the fault-injected variant
    (``_preempt_rings_bf`` eviction, down servers out of the visit set and
    infeasible for the BF-J pass).  ``state=`` / ``return_state=True``
    thread the complete scan carry for crash-safe chunked sweeps and
    streaming ingestion (DESIGN.md §9/§12).
    """
    nvq = 2 * J
    confs = k_red_jnp(J)
    W = resolve_work_steps(work_steps, A_max)
    l_iota = jnp.arange(L)
    j_iota = jnp.arange(nvq)
    k_iota = jnp.arange(K)
    faulted = streams.up is not None

    def slot_step(state, inp):
        (srv, dep, vqof, ring_eff, ring_dur, ring_seq, qcnt, seq_ctr,
         cfg_k1, cfg_js, cfg_ks, has_cfg, in_empty, want, t, dropped, trunc,
         ring_try, srv_try, preempted, requeued, lost, up_last) = state
        if faulted:
            n_t, sizes_t, durs_t, up_t = inp
        else:
            n_t, sizes_t, durs_t = inp
            up_t = None

        # 1. departures
        leaving = dep == t
        freed = leaving.any(axis=1)
        n_dep = leaving.sum()
        srv = jnp.where(leaving, 0, srv)
        vqof = jnp.where(leaving, -1, vqof)
        dep = jnp.where(leaving, INF_SLOT, dep)

        # 1b. capacity shocks (identical rule to the reference oracle)
        re_arrived = None
        if faulted:
            srv_try = jnp.where(leaving, 0, srv_try)
            (srv, dep, vqof, ring_eff, ring_dur, ring_seq, ring_try, qcnt,
             seq_ctr, srv_try, n_p, n_r, n_l, re_arrived) = _preempt_rings_bf(
                srv, dep, vqof, ring_eff, ring_dur, ring_seq, ring_try, qcnt,
                seq_ctr, srv_try, up_t, t, max_requeue, J=J, Qcap=Qcap)
            preempted = preempted + n_p
            requeued = requeued + n_r
            lost = lost + n_l
            freed = (freed | (up_t & ~up_last)) & up_t
            up_last = up_t
        empty_now = (srv > 0).sum(axis=1) == 0

        # 2. arrivals
        (ring_eff, ring_dur, ring_seq, qcnt, dropped, seq_ctr, arrived, rt,
         lanes) = _push_arrivals_bf(
            ring_eff, ring_dur, ring_seq, qcnt, dropped, seq_ctr,
            n_t, sizes_t, durs_t, J=J, Qcap=Qcap, A_max=A_max,
            ring_try=ring_try if faulted else None)
        if faulted:
            ring_try = rt
            arrived = arrived | re_arrived

        # 3. visit set
        woken = (want & arrived[None, :]).any(axis=1)
        want = want & ~arrived[None, :]
        visit = freed | woken | (in_empty & (qcnt.sum() > 0))
        if faulted:
            visit = visit & up_t
        renew_needed = visit & (empty_now | ~has_cfg)

        # 4. bounded work list (see docstring)
        def work(carry):
            (srv, dep, vqof, ring_eff, qcnt, cfg_k1, cfg_js, cfg_ks,
             has_cfg, in_empty, want, touched, advanced, trunc, n_steps,
             srv_try) = carry
            pending = visit & ~advanced
            occ_ring = ring_eff > 0
            hx = qcnt > 0
            row_min = jnp.min(jnp.where(occ_ring, ring_eff, _INF32),
                              axis=1)                           # (2J,)
            glob_min = jnp.min(row_min)

            # shared renewal candidate + per-server effective configuration
            r_k1, r_js, r_ks = _mw_config_bf(confs, qcnt, J)
            ren = renew_needed & ~touched
            eff_k1 = jnp.where(ren, r_k1, cfg_k1)
            eff_js = jnp.where(ren, r_js, cfg_js)
            eff_ks = jnp.where(ren, r_ks, cfg_ks)

            occ = srv.sum(axis=1)
            resid = CAP - occ
            has_vq1 = ((vqof == 1) & (srv > 0)).any(axis=1)
            js_oh = eff_js[:, None] == j_iota[None, :]          # (L, 2J)
            js_min = jnp.min(jnp.where(js_oh, row_min[None, :], _INF32),
                             axis=1)
            js_ex = (js_oh & hx[None, :]).any(axis=1)
            cnt_js = ((vqof == eff_js[:, None]) & (srv > 0)).sum(axis=1)

            k1_can = eff_k1 & ~has_vq1 & (row_min[1] <= resid)
            js_can = (eff_js >= 0) & (cnt_js < eff_ks) & (js_min <= resid)
            any_can = glob_min <= resid
            would = pending & (k1_can | js_can | any_can)

            placer = jnp.min(jnp.where(would, l_iota, L))
            tch = pending & (l_iota <= placer)
            adv = pending & (l_iota < placer)

            do_ren = tch & ren
            cfg_k1 = jnp.where(do_ren, r_k1, cfg_k1)
            cfg_js = jnp.where(do_ren, r_js, cfg_js)
            cfg_ks = jnp.where(do_ren, r_ks, cfg_ks)
            has_cfg = has_cfg | tch
            # _empty membership is granted at FIRST touch only (numpy adds
            # at visit time, before serving) — see engine/vqs.py.
            in_empty = in_empty | (tch & ~touched & empty_now)
            touched = touched | tch
            advanced = advanced | adv

            # subscriptions of the servers advanced past
            sub1 = adv & eff_k1 & ~has_vq1 & ~hx[1]
            subj = adv & (eff_js >= 0) & (cnt_js < eff_ks) & ~js_ex
            want = want | (sub1[:, None] & (j_iota[None, :] == 1)) \
                        | (subj[:, None] & js_oh)

            # serve the placer: one largest-fit pop-and-place, staged
            # (i) -> (ii) -> (iii)
            any_p = placer < L
            s = jnp.minimum(placer, L - 1)
            do1 = k1_can[s]
            doj = ~do1 & js_can[s]
            rows_ok = jnp.where(
                do1, j_iota == 1,
                jnp.where(doj, j_iota == jnp.maximum(eff_js[s], 0),
                          jnp.ones((nvq,), bool)))
            found, pvq, ppos = _pop_largest(ring_eff, ring_seq, rows_ok,
                                            resid[s])
            do_place = any_p & found
            eff_p = ring_eff[pvq, ppos]
            dur_p = ring_dur[pvq, ppos]
            kfree = jnp.min(jnp.where(srv[s] == 0, k_iota, K))
            ok = kfree < K
            kw = jnp.where(do_place & ok, jnp.minimum(kfree, K - 1), K)
            srv = srv.at[s, kw].set(eff_p, mode="drop")
            dep = dep.at[s, kw].set(t + dur_p, mode="drop")
            vqof = vqof.at[s, kw].set(pvq, mode="drop")
            if faulted:  # retry counts ride with the placed job
                srv_try = srv_try.at[s, kw].set(ring_try[pvq, ppos],
                                                mode="drop")
            cvq = jnp.where(do_place, pvq, nvq)
            ring_eff = ring_eff.at[cvq, ppos].set(0, mode="drop")
            qcnt = qcnt.at[cvq].add(-1, mode="drop")
            trunc = trunc + (do_place & ~ok).astype(jnp.int32)  # K-overflow
            in_empty = in_empty & ~((l_iota == placer) & do_place)
            return (srv, dep, vqof, ring_eff, qcnt, cfg_k1, cfg_js, cfg_ks,
                    has_cfg, in_empty, want, touched, advanced, trunc,
                    n_steps + 1, srv_try)

        def unfinished(carry):
            advanced, n_steps = carry[12], carry[14]
            return (visit & ~advanced).any() & (n_steps <= W)

        carry = (srv, dep, vqof, ring_eff, qcnt, cfg_k1, cfg_js, cfg_ks,
                 has_cfg, in_empty, want, jnp.zeros((L,), bool),
                 jnp.zeros((L,), bool), trunc, jnp.zeros((), jnp.int32),
                 srv_try)
        carry = jax.lax.while_loop(unfinished, work, carry)
        (srv, dep, vqof, ring_eff, qcnt, cfg_k1, cfg_js, cfg_ks,
         has_cfg, in_empty, want, _, advanced, trunc, _, srv_try) = carry
        # cap hit with servers still unserved: the slot finished lazily
        trunc = trunc + (visit & ~advanced).any().astype(jnp.int32)

        # 5. arrival-side BF-J pass over jobs still queued
        (srv, dep, vqof, ring_eff, qcnt, in_empty, srv_try,
         trunc) = _arrival_bf_pass(
            srv, dep, vqof, ring_eff, ring_seq, qcnt, in_empty, srv_try,
            trunc, t, lanes, up_t, L=L, K=K, A_max=A_max, faulted=faulted)

        out = (qcnt.sum().astype(jnp.int32),
               srv.sum().astype(jnp.float32) / RES,
               n_dep.astype(jnp.int32))
        state = (srv, dep, vqof, ring_eff, ring_dur, ring_seq, qcnt,
                 seq_ctr, cfg_k1, cfg_js, cfg_ks, has_cfg, in_empty, want,
                 t + 1, dropped, trunc, ring_try, srv_try, preempted,
                 requeued, lost, up_last)
        return state, out

    if state is None:
        state = _init_state(J, L, K, Qcap)
    xs = (streams.n, streams.sizes, streams.durs)
    if faulted:
        xs = xs + (streams.up,)
    state, (qlen, occ, ndep) = jax.lax.scan(slot_step, state, xs)
    res = PolicyResult(qlen, occ, jnp.cumsum(ndep), state[15], state[16],
                       state[19], state[20], state[21])
    return (res, state) if return_state else res


def run_vqs_bf_trace(streams: SchedStreams, *, J: int, L: int, K: int,
                     Qcap: int, A_max: int, engine: str = "scan",
                     work_steps: int | None = None,
                     window: int | None = None,
                     max_requeue: int = DEFAULT_MAX_REQUEUE,
                     strict: bool = False) -> PolicyResult:
    """Run one VQS-BF simulation over explicit streams (random or trace).
    ``window`` is the Pallas kernel's VMEM time-window length (must divide
    the horizon; ignored by the other engines)."""
    if engine == "reference":
        return _run_vqs_bf_reference_streams(streams, J=J, L=L, K=K,
                                             Qcap=Qcap, A_max=A_max,
                                             max_requeue=max_requeue)
    if engine == "scan":
        return run_vqs_bf_streams(streams, J=J, L=L, K=K, Qcap=Qcap,
                                  A_max=A_max, work_steps=work_steps,
                                  max_requeue=max_requeue)
    if engine == "pallas":
        from repro.kernels.common import ensemble_plane_bytes, pallas_precheck
        from repro.kernels.vqs_bf.ops import (vqs_bf_scratch_bytes,
                                              vqs_bf_simulate)
        T, D = streams.n.shape[0], streams.durs.shape[-1]
        if not pallas_precheck(
                "vqs_bf", nbytes=vqs_bf_scratch_bytes(J, L, K, Qcap),
                hbm_bytes=ensemble_plane_bytes(
                    1, T, stream_lanes=1 + A_max + D, out_lanes=3),
                fault_plane=streams.up is not None, strict=strict):
            return run_vqs_bf_streams(streams, J=J, L=L, K=K, Qcap=Qcap,
                                      A_max=A_max, work_steps=work_steps,
                                      max_requeue=max_requeue)
        batched = jax.tree.map(lambda x: x[None], streams)
        res = vqs_bf_simulate(batched, J=J, L=L, K=K, Qcap=Qcap,
                              A_max=A_max, work_steps=work_steps,
                              window=window)
        return jax.tree.map(lambda x: x[0], res)
    raise ValueError(f"unknown engine {engine!r}")


def run_vqs_bf(key: jax.Array, lam: float, mu: float,
               sampler: Callable[[jax.Array, int], jax.Array],
               J: int = 4, L: int = 8, K: int = 16, Qcap: int = 512,
               A_max: int = 8, horizon: int = 10_000, engine: str = "scan",
               work_steps: int | None = None,
               window: int | None = None,
               fault_rate: float = 0.0, repair_rate: float = 1.0,
               max_requeue: int = DEFAULT_MAX_REQUEUE,
               strict: bool = False) -> PolicyResult:
    """Simulate VQS-BF on L unit-capacity servers for ``horizon`` slots.

    Randomness is hoisted into ``make_streams`` exactly as for the other
    policies, so the streams (and any fault plane) are bitwise identical
    to a VQS run on the same key — the delay comparison in the paper's
    Section VI figures is a same-streams comparison here too.
    """
    streams = make_streams(key, lam, mu, sampler, L=L, K=K, A_max=A_max,
                           horizon=horizon, fault_rate=fault_rate,
                           repair_rate=repair_rate)
    return run_vqs_bf_trace(streams, J=J, L=L, K=K, Qcap=Qcap, A_max=A_max,
                            engine=engine, work_steps=work_steps,
                            window=window, max_requeue=max_requeue,
                            strict=strict)


def run_vqs_bf_workload(workload, key: jax.Array, *, engine: str = "scan",
                        **config) -> PolicyResult:
    """Workload-first adapter: the registry entry behind
    ``run_policy(workload, policy="vqs-bf", ...)``.  VQS-BF partitions
    scalar sizes; vector workloads are rejected loudly."""
    workload.require_scalar("vqs-bf")
    workload.check_sampler()
    return run_vqs_bf(key, workload.lam, workload.mu, workload.sampler,
                      engine=engine, **config)


def monte_carlo_vqs_bf_workload(workload, keys: jax.Array, *,
                                engine: str = "scan",
                                **config) -> PolicyResult:
    """Workload-first adapter for ``monte_carlo_policy(policy="vqs-bf")``."""
    workload.require_scalar("vqs-bf")
    workload.check_sampler()
    return monte_carlo_vqs_bf(keys, workload.lam, workload.mu,
                              workload.sampler, engine=engine, **config)


def monte_carlo_vqs_bf(keys: jax.Array, lam: float, mu: float, sampler,
                       engine: str = "scan", work_steps: int | None = None,
                       window: int | None = None, J: int = 4, L: int = 8,
                       K: int = 16, Qcap: int = 512, A_max: int = 8,
                       horizon: int = 10_000, fault_rate: float = 0.0,
                       repair_rate: float = 1.0,
                       max_requeue: int = DEFAULT_MAX_REQUEUE,
                       strict: bool = False) -> PolicyResult:
    """One simulated cluster per key (vmap; "pallas" uses the kernel grid)."""
    if engine == "pallas":
        from repro.kernels.common import ensemble_plane_bytes, pallas_precheck
        from repro.kernels.vqs_bf.ops import (vqs_bf_scratch_bytes,
                                              vqs_bf_simulate)
        # keys is the LOCAL batch under a sharded mesh launch, so the
        # footprint check is per device (core.engine.sharding).
        G = int(keys.shape[0])
        if not pallas_precheck(
                "vqs_bf", nbytes=vqs_bf_scratch_bytes(J, L, K, Qcap),
                hbm_bytes=ensemble_plane_bytes(
                    G, horizon, stream_lanes=1 + A_max + (L * K + A_max),
                    out_lanes=3),
                fault_plane=fault_rate > 0.0, strict=strict):
            engine = "scan"
        else:
            streams = jax.vmap(
                lambda k: make_streams(k, lam, mu, sampler, L=L, K=K,
                                       A_max=A_max, horizon=horizon))(keys)
            return vqs_bf_simulate(streams, J=J, L=L, K=K, Qcap=Qcap,
                                   A_max=A_max, work_steps=work_steps,
                                   window=window)
    fn = functools.partial(run_vqs_bf, lam=lam, mu=mu, sampler=sampler,
                           engine=engine, work_steps=work_steps,
                           J=J, L=L, K=K, Qcap=Qcap, A_max=A_max,
                           horizon=horizon, fault_rate=fault_rate,
                           repair_rate=repair_rate, max_requeue=max_requeue)
    return jax.vmap(fn)(keys)
