"""Shape-keyed kernel autotuner with a persistent JSON tuning cache.

The engines expose two launch knobs whose best values depend only on the
launch SHAPE, not on the realized randomness: ``work_steps`` (the bound of
the per-slot placement work list — ``micro/jax_bfjs_slot_tuned`` shows it
alone is worth ~2x) and ``window`` (the Pallas kernels' VMEM time-window
length).  This module sweeps those knobs per

    (policy, L, K, R, Qcap, A_max, engine, backend)

shape, verifies every candidate BIT-MATCHES the untuned run before it can
win (a faster-but-divergent config is rejected, never cached), and stores
winners in a persistent JSON cache so later runs pick tuned configs
automatically: ``run_policy`` / ``run_policy_streams`` /
``monte_carlo_policy`` / ``serving.estimate_capacity`` consult the cache
(:func:`apply_tuned`) whenever the caller did not pin the knob explicitly.

Cache contract (DESIGN.md §11):

  * location: ``REPRO_TUNING_CACHE`` env var > ``~/.cache/repro/
    sched_tuning.json``; the special value ``off`` disables both lookup
    and writes (the bypass the test suite runs under);
  * writes are atomic (tmp file + ``os.replace``, the same crash-safety
    rule as ``repro.checkpoint``), so a killed sweep never leaves a torn
    cache;
  * a corrupt or schema-mismatched cache file is IGNORED with a loud
    warning and overwritten by the next store — never a crash, never a
    silently-wrong config;
  * invalidation: entries are keyed by the full launch shape + backend and
    carry the module ``SCHEMA`` version; bumping ``SCHEMA`` (any PR that
    changes engine/kernel cost structure) discards every stale entry;
  * the autotuner is BYPASSED (no lookup, no sweep) for
    ``engine="reference"`` (nothing to tune) and refuses to *produce*
    entries for Pallas kernels running in interpret mode — interpret
    timings are correctness-grade, not perf-grade (pass
    ``allow_interpret=True`` to override, e.g. in tests).

A tuned ``work_steps`` is still only a bound: a different workload at the
same shape may need more steps, and then the engines' ``truncated``
counter reports the divergence loudly — the bit-match contract stays
enforced at run time, not assumed from the cache.
"""
from __future__ import annotations

import json
import os
import tempfile
import time
import warnings

import numpy as np

#: Bumping this discards every previously-cached entry (see invalidation
#: rule above) — bump whenever an engine/kernel change shifts the cost
#: model under the same shape key.
SCHEMA = "tuning.v1"

_ENV = "REPRO_TUNING_CACHE"
_DEFAULT_PATH = os.path.join("~", ".cache", "repro", "sched_tuning.json")

#: Shape-key defaults, mirroring the policy runners' signature defaults so
#: a knob the caller leaves unset keys the same shape the runner will use.
_SHAPE_DEFAULTS = {"L": 8, "K": 16, "Qcap": 512, "A_max": 8}


def cache_path() -> str | None:
    """Resolved cache file path, or None when tuning is disabled."""
    raw = os.environ.get(_ENV, "")
    if raw.lower() == "off":
        return None
    return os.path.expanduser(raw or _DEFAULT_PATH)


def tuning_enabled() -> bool:
    return cache_path() is not None


def shape_key(policy: str, engine: str, *, L: int, K: int, R: int,
              Qcap: int, A_max: int, backend: str | None = None) -> str:
    """The cache key of one launch shape (stable, human-readable)."""
    if backend is None:
        import jax
        backend = jax.default_backend()
    return (f"{policy}|{engine}|{backend}|L={L}|K={K}|R={R}|"
            f"Qcap={Qcap}|A_max={A_max}")


class TuningCache:
    """Persistent shape-key -> winner-config map (atomic JSON writes)."""

    def __init__(self, path: str | None = None):
        self.path = cache_path() if path is None else os.path.expanduser(path)

    def load(self) -> dict:
        """All valid entries; corrupt/stale files are ignored loudly."""
        if self.path is None or not os.path.exists(self.path):
            return {}
        try:
            with open(self.path) as f:
                data = json.load(f)
        except (json.JSONDecodeError, OSError, UnicodeDecodeError) as e:
            warnings.warn(
                f"ignoring corrupt tuning cache at {self.path!r} ({e}); "
                "it will be overwritten by the next store", stacklevel=2)
            return {}
        if not isinstance(data, dict) or data.get("schema") != SCHEMA:
            warnings.warn(
                f"ignoring tuning cache at {self.path!r}: schema "
                f"{data.get('schema') if isinstance(data, dict) else None!r}"
                f" != {SCHEMA!r} (stale entries are discarded, not reused)",
                stacklevel=2)
            return {}
        entries = data.get("entries")
        return entries if isinstance(entries, dict) else {}

    def get(self, key: str) -> dict | None:
        entry = self.load().get(key)
        return entry if isinstance(entry, dict) else None

    def put(self, key: str, entry: dict) -> None:
        """Read-merge-replace with an atomic tmp-then-rename write."""
        if self.path is None:
            return
        entries = self.load()
        entries[key] = entry
        d = os.path.dirname(self.path) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump({"schema": SCHEMA, "entries": entries}, f,
                          indent=1, sort_keys=True)
                f.write("\n")
            os.replace(tmp, self.path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise


def _shape_of(config: dict, num_resources: int) -> dict:
    shape = {k: int(config.get(k, d)) for k, d in _SHAPE_DEFAULTS.items()}
    shape["R"] = int(num_resources)
    return shape


def apply_tuned(policy: str, engine: str, config: dict,
                num_resources: int = 1,
                cache: TuningCache | None = None) -> dict:
    """Fill unset launch knobs from the tuning cache, in place.

    Only knobs the caller left unset (absent or None) are filled —
    an explicit ``work_steps=``/``window=`` always wins over the cache.
    Returns telemetry for bench meta strings: ``{"tuned": 0|1,
    "cache_hit": 0|1}`` (``tuned`` = at least one knob was actually
    injected; ``cache_hit`` = the shape had a cache entry at all).
    """
    meta = {"tuned": 0, "cache_hit": 0}
    if engine == "reference" or not tuning_enabled():
        return meta
    cache = cache or TuningCache()
    shape = _shape_of(config, num_resources)
    entry = cache.get(shape_key(policy, engine, **shape))
    if entry is None:
        return meta
    meta["cache_hit"] = 1
    knobs = ["work_steps"] + (["window"] if engine == "pallas" else [])
    for knob in knobs:
        if config.get(knob) is None and entry.get(knob) is not None:
            config[knob] = int(entry[knob])
            meta["tuned"] = 1
    return meta


def _bitmatch(a, b) -> bool:
    for f in a._fields:
        x, y = getattr(a, f), getattr(b, f)
        if (x is None) != (y is None):
            return False
        if x is not None and not (np.asarray(x) == np.asarray(y)).all():
            return False
    return True


def _default_grids(engine: str, A_max: int, horizon: int):
    default_ws = A_max + 4  # resolve_work_steps' default bound
    ws = sorted({1, 2, 3, 4, 6, 8, default_ws, 2 * default_ws})
    windows: list[int | None] = [None]
    if engine == "pallas":
        for div in (2, 4, 8):
            if horizon % div == 0 and horizon // div >= 8:
                windows.append(horizon // div)
    return ws, windows


def autotune(workload, keys, *, policy: str = "bfjs", engine: str = "scan",
             work_steps_grid=None, window_grid=None, rounds: int = 3,
             cache: TuningCache | None = None,
             allow_interpret: bool = False, **config) -> dict:
    """Sweep ``work_steps``/``window`` for one launch shape and cache the
    verified winner.

    Runs ``monte_carlo_policy``'s underlying engine once per candidate on
    the SAME keys, round-robin best-of-``rounds`` timed (interleaved so
    machine-load drift hits every candidate equally), and rejects any
    candidate whose trajectory is not bit-identical to the untuned
    baseline or whose ``truncated`` is nonzero.  The winner (fastest
    verified candidate, baseline included) is stored under the launch's
    :func:`shape_key` and returned:

        {"work_steps": ..., "window": ..., "us": ..., "baseline_us": ...,
         "speedup": ..., "key": ..., "candidates": N, "rejected": M}

    ``engine="reference"`` has no launch knobs and is rejected; Pallas in
    interpret mode is rejected unless ``allow_interpret=True`` (interpret
    timings do not transfer to compiled kernels — DESIGN.md §11).
    """
    from repro.kernels.common import interpret_default

    from .api import get_policy

    if engine == "reference":
        raise ValueError("engine=\"reference\" has no launch knobs to tune")
    if engine == "pallas" and interpret_default() and not allow_interpret:
        raise ValueError(
            "refusing to autotune Pallas kernels in interpret mode: "
            "interpret timings are correctness-grade and do not transfer "
            "to compiled kernels (pass allow_interpret=True to override)")
    if not tuning_enabled():
        raise ValueError(
            f"tuning cache is disabled ({_ENV}=off); autotune would "
            "sweep and then discard the winner")
    cache = cache or TuningCache()
    run = get_policy(policy).monte_carlo
    horizon = int(config.get("horizon", 10_000))
    shape = _shape_of(config, workload.num_resources)
    ws_grid, win_grid = _default_grids(engine, shape["A_max"], horizon)
    if work_steps_grid is not None:
        ws_grid = sorted({int(w) for w in work_steps_grid})
    if window_grid is not None:
        win_grid = list(window_grid)

    base_cfg = dict(config)
    base_cfg.pop("work_steps", None)
    base_cfg.pop("window", None)

    def runner(ws, win):
        kw = dict(base_cfg)
        if ws is not None:
            kw["work_steps"] = ws
        if win is not None:
            kw["window"] = win
        return run(workload, keys, engine=engine, **kw)

    baseline = runner(None, None)
    jax_block = lambda r: r.queue_len.block_until_ready()
    jax_block(baseline)

    cands = [(ws, win) for ws in ws_grid for win in win_grid]
    results, rejected = {}, 0
    for c in list(cands):
        res = runner(*c)
        jax_block(res)
        if int(np.asarray(res.truncated).sum()) != 0 \
                or not _bitmatch(res, baseline):
            cands.remove(c)
            rejected += 1
            continue
        results[c] = res
    # round-robin best-of-N over the surviving candidates + the baseline
    best = {c: float("inf") for c in cands + [("baseline", None)]}
    for _ in range(max(rounds, 1)):
        for c in best:
            t0 = time.perf_counter()
            jax_block(runner(None, None) if c[0] == "baseline"
                      else runner(*c))
            best[c] = min(best[c], time.perf_counter() - t0)
    base_us = best.pop(("baseline", None)) * 1e6
    win_c = min(best, key=best.get)
    win_us = best[win_c] * 1e6
    if win_us > base_us:  # nothing beat the default: record the default
        win_c, win_us = (None, None), base_us
    key = shape_key(policy, engine, **shape)
    entry = {**shape, "policy": policy, "engine": engine,
             "work_steps": win_c[0], "window": win_c[1],
             "us": round(win_us, 3), "baseline_us": round(base_us, 3),
             "speedup": round(base_us / win_us, 4)}
    cache.put(key, entry)
    return {**entry, "key": key, "candidates": len(cands),
            "rejected": rejected}
