"""Self-healing supervision for the streaming runtime (DESIGN.md §14).

The streaming production loop (``core.engine.streaming.stream_policy``)
runs unattended for days against real trace readers, network filesystems
and checkpoint disks — exactly the places transient faults live.  This
module is the supervision layer around it:

  * **Retry with jittered exponential backoff** (:class:`RetryPolicy`) for
    the three host-side operations that fail transiently — chunk ingestion
    (``next()`` on the source iterator, e.g. ``iter_trace_csv`` readers
    raising ``OSError``), chunk staging (``jax.device_put``), and
    checkpoint writes.  Every retry is a loud :class:`SupervisorWarning`
    and counted on ``PolicyResult.retries`` — never silent.
  * **Watchdog timeouts** (:meth:`Supervisor.watch`): per-chunk device
    compute (the ``block_until_ready`` drain of the depth-2 pipeline) and
    host staging each run under a bounded wall-clock budget; exceeding it
    raises a typed :class:`SupervisorTimeout` naming the phase and chunk.
    A timeout escalates immediately — a hung host or device is not a
    retryable condition.
  * **Checkpoint rollback**: every ``repro.checkpoint`` save records a
    SHA-256 of its arrays; on supervised resume,
    ``ckpt.latest_valid_step`` walks back over truncated/garbled
    boundaries (typed ``CheckpointCorruptError`` detection) to the newest
    checkpoint that still verifies, warns
    (:class:`CheckpointRollbackWarning`), counts the skips on
    ``PolicyResult.rollbacks`` — and the resumed run is still
    BIT-IDENTICAL to a straight-through one (the skipped chunks simply
    re-execute).
  * **Poison-chunk quarantine**: a chunk that deterministically fails
    after ``RetryPolicy.max_retries`` attempts (or fails staging with a
    non-retryable error) is written to ``quarantine_dir/chunk_<i>/`` with
    a JSON manifest (error, traceback, policy, config) and its stream
    planes when they were readable, then skipped with explicit accounting
    (``PolicyResult.quarantined`` + a :class:`SupervisorWarning`) —
    mirroring the house rule that drops are counted, never silent.
    Without a ``quarantine_dir`` there is nowhere to preserve the
    evidence, so the failure propagates instead of skipping.
  * **Runtime invariant auditor** (:func:`make_auditor`,
    :func:`audit_result`): an opt-in, jitted per-chunk check of the
    conservation laws the engines imply — see :data:`INVARIANTS` —
    raising a typed :class:`InvariantViolation` naming the chunk index
    and the failed counter.

Layering: this module depends only on jax/numpy/stdlib, so the host-side
simulators (``core/cluster_state.py``) and the serving engine can import
its typed exceptions lazily without cycles.  :class:`InvariantViolation`
subclasses ``ValueError`` on purpose — the pre-existing invariant raises
(``cluster/admission.release``, ``serving/live`` invalid-release sync,
``ClusterState.check_invariants``) keep their documented exception type
while gaining the common supervised one.
"""
from __future__ import annotations

import json
import os
import random
import shutil
import threading
import time
import traceback
import warnings
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "RetryPolicy", "Supervisor", "SupervisorError", "SupervisorTimeout",
    "SupervisorWarning", "CheckpointRollbackWarning", "InvariantViolation",
    "INVARIANTS", "make_auditor", "audit_result",
]


class SupervisorError(RuntimeError):
    """Supervision gave up: retries exhausted past quarantine limits, or
    a structurally unrecoverable stream."""


class SupervisorTimeout(SupervisorError):
    """A watchdog budget elapsed with the supervised phase still running.

    The abandoned work keeps running on its daemon thread (a hung
    ``block_until_ready`` cannot be cancelled portably); the escalation
    is the point — a serving loop must never wedge silently."""

    def __init__(self, phase: str, budget_s: float,
                 chunk_index: int | None = None):
        self.phase = phase
        self.budget_s = budget_s
        self.chunk_index = chunk_index
        at = "" if chunk_index is None else f" (chunk {chunk_index})"
        super().__init__(
            f"watchdog: {phase}{at} still running after its "
            f"{budget_s:.3g}s budget")


class InvariantViolation(ValueError):
    """A runtime conservation law failed.  Subclasses ``ValueError`` so
    call sites that historically raised/expected ``ValueError`` on
    bookkeeping corruption keep working unchanged."""

    def __init__(self, message: str, *, invariant: str | None = None,
                 chunk_index: int | None = None):
        self.invariant = invariant
        self.chunk_index = chunk_index
        super().__init__(message)


class SupervisorWarning(UserWarning):
    """Loud, non-fatal supervision events: retries and quarantines."""


class CheckpointRollbackWarning(SupervisorWarning):
    """Corrupt checkpoint boundaries were skipped on resume."""


@dataclass(frozen=True)
class RetryPolicy:
    """Jittered, capped exponential backoff for transient host failures.

    Attempt ``k`` (1-based) sleeps ``min(max_delay, base_delay *
    2**(k-1))`` scaled by a deterministic jitter drawn uniformly from
    ``[1 - jitter, 1]`` (seeded — chaos tests replay the exact schedule).
    ``retryable`` lists the exception types worth retrying at all;
    everything else escalates immediately."""

    max_retries: int = 3
    base_delay: float = 0.05
    max_delay: float = 2.0
    jitter: float = 0.5
    retryable: tuple = (OSError,)
    seed: int = 0

    def delay(self, attempt: int, rng: random.Random) -> float:
        d = min(self.max_delay, self.base_delay * 2.0 ** (attempt - 1))
        return d * (1.0 - self.jitter * rng.random())


@dataclass
class Supervisor:
    """Supervision state threaded through one ``stream_policy`` run.

    ``sleep`` is injectable so tests and soak harnesses replay backoff
    schedules without wall-clock cost.  Counters (``retries``,
    ``quarantined``, ``rollbacks``, ``timeouts``) are surfaced on the
    returned ``PolicyResult``; ``events`` keeps the full ordered log for
    forensics (:meth:`report`)."""

    retry: RetryPolicy = field(default_factory=RetryPolicy)
    compute_timeout: float | None = None   # s per pipeline drain
    stage_timeout: float | None = None     # s per ingest/stage attempt
    quarantine_dir: str | None = None
    max_consecutive_quarantines: int = 2
    sleep: Callable[[float], None] = time.sleep

    retries: int = field(default=0, init=False)
    quarantined: int = field(default=0, init=False)
    rollbacks: int = field(default=0, init=False)
    timeouts: int = field(default=0, init=False)
    events: list = field(default_factory=list, init=False)
    _consecutive: int = field(default=0, init=False)

    def __post_init__(self):
        self._rng = random.Random(self.retry.seed)

    # -- watchdog ---------------------------------------------------------
    def watch(self, phase: str, fn: Callable, timeout: float | None,
              chunk_index: int | None = None):
        """Run ``fn()`` under a wall-clock budget; raise
        :class:`SupervisorTimeout` if it is still running afterwards."""
        if timeout is None:
            return fn()
        box: list = []

        def run():
            try:
                box.append(("ok", fn()))
            except BaseException as e:  # surfaced on the caller thread
                box.append(("err", e))

        t = threading.Thread(target=run, daemon=True,
                             name=f"supervised-{phase}")
        t.start()
        t.join(timeout)
        if not box:
            self.timeouts += 1
            self.events.append(("timeout", phase, chunk_index, timeout))
            raise SupervisorTimeout(phase, timeout, chunk_index)
        tag, val = box[0]
        if tag == "err":
            raise val
        return val

    # -- retry ------------------------------------------------------------
    def call(self, kind: str, fn: Callable, *,
             chunk_index: int | None = None,
             timeout: float | None = None):
        """Run ``fn()`` with retry-on-retryable + per-attempt watchdog.

        ``StopIteration`` always propagates (an exhausted source is not a
        failure); :class:`SupervisorTimeout` escalates without retry."""
        attempt = 0
        while True:
            try:
                return self.watch(kind, fn, timeout, chunk_index)
            except self.retry.retryable as e:
                if isinstance(e, (StopIteration, SupervisorTimeout)):
                    raise
                attempt += 1
                self.events.append(
                    ("retry", kind, chunk_index, attempt, repr(e)))
                if attempt > self.retry.max_retries:
                    raise
                self.retries += 1
                delay = self.retry.delay(attempt, self._rng)
                warnings.warn(
                    f"{kind}"
                    + ("" if chunk_index is None
                       else f" (chunk {chunk_index})")
                    + f" failed with {e!r}; retry "
                      f"{attempt}/{self.retry.max_retries} after "
                      f"{delay * 1e3:.1f}ms backoff",
                    SupervisorWarning, stacklevel=3)
                self.sleep(delay)

    # -- quarantine -------------------------------------------------------
    def quarantine(self, src_index: int, error: BaseException, *,
                   streams_chunk=None, policy: str | None = None,
                   config: dict | None = None) -> str:
        """Record chunk ``src_index`` as poison and authorize skipping it.

        Writes ``quarantine_dir/chunk_<i>/manifest.json`` (+ ``chunk.npz``
        stream planes when the chunk was readable) via tmp-then-rename,
        counts the skip, and warns.  Raises :class:`SupervisorError` when
        no ``quarantine_dir`` is configured (nowhere to preserve the
        evidence — skipping would be silent data loss) or when more than
        ``max_consecutive_quarantines`` chunks fail back-to-back (that is
        a broken source, not isolated poison)."""
        if self.quarantine_dir is None:
            raise SupervisorError(
                f"chunk {src_index} is poison ({error!r}) and no "
                "quarantine_dir= is configured; refusing to skip data "
                "without preserving it") from error
        final = os.path.join(self.quarantine_dir,
                             f"chunk_{src_index:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp, exist_ok=True)
        has_planes = streams_chunk is not None
        if has_planes:
            arrays = {name: np.asarray(v) for name, v
                      in zip(streams_chunk._fields, tuple(streams_chunk))
                      if v is not None}
            np.savez(os.path.join(tmp, "chunk.npz"), **arrays)
        manifest = {
            "chunk_index": int(src_index),
            "error_type": type(error).__name__,
            "error": str(error),
            "traceback": "".join(traceback.format_exception(
                type(error), error, error.__traceback__)),
            "policy": policy,
            "config": {k: repr(v) for k, v in sorted((config or {})
                                                     .items())},
            "has_planes": has_planes,
            "time": time.time(),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self.quarantined += 1
        self._consecutive += 1
        self.events.append(("quarantine", src_index, repr(error)))
        warnings.warn(
            f"quarantined poison chunk {src_index} to {final} "
            f"({type(error).__name__}: {error}); the stream continues "
            "WITHOUT it (counted on PolicyResult.quarantined)",
            SupervisorWarning, stacklevel=3)
        if self._consecutive > self.max_consecutive_quarantines:
            raise SupervisorError(
                f"{self._consecutive} consecutive chunks quarantined "
                f"(limit {self.max_consecutive_quarantines}) — the source "
                "is broken, not poisoned; aborting instead of skipping "
                "the rest of the stream") from error
        return final

    def mark_chunk_ok(self) -> None:
        self._consecutive = 0

    # -- rollback ---------------------------------------------------------
    def note_rollback(self, corrupt_steps: list[int],
                      checkpoint_dir: str) -> None:
        if not corrupt_steps:
            return
        self.rollbacks += len(corrupt_steps)
        self.events.append(("rollback", tuple(corrupt_steps),
                            checkpoint_dir))
        warnings.warn(
            f"rolled back over {len(corrupt_steps)} corrupt checkpoint "
            f"step(s) {sorted(corrupt_steps)} in {checkpoint_dir}; "
            "resuming from the last good boundary (the skipped chunks "
            "re-execute bit-identically)",
            CheckpointRollbackWarning, stacklevel=3)

    def report(self) -> dict:
        """Accounting snapshot — what the soak harness prints."""
        return {
            "retries": self.retries,
            "quarantined": self.quarantined,
            "rollbacks": self.rollbacks,
            "timeouts": self.timeouts,
            "events": list(self.events),
        }


# -- runtime invariant auditor -------------------------------------------

#: (key, statement) per audited conservation law, in margin order.  The
#: in-flight count is derived — ``arrivals - served - queued - dropped -
#: lost`` — so the two bounds together ARE the paper's job-conservation
#: law ``arrivals == served + queued + dropped + lost + in-flight`` with
#: in-flight confined to the physical ``(L, K)`` server planes every
#: engine carries.
INVARIANTS = (
    ("in_flight_nonneg",
     "arrivals - served - queued - dropped - lost >= 0 (job conservation)"),
    ("in_flight_bound",
     "in-flight jobs <= L*K server slots (job conservation)"),
    ("occupancy_capacity",
     "occupancy <= L*capacity per resource"),
    ("preempted_split",
     "preempted == requeued + lost (fault accounting)"),
    ("queue_nonneg", "queue_len >= 0"),
    ("departed_monotone", "cumulative departures nondecreasing"),
)

#: f32 slack for the capacity margin: occupancy sums are exact on the
#: quantize.RES grid, but the margin subtraction itself is f32.
_AUDIT_EPS = 1e-3


def _check_margins(margins, *, policy: str, chunk_index: int | None,
                   what: str) -> None:
    m = np.asarray(margins, dtype=np.float64)
    bad = np.where(m < -_AUDIT_EPS)[0]
    if bad.size:
        k = int(bad[np.argmin(m[bad])])
        key, law = INVARIANTS[k]
        where = what if chunk_index is None \
            else f"{what} chunk {chunk_index}"
        raise InvariantViolation(
            f"policy {policy!r} violated runtime invariant "
            f"`{key}` ({law}) on {where}: margin {m[k]:.6g} "
            f"(all margins {np.round(m, 4).tolist()}; order "
            f"{[key for key, _ in INVARIANTS]})",
            invariant=key, chunk_index=chunk_index)


def make_auditor(*, policy: str, config: dict, num_resources: int,
                 what: str = "stream"):
    """Build the jitted per-chunk invariant checker.

    Returns ``audit(arr_cum, res, dep_base, chunk_index)`` where
    ``arr_cum`` is the cumulative arrival count through this chunk (per
    ensemble member when batched), ``res`` the chunk's ``PolicyResult``
    (chunk-local planes, whole-run scalar counters — the carry
    accumulates them), and ``dep_base`` the cumulative departures before
    this chunk.  Raises :class:`InvariantViolation` naming the chunk and
    counter.  The margin computation is one fused jitted call; checking
    forces a host sync per chunk, which is why the knob is opt-in."""
    try:
        L, K = int(config["L"]), int(config["K"])
    except KeyError as e:
        raise ValueError(
            "audit needs explicit L= and K= in the run config — the "
            "conservation bounds are physical (L*K server slots, "
            "L*capacity occupancy) and cannot be inferred from engine "
            "defaults") from e
    cap = config.get("capacity", 1.0)
    if not isinstance(cap, (tuple, list)):
        cap = (float(cap),) * num_resources
    cap_total = jnp.asarray(np.asarray(cap, dtype=np.float32) * L)
    max_in_flight = float(L * K)

    @jax.jit
    def margins(arr_cum, queue_plane, occ_plane, dep_plane, dep_base,
                dropped, lost, preempted, requeued):
        f32 = lambda x: jnp.asarray(x).astype(jnp.float32)
        q_last = f32(queue_plane[..., -1])
        dep_last = f32(dep_base) + f32(dep_plane[..., -1])
        in_flight = f32(arr_cum) - dep_last - q_last - f32(dropped) \
            - f32(lost)
        # occupancy: (T,), (G,T), (T,R) or (G,T,R) — the time axis is the
        # queue plane's last axis
        occ = f32(occ_plane)
        t_ax = queue_plane.ndim - 1
        occ_margin = jnp.min(cap_total - jnp.max(occ, axis=t_ax))
        dep_steps = jnp.diff(f32(dep_plane), axis=-1)
        return jnp.stack([
            jnp.min(in_flight),
            max_in_flight - jnp.max(in_flight),
            occ_margin,
            -jnp.max(jnp.abs(f32(preempted) - f32(requeued) - f32(lost))),
            jnp.min(f32(queue_plane)),
            jnp.min(dep_steps) if dep_plane.shape[-1] > 1
            else jnp.asarray(0.0, jnp.float32),
        ])

    def audit(arr_cum, res, dep_base, chunk_index=None):
        zero = jnp.zeros_like(jnp.asarray(res.dropped))
        m = margins(arr_cum, res.queue_len, res.occupancy, res.departed,
                    dep_base, res.dropped,
                    zero if res.lost is None else res.lost,
                    zero if res.preempted is None else res.preempted,
                    zero if res.requeued is None else res.requeued)
        _check_margins(m, policy=policy, chunk_index=chunk_index,
                       what=what)

    return audit


def audit_result(streams, res, *, policy: str, config: dict) -> None:
    """Post-hoc invariant audit of a ONE-SHOT run (benches, CI gates):
    the whole horizon is treated as a single chunk.  ``config`` needs the
    ``L``/``K`` (and ``capacity``) the run used.  Raises
    :class:`InvariantViolation`; returns None when every margin holds.

    Not for ``trajectory="tail"`` streaming results — their planes cover
    only the newest chunk while ``streams`` covers the full horizon."""
    n_res = res.occupancy.ndim - res.queue_len.ndim + 1
    cfg = dict(config)
    if cfg.get("capacity") is not None \
            and not isinstance(cfg["capacity"], (tuple, list)):
        cfg["capacity"] = (float(cfg["capacity"]),) * n_res
    audit = make_auditor(policy=policy, config=cfg, num_resources=n_res,
                         what="one-shot run")
    # a partial result (stop_after_chunks) covers fewer slots than the
    # streams — count arrivals only over the horizon the result covers
    T_res = int(res.queue_len.shape[-1])
    arr_cum = jnp.asarray(streams.n)[..., :T_res].sum(axis=-1)
    audit(arr_cum, res, jnp.zeros((), jnp.int32), None)
