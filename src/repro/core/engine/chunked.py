"""Crash-safe chunked policy sweeps over the scan engines.

Long Monte-Carlo horizons run as a sequence of T-chunks: each chunk is one
``lax.scan`` over ``chunk`` slots whose COMPLETE carry (server planes,
queue planes, retry/seq planes, counters, ``up_last``) is persisted with
:mod:`repro.checkpoint.ckpt` at every chunk boundary — atomic
tmp-then-rename directories, so a SIGKILL at ANY point leaves either the
previous or the next complete checkpoint on disk, never a torn one.
``resume=True`` restores the newest boundary and continues; because the
scan carry is the engine's entire state (fault recovery detection included
— ``up_last`` lives in the carry, not in a shifted stream plane), an
interrupted-and-resumed sweep is BIT-IDENTICAL to a straight-through run.

The driver refuses engines other than ``"scan"`` upstream
(``api.run_policy_streams``): the reference oracles keep host-side state
that cannot be checkpointed, and the Pallas kernels keep theirs in VMEM
scratch.  Checkpoints are validated on resume — policy, horizon, chunk
length, engine config and a SHA-256 fingerprint of the streams must all
match, so a checkpoint can never silently continue a different sweep.

Per-chunk ``departed`` restarts at zero (it is an output, not carry); the
driver re-offsets each chunk by the previous cumulative total.  The scalar
deviation/fault counters (``dropped``, ``truncated``, ``preempted``,
``requeued``, ``lost``) accumulate inside the carry, so the final chunk's
values are already whole-horizon totals.

Monte-Carlo sweeps chunk too: ensemble-batched streams (a leading G axis
on every plane, ``sharding.ensemble_streams``) run the per-chunk scan
VMAPPED over the ensemble — and, with ``mesh=``, shard_mapped so each
device owns its G/D members (``core.engine.sharding``).  The per-chunk
carry keeps the full ``(G, ...)`` shape in the checkpoint (carries are
donated on-device but persisted host-side), and the manifest never pins a
device count: a sweep checkpointed on D devices resumes bit-exactly on D'
for any D' dividing G (DESIGN.md §11).
"""
from __future__ import annotations

import hashlib
import os
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt

from .streams import PolicyResult, SchedStreams


def _bfjs_stateful(streams, state, config):
    from .bfjs import run_bfjs_streams
    return run_bfjs_streams(streams, state=state, return_state=True,
                            **config)


def _vqs_stateful(streams, state, config):
    from .vqs import run_vqs_streams
    return run_vqs_streams(streams, state=state, return_state=True,
                           **config)


def _bfjs_mr_stateful(streams, state, config):
    from .bfjs_mr import run_bfjs_mr_streams
    return run_bfjs_mr_streams(streams, state=state, return_state=True,
                               **config)


def _vqs_bf_stateful(streams, state, config):
    from .vqs_bf import run_vqs_bf_streams
    return run_vqs_bf_streams(streams, state=state, return_state=True,
                              **config)


_STATEFUL: dict[str, Callable] = {
    "bfjs": _bfjs_stateful,
    "vqs": _vqs_stateful,
    "bfjs-mr": _bfjs_mr_stateful,
    "vqs-bf": _vqs_bf_stateful,
}


def streams_fingerprint(streams: SchedStreams) -> str:
    """SHA-256 over every stream plane (dtype, shape and bytes) — the
    resume guard that a checkpoint only ever continues its own sweep."""
    h = hashlib.sha256()
    for name, arr in zip(streams._fields, tuple(streams)):
        if arr is None:
            h.update(f"{name}:none;".encode())
        else:
            a = np.asarray(arr)
            h.update(f"{name}:{a.dtype}:{a.shape};".encode())
            h.update(a.tobytes())
    return h.hexdigest()


def _slice_streams(streams: SchedStreams, lo: int, hi: int,
                   ensemble: bool = False) -> SchedStreams:
    sl = (slice(None), slice(lo, hi)) if ensemble else slice(lo, hi)
    return streams._replace(
        n=streams.n[sl], sizes=streams.sizes[sl], durs=streams.durs[sl],
        up=None if streams.up is None else streams.up[sl])


def _append(partial: PolicyResult | None, res: PolicyResult,
            axis: int = 0) -> PolicyResult:
    if partial is None:
        return res
    dep_off = partial.departed[..., -1:] if axis else partial.departed[-1]
    return PolicyResult(
        jnp.concatenate([partial.queue_len, res.queue_len], axis=axis),
        jnp.concatenate([partial.occupancy, res.occupancy], axis=axis),
        jnp.concatenate([partial.departed, res.departed + dep_off],
                        axis=axis),
        res.dropped, res.truncated, res.preempted, res.requeued, res.lost)


def _save_step(checkpoint_dir: str, step: int, payload: Any,
               extra: dict) -> None:
    """One chunk-boundary save (factored out so crash tests can intercept
    the exact boundary)."""
    ckpt.save(checkpoint_dir, step, payload, extra=extra)


def _load_step(checkpoint_dir: str, step: int
               ) -> tuple[tuple, PolicyResult]:
    """Rebuild (scan state, partial result) from a boundary checkpoint.

    The engine state is an anonymous tuple whose structure is
    policy-/config-dependent, so restore by npz key layout rather than a
    ``like`` pytree: ``state/<i>`` leaves in index order and
    ``partial/<field>`` leaves by ``PolicyResult`` field name.

    Reads go through ``ckpt.load_arrays`` — checksum-verified, so a
    truncated or garbled file raises a typed
    :class:`~repro.checkpoint.ckpt.CheckpointCorruptError` naming the
    path (never a raw pickle/zip/numpy error); supervised streaming
    catches exactly that type to roll back to the last good boundary.
    """
    path = os.path.join(checkpoint_dir, f"step_{step:08d}", "arrays.npz")
    data = ckpt.load_arrays(checkpoint_dir, step)
    idxs = sorted(int(k.split("/", 1)[1]) for k in data
                  if k.startswith("state/"))
    if idxs != list(range(len(idxs))) or not idxs:
        raise ckpt.CheckpointCorruptError(
            path, f"state indices {idxs} are not a dense 0..N range")
    state = tuple(jnp.asarray(data[f"state/{i}"]) for i in idxs)
    # Optional fields (fault counters on unfaulted runs, the streaming
    # backpressure/supervision counters always) are None leaves — dropped
    # by tree_flatten at save time, so absent from the npz.
    partial = PolicyResult(*(
        jnp.asarray(data[f"partial/{f}"])
        if f"partial/{f}" in data else None
        for f in PolicyResult._fields))
    return state, partial


def run_chunked(streams: SchedStreams, *, policy: str = "bfjs",
                chunk: int, checkpoint_dir: str | None = None,
                resume: bool = False,
                stop_after_chunks: int | None = None,
                mesh=None, **config) -> PolicyResult:
    """Run a scan-engine sweep in crash-safe chunks (see module docstring).

    ``stop_after_chunks`` ends the run early after that many chunks have
    been EXECUTED this call (checkpoints included) — the hook crash tests
    use to stop at an arbitrary boundary; the partial result is returned.

    Streams with a leading ensemble axis (``n.ndim == 2``) run the
    per-chunk scan vmapped over the ensemble; ``mesh=`` additionally
    shards that axis over devices (``core.engine.sharding``).
    """
    if policy not in _STATEFUL:
        raise ValueError(
            f"policy {policy!r} has no stateful scan engine; chunked "
            f"sweeps support: {', '.join(sorted(_STATEFUL))}")
    if chunk <= 0:
        raise ValueError(f"chunk must be positive, got {chunk}")
    if resume and checkpoint_dir is None:
        raise ValueError("resume=True needs checkpoint_dir=")
    ensemble = streams.n.ndim == 2
    if mesh is not None and not ensemble:
        raise ValueError("mesh= needs ensemble-batched streams (a leading "
                         "G axis on every plane); single-run streams have "
                         "nothing to shard")
    if policy == "bfjs-mr":
        from .bfjs_mr import _lift_sizes, _norm_capacity
        streams = _lift_sizes(streams)
        cap = config.get("capacity", 1.0)
        if not isinstance(cap, tuple):
            config["capacity"] = _norm_capacity(
                cap, int(streams.sizes.shape[-1]))
    config.setdefault("A_max", int(streams.sizes.shape[streams.n.ndim]))
    T = int(streams.n.shape[-1])
    bounds = [(lo, min(lo + chunk, T)) for lo in range(0, T, chunk)]
    meta = {
        "policy": policy,
        "horizon": T,
        "chunk": int(chunk),
        "n_chunks": len(bounds),
        "faulted": streams.up is not None,
        "streams_sha256": streams_fingerprint(streams),
        "config": {k: repr(v) for k, v in sorted(config.items())},
    }

    start = 0
    state: tuple | None = None
    partial: PolicyResult | None = None
    if resume:
        latest = ckpt.latest_step(checkpoint_dir)
        if latest is not None:
            extra = ckpt.read_manifest(checkpoint_dir, latest)["extra"]
            stale = {k: (extra.get(k), v) for k, v in meta.items()
                     if extra.get(k) != v}
            if stale:
                raise ValueError(
                    f"checkpoint at {checkpoint_dir!r} belongs to a "
                    f"different sweep; mismatched (found, expected): "
                    f"{stale}")
            if latest > len(bounds):
                raise ValueError(
                    f"checkpoint step {latest} exceeds the sweep's "
                    f"{len(bounds)} chunks")
            state, partial = _load_step(checkpoint_dir, latest)
            start = latest

    runner = _STATEFUL[policy]
    if ensemble:
        base = runner

        def _first(s):
            return jax.vmap(lambda x: base(x, None, config))(s)

        def _next(s, st):
            return jax.vmap(lambda x, y: base(x, y, config))(s, st)

        if mesh is not None:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            from .sharding import _check_divides
            _check_divides(int(streams.n.shape[0]), mesh)
            spec = P(mesh.axis_names[0])
            out = (spec, spec)
            _first = shard_map(_first, mesh=mesh, in_specs=(spec,),
                               out_specs=out, check_rep=False)
            _next = shard_map(_next, mesh=mesh, in_specs=(spec, spec),
                              out_specs=out, check_rep=False)
        # jit once per run so every chunk reuses the compilation; the
        # previous chunk's carry is donated — its buffers back the next
        # chunk's state in place.
        _first = jax.jit(_first)
        _next = jax.jit(_next, donate_argnums=(1,))

        def runner(streams_chunk, st, _cfg):
            if st is None:
                return _first(streams_chunk)
            return _next(streams_chunk, st)

    executed = 0
    for i in range(start, len(bounds)):
        if stop_after_chunks is not None and executed >= stop_after_chunks:
            break
        lo, hi = bounds[i]
        res, state = runner(_slice_streams(streams, lo, hi, ensemble),
                            state, config)
        partial = _append(partial, res, axis=1 if ensemble else 0)
        executed += 1
        if checkpoint_dir is not None:
            _save_step(checkpoint_dir, i + 1,
                       {"state": state, "partial": partial}, meta)
    if partial is None:
        raise ValueError("nothing to run: empty horizon or "
                         "stop_after_chunks=0 with no checkpoint")
    return partial
