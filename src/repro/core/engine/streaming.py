"""Streaming driver: unbounded arrival iterators through the scan engines.

Every other entry point replays a fixed-``T`` pre-materialized stream; the
paper's setting (Psychas–Ghaderi 2019, Section III) is an *unbounded*
arrival process served online.  :func:`stream_policy` iterates chunks of
any — possibly infinite — ``SchedStreams`` iterator through the stateful
scan engines, threading the complete carried queue/occupancy/fault state
between chunks exactly as ``core.engine.chunked`` does, so

    **streaming replay of any finite trace is BIT-IDENTICAL to the
    one-shot ``run_policy_streams`` run, under any chunking** —

the invariant ``tests/test_streaming.py`` enforces per policy x engine x
chunk size.  What streaming adds over ``run_chunked`` is the *pipeline*:

  * **Double-buffered ingestion.**  JAX dispatch is asynchronous, so while
    the device computes chunk N the host pulls chunk N+1 from the iterator
    and stages it with ``jax.device_put``.  At most two chunks are ever in
    flight (the host blocks on chunk N-1 before dispatching N+1), which
    bounds host memory for infinite iterators to O(2 chunks), not O(T).
  * **Backpressure counters.**  The returned :class:`PolicyResult` carries
    ``chunks_behind`` — chunks whose device compute finished before the
    host had the NEXT chunk staged (ingestion is the bottleneck; feed the
    device bigger chunks or a faster reader) — and ``host_stall_us`` — the
    total host time spent blocked waiting on device compute (the device is
    the bottleneck; the healthy state for a serving loop).  Both measure
    host/device overlap only: they are excluded from bit-match
    comparisons, and the trajectory never depends on timing.
  * **Bounded-memory trajectories.**  ``trajectory="full"`` concatenates
    per-chunk planes (the default; what the parity tests compare).
    ``trajectory="tail"`` keeps only the newest chunk's planes — with the
    cumulative ``departed`` offset folded in and the scalar counters
    already whole-run totals (they accumulate in the carry) — so an
    unbounded run holds O(chunk), not O(elapsed horizon).

Engines: ``"scan"`` is the native streaming engine (its carry is the
entire simulation state).  ``"pallas"`` routes through
``kernels.common.pallas_precheck(streaming_carry=True)`` — the fused
kernels keep state in VMEM scratch for one launch and cannot thread it
across chunks, so the request degrades loudly (GracefulDegradationWarning)
to the bit-identical scan engine, or raises under ``strict=True``.
``"reference"`` keeps host-side state and is rejected.

``checkpoint_dir=`` persists the carry at every chunk boundary (same
atomic tmp-then-rename contract as chunked sweeps); ``resume=True``
re-iterates the source, skips the chunks already executed — verifying the
first chunk's fingerprint so a checkpoint never continues a different
stream — and continues bit-exactly.
"""
from __future__ import annotations

import time
import warnings
from collections import deque
from typing import Iterable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt

from .chunked import (_STATEFUL, _append, _load_step, _save_step,
                      _slice_streams, streams_fingerprint)
from .streams import PolicyResult, SchedStreams

#: jitted ensemble (vmapped) runner pairs keyed by (policy, config items)
#: — reused across stream_policy calls so repeated streaming runs of the
#: same study (sweeps, benches, tests) compile once, like the module-level
#: jits of the underlying engines.  jax.jit then re-specializes per chunk
#: shape as usual.
_ENSEMBLE_RUNNERS: dict = {}


def _ensemble_runners(policy: str, config: dict):
    try:
        key = (policy, tuple(sorted(config.items())))
        cached = _ENSEMBLE_RUNNERS.get(key)
    except TypeError:        # unhashable config value: skip the cache
        key, cached = None, None
    if cached is not None:
        return cached
    base, cfg = _STATEFUL[policy], dict(config)
    first_fn = jax.jit(
        lambda s: jax.vmap(lambda x: base(x, None, cfg))(s))
    next_fn = jax.jit(
        lambda s, st: jax.vmap(lambda x, y: base(x, y, cfg))(s, st),
        donate_argnums=(1,))
    if key is not None:
        _ENSEMBLE_RUNNERS[key] = (first_fn, next_fn)
    return first_fn, next_fn


def iter_stream_chunks(streams: SchedStreams, chunk: int
                       ) -> Iterator[SchedStreams]:
    """Slice a materialized ``SchedStreams`` into contiguous time chunks —
    the trivial chunk source (tests, benches, replaying an in-memory
    sweep through :func:`stream_policy`).  Ensemble-batched streams
    (leading G axis) slice along their time axis."""
    if chunk <= 0:
        raise ValueError(f"chunk must be positive, got {chunk}")
    ensemble = streams.n.ndim == 2
    T = int(streams.n.shape[-1])
    for lo in range(0, T, chunk):
        yield _slice_streams(streams, lo, min(lo + chunk, T), ensemble)


def stream_chunks_from_trace(traces: Iterable, *, chunk_slots: int,
                             A_max: int, collapse: bool = True,
                             num_resources: int | None = None
                             ) -> Iterator[SchedStreams]:
    """Re-bucket an iterator of :class:`~repro.core.trace.Trace` chunks
    (e.g. ``core.trace.iter_trace_csv`` output, chunked by ROW COUNT) into
    fixed ``chunk_slots``-slot ``SchedStreams`` windows for
    :func:`stream_policy`.

    The two chunkings disagree by construction — a CSV reader cuts on
    rows, the engines need contiguous time windows — so arrivals are
    buffered until a window's end has provably passed (arrival slots are
    non-decreasing across reader chunks; the reader validates that) and
    emitted window by window, INCLUDING all-empty windows for slot gaps
    longer than a window: time must advance for in-service durations to
    tick.  Only the not-yet-emitted rows are ever held — constant memory.

    ``A_max`` is mandatory: a streaming source cannot know the global
    per-slot arrival peak in advance, and the engines' carry must keep one
    shape across chunks.  A window whose peak exceeds it raises (streams
    never drop trace jobs silently).  The final window is trimmed to the
    last arrival's slot, so the concatenated horizon equals the one-shot
    ``streams_from_trace`` horizon and trajectories bit-match.

    ``collapse=True`` applies the paper's max(cpu, mem) preprocessing;
    ``collapse=False`` keeps (cpu, mem) requirement vectors
    (``policy="bfjs-mr"``).  ``num_resources`` pins the expected R exactly
    as ``streams_from_trace`` does.
    """
    from .streams import streams_from_trace

    if chunk_slots <= 0:
        raise ValueError(f"chunk_slots must be positive, got {chunk_slots}")
    R = 1 if collapse else 2
    if num_resources is not None and num_resources != R:
        raise ValueError(
            f"collapse={collapse} yields R={R} resource plane(s) but "
            f"num_resources={num_resources} was requested")
    empty_sizes = np.empty((0,) if collapse else (0, R), dtype=np.float64)
    buf_slots = np.empty((0,), dtype=np.int64)
    buf_sizes = empty_sizes
    buf_durs = np.empty((0,), dtype=np.int64)
    win_lo = 0           # first slot of the next window to emit
    last_slot = -1       # newest slot seen (slots are non-decreasing)

    def emit(hi_slots: int) -> SchedStreams:
        """Emit the window [win_lo, win_lo + hi_slots) from the buffer."""
        nonlocal buf_slots, buf_sizes, buf_durs, win_lo
        take = buf_slots < win_lo + hi_slots
        win = streams_from_trace(
            buf_slots[take] - win_lo, buf_sizes[take], buf_durs[take],
            horizon=hi_slots, A_max=A_max, num_resources=num_resources)
        buf_slots = buf_slots[~take]
        buf_sizes = buf_sizes[~take]
        buf_durs = buf_durs[~take]
        win_lo += hi_slots
        return win

    for tr in traces:
        slots = np.asarray(tr.arrival_slots, dtype=np.int64)
        if len(slots) == 0:
            continue
        if slots[0] < last_slot:
            raise ValueError(
                f"trace chunks went backwards in time: slot {slots[0]} "
                f"after {last_slot} (the reader guarantees monotone "
                "arrivals — did chunks arrive out of order?)")
        sizes = (np.maximum(tr.cpu, tr.mem) if collapse
                 else np.stack([tr.cpu, tr.mem], axis=1))
        buf_slots = np.concatenate([buf_slots, slots])
        buf_sizes = np.concatenate([buf_sizes, sizes])
        buf_durs = np.concatenate([buf_durs,
                                   np.asarray(tr.durations, np.int64)])
        last_slot = int(slots[-1])
        # every window whose end has provably passed is complete
        while last_slot >= win_lo + chunk_slots:
            yield emit(chunk_slots)
    if len(buf_slots):
        # final window: trim to the last arrival so the concatenated
        # horizon equals the one-shot streams_from_trace horizon
        yield emit(last_slot - win_lo + 1)


def _chunk_shape(streams: SchedStreams) -> tuple:
    """(ensemble?, G, A_max lanes, R) — the shape a stream's chunks must
    keep constant (the engine carry is built once, from the first)."""
    ensemble = streams.n.ndim == 2
    G = int(streams.n.shape[0]) if ensemble else 0
    R = streams.num_resources
    return (ensemble, G, int(streams.sizes.shape[streams.n.ndim]), R)


def _is_ready(arr) -> bool:
    """True when a dispatched array's computation has completed (False =
    still in flight).  Falls back to True — counting a chunk as
    device-idle — on runtimes without ``is_ready`` introspection."""
    try:
        return bool(arr.is_ready())
    except AttributeError:
        return True


def stream_policy(chunks: Iterable, *, policy: str = "bfjs",
                  engine: str = "scan",
                  checkpoint_dir: str | None = None,
                  resume: bool = False,
                  stop_after_chunks: int | None = None,
                  trajectory: str = "full",
                  strict: bool = False,
                  **config) -> PolicyResult:
    """Run a (possibly infinite) iterator of ``SchedStreams`` chunks
    through a stateful scan engine with carried state — see the module
    docstring for the pipeline, invariants and backpressure semantics.

    ``chunks`` yields contiguous time windows (``iter_stream_chunks``,
    ``stream_chunks_from_trace``, or any generator — windows may have
    different lengths, but must keep one arrival-lane width and, for
    ensembles, one G).  ``stop_after_chunks`` bounds how many chunks THIS
    call executes (the unbounded-generator escape hatch; the partial
    result is returned and, with ``checkpoint_dir=``, resumable).
    ``trajectory="tail"`` keeps only the newest chunk's per-slot planes
    (bounded memory; scalar counters stay whole-run exact).

    Bit-match contract: for any finite chunking of streams ``S``,
    ``stream_policy(iter_stream_chunks(S, c), policy=p)`` equals
    ``run_policy_streams(S, policy=p)`` bit-for-bit on every trajectory
    field, for every chunk size ``c``.
    """
    if policy not in _STATEFUL:
        raise ValueError(
            f"policy {policy!r} has no stateful scan engine; streaming "
            f"supports: {', '.join(sorted(_STATEFUL))}")
    if trajectory not in ("full", "tail"):
        raise ValueError(f"trajectory must be 'full' or 'tail', "
                         f"got {trajectory!r}")
    if engine == "reference":
        raise ValueError(
            'engine="reference" keeps host-side state and cannot stream; '
            'use engine="scan"')
    if engine == "pallas":
        from repro.kernels.common import pallas_precheck
        # never True: the fused kernels' state lives in VMEM scratch for
        # one launch only — raises under strict, else warns + scan
        pallas_precheck(f"{policy} stream", nbytes=0, streaming_carry=True,
                        strict=strict)
        engine = "scan"
    elif engine != "scan":
        raise ValueError(f"unknown engine {engine!r}; streaming supports "
                         '"scan" (and "pallas" via loud fallback)')
    if resume and checkpoint_dir is None:
        raise ValueError("resume=True needs checkpoint_dir=")

    it = iter(chunks)
    try:
        first = next(it)
    except StopIteration:
        raise ValueError("stream_policy: the chunk iterator is empty") \
            from None

    ensemble, G, lanes, n_res = _chunk_shape(first)
    if policy == "bfjs-mr":
        from .bfjs_mr import _norm_capacity
        cap = config.get("capacity", 1.0)
        if not isinstance(cap, tuple):
            config["capacity"] = _norm_capacity(cap, max(n_res, 1))
    config.setdefault("A_max", lanes)
    from .tuning import apply_tuned
    apply_tuned(policy, "scan", config, max(n_res, 1))
    config.pop("strict", None)
    config.pop("window", None)

    meta = {
        "policy": policy,
        "trajectory": trajectory,
        "ensemble": ensemble,
        "faulted": first.up is not None,
        "first_chunk_sha256": None,  # filled below (after lifting)
        "config": {k: repr(v) for k, v in sorted(config.items())},
    }

    def prepare(streams_chunk: SchedStreams, index: int) -> SchedStreams:
        """Host-side chunk staging: validate shape, lift bfjs-mr planes,
        push to the device.  This is the work double-buffered against the
        previous chunk's device compute."""
        shape = _chunk_shape(streams_chunk)
        if shape != (ensemble, G, lanes, n_res):
            raise ValueError(
                f"chunk {index} changed shape mid-stream: (ensemble, G, "
                f"A_max, R) {shape} != first chunk's "
                f"{(ensemble, G, lanes, n_res)} — the engine carry keeps "
                "one shape for the life of the stream")
        if policy == "bfjs-mr":
            from .bfjs_mr import _lift_sizes
            streams_chunk = _lift_sizes(streams_chunk)
        return jax.device_put(streams_chunk)

    base = _STATEFUL[policy]
    if ensemble:
        _first_fn, _next_fn = _ensemble_runners(policy, config)

        def runner(streams_chunk, st):
            return _first_fn(streams_chunk) if st is None \
                else _next_fn(streams_chunk, st)
    else:
        def runner(streams_chunk, st):
            return base(streams_chunk, st, config)

    staged = prepare(first, 0)
    meta["first_chunk_sha256"] = streams_fingerprint(staged)

    start = 0
    state: tuple | None = None
    partial: PolicyResult | None = None
    if resume:
        latest = ckpt.latest_step(checkpoint_dir)
        if latest is not None:
            extra = ckpt.read_manifest(checkpoint_dir, latest)["extra"]
            stale = {k: (extra.get(k), v) for k, v in meta.items()
                     if extra.get(k) != v}
            if stale:
                raise ValueError(
                    f"checkpoint at {checkpoint_dir!r} belongs to a "
                    f"different stream; mismatched (found, expected): "
                    f"{stale}")
            state, partial = _load_step(checkpoint_dir, latest)
            start = latest
            # skip the chunks already executed (the source re-iterates
            # deterministically; chunk 0's fingerprint was checked above)
            skipped = 1  # `first` is chunk 0
            while skipped < start:
                try:
                    nxt = next(it)
                except StopIteration:
                    raise ValueError(
                        f"checkpoint says {start} chunks were executed "
                        f"but the iterator ran out after {skipped} — "
                        "resuming a DIFFERENT (shorter) stream?") from None
                prepare(nxt, skipped)  # shape check only; result dropped
                skipped += 1
            if start >= 1:
                try:
                    staged = prepare(next(it), start)
                except StopIteration:
                    # stream fully executed already: return the checkpoint
                    return partial._replace(chunks_behind=0,
                                            host_stall_us=0.0)

    concat_axis = 1 if ensemble else 0
    dep_off = (lambda p: p.departed[..., -1:]) if ensemble \
        else (lambda p: p.departed[-1])

    def fold(part: PolicyResult | None, res: PolicyResult) -> PolicyResult:
        if trajectory == "full":
            return _append(part, res, axis=concat_axis)
        if part is None:
            return res
        return res._replace(departed=res.departed + dep_off(part))

    executed = 0
    chunks_behind = 0
    host_stall = 0.0
    inflight: deque = deque()  # one representative leaf per dispatch
    i = start
    exhausted = False
    while not exhausted:
        if stop_after_chunks is not None and executed >= stop_after_chunks:
            break
        # depth-2 pipeline: before dispatching chunk i, drain to at most
        # one incomplete dispatch; the time blocked here is device-bound
        # time — the healthy direction of backpressure.
        while len(inflight) > 1:
            t0 = time.perf_counter()
            jax.block_until_ready(inflight.popleft())
            host_stall += time.perf_counter() - t0
        res, state = runner(staged, state)
        inflight.append(res.queue_len)
        # host-side work overlapped against the device: pull + stage the
        # NEXT chunk while this one computes
        try:
            nxt = next(it)
        except StopIteration:
            exhausted = True
        else:
            staged = prepare(nxt, i + 1)
        if not _is_ready(res.queue_len):
            pass  # device still busy: ingestion kept up
        elif not exhausted:
            chunks_behind += 1  # device idle before the host had chunk N+1
        partial = fold(partial, res)
        executed += 1
        i += 1
        if checkpoint_dir is not None:
            # ckpt pulls arrays to host — synchronizes, trading pipeline
            # overlap for crash-safety at every boundary
            _save_step(checkpoint_dir, i, {"state": state,
                                           "partial": partial}, meta)
    if partial is None:
        raise ValueError("nothing to run: stop_after_chunks=0 with no "
                         "checkpoint to return")
    return partial._replace(chunks_behind=chunks_behind,
                            host_stall_us=host_stall * 1e6)
