"""Streaming driver: unbounded arrival iterators through the scan engines.

Every other entry point replays a fixed-``T`` pre-materialized stream; the
paper's setting (Psychas–Ghaderi 2019, Section III) is an *unbounded*
arrival process served online.  :func:`stream_policy` iterates chunks of
any — possibly infinite — ``SchedStreams`` iterator through the stateful
scan engines, threading the complete carried queue/occupancy/fault state
between chunks exactly as ``core.engine.chunked`` does, so

    **streaming replay of any finite trace is BIT-IDENTICAL to the
    one-shot ``run_policy_streams`` run, under any chunking** —

the invariant ``tests/test_streaming.py`` enforces per policy x engine x
chunk size.  What streaming adds over ``run_chunked`` is the *pipeline*:

  * **Double-buffered ingestion.**  JAX dispatch is asynchronous, so while
    the device computes chunk N the host pulls chunk N+1 from the iterator
    and stages it with ``jax.device_put``.  At most two chunks are ever in
    flight (the host blocks on chunk N-1 before dispatching N+1), which
    bounds host memory for infinite iterators to O(2 chunks), not O(T).
  * **Backpressure counters.**  The returned :class:`PolicyResult` carries
    ``chunks_behind`` — chunks whose device compute finished before the
    host had the NEXT chunk staged (ingestion is the bottleneck; feed the
    device bigger chunks or a faster reader) — and ``host_stall_us`` — the
    total host time spent blocked waiting on device compute (the device is
    the bottleneck; the healthy state for a serving loop).  Both measure
    host/device overlap only: they are excluded from bit-match
    comparisons, and the trajectory never depends on timing.
  * **Bounded-memory trajectories.**  ``trajectory="full"`` concatenates
    per-chunk planes (the default; what the parity tests compare).
    ``trajectory="tail"`` keeps only the newest chunk's planes — with the
    cumulative ``departed`` offset folded in and the scalar counters
    already whole-run totals (they accumulate in the carry) — so an
    unbounded run holds O(chunk), not O(elapsed horizon).

Engines: ``"scan"`` is the native streaming engine (its carry is the
entire simulation state).  ``"pallas"`` routes through
``kernels.common.pallas_precheck(streaming_carry=True)`` — the fused
kernels keep state in VMEM scratch for one launch and cannot thread it
across chunks, so the request degrades loudly (GracefulDegradationWarning)
to the bit-identical scan engine, or raises under ``strict=True``.
``"reference"`` keeps host-side state and is rejected.

``checkpoint_dir=`` persists the carry at every chunk boundary (same
atomic tmp-then-rename contract as chunked sweeps); ``resume=True``
re-iterates the source, skips the chunks already executed — verifying the
first chunk's fingerprint so a checkpoint never continues a different
stream — and continues bit-exactly.

``supervisor=`` (a :class:`~repro.core.engine.supervisor.Supervisor`)
makes the loop self-healing — retry/backoff on transient ingestion,
staging and checkpoint-write failures, watchdog timeouts on device
compute and host staging, rollback over corrupt checkpoints on resume,
poison-chunk quarantine — and ``audit=True`` turns on the per-chunk
jitted invariant auditor.  Both are opt-in and leave the unsupervised
fast path byte-for-byte unchanged (DESIGN.md §14).
"""
from __future__ import annotations

import time
import warnings
from collections import deque
from typing import Iterable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt

from .chunked import (_STATEFUL, _append, _load_step, _save_step,
                      _slice_streams, streams_fingerprint)
from .streams import PolicyResult, SchedStreams
from .supervisor import Supervisor, SupervisorTimeout, make_auditor

#: jitted ensemble (vmapped) runner pairs keyed by (policy, config items)
#: — reused across stream_policy calls so repeated streaming runs of the
#: same study (sweeps, benches, tests) compile once, like the module-level
#: jits of the underlying engines.  jax.jit then re-specializes per chunk
#: shape as usual.
_ENSEMBLE_RUNNERS: dict = {}


def _ensemble_runners(policy: str, config: dict):
    try:
        key = (policy, tuple(sorted(config.items())))
        cached = _ENSEMBLE_RUNNERS.get(key)
    except TypeError:        # unhashable config value: skip the cache
        key, cached = None, None
    if cached is not None:
        return cached
    base, cfg = _STATEFUL[policy], dict(config)
    first_fn = jax.jit(
        lambda s: jax.vmap(lambda x: base(x, None, cfg))(s))
    next_fn = jax.jit(
        lambda s, st: jax.vmap(lambda x, y: base(x, y, cfg))(s, st),
        donate_argnums=(1,))
    if key is not None:
        _ENSEMBLE_RUNNERS[key] = (first_fn, next_fn)
    return first_fn, next_fn


def iter_stream_chunks(streams: SchedStreams, chunk: int
                       ) -> Iterator[SchedStreams]:
    """Slice a materialized ``SchedStreams`` into contiguous time chunks —
    the trivial chunk source (tests, benches, replaying an in-memory
    sweep through :func:`stream_policy`).  Ensemble-batched streams
    (leading G axis) slice along their time axis."""
    if chunk <= 0:
        raise ValueError(f"chunk must be positive, got {chunk}")
    ensemble = streams.n.ndim == 2
    T = int(streams.n.shape[-1])
    for lo in range(0, T, chunk):
        yield _slice_streams(streams, lo, min(lo + chunk, T), ensemble)


def stream_chunks_from_trace(traces: Iterable, *, chunk_slots: int,
                             A_max: int, collapse: bool = True,
                             num_resources: int | None = None
                             ) -> Iterator[SchedStreams]:
    """Re-bucket an iterator of :class:`~repro.core.trace.Trace` chunks
    (e.g. ``core.trace.iter_trace_csv`` output, chunked by ROW COUNT) into
    fixed ``chunk_slots``-slot ``SchedStreams`` windows for
    :func:`stream_policy`.

    The two chunkings disagree by construction — a CSV reader cuts on
    rows, the engines need contiguous time windows — so arrivals are
    buffered until a window's end has provably passed (arrival slots are
    non-decreasing across reader chunks; the reader validates that) and
    emitted window by window, INCLUDING all-empty windows for slot gaps
    longer than a window: time must advance for in-service durations to
    tick.  Only the not-yet-emitted rows are ever held — constant memory.

    ``A_max`` is mandatory: a streaming source cannot know the global
    per-slot arrival peak in advance, and the engines' carry must keep one
    shape across chunks.  A window whose peak exceeds it raises (streams
    never drop trace jobs silently).  The final window is trimmed to the
    last arrival's slot, so the concatenated horizon equals the one-shot
    ``streams_from_trace`` horizon and trajectories bit-match.

    ``collapse=True`` applies the paper's max(cpu, mem) preprocessing;
    ``collapse=False`` keeps (cpu, mem) requirement vectors
    (``policy="bfjs-mr"``).  ``num_resources`` pins the expected R exactly
    as ``streams_from_trace`` does.

    The returned iterator is a CLASS, not a generator, on purpose: a
    failure raised by the inner ``traces`` source propagates without
    killing the re-bucketing state, so when the source is itself
    idempotent-on-failure (``core.trace.ResumableTraceReader``) the whole
    composition is retryable by the streaming supervisor — and a
    ``skip()`` on the source is forwarded for poison-chunk quarantine.
    """
    if chunk_slots <= 0:
        raise ValueError(f"chunk_slots must be positive, got {chunk_slots}")
    R = 1 if collapse else 2
    if num_resources is not None and num_resources != R:
        raise ValueError(
            f"collapse={collapse} yields R={R} resource plane(s) but "
            f"num_resources={num_resources} was requested")
    return _TraceChunkSource(iter(traces), chunk_slots, A_max, collapse,
                             num_resources)


class _TraceChunkSource:
    """The re-bucketing iterator behind :func:`stream_chunks_from_trace`.

    State (arrival buffer, window cursor, pending completed windows) only
    advances on a SUCCESSFUL pull from the inner source, so an exception
    from ``next(traces)`` leaves this iterator retryable — re-calling
    ``__next__`` re-attempts the same inner pull (the supervisor's
    idempotent-source contract, which a plain generator cannot satisfy).
    """

    def __init__(self, traces, chunk_slots: int, A_max: int,
                 collapse: bool, num_resources: int | None):
        self.traces = traces
        self.chunk_slots = chunk_slots
        self.A_max = A_max
        self.collapse = collapse
        self.num_resources = num_resources
        R = 1 if collapse else 2
        self.buf_slots = np.empty((0,), dtype=np.int64)
        self.buf_sizes = np.empty((0,) if collapse else (0, R),
                                  dtype=np.float64)
        self.buf_durs = np.empty((0,), dtype=np.int64)
        self.win_lo = 0      # first slot of the next window to emit
        self.last_slot = -1  # newest slot seen (slots are non-decreasing)
        self._pending: deque = deque()
        self._exhausted = False
        self._inner_failed = False

    def __iter__(self):
        return self

    def skip(self) -> None:
        """Advance the inner source past a poison chunk (supervised
        quarantine protocol) when it supports skipping."""
        skip = getattr(self.traces, "skip", None)
        if skip is not None:
            skip()

    def _emit(self, hi_slots: int) -> SchedStreams:
        """Emit the window [win_lo, win_lo + hi_slots) from the buffer."""
        from .streams import streams_from_trace

        take = self.buf_slots < self.win_lo + hi_slots
        win = streams_from_trace(
            self.buf_slots[take] - self.win_lo, self.buf_sizes[take],
            self.buf_durs[take], horizon=hi_slots, A_max=self.A_max,
            num_resources=self.num_resources)
        self.buf_slots = self.buf_slots[~take]
        self.buf_sizes = self.buf_sizes[~take]
        self.buf_durs = self.buf_durs[~take]
        self.win_lo += hi_slots
        return win

    def __next__(self) -> SchedStreams:
        import types
        while not self._pending and not self._exhausted:
            try:
                tr = next(self.traces)
            except StopIteration:
                if self._inner_failed \
                        and isinstance(self.traces, types.GeneratorType):
                    # a plain generator dies on its first error; its
                    # post-failure StopIteration is death, not a clean end
                    from .supervisor import SupervisorError
                    raise SupervisorError(
                        "trace source raised StopIteration right after "
                        "failing: a plain generator dies on its first "
                        "error and cannot be retried — wrap the source "
                        "in a resumable reader (e.g. "
                        "core.trace.ResumableTraceReader)") from None
                self._exhausted = True
                if len(self.buf_slots):
                    # final window: trim to the last arrival so the
                    # concatenated horizon equals the one-shot
                    # streams_from_trace horizon
                    self._pending.append(
                        self._emit(self.last_slot - self.win_lo + 1))
                break
            except BaseException:
                self._inner_failed = True
                raise
            self._inner_failed = False
            slots = np.asarray(tr.arrival_slots, dtype=np.int64)
            if len(slots) == 0:
                continue
            if slots[0] < self.last_slot:
                raise ValueError(
                    f"trace chunks went backwards in time: slot "
                    f"{slots[0]} after {self.last_slot} (the reader "
                    "guarantees monotone arrivals — did chunks arrive "
                    "out of order?)")
            sizes = (np.maximum(tr.cpu, tr.mem) if self.collapse
                     else np.stack([tr.cpu, tr.mem], axis=1))
            self.buf_slots = np.concatenate([self.buf_slots, slots])
            self.buf_sizes = np.concatenate([self.buf_sizes, sizes])
            self.buf_durs = np.concatenate(
                [self.buf_durs, np.asarray(tr.durations, np.int64)])
            self.last_slot = int(slots[-1])
            # every window whose end has provably passed is complete
            while self.last_slot >= self.win_lo + self.chunk_slots:
                self._pending.append(self._emit(self.chunk_slots))
        if self._pending:
            return self._pending.popleft()
        raise StopIteration


def _chunk_shape(streams: SchedStreams) -> tuple:
    """(ensemble?, G, A_max lanes, R) — the shape a stream's chunks must
    keep constant (the engine carry is built once, from the first)."""
    ensemble = streams.n.ndim == 2
    G = int(streams.n.shape[0]) if ensemble else 0
    R = streams.num_resources
    return (ensemble, G, int(streams.sizes.shape[streams.n.ndim]), R)


def _is_ready(arr) -> bool:
    """True when a dispatched array's computation has completed (False =
    still in flight).  Falls back to True — counting a chunk as
    device-idle — on runtimes without ``is_ready`` introspection."""
    try:
        return bool(arr.is_ready())
    except AttributeError:
        return True


def stream_policy(chunks: Iterable, *, policy: str = "bfjs",
                  engine: str = "scan",
                  checkpoint_dir: str | None = None,
                  resume: bool = False,
                  stop_after_chunks: int | None = None,
                  trajectory: str = "full",
                  strict: bool = False,
                  supervisor: Supervisor | None = None,
                  audit: bool = False,
                  **config) -> PolicyResult:
    """Run a (possibly infinite) iterator of ``SchedStreams`` chunks
    through a stateful scan engine with carried state — see the module
    docstring for the pipeline, invariants and backpressure semantics.

    ``chunks`` yields contiguous time windows (``iter_stream_chunks``,
    ``stream_chunks_from_trace``, or any generator — windows may have
    different lengths, but must keep one arrival-lane width and, for
    ensembles, one G).  ``stop_after_chunks`` bounds how many chunks THIS
    call executes (the unbounded-generator escape hatch; the partial
    result is returned and, with ``checkpoint_dir=``, resumable).
    ``trajectory="tail"`` keeps only the newest chunk's per-slot planes
    (bounded memory; scalar counters stay whole-run exact).

    Bit-match contract: for any finite chunking of streams ``S``,
    ``stream_policy(iter_stream_chunks(S, c), policy=p)`` equals
    ``run_policy_streams(S, policy=p)`` bit-for-bit on every trajectory
    field, for every chunk size ``c``.

    ``supervisor=`` turns on the self-healing layer (retry/backoff,
    watchdogs, checkpoint rollback, poison-chunk quarantine — see
    ``core.engine.supervisor``); its counters land on the result's
    ``retries``/``quarantined``/``rollbacks`` fields.  Transient-fault
    recovery preserves the bit-match contract exactly; only a QUARANTINED
    chunk (deterministic poison, always counted, never silent) changes
    the trajectory vs. the unperturbed run.  ``audit=True`` checks the
    runtime conservation laws after every chunk (jitted margins; the
    check syncs the pipeline once per chunk) and raises a typed
    ``InvariantViolation`` naming chunk and counter.
    """
    if policy not in _STATEFUL:
        raise ValueError(
            f"policy {policy!r} has no stateful scan engine; streaming "
            f"supports: {', '.join(sorted(_STATEFUL))}")
    if trajectory not in ("full", "tail"):
        raise ValueError(f"trajectory must be 'full' or 'tail', "
                         f"got {trajectory!r}")
    if engine == "reference":
        raise ValueError(
            'engine="reference" keeps host-side state and cannot stream; '
            'use engine="scan"')
    if engine == "pallas":
        from repro.kernels.common import pallas_precheck
        # never True: the fused kernels' state lives in VMEM scratch for
        # one launch only — raises under strict, else warns + scan
        pallas_precheck(f"{policy} stream", nbytes=0, streaming_carry=True,
                        strict=strict)
        engine = "scan"
    elif engine != "scan":
        raise ValueError(f"unknown engine {engine!r}; streaming supports "
                         '"scan" (and "pallas" via loud fallback)')
    if resume and checkpoint_dir is None:
        raise ValueError("resume=True needs checkpoint_dir=")

    sup = supervisor
    it = iter(chunks)

    def pull(index: int):
        """``next(it)`` — supervised: retried with backoff on transient
        (retryable) errors, each attempt under the staging watchdog.  A
        plain generator dies on the FIRST error it raises; detecting its
        premature ``StopIteration`` on retry turns silent stream
        truncation into a loud failure."""
        if sup is None:
            return next(it)
        failed = False

        def attempt():
            nonlocal failed
            import types
            try:
                return next(it)
            except StopIteration:
                # a resumable source may legitimately end right after a
                # recovered failure; a PLAIN generator cannot — it died
                if failed and isinstance(it, types.GeneratorType):
                    from .supervisor import SupervisorError
                    raise SupervisorError(
                        f"chunk source raised StopIteration while "
                        f"retrying chunk {index}: a plain generator dies "
                        "on its first error and cannot be retried — wrap "
                        "the source in a resumable reader (e.g. "
                        "core.trace.ResumableTraceReader)") from None
                raise
            except BaseException:
                failed = True
                raise

        return sup.call("chunk ingestion", attempt, chunk_index=index,
                        timeout=sup.stage_timeout)

    try:
        first = pull(0)
    except StopIteration:
        raise ValueError("stream_policy: the chunk iterator is empty") \
            from None

    ensemble, G, lanes, n_res = _chunk_shape(first)
    if policy == "bfjs-mr":
        from .bfjs_mr import _norm_capacity
        cap = config.get("capacity", 1.0)
        if not isinstance(cap, tuple):
            config["capacity"] = _norm_capacity(cap, max(n_res, 1))
    config.setdefault("A_max", lanes)
    from .tuning import apply_tuned
    apply_tuned(policy, "scan", config, max(n_res, 1))
    config.pop("strict", None)
    config.pop("window", None)

    meta = {
        "policy": policy,
        "trajectory": trajectory,
        "ensemble": ensemble,
        "faulted": first.up is not None,
        "first_chunk_sha256": None,  # filled below (after lifting)
        "config": {k: repr(v) for k, v in sorted(config.items())},
    }

    def prepare(streams_chunk: SchedStreams, index: int) -> SchedStreams:
        """Host-side chunk staging: validate shape, lift bfjs-mr planes,
        push to the device.  This is the work double-buffered against the
        previous chunk's device compute."""
        shape = _chunk_shape(streams_chunk)
        if shape != (ensemble, G, lanes, n_res):
            raise ValueError(
                f"chunk {index} changed shape mid-stream: (ensemble, G, "
                f"A_max, R) {shape} != first chunk's "
                f"{(ensemble, G, lanes, n_res)} — the engine carry keeps "
                "one shape for the life of the stream")
        if policy == "bfjs-mr":
            from .bfjs_mr import _lift_sizes
            streams_chunk = _lift_sizes(streams_chunk)
        return jax.device_put(streams_chunk)

    base = _STATEFUL[policy]
    if ensemble:
        _first_fn, _next_fn = _ensemble_runners(policy, config)

        def runner(streams_chunk, st):
            return _first_fn(streams_chunk) if st is None \
                else _next_fn(streams_chunk, st)
    else:
        def runner(streams_chunk, st):
            return base(streams_chunk, st, config)

    def stage(chunk, index: int):
        """``prepare`` — supervised: retried transients, staging
        watchdog."""
        if sup is None:
            return prepare(chunk, index)
        return sup.call("chunk staging",
                        lambda: prepare(chunk, index),
                        chunk_index=index, timeout=sup.stage_timeout)

    def pull_staged(index: int):
        """Pull + stage source chunk ``index``.  Under supervision, a
        chunk that still fails after retries — or fails staging with a
        non-retryable error (e.g. a mid-stream shape change) — is
        quarantined (when a quarantine_dir exists) and the next source
        chunk tried.  Returns ``(staged, source_index)``; raises
        ``StopIteration`` on exhaustion.

        Retry contract: a supervised source must be IDEMPOTENT on failure
        — re-calling ``next()`` after an error re-attempts the SAME chunk
        (``core.trace.ResumableTraceReader`` provides this for CSV
        readers; a plain generator dies instead, which ``pull`` detects).
        A source may additionally expose ``skip()`` to advance past a
        poison chunk after quarantine; without it, a deterministically
        failing position keeps failing and the consecutive-quarantine
        limit aborts the stream (a broken source, not isolated poison)."""
        idx = index
        while True:
            try:
                raw = pull(idx)
            except (StopIteration, SupervisorTimeout):
                raise
            except Exception as e:
                if sup is None or not isinstance(e, sup.retry.retryable):
                    raise
                sup.quarantine(idx, e, policy=policy, config=config)
                skip = getattr(it, "skip", None)
                if skip is not None:
                    skip()
                idx += 1
                continue
            try:
                staged_chunk = stage(raw, idx)
            except (StopIteration, SupervisorTimeout):
                raise
            except Exception as e:
                if sup is None:
                    raise
                sup.quarantine(idx, e, streams_chunk=raw, policy=policy,
                               config=config)
                idx += 1
                continue
            if sup is not None:
                sup.mark_chunk_ok()
            return staged_chunk, idx

    def finish(result: PolicyResult, behind: int,
               stall_us: float) -> PolicyResult:
        extra = dict(chunks_behind=behind, host_stall_us=stall_us)
        if sup is not None:
            extra.update(retries=sup.retries, quarantined=sup.quarantined,
                         rollbacks=sup.rollbacks)
        return result._replace(**extra)

    staged = stage(first, 0)
    src = 0  # source index of the newest pulled chunk (quarantines count)
    meta["first_chunk_sha256"] = streams_fingerprint(staged)

    auditor = None
    if audit:
        auditor = make_auditor(policy=policy, config=config,
                               num_resources=max(n_res, 1))

        def arr_sum(s: SchedStreams):
            return jnp.asarray(s.n).sum(axis=-1)

        arr_cum = jnp.zeros_like(arr_sum(staged))
        audit_zero = arr_cum

    start = 0
    state: tuple | None = None
    partial: PolicyResult | None = None
    if resume:
        if sup is not None:
            # rollback: walk back over corrupt boundaries (counted on
            # PolicyResult.rollbacks + CheckpointRollbackWarning) to the
            # newest checkpoint that still verifies
            latest, corrupt = ckpt.latest_valid_step(checkpoint_dir)
            sup.note_rollback(corrupt, checkpoint_dir)
        else:
            # unsupervised: a corrupt newest checkpoint surfaces as a
            # typed CheckpointCorruptError from read_manifest/_load_step
            latest = ckpt.latest_step(checkpoint_dir)
        if latest is not None:
            extra = ckpt.read_manifest(checkpoint_dir, latest)["extra"]
            stale = {k: (extra.get(k), v) for k, v in meta.items()
                     if extra.get(k) != v}
            if stale:
                raise ValueError(
                    f"checkpoint at {checkpoint_dir!r} belongs to a "
                    f"different stream; mismatched (found, expected): "
                    f"{stale}")
            state, partial = _load_step(checkpoint_dir, latest)
            start = latest
            # skip the chunks already executed (the source re-iterates
            # deterministically — poison chunks quarantine again under
            # supervision, keeping the alignment; chunk 0's fingerprint
            # was checked above)
            if audit:
                arr_cum = arr_cum + arr_sum(staged)
            skipped = 1  # `first` is executed chunk 0
            while skipped < start:
                try:
                    done, src = pull_staged(src + 1)
                except StopIteration:
                    raise ValueError(
                        f"checkpoint says {start} chunks were executed "
                        f"but the iterator ran out after {skipped} — "
                        "resuming a DIFFERENT (shorter) stream?") from None
                if audit:
                    arr_cum = arr_cum + arr_sum(done)
                skipped += 1
            if start >= 1:
                try:
                    staged, src = pull_staged(src + 1)
                except StopIteration:
                    # stream fully executed already: return the checkpoint
                    return finish(partial, 0, 0.0)

    concat_axis = 1 if ensemble else 0
    dep_off = (lambda p: p.departed[..., -1:]) if ensemble \
        else (lambda p: p.departed[-1])

    def fold(part: PolicyResult | None, res: PolicyResult) -> PolicyResult:
        if trajectory == "full":
            return _append(part, res, axis=concat_axis)
        if part is None:
            return res
        return res._replace(departed=res.departed + dep_off(part))

    executed = 0
    chunks_behind = 0
    host_stall = 0.0
    inflight: deque = deque()  # (chunk index, representative leaf)
    i = start
    exhausted = False

    def drain_one() -> None:
        ck, leaf = inflight.popleft()
        if sup is not None and sup.compute_timeout is not None:
            sup.watch("device compute",
                      lambda: jax.block_until_ready(leaf),
                      sup.compute_timeout, chunk_index=ck)
        else:
            jax.block_until_ready(leaf)

    while not exhausted:
        if stop_after_chunks is not None and executed >= stop_after_chunks:
            break
        # depth-2 pipeline: before dispatching chunk i, drain to at most
        # one incomplete dispatch; the time blocked here is device-bound
        # time — the healthy direction of backpressure.
        while len(inflight) > 1:
            t0 = time.perf_counter()
            drain_one()
            host_stall += time.perf_counter() - t0
        if audit:
            chunk_arr = arr_sum(staged)
        res, state = runner(staged, state)
        inflight.append((i, res.queue_len))
        ready_leaf = res.queue_len
        # host-side work overlapped against the device: pull + stage the
        # NEXT chunk while this one computes
        try:
            staged, src = pull_staged(src + 1)
        except StopIteration:
            exhausted = True
        if not _is_ready(ready_leaf):
            pass  # device still busy: ingestion kept up
        elif not exhausted:
            chunks_behind += 1  # device idle before the host had chunk N+1
        if audit:
            dep_base = audit_zero if partial is None \
                else partial.departed[..., -1]
        partial = fold(partial, res)
        if audit:
            arr_cum = arr_cum + chunk_arr
            # the margins check syncs on this chunk's outputs — the price
            # of per-chunk auditing is one pipeline sync per chunk
            auditor(arr_cum, res, dep_base, chunk_index=i)
        executed += 1
        i += 1
        if checkpoint_dir is not None:
            # ckpt pulls arrays to host — synchronizes, trading pipeline
            # overlap for crash-safety at every boundary
            payload = {"state": state, "partial": partial}
            if sup is None:
                _save_step(checkpoint_dir, i, payload, meta)
            else:
                step = i
                sup.call(
                    "checkpoint write",
                    lambda: _save_step(checkpoint_dir, step, payload, meta),
                    chunk_index=step - 1)
    # drain the tail of the pipeline so a compute watchdog covers the
    # final dispatch too
    while inflight:
        drain_one()
    if partial is None:
        raise ValueError("nothing to run: stop_after_chunks=0 with no "
                         "checkpoint to return")
    return finish(partial, chunks_behind, host_stall * 1e6)
