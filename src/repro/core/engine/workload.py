"""First-class workload specification for the engine entry points.

The paper's simulation inputs — Poisson arrival rate, job-size sampler,
geometric service rate — used to travel through ``run_policy`` as loose
positional arguments, which baked the single-resource assumption into the
API: a sampler returned ``(n,)`` scalars and nothing carried the resource
count or per-resource server capacity.  ``Workload`` makes the workload the
typed object every entry point dispatches on:

    wl = Workload(lam=1.5, mu=0.01, sampler=sampler)          # R = 1
    wl = Workload(lam=1.5, mu=0.01, sampler=vec_sampler,
                  num_resources=2, capacity=(1.0, 1.0))       # (cpu, mem)
    run_policy(wl, policy="bfjs", engine="scan", key=key, L=8, ...)

``sampler(key, n)`` must return ``(n,)`` float sizes in (0, 1] when
``num_resources == 1`` and ``(n, R)`` demand vectors in (0, 1]^R otherwise
— checked shape-only (``jax.eval_shape``, no FLOPs) by ``check_sampler``,
which every entry point calls before generating streams.  ``capacity`` is
the per-resource server capacity; the single-resource engines (``bfjs``,
``vqs``) support unit capacity only and reject anything else loudly
(``require_scalar``), while ``bfjs-mr`` honours arbitrary per-resource
capacities.

The PR 2 loose-argument signatures remain as deprecation shims in
``engine.api`` that build a ``Workload`` internally — bit-match regression
tested, so migrating callers is a pure refactor.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable

import jax


@dataclass(frozen=True)
class Workload:
    """One cluster workload: arrivals, sizes, service, resource geometry.

    Attributes:
      lam: Poisson arrival rate (jobs per slot).
      mu: geometric service rate (mean service time ``1/mu`` slots).
      sampler: ``sampler(key, n) -> (n,)`` sizes (``R == 1``) or ``(n, R)``
        demand vectors (``R > 1``), values in (0, 1] per resource.
      num_resources: R, the length of every job's requirement vector.
      capacity: per-resource server capacity — a scalar (broadcast to all R
        resources) or a length-R tuple.  Normalized to a tuple of floats.
    """

    lam: float
    mu: float
    sampler: Callable[[jax.Array, int], jax.Array]
    num_resources: int = 1
    capacity: float | tuple[float, ...] = 1.0

    def __post_init__(self):
        if not isinstance(self.num_resources, int) or self.num_resources < 1:
            raise ValueError(
                f"num_resources must be a positive int, got "
                f"{self.num_resources!r}")
        if self.lam < 0:
            raise ValueError(f"lam must be >= 0, got {self.lam}")
        if not 0 < self.mu <= 1:
            raise ValueError(f"mu must be in (0, 1], got {self.mu}")
        cap = self.capacity
        if not isinstance(cap, tuple):
            cap = (float(cap),) * self.num_resources
        else:
            cap = tuple(float(c) for c in cap)
        if len(cap) != self.num_resources:
            raise ValueError(
                f"capacity has {len(cap)} entries for num_resources="
                f"{self.num_resources}")
        if any(c <= 0 for c in cap):
            raise ValueError(f"capacity entries must be > 0, got {cap}")
        object.__setattr__(self, "capacity", cap)

    # -- validation ---------------------------------------------------------
    def check_sampler(self) -> None:
        """Shape-check ``sampler`` against ``num_resources`` (no FLOPs).

        ``jax.eval_shape`` traces one abstract call ``sampler(key, 2)`` and
        verifies the output is ``(2,)`` for R == 1 / ``(2, R)`` for R > 1 —
        the mismatch every multi-resource bug starts with, caught at the
        API boundary instead of deep inside a scan."""
        key = jax.ShapeDtypeStruct((2,), jax.numpy.uint32)
        try:
            out = jax.eval_shape(lambda k: self.sampler(k, 2), key)
        except TypeError:
            # typed-key samplers (jax >= 0.4.16 PRNGKeyArray)
            key = jax.eval_shape(lambda: jax.random.PRNGKey(0))
            out = jax.eval_shape(lambda k: self.sampler(k, 2), key)
        expect = (2,) if self.num_resources == 1 else (2, self.num_resources)
        if tuple(out.shape) != expect:
            raise ValueError(
                f"sampler output shape {tuple(out.shape)} does not match "
                f"num_resources={self.num_resources}: expected {expect} "
                "for sampler(key, 2)")

    def require_scalar(self, policy: str) -> None:
        """Single-resource engines reject vector workloads loudly."""
        if self.num_resources != 1:
            raise ValueError(
                f"policy {policy!r} is single-resource; this workload has "
                f"num_resources={self.num_resources} — use policy="
                "\"bfjs-mr\" (or collapse the demands first)")
        if self.capacity != (1.0,):
            raise ValueError(
                f"policy {policy!r} supports unit server capacity only, "
                f"got capacity={self.capacity}")

    # -- ergonomics ---------------------------------------------------------
    def replace(self, **changes) -> "Workload":
        return dataclasses.replace(self, **changes)

    @property
    def mean_service(self) -> float:
        return 1.0 / self.mu
