"""Multi-resource BF-J/S engines (paper Section VIII) on the scan stack.

Ports ``core/multi_resource.py``'s Tetris-alignment BF-J/S — the paper's
named future-work extension, cf. Yao et al. (*Throughput-Optimal
Multiresource-Job Scheduling*) — onto the fixed-shape accelerator stack as
``policy="bfjs-mr"``:

  * ``engine="reference"`` — the event-driven ``MultiResourceBFJS`` numpy
    oracle driven slot-by-slot from the same ``SchedStreams`` (host-side,
    not jittable): the behavioural anchor;
  * ``engine="scan"``      — a branch-free ``lax.scan`` over slots with a
    bounded early-exit placement work list, the same program shape as the
    single-resource BF-J/S scan engine, generalized to ``(L, R)`` integer
    occupancy planes and ``(Qcap, R)`` queued demand vectors;
  * ``engine="pallas"``    — the fused slot-step kernel in
    ``kernels/bfjs_mr`` (occupancy planes, queue state and counters stay
    resident in VMEM; the Monte-Carlo ensemble is the kernel grid), which
    bit-matches "scan" whenever ``truncated == 0``.

Semantics (one slot, identical to the oracle's ``step``):

  1. departures free their demand vectors;
  2. arrivals join the queue (first-empty positions, arrival-order seq ids);
  3. BF-S over freed servers in ascending order: repeatedly place the
     queued job with the LARGEST total demand that fits (ties: lowest seq,
     i.e. earliest arrival — the oracle's insertion-order tie-break);
  4. BF-J over the slot's arrivals in order: place each still-queued job on
     the feasible server with the LOWEST alignment score
     ``<demand, available>`` (ties: lowest server index).

Exactness: demands and occupancies are ``quantize.RES`` grid integers, so
every feasibility and total-demand comparison is exact; the alignment
score is exact integer arithmetic compared as an int32 ``(hi, lo)`` pair
(``alignment_score_pair_jnp``), equal to the oracle's exact float64
``alignment_scores`` on every backend, vmap batch width and compiler
version — so ``"scan"`` bit-matches ``"reference"`` whenever
``truncated == 0``, and sharded/unsharded runs bit-match each other.

Durations attach to jobs at arrival (like VQS), so trace-built streams
(``streams_from_trace(trace, collapse=False)`` — per-arrival duration
lanes only) replay directly: the path that runs the synthesized Google-like
(cpu, mem) trace uncollapsed, the preprocessing step the paper's Section
VIII wants removed.

Fixed-shape deviations (counted, never silent): queue overflow beyond
``Qcap`` drops arrivals (``dropped``); a placement onto a server whose
``K`` job slots are full is skipped and counted (``truncated``), as are
slots that exhaust the ``work_steps`` bound with placements still pending.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..quantize import RES
from .bfjs import DEFAULT_MAX_REQUEUE
from .ops import alignment_score_pair_jnp
from .streams import (INF_SLOT, PolicyResult, SchedStreams, make_streams,
                      resolve_work_steps)

INT32_MAX = jnp.iinfo(jnp.int32).max


def _preempt_planes(dem, dep, occ, qdem, qdur, qseq, qtry, tries, sseq,
                    seq0, q_cnt, up_t, t, max_requeue):
    """Evict every job in service on a down server (multi-resource planes).

    Victims with ``tries < max_requeue`` re-enter the queue at the first
    empty positions in ascending current-``seq`` order, carrying their
    REMAINING duration, ``tries + 1`` and a FRESH seq id — exactly the
    oracle's dict-insertion order (requeues before the slot's arrivals),
    so BF-S tie-breaks keep bit-matching.  Exhausted victims (and any that
    find the queue full) are dropped entirely and counted ``lost``.
    Returns the updated planes plus ``(n_preempted, n_requeued, n_lost)``.
    """
    R = dem.shape[-1]
    victim = (~up_t)[:, None] & (dep != INF_SLOT)
    vic_f = victim.reshape(-1)
    elig = vic_f & (tries.reshape(-1) < max_requeue)
    # rank eligible victims by current seq; ineligible sort to the back
    key = jnp.where(elig, sseq.reshape(-1), INT32_MAX)
    rank_of = jnp.argsort(jnp.argsort(key)).astype(jnp.int32)
    n_empty = jnp.cumsum((qseq < 0).astype(jnp.int32))
    pos = jnp.searchsorted(n_empty, rank_of + 1)
    land = elig & (pos < qseq.shape[0])
    at = jnp.where(land, pos, qseq.shape[0])
    rem = jnp.maximum(dep.reshape(-1) - t, 1)
    qdem = qdem.at[at].set(dem.reshape(-1, R), mode="drop")
    qdur = qdur.at[at].set(rem, mode="drop")
    qtry = qtry.at[at].set(tries.reshape(-1) + 1, mode="drop")
    qseq = qseq.at[at].set(seq0 + rank_of, mode="drop")
    n_vict = vic_f.sum()
    n_req = land.sum()
    seq0 = seq0 + n_req
    q_cnt = q_cnt + n_req
    occ = occ - (dem * victim[..., None]).sum(axis=1)
    dem = jnp.where(victim[..., None], 0, dem)
    dep = jnp.where(victim, INF_SLOT, dep)
    tries = jnp.where(victim, 0, tries)
    sseq = jnp.where(victim, 0, sseq)
    return (dem, dep, occ, qdem, qdur, qseq, qtry, tries, sseq, seq0,
            q_cnt, n_vict, n_req, n_vict - n_req)


def _norm_capacity(capacity, R: int) -> tuple[float, ...]:
    if not isinstance(capacity, tuple):
        capacity = (float(capacity),) * R
    if len(capacity) != R:
        raise ValueError(
            f"capacity has {len(capacity)} entries for R={R} resources")
    if any(c <= 0 for c in capacity):
        raise ValueError(f"capacity entries must be > 0, got {capacity}")
    return tuple(float(c) for c in capacity)


def _lift_sizes(streams: SchedStreams) -> SchedStreams:
    """bfjs-mr consumes (T, A_max, R) sizes; lift squeezed R=1 streams."""
    if streams.sizes.ndim == streams.durs.ndim:
        return streams._replace(sizes=streams.sizes[..., None])
    return streams


@functools.partial(
    jax.jit,
    static_argnames=("L", "K", "Qcap", "A_max", "work_steps", "capacity",
                     "max_requeue", "return_state"))
def run_bfjs_mr_streams(streams: SchedStreams, L: int, K: int, Qcap: int,
                        A_max: int, work_steps: int | None = None,
                        capacity: tuple[float, ...] | float = 1.0,
                        max_requeue: int = DEFAULT_MAX_REQUEUE,
                        state: tuple | None = None,
                        return_state: bool = False):
    """Branch-free multi-resource BF-J/S slot engine over streams.

    One ``lax.scan`` over slots; inside each slot the BF-S refill and BF-J
    placement passes are a bounded early-exit work list
    (``lax.while_loop`` capped at ``work_steps``).  Each step either
    performs the BF-S placement for the lowest-index freed server that
    still has a fitting queued job, or attempts the next arrival's BF-J
    placement — the same dynamic dispatch as the single-resource engine,
    with vector feasibility (``all_r  dem_r <= avail_r``) and the exact
    integer alignment-score pair replacing scalar residual comparisons.  Placements
    only consume queue entries and only shrink availability, so the
    lowest-index-first order reproduces the oracle's nested loops exactly.
    """
    streams = _lift_sizes(streams)
    horizon, _, R = streams.sizes.shape
    capacity = _norm_capacity(capacity, R)
    CAP = jnp.asarray([round(c * RES) for c in capacity], jnp.int32)
    W = resolve_work_steps(work_steps, A_max)
    faulted = streams.up is not None
    a_iota = jnp.arange(A_max)
    l_iota = jnp.arange(L)
    q_iota = jnp.arange(Qcap)
    k_iota = jnp.arange(K)
    dur_off = streams.durs.shape[-1] - A_max

    def slot_step(state, inp):
        (dem, dep, occ, qdem, qdur, qseq, t, q_cnt, seq0, dropped, trunc,
         qtry, tries, sseq, preempted, requeued, lost, up_last) = state
        if faulted:
            n, sizes, durs, up_t = inp
        else:
            n, sizes, durs = inp
            up_t = None

        # 1. departures
        leaving = dep == t
        freed = leaving.any(axis=1)
        n_dep = leaving.sum()
        occ = occ - (dem * leaving[..., None]).sum(axis=1)
        dem = jnp.where(leaving[..., None], 0, dem)
        dep = jnp.where(leaving, INF_SLOT, dep)
        tries = jnp.where(leaving, 0, tries)
        sseq = jnp.where(leaving, 0, sseq)

        # 1b. fault preemption: down servers evict, victims requeue or
        # are lost; recovered servers rejoin the BF-S freed set.
        if faulted:
            (dem, dep, occ, qdem, qdur, qseq, qtry, tries, sseq, seq0,
             q_cnt, n_v, n_r, n_l) = _preempt_planes(
                 dem, dep, occ, qdem, qdur, qseq, qtry, tries, sseq,
                 seq0, q_cnt, up_t, t, max_requeue)
            preempted = preempted + n_v
            requeued = requeued + n_r
            lost = lost + n_l
            freed = (freed | (up_t & ~up_last)) & up_t
            up_last = up_t

        # 2. arrivals -> first empty queue positions (grid-quantized)
        g = jnp.maximum(jnp.round(sizes * RES), 1.0).astype(jnp.int32)
        n_empty = jnp.cumsum((qseq < 0).astype(jnp.int32))
        pos_a = jnp.searchsorted(n_empty, a_iota + 1)
        landed = (a_iota < n) & (pos_a < Qcap)
        n_landed = landed.sum()
        dropped = dropped + n - n_landed
        q_cnt = q_cnt + n_landed
        wpos = jnp.where(landed, pos_a, Qcap)
        qdem = qdem.at[wpos].set(jnp.where(landed[:, None], g, 0),
                                 mode="drop")
        qdur = qdur.at[wpos].set(durs[dur_off + a_iota], mode="drop")
        qseq = qseq.at[wpos].set(seq0 + a_iota, mode="drop")
        qtry = qtry.at[wpos].set(0, mode="drop")
        seq0 = seq0 + n
        new_pos = jnp.where(landed, pos_a, -1)
        rank = jnp.cumsum(landed.astype(jnp.int32)) - 1
        landed_list = jnp.full((A_max,), A_max - 1, jnp.int32).at[
            jnp.where(landed, rank, A_max)].set(a_iota.astype(jnp.int32),
                                                mode="drop")
        pos_list = new_pos[landed_list]

        def fits_matrix(occ, qdem, qseq, freed_mask):
            """(L, Qcap) — job j fits on server i (static unroll over R)."""
            avail = CAP[None, :] - occ
            fits = freed_mask[:, None] & (qseq >= 0)[None, :]
            for r in range(R):
                fits = fits & (qdem[:, r][None, :] <= avail[:, r][:, None])
            return fits

        # 3+4. BF-S then BF-J as one bounded early-exit work list
        def work(carry):
            (dem, dep, occ, qdem, qdur, qseq, qtry, tries, sseq, q_cnt,
             blocked, a_ptr, trunc, done, n_steps) = carry
            avail = CAP[None, :] - occ

            # BF-S candidate: lowest-index freed, unblocked server with a
            # fitting job; its job = largest total demand, earliest seq.
            fits = fits_matrix(occ, qdem, qseq, freed & ~blocked)
            has_fit = fits.any(axis=1)
            cur = jnp.min(jnp.where(has_fit, l_iota, L))
            any_bfs = cur < L
            cur_c = jnp.minimum(cur, L - 1)
            fit_cur = fits[cur_c]
            tot = qdem.sum(axis=-1)
            best_tot = jnp.max(jnp.where(fit_cur, tot, -1))
            cand = fit_cur & (tot == best_tot)
            best_seq = jnp.min(jnp.where(cand, qseq, INT32_MAX))
            j_bfs = jnp.min(jnp.where(cand & (qseq == best_seq), q_iota,
                                      Qcap))
            j_bfs = jnp.minimum(j_bfs, Qcap - 1)

            # BF-J candidate: next landed arrival still in the queue, on
            # the min-alignment feasible server (any server, not just
            # freed — the oracle's _best_server scans all L).
            is_bfj = (~any_bfs) & (a_ptr < n_landed)
            ap = jnp.minimum(a_ptr, A_max - 1)
            pos = pos_list[ap]
            posc = jnp.maximum(pos, 0)
            present = is_bfj & (pos >= 0) & (qseq[posc] >= 0)
            d_bfj = qdem[posc]
            feas = jnp.ones((L,), bool)
            for r in range(R):
                feas = feas & (d_bfj[r] <= avail[:, r])
            if faulted:
                feas = feas & up_t
            s_hi, s_lo = alignment_score_pair_jnp(avail, d_bfj)
            best_hi = jnp.min(jnp.where(feas, s_hi, INT32_MAX))
            cand_j = feas & (s_hi == best_hi)
            best_lo = jnp.min(jnp.where(cand_j, s_lo, INT32_MAX))
            s_bfj = jnp.min(jnp.where(cand_j & (s_lo == best_lo), l_iota,
                                      L))
            s_bfj_c = jnp.minimum(s_bfj, L - 1)
            ok_bfj = present & feas.any()

            do = any_bfs | ok_bfj
            tgt = jnp.where(any_bfs, cur_c, s_bfj_c)
            qidx = jnp.where(any_bfs, j_bfs, posc)
            d_place = qdem[qidx]
            dur = qdur[qidx]
            try_pl = qtry[qidx]
            seq_pl = qseq[qidx]

            row_dep = dep[tgt]
            slot = jnp.min(jnp.where(row_dep == INF_SLOT, k_iota, K))
            ok_slot = slot < K
            place = do & ok_slot
            slot_w = jnp.where(place, jnp.minimum(slot, K - 1), K)
            dem = dem.at[tgt, slot_w].set(d_place, mode="drop")
            dep = dep.at[tgt, slot_w].set(t + dur, mode="drop")
            tries = tries.at[tgt, slot_w].set(try_pl, mode="drop")
            sseq = sseq.at[tgt, slot_w].set(seq_pl, mode="drop")
            occ = occ.at[jnp.where(place, tgt, L)].add(d_place, mode="drop")
            qclear = jnp.where(place, qidx, Qcap)
            qseq = qseq.at[qclear].set(-1, mode="drop")
            qdem = qdem.at[qclear].set(0, mode="drop")
            qtry = qtry.at[qclear].set(0, mode="drop")
            q_cnt = q_cnt - place.astype(jnp.int32)
            # K-full server: the oracle would place; count, don't spin.
            trunc = trunc + (do & ~ok_slot).astype(jnp.int32)
            blocked = blocked | (any_bfs & ~ok_slot)
            a_ptr = a_ptr + is_bfj.astype(jnp.int32)
            # BF-S fits only shrink and each arrival is attempted once, so
            # once neither exists the slot is finished for good.
            done = (~any_bfs) & (a_ptr >= n_landed)
            return (dem, dep, occ, qdem, qdur, qseq, qtry, tries, sseq,
                    q_cnt, blocked, a_ptr, trunc, done, n_steps + 1)

        def unfinished(carry):
            done, n_steps = carry[13], carry[14]
            return (~done) & (n_steps < W)

        zero = jnp.zeros((), jnp.int32)
        carry = (dem, dep, occ, qdem, qdur, qseq, qtry, tries, sseq,
                 q_cnt, jnp.zeros((L,), bool), zero, trunc,
                 jnp.zeros((), bool), zero)
        carry = jax.lax.while_loop(unfinished, work, carry)
        (dem, dep, occ, qdem, qdur, qseq, qtry, tries, sseq, q_cnt,
         blocked, a_ptr, trunc, done, _) = carry

        # saturation check: work the oracle would still do => the bounded
        # list diverged this slot (K-full blocks were already counted).
        fits = fits_matrix(occ, qdem, qseq, freed & ~blocked)
        pend_bfs = fits.any()
        left = (a_iota >= a_ptr) & (a_iota < n_landed)
        posb = jnp.maximum(pos_list, 0)
        present_l = left & (pos_list >= 0) & (qseq[posb] >= 0)
        avail = CAP[None, :] - occ
        feas_l = jnp.ones((A_max, L), bool)
        for r in range(R):
            feas_l = feas_l & (qdem[posb][:, r][:, None]
                               <= avail[:, r][None, :])
        if faulted:
            feas_l = feas_l & up_t[None, :]
        pend_bfj = (present_l & feas_l.any(axis=1)).any()
        trunc = trunc + (pend_bfs | pend_bfj).astype(jnp.int32)

        out = (q_cnt, occ.sum(axis=0).astype(jnp.float32) / RES,
               n_dep.astype(jnp.int32))
        state = (dem, dep, occ, qdem, qdur, qseq, t + 1, q_cnt, seq0,
                 dropped, trunc, qtry, tries, sseq, preempted, requeued,
                 lost, up_last)
        return state, out

    zero = jnp.zeros((), jnp.int32)
    if state is None:
        state = (
            jnp.zeros((L, K, R), jnp.int32),
            jnp.full((L, K), INF_SLOT, jnp.int32),
            jnp.zeros((L, R), jnp.int32),
            jnp.zeros((Qcap, R), jnp.int32),
            jnp.ones((Qcap,), jnp.int32),
            jnp.full((Qcap,), -1, jnp.int32),
            zero, zero, zero, zero, zero,
            jnp.zeros((Qcap,), jnp.int32),   # qtry: queued retry counts
            jnp.zeros((L, K), jnp.int32),    # tries: in-service retries
            jnp.zeros((L, K), jnp.int32),    # sseq: in-service seq ids
            zero, zero, zero,                # preempted / requeued / lost
            jnp.ones((L,), bool),            # up_last (recovery detection)
        )
    xs = (streams.n, streams.sizes, streams.durs)
    if faulted:
        xs = xs + (streams.up,)
    state, (qlen, occ, ndep) = jax.lax.scan(slot_step, state, xs)
    res = PolicyResult(qlen, occ, jnp.cumsum(ndep), state[9], state[10],
                       state[14], state[15], state[16])
    return (res, state) if return_state else res


def _run_bfjs_mr_reference(streams: SchedStreams, *, L: int,
                           capacity: tuple[float, ...] | float = 1.0,
                           max_requeue: int = DEFAULT_MAX_REQUEUE
                           ) -> PolicyResult:
    """The event-driven ``MultiResourceBFJS`` oracle driven from streams.

    Host-side numpy, slot by slot — not jittable, kept as the behavioural
    anchor the scan engine is parity-tested against.  Demands are the same
    grid quantization the scan engine applies (``max(round(s * RES), 1)``)
    replayed as exact dyadics ``g / RES``; the capacity is quantized to the
    grid too, so every feasibility comparison is exact and agrees with the
    integer engine.  When the streams carry a fault plane the oracle is
    stepped with ``down = ~up[t]`` and the counters come from its fault
    accounting (lost jobs never depart, so cumulative departures subtract
    them).  The oracle has no fixed-size buffers: ``dropped`` and
    ``truncated`` are always 0.
    """
    from ..multi_resource import MRJob, MultiResourceBFJS

    streams = _lift_sizes(streams)
    n = np.asarray(streams.n)
    sizes = np.asarray(streams.sizes, dtype=np.float64)
    durs = np.asarray(streams.durs)
    up = None if streams.up is None else np.asarray(streams.up)
    T, A_max, R = sizes.shape
    capacity = _norm_capacity(capacity, R)
    cap_dyadic = tuple(round(c * RES) / RES for c in capacity)
    g = np.maximum(np.rint(sizes * RES), 1.0)
    dem = g / RES
    dur_off = durs.shape[-1] - A_max

    policy = MultiResourceBFJS(L, R, capacity=cap_dyadic)
    qlen = np.zeros(T, dtype=np.int32)
    occ = np.zeros((T, R), dtype=np.float64)
    dep_cum = np.zeros(T, dtype=np.int32)
    jid = 0
    for t in range(T):
        jobs = []
        for a in range(int(n[t])):
            jobs.append(MRJob(jid, dem[t, a], t, int(durs[t, dur_off + a])))
            jid += 1
        down = None if up is None else ~up[t]
        policy.step(t, jobs, down=down, max_requeue=max_requeue)
        q = policy.queue_len()
        qlen[t] = q
        occ[t] = policy.occupied.sum(axis=0)
        in_service = sum(len(s) for s in policy.jobs)
        dep_cum[t] = jid - in_service - q - policy.lost
    i32 = lambda v: jnp.asarray(np.int32(v))
    return PolicyResult(
        jnp.asarray(qlen), jnp.asarray(occ.astype(np.float32)),
        jnp.asarray(dep_cum), jnp.zeros((), jnp.int32),
        jnp.zeros((), jnp.int32), i32(policy.preempted),
        i32(policy.requeued), i32(policy.lost))


def run_bfjs_mr_trace(streams: SchedStreams, *, L: int, K: int = 16,
                      Qcap: int = 512, A_max: int | None = None,
                      engine: str = "scan", work_steps: int | None = None,
                      capacity: tuple[float, ...] | float = 1.0,
                      window: int | None = None,
                      max_requeue: int = DEFAULT_MAX_REQUEUE,
                      strict: bool = False) -> PolicyResult:
    """Run one multi-resource BF-J/S simulation over explicit streams.

    Accepts both trace-built streams (per-arrival duration lanes only —
    the ``streams_from_trace(trace, collapse=False)`` path) and
    ``make_streams`` full-width streams (the engine consumes the last
    ``A_max`` per-arrival lanes; durations attach at arrival).  ``window``
    is the Pallas engine's VMEM time-window length (must divide the
    horizon; ignored by the other engines).  ``engine="pallas"`` is gated
    by :func:`repro.kernels.common.pallas_precheck` — a fault plane or an
    over-budget VMEM estimate degrades to the bit-identical scan engine
    with a :class:`GracefulDegradationWarning` (or raises, ``strict=True``).
    """
    streams = _lift_sizes(streams)
    if A_max is None:
        A_max = int(streams.sizes.shape[1])
    if engine == "reference":
        return _run_bfjs_mr_reference(streams, L=L, capacity=capacity,
                                      max_requeue=max_requeue)
    if engine == "pallas":
        from repro.kernels.bfjs_mr.ops import (bfjs_mr_scratch_bytes,
                                               bfjs_mr_simulate)
        from repro.kernels.common import ensemble_plane_bytes, pallas_precheck
        R = int(streams.sizes.shape[-1])
        T, D = streams.n.shape[0], streams.durs.shape[-1]
        if not pallas_precheck(
                "bfjs-mr", nbytes=bfjs_mr_scratch_bytes(L, K, Qcap, R),
                hbm_bytes=ensemble_plane_bytes(
                    1, T, stream_lanes=1 + A_max * R + D, out_lanes=2 + R),
                fault_plane=streams.up is not None, strict=strict):
            engine = "scan"
        else:
            batched = jax.tree.map(lambda x: x[None], streams)
            res = bfjs_mr_simulate(batched, L=L, K=K, Qcap=Qcap,
                                   A_max=A_max, work_steps=work_steps,
                                   capacity=capacity, window=window)
            return jax.tree.map(lambda x: x[0], res)
    if engine == "scan":
        if not isinstance(capacity, tuple):
            capacity = _norm_capacity(capacity, int(streams.sizes.shape[-1]))
        return run_bfjs_mr_streams(streams, L=L, K=K, Qcap=Qcap,
                                   A_max=A_max, work_steps=work_steps,
                                   capacity=capacity,
                                   max_requeue=max_requeue)
    raise ValueError(f"unknown engine {engine!r}")


def run_bfjs_mr_workload(workload, key, *, engine: str = "scan",
                         L: int = 8, K: int = 16, Qcap: int = 512,
                         A_max: int = 8, horizon: int = 10_000,
                         work_steps: int | None = None,
                         window: int | None = None,
                         fault_rate: float = 0.0, repair_rate: float = 1.0,
                         max_requeue: int = DEFAULT_MAX_REQUEUE,
                         strict: bool = False) -> PolicyResult:
    """Simulate multi-resource BF-J/S for one ``Workload`` and key."""
    workload.check_sampler()
    streams = make_streams(key, workload.lam, workload.mu, workload.sampler,
                           L=L, K=K, A_max=A_max, horizon=horizon,
                           num_resources=workload.num_resources,
                           fault_rate=fault_rate, repair_rate=repair_rate)
    return run_bfjs_mr_trace(streams, L=L, K=K, Qcap=Qcap, A_max=A_max,
                             engine=engine, work_steps=work_steps,
                             capacity=workload.capacity, window=window,
                             max_requeue=max_requeue, strict=strict)


def monte_carlo_bfjs_mr_workload(workload, keys, *, engine: str = "scan",
                                 L: int = 8, K: int = 16, Qcap: int = 512,
                                 A_max: int = 8, horizon: int = 10_000,
                                 work_steps: int | None = None,
                                 window: int | None = None,
                                 fault_rate: float = 0.0,
                                 repair_rate: float = 1.0,
                                 max_requeue: int = DEFAULT_MAX_REQUEUE,
                                 strict: bool = False) -> PolicyResult:
    """One simulated cluster per key ("scan" vmaps; "reference" loops the
    host-side oracle and stacks; "pallas" pre-generates every member's
    streams and runs the fused kernel with the ensemble as the grid —
    degrading to "scan" when the precheck rejects the request)."""
    workload.check_sampler()
    if engine == "reference":
        res = [run_bfjs_mr_workload(workload, k, engine=engine, L=L, K=K,
                                    Qcap=Qcap, A_max=A_max, horizon=horizon,
                                    work_steps=work_steps,
                                    fault_rate=fault_rate,
                                    repair_rate=repair_rate,
                                    max_requeue=max_requeue) for k in keys]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *res)
    if engine == "pallas":
        from repro.kernels.bfjs_mr.ops import (bfjs_mr_scratch_bytes,
                                               bfjs_mr_simulate)
        from repro.kernels.common import ensemble_plane_bytes, pallas_precheck
        R = int(workload.num_resources)
        # keys is the LOCAL batch under a sharded mesh launch, so the
        # footprint check is per device (core.engine.sharding).
        G = int(keys.shape[0])
        if not pallas_precheck(
                "bfjs-mr", nbytes=bfjs_mr_scratch_bytes(L, K, Qcap, R),
                hbm_bytes=ensemble_plane_bytes(
                    G, horizon,
                    stream_lanes=1 + A_max * R + (L * K + A_max),
                    out_lanes=2 + R),
                fault_plane=fault_rate > 0.0, strict=strict):
            engine = "scan"
        else:
            streams = jax.vmap(
                lambda k: make_streams(k, workload.lam, workload.mu,
                                       workload.sampler, L=L, K=K,
                                       A_max=A_max, horizon=horizon,
                                       num_resources=workload.num_resources)
            )(keys)
            return bfjs_mr_simulate(streams, L=L, K=K, Qcap=Qcap,
                                    A_max=A_max, work_steps=work_steps,
                                    capacity=workload.capacity,
                                    window=window)
    fn = functools.partial(run_bfjs_mr_workload, workload, engine=engine,
                           L=L, K=K, Qcap=Qcap, A_max=A_max,
                           horizon=horizon, work_steps=work_steps,
                           fault_rate=fault_rate, repair_rate=repair_rate,
                           max_requeue=max_requeue)
    return jax.vmap(fn)(keys)
