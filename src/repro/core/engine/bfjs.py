"""BF-J/S cluster engines (paper Section IV) on the scan + Pallas stack.

Three engines share one trajectory semantics (see DESIGN.md):

  * ``engine="reference"`` — the original nested ``fori/while/cond`` program,
    kept verbatim as the behavioural oracle;
  * ``engine="scan"``      — the branch-free rewrite: all randomness is
    hoisted into pre-generated streams (``streams.make_streams``) and the
    per-slot BF-S/BF-J placement nest becomes a single bounded work-list
    scan of masked vectorized selects (no ``cond``, no data-dependent trip
    counts), so ``vmap`` over seeds vectorizes cleanly;
  * ``engine="pallas"``    — the fused slot-step kernel in ``kernels/bfjs``
    (residuals, departure times and the queue stay resident in VMEM; the
    Monte-Carlo ensemble is the kernel grid).

"scan" and "reference" produce bit-identical trajectories on the shared
random streams as long as the bounded work list does not saturate; the
``truncated`` field of the result counts slots where the bound cut BF-S
short (0 == exact).

Fixed-capacity redesign (documented deviation from the unbounded queueing
model): the queue is a ``Qcap``-slot buffer and arrivals beyond ``A_max`` per
slot are dropped AND COUNTED (``dropped`` in the result) — runs whose drop
count is nonzero must be treated as saturated, not stable.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .ops import first_empty_positions
from .streams import (INF_SLOT, PolicyResult, SchedStreams, _geometric,
                      make_fault_plane, make_streams, resolve_work_steps)

BFJSResult = PolicyResult

#: Default bound on fault-driven requeues: a job evicted by a server-down
#: shock re-enters the queue until it has been preempted ``max_requeue``
#: times, then it is counted ``lost``.
DEFAULT_MAX_REQUEUE = 2


def _preempt_grid(srv, dep, tries, queue, qtry, up_t, max_requeue):
    """Evict every job resident on a down server (DESIGN.md §9).

    Shared verbatim by the scan engine and the reference oracle, so faulted
    trajectories bit-match for free.  Victims below the retry bound re-enter
    the queue in row-major ``(server, slot)`` order through the same
    first-empty admission rule as arrivals, carrying ``tries + 1``; the rest
    (bound exhausted, or queue full) are lost.  Returns the updated planes
    plus this slot's ``(n_preempted, n_requeued, n_lost)`` counts — always
    ``n_preempted == n_requeued + n_lost``.
    """
    Qcap = queue.shape[0]
    victim = (~up_t)[:, None] & (srv > 0.0)
    elig = (victim & (tries < max_requeue)).reshape(-1)
    pos, land = first_empty_positions(queue == 0.0, elig)
    at = jnp.where(land, pos, Qcap)
    queue = queue.at[at].set(jnp.where(land, srv.reshape(-1), 0.0),
                             mode="drop")
    qtry = qtry.at[at].set(jnp.where(land, tries.reshape(-1) + 1, 0),
                           mode="drop")
    n_vict = victim.sum().astype(jnp.int32)
    n_req = land.sum().astype(jnp.int32)
    srv = jnp.where(victim, 0.0, srv)
    dep = jnp.where(victim, INF_SLOT, dep)
    tries = jnp.where(victim, 0, tries)
    return srv, dep, tries, queue, qtry, n_vict, n_req, n_vict - n_req


def _check_sequential_durs(streams: SchedStreams, L: int, K: int,
                           A_max: int) -> None:
    """BF-J/S consumes a ``durs[t, :L*K]`` sequential-draw region that
    trace-built streams (``streams_from_trace``) deliberately lack — their
    BF-S refills would detach durations from job identities.  The width
    check is static (shape-only), so it raises at trace time even under
    jit/vmap instead of replaying the trace wrong."""
    width = streams.durs.shape[-1]
    if width != L * K + A_max:
        raise ValueError(
            f"BF-J/S needs a duration stream of width L*K + A_max = "
            f"{L * K + A_max} (sequential-draw region + per-arrival lanes), "
            f"got {width}.  Trace-built streams carry per-arrival durations "
            "only — replay traces through a policy that attaches durations "
            "at arrival (policy=\"vqs\").")


class BFJSState(NamedTuple):
    srv: jax.Array       # (L, K) float32 job sizes in servers (0 = empty slot)
    dep: jax.Array       # (L, K) int32 departure slot (INF_SLOT when empty)
    queue: jax.Array     # (Qcap,) float32 queued sizes (0 = empty)
    dropped: jax.Array   # () int32 arrivals dropped by the fixed-size buffer
    key: jax.Array
    # Fault-injection planes (zeros/ones on fault-free runs):
    qtry: jax.Array      # (Qcap,) int32 retry counts riding with queued jobs
    tries: jax.Array     # (L, K) int32 retry counts of resident jobs
    preempted: jax.Array  # () int32
    requeued: jax.Array   # () int32
    lost: jax.Array       # () int32
    up_last: jax.Array   # (L,) bool: previous slot's fault-plane row


@functools.partial(
    jax.jit, static_argnames=("L", "K", "Qcap", "A_max", "work_steps",
                              "max_requeue", "return_state"))
def run_bfjs_streams(streams: SchedStreams,
                     L: int, K: int, Qcap: int, A_max: int,
                     work_steps: int | None = None,
                     max_requeue: int = DEFAULT_MAX_REQUEUE,
                     state: tuple | None = None,
                     return_state: bool = False):
    """Branch-free BF-J/S slot engine over pre-generated streams.

    One ``lax.scan`` over slots; inside each slot the BF-S refill and BF-J
    placement passes are a single bounded work list (unrolled: ``work_steps``
    masked-select placement steps, no ``cond``, no data-dependent trip
    counts).  Each step dynamically dispatches: while any freed server still
    has a fitting queued job it performs the BF-S placement for the
    lowest-index such server, otherwise it attempts the next landed arrival
    (BF-J).  Jobs only ever leave the queue and placements only shrink
    residuals, so an exhausted server never un-exhausts and BF-S placements
    genuinely all precede BF-J attempts — the step order is identical to the
    reference engine's per-server ``while`` nest, but no step is wasted on a
    failed probe.

    Residuals are maintained incrementally yet exactly: a placement
    recomputes the target server's residual as ``1 - row.sum()`` over the
    slot-ordered row, the same expression the reference engine evaluates, so
    trajectories bit-match (as long as ``truncated`` stays 0).

    Streams carrying a fault plane (``streams.up is not None``) run the
    fault-injected variant: down servers evict their jobs (``_preempt_grid``
    — requeue under the ``max_requeue`` bound, lost past it), leave every
    placement-feasibility mask, and rejoin the BF-S freed set on recovery.
    Fault-free streams compile to exactly the historical program.

    ``state=`` / ``return_state=True`` thread the complete scan carry for
    crash-safe chunked sweeps (DESIGN.md §9): running the horizon in slices,
    feeding each slice the previous slice's returned state, reproduces the
    straight-through trajectory bit-for-bit.  Per-chunk ``departed`` restarts
    from 0 (the chunked driver offsets it); the scalar counters accumulate
    inside the carry.
    """
    horizon = streams.n.shape[0]
    faulted = streams.up is not None
    W = resolve_work_steps(work_steps, A_max)
    D = L * K + A_max
    _check_sequential_durs(streams, L, K, A_max)
    a_iota = jnp.arange(A_max)
    l_iota = jnp.arange(L)
    q_iota = jnp.arange(Qcap)
    k_iota = jnp.arange(K)

    def slot_step(state, inp):
        (srv, dep, queue, t, q_cnt, dropped, trunc,
         qtry, tries, preempted, requeued, lost, up_last) = state
        if faulted:
            n, sizes, durs, up_t = inp
        else:
            n, sizes, durs = inp

        # 1. departures
        leaving = dep == t
        freed = leaving.any(axis=1)
        n_dep = leaving.sum()
        srv = jnp.where(leaving, 0.0, srv)
        dep = jnp.where(leaving, INF_SLOT, dep)

        # 1b. capacity shocks: evict jobs resident on down servers
        # (requeue under the retry bound, lose the rest), drop down servers
        # from every placement mask, and treat recoveries as freed.
        if faulted:
            tries = jnp.where(leaving, 0, tries)
            srv, dep, tries, queue, qtry, n_p, n_r, n_l = _preempt_grid(
                srv, dep, tries, queue, qtry, up_t, max_requeue)
            preempted = preempted + n_p
            requeued = requeued + n_r
            lost = lost + n_l
            q_cnt = q_cnt + n_r
            freed = (freed | (up_t & ~up_last)) & up_t
            up_last = up_t
        resid = 1.0 - srv.sum(axis=1)

        # 2. arrivals -> first empty queue slots (record where they landed)
        n_empty = jnp.cumsum((queue == 0.0).astype(jnp.int32))
        pos_a = jnp.searchsorted(n_empty, a_iota + 1)  # a-th empty index
        landed = (a_iota < n) & (pos_a < Qcap)
        n_landed = landed.sum()
        dropped = dropped + n - n_landed
        q_cnt = q_cnt + n_landed
        queue = queue.at[jnp.where(landed, pos_a, Qcap)].set(
            jnp.where(landed, sizes, 0.0), mode="drop")
        new_pos = jnp.where(landed, pos_a, -1)
        # landed arrival indices, compacted ascending (for BF-J dispatch),
        # with their duration-stream entries pre-gathered.
        rank = jnp.cumsum(landed.astype(jnp.int32)) - 1
        landed_list = jnp.full((A_max,), A_max - 1, jnp.int32).at[
            jnp.where(landed, rank, A_max)].set(a_iota.astype(jnp.int32),
                                                mode="drop")
        pos_list = new_pos[landed_list]
        dur_list = durs[L * K + landed_list]

        # 3+4. BF-S then BF-J as one bounded, unrolled placement work list.
        # Index extraction uses min-of-masked-iota instead of argmax/argmin
        # (same first-index tie-breaks, but plain min/max reductions
        # vectorize on CPU where XLA's variadic arg-reduce does not).
        def work(carry):
            srv, dep, queue, qtry, tries, resid, q_cnt, dc, a_ptr = carry
            occupied = queue > 0.0
            qmin = jnp.min(jnp.where(occupied, queue, jnp.inf))
            fits = freed & (resid >= qmin)

            # BF-S candidate: largest fitting job for the lowest-index
            # freed server that still has one.
            cur = jnp.min(jnp.where(fits, l_iota, L))
            any_bfs = cur < L
            cur = jnp.minimum(cur, L - 1)
            fitq = jnp.where(occupied & (queue <= resid[cur]), queue,
                             -jnp.inf)
            size_bfs = jnp.max(fitq)
            j_bfs = jnp.min(jnp.where(fitq == size_bfs, q_iota, Qcap))
            j_bfs = jnp.minimum(j_bfs, Qcap - 1)

            # BF-J candidate: next landed arrival (one attempt each, in
            # arrival order, even if BF-S already consumed its job).
            is_bfj = (~any_bfs) & (a_ptr < n_landed)
            ap = jnp.minimum(a_ptr, A_max - 1)
            pos = pos_list[ap]
            size_bfj = queue[jnp.maximum(pos, 0)]
            feas = resid >= size_bfj
            if faulted:
                feas = feas & up_t
            masked_r = jnp.where(feas, resid, jnp.inf)
            best_r = jnp.min(masked_r)
            s_bfj = jnp.min(jnp.where(masked_r == best_r, l_iota, L))
            s_bfj = jnp.minimum(s_bfj, L - 1)
            ok_bfj = is_bfj & (best_r < jnp.inf) & (size_bfj > 0)

            do = any_bfs | ok_bfj
            tgt = jnp.where(any_bfs, cur, s_bfj)
            qidx = jnp.where(do, jnp.where(any_bfs, j_bfs,
                                           jnp.maximum(pos, 0)), Qcap)
            size = jnp.where(any_bfs, size_bfs, size_bfj)
            dur = jnp.where(any_bfs, durs[jnp.minimum(dc, D - 1)],
                            dur_list[ap])

            row = srv[tgt]
            slot = jnp.min(jnp.where(row == 0.0, k_iota, K))
            slot = jnp.where(slot == K, 0, slot)  # row full: reference
            slot_w = jnp.where(do, slot, K)       # engine overwrites slot 0
            new_row = row.at[slot_w].set(size, mode="drop")
            srv = srv.at[tgt].set(new_row)
            dep = dep.at[tgt].set(
                dep[tgt].at[slot_w].set(t + dur, mode="drop"))
            if faulted:
                # retry count rides with the job: queue slot -> server slot
                tr = qtry[jnp.minimum(qidx, Qcap - 1)]
                tries = tries.at[tgt].set(
                    tries[tgt].at[slot_w].set(tr, mode="drop"))
                qtry = qtry.at[qidx].set(0, mode="drop")
            queue = queue.at[qidx].set(0.0, mode="drop")
            resid = resid.at[jnp.where(do, tgt, L)].set(
                1.0 - new_row.sum(), mode="drop")
            q_cnt = q_cnt - do.astype(jnp.int32)
            dc = dc + any_bfs.astype(jnp.int32)
            a_ptr = a_ptr + is_bfj.astype(jnp.int32)
            return srv, dep, queue, qtry, tries, resid, q_cnt, dc, a_ptr

        zero = jnp.zeros((), jnp.int32)
        carry = (srv, dep, queue, qtry, tries, resid, q_cnt, zero, zero)
        for _ in range(W):
            carry = work(carry)
        srv, dep, queue, qtry, tries, resid, q_cnt, _, a_ptr = carry

        # saturation check: a placement the reference engine would have made
        # is still possible => the bounded list diverged this slot.  (Missed
        # BF-J attempts whose job was already consumed, or whose job fits no
        # server, are no-ops in the reference engine too — not divergence.)
        qmin = jnp.min(jnp.where(queue > 0.0, queue, jnp.inf))
        pend_bfs = (freed & (resid >= qmin)).any()
        left = (a_iota >= a_ptr) & (a_iota < n_landed)
        sz_left = queue[jnp.maximum(pos_list, 0)]
        cap_max = jnp.max(jnp.where(up_t, resid, -jnp.inf)) if faulted \
            else resid.max()
        pend_bfj = (left & (sz_left > 0) & (sz_left <= cap_max)).any()
        trunc = trunc + (pend_bfs | pend_bfj).astype(jnp.int32)

        out = (q_cnt, srv.sum(), n_dep.astype(jnp.int32))
        return (srv, dep, queue, t + 1, q_cnt, dropped, trunc,
                qtry, tries, preempted, requeued, lost, up_last), out

    if state is None:
        zero = jnp.zeros((), jnp.int32)
        state = (
            jnp.zeros((L, K), jnp.float32),
            jnp.full((L, K), INF_SLOT, jnp.int32),
            jnp.zeros(Qcap, jnp.float32),
            zero,                          # t
            zero,                          # q_cnt
            zero,                          # dropped
            zero,                          # trunc
            jnp.zeros(Qcap, jnp.int32),    # qtry
            jnp.zeros((L, K), jnp.int32),  # tries
            zero,                          # preempted
            zero,                          # requeued
            zero,                          # lost
            jnp.ones((L,), bool),          # up_last
        )
    xs = (streams.n, streams.sizes, streams.durs)
    if faulted:
        xs = xs + (streams.up,)
    state, (qlen, occ, ndep) = jax.lax.scan(slot_step, state, xs)
    res = PolicyResult(qlen, occ, jnp.cumsum(ndep), state[5], state[6],
                       state[9], state[10], state[11])
    return (res, state) if return_state else res


@functools.partial(
    jax.jit,
    static_argnames=("sampler", "L", "K", "Qcap", "A_max", "horizon",
                     "fault_rate", "repair_rate", "max_requeue"),
)
def _run_bfjs_reference(key: jax.Array,
                        lam: float,
                        mu: float,
                        sampler: Callable[[jax.Array, int], jax.Array],
                        L: int = 8,
                        K: int = 16,
                        Qcap: int = 512,
                        A_max: int = 8,
                        horizon: int = 10_000,
                        fault_rate: float = 0.0,
                        repair_rate: float = 1.0,
                        max_requeue: int = DEFAULT_MAX_REQUEUE
                        ) -> PolicyResult:
    """The original nested fori/while/cond slot engine (behavioural oracle).

    Serial and branch-heavy — kept verbatim for equivalence testing and as
    the baseline of benchmarks/sched_micro.py.

    ``fault_rate > 0`` runs the fault-injected variant: the oracle
    regenerates the exact ``make_fault_plane`` the scan engine's streams
    carry (same key, same fold) and applies the shared ``_preempt_grid``
    eviction between departures and arrivals, so faulted trajectories stay
    bit-matched engine-to-engine.
    """
    from .ops import best_fit_server, largest_fitting_job

    faulted = fault_rate > 0.0

    def place_in_server(srv_i, dep_i, size, dslot):
        slot = jnp.argmax(srv_i == 0.0)
        return srv_i.at[slot].set(size), dep_i.at[slot].set(dslot), slot

    def slot_step(state: BFJSState, inp):
        (srv, dep, queue, dropped, key, qtry, tries,
         preempted, requeued, lost, up_last) = state
        if faulted:
            t, up_t = inp
        else:
            t = inp
        key, k_arr, k_n, k_sizes, k_dur = jax.random.split(key, 5)

        # 1. departures
        leaving = dep == t
        freed = leaving.any(axis=1)
        n_dep = leaving.sum()
        srv = jnp.where(leaving, 0.0, srv)
        dep = jnp.where(leaving, INF_SLOT, dep)

        # 1b. capacity shocks (identical rule to the scan engine: the
        # shared _preempt_grid, then recovered servers count as freed and
        # down servers leave every feasibility mask).
        if faulted:
            tries = jnp.where(leaving, 0, tries)
            srv, dep, tries, queue, qtry, n_p, n_r, n_l = _preempt_grid(
                srv, dep, tries, queue, qtry, up_t, max_requeue)
            preempted = preempted + n_p
            requeued = requeued + n_r
            lost = lost + n_l
            freed = (freed | (up_t & ~up_last)) & up_t
            up_last = up_t

        # 2. arrivals -> queue (record the slots they landed in)
        n = jnp.minimum(jax.random.poisson(k_n, lam), A_max)
        sizes = sampler(k_sizes, A_max)
        valid = jnp.arange(A_max) < n
        empty_slots = jnp.nonzero(queue == 0.0, size=A_max, fill_value=Qcap)[0]
        landed = valid & (empty_slots < Qcap)
        dropped = dropped + (valid & ~landed).sum()
        queue = queue.at[jnp.where(landed, empty_slots, Qcap)].set(
            jnp.where(landed, sizes, 0.0), mode="drop")
        new_pos = jnp.where(landed, empty_slots, -1)

        durs = _geometric(k_dur, mu, (L * K + A_max,))
        dcounter = 0

        # 3. BF-S over freed servers: fill each with the largest fitting job.
        def bfs_server(i, carry):
            srv, dep, queue, qtry, tries, dc = carry

            def try_place(carry):
                srv, dep, queue, qtry, tries, dc, go = carry
                resid = 1.0 - srv[i].sum()
                j = largest_fitting_job(queue, resid)
                ok = j >= 0

                def do(args):
                    srv, dep, queue, qtry, tries, dc = args
                    size = queue[j]
                    s_i, d_i, slot = place_in_server(srv[i], dep[i], size,
                                                     t + durs[dc])
                    if faulted:
                        tries = tries.at[i, slot].set(qtry[j])
                        qtry = qtry.at[j].set(0)
                    return (srv.at[i].set(s_i), dep.at[i].set(d_i),
                            queue.at[j].set(0.0), qtry, tries, dc + 1)

                srv, dep, queue, qtry, tries, dc = jax.lax.cond(
                    ok, do, lambda a: a, (srv, dep, queue, qtry, tries, dc))
                return srv, dep, queue, qtry, tries, dc, ok

            def fill(carry):
                srv, dep, queue, qtry, tries, dc = carry
                out = jax.lax.while_loop(
                    lambda c: c[6],
                    try_place,
                    (srv, dep, queue, qtry, tries, dc, True))
                return out[:6]

            return jax.lax.cond(freed[i], fill, lambda c: c,
                                (srv, dep, queue, qtry, tries, dc))

        srv, dep, queue, qtry, tries, dcounter = jax.lax.fori_loop(
            0, L, bfs_server, (srv, dep, queue, qtry, tries, dcounter))

        # 4. BF-J over the new arrivals still in queue.
        def bfj_job(a, carry):
            srv, dep, queue, qtry, tries, dc = carry
            pos = new_pos[a]
            size = jnp.where(pos >= 0, queue[jnp.maximum(pos, 0)], 0.0)
            resid = 1.0 - srv.sum(axis=1)
            if faulted:
                resid = jnp.where(up_t, resid, -jnp.inf)
            s_idx = best_fit_server(resid, jnp.where(size > 0, size, jnp.inf))
            ok = (size > 0) & (s_idx >= 0)

            def do(args):
                srv, dep, queue, qtry, tries, dc = args
                s_i, d_i, slot = place_in_server(srv[s_idx], dep[s_idx], size,
                                                 t + durs[L * K + a])
                if faulted:
                    tries = tries.at[s_idx, slot].set(qtry[jnp.maximum(pos, 0)])
                    qtry = qtry.at[jnp.maximum(pos, 0)].set(0)
                return (srv.at[s_idx].set(s_i), dep.at[s_idx].set(d_i),
                        queue.at[pos].set(0.0), qtry, tries, dc)

            return jax.lax.cond(ok, do, lambda x: x,
                                (srv, dep, queue, qtry, tries, dc))

        srv, dep, queue, qtry, tries, dcounter = jax.lax.fori_loop(
            0, A_max, bfj_job, (srv, dep, queue, qtry, tries, dcounter))

        out = (
            (queue > 0).sum().astype(jnp.int32),
            srv.sum(),
            n_dep.astype(jnp.int32),
        )
        return BFJSState(srv, dep, queue, dropped, key, qtry, tries,
                         preempted, requeued, lost, up_last), out

    zero = jnp.zeros((), jnp.int32)
    state0 = BFJSState(
        srv=jnp.zeros((L, K), jnp.float32),
        dep=jnp.full((L, K), INF_SLOT, jnp.int32),
        queue=jnp.zeros(Qcap, jnp.float32),
        dropped=zero,
        key=key,
        qtry=jnp.zeros(Qcap, jnp.int32),
        tries=jnp.zeros((L, K), jnp.int32),
        preempted=zero,
        requeued=zero,
        lost=zero,
        up_last=jnp.ones((L,), bool),
    )
    ts = jnp.arange(horizon, dtype=jnp.int32)
    xs = (ts, make_fault_plane(key, L=L, horizon=horizon,
                               fault_rate=fault_rate,
                               repair_rate=repair_rate)) if faulted else ts
    state, (qlen, occ, ndep) = jax.lax.scan(slot_step, state0, xs)
    return PolicyResult(qlen, occ, jnp.cumsum(ndep), state.dropped,
                        jnp.zeros((), jnp.int32), state.preempted,
                        state.requeued, state.lost)


def run_bfjs(key: jax.Array,
             lam: float,
             mu: float,
             sampler: Callable[[jax.Array, int], jax.Array],
             L: int = 8,
             K: int = 16,
             Qcap: int = 512,
             A_max: int = 8,
             horizon: int = 10_000,
             engine: str = "scan",
             work_steps: int | None = None,
             window: int | None = None,
             fault_rate: float = 0.0,
             repair_rate: float = 1.0,
             max_requeue: int = DEFAULT_MAX_REQUEUE) -> PolicyResult:
    """Simulate BF-J/S on L unit-capacity servers for `horizon` slots.

    sampler(key, n) -> (n,) float sizes in (0,1].  vmap over `key` for
    Monte-Carlo ensembles (or use monte_carlo_bfjs, which also knows the
    gridded Pallas engine).

    engine: "scan" (branch-free, default) | "reference" (original nested
    loop oracle) | "pallas" (fused kernels/bfjs slot-step kernel).

    ``fault_rate > 0`` injects per-slot server capacity shocks
    (``make_fault_plane``): down servers evict their jobs, which requeue up
    to ``max_requeue`` times and are counted ``lost`` past that — reported
    in the result's ``preempted/requeued/lost`` counters, identically on
    every engine.
    """
    if engine == "reference":
        return _run_bfjs_reference(key, lam, mu, sampler, L=L, K=K, Qcap=Qcap,
                                   A_max=A_max, horizon=horizon,
                                   fault_rate=fault_rate,
                                   repair_rate=repair_rate,
                                   max_requeue=max_requeue)
    streams = make_streams(key, lam, mu, sampler, L=L, K=K, A_max=A_max,
                           horizon=horizon, fault_rate=fault_rate,
                           repair_rate=repair_rate)
    return run_bfjs_trace(streams, L=L, K=K, Qcap=Qcap, A_max=A_max,
                          engine=engine, work_steps=work_steps,
                          window=window, max_requeue=max_requeue)


def run_bfjs_trace(streams: SchedStreams, *, L: int, K: int, Qcap: int,
                   A_max: int, engine: str = "scan",
                   work_steps: int | None = None,
                   window: int | None = None,
                   max_requeue: int = DEFAULT_MAX_REQUEUE,
                   strict: bool = False) -> PolicyResult:
    """Run one BF-J/S simulation over explicit streams (make_streams-shaped;
    trace-built streams are rejected — see _check_sequential_durs).
    ``window`` is the Pallas kernel's VMEM time-window length (must divide
    the horizon; ignored by the other engines)."""
    _check_sequential_durs(streams, L, K, A_max)
    if engine == "reference":
        raise ValueError(
            "bfjs has no stream-driven reference engine: its oracle draws "
            "RNG in-loop from a key.  Use engine=\"scan\"/\"pallas\" on "
            "streams, or run_bfjs(key, ..., engine=\"reference\").")
    if engine == "scan":
        return run_bfjs_streams(streams, L=L, K=K, Qcap=Qcap, A_max=A_max,
                                work_steps=work_steps,
                                max_requeue=max_requeue)
    if engine == "pallas":
        from repro.kernels.bfjs.ops import bfjs_scratch_bytes, bfjs_simulate
        from repro.kernels.common import ensemble_plane_bytes, pallas_precheck
        T, D = streams.n.shape[0], streams.durs.shape[-1]
        if not pallas_precheck(
                "bfjs", nbytes=bfjs_scratch_bytes(L, K, Qcap, A_max),
                hbm_bytes=ensemble_plane_bytes(
                    1, T, stream_lanes=1 + A_max + D, out_lanes=3),
                fault_plane=streams.up is not None, strict=strict):
            return run_bfjs_streams(streams, L=L, K=K, Qcap=Qcap,
                                    A_max=A_max, work_steps=work_steps,
                                    max_requeue=max_requeue)
        batched = jax.tree.map(lambda x: x[None], streams)
        res = bfjs_simulate(batched, L=L, K=K, Qcap=Qcap, A_max=A_max,
                            work_steps=work_steps, window=window)
        return jax.tree.map(lambda x: x[0], res)
    raise ValueError(f"unknown engine {engine!r}")


def run_bfjs_workload(workload, key: jax.Array, *, engine: str = "scan",
                      **config) -> PolicyResult:
    """Workload-first adapter: the registry entry behind
    ``run_policy(workload, policy="bfjs", ...)``.  BF-J/S is
    single-resource with unit servers; vector workloads are rejected
    loudly (use ``policy="bfjs-mr"``)."""
    workload.require_scalar("bfjs")
    workload.check_sampler()
    return run_bfjs(key, workload.lam, workload.mu, workload.sampler,
                    engine=engine, **config)


def monte_carlo_bfjs_workload(workload, keys: jax.Array, *,
                              engine: str = "scan", **config) -> PolicyResult:
    """Workload-first adapter for ``monte_carlo_policy(policy="bfjs")``."""
    workload.require_scalar("bfjs")
    workload.check_sampler()
    return monte_carlo_bfjs(keys, workload.lam, workload.mu,
                            workload.sampler, engine=engine, **config)


def monte_carlo_bfjs(keys: jax.Array, lam: float, mu: float, sampler,
                     engine: str = "scan", work_steps: int | None = None,
                     window: int | None = None,
                     L: int = 8, K: int = 16, Qcap: int = 512,
                     A_max: int = 8, horizon: int = 10_000,
                     fault_rate: float = 0.0, repair_rate: float = 1.0,
                     max_requeue: int = DEFAULT_MAX_REQUEUE,
                     strict: bool = False) -> PolicyResult:
    """One simulated cluster per key.

    "scan"/"reference" vmap run_bfjs over the keys; "pallas" pre-generates
    every ensemble member's streams and runs the fused kernel with the
    ensemble as the kernel grid (one independent cluster per program
    instance)."""
    if engine == "pallas":
        from repro.kernels.bfjs.ops import bfjs_scratch_bytes, bfjs_simulate
        from repro.kernels.common import ensemble_plane_bytes, pallas_precheck
        # keys is the LOCAL batch here: under a sharded mesh launch
        # (core.engine.sharding) each device traces with its G/D shard, so
        # this footprint check is naturally per device.
        G = int(keys.shape[0])
        if not pallas_precheck(
                "bfjs", nbytes=bfjs_scratch_bytes(L, K, Qcap, A_max),
                hbm_bytes=ensemble_plane_bytes(
                    G, horizon, stream_lanes=1 + A_max + (L * K + A_max),
                    out_lanes=3),
                fault_plane=fault_rate > 0.0, strict=strict):
            engine = "scan"
        else:
            streams = jax.vmap(
                lambda k: make_streams(k, lam, mu, sampler, L=L, K=K,
                                       A_max=A_max, horizon=horizon))(keys)
            return bfjs_simulate(streams, L=L, K=K, Qcap=Qcap, A_max=A_max,
                                 work_steps=work_steps, window=window)
    fn = functools.partial(run_bfjs, lam=lam, mu=mu, sampler=sampler,
                           engine=engine, work_steps=work_steps, L=L, K=K,
                           Qcap=Qcap, A_max=A_max, horizon=horizon,
                           fault_rate=fault_rate, repair_rate=repair_rate,
                           max_requeue=max_requeue)
    return jax.vmap(fn)(keys)
