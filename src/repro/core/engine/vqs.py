"""VQS accelerator engines (paper Section V, Theorem 3: >= 2/3 rho*).

Re-expresses the event-driven ``core/vqs.py`` scheduler as fixed-shape JAX
programs that share the ``SchedStreams`` stack with the BF-J/S engines:

  * ``engine="reference"`` — a nested ``fori/while/cond`` transcription of
    the numpy scheduler (visit sets, configuration renewal at server-empty
    epochs, head-of-VQ packing, subscription wake-ups), kept as the
    behavioural oracle: on trace-driven streams it reproduces
    ``simulate_trace(VQS(J), ...)`` queue trajectories bit-for-bit;
  * ``engine="scan"``      — the branch-free rewrite: per slot, a bounded
    work list of masked-select steps.  Each step (a) advances past EVERY
    pending visited server that cannot place (their renewals collapse to
    one shared max-weight configuration because the VQ-size vector is
    unchanged between placements, and their subscriptions are pure mask
    writes), then (b) fully serves the first server that can place — the
    head-of-VQ packing loop becomes a prefix-fit over a ``drain``-wide
    window of consecutive ring entries, so one step can pack a whole
    server.  Steps therefore scale with *placing* visits, not visits;
  * ``engine="pallas"``    — the fused kernel in ``kernels/vqs`` (rings,
    configurations and subscriptions resident in VMEM; the Monte-Carlo
    ensemble is the kernel grid).

All capacity arithmetic is exact integer math on the ``quantize.RES`` grid
(the same grid the event-driven engine uses), so "bit-match" is equality of
integer trajectories — no float tolerance anywhere.

Fixed-shape deviations (counted, never silent):

  * each virtual queue is a ``Qcap``-entry ring; arrivals that overflow
    their ring are dropped and counted (``dropped``);
  * each server holds at most ``K`` jobs; a placement the paper's unbounded
    model would make onto a full server is counted in ``truncated``
    (choose ``K >= 2**J`` to make this impossible);
  * a slot that needs more than ``work_steps`` placing servers is finished
    lazily (remaining placements postponed to later wake-ups) and counted
    in ``truncated``.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from ..quantize import RES, TWO_THIRDS
from .bfjs import DEFAULT_MAX_REQUEUE
from .ops import k_red_jnp, vq_type_of_grid
from .streams import (INF_SLOT, PolicyResult, SchedStreams, make_streams,
                      resolve_work_steps)

CAP = RES             # unit server capacity on the grid
RESERVE = TWO_THIRDS  # (2*CAP + 1) // 3, the paper's VQ_1 reservation


def _default_drain(K: int, J: int) -> int:
    # widest useful packing burst: a server cannot hold more than K jobs,
    # nor more than 2**J of the smallest effective size CAP >> J.
    return max(1, min(K, 1 << J, 16))


def _decode_config(row: jax.Array, J: int) -> tuple[jax.Array, jax.Array]:
    """(k1, jstar) of a K_RED row — jstar is the first nonzero type != 1
    (-1 if none), replicating ``VQS._set_config``."""
    j_iota = jnp.arange(2 * J)
    k1 = row[1] > 0
    js = jnp.min(jnp.where((row > 0) & (j_iota != 1), j_iota, 2 * J))
    return k1, jnp.where(js == 2 * J, -1, js).astype(jnp.int32)


def _mw_config(confs: jax.Array, qcnt: jax.Array, J: int):
    """First-index max-weight row over K_RED (paper Eq. 8, np.argmax ties)."""
    w = confs @ qcnt
    c_iota = jnp.arange(confs.shape[0])
    i = jnp.min(jnp.where(w == w.max(), c_iota, confs.shape[0]))
    row = confs[jnp.minimum(i, confs.shape[0] - 1)]
    return _decode_config(row, J)


def _push_arrivals(ring_eff, ring_dur, head, qcnt, dropped,
                   n_t, sizes_t, durs_t, *, J, Qcap, A_max, ring_try=None):
    """Classify + enqueue one slot's arrivals (vectorized, order-exact).

    Durations come from the LAST ``A_max`` lanes of the duration stream —
    the per-arrival lanes shared by make_streams (full-width) and
    streams_from_trace (lanes only), so a job's duration always travels
    with the job.  Returns updated rings/counts plus the ``arrived`` type
    mask that drives subscription wake-ups (all sampled arrivals wake, as
    in the numpy engine — a dropped arrival already flags the run via
    ``dropped``).

    On fault-injected runs the rings additionally carry a retry-count plane
    (``ring_try``, written by ``_preempt_rings``); fresh arrivals zero their
    entry so a ring slot's count always belongs to the job stored there.
    """
    nvq = 2 * J
    a_iota = jnp.arange(A_max)
    j_iota = jnp.arange(nvq)
    dur_off = durs_t.shape[0] - A_max
    g = jnp.maximum(jnp.round(sizes_t * RES), 1.0).astype(jnp.int32)
    vq = vq_type_of_grid(g, J)
    eff = jnp.where(vq == nvq - 1, jnp.maximum(g, RES >> J), g)
    valid = a_iota < n_t
    oh = (vq[:, None] == j_iota[None, :]) & valid[:, None]      # (A, 2J)
    rank = ((jnp.cumsum(oh.astype(jnp.int32), axis=0) - 1) * oh).sum(1)
    cnt_own = (oh * qcnt[None, :]).sum(1)
    head_own = (oh * head[None, :]).sum(1)
    land = valid & (cnt_own + rank < Qcap)
    pos = (head_own + cnt_own + rank) % Qcap
    vq_w = jnp.where(land, vq, nvq)
    ring_eff = ring_eff.at[vq_w, pos].set(eff, mode="drop")
    ring_dur = ring_dur.at[vq_w, pos].set(durs_t[dur_off + a_iota],
                                          mode="drop")
    if ring_try is not None:
        ring_try = ring_try.at[vq_w, pos].set(0, mode="drop")
    qcnt = qcnt + (oh & land[:, None]).sum(0).astype(jnp.int32)
    dropped = dropped + (valid & ~land).sum()
    arrived = oh.any(0)
    return ring_eff, ring_dur, head, qcnt, dropped, arrived, ring_try


def _preempt_rings(srv, dep, vqof, ring_eff, ring_dur, ring_try, head, qcnt,
                   srv_try, up_t, t, max_requeue, *, J, Qcap):
    """Evict every job resident on a down server (DESIGN.md §9), VQS form.

    Shared verbatim by the scan engine and the reference oracle.  Victims
    below the retry bound re-enter the TAIL of their own virtual queue in
    row-major ``(server, k-slot)`` order — the same one-hot tail-append rule
    as ``_push_arrivals`` — with their REMAINING duration ``dep - t`` and
    ``tries + 1``; victims past the bound (or whose ring is full) are lost.
    Returns the updated planes, the slot's ``(n_preempted, n_requeued,
    n_lost)`` counts, and the ``re_arrived`` type mask of rings that
    received a requeue (it wakes subscribers exactly like an arrival).
    """
    nvq = 2 * J
    j_iota = jnp.arange(nvq)
    victim = (~up_t)[:, None] & (srv > 0)                       # (L, K)
    elig = (victim & (srv_try < max_requeue)).reshape(-1)       # (L*K,)
    vq = jnp.where(elig, vqof.reshape(-1), nvq)
    oh = vq[:, None] == j_iota[None, :]                         # (L*K, 2J)
    rank = ((jnp.cumsum(oh.astype(jnp.int32), axis=0) - 1) * oh).sum(1)
    cnt_own = (oh * qcnt[None, :]).sum(1)
    head_own = (oh * head[None, :]).sum(1)
    land = elig & (cnt_own + rank < Qcap)
    pos = (head_own + cnt_own + rank) % Qcap
    vq_w = jnp.where(land, vq, nvq)
    rem = jnp.maximum(dep.reshape(-1) - t, 1)   # remaining service slots
    ring_eff = ring_eff.at[vq_w, pos].set(srv.reshape(-1), mode="drop")
    ring_dur = ring_dur.at[vq_w, pos].set(rem, mode="drop")
    ring_try = ring_try.at[vq_w, pos].set(srv_try.reshape(-1) + 1,
                                          mode="drop")
    qcnt = qcnt + (oh & land[:, None]).sum(0).astype(jnp.int32)
    re_arrived = (oh & land[:, None]).any(0)
    n_vict = victim.sum().astype(jnp.int32)
    n_req = land.sum().astype(jnp.int32)
    srv = jnp.where(victim, 0, srv)
    dep = jnp.where(victim, INF_SLOT, dep)
    vqof = jnp.where(victim, -1, vqof)
    srv_try = jnp.where(victim, 0, srv_try)
    return (srv, dep, vqof, ring_eff, ring_dur, ring_try, head, qcnt,
            srv_try, n_vict, n_req, n_vict - n_req, re_arrived)


@functools.partial(
    jax.jit, static_argnames=("J", "L", "K", "Qcap", "A_max", "max_requeue"))
def _run_vqs_reference_streams(streams: SchedStreams, J: int, L: int, K: int,
                               Qcap: int, A_max: int,
                               max_requeue: int = DEFAULT_MAX_REQUEUE
                               ) -> PolicyResult:
    """Nested-loop VQS oracle over pre-generated streams.

    A control-flow-faithful transcription of ``core/vqs.py`` +
    ``core/simulator.py``: sorted visit order via ``fori`` over servers,
    per-server renewal ``cond``, single-job VQ_1 step, head-of-VQ ``while``
    packing, subscription sets as a boolean (L, 2J) matrix.  Serial and
    branch-heavy — the behavioural anchor the scan engine is tested
    against (and, through trace streams, the bridge to the numpy engine).

    Streams carrying a fault plane run the fault-injected variant through
    the shared ``_preempt_rings`` rule, bit-matched with the scan engine.
    """
    horizon = streams.n.shape[0]
    nvq = 2 * J
    confs = k_red_jnp(J)
    k_iota = jnp.arange(K)
    faulted = streams.up is not None

    def slot_step(state, inp):
        (srv, dep, vqof, ring_eff, ring_dur, head, qcnt,
         cfg_k1, cfg_js, has_cfg, in_empty, want, t, dropped, trunc,
         ring_try, srv_try, preempted, requeued, lost, up_last) = state
        if faulted:
            n_t, sizes_t, durs_t, up_t = inp
        else:
            n_t, sizes_t, durs_t = inp

        # 1. departures
        leaving = dep == t
        freed = leaving.any(axis=1)
        n_dep = leaving.sum()
        srv = jnp.where(leaving, 0, srv)
        vqof = jnp.where(leaving, -1, vqof)
        dep = jnp.where(leaving, INF_SLOT, dep)

        # 1b. capacity shocks: evict down servers into the VQ tails
        # (shared _preempt_rings rule), recoveries count as freed, down
        # servers leave the visit set.
        re_arrived = None
        if faulted:
            srv_try = jnp.where(leaving, 0, srv_try)
            (srv, dep, vqof, ring_eff, ring_dur, ring_try, head, qcnt,
             srv_try, n_p, n_r, n_l, re_arrived) = _preempt_rings(
                srv, dep, vqof, ring_eff, ring_dur, ring_try, head, qcnt,
                srv_try, up_t, t, max_requeue, J=J, Qcap=Qcap)
            preempted = preempted + n_p
            requeued = requeued + n_r
            lost = lost + n_l
            freed = (freed | (up_t & ~up_last)) & up_t
            up_last = up_t
        empty_now = (srv > 0).sum(axis=1) == 0

        # 2. arrivals
        (ring_eff, ring_dur, head, qcnt, dropped, arrived,
         rt) = _push_arrivals(
            ring_eff, ring_dur, head, qcnt, dropped, n_t, sizes_t, durs_t,
            J=J, Qcap=Qcap, A_max=A_max,
            ring_try=ring_try if faulted else None)
        if faulted:
            ring_try = rt
            arrived = arrived | re_arrived

        # 3. visit set (freed + woken subscribers + empty-with-work)
        woken = (want & arrived[None, :]).any(axis=1)
        want = want & ~arrived[None, :]
        visit = freed | woken | (in_empty & (qcnt.sum() > 0))
        if faulted:
            visit = visit & up_t

        def place_one(i, j, carry):
            srv, dep, vqof, head, qcnt, in_empty, srv_try, trunc = carry
            pos = head[j] % Qcap
            eff_p = ring_eff[j, pos]
            dur_p = ring_dur[j, pos]
            head = head.at[j].add(1)
            qcnt = qcnt.at[j].add(-1)
            row = srv[i]
            slot = jnp.min(jnp.where(row == 0, k_iota, K))
            ok = slot < K
            kw = jnp.minimum(slot, K - 1)
            kw = jnp.where(ok, kw, K)
            srv = srv.at[i, kw].set(eff_p, mode="drop")
            dep = dep.at[i, kw].set(t + dur_p, mode="drop")
            vqof = vqof.at[i, kw].set(j, mode="drop")
            if faulted:  # retry count rides with the job
                srv_try = srv_try.at[i, kw].set(ring_try[j, pos],
                                                mode="drop")
            trunc = trunc + (~ok).astype(jnp.int32)
            in_empty = in_empty.at[i].set(False)
            return srv, dep, vqof, head, qcnt, in_empty, srv_try, trunc

        # 4. serve visited servers in ascending order
        def visit_server(i, carry):
            def serve(carry):
                (srv, dep, vqof, head, qcnt,
                 cfg_k1, cfg_js, has_cfg, in_empty, want, srv_try,
                 trunc) = carry
                need = empty_now[i] | ~has_cfg[i]
                r_k1, r_js = _mw_config(confs, qcnt, J)
                k1 = jnp.where(need, r_k1, cfg_k1[i])
                js = jnp.where(need, r_js, cfg_js[i])
                cfg_k1 = cfg_k1.at[i].set(k1)
                cfg_js = cfg_js.at[i].set(js)
                has_cfg = has_cfg.at[i].set(True)
                in_empty = in_empty.at[i].set(in_empty[i] | empty_now[i])

                # (i) one VQ_1 job into the 2/3 reservation when missing
                resid = CAP - srv[i].sum()
                has_vq1 = ((vqof[i] == 1) & (srv[i] > 0)).any()
                ex1 = qcnt[1] > 0
                he1 = ring_eff[1, head[1] % Qcap]
                do1 = k1 & ~has_vq1 & ex1 & (he1 <= resid)
                want = want.at[i, 1].set(want[i, 1] | (k1 & ~has_vq1 & ~ex1))
                pl = (srv, dep, vqof, head, qcnt, in_empty, srv_try, trunc)
                pl = jax.lax.cond(do1, lambda c: place_one(i, 1, c),
                                  lambda c: c, pl)
                srv, dep, vqof, head, qcnt, in_empty, srv_try, trunc = pl

                # (ii) head-of-VQ_{j*} packing into the unreserved capacity
                other_cap = jnp.where(k1, CAP - RESERVE, CAP)
                jsx = jnp.maximum(js, 0)

                def jcond(c):
                    srv, dep, vqof, head, qcnt, in_empty, srv_try, trunc = c
                    ex = qcnt[jsx] > 0
                    he = ring_eff[jsx, head[jsx] % Qcap]
                    vq1_occ = (srv[i] * (vqof[i] == 1)).sum()
                    other_occ = srv[i].sum() - vq1_occ
                    return (js >= 0) & ex & (other_occ + he <= other_cap)

                pl = (srv, dep, vqof, head, qcnt, in_empty, srv_try, trunc)
                pl = jax.lax.while_loop(jcond,
                                        lambda c: place_one(i, jsx, c), pl)
                srv, dep, vqof, head, qcnt, in_empty, srv_try, trunc = pl
                sub_j = (js >= 0) & (qcnt[jsx] == 0)
                want = want.at[i, jnp.where(sub_j, jsx, nvq)].set(
                    True, mode="drop")
                return (srv, dep, vqof, head, qcnt,
                        cfg_k1, cfg_js, has_cfg, in_empty, want, srv_try,
                        trunc)

            return jax.lax.cond(visit[i], serve, lambda c: c, carry)

        carry = (srv, dep, vqof, head, qcnt,
                 cfg_k1, cfg_js, has_cfg, in_empty, want, srv_try, trunc)
        carry = jax.lax.fori_loop(0, L, visit_server, carry)
        (srv, dep, vqof, head, qcnt,
         cfg_k1, cfg_js, has_cfg, in_empty, want, srv_try, trunc) = carry

        out = (qcnt.sum().astype(jnp.int32),
               srv.sum().astype(jnp.float32) / RES,
               n_dep.astype(jnp.int32))
        state = (srv, dep, vqof, ring_eff, ring_dur, head, qcnt,
                 cfg_k1, cfg_js, has_cfg, in_empty, want, t + 1,
                 dropped, trunc, ring_try, srv_try, preempted, requeued,
                 lost, up_last)
        return state, out

    state0 = _init_state(J, L, K, Qcap)
    xs = (streams.n, streams.sizes, streams.durs)
    if faulted:
        xs = xs + (streams.up,)
    state, (qlen, occ, ndep) = jax.lax.scan(slot_step, state0, xs)
    return PolicyResult(qlen, occ, jnp.cumsum(ndep), state[13], state[14],
                        state[17], state[18], state[19])


def _init_state(J: int, L: int, K: int, Qcap: int):
    nvq = 2 * J
    zero = jnp.zeros((), jnp.int32)
    return (
        jnp.zeros((L, K), jnp.int32),              # srv (eff sizes)
        jnp.full((L, K), INF_SLOT, jnp.int32),     # dep
        jnp.full((L, K), -1, jnp.int32),           # vqof
        jnp.zeros((nvq, Qcap), jnp.int32),         # ring_eff
        jnp.ones((nvq, Qcap), jnp.int32),          # ring_dur
        jnp.zeros((nvq,), jnp.int32),              # head
        jnp.zeros((nvq,), jnp.int32),              # qcnt
        jnp.zeros((L,), bool),                     # cfg_k1
        jnp.full((L,), -1, jnp.int32),             # cfg_js
        jnp.zeros((L,), bool),                     # has_cfg
        jnp.ones((L,), bool),                      # in_empty (all start empty)
        jnp.zeros((L, nvq), bool),                 # want
        zero, zero, zero,                          # t, dropped, truncated
        # fault-injection planes (indices 15+; zeros/ones when fault-free):
        jnp.zeros((nvq, Qcap), jnp.int32),         # ring_try
        jnp.zeros((L, K), jnp.int32),              # srv_try
        zero, zero, zero,                          # preempted, requeued, lost
        jnp.ones((L,), bool),                      # up_last
    )


@functools.partial(
    jax.jit,
    static_argnames=("J", "L", "K", "Qcap", "A_max", "work_steps", "drain",
                     "max_requeue", "return_state"))
def run_vqs_streams(streams: SchedStreams, J: int, L: int, K: int,
                    Qcap: int, A_max: int, work_steps: int | None = None,
                    drain: int | None = None,
                    max_requeue: int = DEFAULT_MAX_REQUEUE,
                    state: tuple | None = None,
                    return_state: bool = False):
    """Branch-free VQS slot engine over pre-generated streams.

    One ``lax.scan`` over slots; the per-slot serve pass is a work list of
    at most ``work_steps + 1`` masked-select steps (an early-exit bounded
    loop: a slot pays for the placements it performs, not for the bound).
    Each step:

      1. evaluates, for every still-pending visited server, whether it
         could place a job under its effective configuration (its own, or —
         for first-touch renewals — the shared max-weight configuration of
         the CURRENT VQ-size vector, identical for every server touched in
         the same step because only placements change the vector);
      2. advances past all pending servers below the first placer,
         applying their renewals / ``_empty`` membership / subscription
         writes as one vectorized mask update (order-exact: they are
         exactly the servers the numpy engine would have served, with the
         same queue state, before the placer);
      3. serves the placer: either the single reserved VQ_1 placement, or
         a prefix-fit batch of up to ``drain`` consecutive head-of-VQ_{j*}
         jobs (the ``while`` packing loop collapsed into one cumsum);
         the placer stays current until it can no longer place.

    When no pending server can place, the same step degenerates to a pure
    advance pass (placement masks all no-ops) that drains the visit list
    and ends the slot.  A slot that exhausts the step bound with servers
    still unserved increments ``truncated`` (finished lazily — never
    silently wrong).

    Streams carrying a fault plane run the fault-injected variant (shared
    ``_preempt_rings`` eviction, down servers out of the visit set) and
    stay bit-matched with the reference oracle.  ``state=`` /
    ``return_state=True`` thread the complete scan carry for crash-safe
    chunked sweeps (DESIGN.md §9).
    """
    horizon = streams.n.shape[0]
    nvq = 2 * J
    confs = k_red_jnp(J)
    W = resolve_work_steps(work_steps, A_max)
    P = drain if drain is not None else _default_drain(K, J)
    l_iota = jnp.arange(L)
    j_iota = jnp.arange(nvq)
    k_iota = jnp.arange(K)
    p_iota = jnp.arange(P)
    faulted = streams.up is not None

    def slot_step(state, inp):
        (srv, dep, vqof, ring_eff, ring_dur, head, qcnt,
         cfg_k1, cfg_js, has_cfg, in_empty, want, t, dropped, trunc,
         ring_try, srv_try, preempted, requeued, lost, up_last) = state
        if faulted:
            n_t, sizes_t, durs_t, up_t = inp
        else:
            n_t, sizes_t, durs_t = inp

        # 1. departures
        leaving = dep == t
        freed = leaving.any(axis=1)
        n_dep = leaving.sum()
        srv = jnp.where(leaving, 0, srv)
        vqof = jnp.where(leaving, -1, vqof)
        dep = jnp.where(leaving, INF_SLOT, dep)

        # 1b. capacity shocks (identical rule to the reference oracle)
        re_arrived = None
        if faulted:
            srv_try = jnp.where(leaving, 0, srv_try)
            (srv, dep, vqof, ring_eff, ring_dur, ring_try, head, qcnt,
             srv_try, n_p, n_r, n_l, re_arrived) = _preempt_rings(
                srv, dep, vqof, ring_eff, ring_dur, ring_try, head, qcnt,
                srv_try, up_t, t, max_requeue, J=J, Qcap=Qcap)
            preempted = preempted + n_p
            requeued = requeued + n_r
            lost = lost + n_l
            freed = (freed | (up_t & ~up_last)) & up_t
            up_last = up_t
        empty_now = (srv > 0).sum(axis=1) == 0

        # 2. arrivals
        (ring_eff, ring_dur, head, qcnt, dropped, arrived,
         rt) = _push_arrivals(
            ring_eff, ring_dur, head, qcnt, dropped, n_t, sizes_t, durs_t,
            J=J, Qcap=Qcap, A_max=A_max,
            ring_try=ring_try if faulted else None)
        if faulted:
            ring_try = rt
            arrived = arrived | re_arrived

        # 3. visit set
        woken = (want & arrived[None, :]).any(axis=1)
        want = want & ~arrived[None, :]
        visit = freed | woken | (in_empty & (qcnt.sum() > 0))
        if faulted:
            visit = visit & up_t
        renew_needed = visit & (empty_now | ~has_cfg)

        # 4. bounded work list (see module docstring)
        def work(carry):
            (srv, dep, vqof, head, qcnt, cfg_k1, cfg_js, has_cfg,
             in_empty, want, touched, advanced, trunc, n_steps,
             srv_try) = carry
            pending = visit & ~advanced
            hx = qcnt > 0
            head_effs = jnp.take_along_axis(
                ring_eff, (head % Qcap)[:, None], axis=1)[:, 0]

            # shared renewal candidate + per-server effective configuration
            r_k1, r_js = _mw_config(confs, qcnt, J)
            ren = renew_needed & ~touched
            eff_k1 = jnp.where(ren, r_k1, cfg_k1)
            eff_js = jnp.where(ren, r_js, cfg_js)

            occ = srv.sum(axis=1)
            is1 = (vqof == 1) & (srv > 0)
            vq1_occ = (srv * is1).sum(axis=1)
            has_vq1 = is1.any(axis=1)
            resid = CAP - occ
            other_occ = occ - vq1_occ
            other_cap = jnp.where(eff_k1, CAP - RESERVE, CAP)
            k1_can = eff_k1 & ~has_vq1 & hx[1] & (head_effs[1] <= resid)
            js_oh = eff_js[:, None] == j_iota[None, :]        # (L, 2J)
            js_head = (js_oh * head_effs[None, :]).sum(axis=1)
            js_ex = (js_oh & hx[None, :]).any(axis=1)
            js_can = (eff_js >= 0) & js_ex & (other_occ + js_head <= other_cap)
            would = pending & (k1_can | js_can)

            placer = jnp.min(jnp.where(would, l_iota, L))
            tch = pending & (l_iota <= placer)
            adv = pending & (l_iota < placer)

            do_ren = tch & ren
            cfg_k1 = jnp.where(do_ren, r_k1, cfg_k1)
            cfg_js = jnp.where(do_ren, r_js, cfg_js)
            has_cfg = has_cfg | tch
            # _empty membership is granted at FIRST touch only (numpy adds
            # at visit time, before serving): a placer that emptied at slot
            # start but placed jobs in earlier steps must not be re-marked
            # from the stale empty_now mask when it is advanced past.
            in_empty = in_empty | (tch & ~touched & empty_now)
            touched = touched | tch
            advanced = advanced | adv

            # subscriptions of the servers advanced past (they place
            # nothing, so these are their only state writes)
            sub1 = adv & eff_k1 & ~has_vq1 & ~hx[1]
            subj = adv & (eff_js >= 0) & ~js_ex
            want = want | (sub1[:, None] & (j_iota[None, :] == 1)) \
                        | (subj[:, None] & js_oh)

            # serve the placer
            any_p = placer < L
            s = jnp.minimum(placer, L - 1)
            do_k1 = any_p & k1_can[s]
            j_sel = jnp.where(do_k1, 1, jnp.maximum(eff_js[s], 0))
            wpos = (head[j_sel] + p_iota) % Qcap
            effs_w = ring_eff[j_sel, wpos]
            durs_w = ring_dur[j_sel, wpos]
            in_q = p_iota < qcnt[j_sel]
            fit = in_q & (jnp.cumsum(effs_w) <= other_cap[s] - other_occ[s])
            m = jnp.where(do_k1, 1, fit.sum())
            m = jnp.where(any_p, m, 0)

            row = srv[s]
            es = row == 0
            free_cnt = es.sum()
            slotrank = jnp.cumsum(es.astype(jnp.int32)) - 1
            sel = (es[:, None] & (slotrank[:, None] == p_iota[None, :])
                   & (p_iota[None, :] < m))                   # (K, P)
            placed_k = sel.any(axis=1)
            new_row = row + sel.astype(jnp.int32) @ effs_w
            new_dep = jnp.where(placed_k, t + sel.astype(jnp.int32) @ durs_w,
                                dep[s])
            new_vq = jnp.where(placed_k, j_sel, vqof[s])
            lmask = (l_iota == placer)[:, None]
            srv = jnp.where(lmask, new_row[None, :], srv)
            dep = jnp.where(lmask, new_dep[None, :], dep)
            vqof = jnp.where(lmask, new_vq[None, :], vqof)
            if faulted:  # retry counts ride with the placed jobs
                tries_w = ring_try[j_sel, wpos]
                new_try = jnp.where(placed_k,
                                    sel.astype(jnp.int32) @ tries_w,
                                    srv_try[s])
                srv_try = jnp.where(lmask, new_try[None, :], srv_try)
            jw = jnp.where(any_p, j_sel, nvq)
            head = head.at[jw].add(m, mode="drop")
            qcnt = qcnt.at[jw].add(-m, mode="drop")
            in_empty = in_empty & ~((l_iota == placer) & (m > 0))
            trunc = trunc + jnp.maximum(m - free_cnt, 0)  # K-overflow
            return (srv, dep, vqof, head, qcnt, cfg_k1, cfg_js,
                    has_cfg, in_empty, want, touched, advanced, trunc,
                    n_steps + 1, srv_try)

        # Early-exit bounded loop: when no pending server can place, the
        # body degenerates to the advance-everyone finalization (placement
        # masks are all no-ops), pending empties and the loop exits — so a
        # slot costs (#placing servers + 1) iterations, not the W bound.
        # Each iteration is the same branch-free masked-select program the
        # Pallas kernel unrolls with a fixed trip count.
        def unfinished(carry):
            advanced, n_steps = carry[11], carry[13]
            return (visit & ~advanced).any() & (n_steps <= W)

        carry = (srv, dep, vqof, head, qcnt, cfg_k1, cfg_js, has_cfg,
                 in_empty, want, jnp.zeros((L,), bool), jnp.zeros((L,), bool),
                 trunc, jnp.zeros((), jnp.int32), srv_try)
        carry = jax.lax.while_loop(unfinished, work, carry)
        (srv, dep, vqof, head, qcnt, cfg_k1, cfg_js, has_cfg,
         in_empty, want, _, advanced, trunc, _, srv_try) = carry
        # cap hit with servers still unserved: the slot finished lazily
        trunc = trunc + (visit & ~advanced).any().astype(jnp.int32)

        out = (qcnt.sum().astype(jnp.int32),
               srv.sum().astype(jnp.float32) / RES,
               n_dep.astype(jnp.int32))
        state = (srv, dep, vqof, ring_eff, ring_dur, head, qcnt,
                 cfg_k1, cfg_js, has_cfg, in_empty, want, t + 1,
                 dropped, trunc, ring_try, srv_try, preempted, requeued,
                 lost, up_last)
        return state, out

    if state is None:
        state = _init_state(J, L, K, Qcap)
    xs = (streams.n, streams.sizes, streams.durs)
    if faulted:
        xs = xs + (streams.up,)
    state, (qlen, occ, ndep) = jax.lax.scan(slot_step, state, xs)
    res = PolicyResult(qlen, occ, jnp.cumsum(ndep), state[13], state[14],
                       state[17], state[18], state[19])
    return (res, state) if return_state else res


def run_vqs_trace(streams: SchedStreams, *, J: int, L: int, K: int,
                  Qcap: int, A_max: int, engine: str = "scan",
                  work_steps: int | None = None,
                  drain: int | None = None,
                  window: int | None = None,
                  max_requeue: int = DEFAULT_MAX_REQUEUE,
                  strict: bool = False) -> PolicyResult:
    """Run one VQS simulation over explicit streams (random or trace).
    ``window`` is the Pallas kernel's VMEM time-window length (must divide
    the horizon; ignored by the other engines)."""
    if engine == "reference":
        return _run_vqs_reference_streams(streams, J=J, L=L, K=K, Qcap=Qcap,
                                          A_max=A_max,
                                          max_requeue=max_requeue)
    if engine == "scan":
        return run_vqs_streams(streams, J=J, L=L, K=K, Qcap=Qcap,
                               A_max=A_max, work_steps=work_steps,
                               drain=drain, max_requeue=max_requeue)
    if engine == "pallas":
        from repro.kernels.common import ensemble_plane_bytes, pallas_precheck
        from repro.kernels.vqs.ops import vqs_scratch_bytes, vqs_simulate
        T, D = streams.n.shape[0], streams.durs.shape[-1]
        if not pallas_precheck(
                "vqs", nbytes=vqs_scratch_bytes(J, L, K, Qcap),
                hbm_bytes=ensemble_plane_bytes(
                    1, T, stream_lanes=1 + A_max + D, out_lanes=3),
                fault_plane=streams.up is not None, strict=strict):
            return run_vqs_streams(streams, J=J, L=L, K=K, Qcap=Qcap,
                                   A_max=A_max, work_steps=work_steps,
                                   drain=drain, max_requeue=max_requeue)
        batched = jax.tree.map(lambda x: x[None], streams)
        res = vqs_simulate(batched, J=J, L=L, K=K, Qcap=Qcap, A_max=A_max,
                           work_steps=work_steps, drain=drain, window=window)
        return jax.tree.map(lambda x: x[0], res)
    raise ValueError(f"unknown engine {engine!r}")


def run_vqs(key: jax.Array, lam: float, mu: float,
            sampler: Callable[[jax.Array, int], jax.Array],
            J: int = 4, L: int = 8, K: int = 16, Qcap: int = 512,
            A_max: int = 8, horizon: int = 10_000, engine: str = "scan",
            work_steps: int | None = None,
            drain: int | None = None,
            window: int | None = None,
            fault_rate: float = 0.0, repair_rate: float = 1.0,
            max_requeue: int = DEFAULT_MAX_REQUEUE,
            strict: bool = False) -> PolicyResult:
    """Simulate VQS on L unit-capacity servers for ``horizon`` slots.

    Randomness is always hoisted into ``make_streams`` (service durations
    attach to jobs at arrival — distributionally identical to the numpy
    engine's draw-at-placement for the memoryless service model).

    ``fault_rate > 0`` injects per-slot server capacity shocks: down
    servers evict their jobs into the tails of their virtual queues (up to
    ``max_requeue`` retries each, ``lost`` past that), identically on the
    scan and reference engines.
    """
    streams = make_streams(key, lam, mu, sampler, L=L, K=K, A_max=A_max,
                           horizon=horizon, fault_rate=fault_rate,
                           repair_rate=repair_rate)
    return run_vqs_trace(streams, J=J, L=L, K=K, Qcap=Qcap, A_max=A_max,
                         engine=engine, work_steps=work_steps, drain=drain,
                         window=window, max_requeue=max_requeue,
                         strict=strict)


def run_vqs_workload(workload, key: jax.Array, *, engine: str = "scan",
                     **config) -> PolicyResult:
    """Workload-first adapter: the registry entry behind
    ``run_policy(workload, policy="vqs", ...)``.  VQS partitions scalar
    sizes; vector workloads are rejected loudly."""
    workload.require_scalar("vqs")
    workload.check_sampler()
    return run_vqs(key, workload.lam, workload.mu, workload.sampler,
                   engine=engine, **config)


def monte_carlo_vqs_workload(workload, keys: jax.Array, *,
                             engine: str = "scan", **config) -> PolicyResult:
    """Workload-first adapter for ``monte_carlo_policy(policy="vqs")``."""
    workload.require_scalar("vqs")
    workload.check_sampler()
    return monte_carlo_vqs(keys, workload.lam, workload.mu,
                           workload.sampler, engine=engine, **config)


def monte_carlo_vqs(keys: jax.Array, lam: float, mu: float, sampler,
                    engine: str = "scan", work_steps: int | None = None,
                    drain: int | None = None, window: int | None = None,
                    J: int = 4, L: int = 8,
                    K: int = 16, Qcap: int = 512, A_max: int = 8,
                    horizon: int = 10_000, fault_rate: float = 0.0,
                    repair_rate: float = 1.0,
                    max_requeue: int = DEFAULT_MAX_REQUEUE,
                    strict: bool = False) -> PolicyResult:
    """One simulated cluster per key (vmap; "pallas" uses the kernel grid)."""
    if engine == "pallas":
        from repro.kernels.common import ensemble_plane_bytes, pallas_precheck
        from repro.kernels.vqs.ops import vqs_scratch_bytes, vqs_simulate
        # keys is the LOCAL batch under a sharded mesh launch, so the
        # footprint check is per device (core.engine.sharding).
        G = int(keys.shape[0])
        if not pallas_precheck(
                "vqs", nbytes=vqs_scratch_bytes(J, L, K, Qcap),
                hbm_bytes=ensemble_plane_bytes(
                    G, horizon, stream_lanes=1 + A_max + (L * K + A_max),
                    out_lanes=3),
                fault_plane=fault_rate > 0.0, strict=strict):
            engine = "scan"
        else:
            streams = jax.vmap(
                lambda k: make_streams(k, lam, mu, sampler, L=L, K=K,
                                       A_max=A_max, horizon=horizon))(keys)
            return vqs_simulate(streams, J=J, L=L, K=K, Qcap=Qcap,
                                A_max=A_max, work_steps=work_steps,
                                drain=drain, window=window)
    fn = functools.partial(run_vqs, lam=lam, mu=mu, sampler=sampler,
                           engine=engine, work_steps=work_steps, drain=drain,
                           J=J, L=L, K=K, Qcap=Qcap, A_max=A_max,
                           horizon=horizon, fault_rate=fault_rate,
                           repair_rate=repair_rate, max_requeue=max_requeue)
    return jax.vmap(fn)(keys)
