"""Pre-generated randomness streams shared by every accelerator engine.

All engine randomness is hoisted out of the slot loops into ``SchedStreams``:
per-slot arrival counts, job sizes and service durations, generated either

  * from a PRNG key (``make_streams``) with exactly the key chain of the
    original in-loop reference engine, so stream-consuming engines reproduce
    it bit-for-bit; or
  * from a workload trace (``streams_from_trace``), so Google-like traces
    (core/trace.py) replay through the same fixed-shape engines that run the
    synthetic Monte-Carlo studies.

The duration stream layout is shared across policies: the LAST ``A_max``
lanes of ``durs[t]`` belong to the slot's arrivals (``durs[t, -A_max + a]``
is arrival ``a``'s duration — consumed by BF-J placements, and by the VQS
engines, which attach the duration to the job at arrival), while everything
before them is the sequential-draw region consumed dc-th-placement-first by
the BF-J/S engines' BF-S refills.  ``make_streams`` emits the full
``L*K + A_max`` width; ``streams_from_trace`` emits only the per-arrival
lanes — a trace has no meaningful sequential region (BF-S refills would
detach durations from job identities), so the BF-J/S engines statically
reject trace-shaped streams instead of replaying them wrong.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

INF_SLOT = jnp.iinfo(jnp.int32).max


class SchedStreams(NamedTuple):
    """Per-slot randomness consumed by the scheduling engines.

    Generated with exactly the key chain of the in-loop reference engine, so
    engines consuming these streams reproduce ``engine="reference"``
    bit-for-bit.  (Known historically as ``BFJSStreams`` — the layout is
    policy-generic and the old name remains as an alias.)
    """
    n: jax.Array       # (T,) int32 arrival counts, already clipped to A_max
    sizes: jax.Array   # (T, A_max) float32 job sizes in (0, 1]
    durs: jax.Array    # (T, L*K + A_max) int32 geometric service durations


#: Back-compat alias (PR 1 public name).
BFJSStreams = SchedStreams


class PolicyResult(NamedTuple):
    """Per-slot trajectory of one simulated cluster (any policy/engine)."""
    queue_len: jax.Array   # (T,) int32
    occupancy: jax.Array   # (T,) float32 total occupied capacity (servers)
    departed: jax.Array    # (T,) int32 cumulative departures
    dropped: jax.Array     # () int32 arrivals dropped by fixed-size buffers
    truncated: jax.Array   # () int32 slots where a fixed bound cut the
    #                        policy short (0 == bit-exact vs. the reference)


#: Back-compat alias (PR 1 public name).
BFJSResult = PolicyResult


def _geometric(key: jax.Array, mu: float, shape=()) -> jax.Array:
    u = jax.random.uniform(key, shape, minval=1e-7, maxval=1.0)
    return jnp.maximum(jnp.ceil(jnp.log(u) / jnp.log1p(-mu)), 1.0).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("sampler", "L", "K", "A_max", "horizon"))
def make_streams(key: jax.Array, lam: float, mu: float,
                 sampler: Callable[[jax.Array, int], jax.Array],
                 L: int, K: int, A_max: int, horizon: int) -> SchedStreams:
    """Pre-generate all per-slot randomness for one cluster simulation.

    Replicates the reference engine's per-slot key chain
    (``key, _, k_n, k_sizes, k_dur = split(key, 5)``) and draws each slot's
    Poisson count / sizes / durations under ``vmap`` — bitwise identical to
    the in-loop draws, but issued as three large batched RNG calls instead
    of ``5 * horizon`` tiny ones.
    """

    def chain(k, _):
        ks = jax.random.split(k, 5)
        return ks[0], ks[1:]

    _, ks = jax.lax.scan(chain, key, None, length=horizon)
    n = jnp.minimum(jax.vmap(lambda k: jax.random.poisson(k, lam))(ks[:, 1]),
                    A_max).astype(jnp.int32)
    sizes = jax.vmap(lambda k: sampler(k, A_max))(ks[:, 2])
    durs = jax.vmap(lambda k: _geometric(k, mu, (L * K + A_max,)))(ks[:, 3])
    return SchedStreams(n, sizes, durs)


def streams_from_trace(arrival_slots, sizes, durations, *,
                       horizon: int | None = None,
                       A_max: int | None = None) -> SchedStreams:
    """Build ``SchedStreams`` that replay a workload trace exactly.

    Mirrors ``core.simulator.simulate_trace`` preprocessing bit-for-bit:
    jobs are stably sorted by arrival slot, float sizes are quantized with
    ``quantize.to_grid`` (the stream stores the exact grid value ``g/RES``,
    which float32 represents exactly for ``RES = 2**16``, so the engines'
    in-loop quantization recovers ``g`` verbatim) and durations are clamped
    to >= 1 slot.

    The duration stream holds ONLY the per-arrival lanes (``(T, A_max)``):
    every job's duration travels with the job, which is exactly the
    semantics of policies that attach durations at arrival (VQS).  The
    BF-J/S engines additionally need a sequential-draw region that a trace
    cannot provide (their BF-S refills would detach durations from job
    identities), so they reject trace-shaped streams with a ValueError at
    trace time instead of replaying them wrong.

    ``A_max`` defaults to the trace's actual max arrivals-per-slot so no
    arrival is ever silently dropped; passing a smaller ``A_max`` is an
    error rather than a truncation.
    """
    from ..quantize import RES, to_grid

    arrival_slots = np.asarray(arrival_slots)
    order = np.argsort(arrival_slots, kind="stable")
    arrival_slots = arrival_slots[order].astype(np.int64)
    g = to_grid(np.asarray(sizes)[order])
    durations = np.maximum(np.asarray(durations)[order].astype(np.int64), 1)
    if horizon is None:
        if len(arrival_slots) == 0:
            raise ValueError(
                "empty trace and no horizon: pass horizon= explicitly")
        horizon = int(arrival_slots[-1]) + 1

    in_h = (arrival_slots >= 0) & (arrival_slots < horizon)
    counts = np.bincount(arrival_slots[in_h], minlength=horizon)[:horizon]
    peak = int(counts.max()) if len(counts) else 0
    if A_max is None:
        A_max = max(peak, 1)
    elif peak > A_max:
        raise ValueError(
            f"trace has {peak} arrivals in one slot > A_max={A_max}; "
            "raise A_max (streams never drop trace jobs silently)")

    size_arr = np.zeros((horizon, A_max), dtype=np.float32)
    dur_arr = np.ones((horizon, A_max), dtype=np.int32)
    slot = arrival_slots[in_h]
    # lane[i] = index of job i within its slot (jobs are slot-sorted)
    lane = np.arange(len(slot)) - np.repeat(np.cumsum(counts) - counts,
                                            counts)
    size_arr[slot, lane] = (g[in_h].astype(np.float64) / RES).astype(np.float32)
    dur_arr[slot, lane] = durations[in_h]
    return SchedStreams(jnp.asarray(counts, jnp.int32),
                        jnp.asarray(size_arr),
                        jnp.asarray(dur_arr))


def resolve_work_steps(work_steps: int | None, A_max: int) -> int:
    """Default bound of the per-slot placement work lists: enough for every
    landed arrival plus a burst of refills; the ``truncated`` counter
    reports the (rare) slots where this was short."""
    return work_steps if work_steps is not None else A_max + 4


#: Back-compat alias (PR 1 private name, imported by kernels/bfjs).
_resolve_work_steps = resolve_work_steps
