"""Pre-generated randomness streams shared by every accelerator engine.

All engine randomness is hoisted out of the slot loops into ``SchedStreams``:
per-slot arrival counts, job sizes and service durations, generated either

  * from a PRNG key (``make_streams``) with exactly the key chain of the
    original in-loop reference engine, so stream-consuming engines reproduce
    it bit-for-bit; or
  * from a workload trace (``streams_from_trace``), so Google-like traces
    (core/trace.py) replay through the same fixed-shape engines that run the
    synthetic Monte-Carlo studies.

The duration stream layout is shared across policies: the LAST ``A_max``
lanes of ``durs[t]`` belong to the slot's arrivals (``durs[t, -A_max + a]``
is arrival ``a``'s duration — consumed by BF-J placements, and by the VQS
engines, which attach the duration to the job at arrival), while everything
before them is the sequential-draw region consumed dc-th-placement-first by
the BF-J/S engines' BF-S refills.  ``make_streams`` emits the full
``L*K + A_max`` width; ``streams_from_trace`` emits only the per-arrival
lanes — a trace has no meaningful sequential region (BF-S refills would
detach durations from job identities), so the BF-J/S engines statically
reject trace-shaped streams instead of replaying them wrong.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

INF_SLOT = jnp.iinfo(jnp.int32).max


class SchedStreams(NamedTuple):
    """Per-slot randomness consumed by the scheduling engines.

    Generated with exactly the key chain of the in-loop reference engine, so
    engines consuming these streams reproduce ``engine="reference"``
    bit-for-bit.  (Known historically as ``BFJSStreams`` — the layout is
    policy-generic and the old name remains as an alias.)

    The canonical size layout is ``(T, A_max, R)`` — one requirement vector
    per arrival.  Single-resource streams (``R == 1``) squeeze the resource
    axis away and keep the historical ``(T, A_max)`` plane, so every
    existing engine, kernel and test consumes exactly the layout it always
    did; ``num_resources`` reads R off the shape.
    """
    n: jax.Array       # (T,) int32 arrival counts, already clipped to A_max
    sizes: jax.Array   # (T, A_max) f32 sizes in (0,1] — (T, A_max, R) if R>1
    durs: jax.Array    # (T, L*K + A_max) int32 geometric service durations

    @property
    def num_resources(self) -> int:
        """R: 1 for the squeezed legacy layout, trailing dim otherwise.

        Anchored on ``durs``'s rank (always one axis fewer than an
        R-carrying ``sizes``) so it also reads correctly on ensemble-batched
        streams with a leading G axis."""
        return 1 if self.sizes.ndim == self.durs.ndim \
            else int(self.sizes.shape[-1])


#: Back-compat alias (PR 1 public name).
BFJSStreams = SchedStreams


class PolicyResult(NamedTuple):
    """Per-slot trajectory of one simulated cluster (any policy/engine).

    Single-resource policies keep ``occupancy`` as the historical ``(T,)``
    plane; multi-resource policies (``bfjs-mr``) report one occupancy plane
    per resource, ``(T, R)`` — total occupied capacity in servers, per
    resource, exact on the ``quantize.RES`` grid."""
    queue_len: jax.Array   # (T,) int32
    occupancy: jax.Array   # (T,) f32 occupied capacity (servers); (T, R)
    #                        per-resource planes for multi-resource policies
    departed: jax.Array    # (T,) int32 cumulative departures
    dropped: jax.Array     # () int32 arrivals dropped by fixed-size buffers
    truncated: jax.Array   # () int32 slots where a fixed bound cut the
    #                        policy short (0 == bit-exact vs. the reference)


#: Back-compat alias (PR 1 public name).
BFJSResult = PolicyResult


def _geometric(key: jax.Array, mu: float, shape=()) -> jax.Array:
    u = jax.random.uniform(key, shape, minval=1e-7, maxval=1.0)
    return jnp.maximum(jnp.ceil(jnp.log(u) / jnp.log1p(-mu)), 1.0).astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("sampler", "L", "K", "A_max", "horizon",
                     "num_resources"))
def make_streams(key: jax.Array, lam: float, mu: float,
                 sampler: Callable[[jax.Array, int], jax.Array],
                 L: int, K: int, A_max: int, horizon: int,
                 num_resources: int = 1) -> SchedStreams:
    """Pre-generate all per-slot randomness for one cluster simulation.

    Replicates the reference engine's per-slot key chain
    (``key, _, k_n, k_sizes, k_dur = split(key, 5)``) and draws each slot's
    Poisson count / sizes / durations under ``vmap`` — bitwise identical to
    the in-loop draws, but issued as three large batched RNG calls instead
    of ``5 * horizon`` tiny ones.

    With ``num_resources == 1`` (the default) the sampler returns ``(n,)``
    scalar sizes and the stream keeps the historical ``(T, A_max)`` layout.
    With R > 1 the sampler returns ``(n, R)`` requirement vectors and the
    size stream is ``(T, A_max, R)``; the key chain is unchanged, so the
    non-size streams stay bitwise identical across R.
    """

    def chain(k, _):
        ks = jax.random.split(k, 5)
        return ks[0], ks[1:]

    _, ks = jax.lax.scan(chain, key, None, length=horizon)
    n = jnp.minimum(jax.vmap(lambda k: jax.random.poisson(k, lam))(ks[:, 1]),
                    A_max).astype(jnp.int32)
    sizes = jax.vmap(lambda k: sampler(k, A_max))(ks[:, 2])
    expect = (horizon, A_max) if num_resources == 1 \
        else (horizon, A_max, num_resources)
    if tuple(sizes.shape) != expect:
        raise ValueError(
            f"sampler produced sizes of shape {tuple(sizes.shape)} for "
            f"num_resources={num_resources}: expected {expect} "
            "(sampler(key, n) must return (n,) for R == 1, (n, R) "
            "otherwise)")
    durs = jax.vmap(lambda k: _geometric(k, mu, (L * K + A_max,)))(ks[:, 3])
    return SchedStreams(n, sizes, durs)


def streams_from_trace(trace_or_slots, sizes=None, durations=None, *,
                       horizon: int | None = None,
                       A_max: int | None = None,
                       collapse: bool = True,
                       num_resources: int | None = None) -> SchedStreams:
    """Build ``SchedStreams`` that replay a workload trace exactly.

    Accepts either raw arrays ``(arrival_slots, sizes, durations)`` — with
    ``sizes`` of shape ``(N,)`` for scalar jobs or ``(N, R)`` for
    requirement vectors — or a ``core.trace.Trace`` directly:

        streams_from_trace(trace)                  # max(cpu, mem), paper's
                                                   # collapse preprocessing
        streams_from_trace(trace, collapse=False)  # (cpu, mem) uncollapsed,
                                                   # (T, A_max, 2) sizes for
                                                   # policy="bfjs-mr"

    Mirrors ``core.simulator.simulate_trace`` preprocessing bit-for-bit:
    jobs are stably sorted by arrival slot, float sizes are quantized with
    ``quantize.to_grid`` per resource (the stream stores the exact grid
    value ``g/RES``, which float32 represents exactly for ``RES = 2**16``,
    so the engines' in-loop quantization recovers ``g`` verbatim) and
    durations are clamped to >= 1 slot.

    The duration stream holds ONLY the per-arrival lanes (``(T, A_max)``):
    every job's duration travels with the job, which is exactly the
    semantics of policies that attach durations at arrival (VQS, bfjs-mr).
    The single-resource BF-J/S engines additionally need a sequential-draw
    region that a trace cannot provide (their BF-S refills would detach
    durations from job identities), so they reject trace-shaped streams
    with a ValueError at trace time instead of replaying them wrong.

    ``A_max`` defaults to the trace's actual max arrivals-per-slot so no
    arrival is ever silently dropped; passing a smaller ``A_max`` is an
    error rather than a truncation.

    ``num_resources`` pins the R the caller's engine config expects
    (``Workload.num_resources``): a trace whose resource count disagrees
    raises with both shapes named instead of letting a squeezed or
    truncated plane broadcast into the wrong engine downstream.
    """
    from ..quantize import RES, to_grid

    if sizes is None or hasattr(trace_or_slots, "arrival_slots"):
        trace = trace_or_slots
        if sizes is not None or durations is not None:
            raise TypeError(
                "pass either a Trace or (arrival_slots, sizes, durations), "
                "not both")
        arrival_slots = np.asarray(trace.arrival_slots)
        durations = np.asarray(trace.durations)
        if collapse:
            sizes = np.maximum(trace.cpu, trace.mem)
        else:
            sizes = np.stack([trace.cpu, trace.mem], axis=1)
    else:
        arrival_slots = np.asarray(trace_or_slots)

    arrival_slots = np.asarray(arrival_slots)
    order = np.argsort(arrival_slots, kind="stable")
    arrival_slots = arrival_slots[order].astype(np.int64)
    sizes = np.asarray(sizes)
    R = 1 if sizes.ndim == 1 else int(sizes.shape[1])
    if num_resources is not None and R != num_resources:
        hint = ""
        if num_resources == 1 and R > 1:
            hint = " (or pass collapse=True)"
        elif R == 1 and num_resources == 2:
            hint = " (or pass collapse=False)"
        raise ValueError(
            f"trace carries R={R} resource plane(s) (sizes shape "
            f"{tuple(sizes.shape)}) but the target workload expects "
            f"num_resources={num_resources}; pass a matching trace"
            f"{hint} instead of broadcasting")
    g = to_grid(sizes[order])
    durations = np.maximum(np.asarray(durations)[order].astype(np.int64), 1)
    if horizon is None:
        if len(arrival_slots) == 0:
            raise ValueError(
                "empty trace and no horizon: pass horizon= explicitly")
        horizon = int(arrival_slots[-1]) + 1

    in_h = (arrival_slots >= 0) & (arrival_slots < horizon)
    counts = np.bincount(arrival_slots[in_h], minlength=horizon)[:horizon]
    peak = int(counts.max()) if len(counts) else 0
    if A_max is None:
        A_max = max(peak, 1)
    elif peak > A_max:
        raise ValueError(
            f"trace has {peak} arrivals in one slot > A_max={A_max}; "
            "raise A_max (streams never drop trace jobs silently)")

    size_shape = (horizon, A_max) if R == 1 else (horizon, A_max, R)
    size_arr = np.zeros(size_shape, dtype=np.float32)
    dur_arr = np.ones((horizon, A_max), dtype=np.int32)
    slot = arrival_slots[in_h]
    # lane[i] = index of job i within its slot (jobs are slot-sorted)
    lane = np.arange(len(slot)) - np.repeat(np.cumsum(counts) - counts,
                                            counts)
    size_arr[slot, lane] = (g[in_h].astype(np.float64) / RES).astype(np.float32)
    dur_arr[slot, lane] = durations[in_h]
    return SchedStreams(jnp.asarray(counts, jnp.int32),
                        jnp.asarray(size_arr),
                        jnp.asarray(dur_arr))


def resolve_work_steps(work_steps: int | None, A_max: int) -> int:
    """Default bound of the per-slot placement work lists: enough for every
    landed arrival plus a burst of refills; the ``truncated`` counter
    reports the (rare) slots where this was short."""
    return work_steps if work_steps is not None else A_max + 4


#: Back-compat alias (PR 1 private name, imported by kernels/bfjs).
_resolve_work_steps = resolve_work_steps
