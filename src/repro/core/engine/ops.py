"""Primitive scheduling ops shared by the engines and the serving stack.

Pure-jnp, jit/vmap-friendly.  The Pallas kernels under ``repro.kernels``
re-express the hot ones with broadcasted-iota masks; these are the
behavioural definitions they are tested against.
"""
from __future__ import annotations



import jax
import jax.numpy as jnp

from ..partition import k_red
from ..quantize import RES


def best_fit_server(residuals: jax.Array, size: jax.Array) -> jax.Array:
    """Tightest feasible server for one job: argmin residual among residuals
    >= size; returns -1 if none fits. O(L) vectorized."""
    feasible = residuals >= size
    masked = jnp.where(feasible, residuals, jnp.inf)
    idx = jnp.argmin(masked)
    return jnp.where(feasible.any(), idx, -1)


def best_fit_place(residuals: jax.Array, sizes: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Sequentially Best-Fit place a batch of jobs (pure-jnp reference used by
    the serving engine; kernels/best_fit provides the Pallas TPU version).

    Returns (assignment (N,) int32 with -1 = rejected, new residuals)."""

    def body(resid, size):
        srv = best_fit_server(resid, size)
        ok = srv >= 0
        resid = jnp.where(ok, resid.at[srv].add(-size), resid)
        return resid, jnp.where(ok, srv, -1)

    new_resid, assign = jax.lax.scan(body, residuals, sizes)
    return assign.astype(jnp.int32), new_resid


def alignment_score_pair_jnp(avail: jax.Array,
                             demand: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Tetris alignment <demand, avail> per server (paper §VIII), exact.

    ``avail`` is (L, R) grid-integer availability, ``demand`` is (R,) grid
    integers.  The true score ``sum_r avail_r * demand_r`` needs up to
    ~34 bits — too wide for int32 and for a float32 mantissa, and float32
    accumulation is NOT portable: XLA is free to contract ``mul+add`` into
    an FMA in one lowering but not another (observed to differ with vmap
    batch width on CPU), which flips argmin tie-breaks.  Instead the score
    is returned as a normalized int32 pair ``(hi, lo)`` with
    ``score == hi * 256 + lo`` and ``0 <= lo < 256``: products against the
    split demand ``(d >> 8, d & 255)`` stay below 2**24 each, so every op
    is exact integer arithmetic and comparing ``(hi, lo)``
    lexicographically compares the exact scores — identical to the numpy
    oracle's exact float64 ``core.multi_resource.alignment_scores`` on any
    backend, batch width or compiler version.  Exact while
    ``R * capacity`` stays under ~128 server-capacities (int32 headroom).
    """
    a = avail.astype(jnp.int32)
    d = demand.astype(jnp.int32)
    hi = a[:, 0] * (d[0] >> 8)
    lo = a[:, 0] * (d[0] & 255)
    for r in range(1, a.shape[1]):
        hi = hi + a[:, r] * (d[r] >> 8)
        lo = lo + a[:, r] * (d[r] & 255)
    return hi + (lo >> 8), lo & 255


def first_empty_positions(empty: jax.Array,
                          want: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Scatter targets for admitting a masked batch into a fixed buffer.

    ``empty`` is the buffer's ``(Q,)`` empty-slot mask, ``want`` a ``(N,)``
    mask of items asking for a slot.  Returns ``(pos, landed)``: the i-th
    wanting item (in index order) is assigned the i-th empty slot, ``landed``
    masks the items that actually got one (``pos < Q``; entries of
    non-wanting items are garbage and must stay masked).  This is the
    admission rule every engine uses — slot arrivals and fault-preemption
    requeues go through the same first-empty order, so the scan engines and
    the reference oracles agree on queue layout bit-for-bit.
    """
    n_empty = jnp.cumsum(empty.astype(jnp.int32))
    rank = jnp.cumsum(want.astype(jnp.int32)) - 1
    pos = jnp.searchsorted(n_empty, rank + 1)
    return pos, want & (pos < empty.shape[0])


def largest_fitting_job(queue: jax.Array, cap: jax.Array) -> jax.Array:
    """Index of the largest queued job with size <= cap (BF-S step);
    -1 if none. Zero entries mean empty queue slots."""
    fits = (queue > 0) & (queue <= cap)
    masked = jnp.where(fits, queue, -jnp.inf)
    idx = jnp.argmax(masked)
    return jnp.where(fits.any(), idx, -1)


def k_red_jnp(J: int) -> jax.Array:
    """The reduced configuration set K_RED^(J) as an int32 array (a constant
    when used under jit; ``k_red`` itself is lru-cached host-side)."""
    return jnp.asarray(k_red(J), jnp.int32)


def max_weight_config_jax(J: int, vq_sizes: jax.Array) -> tuple[jax.Array, jax.Array]:
    """argmax_{k in K_RED^{(J)}} <k, Q>  (paper Eq. 8), jit/vmap-friendly."""
    confs = k_red_jnp(J)
    w = confs @ vq_sizes.astype(jnp.int32)
    i = jnp.argmax(w)
    return i, confs[i]


def vq_type_of_grid(g: jax.Array, J: int) -> jax.Array:
    """Partition-I type of integer grid sizes (exact, jittable).

    Transcribes ``PartitionI.type_of`` comparison-for-comparison:
    ``m = #{k in 1..J : g <= RES >> k}`` clipped to ``J-1``, even/odd split
    by ``3g > 2*(RES >> m)``, and the ``g <= RES >> J`` tail mapping to the
    last type ``2J - 1``.  Agrees with ``PartitionI.type_of_scalar`` on
    every grid point — the VQS engines classify with this so their virtual
    queues are bit-identical to the event-driven engine's.
    """
    g = jnp.asarray(g, jnp.int32)
    bounds = jnp.asarray([RES >> k for k in range(1, J + 1)], jnp.int32)
    m = jnp.minimum((g[..., None] <= bounds).sum(-1).astype(jnp.int32), J - 1)
    upper = jnp.right_shift(jnp.int32(RES), m)
    t = jnp.where(3 * g > 2 * upper, 2 * m, 2 * m + 1)
    return jnp.where(g <= (RES >> J), 2 * J - 1, t).astype(jnp.int32)


def vq_type_of(sizes: jax.Array, J: int) -> jax.Array:
    """Partition-I type of float sizes in (0,1] (vectorized, jittable).

    Sizes are quantized to the ``quantize.RES`` grid (the same
    ``max(round(size * RES), 1)`` rule the engines apply) and classified by
    the exact integer rule, so the result agrees with
    ``PartitionI.type_of_scalar`` on every grid point (including exact
    powers of two and the ``size <= 2^-J`` tail).
    """
    g = jnp.maximum(jnp.round(sizes * RES), 1.0).astype(jnp.int32)
    return vq_type_of_grid(g, J)
