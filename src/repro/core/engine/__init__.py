"""Accelerator-resident scheduling engines (pure JAX + Pallas kernels).

The event-driven numpy engine (core/simulator.py) is exact and fast on
hosts; this package re-expresses the paper's schedulers as fixed-shape,
branch-free programs that run ON the accelerator:

  * ``workload`` — the first-class :class:`Workload` spec (arrival rate,
    size sampler, service rate, ``num_resources``, per-resource capacity)
    every entry point dispatches on;
  * ``streams``  — pre-generated randomness (``SchedStreams``), from PRNG
    keys (``make_streams``) or workload traces (``streams_from_trace``),
    with ``(T, A_max, R)`` requirement vectors when R > 1;
  * ``ops``      — jit/vmap-friendly primitive ops (Best-Fit placement,
    max-weight configurations, exact partition-I classification, the f32
    Tetris alignment score);
  * ``bfjs``     — the single-resource BF-J/S engines (PR 1);
  * ``vqs``      — the VQS engines (paper Section V);
  * ``vqs_bf``   — the VQS-BF engines (paper Section VI — VQS throughput
    with BF-like delay via largest-fit-first bucketed rings),
    ``policy="vqs-bf"``;
  * ``bfjs_mr``  — the multi-resource Tetris-alignment BF-J/S engines
    (paper Section VIII), ``policy="bfjs-mr"``;
  * ``api``      — the policy registry behind ``run_policy(workload, ...)``
    (the PR 2 loose-argument forms remain as deprecation shims);
  * ``sharding`` — the ensemble dimension G on a device mesh
    (``monte_carlo_policy(..., mesh=|devices=)``, bit-identical to the
    single-device run; composes with ``chunked`` checkpointed sweeps);
  * ``tuning``   — the shape-keyed ``window=``/``work_steps=`` autotuner
    with its persistent, bit-match-verified tuning cache
    (``REPRO_TUNING_CACHE``);
  * ``streaming`` — ``stream_policy`` drives chunks of any (possibly
    infinite) arrival iterator through the stateful scan engines with
    carried state, double-buffering host ingestion against device compute
    (backpressure counters on ``PolicyResult``); finite traces replay
    bit-identically to the one-shot run under any chunking;
  * ``supervisor`` — the self-healing layer around the streaming loop
    (``stream_policy(supervisor=Supervisor(...))``): retry with jittered
    backoff on transient ingestion/staging/checkpoint failures, watchdog
    timeouts, rollback over corrupt checkpoints, poison-chunk quarantine,
    and the opt-in jitted runtime invariant auditor (``audit=True``,
    ``audit_result``) — DESIGN.md §14.

Engine contract (DESIGN.md §1): per policy, ``"scan"`` bit-matches
``"reference"`` while ``truncated == 0``, and ``"pallas"`` bit-matches
``"scan"`` — asserted by tests/test_jax_sched.py, tests/test_vqs_engine.py,
tests/test_mr_engine.py, tests/test_kernels.py and, for every registered
(policy, engine) cell at once, tests/test_engine_parity_matrix.py.
"""
from .api import (ENGINES, PolicySpec, available_policies, get_policy,
                  monte_carlo_policy, register_policy, run_policy,
                  run_policy_streams)
from .bfjs import (BFJSResult, BFJSState, DEFAULT_MAX_REQUEUE,
                   monte_carlo_bfjs, run_bfjs, run_bfjs_streams,
                   run_bfjs_trace)
from .bfjs_mr import (monte_carlo_bfjs_mr_workload, run_bfjs_mr_streams,
                      run_bfjs_mr_trace, run_bfjs_mr_workload)
from .chunked import run_chunked, streams_fingerprint
from .streaming import (iter_stream_chunks, stream_chunks_from_trace,
                        stream_policy)
from .supervisor import (INVARIANTS, CheckpointRollbackWarning,
                         InvariantViolation, RetryPolicy, Supervisor,
                         SupervisorError, SupervisorTimeout,
                         SupervisorWarning, audit_result, make_auditor)
from .sharding import (ENSEMBLE_AXIS, ensemble_streams, monte_carlo_chunked,
                       resolve_mesh, sharded_monte_carlo)
from .tuning import (TuningCache, apply_tuned, autotune, shape_key,
                     tuning_enabled)
from .ops import (alignment_score_pair_jnp, best_fit_place, best_fit_server,
                  k_red_jnp, largest_fitting_job, max_weight_config_jax,
                  vq_type_of, vq_type_of_grid)
from .streams import (BFJSStreams, INF_SLOT, PolicyResult, SchedStreams,
                      fault_plane_from_events, make_fault_plane,
                      make_streams, resolve_work_steps, streams_from_trace,
                      with_fault_plane)
from .vqs import (monte_carlo_vqs, run_vqs, run_vqs_streams, run_vqs_trace)
from .vqs_bf import (monte_carlo_vqs_bf, run_vqs_bf, run_vqs_bf_streams,
                     run_vqs_bf_trace)
from .workload import Workload

__all__ = [
    "ENGINES", "PolicySpec", "available_policies", "get_policy",
    "monte_carlo_policy", "register_policy", "run_policy",
    "run_policy_streams", "BFJSResult", "BFJSState", "DEFAULT_MAX_REQUEUE",
    "monte_carlo_bfjs", "run_bfjs", "run_bfjs_streams", "run_bfjs_trace",
    "monte_carlo_bfjs_mr_workload", "run_bfjs_mr_streams",
    "run_bfjs_mr_trace", "run_bfjs_mr_workload", "run_chunked",
    "streams_fingerprint", "iter_stream_chunks",
    "stream_chunks_from_trace", "stream_policy",
    "INVARIANTS", "CheckpointRollbackWarning", "InvariantViolation",
    "RetryPolicy", "Supervisor", "SupervisorError", "SupervisorTimeout",
    "SupervisorWarning", "audit_result", "make_auditor",
    "ENSEMBLE_AXIS", "ensemble_streams",
    "monte_carlo_chunked", "resolve_mesh", "sharded_monte_carlo",
    "TuningCache", "apply_tuned", "autotune", "shape_key",
    "tuning_enabled", "alignment_score_pair_jnp",
    "best_fit_place", "best_fit_server", "k_red_jnp", "largest_fitting_job",
    "max_weight_config_jax", "vq_type_of", "vq_type_of_grid", "BFJSStreams",
    "INF_SLOT", "PolicyResult", "SchedStreams", "fault_plane_from_events",
    "make_fault_plane", "make_streams", "resolve_work_steps",
    "streams_from_trace", "with_fault_plane", "monte_carlo_vqs",
    "run_vqs", "run_vqs_streams", "run_vqs_trace", "monte_carlo_vqs_bf",
    "run_vqs_bf", "run_vqs_bf_streams", "run_vqs_bf_trace", "Workload",
]
