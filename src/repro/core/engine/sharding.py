"""Mesh-sharded Monte-Carlo: the ensemble dimension G across devices.

The paper's headline evidence (Figs. 6/7) averages throughput over large
ensembles of independently-simulated clusters — embarrassingly parallel in
the ensemble dimension G.  This module places G on a 1-D
``jax.sharding.Mesh`` (axis ``"ensemble"``) via ``shard_map``: every
device traces the SAME per-policy Monte-Carlo program on its G/D shard of
the PRNG keys, so per-member randomness, the scan carries and the Pallas
kernel grid all stay device-local — no collectives anywhere, time windows
never cross devices (DESIGN.md §11).

Layout invariant the wrapper relies on: every ``PolicyResult`` field of a
Monte-Carlo run carries a LEADING G axis (``queue_len (G, T)``,
``occupancy (G, T[, R])``, ``departed (G, T)``, scalar counters ``(G,)``),
so one ``PartitionSpec("ensemble")`` prefix shards the whole pytree.
Because each member's simulation consumes exactly its own key — the same
key chain as the unsharded path — sharded results are BIT-IDENTICAL to
single-device runs, just laid out across devices
(tests/test_sharded_mc.py).

Engine rules:

  * ``"scan"`` / ``"pallas"`` run under ``shard_map``; the Pallas VMEM
    precheck sees the per-device local G, so footprints that overflow one
    device can still dispatch on a mesh (``kernels.common.pallas_precheck``);
  * ``"reference"`` is a host-side numpy oracle — not traceable, so
    ``mesh=`` is accepted but ignored (the run is host-serial either way;
    parity against it is what the sharded engines are tested for).

``monte_carlo_chunked`` composes the mesh with ``core.engine.chunked``:
per-chunk carries keep the full ``(G, ...)`` shape on the host checkpoint
(the manifest never pins a device count), so a sweep checkpointed on D
devices resumes bit-exactly on D' — re-sharding is just the next launch's
input placement.

On hosts without real accelerators, force a multi-device platform with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` BEFORE importing
jax — how CI runs the 4-device smoke job.
"""
from __future__ import annotations

import functools

import jax
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from .streams import make_streams

#: The mesh axis the ensemble dimension is sharded over.
ENSEMBLE_AXIS = "ensemble"


def resolve_mesh(mesh: Mesh | None = None,
                 devices: int | list | None = None) -> Mesh | None:
    """Normalize the ``mesh=``/``devices=`` knobs to a 1-D Mesh (or None).

    ``devices=`` is the convenience form: an int takes the first N of
    ``jax.devices()``, a sequence of Device objects is used as given —
    either way on a fresh 1-D mesh with axis :data:`ENSEMBLE_AXIS`.  A
    ready-made ``mesh=`` must be 1-D (the ensemble is the only sharded
    dimension; time windows stay per-device).  Both None means unsharded.
    """
    if mesh is not None and devices is not None:
        raise ValueError("pass mesh= or devices=, not both")
    if mesh is not None:
        if len(mesh.axis_names) != 1:
            raise ValueError(
                f"ensemble sharding needs a 1-D mesh; got axes "
                f"{mesh.axis_names} (only the ensemble dimension G is "
                "sharded — time windows stay per-device)")
        return mesh
    if devices is None:
        return None
    if isinstance(devices, int):
        avail = jax.devices()
        if devices > len(avail):
            raise ValueError(
                f"devices={devices} but only {len(avail)} JAX device(s) "
                "are visible; on CPU hosts set XLA_FLAGS="
                "--xla_force_host_platform_device_count=N before importing "
                "jax")
        devices = avail[:devices]
    return Mesh(np.asarray(devices), (ENSEMBLE_AXIS,))


def ensemble_streams(workload, keys, *, L: int = 8, K: int = 16,
                     A_max: int = 8, horizon: int = 10_000,
                     fault_rate: float = 0.0, repair_rate: float = 1.0):
    """(G, ...)-batched ``SchedStreams``, one member per key.

    ``jax.vmap(make_streams)`` preserves the exact per-key chain, so member
    g's planes are bit-identical to ``make_streams(keys[g], ...)`` — the
    invariant that makes chunked/sharded Monte-Carlo interchangeable with
    the per-key engines."""
    workload.check_sampler()
    return jax.vmap(
        lambda k: make_streams(k, workload.lam, workload.mu,
                               workload.sampler, L=L, K=K, A_max=A_max,
                               horizon=horizon,
                               num_resources=workload.num_resources,
                               fault_rate=fault_rate,
                               repair_rate=repair_rate))(keys)


def _check_divides(G: int, mesh: Mesh) -> None:
    ndev = mesh.devices.size
    if G % ndev:
        raise ValueError(
            f"ensemble size G={G} must divide evenly over the {ndev}-device "
            f"mesh (equal per-device shards); pad the key batch or change "
            "the device count")


#: Memoized shard_mapped+jitted runners.  ``shard_map`` re-traces (and the
#: surrounding jit recompiles) whenever it is handed a NEW closure, so
#: building one per call would pay full compilation on EVERY
#: ``monte_carlo_policy(..., mesh=)`` invocation; caching on the launch
#: identity — workload (frozen dataclass), policy, engine, mesh, sorted
#: config — makes repeated sharded launches as cheap as the unsharded
#: engines' own jit caches.
_RUNNERS: dict = {}


def _sharded_runner(workload, *, spec, mesh, engine, config):
    axis = mesh.axis_names[0]

    @functools.partial(shard_map, mesh=mesh, in_specs=P(axis),
                       out_specs=P(axis), check_rep=False)
    def run(local_keys):
        return spec.monte_carlo(workload, local_keys, engine=engine,
                                **config)

    return jax.jit(run)


def sharded_monte_carlo(workload, keys, *, policy: str = "bfjs",
                        mesh: Mesh, engine: str = "scan",
                        **config):
    """Run a registered policy's Monte-Carlo with G sharded over ``mesh``.

    Each device runs the unmodified per-policy program
    (``get_policy(policy).monte_carlo``) on its local G/D key shard;
    outputs come back as one global ``(G, ...)`` pytree laid out across
    the mesh.  ``engine="reference"`` ignores the mesh (host-side oracle).
    """
    from .api import get_policy

    spec = get_policy(policy)
    if engine == "reference":
        return spec.monte_carlo(workload, keys, engine=engine, **config)
    _check_divides(int(keys.shape[0]), mesh)
    try:
        cache_key = (workload, policy, engine, mesh,
                     tuple(sorted(config.items())))
        run = _RUNNERS.get(cache_key)
    except TypeError:           # unhashable config value: run uncached
        cache_key, run = None, None
    if run is None:
        run = _sharded_runner(workload, spec=spec, mesh=mesh,
                              engine=engine, config=config)
        if cache_key is not None:
            _RUNNERS[cache_key] = run
    return run(keys)


def monte_carlo_chunked(workload, keys, *, policy: str = "bfjs",
                        chunk: int, mesh: Mesh | None = None,
                        checkpoint_dir: str | None = None,
                        resume: bool = False,
                        stop_after_chunks: int | None = None,
                        horizon: int = 10_000, fault_rate: float = 0.0,
                        repair_rate: float = 1.0, **config):
    """Crash-safe chunked Monte-Carlo, optionally mesh-sharded.

    Pre-generates the whole ensemble's streams (bit-identical to the
    per-key chains the straight Monte-Carlo path draws), then runs
    ``core.engine.chunked.run_chunked`` with the ensemble axis vmapped —
    and, with ``mesh=``, shard_mapped — inside each chunk.  Checkpoints
    store the full ``(G, ...)`` carry host-side and never pin a device
    count, so ``resume=True`` continues on any mesh whose size divides G.
    """
    if mesh is not None:
        _check_divides(int(keys.shape[0]), mesh)
    streams = ensemble_streams(
        workload, keys, L=config.get("L", 8), K=config.get("K", 16),
        A_max=config.get("A_max", 8), horizon=horizon,
        fault_rate=fault_rate, repair_rate=repair_rate)
    if policy == "bfjs-mr" and "capacity" not in config:
        config["capacity"] = workload.capacity
    from .chunked import run_chunked
    return run_chunked(streams, policy=policy, chunk=chunk, mesh=mesh,
                       checkpoint_dir=checkpoint_dir, resume=resume,
                       stop_after_chunks=stop_after_chunks, **config)
