"""Mamba2 mixer — SSD (state-space duality), arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm: within-chunk quadratic form
plus an inter-chunk linear state recurrence, expressed as ONE ``lax.scan``
over chunks so the (Lc x Lc) decay matrix only ever exists for the current
chunk.  Decode is the O(1)-state recurrence.  kernels/ssd_scan provides the
Pallas TPU version of the chunk kernel; this module is the jnp oracle-grade
implementation used under pjit.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import cdtype, dense_init


def mamba_init(key, cfg: ModelConfig):
    D = cfg.d_model
    din = cfg.d_inner
    G, N, nh = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_dim = din + 2 * G * N
    zdim = 2 * din + 2 * G * N + nh          # [z, x, B, C, dt]
    ks = jax.random.split(key, 4)
    return {
        "w_in": dense_init(ks[0], (D, zdim)),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim),
                                    jnp.float32) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "D_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((nh,), 0.01, jnp.float32))),
        "norm_scale": jnp.ones((din,), jnp.float32),
        "w_out": dense_init(ks[2], (din, D)),
    }


class MambaCache(NamedTuple):
    conv: jax.Array   # (B, k-1, conv_dim) last inputs to the causal conv
    ssm: jax.Array    # (B, nh, hd, N) state
    length: jax.Array


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=None) -> MambaCache:
    dt = dtype or cdtype(cfg)
    G, N = cfg.ssm_groups, cfg.ssm_state
    conv_dim = cfg.d_inner + 2 * G * N
    return MambaCache(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dt),
        ssm=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, N), jnp.float32),
        length=jnp.zeros((), jnp.int32),
    )


def _split_proj(params, x, cfg: ModelConfig):
    dt_ = cdtype(cfg)
    din, G, N, nh = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = x @ params["w_in"].astype(dt_)
    z, xbc, dt_raw = jnp.split(zxbcdt, [din, 2 * din + 2 * G * N], axis=-1)
    return z, xbc, dt_raw


def _causal_conv(params, xbc, cfg: ModelConfig):
    """Depthwise causal conv1d + SiLU over the [x, B, C] channels."""
    k = cfg.ssm_conv
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    w = params["conv_w"].astype(xbc.dtype)                 # (k, conv_dim)
    out = sum(pad[:, i : i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + params["conv_b"].astype(xbc.dtype))


def _gated_norm(params, y, z, eps):
    g = y * jax.nn.silu(z)
    gf = g.astype(jnp.float32)
    var = jnp.mean(gf * gf, axis=-1, keepdims=True)
    out = gf * jax.lax.rsqrt(var + eps) * params["norm_scale"]
    return out.astype(y.dtype)


def _segsum(a):
    """a: (B, L, H) -> (B, H, L, L) lower-triangular pairwise sums
    exp-arg[i,j] = sum_{k=j+1..i} a_k for i >= j."""
    cs = jnp.cumsum(a, axis=1)                              # (B, L, H)
    d = cs[:, :, None, :] - cs[:, None, :, :]               # (B, L, L, H)
    L = a.shape[1]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask[None, :, :, None], d, -jnp.inf).transpose(0, 3, 1, 2)


def ssd_chunk_scan(xdt, Bm, Cm, a, state0, unroll: bool = False):
    """The SSD core over pre-chunked inputs.

    xdt: (B, nc, Lc, H, P)  -- dt * x
    Bm, Cm: (B, nc, Lc, H, N)
    a:   (B, nc, Lc, H)     -- dt * A (negative)
    state0: (B, H, P, N)
    Returns y: (B, nc, Lc, H, P), final state.
    """

    def body(S, inp):
        x_c, B_c, C_c, a_c = inp                          # leading axis = chunk
        cs = jnp.cumsum(a_c, axis=1)                       # (B, Lc, H)
        Lmat = jnp.exp(_segsum(a_c))                       # (B, H, Lc, Lc)
        y_diag = jnp.einsum("blhn,bshn,bhls,bshp->blhp",
                            C_c, B_c, Lmat, x_c)
        decay_out = jnp.exp(cs)                            # (B, Lc, H)
        y_off = jnp.einsum("blhn,bhpn,blh->blhp", C_c, S, decay_out)
        decay_state = jnp.exp(cs[:, -1:, :] - cs)          # (B, Lc, H)
        new_states = jnp.einsum("blhn,blh,blhp->bhpn",
                                B_c, decay_state, x_c)
        S = S * jnp.exp(cs[:, -1, :])[:, :, None, None] + new_states
        return S, y_diag + y_off

    # scan over the chunk axis
    xs = (xdt.swapaxes(0, 1), Bm.swapaxes(0, 1), Cm.swapaxes(0, 1),
          a.swapaxes(0, 1))
    state, y = jax.lax.scan(body, state0, xs, unroll=unroll)
    return y.swapaxes(0, 1), state


def mamba_apply(params, x, positions, cfg: ModelConfig) -> jax.Array:
    """Full-sequence SSD. x: (B, S, D)."""
    del positions
    dt_ = cdtype(cfg)
    B, S, D = x.shape
    din, G, N = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    nh, P = cfg.ssm_heads, cfg.ssm_head_dim
    Lc = min(cfg.ssm_chunk, S)
    assert S % Lc == 0, f"seq {S} % chunk {Lc}"
    nc = S // Lc

    z, xbc, dt_raw = _split_proj(params, x, cfg)
    xbc = _causal_conv(params, xbc, cfg)
    xs, Bc, Cc = jnp.split(xbc, [din, din + G * N], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"])              # (B,S,nh)
    A = -jnp.exp(params["A_log"])                          # (nh,)
    a = dt * A                                             # (B,S,nh)

    xh = xs.reshape(B, S, nh, P).astype(jnp.float32)
    xdt = xh * dt[..., None]
    heads_per_group = nh // G
    Bm = jnp.repeat(Bc.reshape(B, S, G, N), heads_per_group, axis=2
                    ).astype(jnp.float32)
    Cm = jnp.repeat(Cc.reshape(B, S, G, N), heads_per_group, axis=2
                    ).astype(jnp.float32)

    chunk = lambda t: t.reshape(B, nc, Lc, *t.shape[2:])
    state0 = jnp.zeros((B, nh, P, N), jnp.float32)
    y, _ = ssd_chunk_scan(chunk(xdt), chunk(Bm), chunk(Cm),
                          chunk(a), state0, unroll=cfg.unroll_scans)
    y = y.reshape(B, S, nh, P) + params["D_skip"][None, None, :, None] * xh
    y = y.reshape(B, S, din).astype(dt_)
    y = _gated_norm(params, y, z, cfg.norm_eps)
    return y @ params["w_out"].astype(dt_)


def mamba_decode(params, x, pos, cache: MambaCache, cfg: ModelConfig
                 ) -> tuple[jax.Array, MambaCache]:
    """One-token recurrence. x: (B, 1, D)."""
    del pos
    dt_ = cdtype(cfg)
    B = x.shape[0]
    din, G, N = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    nh, P = cfg.ssm_heads, cfg.ssm_head_dim

    z, xbc, dt_raw = _split_proj(params, x, cfg)           # (B,1,*)
    window = jnp.concatenate([cache.conv, xbc.astype(cache.conv.dtype)], axis=1)
    w = params["conv_w"].astype(xbc.dtype)                 # (k, conv_dim)
    conv_out = (window * w[None]).sum(axis=1) + params["conv_b"].astype(xbc.dtype)
    xbc1 = jax.nn.silu(conv_out)                           # (B, conv_dim)
    xs, Bc, Cc = jnp.split(xbc1, [din, din + G * N], axis=-1)

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * A)                                # (B, nh)

    xh = xs.reshape(B, nh, P).astype(jnp.float32)
    hpg = nh // G
    Bm = jnp.repeat(Bc.reshape(B, G, N), hpg, axis=1).astype(jnp.float32)
    Cm = jnp.repeat(Cc.reshape(B, G, N), hpg, axis=1).astype(jnp.float32)

    S = cache.ssm * decay[:, :, None, None] + jnp.einsum(
        "bhp,bhn->bhpn", xh * dt[..., None], Bm)
    y = jnp.einsum("bhpn,bhn->bhp", S, Cm) + params["D_skip"][None, :, None] * xh
    y = y.reshape(B, 1, din).astype(dt_)
    y = _gated_norm(params, y, z, cfg.norm_eps)
    new_cache = MambaCache(window[:, 1:], S, cache.length + 1)
    return y @ params["w_out"].astype(dt_), new_cache
