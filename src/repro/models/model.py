"""The unified causal LM over all 10 architectures.

Decoder = ``lax.scan`` over ``num_periods`` stacked super-blocks; each
super-block unrolls the period's layer descriptors (1 for homogeneous models,
8 for Jamba).  HLO size therefore stays ~one period regardless of depth —
essential for compiling 88-layer configs in the dry-run.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .blocks import (layer_apply, layer_cache_init, layer_decode, layer_init)
from .config import ModelConfig
from .layers import (cdtype, embed_apply, embed_init, head_apply, rms_norm,
                     rms_norm_init, softmax_cross_entropy)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    program = cfg.layer_program()
    keys = jax.random.split(key, len(program) + 1)
    params: dict[str, Any] = embed_init(keys[-1], cfg)
    layers = {}
    for p, desc in enumerate(program):
        pk = jax.random.split(keys[p], cfg.num_periods)
        layers[f"p{p}"] = jax.vmap(
            functools.partial(layer_init, cfg=cfg, desc=desc))(pk)
    params["layers"] = layers
    params["final_norm"] = rms_norm_init(cfg.d_model)
    return params


def init_abstract(cfg: ModelConfig) -> dict:
    """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------
def constrain_residual(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Pin the (B, S, D) residual stream to batch-DP sharding at layer
    boundaries.  Without this XLA SPMD may choose a weight-stationary
    strategy per layer (all-reducing batch-replicated activations) —
    catastrophic at depth (§Perf)."""
    if not cfg.act_shard:
        return x
    from jax.sharding import PartitionSpec as P
    dp, _ = cfg.act_shard
    return jax.lax.with_sharding_constraint(x, P(dp, None, None))


def _stack_body(cfg: ModelConfig, program):
    def body(x_and_pos, period_params):
        x, positions = x_and_pos
        aux_sum = jnp.zeros((), jnp.float32)
        for p, desc in enumerate(program):
            x = constrain_residual(x, cfg)
            x, aux = layer_apply(period_params[f"p{p}"], x, positions, cfg, desc)
            if "moe_aux_loss" in aux:
                aux_sum = aux_sum + aux["moe_aux_loss"]
        return (constrain_residual(x, cfg), positions), aux_sum
    return body


def forward(params: dict, cfg: ModelConfig, *,
            tokens: jax.Array | None = None,
            embeds: jax.Array | None = None,
            positions: jax.Array | None = None) -> tuple[jax.Array, dict]:
    """Returns (logits (B,S,V) , aux metrics)."""
    if tokens is not None:
        x = embed_apply(params, tokens, cfg)
        B, S = tokens.shape
    else:
        x = embeds.astype(cdtype(cfg))
        B, S = embeds.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    program = cfg.layer_program()
    body = _stack_body(cfg, program)
    if cfg.remat and cfg.remat_policy != "full":
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots"
                  else jax.checkpoint_policies.nothing_saveable)
        body = jax.checkpoint(body, policy=policy)
    (x, _), aux = jax.lax.scan(body, (x, positions), params["layers"],
                               unroll=cfg.unroll_scans)

    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = head_apply(params, x, cfg)
    return logits, {"moe_aux_loss": aux.sum()}


def loss_fn(params: dict, cfg: ModelConfig, batch: dict,
            aux_weight: float = 0.01) -> tuple[jax.Array, dict]:
    logits, aux = forward(
        params, cfg,
        tokens=batch.get("tokens"),
        embeds=batch.get("embeds"),
    )
    loss = softmax_cross_entropy(logits, batch["labels"], batch.get("mask"))
    total = loss + aux_weight * aux["moe_aux_loss"]
    return total, {"ce_loss": loss, **aux}


# ---------------------------------------------------------------------------
# decode (serve)
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    """Per-period-position caches stacked over periods (scan-compatible)."""
    program = cfg.layer_program()
    caches = {}
    for p, desc in enumerate(program):
        one = layer_cache_init(cfg, desc, batch, cache_len)
        caches[f"p{p}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.num_periods, *a.shape)),
            one)
    return caches


def init_cache_abstract(cfg: ModelConfig, batch: int, cache_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, cache_len))


def decode_step(params: dict, cfg: ModelConfig, tokens_or_embeds: jax.Array,
                pos: jax.Array, caches) -> tuple[jax.Array, Any]:
    """One decode step for the whole batch.

    tokens_or_embeds: (B, 1) int tokens or (B, 1, D) embeds; pos: () int32 —
    current absolute position (cache fill level).
    Returns (logits (B, 1, V), updated caches).
    """
    if tokens_or_embeds.ndim == 2:
        x = embed_apply(params, tokens_or_embeds, cfg)
    else:
        x = tokens_or_embeds.astype(cdtype(cfg))

    program = cfg.layer_program()

    def body(x, scanned):
        period_params, cache = scanned
        new_cache = {}
        for p, desc in enumerate(program):
            x, new_cache[f"p{p}"] = layer_decode(
                period_params[f"p{p}"], x, pos, cache[f"p{p}"], cfg, desc)
        return x, new_cache

    x, new_caches = jax.lax.scan(body, x, (params["layers"], caches),
                                 unroll=cfg.unroll_scans)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = head_apply(params, x, cfg)
    return logits, new_caches


def prefill(params: dict, cfg: ModelConfig, *,
            tokens: jax.Array | None = None,
            embeds: jax.Array | None = None) -> jax.Array:
    """Prefill = forward pass returning last-position logits (B, V); the
    serving engine uses this for admission-time scoring."""
    logits, _ = forward(params, cfg, tokens=tokens, embeds=embeds)
    return logits[:, -1]
