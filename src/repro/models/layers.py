"""Shared layers: RMSNorm, SwiGLU MLP, embeddings, RoPE. Pure functions over
parameter pytrees (nested dicts of jnp arrays); params live in fp32, compute
runs in cfg.dtype (bf16 by default)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, in_axis: int = 0, scale: float = 1.0):
    fan_in = shape[in_axis]
    std = scale / jnp.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32) * std)


# -- RMSNorm -----------------------------------------------------------------
def rms_norm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(params, x, eps: float):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(dt)


# -- SwiGLU MLP ---------------------------------------------------------------
def mlp_init(key, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff)),
        "w_in": dense_init(k2, (d_model, d_ff)),
        "w_out": dense_init(k3, (d_ff, d_model)),
    }


def mlp_apply(params, x, cfg: ModelConfig):
    dt = cdtype(cfg)
    g = x @ params["w_gate"].astype(dt)
    h = x @ params["w_in"].astype(dt)
    return (jax.nn.silu(g) * h) @ params["w_out"].astype(dt)


# -- Embedding / LM head --------------------------------------------------------
def embed_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    p = {"embed": {"w": jax.random.normal(k1, (cfg.vocab_size, cfg.d_model),
                                          jnp.float32) * 0.02}}
    if not cfg.tie_embeddings:
        p["head"] = {"w": dense_init(k2, (cfg.d_model, cfg.vocab_size))}
    return p


def embed_apply(params, tokens, cfg: ModelConfig):
    return params["embed"]["w"].astype(cdtype(cfg))[tokens]


def head_apply(params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        w = params["embed"]["w"].T
    else:
        w = params["head"]["w"]
    logits = x @ w.astype(x.dtype)
    return logits.astype(jnp.float32) if cfg.logits_fp32 else logits


# -- RoPE ----------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                     # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- losses ----------------------------------------------------------------------
def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: jax.Array | None = None) -> jax.Array:
    """Mean token cross-entropy; logits (B,S,V) fp32, labels (B,S) int."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is None:
        return nll.mean()
    mask = mask.astype(nll.dtype)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
