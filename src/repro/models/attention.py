"""GQA attention: chunked (flash-style) prefill/train path with O(S·chunk)
memory, and a single-token decode path over a (optionally sliding-window
ring) KV cache.  The Pallas kernel in kernels/flash_attention implements the
same math for the TPU hot path; this module is the composable jnp version
used under pjit (XLA SPMD shards it by batch/heads).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_rope, cdtype, dense_init

NEG_INF = -1e30


def constrain_heads(x, cfg: ModelConfig):
    """Pin (B, S, H, hd) activations to batch-DP x head-TP sharding when the
    launcher enabled act_shard and the head count divides the TP axis.
    Without this, XLA's SPMD fallback for the GQA einsums is replicated
    compute over the model axis (16x the attention FLOPs per chip)."""
    if not cfg.act_shard:
        return x
    dp, tp = cfg.act_shard
    heads = x.shape[2]
    tp_ax = tp if heads % max(cfg.tp_size, 1) == 0 else None
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(dp, None, tp_ax, None))


def attn_init(key, cfg: ModelConfig):
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, H * hd)),
        "wk": dense_init(ks[1], (D, KV * hd)),
        "wv": dense_init(ks[2], (D, KV * hd)),
        "wo": dense_init(ks[3], (H * hd, D)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), jnp.float32)
        p["bk"] = jnp.zeros((KV * hd,), jnp.float32)
        p["bv"] = jnp.zeros((KV * hd,), jnp.float32)
    return p


class KVCache(NamedTuple):
    k: jax.Array      # (B, C, KV, hd) — C = cache length (window for SWA)
    v: jax.Array      # (B, C, KV, hd)
    length: jax.Array  # () int32: total tokens written so far


def init_kv_cache(cfg: ModelConfig, batch: int, cache_len: int,
                  dtype=None) -> KVCache:
    C = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    dt = dtype or cdtype(cfg)
    return KVCache(
        k=jnp.zeros((batch, C, KV, hd), dt),
        v=jnp.zeros((batch, C, KV, hd), dt),
        length=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# chunked causal attention (train / prefill)
# ---------------------------------------------------------------------------
def chunked_attention(q, k, v, *, causal: bool = True, window: int = 0,
                      q_chunk: int = 1024, kv_chunk: int = 1024,
                      base_q_pos: int = 0, unroll: bool = False) -> jax.Array:
    """Flash-style two-level chunking with online softmax.

    q: (B, Sq, H, hd); k: (B, Sk, H, hd); v: (B, Sk, H, hd_v).
    GQA callers expand K/V to H heads BEFORE this call (a free repeat of
    replicated tensors) so every einsum is cleanly head-sharded — the
    grouped (KV, G) reshape forces XLA SPMD into replicated attention
    compute (EXPERIMENTS.md §Perf iteration 1).
    Memory is O(B*H*q_chunk*kv_chunk) scores instead of O(Sq*Sk).
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    assert k.shape[2] == H and v.shape[2] == H
    hd_v = v.shape[-1]
    scale = hd**-0.5
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    assert Sq % q_chunk == 0 and Sk % kv_chunk == 0

    qc = q.reshape(B, Sq // q_chunk, q_chunk, H, hd).swapaxes(0, 1)
    kc = k.reshape(B, Sk // kv_chunk, kv_chunk, H, hd).swapaxes(0, 1)
    vc = v.reshape(B, Sk // kv_chunk, kv_chunk, H, hd_v).swapaxes(0, 1)

    def q_block(qi, q_blk):
        q_pos = base_q_pos + qi * q_chunk + jnp.arange(q_chunk)

        def kv_block(carry, inputs):
            acc, m, l = carry
            ki, k_blk, v_blk = inputs
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            # scores: (B, q_chunk, H, kv_chunk)
            s = jnp.einsum("bqhe,bshe->bqhs", q_blk.astype(jnp.float32),
                           k_blk.astype(jnp.float32)) * scale
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window:
                mask &= k_pos[None, :] > q_pos[:, None] - window
            s = jnp.where(mask[None, :, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bqhs,bshe->bqhe", p,
                            v_blk.astype(jnp.float32))
            acc = acc * corr[..., None] + pv
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((B, q_chunk, H, hd_v), jnp.float32)
        m0 = jnp.full((B, q_chunk, H), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, q_chunk, H), jnp.float32)
        ks_idx = jnp.arange(Sk // kv_chunk)
        (acc, m, l), _ = jax.lax.scan(kv_block, (acc0, m0, l0),
                                      (ks_idx, kc, vc), unroll=unroll)
        return acc / jnp.maximum(l[..., None], 1e-30)

    def q_scan(_, args):
        return None, q_block(*args)

    _, out = jax.lax.scan(q_scan, None,
                          (jnp.arange(Sq // q_chunk), qc), unroll=unroll)
    out = out.swapaxes(0, 1).reshape(B, Sq, H, hd_v)
    return out.astype(q.dtype)


def attn_apply(params, x, positions, cfg: ModelConfig, *,
               q_chunk: int = 0, kv_chunk: int = 0) -> jax.Array:
    """Full-sequence causal attention (train / prefill)."""
    q_chunk = q_chunk or cfg.q_chunk
    kv_chunk = kv_chunk or cfg.kv_chunk
    dt = cdtype(cfg)
    B, S, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = x @ params["wq"].astype(dt)
    k = x @ params["wk"].astype(dt)
    v = x @ params["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    # expand KV to H heads, then pin head-sharded layout (TP over heads)
    groups = H // KV
    if groups > 1:
        k = jnp.repeat(k, groups, axis=2)
        v = jnp.repeat(v, groups, axis=2)
    q = constrain_heads(q, cfg)
    k = constrain_heads(k, cfg)
    v = constrain_heads(v, cfg)
    out = chunked_attention(q, k, v, causal=True, window=cfg.sliding_window,
                            q_chunk=q_chunk, kv_chunk=kv_chunk,
                            unroll=cfg.unroll_scans)
    out = constrain_heads(out, cfg)
    return out.reshape(B, S, H * hd) @ params["wo"].astype(dt)


# ---------------------------------------------------------------------------
# decode path
# ---------------------------------------------------------------------------
def attn_decode(params, x, pos, cache: KVCache, cfg: ModelConfig
                ) -> tuple[jax.Array, KVCache]:
    """One-token decode. x: (B, 1, D); pos: () int32 absolute position.

    The cache is a ring buffer of length C (= sliding window for SWA models,
    else the max context); attention is masked to valid / in-window entries.
    """
    dt = cdtype(cfg)
    B, _, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    C = cache.k.shape[1]

    q = x @ params["wq"].astype(dt)
    k = x @ params["wk"].astype(dt)
    v = x @ params["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    q = q.reshape(B, 1, H, hd)
    k = k.reshape(B, 1, KV, hd)
    v = v.reshape(B, 1, KV, hd)
    posv = jnp.full((B, 1), pos, jnp.int32)
    q = apply_rope(q, posv, cfg.rope_theta)
    k = apply_rope(k, posv, cfg.rope_theta)

    slot = pos % C
    new_k = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), slot, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), slot, axis=1)
    cache = KVCache(new_k, new_v, pos + 1)

    # positions stored in each ring slot (for masking)
    idx = jnp.arange(C)
    stored_pos = jnp.where(idx <= slot, pos - (slot - idx), pos - (slot + C - idx))
    valid = stored_pos >= 0
    if cfg.sliding_window:
        valid &= stored_pos > pos - cfg.sliding_window

    groups = H // KV
    qr = q.reshape(B, KV, groups, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bckh->bkgc", qr, new_k.astype(jnp.float32)) * hd**-0.5
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgc,bckh->bkgh", p, new_v.astype(jnp.float32))
    o = o.reshape(B, 1, H * hd).astype(dt)
    return o @ params["wo"].astype(dt), cache
