"""Multi-head Latent Attention (DeepSeek-V2): the KV cache is a compressed
latent c_kv (kv_lora_rank) plus a shared rotary key (qk_rope_dim) per token —
~an order of magnitude smaller than GQA caches.  Decode decompresses K/V
through the up-projections; prefill materializes K/V per chunk inside the
flash-style loop so full K/V for the sequence never exists at once.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .attention import chunked_attention
from .config import ModelConfig
from .layers import apply_rope, cdtype, dense_init

NEG_INF = -1e30


def mla_init(key, cfg: ModelConfig):
    D, H = cfg.d_model, cfg.num_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    ks = jax.random.split(key, 5)
    return {
        "wq": dense_init(ks[0], (D, H * qd)),
        "w_dkv": dense_init(ks[1], (D, cfg.kv_lora_rank)),
        "w_kr": dense_init(ks[2], (D, cfg.qk_rope_dim)),
        "w_uk": dense_init(ks[3], (cfg.kv_lora_rank, H * cfg.qk_nope_dim)),
        "w_uv": dense_init(ks[3], (cfg.kv_lora_rank, H * cfg.v_head_dim)),
        "wo": dense_init(ks[4], (H * cfg.v_head_dim, D)),
    }


class MLACache(NamedTuple):
    c_kv: jax.Array    # (B, C, kv_lora_rank)
    k_rope: jax.Array  # (B, C, qk_rope_dim)
    length: jax.Array


def init_mla_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=None) -> MLACache:
    dt = dtype or cdtype(cfg)
    return MLACache(
        c_kv=jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dt),
        k_rope=jnp.zeros((batch, cache_len, cfg.qk_rope_dim), dt),
        length=jnp.zeros((), jnp.int32),
    )


def _project_qkv(params, x, positions, cfg: ModelConfig):
    dt = cdtype(cfg)
    B, S, _ = x.shape
    H = cfg.num_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    q = (x @ params["wq"].astype(dt)).reshape(B, S, H, qd)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv = x @ params["w_dkv"].astype(dt)                       # (B,S,r)
    k_rope = x @ params["w_kr"].astype(dt)                      # (B,S,rd)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, c_kv, k_rope


def _decompress(params, c_kv, cfg: ModelConfig):
    dt = c_kv.dtype
    B, S, _ = c_kv.shape
    H = cfg.num_heads
    k_nope = (c_kv @ params["w_uk"].astype(dt)).reshape(B, S, H, cfg.qk_nope_dim)
    v = (c_kv @ params["w_uv"].astype(dt)).reshape(B, S, H, cfg.v_head_dim)
    return k_nope, v


def mla_apply(params, x, positions, cfg: ModelConfig, *,
              q_chunk: int = 0, kv_chunk: int = 0) -> jax.Array:
    """Full-sequence causal MLA (train / prefill)."""
    q_chunk = q_chunk or cfg.q_chunk
    kv_chunk = kv_chunk or cfg.kv_chunk
    dt = cdtype(cfg)
    B, S, _ = x.shape
    H = cfg.num_heads
    q_nope, q_rope, c_kv, k_rope = _project_qkv(params, x, positions, cfg)
    k_nope, v = _decompress(params, c_kv, cfg)
    # fold the shared rotary key into per-head keys; queries concat likewise
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                (B, S, H, cfg.qk_rope_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    from .attention import constrain_heads
    q = constrain_heads(q, cfg)
    k = constrain_heads(k, cfg)
    v = constrain_heads(v, cfg)
    out = chunked_attention(q, k, v, causal=True,
                            q_chunk=q_chunk, kv_chunk=kv_chunk,
                            unroll=cfg.unroll_scans)
    out = out.reshape(B, S, H * cfg.v_head_dim)
    return out @ params["wo"].astype(dt)


def mla_decode(params, x, pos, cache: MLACache, cfg: ModelConfig
               ) -> tuple[jax.Array, MLACache]:
    """One-token decode against the compressed cache."""
    dt = cdtype(cfg)
    B, _, D = x.shape
    H = cfg.num_heads
    C = cache.c_kv.shape[1]
    posv = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope, c_kv_new, k_rope_new = _project_qkv(params, x, posv, cfg)

    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache.c_kv, c_kv_new.astype(cache.c_kv.dtype), pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache.k_rope, k_rope_new.astype(cache.k_rope.dtype), pos, axis=1)
    cache = MLACache(c_kv, k_rope, pos + 1)

    valid = jnp.arange(C) <= pos
    rd = cfg.qk_rope_dim
    scale = (cfg.qk_nope_dim + rd) ** -0.5
    if cfg.mla_absorb:
        # Absorbed attention: fold w_uk into the query and w_uv into the
        # output so scores/values are taken against the (B, C, r) latent —
        # O(C*r) per head-step instead of O(C*r*head_dim) decompression.
        r = cfg.kv_lora_rank
        w_uk = params["w_uk"].astype(jnp.float32).reshape(
            r, H, cfg.qk_nope_dim)
        q_abs = jnp.einsum("bqhd,rhd->bhr", q_nope.astype(jnp.float32), w_uk)
        s = jnp.einsum("bhr,bcr->bhc", q_abs, c_kv.astype(jnp.float32))
        s = s + jnp.einsum("bqhd,bcd->bhc", q_rope.astype(jnp.float32),
                           k_rope.astype(jnp.float32))
        s = jnp.where(valid[None, None, :], s * scale, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhc,bcr->bhr", p, c_kv.astype(jnp.float32))
        w_uv = params["w_uv"].astype(jnp.float32).reshape(
            r, H, cfg.v_head_dim)
        o = jnp.einsum("bhr,rhd->bhd", o_lat, w_uv)
    else:
        # baseline: decompress K/V for the whole cache, then attend
        k_nope, v = _decompress(params, c_kv, cfg)           # (B,C,H,*)
        s = jnp.einsum("bqhd,bchd->bhc", q_nope.astype(jnp.float32),
                       k_nope.astype(jnp.float32))
        s = s + jnp.einsum("bqhd,bcd->bhc", q_rope.astype(jnp.float32),
                           k_rope.astype(jnp.float32))
        s = jnp.where(valid[None, None, :], s * scale, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhc,bchd->bhd", p, v.astype(jnp.float32))
    o = o.reshape(B, 1, H * cfg.v_head_dim).astype(dt)
    return o @ params["wo"].astype(dt), cache
