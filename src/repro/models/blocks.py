"""Decoder blocks: (pre-norm mixer + residual) -> (pre-norm FFN + residual),
with the mixer/FFN kinds chosen by the layer descriptor (dense / MoE / Mamba /
GQA / MLA / SWA)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import attn_apply, attn_decode, attn_init, init_kv_cache
from .config import FFNKind, MixerKind, ModelConfig
from .layers import mlp_apply, mlp_init, rms_norm, rms_norm_init
from .mamba2 import init_mamba_cache, mamba_apply, mamba_decode, mamba_init
from .mla import init_mla_cache, mla_apply, mla_decode, mla_init
from .moe import moe_apply, moe_init


def layer_init(key, cfg: ModelConfig, desc: tuple[MixerKind, FFNKind]):
    mixer_kind, ffn_kind = desc
    k1, k2 = jax.random.split(key)
    p = {"mixer_norm": rms_norm_init(cfg.d_model)}
    if mixer_kind == "attn":
        p["mixer"] = attn_init(k1, cfg)
    elif mixer_kind == "mla":
        p["mixer"] = mla_init(k1, cfg)
    else:
        p["mixer"] = mamba_init(k1, cfg)
    if ffn_kind != "none":
        p["ffn_norm"] = rms_norm_init(cfg.d_model)
        p["ffn"] = mlp_init(k2, cfg.d_model, cfg.d_ff) if ffn_kind == "dense" \
            else moe_init(k2, cfg)
    return p


def layer_apply(params, x, positions, cfg: ModelConfig,
                desc: tuple[MixerKind, FFNKind]) -> tuple[jax.Array, dict]:
    """Full-sequence (train / prefill) layer."""
    mixer_kind, ffn_kind = desc
    h = rms_norm(params["mixer_norm"], x, cfg.norm_eps)
    if mixer_kind == "attn":
        h = attn_apply(params["mixer"], h, positions, cfg)
    elif mixer_kind == "mla":
        h = mla_apply(params["mixer"], h, positions, cfg)
    else:
        h = mamba_apply(params["mixer"], h, positions, cfg)
    x = x + h
    aux: dict = {}
    if ffn_kind != "none":
        h = rms_norm(params["ffn_norm"], x, cfg.norm_eps)
        if ffn_kind == "dense":
            h = mlp_apply(params["ffn"], h, cfg)
        else:
            h, aux = moe_apply(params["ffn"], h, cfg)
        x = x + h
    return x, aux


def layer_cache_init(cfg: ModelConfig, desc, batch: int, cache_len: int):
    mixer_kind, _ = desc
    if mixer_kind == "attn":
        return init_kv_cache(cfg, batch, cache_len)
    if mixer_kind == "mla":
        return init_mla_cache(cfg, batch, cache_len)
    return init_mamba_cache(cfg, batch)


def layer_decode(params, x, pos, cache, cfg: ModelConfig, desc):
    """One-token decode step. x: (B, 1, D)."""
    mixer_kind, ffn_kind = desc
    h = rms_norm(params["mixer_norm"], x, cfg.norm_eps)
    if mixer_kind == "attn":
        h, cache = attn_decode(params["mixer"], h, pos, cache, cfg)
    elif mixer_kind == "mla":
        h, cache = mla_decode(params["mixer"], h, pos, cache, cfg)
    else:
        h, cache = mamba_decode(params["mixer"], h, pos, cache, cfg)
    x = x + h
    if ffn_kind != "none":
        h = rms_norm(params["ffn_norm"], x, cfg.norm_eps)
        if ffn_kind == "dense":
            h = mlp_apply(params["ffn"], h, cfg)
        else:
            h, _ = moe_apply(params["ffn"], h, cfg)
        x = x + h
    return x, cache
