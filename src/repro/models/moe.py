"""Token-choice top-k Mixture-of-Experts with capacity-based dispatch.

The dispatch is gather/scatter (sort tokens by expert, truncate at capacity)
rather than dense one-hot einsum, so compiled FLOPs scale with ACTIVATED
parameters (6*N_active*D accounting) instead of all experts.  Experts shard
over the `model` mesh axis (EP); shared experts are plain TP MLPs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import cdtype, dense_init, mlp_apply, mlp_init


def moe_init(key, cfg: ModelConfig):
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (D, E), scale=0.1),
        "w_gate": dense_init(ks[1], (E, D, F)),
        "w_in": dense_init(ks[2], (E, D, F)),
        "w_out": dense_init(ks[3], (E, F, D)),
    }
    if cfg.num_shared_experts:
        p["shared"] = mlp_init(ks[4], D, cfg.moe_d_ff * cfg.num_shared_experts)
    return p


def moe_apply(params, x, cfg: ModelConfig) -> tuple[jax.Array, dict]:
    """x: (B, S, D) -> (B, S, D), aux metrics (load-balance loss, drop rate)."""
    dt = cdtype(cfg)
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    capacity = int(cfg.capacity_factor * T * k / E) + 1

    xf = x.reshape(T, D)
    logits = (xf @ params["router"].astype(dt)).astype(jnp.float32)  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                           # (T,k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance auxiliary (Switch-style) -------------------------
    me = probs.mean(axis=0)                                          # (E,)
    ce = jnp.zeros(E).at[top_e.reshape(-1)].add(1.0) / (T * k)
    aux_loss = E * jnp.sum(me * ce)

    # ---- capacity dispatch via sort ------------------------------------
    e_flat = top_e.reshape(-1)                                       # (T*k,)
    w_flat = top_w.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(e_flat, stable=True)
    e_sorted = e_flat[order]
    tok_sorted = tok_flat[order]
    w_sorted = w_flat[order]
    group_start = jnp.searchsorted(e_sorted, jnp.arange(E), side="left")
    rank = jnp.arange(T * k) - group_start[e_sorted]
    keep = rank < capacity
    slot = e_sorted * capacity + jnp.minimum(rank, capacity - 1)

    x_disp = jnp.zeros((E * capacity, D), dt)
    x_disp = x_disp.at[jnp.where(keep, slot, E * capacity)].set(
        xf[tok_sorted], mode="drop")
    x_disp = x_disp.reshape(E, capacity, D)

    # ---- expert computation (einsum over the expert axis: EP shards e) --
    g = jnp.einsum("ecd,edf->ecf", x_disp, params["w_gate"].astype(dt))
    h = jnp.einsum("ecd,edf->ecf", x_disp, params["w_in"].astype(dt))
    y_e = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h,
                     params["w_out"].astype(dt))
    y_e = y_e.reshape(E * capacity, D)

    # ---- combine ---------------------------------------------------------
    contrib = y_e[slot] * (w_sorted * keep)[:, None].astype(dt)
    y = jnp.zeros((T, D), dt).at[tok_sorted].add(contrib)

    if cfg.num_shared_experts:
        y = y + mlp_apply(params["shared"], xf, cfg)

    drop_rate = 1.0 - keep.mean()
    return y.reshape(B, S, D), {"moe_aux_loss": aux_loss,
                                "moe_drop_rate": drop_rate}
