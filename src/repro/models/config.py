"""Model configuration shared by all 10 assigned architectures.

A single ModelConfig describes dense / MoE / SSM / hybrid decoder-only LMs.
Heterogeneous stacks (Jamba) are expressed with a *period*: the decoder is a
``lax.scan`` over ``num_layers // period`` identical super-blocks, each an
unrolled sequence of ``period`` layer descriptors (mixer kind + FFN kind).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Literal

MixerKind = Literal["attn", "mla", "mamba"]
FFNKind = Literal["dense", "moe", "none"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 => d_model // num_heads

    # attention
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    sliding_window: int = 0          # 0 = full attention (danube: 4096)

    # hybrid layout: attention every `attn_every` layers (Jamba 1:7 => 8,
    # offset 3); attn_every=1 => all-attention; attn_every=0 => attention-free
    attn_every: int = 1
    attn_offset: int = 0

    # MLA (DeepSeek-V2)
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # decode-time weight absorption: score against the compressed latent
    # directly instead of decompressing K/V for the whole cache each step
    # (EXPERIMENTS.md §Perf, deepseek decode cell)
    mla_absorb: bool = False

    # MoE: FFN is MoE every `moe_every` layers (offset `moe_offset`); 0 = none
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    moe_every: int = 0
    moe_offset: int = 0
    capacity_factor: float = 1.25

    # SSM / Mamba2 (SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_groups: int = 1
    ssm_chunk: int = 256

    # modality frontend stub
    input_mode: str = "tokens"       # tokens | embeds (vlm / audio backbones)

    # numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    remat: bool = True
    remat_policy: str = "nothing"    # nothing | dots | full(no remat)
    logits_fp32: bool = True

    # attention chunking (flash-style jnp path)
    q_chunk: int = 1024
    kv_chunk: int = 1024

    # cost-exact mode: unroll every lax.scan so compiled.cost_analysis()
    # counts all trips (XLA prices a while-loop body ONCE).  Used by the
    # dry-run's second compile; production compiles keep rolled scans.
    unroll_scans: bool = False

    # activation sharding constraints: ("dp-axis-or-tuple", "tp-axis").
    # Empty = let XLA SPMD decide (host tests).  The launcher sets this to
    # (("pod","data"), "model") so attention runs head-sharded with
    # replicated KV instead of XLA's replicated-compute fallback
    # (EXPERIMENTS.md §Perf iteration 1).
    act_shard: tuple = ()
    tp_size: int = 1        # model-axis size, for divisibility guards

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def period(self) -> int:
        p = 1
        if self.attn_every > 1:
            p = math.lcm(p, self.attn_every)
        if self.moe_every > 1:
            p = math.lcm(p, self.moe_every)
        return p

    @property
    def num_periods(self) -> int:
        assert self.num_layers % self.period == 0, (
            f"{self.name}: num_layers {self.num_layers} % period {self.period}")
        return self.num_layers // self.period

    def mixer_kind(self, layer_idx: int) -> MixerKind:
        if self.attn_every == 0:
            return "mamba"
        if self.attn_every == 1 or layer_idx % self.attn_every == self.attn_offset:
            return "mla" if self.use_mla else "attn"
        return "mamba"

    def ffn_kind(self, layer_idx: int) -> FFNKind:
        if self.d_ff == 0 and self.num_experts == 0:
            return "none"
        if self.num_experts > 0 and (
            self.moe_every == 1 or
            (self.moe_every > 1 and layer_idx % self.moe_every == self.moe_offset)
        ):
            return "moe"
        return "dense" if self.d_ff > 0 else "none"

    def layer_program(self) -> list[tuple[MixerKind, FFNKind]]:
        """Descriptors for one period of the stack."""
        return [(self.mixer_kind(i), self.ffn_kind(i)) for i in range(self.period)]

    # -- parameter counting (for 6*N*D roofline accounting) ---------------
    def param_counts(self) -> dict[str, float]:
        D, hd = self.d_model, self.resolved_head_dim
        H, KV = self.num_heads, self.num_kv_heads
        counts = {"embed": self.vocab_size * D,
                  "head": 0 if self.tie_embeddings else D * self.vocab_size}
        attn = mamba = dense_ffn = moe_ffn = moe_active = 0
        for i in range(self.num_layers):
            mk, fk = self.mixer_kind(i), self.ffn_kind(i)
            if mk == "attn":
                attn += D * H * hd + 2 * D * KV * hd + H * hd * D
            elif mk == "mla":
                qdim = self.qk_nope_dim + self.qk_rope_dim
                attn += (D * H * qdim + D * self.kv_lora_rank + D * self.qk_rope_dim
                         + self.kv_lora_rank * H * (self.qk_nope_dim + self.v_head_dim)
                         + H * self.v_head_dim * D)
            else:
                din, G, N = self.d_inner, self.ssm_groups, self.ssm_state
                zdim = 2 * din + 2 * G * N + self.ssm_heads
                mamba += D * zdim + din * D + (din + 2 * G * N) * self.ssm_conv
            if fk == "dense":
                dense_ffn += 3 * D * self.d_ff
            elif fk == "moe":
                moe_ffn += self.num_experts * 3 * D * self.moe_d_ff
                moe_ffn += self.num_shared_experts * 3 * D * self.moe_d_ff
                moe_ffn += D * self.num_experts
                moe_active += (self.num_experts_per_tok + self.num_shared_experts) \
                    * 3 * D * self.moe_d_ff + D * self.num_experts
        counts.update(attn=attn, mamba=mamba, dense_ffn=dense_ffn, moe_ffn=moe_ffn)
        total = sum(counts.values())
        active = total - moe_ffn + moe_active
        counts["total"] = total
        counts["active"] = active
        return counts

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    def cost_exact_variant(self, seq_len: int) -> "ModelConfig":
        """Variant whose compiled cost_analysis is trip-count-exact:
        unrolled scans, one-block attention, coarse SSD chunks."""
        return self.with_(
            unroll_scans=True,
            q_chunk=max(seq_len, 1024),
            kv_chunk=max(seq_len, 1024),
            ssm_chunk=1024 if seq_len >= 4096 else self.ssm_chunk,
        )


@dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell of the evaluation grid."""
    name: str
    kind: str           # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524288, 1)
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
