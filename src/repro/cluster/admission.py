"""Admission control = the paper's scheduling problem, verbatim.

A serving fleet of L replicas is the paper's cluster of L unit-capacity
servers; an inference request with (prompt + budgeted generation) tokens
occupies a FRACTION of a replica's KV-cache memory — a job with random
resource requirement R in (0, 1] drawn from an unknown distribution (users
decide prompt lengths).  Service time = generation length (geometric-ish).
The controller therefore runs BF-J/S (Theorem 2) or VQS-BF (Theorem 4)
UNCHANGED on the replica residuals.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.partition import PartitionI, k_red
from repro.core.quantize import RES, to_grid


@dataclass
class PendingJob:
    rid: int
    frac: float              # KV fraction of one replica (paper's R_j)
    size: int = 0            # grid units

    def __post_init__(self):
        self.size = int(to_grid([self.frac])[0])


@dataclass
class AdmissionController:
    """Best-Fit (BF-J/S-style) admission over replica residual capacity.

    replicas' residuals are tracked in paper grid units; `admit` is the
    BF-J pass over new requests, `refill(replica)` is the BF-S pass run
    when a replica frees memory (request completes).
    """

    num_replicas: int
    policy: str = "bf"          # bf | vqs-bf | fifo
    J: int = 6
    queue: list[PendingJob] = field(default_factory=list)
    residual: np.ndarray = None
    _vq_sizes: np.ndarray = None
    _active_cfg: list = None

    def __post_init__(self):
        self.residual = np.full(self.num_replicas, RES, dtype=np.int64)
        self.part = PartitionI(self.J)
        self._kred = k_red(self.J)
        self._vq_sizes = np.zeros(2 * self.J, dtype=np.int64)
        self._active_cfg = [None] * self.num_replicas

    # -- paper scheduling -------------------------------------------------
    def _best_fit_server(self, size: int) -> int:
        feas = self.residual >= size
        if not feas.any():
            return -1
        masked = np.where(feas, self.residual, np.iinfo(np.int64).max)
        return int(np.argmin(masked))

    def admit(self, jobs: list[PendingJob]) -> list[tuple[int, int]]:
        """BF-J over new requests; returns [(rid, replica)] placements."""
        placed = []
        for job in jobs:
            r = self._best_fit_server(job.size)
            if r >= 0:
                self.residual[r] -= job.size
                placed.append((job.rid, r))
            else:
                self.queue.append(job)
                self._vq_sizes[self.part.type_of_scalar(job.size)] += 1
        return placed

    def refill(self, replica: int) -> list[tuple[int, int]]:
        """BF-S over the queue after memory was released on `replica`."""
        placed = []
        while self.queue:
            fits = [j for j in self.queue if j.size <= self.residual[replica]]
            if not fits:
                break
            job = max(fits, key=lambda j: j.size)   # largest fitting first
            self.queue.remove(job)
            self._vq_sizes[self.part.type_of_scalar(job.size)] -= 1
            self.residual[replica] -= job.size
            placed.append((job.rid, replica))
        return placed

    def release(self, replica: int, size: int) -> None:
        """Return ``size`` grid units to ``replica`` (request completed).

        Guards the controller's capacity invariant: freeing more than the
        replica ever lent out means double-release or a size-accounting
        bug upstream — raise instead of silently corrupting residuals
        (an ``assert`` would vanish under ``python -O``).
        """
        if not 0 <= replica < self.num_replicas:
            raise ValueError(
                f"release on unknown replica {replica} "
                f"(controller has {self.num_replicas})")
        if size < 0:
            raise ValueError(f"release of negative size {size} on "
                             f"replica {replica}")
        if self.residual[replica] + size > RES:
            raise ValueError(
                f"release of {size} grid units on replica {replica} "
                f"exceeds capacity: residual {int(self.residual[replica])} "
                f"+ {size} > {RES} — double release or size mismatch")
        self.residual[replica] += size

    def push_front(self, job: PendingJob) -> None:
        """Queue-head insert: the serving engine's slot-rejection path
        re-admits a memory-admitted request ahead of every waiting one
        (it outranks the newest arrival).  Keeps the virtual-queue
        accounting consistent — ``refill`` will decrement the same type
        counter when the job eventually places.

        The device-resident controller (``serving/live.py``) implements
        the identical operation as a jitted queue roll.
        """
        self.queue.insert(0, job)
        self._vq_sizes[self.part.type_of_scalar(job.size)] += 1

    def queue_len(self) -> int:
        return len(self.queue)

    def max_weight_config(self):
        """Paper Eq. (8) over the controller's virtual queues (VQS-BF mode
        renews replica configurations with this at empty epochs)."""
        w = self._kred @ self._vq_sizes
        return self._kred[int(np.argmax(w))]
