"""Admission control = the paper's scheduling problem, verbatim.

A serving fleet of L replicas is the paper's cluster of L unit-capacity
servers; an inference request with (prompt + budgeted generation) tokens
occupies a FRACTION of a replica's KV-cache memory — a job with random
resource requirement R in (0, 1] drawn from an unknown distribution (users
decide prompt lengths).  Service time = generation length (geometric-ish).
The controller therefore runs BF-J/S (Theorem 2) or VQS-BF (Theorem 4)
UNCHANGED on the replica residuals.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.partition import PartitionI, k_red
from repro.core.quantize import RES, to_grid


@dataclass
class PendingJob:
    rid: int
    frac: float              # KV fraction of one replica (paper's R_j)
    size: int = 0            # grid units

    def __post_init__(self):
        self.size = int(to_grid([self.frac])[0])


ADMISSION_POLICIES = ("bf", "vqs-bf", "fifo")


@dataclass
class AdmissionController:
    """Queueing-policy admission over replica residual capacity.

    Replicas' residuals are tracked in paper grid units; ``admit`` is the
    arrival pass over new requests and ``refill(replica)`` the queue-serve
    pass run when a replica frees memory (request completes).  The
    ``policy`` field selects the queue discipline:

    ``"bf"``
        BF-J/S (Theorem 2): ``admit`` best-fits each new request,
        ``refill`` serves the queue largest-fitting-first.
    ``"vqs-bf"``
        VQS-BF (Theorem 4): ``refill`` renews the replica's configuration
        via :meth:`max_weight_config` (paper Eq. 8) at empty epochs, then
        serves (i) one largest fitting VQ_1 request when the configuration
        asks for one and none is resident, (ii) the other configured type
        largest-fit-first up to its k_{j*} cap, (iii) a BF-S sweep over
        the whole queue; ``admit`` is the same BF-J arrival pass
        (``VQSBF.schedule``'s closing step).
    ``"fifo"``
        Head-of-line: ``admit`` places only when nothing is waiting,
        ``refill`` serves the queue head while it fits (honest
        head-of-line blocking — the baseline the paper improves on).

    Unknown values raise ``ValueError`` at construction.
    """

    num_replicas: int
    policy: str = "bf"          # one of ADMISSION_POLICIES
    J: int = 6
    queue: list[PendingJob] = field(default_factory=list)
    residual: np.ndarray = None
    _vq_sizes: np.ndarray = None
    _active_cfg: list = None

    def __post_init__(self):
        if self.policy not in ADMISSION_POLICIES:
            raise ValueError(
                f"unknown admission policy {self.policy!r}; expected one "
                f"of {', '.join(ADMISSION_POLICIES)}")
        self.residual = np.full(self.num_replicas, RES, dtype=np.int64)
        self.part = PartitionI(self.J)
        self._kred = k_red(self.J)
        self._vq_sizes = np.zeros(2 * self.J, dtype=np.int64)
        self._active_cfg = [None] * self.num_replicas
        # per-replica resident request counts by partition type — the
        # vqs-bf serve pass needs "is a VQ_1 request resident" / "how many
        # of type j*"; maintained for every policy (release infers the
        # type from the released size, exact on the grid)
        self._resident = np.zeros((self.num_replicas, 2 * self.J),
                                  dtype=np.int64)

    # -- paper scheduling -------------------------------------------------
    def _best_fit_server(self, size: int) -> int:
        feas = self.residual >= size
        if not feas.any():
            return -1
        masked = np.where(feas, self.residual, np.iinfo(np.int64).max)
        return int(np.argmin(masked))

    def _place(self, job: PendingJob, replica: int,
               placed: list[tuple[int, int]]) -> None:
        self.residual[replica] -= job.size
        self._resident[replica][self.part.type_of_scalar(job.size)] += 1
        placed.append((job.rid, replica))

    def _enqueue(self, job: PendingJob) -> None:
        self.queue.append(job)
        self._vq_sizes[self.part.type_of_scalar(job.size)] += 1

    def _take(self, job: PendingJob, replica: int,
              placed: list[tuple[int, int]]) -> None:
        self.queue.remove(job)
        self._vq_sizes[self.part.type_of_scalar(job.size)] -= 1
        self._place(job, replica, placed)

    def _largest_fitting(self, replica: int, vq: int | None = None):
        """Largest queued request that fits ``replica``'s residual,
        optionally restricted to partition type ``vq``; FIFO among equal
        sizes (``max`` keeps the earliest queued maximal element)."""
        fits = [j for j in self.queue
                if j.size <= self.residual[replica]
                and (vq is None or self.part.type_of_scalar(j.size) == vq)]
        return max(fits, key=lambda j: j.size) if fits else None

    def admit(self, jobs: list[PendingJob]) -> list[tuple[int, int]]:
        """Arrival pass over new requests; returns [(rid, replica)]
        placements.  BF-J for ``bf`` and ``vqs-bf`` (the latter is
        ``VQSBF.schedule``'s closing arrival pass); ``fifo`` admits only
        past an empty queue (no overtaking)."""
        placed = []
        for job in jobs:
            if self.policy == "fifo" and self.queue:
                self._enqueue(job)
                continue
            r = self._best_fit_server(job.size)
            if r >= 0:
                self._place(job, r, placed)
            else:
                self._enqueue(job)
        return placed

    def refill(self, replica: int) -> list[tuple[int, int]]:
        """Serve the queue after memory was released on ``replica``:
        BF-S (``bf``), the configured (i)–(iii) VQS-BF order (``vqs-bf``)
        or head-of-line (``fifo``)."""
        placed = []
        if self.policy == "fifo":
            while self.queue and \
                    self.queue[0].size <= self.residual[replica]:
                self._take(self.queue[0], replica, placed)
            return placed
        if self.policy == "vqs-bf":
            # configuration renewal at empty epochs (paper Eq. 8)
            if self.residual[replica] == RES \
                    or self._active_cfg[replica] is None:
                self._active_cfg[replica] = self.max_weight_config()
            row = self._active_cfg[replica]
            k1 = row[1] > 0
            others = [j for j in np.flatnonzero(row) if j != 1]
            jstar = int(others[0]) if others else -1
            kstar = int(row[jstar]) if jstar >= 0 else 0
            # (i) one largest fitting VQ_1 request, if none resident
            if k1 and self._resident[replica][1] == 0:
                job = self._largest_fitting(replica, vq=1)
                if job is not None:
                    self._take(job, replica, placed)
            # (ii) largest-fit-first from VQ_{j*}, capped at k_{j*}
            while jstar >= 0 and self._resident[replica][jstar] < kstar:
                job = self._largest_fitting(replica, vq=jstar)
                if job is None:
                    break
                self._take(job, replica, placed)
            # (iii) BF-S sweep over the whole queue — falls through to bf
        while self.queue:
            job = self._largest_fitting(replica)  # largest fitting first
            if job is None:
                break
            self._take(job, replica, placed)
        return placed

    def release(self, replica: int, size: int) -> None:
        """Return ``size`` grid units to ``replica`` (request completed).

        Guards the controller's capacity invariant: freeing more than the
        replica ever lent out means double-release or a size-accounting
        bug upstream — raise instead of silently corrupting residuals
        (an ``assert`` would vanish under ``python -O``).
        """
        if not 0 <= replica < self.num_replicas:
            raise ValueError(
                f"release on unknown replica {replica} "
                f"(controller has {self.num_replicas})")
        if size < 0:
            raise ValueError(f"release of negative size {size} on "
                             f"replica {replica}")
        if self.residual[replica] + size > RES:
            raise ValueError(
                f"release of {size} grid units on replica {replica} "
                f"exceeds capacity: residual {int(self.residual[replica])} "
                f"+ {size} > {RES} — double release or size mismatch")
        self.residual[replica] += size
        if size > 0:
            vq = self.part.type_of_scalar(size)
            if self._resident[replica][vq] > 0:
                self._resident[replica][vq] -= 1

    def push_front(self, job: PendingJob) -> None:
        """Queue-head insert: the serving engine's slot-rejection path
        re-admits a memory-admitted request ahead of every waiting one
        (it outranks the newest arrival).  Keeps the virtual-queue
        accounting consistent — ``refill`` will decrement the same type
        counter when the job eventually places.

        The device-resident controller (``serving/live.py``) implements
        the identical operation as a jitted queue roll.
        """
        self.queue.insert(0, job)
        self._vq_sizes[self.part.type_of_scalar(job.size)] += 1

    def queue_len(self) -> int:
        return len(self.queue)

    def max_weight_config(self):
        """Paper Eq. (8) over the controller's virtual queues (VQS-BF mode
        renews replica configurations with this at empty epochs)."""
        w = self._kred @ self._vq_sizes
        return self._kred[int(np.argmax(w))]
