"""Gang scheduler: the paper's algorithm at the POD level.

Training jobs with random HBM footprints (model + optimizer bytes as a
fraction of a pod) arrive over time and are packed onto a fixed fleet of
pods with BF-J/S.  On pod failure the victim jobs are re-queued and the
BF-S pass re-packs them onto the survivors — cluster repair IS the paper's
scheduling step (DESIGN.md §6).  Jobs resume from their latest checkpoint
(checkpoint/ckpt.py), so a failure costs at most `ckpt_every` steps.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.best_fit import BFJS
from repro.core.cluster_state import Cluster, ServiceModel
from repro.core.queues import Job
from repro.core.quantize import RES, to_grid


@dataclass
class TrainJob:
    jid: int
    hbm_frac: float           # fraction of one pod's HBM
    steps_total: int
    steps_done: int = 0
    pod: int = -1
    restarts: int = 0


class GangScheduler:
    """BF-J/S over pods; jobs tick one step per slot; failures re-pack."""

    def __init__(self, num_pods: int, seed: int = 0):
        self.cluster = Cluster(num_pods)
        self.policy = BFJS().bind(self.cluster, ServiceModel("fixed", 1.0),
                                  np.random.Generator(np.random.Philox(seed)))
        self.jobs: dict[int, TrainJob] = {}
        self._cluster_jobs: dict[int, Job] = {}
        self.t = 0

    def submit(self, jobs: list[TrainJob]) -> None:
        cjobs = []
        for j in jobs:
            self.jobs[j.jid] = j
            size = int(to_grid([j.hbm_frac])[0])
            # duration = remaining steps (fixed service)
            cj = Job(j.jid, size, size, -1, self.t,
                     dur=max(j.steps_total - j.steps_done, 1))
            self._cluster_jobs[j.jid] = cj
            cjobs.append(cj)
        self.policy.on_arrivals(self.t, cjobs)

    def tick(self) -> None:
        """One scheduling slot: departures (completed jobs), placements."""
        freed, emptied = self.cluster.process_departures(self.t)
        if not hasattr(self.policy, "_new"):
            self.policy._new = []
        self.policy.schedule(self.t, freed, emptied)
        # progress accounting + placement discovery
        for pod in range(self.cluster.L):
            for cj in self.cluster.jobs[pod].values():
                job = self.jobs[cj.jid]
                job.pod = pod
                job.steps_done += 1
        self.t += 1
        self.policy.on_arrivals(self.t, [])

    def fail_pod(self, pod: int) -> list[int]:
        """Kill a pod: requeue its jobs (they resume from checkpoints)."""
        victims = list(self.cluster.jobs[pod].keys())
        requeue = []
        for jid in victims:
            cj = self.cluster.evict(pod, jid)
            job = self.jobs[jid]
            job.restarts += 1
            job.pod = -1
            nj = Job(jid, cj.size, cj.eff_size, -1, self.t,
                     dur=max(job.steps_total - job.steps_done, 1))
            self._cluster_jobs[jid] = nj
            requeue.append(nj)
        self.policy.on_arrivals(self.t, requeue)
        self.policy.schedule(self.t, {pod}, {pod})
        return victims

    def running(self) -> list[int]:
        return [j.jid for j in self.jobs.values() if j.pod >= 0]

    def queued(self) -> int:
        return self.policy.queue_len()
