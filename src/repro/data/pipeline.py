"""Deterministic, index-addressable data pipeline.

Every batch is a pure function of (seed, step, host_shard) — no iterator
state.  This is the straggler/elasticity story: any host can (re)compute any
step's shard after a restart, a pod replacement, or a re-shard, with no
state handoff (DESIGN.md §6).

The synthetic stream is a Zipf-ish token process with document boundaries
and sequence packing, which exercises the same code paths a real tokenized
corpus would (labels = next token, loss-masked at document starts).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512
    eos_id: int = 0


def _host_rng(cfg: DataConfig, step: int, index: int) -> np.random.Generator:
    key = (cfg.seed << 48) ^ (step << 16) ^ index ^ 0xDA7A
    return np.random.Generator(np.random.Philox(key=key))


def batch_at(cfg: DataConfig, step: int, shard: int = 0,
             num_shards: int = 1) -> dict[str, np.ndarray]:
    """The (step, shard)-th batch: tokens/labels (B/num_shards, S)."""
    assert cfg.global_batch % num_shards == 0
    b = cfg.global_batch // num_shards
    rng = _host_rng(cfg, step, shard)
    S = cfg.seq_len
    # Zipf-distributed tokens (heavy head like natural text)
    ranks = rng.zipf(1.3, size=(b, S + 1)).astype(np.int64)
    tokens = np.minimum(ranks, cfg.vocab_size - 1).astype(np.int32)
    # document boundaries: geometric doc lengths -> EOS + loss mask
    eos = rng.random((b, S + 1)) < (1.0 / cfg.mean_doc_len)
    tokens = np.where(eos, cfg.eos_id, tokens)
    x, y = tokens[:, :-1], tokens[:, 1:]
    mask = (y != cfg.eos_id).astype(np.float32)
    return {"tokens": x, "labels": y, "mask": mask}


def batch_for_model(mcfg: ModelConfig, dcfg: DataConfig, step: int,
                    shard: int = 0, num_shards: int = 1) -> dict:
    """Model-ready batch; embeds-mode archs get a deterministic frontend-stub
    projection of the tokens (precomputed frame/patch embeddings)."""
    raw = batch_at(dcfg, step, shard, num_shards)
    if mcfg.input_mode == "tokens":
        return raw
    # frontend stub: fixed random projection of one-hot tokens -> embeddings
    proj_rng = np.random.Generator(np.random.Philox(key=[dcfg.seed, 0, 0, 0xE5]))
    table = proj_rng.standard_normal((dcfg.vocab_size, mcfg.d_model)) * 0.02
    embeds = table[raw["tokens"]].astype(np.float32)
    return {"embeds": embeds, "labels": raw["labels"], "mask": raw["mask"]}


def device_put_batch(batch: dict, sharding=None) -> dict:
    put = (lambda a: jax.device_put(a, sharding)) if sharding is not None \
        else jnp.asarray
    return {k: put(v) for k, v in batch.items()}
