"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell and extract memory / cost / collective statistics.

MUST be run as its own process (python -m repro.launch.dryrun ...): the
XLA_FLAGS below create 512 host platform devices and jax locks the device
count at first init.  ``--all`` orchestrates one subprocess per cell so
compile memory is reclaimed between cells.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import json
import subprocess
import sys
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.registry import applicable_shapes, get_shape
from repro.distributed.hlo_analysis import collective_stats
from repro.distributed.sharding import (batch_specs, bytes_per_device,
                                        cache_specs, dp_axes, fit_spec_tree,
                                        param_specs, to_named)
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (input_specs, make_optimizer,
                                make_prefill_step, make_serve_step,
                                make_train_step)

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")


def _mem_info(compiled) -> dict:
    out = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes",
                     "host_argument_size_in_bytes",
                     "peak_memory_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                out[attr] = int(v)
    except Exception as e:  # CPU backend may not implement everything
        out["error"] = f"{type(e).__name__}: {e}"
    return out


def _cost_info(compiled) -> dict:
    out = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        for k, v in ca.items():
            if isinstance(v, (int, float)):
                out[k] = float(v)
    except Exception as e:
        out["error"] = f"{type(e).__name__}: {e}"
    return out


def _lower_cell(cfg, shape, mesh, specs, pure_dp: bool = False):
    """Build the jit'd step for one cell and lower it (shared by the
    production and cost-exact compiles).

    pure_dp: no tensor parallelism — params replicated over `model`, batch
    spread over every mesh axis (the §Perf lever for small models)."""
    from jax.sharding import PartitionSpec
    dp = tuple(mesh.axis_names) if pure_dp else dp_axes(mesh)

    def pspecs(abstract):
        if pure_dp:
            return jax.tree.map(
                lambda l: PartitionSpec(*([None] * len(l.shape))), abstract)
        return param_specs(abstract, cfg, mesh)

    def bspecs(kind):
        spec = batch_specs(cfg, mesh, kind)
        if pure_dp:
            spec = jax.tree.map(
                lambda s: PartitionSpec(dp, *list(s)[1:]), spec,
                is_leaf=lambda x: isinstance(x, PartitionSpec))
        return spec
    with mesh:
        p_sh = to_named(mesh, pspecs(specs["params"]))
        if shape.kind == "train":
            o_sh = to_named(mesh, pspecs(specs["opt_state"].mu))
            opt_sh = type(specs["opt_state"])(
                step=NamedSharding(mesh, P()), mu=o_sh, nu=o_sh)
            b_sh = to_named(mesh, fit_spec_tree(
                mesh, bspecs("train"), specs["batch"]))
            step = make_train_step(cfg, make_optimizer(cfg))
            jitted = jax.jit(step,
                             in_shardings=(p_sh, opt_sh, b_sh),
                             out_shardings=(p_sh, opt_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(specs["params"], specs["opt_state"],
                                   specs["batch"])
        elif shape.kind == "prefill":
            b_sh = to_named(mesh, fit_spec_tree(
                mesh, bspecs("prefill"), specs["batch"]))
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(specs["params"], specs["batch"])
        else:  # decode
            c_sh = to_named(mesh, cache_specs(specs["caches"], cfg, mesh))
            tok_spec = P(dp, None) if cfg.input_mode == "tokens" \
                else P(dp, None, None)
            tok_spec = fit_spec_tree(mesh, tok_spec, specs["tokens"])
            tok_sh = NamedSharding(mesh, tok_spec)
            out_tok = fit_spec_tree(
                mesh, P(dp, None),
                jax.ShapeDtypeStruct((shape.global_batch, 1), "int32"))
            step = make_serve_step(cfg) if cfg.input_mode == "tokens" \
                else _make_embeds_serve_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, c_sh, tok_sh, NamedSharding(mesh, P())),
                out_shardings=(NamedSharding(mesh, out_tok), c_sh),
                donate_argnums=(1,))
            lowered = jitted.lower(specs["params"], specs["caches"],
                                   specs["tokens"], specs["pos"])
    return lowered


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: str | None = None, save_hlo: bool = False,
             cost_exact: bool = True, overrides: dict | None = None,
             tag: str = "") -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    overrides = dict(overrides or {})
    pure_dp = bool(overrides.pop("pure_dp", False))
    if pure_dp:
        # §Perf lever for small models: no TP — replicate params over the
        # model axis and spread the batch over BOTH axes (256-way DP).
        cfg = cfg.with_(tp_size=1)
    else:
        cfg = cfg.with_(act_shard=(dp_axes(mesh), "model"),
                        tp_size=int(mesh.shape["model"]))
    if overrides:
        cfg = cfg.with_(**overrides)
    specs = input_specs(cfg, shape)

    t0 = time.time()
    lowered = _lower_cell(cfg, shape, mesh, specs, pure_dp=pure_dp)
    lower_s = time.time() - t0
    t1 = time.time()
    with mesh:
        compiled = lowered.compile()
    compile_s = time.time() - t1

    mem = _mem_info(compiled)
    cost = _cost_info(compiled)
    hlo = compiled.as_text()
    coll = collective_stats(hlo)

    # Second, trip-count-exact cost model for the roofline terms (single-pod
    # only — the roofline table is single-pod per the brief).  XLA prices a
    # while-loop body once, so the production compile undercounts FLOPs /
    # collective bytes by each scan's trip count.  Rather than unrolling the
    # FULL stack (10-minute compiles for SSD models), compile the unrolled
    # variant at `period` and `2*period` layers and extrapolate linearly —
    # exact for uniform stacks since everything outside the layer loop
    # (embed/head/loss/optimizer-global) is depth-independent.
    exact = None
    if cost_exact and mesh_kind == "pod":
        t2 = time.time()
        exact = _cost_exact_extrapolated(cfg, shape, mesh, pure_dp)
        exact["compile_s"] = round(time.time() - t2, 2)

    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "mesh_shape": list(mesh.devices.shape), "chips": mesh.devices.size,
        "kind": shape.kind, "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "lower_s": round(lower_s, 2), "compile_s": round(compile_s, 2),
        "memory_analysis": mem, "cost_analysis": cost,
        "collectives": coll.summary(),
        "param_bytes_per_device": bytes_per_device(
            specs["params"], param_specs(specs["params"], cfg, mesh), mesh),
        "hlo_bytes": len(hlo),
    }
    if exact is not None:
        record["cost_exact"] = exact
    if shape.kind == "decode":
        record["cache_bytes_per_device"] = bytes_per_device(
            specs["caches"], cache_specs(specs["caches"], cfg, mesh), mesh)
    if shape.kind == "train":
        record["opt_bytes_per_device"] = 2 * record["param_bytes_per_device"]

    if tag:
        record["tag"] = tag
        record["overrides"] = {**overrides, "pure_dp": pure_dp}
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        name = f"{arch}__{shape_name}__{mesh_kind}{suffix}.json"
        with open(os.path.join(out_dir, name), "w") as f:
            json.dump(record, f, indent=1)
        if save_hlo:
            with open(os.path.join(out_dir, name[:-5] + ".hlo.txt"), "w") as f:
                f.write(hlo)
    return record


def _cost_exact_extrapolated(cfg, shape, mesh, pure_dp: bool) -> dict:
    """Exact-cost model via depth extrapolation (see run_cell comment)."""
    p = cfg.period
    subs = []
    for L in (p, 2 * p):
        cfgL = cfg.cost_exact_variant(shape.seq_len).with_(num_layers=L)
        specsL = input_specs(cfgL, shape)
        lowered = _lower_cell(cfgL, shape, mesh, specsL, pure_dp=pure_dp)
        with mesh:
            compiled = lowered.compile()
        ca = _cost_info(compiled)
        coll = collective_stats(compiled.as_text())
        subs.append({"layers": L, "cost_analysis": ca,
                     "coll_bytes": coll.total_bytes,
                     "coll_count": coll.total_count,
                     "coll_by_op": dict(coll.bytes_by_op),
                     "largest": coll.summary()["largest"]})
        del compiled, lowered

    L_full = cfg.num_layers
    c1, c2 = subs[0], subs[1]

    def extrap(v1: float, v2: float) -> float:
        per_layer = (v2 - v1) / p
        return max(v1 + per_layer * (L_full - p), 0.0)

    cost: dict = {}
    keys = set(c1["cost_analysis"]) | set(c2["cost_analysis"])
    for k in keys:
        a, b = c1["cost_analysis"].get(k), c2["cost_analysis"].get(k)
        if isinstance(a, (int, float)) and isinstance(b, (int, float)):
            cost[k] = extrap(a, b)
    coll_by_op = {
        k: extrap(c1["coll_by_op"].get(k, 0), c2["coll_by_op"].get(k, 0))
        for k in set(c1["coll_by_op"]) | set(c2["coll_by_op"])}
    return {
        "method": f"depth-extrapolated ({p} and {2*p} unrolled layers -> "
                  f"{L_full})",
        "cost_analysis": cost,
        "collectives": {
            "total_bytes": sum(coll_by_op.values()),
            "total_count": int(extrap(c1["coll_count"], c2["coll_count"])),
            "bytes_by_op": coll_by_op,
            "largest": c2["largest"],
        },
        "sub_compiles": subs,
    }


def _make_embeds_serve_step(cfg):
    """Decode step for embeds-mode archs: greedy token out, embeds in."""
    from repro.models import model as M
    import jax.numpy as jnp

    def serve_step(params, caches, embeds, pos):
        logits, caches = M.decode_step(params, cfg, embeds, pos, caches)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], caches

    return serve_step


def iter_cells(meshes=("pod", "multipod")):
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            for mesh_kind in meshes:
                yield arch, shape.name, mesh_kind


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape",
                    choices=["train_4k", "prefill_32k", "decode_32k",
                             "long_500k"])
    ap.add_argument("--mesh", choices=["pod", "multipod"], default="pod")
    ap.add_argument("--all", action="store_true",
                    help="run every applicable cell in subprocesses")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default="", help="suffix for perf-iteration runs")
    ap.add_argument("--override", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="ModelConfig override (or pure_dp=1), repeatable")
    args = ap.parse_args()

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        if v in ("0", "1"):
            overrides[k] = bool(int(v))
        else:
            try:
                overrides[k] = int(v)
            except ValueError:
                overrides[k] = v

    if args.all:
        failures, done = [], 0
        for arch, shape, mesh_kind in iter_cells():
            tag = f"{arch} x {shape} x {mesh_kind}"
            path = os.path.join(args.out, f"{arch}__{shape}__{mesh_kind}.json")
            if args.skip_existing and os.path.exists(path):
                print(f"[skip] {tag}", flush=True)
                done += 1
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                   "--out", args.out]
            if args.save_hlo:
                cmd.append("--save-hlo")
            t0 = time.time()
            r = subprocess.run(cmd, capture_output=True, text=True)
            if r.returncode == 0:
                done += 1
                print(f"[ok]   {tag} ({time.time()-t0:.0f}s)", flush=True)
            else:
                failures.append(tag)
                print(f"[FAIL] {tag}\n{r.stdout[-2000:]}\n{r.stderr[-4000:]}",
                      flush=True)
        print(f"\n{done} cells ok, {len(failures)} failed")
        for f in failures:
            print("  FAIL:", f)
        return 1 if failures else 0

    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    try:
        rec = run_cell(args.arch, args.shape, args.mesh, args.out,
                       args.save_hlo, overrides=overrides, tag=args.tag)
    except Exception:
        traceback.print_exc()
        return 1
    ca, coll = rec["cost_analysis"], rec["collectives"]
    print(json.dumps({
        "cell": f'{rec["arch"]} x {rec["shape"]} x {rec["mesh"]}',
        "compile_s": rec["compile_s"],
        "flops": ca.get("flops"),
        "bytes": ca.get("bytes accessed"),
        "collective_bytes": coll["total_bytes"],
        "collective_count": coll["total_count"],
        "param_bytes_per_device": rec["param_bytes_per_device"],
        "memory": rec["memory_analysis"],
    }, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
