"""Production meshes.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is the cross-DCN pure-DP axis.

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS before calling.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"need {need} devices for mesh {shape}, have {len(devices)}; "
            "the dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import")
    dev = np.asarray(devices[:need]).reshape(shape)
    return Mesh(dev, axes)


def make_host_mesh() -> Mesh:
    """Whatever devices exist, as a 1-D ("data",) mesh (tests / examples)."""
    dev = np.asarray(jax.devices())
    return Mesh(dev, ("data",))


# Hardware constants for the roofline model (TPU v5e class chip).
PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
