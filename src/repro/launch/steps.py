"""Step functions + abstract input specs for every (arch x shape) cell.

``input_specs`` returns weak-type-correct ShapeDtypeStructs so the dry-run
lowers without allocating anything; train/serve use the same builders with
real arrays.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig, ShapeConfig
from repro.train.optimizer import AdamW, cosine_schedule


def make_optimizer(cfg: ModelConfig, total_steps: int = 100_000) -> AdamW:
    return AdamW(schedule=cosine_schedule(3e-4, 2000, total_steps))


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, optimizer: AdamW | None = None):
    optimizer = optimizer or make_optimizer(cfg)

    def train_step(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(
            M.loss_fn, has_aux=True)(params, cfg, batch)
        params, opt_state, opt_metrics = optimizer.update(
            grads, opt_state, params)
        metrics = {"loss": loss, **aux, **opt_metrics}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return M.prefill(params, cfg,
                         tokens=batch.get("tokens"),
                         embeds=batch.get("embeds"))
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One synchronized decode step: next-token logits -> greedy token."""

    def serve_step(params, caches, tokens, pos):
        logits, caches = M.decode_step(params, cfg, tokens, pos, caches)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], caches

    return serve_step


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs_abstract(cfg: ModelConfig, shape: ShapeConfig,
                         with_labels: bool = True) -> dict:
    B, S = shape.global_batch, shape.seq_len
    batch: dict[str, Any] = {}
    if cfg.input_mode == "tokens":
        batch["tokens"] = _sds((B, S), jnp.int32)
    else:
        batch["embeds"] = _sds((B, S, cfg.d_model), jnp.bfloat16)
    if with_labels:
        batch["labels"] = _sds((B, S), jnp.int32)
    return batch


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """All abstract inputs for the cell's step function.

    train:   {params, opt_state, batch}
    prefill: {params, batch}
    decode:  {params, caches, tokens, pos}
    """
    params = M.init_abstract(cfg)
    if shape.kind == "train":
        opt_state = jax.eval_shape(make_optimizer(cfg).init, params)
        return {"params": params, "opt_state": opt_state,
                "batch": batch_specs_abstract(cfg, shape)}
    if shape.kind == "prefill":
        return {"params": params,
                "batch": batch_specs_abstract(cfg, shape, with_labels=False)}
    if shape.kind == "decode":
        B = shape.global_batch
        caches = M.init_cache_abstract(cfg, B, shape.seq_len)
        if cfg.input_mode == "tokens":
            tok = _sds((B, 1), jnp.int32)
        else:
            tok = _sds((B, 1, cfg.d_model), jnp.bfloat16)
        return {"params": params, "caches": caches, "tokens": tok,
                "pos": _sds((), jnp.int32)}
    raise ValueError(shape.kind)


def concrete_batch(cfg: ModelConfig, B: int, S: int, key) -> dict:
    """Real synthetic batch (smoke tests / examples)."""
    k1, k2 = jax.random.split(key)
    batch = {"labels": jax.random.randint(k2, (B, S), 0, cfg.vocab_size)}
    if cfg.input_mode == "tokens":
        batch["tokens"] = jax.random.randint(k1, (B, S), 0, cfg.vocab_size)
    else:
        batch["embeds"] = jax.random.normal(k1, (B, S, cfg.d_model),
                                            jnp.bfloat16) * 0.02
    return batch
