"""Sharding rules: parameter / input / cache PartitionSpecs for the
production mesh.

Layout (MaxText-style FSDP x TP):
  * batch shards over the data-parallel axes ("pod","data") / ("data",);
  * every weight matrix shards one dim over "data" (ZeRO-3 / FSDP — XLA
    inserts the all-gathers) and one over "model" (TP);
  * routed experts shard their expert dim over "model" (EP);
  * any dim that does not divide its mesh axis falls back to replication
    (e.g. kv_heads=8 on a 16-way model axis, musicgen's 24 heads) — the
    fallback is *per-leaf-dim*, so everything always lowers.

The same rule table serves real arrays and ShapeDtypeStructs (dry-run).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _fit(mesh: Mesh, dim_size: int, axis):
    """axis if dim divides its mesh size, else None (replicate)."""
    return axis if axis is not None and dim_size % _axis_size(mesh, axis) == 0 \
        else None


def _spec(mesh: Mesh, shape: tuple[int, ...], axes: tuple) -> P:
    return P(*[_fit(mesh, d, a) for d, a in zip(shape, axes)])


# -- rule table -----------------------------------------------------------
# leaf name -> per-dim logical axes, where 'F' = fsdp (data), 'T' = tensor
# (model), 'E' = expert (model), None = replicated.  Dims are the TRAILING
# dims of the leaf (a leading stacked-periods dim is always replicated).
_RULES: dict[str, tuple] = {
    # embeddings
    "embed.w": ("T", "F"),
    "head.w": ("F", "T"),
    # attention / MLA
    "wq": ("F", "T"),
    # KV projections replicate over `model`: kv_heads (8) rarely divide the
    # 16-way TP axis, and a model-sharded (D, KV*hd) matrix forces an
    # all-to-all when reshaped to heads (§Perf iteration 1).
    "wk": ("F", None),
    "wv": ("F", None),
    "wo": ("T", "F"),
    "bq": ("T",),
    "bk": ("T",),
    "bv": ("T",),
    "w_dkv": ("F", None),
    "w_kr": ("F", None),
    "w_uk": ("F", "T"),
    "w_uv": ("F", "T"),
    # dense MLP
    "w_gate": ("F", "T"),
    "w_in": ("F", "T"),
    "w_out": ("T", "F"),
    # MoE (expert-stacked weights detected by ndim==3)
    "router": ("F", None),
    # mamba
    "conv_w": (None, "T"),
    "conv_b": ("T",),
    "A_log": (None,),
    "D_skip": (None,),
    "dt_bias": (None,),
    "norm_scale": (None,),
    "scale": (None,),
}

_MOE_RULES = {
    "w_gate": ("E", "F", None),
    "w_in": ("E", "F", None),
    "w_out": ("E", None, "F"),
}


def _path_names(path) -> list[str]:
    """Key names along a pytree path (dicts -> .key, NamedTuples -> .name)."""
    names = []
    for p in path:
        if hasattr(p, "key"):
            names.append(str(p.key))
        elif hasattr(p, "name"):
            names.append(str(p.name))
    return names


def _logical_to_mesh(mesh: Mesh, logical):
    has_model = "model" in mesh.axis_names
    table = {"F": "data" if "data" in mesh.axis_names else None,
             "T": "model" if has_model else None,
             "E": "model" if has_model else None,
             None: None}
    return tuple(table[x] for x in logical)


def param_specs(abstract_params: Any, cfg: ModelConfig, mesh: Mesh):
    """PartitionSpec pytree matching the parameter tree."""

    def rule(path, leaf) -> P:
        names = _path_names(path)
        name = names[-1] if names else ""
        qual = ".".join(names[-2:])
        shape = leaf.shape
        logical = _RULES.get(qual) or _RULES.get(name)
        if logical is None:
            logical = (None,) * len(shape)
        if name in _MOE_RULES and len(shape) - len(logical) >= 2:
            # stacked (periods, E, d, f) or unstacked (E, d, f) expert weights
            logical = _MOE_RULES[name]
        axes = _logical_to_mesh(mesh, logical)
        # left-pad replication for leading stacked dims (periods / vmap)
        pad = len(shape) - len(axes)
        axes = (None,) * pad + axes
        return _spec(mesh, shape, axes)

    return jax.tree_util.tree_map_with_path(rule, abstract_params)


def batch_specs(cfg: ModelConfig, mesh: Mesh, kind: str):
    dp = dp_axes(mesh)
    if kind in ("train", "prefill"):
        spec = {"labels": P(dp, None)}
        if cfg.input_mode == "tokens":
            spec["tokens"] = P(dp, None)
        else:
            spec["embeds"] = P(dp, None, None)
        if kind == "prefill":
            spec.pop("labels")
        return spec
    raise ValueError(kind)


def cache_specs(abstract_caches: Any, cfg: ModelConfig, mesh: Mesh):
    """Decode caches: batch over DP; the cache SEQUENCE dim over `model`
    (context-parallel decode).  Sequence sharding works for any kv-head
    count (8 kv heads never divide the 16-way model axis) and turns decode
    attention into local partial softmax + tiny all-reduces.
    Cache leaves: (periods, B, ...)."""
    dp = dp_axes(mesh)

    def rule(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        shape = leaf.shape
        if name in ("k", "v"):          # (periods, B, C, KV, hd)
            return _spec(mesh, shape, (None, dp, "model", None, None))
        if name == "c_kv" or name == "k_rope":  # (periods, B, C, r)
            return _spec(mesh, shape, (None, dp, "model", None))
        if name == "conv":              # (periods, B, k-1, conv_dim)
            return _spec(mesh, shape, (None, dp, None, "model"))
        if name == "ssm":               # (periods, B, nh, hd, N)
            return _spec(mesh, shape, (None, dp, "model", None, None))
        if name == "length":
            return P()
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(rule, abstract_caches)


def fit_spec_tree(mesh: Mesh, spec_tree, abstract_tree):
    """Drop any spec axis that does not divide the actual dim (e.g. batch=1
    on the 16-way data axis for long_500k)."""

    def fit(spec, leaf):
        return P(*[_fit(mesh, d, a) for d, a in zip(leaf.shape, spec)])

    return jax.tree.map(fit, spec_tree, abstract_tree,
                        is_leaf=lambda x: isinstance(x, P))


def to_named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def bytes_per_device(abstract_tree, spec_tree, mesh: Mesh) -> int:
    """Analytic per-device bytes for a sharded pytree (dry-run memory
    audit, independent of backend memory_analysis support)."""
    total = 0
    for leaf, spec in zip(jax.tree.leaves(abstract_tree),
                          jax.tree.leaves(spec_tree,
                                          is_leaf=lambda x: isinstance(x, P))):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        shards = 1
        for ax in spec:
            if ax is not None:
                shards *= _axis_size(mesh, ax)
        total += n * leaf.dtype.itemsize // max(shards, 1)
    return total
