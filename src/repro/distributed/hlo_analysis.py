"""Post-compile HLO analysis: collective-communication byte accounting.

``compiled.cost_analysis()`` reports FLOPs and memory bytes but NOT
collective traffic, so we parse the optimized HLO module text and sum the
operand sizes of every all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute (and their -start async forms).  Also reports
per-opcode counts — duplicate all-gathers of the same operand are the
classic SPMD perf smell the §Perf loop hunts for.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "collective-broadcast",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(\([^=]*?\)|\S+)\s+([\w\-]+)(?:\.\d+)?\(")
_OPERAND_RE = re.compile(r"%?([\w.\-]+)")


def shape_bytes(shape_str: str) -> int:
    """Bytes of an HLO shape string (sums tuple components)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: dict = field(default_factory=lambda: defaultdict(int))
    count_by_op: dict = field(default_factory=lambda: defaultdict(int))
    largest: list = field(default_factory=list)  # (bytes, opcode, shape)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())

    def summary(self, top: int = 8) -> dict:
        return {
            "total_bytes": self.total_bytes,
            "total_count": self.total_count,
            "bytes_by_op": dict(self.bytes_by_op),
            "count_by_op": dict(self.count_by_op),
            "largest": [
                {"bytes": b, "op": o, "shape": s}
                for b, o, s in sorted(self.largest, reverse=True)[:top]
            ],
        }


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Parse optimized HLO text; sum operand bytes per collective opcode.

    Operand shapes are resolved from each instruction's declared result
    shape (first pass builds the name->shape map).  Async '-start' ops are
    counted once; their '-done' halves are skipped.
    """
    shapes: dict[str, str] = {}
    pending: list[tuple[str, str, str]] = []  # (opcode, result_shape, operands)

    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, result_shape, opcode = m.group(1).lstrip("%"), m.group(2), m.group(3)
        shapes[name] = result_shape
        base = opcode[:-6] if opcode.endswith("-start") else opcode
        if opcode.endswith("-done") or opcode == "async-done":
            continue
        if base in COLLECTIVE_OPS:
            # operand list = text between the first '(' and its matching ')'
            rest = line[m.end():]
            depth, idx = 1, 0
            for idx, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
            pending.append((base, result_shape, rest[:idx]))

    stats = CollectiveStats()
    for opcode, result_shape, operands in pending:
        total = 0
        for op in operands.split(","):
            op = op.strip()
            om = _OPERAND_RE.match(op)
            if om and om.group(1) in shapes:
                total += shape_bytes(shapes[om.group(1)])
            elif _SHAPE_RE.search(op):
                total += shape_bytes(op)
        if total == 0:
            total = shape_bytes(result_shape)
        stats.bytes_by_op[opcode] += total
        stats.count_by_op[opcode] += 1
        stats.largest.append((total, opcode, result_shape[:96]))
    return stats
