"""Fault-tolerant training loop.

Features (DESIGN.md §6): jit'd train step with sharded params/opt-state,
gradient accumulation (microbatch scan), periodic async checkpoints,
--restore resume (bitwise-identical state), simulated preemption injection
for tests, and elastic restart onto a different mesh.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, batch_for_model, device_put_batch
from repro.distributed.sharding import (batch_specs, dp_axes, fit_spec_tree,
                                        param_specs, to_named)
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.train.optimizer import AdamW, cosine_schedule


class PreemptionError(RuntimeError):
    """Simulated SIGTERM from the cluster manager."""


@dataclass
class TrainerConfig:
    seq_len: int = 256
    global_batch: int = 8
    microbatches: int = 1          # gradient accumulation factor
    steps: int = 50
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    peak_lr: float = 3e-4
    warmup: int = 10
    log_every: int = 10
    preempt_at_step: int = -1      # fault injection (tests)
    data_seed: int = 0


@dataclass
class TrainState:
    params: dict
    opt_state: object
    step: int = 0
    metrics: dict = field(default_factory=dict)


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig,
                 mesh: Mesh | None = None):
        self.cfg = cfg
        self.tcfg = tcfg
        if mesh is None:
            import numpy as np
            mesh = Mesh(np.asarray(jax.devices()), ("data",))
        self.mesh = mesh
        self.optimizer = AdamW(schedule=cosine_schedule(
            tcfg.peak_lr, tcfg.warmup, tcfg.steps))
        self.dcfg = DataConfig(vocab_size=cfg.vocab_size,
                               seq_len=tcfg.seq_len,
                               global_batch=tcfg.global_batch,
                               seed=tcfg.data_seed)
        self.checkpointer = ckpt.AsyncCheckpointer(tcfg.ckpt_dir,
                                                   keep=tcfg.ckpt_keep)
        self._build_step()

    # ------------------------------------------------------------------
    def _build_step(self):
        cfg, opt = self.cfg, self.optimizer
        nmicro = self.tcfg.microbatches

        def loss_and_grad(params, batch):
            return jax.value_and_grad(M.loss_fn, has_aux=True)(
                params, cfg, batch)

        def train_step(params, opt_state, batch):
            if nmicro == 1:
                (loss, aux), grads = loss_and_grad(params, batch)
            else:
                def micro(carry, mb):
                    gsum, lsum = carry
                    (loss, _aux), g = loss_and_grad(params, mb)
                    return (jax.tree.map(jnp.add, gsum, g), lsum + loss), None

                mbs = jax.tree.map(
                    lambda a: a.reshape(nmicro, a.shape[0] // nmicro,
                                        *a.shape[1:]), batch)
                zeros = jax.tree.map(jnp.zeros_like, params)
                (gsum, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
                grads = jax.tree.map(lambda g: g / nmicro, gsum)
                loss, aux = lsum / nmicro, {}
            params, opt_state, om = opt.update(grads, opt_state, params)
            return params, opt_state, {"loss": loss, **om}

        abstract = jax.eval_shape(
            lambda k: M.init_params(cfg, k), jax.ShapeDtypeStruct((2,), "uint32"))
        self.p_spec = param_specs(abstract, cfg, self.mesh)
        self.p_sh = to_named(self.mesh, self.p_spec)
        o_abs = jax.eval_shape(opt.init, abstract)
        o_sh = type(o_abs)(step=NamedSharding(self.mesh, P()),
                           mu=self.p_sh, nu=self.p_sh)
        self.o_sh = o_sh
        self._jit_step = jax.jit(train_step,
                                 in_shardings=(self.p_sh, o_sh, None),
                                 out_shardings=(self.p_sh, o_sh, None),
                                 donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    def init_state(self, seed: int = 0) -> TrainState:
        with self.mesh:
            params = jax.jit(
                lambda k: M.init_params(self.cfg, k),
                out_shardings=self.p_sh)(jax.random.PRNGKey(seed))
            opt_state = jax.jit(self.optimizer.init,
                                out_shardings=self.o_sh)(params)
        return TrainState(params, opt_state, 0)

    def restore_latest(self) -> TrainState | None:
        step = ckpt.latest_step(self.tcfg.ckpt_dir)
        if step is None:
            return None
        abstract = jax.eval_shape(
            lambda k: M.init_params(self.cfg, k),
            jax.ShapeDtypeStruct((2,), "uint32"))
        o_abs = jax.eval_shape(self.optimizer.init, abstract)
        like = {"params": abstract, "opt": o_abs}
        sh = {"params": self.p_sh, "opt": self.o_sh}
        restored = ckpt.restore(self.tcfg.ckpt_dir, step, like, sh)
        return TrainState(restored["params"], restored["opt"], step)

    # ------------------------------------------------------------------
    def run(self, state: TrainState | None = None,
            log=print) -> TrainState:
        t = self.tcfg
        if state is None:
            state = self.restore_latest() or self.init_state()
            if state.step:
                log(f"[trainer] resumed from step {state.step}")
        dp = len(jax.devices())  # single-host: one shard
        del dp
        history = []
        t0 = time.time()
        for step in range(state.step, t.steps):
            if step == t.preempt_at_step:
                self.checkpointer.wait()
                raise PreemptionError(f"simulated preemption at step {step}")
            batch = batch_for_model(self.cfg, self.dcfg, step)
            batch = device_put_batch(batch)
            with self.mesh:
                state.params, state.opt_state, metrics = self._jit_step(
                    state.params, state.opt_state, batch)
            state.step = step + 1
            if (step + 1) % t.ckpt_every == 0 or step + 1 == t.steps:
                self.checkpointer.save(
                    state.step,
                    {"params": state.params, "opt": state.opt_state},
                    extra={"loss": float(metrics["loss"])})
            if (step + 1) % t.log_every == 0 or step == state.step:
                log(f"[trainer] step {step+1}/{t.steps} "
                    f"loss={float(metrics['loss']):.4f} "
                    f"lr={float(metrics['lr']):.2e} "
                    f"({(time.time()-t0)/(step-state.step+1+1e-9):.2f}s/step)")
            history.append(float(metrics["loss"]))
        self.checkpointer.wait()
        state.metrics = {"loss_history": history}
        return state
