"""Optimizers (pure JAX, no optax): AdamW with cosine/linear schedules,
global-norm clipping, and optional gradient compression hooks.

Optimizer state mirrors the parameter pytree, so it inherits parameter
shardings (fully sharded states — ZeRO-style — fall out of the FSDP
parameter specs for free).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]


def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                    min_ratio: float = 0.1) -> Schedule:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps) /
                        jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak_lr * jnp.where(step < warmup_steps, warm, cos)
    return fn


def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.full((), lr, jnp.float32)


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    schedule: Schedule
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # moment dtype: "bfloat16" halves optimizer memory (the lever that fits
    # jamba-398B training on 512 v5e chips — EXPERIMENTS.md §Dry-run)
    moment_dtype: str = ""

    def init(self, params) -> AdamWState:
        dt = jnp.dtype(self.moment_dtype) if self.moment_dtype else None

        def zeros(p):
            return jax.tree.map(
                lambda a: jnp.zeros_like(a, dtype=dt or a.dtype), p)

        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=zeros(params), nu=zeros(params))

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        lr = self.schedule(step)

        if self.clip_norm > 0:
            leaves = jax.tree.leaves(grads)
            gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                                 for g in leaves))
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        else:
            gnorm = jnp.zeros(())

        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(
            lambda m, g: (b1 * m.astype(g.dtype) + (1 - b1) * g).astype(m.dtype),
            state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: (b2 * v.astype(g.dtype) + (1 - b2) * g * g).astype(v.dtype),
            state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            mhat = m.astype(p.dtype) / bc1
            vhat = v.astype(p.dtype) / bc2
            u = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                u = u + self.weight_decay * p
            return p - lr * u

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step, mu, nu), {
            "lr": lr, "grad_norm": gnorm}
