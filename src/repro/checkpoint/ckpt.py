"""Checkpointing: atomic, async-capable, elastic.

Layout:  <dir>/step_<N>/arrays.npz  + manifest.json
  * arrays are stored with LOGICAL (unsharded) shapes keyed by pytree path,
    so restore onto a different mesh / device count just re-applies the
    sharding rules — that is the elastic-rescale path (lose a pod, restore
    onto the survivors);
  * writes go to step_<N>.tmp then rename (atomic on POSIX);
  * ``save_async`` runs the host-side write in a thread so the training
    loop only blocks for the device->host copy.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "name"):
                keys.append(str(p.name))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
        flat[SEP.join(keys)] = np.asarray(leaf)
    return flat


def _tree_def(tree):
    return jax.tree_util.tree_structure(tree)


def save(directory: str, step: int, state: Any, extra: dict | None = None
         ) -> str:
    """Blocking save. `state` is any pytree of arrays."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(state)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "time": time.time(),
        "num_arrays": len(flat),
        "total_bytes": int(sum(a.nbytes for a in flat.values())),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Device->host copy on the caller thread; disk write in background."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, state: Any, extra: dict | None = None) -> None:
        self.wait()
        host_state = jax.tree.map(np.asarray, state)  # sync copy out

        def work():
            try:
                save(self.directory, step, host_state, extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(list_steps(self.directory))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)


def list_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, like: Any, shardings: Any | None = None
            ) -> Any:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs).  If `shardings` is given (pytree of NamedSharding),
    arrays are device_put with them — restoring onto a different mesh than
    the one that saved is supported because stored shapes are logical."""
    path = os.path.join(directory, f"step_{step:08d}", "arrays.npz")
    data = np.load(path)
    flat_like = _flatten(like)
    missing = set(flat_like) - set(data.files)
    if missing:
        raise KeyError(f"checkpoint missing arrays: {sorted(missing)[:5]}...")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys = list(_flatten(like).keys())
    arrays = [data[k] for k in keys]
    restored = jax.tree_util.tree_unflatten(treedef, arrays)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s), restored, shardings)
    else:
        restored = jax.tree.map(jax.numpy.asarray, restored)
    return restored


def read_manifest(directory: str, step: int) -> dict:
    with open(os.path.join(directory, f"step_{step:08d}", "manifest.json")) as f:
        return json.load(f)
