"""Checkpointing: atomic, async-capable, elastic, self-verifying.

Layout:  <dir>/step_<N>/arrays.npz  + manifest.json
  * arrays are stored with LOGICAL (unsharded) shapes keyed by pytree path,
    so restore onto a different mesh / device count just re-applies the
    sharding rules — that is the elastic-rescale path (lose a pod, restore
    onto the survivors);
  * writes go to step_<N>.tmp then rename (atomic on POSIX);
  * ``save_async`` runs the host-side write in a thread so the training
    loop only blocks for the device->host copy;
  * every save records a SHA-256 of ``arrays.npz`` in its manifest
    (``arrays_sha256``); loads verify it, so a truncated or bit-rotted
    checkpoint surfaces as a typed :class:`CheckpointCorruptError` naming
    the offending path — never a raw pickle/zip/numpy error — and
    :func:`latest_valid_step` finds the newest checkpoint that still
    verifies (the supervised-streaming rollback hook, DESIGN.md §14).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
import zipfile
from typing import Any

import jax
import numpy as np

SEP = "/"


class CheckpointCorruptError(RuntimeError):
    """A checkpoint on disk is truncated, garbled, or fails its checksum.

    Always names the offending file; raised instead of whatever raw
    ``zipfile``/``pickle``/``numpy`` error the damage would otherwise
    surface as, so callers can catch ONE type to trigger rollback."""

    def __init__(self, path: str, why: str):
        self.path = path
        self.why = why
        super().__init__(f"corrupt checkpoint at {path}: {why}")


def _sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "name"):
                keys.append(str(p.name))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
        flat[SEP.join(keys)] = np.asarray(leaf)
    return flat


def _tree_def(tree):
    return jax.tree_util.tree_structure(tree)


def save(directory: str, step: int, state: Any, extra: dict | None = None
         ) -> str:
    """Blocking save. `state` is any pytree of arrays."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(state)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "time": time.time(),
        "num_arrays": len(flat),
        "total_bytes": int(sum(a.nbytes for a in flat.values())),
        "arrays_sha256": _sha256_file(os.path.join(tmp, "arrays.npz")),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Device->host copy on the caller thread; disk write in background."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, state: Any, extra: dict | None = None) -> None:
        self.wait()
        host_state = jax.tree.map(np.asarray, state)  # sync copy out

        def work():
            try:
                save(self.directory, step, host_state, extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(list_steps(self.directory))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)


def list_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def load_arrays(directory: str, step: int, verify: bool = True
                ) -> dict[str, np.ndarray]:
    """Read a step's arrays as a ``{pytree path: ndarray}`` dict, fully
    materialized, raising :class:`CheckpointCorruptError` on truncated or
    garbled files.  ``verify=True`` (default) additionally checks the
    manifest's ``arrays_sha256`` when present (checkpoints written before
    checksumming landed verify structurally only)."""
    path = os.path.join(directory, f"step_{step:08d}", "arrays.npz")
    if verify:
        sha = read_manifest(directory, step).get("arrays_sha256")
        if sha is not None:
            try:
                actual = _sha256_file(path)
            except OSError as e:
                raise CheckpointCorruptError(path, f"unreadable: {e}") \
                    from e
            if actual != sha:
                raise CheckpointCorruptError(
                    path, f"SHA-256 mismatch: manifest says {sha[:12]}…, "
                          f"file hashes to {actual[:12]}… (truncated write "
                          "or on-disk corruption)")
    try:
        with np.load(path, allow_pickle=False) as data:
            return {k: np.asarray(data[k]) for k in data.files}
    except CheckpointCorruptError:
        raise
    except Exception as e:
        # zipfile.BadZipFile, EOFError, OSError, ValueError from a garbage
        # member, KeyError from a torn index — one typed error, named path
        raise CheckpointCorruptError(
            path, f"{type(e).__name__}: {e}") from e


def verify_step(directory: str, step: int) -> None:
    """Raise :class:`CheckpointCorruptError` unless step ``step`` is fully
    readable (manifest parses, arrays decompress, checksum matches)."""
    load_arrays(directory, step, verify=True)


def latest_valid_step(directory: str) -> tuple[int | None, list[int]]:
    """Newest step that verifies, plus the (newer) corrupt steps skipped
    on the way — the rollback primitive: ``(None, [...])`` means no
    checkpoint survived at all."""
    corrupt: list[int] = []
    for step in reversed(list_steps(directory)):
        try:
            verify_step(directory, step)
        except CheckpointCorruptError:
            corrupt.append(step)
        else:
            return step, corrupt
    return None, corrupt


def restore(directory: str, step: int, like: Any, shardings: Any | None = None
            ) -> Any:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs).  If `shardings` is given (pytree of NamedSharding),
    arrays are device_put with them — restoring onto a different mesh than
    the one that saved is supported because stored shapes are logical."""
    data = load_arrays(directory, step)
    flat_like = _flatten(like)
    missing = set(flat_like) - set(data)
    if missing:
        raise KeyError(f"checkpoint missing arrays: {sorted(missing)[:5]}...")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys = list(_flatten(like).keys())
    arrays = [data[k] for k in keys]
    restored = jax.tree_util.tree_unflatten(treedef, arrays)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s), restored, shardings)
    else:
        restored = jax.tree.map(jax.numpy.asarray, restored)
    return restored


def read_manifest(directory: str, step: int) -> dict:
    path = os.path.join(directory, f"step_{step:08d}", "manifest.json")
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CheckpointCorruptError(
            path, f"{type(e).__name__}: {e}") from e
