"""Device-resident live admission: BF-J/S bookkeeping as one jitted step.

``cluster/admission.py`` runs the paper's BF-J/S admission as host-side
Python — an ``argmin`` per admitted request and a list-comprehension scan
of the whole queue per BF-S refill step, every engine tick.  For a serving
loop that must keep pace with the device, that per-tick host round-trip is
the bottleneck, so this module keeps the ENTIRE admission state on the
accelerator:

    LiveAdmissionState.residual  (L,)    int32 grid-unit residuals
                      .q_rid     (Qcap,) int32 FIFO queue, lane 0 = head
                      .q_size    (Qcap,) int32 (compacted: live lanes first)
                      .q_len, .dropped, .invalid  () int32 counters

and fuses each tick's admit / release / BF-S-refill decisions into single
jitted calls (``lax.scan`` over arrival lanes for BF-J, a bounded
``lax.while_loop`` per freed replica for BF-S).  The host only dequeues
the small per-tick placement vectors — admit/release decisions never
materialize intermediate state host-side.

Semantics are EXACTLY ``AdmissionController``'s, lane-for-lane:

  * BF-J: first-feasible-minimum residual (``argmin`` over residuals
    masked to feasibility — ties break to the lowest replica index, the
    same first-min ``np.argmin`` picks);
  * BF-S: largest fitting job first, earliest-queued among equals
    (``argmax`` over FIFO-compacted sizes returns the FIRST maximum —
    the same job Python's ``max(fits, key=size)`` returns from a
    queue-ordered list);
  * ``release`` guards the capacity invariant; where the host controller
    raises, the jitted step counts the violation in ``invalid`` (a jitted
    region cannot raise) and the host wrapper raises on the next sync.

Queue overflow is counted in ``dropped`` (the host controller's Python
list is unbounded; a device queue cannot be — size ``Qcap`` so parity
holds whenever the host queue stays within it, which the parity suite
pins).  ``tests/test_live_admission.py`` drives both controllers through
identical randomized workloads and asserts placement-for-placement
equality.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import RES

_I32_MAX = jnp.iinfo(jnp.int32).max


class LiveAdmissionState(NamedTuple):
    """Complete device-resident admission state (see module docstring)."""
    residual: jax.Array   # (L,) int32 free grid units per replica
    q_rid: jax.Array      # (Qcap,) int32 queued request ids, FIFO-compacted
    q_size: jax.Array     # (Qcap,) int32 queued sizes (grid units)
    q_len: jax.Array      # () int32 live queue lanes
    dropped: jax.Array    # () int32 arrivals dropped on queue overflow
    invalid: jax.Array    # () int32 release-invariant violations


def init_state(num_replicas: int, Qcap: int) -> LiveAdmissionState:
    return LiveAdmissionState(
        residual=jnp.full((num_replicas,), RES, dtype=jnp.int32),
        q_rid=jnp.full((Qcap,), -1, dtype=jnp.int32),
        q_size=jnp.zeros((Qcap,), dtype=jnp.int32),
        q_len=jnp.zeros((), jnp.int32),
        dropped=jnp.zeros((), jnp.int32),
        invalid=jnp.zeros((), jnp.int32),
    )


def _push_back(state: LiveAdmissionState, rid, size) -> LiveAdmissionState:
    """Append to the queue tail, or count a drop when full."""
    Qcap = state.q_rid.shape[0]
    fits = state.q_len < Qcap
    at = jnp.minimum(state.q_len, Qcap - 1)
    return state._replace(
        q_rid=jnp.where(fits, state.q_rid.at[at].set(rid), state.q_rid),
        q_size=jnp.where(fits, state.q_size.at[at].set(size), state.q_size),
        q_len=state.q_len + fits.astype(jnp.int32),
        dropped=state.dropped + (~fits).astype(jnp.int32))


def _admit_one(state: LiveAdmissionState, job):
    """BF-J for one arrival lane: place on the min-residual feasible
    replica (first-min tie-break) or enqueue.  Returns the placement
    (replica index, or -1 when queued/dropped, untouched when masked)."""
    rid, size, live = job
    feas = state.residual >= size
    any_feas = live & feas.any()
    best = jnp.argmin(jnp.where(feas, state.residual, _I32_MAX)
                      ).astype(jnp.int32)
    residual = jnp.where(
        any_feas, state.residual.at[best].add(-size), state.residual)
    queued = jax.tree.map(
        lambda a, b: jnp.where(live & ~any_feas, a, b),
        _push_back(state, rid, size), state)
    state = queued._replace(residual=residual)
    return state, jnp.where(any_feas, best, -1)


@jax.jit
def admit_step(state: LiveAdmissionState, rids: jax.Array,
               sizes: jax.Array, mask: jax.Array):
    """Jitted BF-J over one tick's arrivals: ``(A,)`` lanes scanned in
    order (the controller admits submission-order).  Returns the new state
    and ``(A,)`` placements (replica, or -1 = queued/dropped)."""
    return jax.lax.scan(
        _admit_one, state,
        (rids.astype(jnp.int32), sizes.astype(jnp.int32), mask))


def _remove_lane(q: jax.Array, idx) -> jax.Array:
    """Drop lane ``idx`` keeping FIFO compaction: lanes after it shift
    left one (the device analogue of ``list.remove``)."""
    lanes = jnp.arange(q.shape[0])
    return jnp.where(lanes >= idx, jnp.roll(q, -1), q)


def _refill_replica(state: LiveAdmissionState, replica,
                    out_rid, out_rep, count):
    """BF-S on one freed replica: repeatedly place the largest fitting
    queued job (earliest among equals) until none fits."""
    Qcap = state.q_rid.shape[0]

    def fits_mask(st):
        lanes = jnp.arange(Qcap)
        return (lanes < st.q_len) & (st.q_size <= st.residual[replica])

    def cond(carry):
        st = carry[0]
        return fits_mask(st).any()

    def body(carry):
        st, orid, orep, cnt = carry
        m = fits_mask(st)
        # argmax over FIFO-compacted lanes -> first (earliest) maximum,
        # matching Python max() over the queue-ordered list
        pick = jnp.argmax(jnp.where(m, st.q_size, -1)).astype(jnp.int32)
        size = st.q_size[pick]
        rid = st.q_rid[pick]
        st = st._replace(
            residual=st.residual.at[replica].add(-size),
            q_rid=_remove_lane(st.q_rid, pick),
            q_size=_remove_lane(st.q_size, pick),
            q_len=st.q_len - 1)
        orid = orid.at[cnt].set(rid)
        orep = orep.at[cnt].set(jnp.asarray(replica, jnp.int32))
        return st, orid, orep, cnt + 1

    return jax.lax.while_loop(cond, body,
                              (state, out_rid, out_rep, count))


@jax.jit
def refill_step(state: LiveAdmissionState, replica: jax.Array):
    """Jitted BF-S refill of one replica.  Returns the new state plus the
    placement buffers ``(rids, replicas, count)`` — lanes ``[0, count)``
    are the placements, in placement order."""
    Qcap = state.q_rid.shape[0]
    out_rid = jnp.full((Qcap,), -1, jnp.int32)
    out_rep = jnp.full((Qcap,), -1, jnp.int32)
    state, out_rid, out_rep, count = _refill_replica(
        state, replica.astype(jnp.int32), out_rid, out_rep,
        jnp.zeros((), jnp.int32))
    return state, (out_rid, out_rep, count)


def _release_one(state: LiveAdmissionState, ev) -> LiveAdmissionState:
    replica, size, live = ev
    L = state.residual.shape[0]
    ok = live & (replica >= 0) & (replica < L) & (size >= 0)
    at = jnp.clip(replica, 0, L - 1)
    ok = ok & (state.residual[at] + size <= RES)
    return state._replace(
        residual=jnp.where(ok, state.residual.at[at].add(size),
                           state.residual),
        invalid=state.invalid + (live & ~ok).astype(jnp.int32))


@jax.jit
def release_step(state: LiveAdmissionState, replicas: jax.Array,
                 sizes: jax.Array, mask: jax.Array) -> LiveAdmissionState:
    """Jitted release of a batch of completions (no refill)."""

    def step(st, ev):
        return _release_one(st, ev), None

    state, _ = jax.lax.scan(
        step, state,
        (replicas.astype(jnp.int32), sizes.astype(jnp.int32), mask))
    return state


@jax.jit
def tick_step(state: LiveAdmissionState, replicas: jax.Array,
              sizes: jax.Array, mask: jax.Array):
    """One fused engine tick: release every completion, then BF-S-refill
    each replica that freed memory, in ascending replica order — exactly
    the host engine's per-replica release+refill sequence (a refill only
    reads its own replica's residual, so batching the releases first is
    order-equivalent).  Returns ``(state, (rids, replicas, count))``
    placement buffers covering ALL refills of the tick.
    """
    L = state.residual.shape[0]
    Qcap = state.q_rid.shape[0]
    replicas = replicas.astype(jnp.int32)
    state = release_step(state, replicas, sizes, mask)
    freed = jnp.zeros((L,), bool).at[jnp.clip(replicas, 0, L - 1)].max(
        mask & (replicas >= 0) & (replicas < L))
    out_rid = jnp.full((Qcap,), -1, jnp.int32)
    out_rep = jnp.full((Qcap,), -1, jnp.int32)
    count = jnp.zeros((), jnp.int32)

    def per_replica(r, carry):
        st, orid, orep, cnt = carry

        def do(c):
            return _refill_replica(c[0], r, c[1], c[2], c[3])

        return jax.lax.cond(freed[r], do, lambda c: c,
                            (st, orid, orep, cnt))

    state, out_rid, out_rep, count = jax.lax.fori_loop(
        0, L, per_replica, (state, out_rid, out_rep, count))
    return state, (out_rid, out_rep, count)


@jax.jit
def push_front_step(state: LiveAdmissionState, rid: jax.Array,
                    size: jax.Array) -> LiveAdmissionState:
    """Jitted queue-head insert (the engine's slot-rejection path).  On a
    full queue the TAIL job is dropped (head inserts are re-admissions
    that outrank the newest arrival) and counted."""
    Qcap = state.q_rid.shape[0]
    tail_drop = (state.q_len >= Qcap).astype(jnp.int32)
    return state._replace(
        q_rid=jnp.roll(state.q_rid, 1).at[0].set(rid.astype(jnp.int32)),
        q_size=jnp.roll(state.q_size, 1).at[0].set(size.astype(jnp.int32)),
        q_len=jnp.minimum(state.q_len + 1, Qcap),
        dropped=state.dropped + tail_drop)


class LiveAdmission:
    """Host facade over the jitted admission steps — drop-in for
    ``AdmissionController`` in ``ServingEngine`` (``admission="live"``).

    State lives on the device between calls; each method is one fused
    dispatch, and only placement vectors (and ``queue_len``) ever return
    to the host.  ``tick(events)`` is the per-engine-tick fast path:
    release + all refills in a single call.
    """

    def __init__(self, num_replicas: int, Qcap: int = 512,
                 tick_width: int | None = None):
        self.num_replicas = num_replicas
        self.Qcap = Qcap
        #: fixed completion-event lane count per tick_step call (pad +
        #: mask), so every tick reuses one compilation
        self.tick_width = tick_width
        self.state = init_state(num_replicas, Qcap)

    # -- bookkeeping --------------------------------------------------------
    def _check(self) -> None:
        inv = int(self.state.invalid)
        if inv:
            from repro.core.engine.supervisor import InvariantViolation
            raise InvariantViolation(
                f"{inv} invalid release(s) since the last sync — "
                "double release, unknown replica, or size mismatch "
                "(the host controller raises eagerly; the device step "
                "counts and raises on sync)",
                invariant="occupancy_capacity")

    def queue_len(self) -> int:
        self._check()
        return int(self.state.q_len)

    @property
    def residual(self) -> np.ndarray:
        return np.asarray(self.state.residual)

    @property
    def dropped(self) -> int:
        return int(self.state.dropped)

    # -- the AdmissionController surface ------------------------------------
    def admit(self, jobs) -> list[tuple[int, int]]:
        """BF-J over new requests; returns [(rid, replica)] placements."""
        if not jobs:
            return []
        rids = np.asarray([j.rid for j in jobs], np.int32)
        sizes = np.asarray([j.size for j in jobs], np.int32)
        self.state, placed = admit_step(
            self.state, rids, sizes, np.ones(len(jobs), bool))
        placed = np.asarray(placed)
        return [(int(rids[i]), int(placed[i]))
                for i in range(len(jobs)) if placed[i] >= 0]

    def refill(self, replica: int) -> list[tuple[int, int]]:
        """BF-S over the device queue after a release on ``replica``."""
        self.state, (rids, reps, count) = refill_step(
            self.state, jnp.asarray(replica))
        n = int(count)
        rids = np.asarray(rids[:n])
        return [(int(rids[i]), replica) for i in range(n)]

    def release(self, replica: int, size: int) -> None:
        """Return grid units to ``replica`` — stays on device; invariant
        violations are counted and raised on the next sync."""
        self.state = release_step(
            self.state, np.asarray([replica], np.int32),
            np.asarray([size], np.int32), np.ones(1, bool))

    def push_front(self, job) -> None:
        """Queue-head insert (slot-rejection re-admission path)."""
        self.state = push_front_step(
            self.state, jnp.asarray(job.rid), jnp.asarray(job.size))

    # -- the fused fast path ------------------------------------------------
    def tick(self, events: list[tuple[int, int]]) -> list[tuple[int, int]]:
        """One engine tick: ``events`` is [(replica, size)] completions.
        Releases all of them and BF-S-refills every freed replica in one
        jitted call; returns [(rid, replica)] placements in the host
        engine's order.  Pads to ``tick_width`` lanes so every tick hits
        one compilation."""
        width = self.tick_width or max(len(events), 1)
        if len(events) > width:
            raise ValueError(
                f"{len(events)} completion events exceed tick_width="
                f"{width}; raise tick_width (it bounds one tick's lanes)")
        reps = np.full(width, -1, np.int32)
        sizes = np.zeros(width, np.int32)
        mask = np.zeros(width, bool)
        for i, (r, s) in enumerate(events):
            reps[i], sizes[i], mask[i] = r, s, True
        self.state, (rids, placed_rep, count) = tick_step(
            self.state, reps, sizes, mask)
        n = int(count)
        rids = np.asarray(rids[:n])
        placed_rep = np.asarray(placed_rep[:n])
        self._check()
        return [(int(rids[i]), int(placed_rep[i])) for i in range(n)]
