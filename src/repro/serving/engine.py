"""Continuous-batching serving engine with paper-scheduler admission.

Each replica holds a jitted ragged decode step (per-request positions via
vmap) over B_slots cache slots of C_max tokens.  A request needs
(prompt_len + max_new) tokens of KV memory = a fraction of the replica's
cache — the paper's job size.  Admission runs BF-J/S (cluster/admission.py):
BF-J on arrival, BF-S on completion.

The engine is single-host but replica-sharded by construction: each replica
owns its params reference, cache pool and slot map, so replicas map 1:1 to
pods in a real deployment.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.admission import AdmissionController, PendingJob
from repro.models import model as M
from repro.models.config import ModelConfig


@dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (P,) int32
    max_new: int
    out: list = field(default_factory=list)
    replica: int = -1
    slot: int = -1
    pos: int = 0                # tokens generated so far (incl. prompt fill)
    done: bool = False

    @property
    def tokens_needed(self) -> int:
        return len(self.prompt) + self.max_new


def make_ragged_decode(cfg: ModelConfig):
    """vmap decode over per-request positions (continuous batching).

    Cache array leaves are (periods, B, ...) -> mapped along axis 1; the
    scalar `length` counters (periods,) are unmapped and re-normalized after
    the call (positions are passed explicitly, lengths are informational).
    """

    def single(params, tok, pos, cache):
        # per-request: tok is a scalar id (or (D,) embed) -> (B=1, 1, ...);
        # cache leaves arrive batch-stripped (periods, ...) -> re-add B=1.
        tok = tok[None, None]
        cache = jax.tree.map(
            lambda l: l[:, None] if l.ndim >= 2 else l, cache)
        logits, cache = M.decode_step(params, cfg, tok, pos, cache)
        cache = jax.tree.map(
            lambda l: l[:, 0] if l.ndim >= 3 else l, cache)
        return jnp.argmax(logits[0, -1]).astype(jnp.int32), cache

    def is_len(path):
        return any(getattr(p, "name", None) == "length" for p in path)

    def step(params, toks, pos, caches):
        ax_in = jax.tree_util.tree_map_with_path(
            lambda p, l: None if is_len(p) else 1, caches)
        ax_out = jax.tree_util.tree_map_with_path(
            lambda p, l: 0 if is_len(p) else 1, caches)
        vm = jax.vmap(single, in_axes=(None, 0, 0, ax_in),
                      out_axes=(0, ax_out))
        toks_out, new_caches = vm(params, toks, pos, caches)
        # length leaves came back (B, periods); collapse to (periods,)
        new_caches = jax.tree_util.tree_map_with_path(
            lambda p, l: l.max(axis=0) if is_len(p) else l, new_caches)
        return toks_out, new_caches

    return jax.jit(step)


class Replica:
    def __init__(self, cfg: ModelConfig, params, b_slots: int, c_max: int):
        self.cfg = cfg
        self.params = params
        self.b_slots = b_slots
        self.c_max = c_max
        self.caches = M.init_cache(cfg, b_slots, c_max)
        self.slots: list[Request | None] = [None] * b_slots
        self.positions = np.zeros(b_slots, dtype=np.int32)
        self._decode = make_ragged_decode(cfg)

    def free_slot(self) -> int:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return -1

    def active(self) -> list[Request]:
        return [r for r in self.slots if r is not None]

    def step(self) -> list[Request]:
        """One decode step for all active slots; returns finished requests."""
        if not self.active():
            return []
        toks = np.zeros(self.b_slots, dtype=np.int32)
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            if r.pos < len(r.prompt):          # prompt feed (teacher forcing)
                toks[i] = r.prompt[r.pos]
            else:
                toks[i] = r.out[-1] if r.out else r.prompt[-1]
        next_toks, self.caches = self._decode(
            self.params, jnp.asarray(toks), jnp.asarray(self.positions),
            self.caches)
        next_toks = np.asarray(next_toks)
        finished = []
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            self.positions[i] += 1
            r.pos += 1
            if r.pos >= len(r.prompt):
                r.out.append(int(next_toks[i]))
            if len(r.out) >= r.max_new or r.pos >= self.c_max:
                r.done = True
                finished.append(r)
                self.slots[i] = None
                self.positions[i] = 0
        return finished


def estimate_capacity(num_replicas: int, lam: float,
                      mean_service_slots: float, size_sampler=None, *,
                      ensembles: int = 8, horizon: int = 2_000,
                      policy: str = "bfjs", engine: str = "scan",
                      workload=None, seed: int = 0, K: int = 16,
                      Qcap: int = 512, A_max: int = 8,
                      mesh=None, devices=None,
                      **policy_config) -> dict:
    """Monte-Carlo what-if sizing for a serving fleet.

    Simulates admission under ``policy`` ("bfjs" — the controller this
    engine runs — or any policy registered with ``repro.core.engine``, e.g.
    "vqs" for the paper's guaranteed-throughput scheduler, "bfjs-mr" for
    vector requests) on ``num_replicas`` replicas under Poisson(``lam``)
    request arrivals whose KV-cache fractions come from
    ``size_sampler(key, n)`` and whose decode lengths are geometric with
    mean ``mean_service_slots`` — on-device via the accelerated engines in
    core/engine, with the ``engine=`` knob ("scan" | "reference" |
    "pallas") selecting the implementation exactly as ``policy=`` selects
    the scheduler.  The what-if is packaged as a
    :class:`repro.core.engine.Workload` internally; pass ``workload=`` to
    size an explicit spec (e.g. a multi-resource one with per-replica
    (kv-mem, compute) capacities) instead of the loose knobs, which are
    then ignored.  Extra keyword arguments (``J=...`` for VQS) pass through
    to the policy runner.  Returns tail-queue / drop statistics to answer
    "how many replicas do I need for this traffic?" before any model is
    loaded.

    ``mesh=``/``devices=`` shard the ensemble over devices (bit-identical
    results — ``core.engine.sharding``); the tuning cache fills unset
    launch knobs automatically (``core.engine.tuning``) — the returned
    dict reports ``devices``, ``tuned`` and ``cache_hit`` so sizing runs
    are attributable to a specific launch configuration.
    """
    from repro.core.engine import (Workload, monte_carlo_policy,
                                   resolve_mesh)
    from repro.core.engine.tuning import apply_tuned

    if workload is None:
        if size_sampler is None:
            def size_sampler(key, n):
                return jax.random.uniform(key, (n,), minval=0.05, maxval=0.5)
        workload = Workload(lam=lam, mu=1.0 / mean_service_slots,
                            sampler=size_sampler)

    mesh = resolve_mesh(mesh, devices)
    policy_config.update(L=num_replicas, K=K, Qcap=Qcap, A_max=A_max,
                         horizon=horizon)
    tuning_meta = apply_tuned(policy, engine, policy_config,
                              workload.num_resources)
    keys = jax.random.split(jax.random.PRNGKey(seed), ensembles)
    res = monte_carlo_policy(workload, keys, policy=policy, engine=engine,
                             mesh=mesh, **policy_config)
    tail = np.asarray(res.queue_len)[:, -max(horizon // 4, 1):]
    return {
        "replicas": num_replicas,
        "policy": policy,
        "engine": engine,
        "devices": 1 if mesh is None else int(mesh.devices.size),
        "tuned": tuning_meta["tuned"],
        "cache_hit": tuning_meta["cache_hit"],
        "mean_tail_queue": float(tail.mean()),
        "p95_tail_queue": float(np.percentile(tail, 95)),
        "mean_occupancy": float(np.asarray(res.occupancy).mean()),
        "dropped": int(np.asarray(res.dropped).sum()),
        "truncated": int(np.asarray(res.truncated).sum()),
        "slots_simulated": ensembles * horizon,
    }


class ServingEngine:
    """L replicas + paper-scheduler admission; host-level request queue.

    ``admission="host"`` (default) runs the Python
    :class:`AdmissionController`; ``admission="live"`` swaps in the
    device-resident jitted controller (``serving/live.py``) — identical
    placements (parity-pinned by tests/test_live_admission.py), but each
    tick's release + BF-S refill decisions run as ONE fused device call
    instead of a host loop, and the host only dequeues the placement
    vector.
    """

    def __init__(self, cfg: ModelConfig, params, num_replicas: int = 2,
                 b_slots: int = 4, c_max: int = 128, policy: str = "bf",
                 admission: str = "host", audit: bool = False):
        self.cfg = cfg
        #: opt-in runtime invariant auditor (DESIGN.md §14): every tick
        #: checks request conservation + slot-map consistency and raises a
        #: typed InvariantViolation instead of serving on corrupt state
        self.audit = audit
        self.replicas = [Replica(cfg, params, b_slots, c_max)
                         for _ in range(num_replicas)]
        if admission == "host":
            self.admission = AdmissionController(num_replicas,
                                                 policy=policy)
        elif admission == "live":
            from repro.serving.live import LiveAdmission
            self.admission = LiveAdmission(
                num_replicas, tick_width=num_replicas * b_slots)
        else:
            raise ValueError(f"unknown admission {admission!r}; expected "
                             '"host" or "live"')
        self._live = admission == "live"
        self.c_max = c_max
        self._by_rid: dict[int, Request] = {}
        self._job_size: dict[int, int] = {}
        self.completed: list[Request] = []
        self.stats = {"queue_len": [], "active": [], "admitted": 0,
                      "rejected_slots": 0}

    # -- paper job model ----------------------------------------------------
    def _to_job(self, req: Request) -> PendingJob:
        frac = min(req.tokens_needed / self.c_max, 1.0)
        return PendingJob(rid=req.rid, frac=frac)

    def submit(self, reqs: list[Request]) -> None:
        jobs = []
        for r in reqs:
            self._by_rid[r.rid] = r
            job = self._to_job(r)
            self._job_size[r.rid] = job.size
            jobs.append(job)
        for rid, replica in self.admission.admit(jobs):
            self._start(rid, replica)

    def _start(self, rid: int, replica_idx: int) -> None:
        req = self._by_rid[rid]
        rep = self.replicas[replica_idx]
        slot = rep.free_slot()
        if slot < 0:
            # memory admitted but no batch slot: return to queue front
            self.admission.release(replica_idx, self._job_size[rid])
            self.admission.push_front(self._to_job(req))
            self.stats["rejected_slots"] += 1
            return
        req.replica, req.slot = replica_idx, slot
        rep.slots[slot] = req
        rep.positions[slot] = 0
        self.stats["admitted"] += 1

    def step(self) -> list[Request]:
        """One engine tick: decode every replica, release + BF-S refill.

        With ``admission="live"`` the whole tick's releases and refills
        fuse into one device call (``LiveAdmission.tick``); order is
        equivalent to the host path — a refill only reads its own
        replica's residual, and refills run in ascending replica order
        either way.
        """
        finished_all = []
        events = []
        for idx, rep in enumerate(self.replicas):
            finished = rep.step()
            for r in finished:
                self.completed.append(r)
                if self._live:
                    events.append((idx, self._job_size[r.rid]))
                else:
                    self.admission.release(idx, self._job_size[r.rid])
            finished_all.extend(finished)
            if finished and not self._live:
                for rid, ridx in self.admission.refill(idx):
                    self._start(rid, ridx)
        if self._live and events:
            for rid, ridx in self.admission.tick(events):
                self._start(rid, ridx)
        self.stats["queue_len"].append(self.admission.queue_len())
        self.stats["active"].append(
            sum(len(rep.active()) for rep in self.replicas))
        if self.audit:
            self.check_invariants()
        return finished_all

    def check_invariants(self) -> None:
        """Audit the engine's conservation laws (``audit=True`` runs this
        every tick; callable directly for forensics):

        * request conservation — every submitted request is exactly one
          of queued / active-in-a-slot / completed;
        * slot-map consistency — each resident request's recorded
          ``(replica, slot)`` matches where it actually sits;
        * admission residuals — nonnegative and within replica capacity
          (``admission="live"`` additionally syncs its device-side
          invalid-release counter via ``queue_len`` above).

        Raises :class:`~repro.core.engine.supervisor.InvariantViolation`
        (a ``ValueError``) naming the failed counter.
        """
        from repro.core.engine.supervisor import InvariantViolation
        active = 0
        for idx, rep in enumerate(self.replicas):
            for slot, r in enumerate(rep.slots):
                if r is None:
                    continue
                active += 1
                if r.replica != idx or r.slot != slot:
                    raise InvariantViolation(
                        f"slot map corrupt: request {r.rid} sits in "
                        f"replica {idx} slot {slot} but records "
                        f"(replica={r.replica}, slot={r.slot})",
                        invariant="slot_map")
                if r.done:
                    raise InvariantViolation(
                        f"request {r.rid} is done but still occupies "
                        f"replica {idx} slot {slot}",
                        invariant="slot_map")
        queued = self.admission.queue_len()
        done = len(self.completed)
        submitted = len(self._by_rid)
        if queued + active + done != submitted:
            raise InvariantViolation(
                f"request conservation failed: queued {queued} + active "
                f"{active} + completed {done} != submitted {submitted}",
                invariant="request_conservation")
        residual = np.asarray(self.admission.residual)
        if (residual < 0).any():
            raise InvariantViolation(
                f"negative admission residual(s): {residual.tolist()}",
                invariant="queue_nonneg")
        from repro.core.quantize import RES
        if (residual > RES).any():
            raise InvariantViolation(
                f"admission residual(s) exceed replica capacity {RES}: "
                f"{residual.tolist()}",
                invariant="occupancy_capacity")

    def run(self, max_steps: int = 1000) -> list[Request]:
        for _ in range(max_steps):
            self.step()
            if not any(rep.active() for rep in self.replicas) \
                    and self.admission.queue_len() == 0:
                break
        return self.completed


#: The serving fleet IS the paper's cluster of L unit-capacity servers —
#: the alias the capacity-planning and live-admission docs use.
Cluster = ServingEngine
