"""h2o-danube-3-4b [dense] — arXiv:2401.16818 family. 24L d3840 32H (GQA
kv=8, head_dim 120) d_ff 10240 vocab 32000, sliding-window attention 4096
=> sub-quadratic long-context decode (runs long_500k)."""
from repro.models.config import ModelConfig

ARCH_ID = "h2o-danube-3-4b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=24, d_model=3840, num_heads=32, num_kv_heads=8,
        d_ff=10240, vocab_size=32000, head_dim=120,
        sliding_window=4096,
    )


def smoke_config() -> ModelConfig:
    return full_config().with_(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=128, sliding_window=32)
