"""llava-next-mistral-7b [vlm] — hf:llava-hf/llava-v1.6-mistral-7b-hf.
Mistral-7B text backbone (32L d4096 32H GQA kv=8 d_ff 14336 vocab 32000);
the anyres vision tower is a STUB: input_specs() feeds precomputed patch
embeddings (input_mode='embeds'), per the assignment brief."""
from repro.models.config import ModelConfig

ARCH_ID = "llava-next-mistral-7b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="vlm",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=32000, head_dim=128,
        input_mode="embeds",
    )


def smoke_config() -> ModelConfig:
    return full_config().with_(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=128)
