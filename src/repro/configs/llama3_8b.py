"""llama3-8b [dense] — arXiv:2407.21783. 32L d4096 32H (GQA kv=8)
d_ff 14336, 128k vocab, rope_theta 500k."""
from repro.models.config import ModelConfig

ARCH_ID = "llama3-8b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=128256, head_dim=128,
        rope_theta=500_000.0,
    )


def smoke_config() -> ModelConfig:
    return full_config().with_(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=128)
