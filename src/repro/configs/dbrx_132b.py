"""dbrx-132b [moe] — hf:databricks/dbrx-base. 40L d6144 48H (GQA kv=8),
16 experts top-4, expert d_ff 10752, vocab 100352."""
from repro.models.config import ModelConfig

ARCH_ID = "dbrx-132b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=0, vocab_size=100352, head_dim=128,
        num_experts=16, num_experts_per_tok=4, moe_d_ff=10752, moe_every=1,
    )


def smoke_config() -> ModelConfig:
    return full_config().with_(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        vocab_size=128, num_experts=4, num_experts_per_tok=2, moe_d_ff=64)
