"""musicgen-medium [audio] — arXiv:2306.05284. 48L d1536 24H (MHA kv=24)
d_ff 6144, decoder-only over EnCodec tokens (vocab 2048, 4 codebooks).
The EnCodec frontend is a STUB: input_specs() feeds precomputed summed
codebook embeddings (input_mode='embeds')."""
from repro.models.config import ModelConfig

ARCH_ID = "musicgen-medium"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="audio",
        num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
        d_ff=6144, vocab_size=2048, head_dim=64,
        input_mode="embeds",
    )


def smoke_config() -> ModelConfig:
    return full_config().with_(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=64)
