"""Architecture registry: --arch <id> resolution, shape applicability."""
from __future__ import annotations

import importlib

from repro.models.config import (ALL_SHAPES, DECODE_32K, LONG_500K,
                                 PREFILL_32K, TRAIN_4K, ModelConfig,
                                 ShapeConfig)

_MODULES = {
    "mamba2-130m": "mamba2_130m",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "dbrx-132b": "dbrx_132b",
    "mistral-large-123b": "mistral_large_123b",
    "llama3-8b": "llama3_8b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "qwen2-72b": "qwen2_72b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "musicgen-medium": "musicgen_medium",
}
ARCH_IDS = tuple(_MODULES)

_SHAPES = {s.name: s for s in ALL_SHAPES}


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).full_config()


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).smoke_config()


def get_shape(name: str) -> ShapeConfig:
    return _SHAPES[name]


def supports_long_context(cfg: ModelConfig) -> bool:
    """long_500k needs sub-quadratic attention: SSM/hybrid stacks or SWA.
    Pure full-attention archs skip it (documented in DESIGN.md)."""
    return cfg.family in ("ssm", "hybrid") or cfg.sliding_window > 0


def applicable_shapes(cfg: ModelConfig) -> list[ShapeConfig]:
    shapes = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if supports_long_context(cfg):
        shapes.append(LONG_500K)
    return shapes
