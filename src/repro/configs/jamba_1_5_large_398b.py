"""jamba-1.5-large-398b [hybrid] — arXiv:2403.19887. 72L d8192 64H (GQA kv=8),
Mamba:attn 1:7 interleave (attention at index 4 of each 8-layer period),
MoE 16e top-2 on every other layer."""
from repro.models.config import ModelConfig

ARCH_ID = "jamba-1.5-large-398b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="hybrid",
        num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=24576, vocab_size=65536, head_dim=128,
        attn_every=8, attn_offset=4,
        num_experts=16, num_experts_per_tok=2, moe_d_ff=24576,
        moe_every=2, moe_offset=1,
        ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
        ssm_groups=8, ssm_chunk=256,
    )


def smoke_config() -> ModelConfig:
    return full_config().with_(
        num_layers=8, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, moe_d_ff=128, vocab_size=128, num_experts=4,
        ssm_state=16, ssm_head_dim=16, ssm_groups=2, ssm_chunk=16)
