"""qwen2-72b [dense] — arXiv:2407.10671. 80L d8192 64H (GQA kv=8)
d_ff 29568 vocab 152064, QKV bias."""
from repro.models.config import ModelConfig

ARCH_ID = "qwen2-72b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=29568, vocab_size=152064, head_dim=128,
        qkv_bias=True, rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return full_config().with_(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=128)
