"""mamba2-130m [ssm] — SSD, arXiv:2405.21060. 24L d768 attention-free,
vocab 50280, ssm_state=128."""
from repro.models.config import ModelConfig

ARCH_ID = "mamba2-130m"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="ssm",
        num_layers=24, d_model=768, num_heads=12, num_kv_heads=12,
        d_ff=0, vocab_size=50280,
        attn_every=0,                      # attention-free
        ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
        ssm_groups=1, ssm_chunk=256,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return full_config().with_(
        num_layers=2, d_model=64, vocab_size=128,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
