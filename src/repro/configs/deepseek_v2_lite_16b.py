"""deepseek-v2-lite-16b [moe] — arXiv:2405.04434. 27L d2048 16H, MLA with
kv_lora_rank=512 (qk_nope 128 / qk_rope 64 / v_head 128), 64 routed experts
top-6 + 2 shared, expert d_ff 1408. (Brief's '160 routed' is the published
model's 64; see DESIGN.md deviations.)"""
from repro.models.config import ModelConfig

ARCH_ID = "deepseek-v2-lite-16b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=0, vocab_size=102400, head_dim=128,
        use_mla=True, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
        v_head_dim=128,
        num_experts=64, num_experts_per_tok=6, num_shared_experts=2,
        moe_d_ff=1408, moe_every=1,
    )


def smoke_config() -> ModelConfig:
    return full_config().with_(
        num_layers=2, d_model=64, num_heads=4, vocab_size=128,
        kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        num_experts=8, num_experts_per_tok=2, num_shared_experts=1,
        moe_d_ff=64)
