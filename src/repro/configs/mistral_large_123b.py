"""mistral-large-123b [dense] — hf:mistralai/Mistral-Large-Instruct-2407.
88L d12288 96H (GQA kv=8) d_ff 28672 vocab 32768."""
from repro.models.config import ModelConfig

ARCH_ID = "mistral-large-123b"


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=88, d_model=12288, num_heads=96, num_kv_heads=8,
        d_ff=28672, vocab_size=32768, head_dim=128,
    )


def smoke_config() -> ModelConfig:
    return full_config().with_(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=128)
