from .registry import (ARCH_IDS, applicable_shapes, get_config,
                       get_shape, get_smoke_config)

__all__ = ["ARCH_IDS", "applicable_shapes", "get_config", "get_shape",
           "get_smoke_config"]
