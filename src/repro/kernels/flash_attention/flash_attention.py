"""Pallas TPU flash attention (forward): causal / sliding-window GQA.

Grid (B, H, Sq/bq, Sk/bk) with the KV-block axis innermost (sequential on
TPU), online-softmax state in VMEM scratch.  Block shapes default to
(128, 128) — MXU-aligned — and K/V blocks index kv-head h // group so GQA
needs no K/V replication.  Fully-masked KV blocks early-out, halving causal
FLOPs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, causal: bool, window: int, scale: float,
                  kv_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * bq
    k_start = ki * bk
    run = jnp.asarray(True)
    if causal:  # whole KV block strictly above the diagonal -> skip
        run = run & (k_start <= q_start + bq - 1)
    if window:  # whole KV block left of every query's window -> skip
        run = run & (k_start + bk - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                 # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                 # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)                 # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                  # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, hd); k, v: (B, KV, Sk, hd); H % KV == 0."""
    B, H, Sq, hd = q.shape
    _, KV, Sk, _ = k.shape
    groups = H // KV
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    kv_blocks = Sk // bk
    grid = (B, H, Sq // bq, kv_blocks)

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, causal=causal, window=window,
        scale=hd**-0.5, kv_blocks=kv_blocks)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki, g=groups: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki, g=groups: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
