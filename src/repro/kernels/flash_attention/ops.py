"""Entry point: Pallas on TPU, interpret-mode validation elsewhere."""
from __future__ import annotations

import jax

from .flash_attention import flash_attention
from .ref import attention_ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              use_pallas: bool = True, bq: int = 128, bk: int = 128):
    if use_pallas:
        return flash_attention(q, k, v, causal=causal, window=window,
                               bq=bq, bk=bk, interpret=_interpret())
    return attention_ref(q, k, v, causal=causal, window=window)
