"""Naive full-materialization attention oracle."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B,H,Sq,hd); k,v: (B,KV,Sk,hd). Full S x S softmax in fp32."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    groups = H // KV
    k = jnp.repeat(k, groups, axis=1)
    v = jnp.repeat(v, groups, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd**-0.5
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
