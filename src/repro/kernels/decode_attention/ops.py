"""Entry point: Pallas on TPU, interpret-mode validation elsewhere."""
from __future__ import annotations

import jax

from .decode_attention import decode_attention
from .ref import decode_attention_ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def decode_attn(q, k, v, pos, *, window: int = 0, use_pallas: bool = True,
                bc: int = 512):
    if use_pallas:
        return decode_attention(q, k, v, pos, window=window, bc=bc,
                                interpret=_interpret())
    return decode_attention_ref(q, k, v, pos, window=window)
