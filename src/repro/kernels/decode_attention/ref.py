"""Oracle for single-token decode attention."""
from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(q, k, v, pos, *, window: int = 0):
    """q: (B,H,hd); k,v: (B,KV,C,hd); pos: () last valid slot index."""
    B, H, hd = q.shape
    KV, C = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bkcd->bkgc", qg, k.astype(jnp.float32)) * hd**-0.5
    c_pos = jnp.arange(C)
    valid = c_pos <= pos
    if window:
        valid &= c_pos > pos - window
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    o = jnp.einsum("bkgc,bkcd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)
