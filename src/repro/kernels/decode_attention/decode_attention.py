"""Pallas TPU kernel: single-token GQA decode attention over a KV cache.

Decode attention is memory-bound (one pass over the cache per token), so the
kernel streams the cache through VMEM in (bc, hd) blocks: grid (B, KV, C/bc)
with the cache-block axis innermost, all G = H/KV query heads of one kv head
processed together (the (G, bc) score tile keeps the MXU busy despite the
single query position).  Invalid (unwritten / out-of-window) cache slots are
masked by position.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *,
                   bc: int, window: int, scale: float, c_blocks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[0]
    q = q_ref[0, 0].astype(jnp.float32)                     # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)                     # (bc, hd)
    v = v_ref[0, 0].astype(jnp.float32)                     # (bc, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    c_pos = ci * bc + jax.lax.broadcasted_iota(jnp.int32, (1, bc), 1)
    valid = c_pos <= pos
    if window:
        valid &= c_pos > pos - window
    s = jnp.where(valid, s, NEG_INF)                         # (G, bc)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ci == c_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "bc", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     pos: jax.Array, *, window: int = 0, bc: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q: (B, H, hd) one token; k, v: (B, KV, C, hd) cache; pos: () int32 —
    index of the LAST valid cache slot.  Returns (B, H, hd)."""
    B, H, hd = q.shape
    _, KV, C, _ = k.shape
    G = H // KV
    bc = min(bc, C)
    assert C % bc == 0
    c_blocks = C // bc
    qg = q.reshape(B, KV, G, hd)

    kernel = functools.partial(_decode_kernel, bc=bc, window=window,
                               scale=hd**-0.5, c_blocks=c_blocks)
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, c_blocks),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, hd), lambda b, g, ci: (b, g, 0, 0)),
            pl.BlockSpec((1, 1, bc, hd), lambda b, g, ci: (b, g, ci, 0)),
            pl.BlockSpec((1, 1, bc, hd), lambda b, g, ci: (b, g, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, g, ci: (b, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(pos[None].astype(jnp.int32), qg, k, v)
    return out.reshape(B, H, hd)
