"""Pallas TPU kernel: fused VQS-BF slot-step engine (DESIGN.md §13).

One program instance simulates one independent cluster of the Monte-Carlo
ensemble: the grid is ``(G, NW)`` — ensemble member x time window — and the
whole mutable simulation state (per-slot job sizes / departure slots / VQ
types, the 2J size-bucketed rings WITH their sequence-stamp plane, the
per-server ``(k_1, j*, k_{j*})`` configurations, the ``_empty`` membership
and the subscription matrix) lives in VMEM scratch that persists across the
sequentially-executed time windows of a member.

The serve pass is the branch-free one-placement-per-step work list of
``repro.core.engine.vqs_bf.run_vqs_bf_streams`` — staged (i)/(ii)/(iii)
largest-fit pops from the bucketed rings, shared max-weight renewal,
vectorized advance-past writes — transcribed with broadcasted-iota masks
and masked reductions in place of every dynamic index ("pop the largest
job <= residual" is a three-reduction lexicographic argmax over the
``(2J, Qcap)`` planes), unrolled to the fixed ``work_steps + 1`` bound (the
kernel pays the bound; the host scan engine early-exits — same trajectory).
Each slot closes with the arrival-side BF-J pass: an unrolled ``A_max``
loop offering every still-queued arrival (identified by its surviving
sequence stamp) to the tightest feasible server.

Trajectories are bit-compatible with the scan engine (and, through it,
with the event-driven ``core/vqs_bf.py`` engine on trace streams) whenever
``truncated`` stays 0 — asserted by the interpret-mode parity tests in
tests/test_vqs_bf_engine.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quantize import RES
from repro.kernels.common import resolve_windows

INF_SLOT = jnp.iinfo(jnp.int32).max
INF32 = jnp.iinfo(jnp.int32).max
CAP = RES


def _vqs_bf_kernel(n_ref, sizes_ref, durs_ref, confs_ref,
                   qlen_ref, occ_ref, ndep_ref, dropped_ref, trunc_ref,
                   srv_ref, dep_ref, vqof_ref, reff_ref, rdur_ref, rseq_ref,
                   meta_ref, cfg_ref, want_ref, acc_ref,
                   *, J, L, K, Qcap, A_max, W, TW):
    w = pl.program_id(1)
    nvq = 2 * J
    C = confs_ref.shape[0]

    @pl.when(w == 0)
    def _init():
        srv_ref[...] = jnp.zeros((L, K), jnp.int32)
        dep_ref[...] = jnp.full((L, K), INF_SLOT, jnp.int32)
        vqof_ref[...] = jnp.full((L, K), -1, jnp.int32)
        reff_ref[...] = jnp.zeros((nvq, Qcap), jnp.int32)
        rdur_ref[...] = jnp.ones((nvq, Qcap), jnp.int32)
        rseq_ref[...] = jnp.zeros((nvq, Qcap), jnp.int32)
        meta_ref[...] = jnp.zeros((2, nvq), jnp.int32)  # qcnt row, seq_ctr
        cfg = jnp.zeros((5, L), jnp.int32)
        cfg = cfg.at[1].set(-1)      # cfg_js = -1 (no active configuration)
        cfg = cfg.at[4].set(1)       # in_empty: all servers start empty
        cfg_ref[...] = cfg
        want_ref[...] = jnp.zeros((L, nvq), jnp.int32)
        acc_ref[...] = jnp.zeros((1, 2), jnp.int32)

    l_col = jax.lax.broadcasted_iota(jnp.int32, (L, 1), 0)
    j_row = jax.lax.broadcasted_iota(jnp.int32, (1, nvq), 1)
    q_jq = jax.lax.broadcasted_iota(jnp.int32, (nvq, Qcap), 1)
    j_jq = jax.lax.broadcasted_iota(jnp.int32, (nvq, Qcap), 0)
    k_row = jax.lax.broadcasted_iota(jnp.int32, (1, K), 1)
    c_col = jax.lax.broadcasted_iota(jnp.int32, (C, 1), 0)
    c_flat = jax.lax.broadcasted_iota(jnp.int32, (C, nvq), 0)
    confs = confs_ref[...]

    def slot_step(tt, carry):
        dropped, trunc = carry
        t = w * TW + tt

        # 1. departures
        dep = dep_ref[...]
        srv = srv_ref[...]
        vqof = vqof_ref[...]
        leaving = dep == t
        freed = leaving.any(axis=1, keepdims=True)            # (L, 1)
        n_dep = leaving.sum()
        srv = jnp.where(leaving, 0, srv)
        vqof = jnp.where(leaving, -1, vqof)
        srv_ref[...] = srv
        vqof_ref[...] = vqof
        dep_ref[...] = jnp.where(leaving, INF_SLOT, dep)
        empty_now = (srv > 0).sum(axis=1, keepdims=True) == 0  # (L, 1)

        # 2. arrivals: classify on the grid, push to first-empty bucket
        # slots with fresh sequence stamps (lane order == push order)
        n_t = n_ref[0, tt]
        meta = meta_ref[...]
        qcnt = meta[0:1]                                       # (1, nvq)
        seq_ctr = meta[1, 0]
        reff = reff_ref[...]
        rdur = rdur_ref[...]
        rseq = rseq_ref[...]
        arrived = jnp.zeros((1, nvq), bool)
        lanes = []
        for a in range(A_max):
            valid = a < n_t
            g = jnp.maximum(jnp.round(sizes_ref[0, tt, a] * RES),
                            1.0).astype(jnp.int32)
            m_h = jnp.int32(0)
            for kk in range(1, J + 1):
                m_h = m_h + (g <= (RES >> kk)).astype(jnp.int32)
            m_h = jnp.minimum(m_h, J - 1)
            upper = jnp.right_shift(jnp.int32(RES), m_h)
            vq_a = jnp.where(3 * g > 2 * upper, 2 * m_h, 2 * m_h + 1)
            vq_a = jnp.where(g <= (RES >> J), nvq - 1, vq_a)
            eff_a = jnp.where(vq_a == nvq - 1, jnp.maximum(g, RES >> J), g)
            dur_a = durs_ref[0, tt, durs_ref.shape[-1] - A_max + a]
            seq_a = seq_ctr + a
            emp_row = (j_jq == vq_a) & (reff == 0)             # (nvq, Qcap)
            pos = jnp.min(jnp.where(emp_row, q_jq, Qcap))
            land = valid & (pos < Qcap)
            wm = (j_jq == vq_a) & (q_jq == pos) & land
            reff = jnp.where(wm, eff_a, reff)
            rdur = jnp.where(wm, dur_a, rdur)
            rseq = jnp.where(wm, seq_a, rseq)
            oh = j_row == vq_a                                 # (1, nvq)
            qcnt = qcnt + jnp.where(oh & land, 1, 0)
            dropped = dropped + jnp.where(valid & ~land, 1, 0)
            arrived = arrived | (oh & valid)
            lanes.append((vq_a, pos, seq_a, eff_a, dur_a, land))
        reff_ref[...] = reff
        rdur_ref[...] = rdur
        rseq_ref[...] = rseq
        meta = meta.at[0].set(qcnt[0])
        meta_ref[...] = meta.at[1, 0].set(seq_ctr + A_max)

        # 3. visit set
        want = want_ref[...] != 0                              # (L, nvq)
        woken = (want & arrived).any(axis=1, keepdims=True)
        want_ref[...] = (want & ~arrived).astype(jnp.int32)
        cfgm = cfg_ref[...]
        has_cfg0 = (cfgm[3:4] != 0).T                          # (L, 1)
        in_empty0 = (cfgm[4:5] != 0).T
        visit = freed | woken | (in_empty0 & (qcnt.sum() > 0))
        renew_needed = visit & (empty_now | ~has_cfg0)

        # 4. work list: W+1 one-placement steps (fixed unroll — each
        # iteration is the scan engine's masked-select step verbatim)
        def work(_, wcarry):
            touched, advanced, trunc = wcarry
            qcnt = meta_ref[0:1, :]
            reff = reff_ref[...]
            rdur = rdur_ref[...]
            rseq = rseq_ref[...]
            srv = srv_ref[...]
            vqof = vqof_ref[...]
            cfgm = cfg_ref[...]
            cfg_k1 = (cfgm[0:1] != 0).T                        # (L, 1)
            cfg_js = cfgm[1:2].T
            cfg_ks = cfgm[2:3].T
            has_cfg = (cfgm[3:4] != 0).T
            in_empty = (cfgm[4:5] != 0).T
            want = want_ref[...] != 0

            pending = visit & ~advanced
            hx = qcnt > 0
            occ_ring = reff > 0
            row_min = jnp.min(jnp.where(occ_ring, reff, INF32),
                              axis=1)[None, :]                 # (1, nvq)
            glob_min = row_min.min()

            # shared max-weight renewal candidate (first-index argmax)
            w_c = jnp.sum(confs * qcnt, axis=1)                # (C,)
            ci = jnp.min(jnp.where(w_c == w_c.max(), c_flat[:, 0], C))
            row = jnp.sum(jnp.where(c_col == ci, confs, 0),
                          axis=0)[None, :]                     # (1, nvq)
            r_k1 = jnp.sum(jnp.where(j_row == 1, row, 0)) > 0
            r_js = jnp.min(jnp.where((row > 0) & (j_row != 1), j_row, nvq))
            r_js = jnp.where(r_js == nvq, -1, r_js)
            r_ks = jnp.sum(jnp.where(j_row == jnp.maximum(r_js, 0), row, 0))
            r_ks = jnp.where(r_js >= 0, r_ks, 0)
            ren = renew_needed & ~touched
            eff_k1 = jnp.where(ren, r_k1, cfg_k1)
            eff_js = jnp.where(ren, r_js, cfg_js)              # (L, 1)
            eff_ks = jnp.where(ren, r_ks, cfg_ks)

            occ = srv.sum(axis=1, keepdims=True)
            resid = CAP - occ
            has_vq1 = ((vqof == 1) & (srv > 0)).any(axis=1, keepdims=True)
            js_oh = eff_js == j_row                            # (L, nvq)
            js_min = jnp.min(jnp.where(js_oh, row_min, INF32),
                             axis=1, keepdims=True)
            js_ex = (js_oh & hx).any(axis=1, keepdims=True)
            cnt_js = ((vqof == eff_js) & (srv > 0)).sum(axis=1,
                                                        keepdims=True)
            rm1 = jnp.min(jnp.where(j_row == 1, row_min, INF32))

            k1_can = eff_k1 & ~has_vq1 & (rm1 <= resid)
            js_can = (eff_js >= 0) & (cnt_js < eff_ks) & (js_min <= resid)
            any_can = glob_min <= resid
            would = pending & (k1_can | js_can | any_can)

            placer = jnp.min(jnp.where(would, l_col, L))
            tch = pending & (l_col <= placer)
            adv = pending & (l_col < placer)
            do_ren = tch & ren
            new_k1 = jnp.where(do_ren, r_k1, cfg_k1)
            new_js = jnp.where(do_ren, r_js, cfg_js)
            new_ks = jnp.where(do_ren, r_ks, cfg_ks)
            new_has = has_cfg | tch
            # first touch only — see engine/vqs.py (stale empty_now mask)
            new_empty = in_empty | (tch & ~touched & empty_now)
            touched = touched | tch
            advanced = advanced | adv

            sub1 = adv & eff_k1 & ~has_vq1 & ~(hx & (j_row == 1)).any()
            subj = adv & (eff_js >= 0) & (cnt_js < eff_ks) & ~js_ex
            want = want | (sub1 & (j_row == 1)) | (subj & js_oh)
            want_ref[...] = want.astype(jnp.int32)

            # serve the placer: ONE staged (i)/(ii)/(iii) largest-fit pop
            any_p = placer < L
            rowmask = l_col == placer                          # (L, 1)
            do1 = (rowmask & k1_can).any()
            doj = ~do1 & (rowmask & js_can).any()
            jsx_s = jnp.maximum(jnp.max(jnp.where(rowmask, eff_js, -1)), 0)
            rowsel = jnp.where(do1, j_jq == 1,
                               jnp.where(doj, j_jq == jsx_s, True))
            resid_s = jnp.max(jnp.where(rowmask, resid, -1))
            elig = occ_ring & rowsel & (reff <= resid_s)
            best_eff = jnp.max(jnp.where(elig, reff, 0))
            cand = elig & (reff == best_eff)
            vq_p = jnp.min(jnp.where(cand, j_jq, nvq))         # lowest VQ
            found = vq_p < nvq
            row_cand = cand & (j_jq == vq_p)
            best_seq = jnp.min(jnp.where(row_cand, rseq, INF32))
            entry = row_cand & (rseq == best_seq)              # FIFO tie
            pos_p = jnp.min(jnp.where(entry, q_jq, Qcap))
            pm = (j_jq == vq_p) & (q_jq == pos_p)
            eff_p = jnp.sum(jnp.where(pm, reff, 0))
            dur_p = jnp.sum(jnp.where(pm, rdur, 0))
            do_place = any_p & found

            row_srv = jnp.sum(jnp.where(rowmask, srv, 0),
                              axis=0)[None, :]                 # (1, K)
            es = row_srv == 0
            kfree = jnp.min(jnp.where(es, k_row, K))
            ok = kfree < K
            lk = rowmask & (k_row == kfree) & ok & do_place    # (L, K)
            srv_ref[...] = jnp.where(lk, eff_p, srv)
            dep_ref[...] = jnp.where(lk, t + dur_p, dep_ref[...])
            vqof_ref[...] = jnp.where(lk, vq_p, vqof)
            reff_ref[...] = jnp.where(pm & do_place, 0, reff)
            meta = meta_ref[...]
            meta_ref[...] = meta.at[0].set(
                (qcnt - jnp.where((j_row == vq_p) & do_place, 1, 0))[0])
            trunc = trunc + (do_place & ~ok).astype(jnp.int32)  # K-overflow
            new_empty = new_empty & ~(rowmask & do_place)
            cfg_ref[...] = jnp.concatenate(
                [new_k1.astype(jnp.int32).T, new_js.T, new_ks.T,
                 new_has.astype(jnp.int32).T,
                 new_empty.astype(jnp.int32).T], axis=0)
            return touched, advanced, trunc

        false_col = jnp.zeros((L, 1), bool)
        _, advanced, trunc = jax.lax.fori_loop(
            0, W + 1, work, (false_col, false_col, trunc))
        # bound hit with servers still unserved: slot finished lazily
        trunc = trunc + (visit & ~advanced).any().astype(jnp.int32)

        # 5. arrival-side BF-J pass: each still-queued arrival (sequence
        # stamp survived the serve pass) to the tightest feasible server
        for vq_a, pos_a, seq_a, eff_a, dur_a, land in lanes:
            reff = reff_ref[...]
            rseq = rseq_ref[...]
            srv = srv_ref[...]
            em = (j_jq == vq_a) & (q_jq == pos_a)
            queued = land & (jnp.sum(jnp.where(em, reff, 0)) > 0) \
                & (jnp.sum(jnp.where(em, rseq, 0)) == seq_a)
            resid = CAP - srv.sum(axis=1, keepdims=True)       # (L, 1)
            candm = resid >= eff_a
            rbest = jnp.min(jnp.where(candm, resid, INF32))
            s = jnp.min(jnp.where(candm & (resid == rbest), l_col, L))
            do = queued & (s < L)
            rowmask = l_col == s
            row_srv = jnp.sum(jnp.where(rowmask, srv, 0),
                              axis=0)[None, :]
            es = row_srv == 0
            kfree = jnp.min(jnp.where(es, k_row, K))
            ok = kfree < K
            lk = rowmask & (k_row == kfree) & ok & do
            srv_ref[...] = jnp.where(lk, eff_a, srv)
            dep_ref[...] = jnp.where(lk, t + dur_a, dep_ref[...])
            vqof_ref[...] = jnp.where(lk, vq_a, vqof_ref[...])
            reff_ref[...] = jnp.where(em & do, 0, reff)
            meta = meta_ref[...]
            meta_ref[...] = meta.at[0].set(
                (meta[0:1] - jnp.where((j_row == vq_a) & do, 1, 0))[0])
            trunc = trunc + (do & ~ok).astype(jnp.int32)
            cfgm = cfg_ref[...]
            in_empty = (cfgm[4:5] != 0).T & ~(rowmask & do)
            cfg_ref[...] = cfgm.at[4].set(in_empty.astype(jnp.int32).T[0])

        qlen_ref[0, tt] = meta_ref[0:1, :].sum()
        occ_ref[0, tt] = srv_ref[...].sum().astype(jnp.float32) / RES
        ndep_ref[0, tt] = n_dep.astype(jnp.int32)
        return dropped, trunc

    acc = acc_ref[...]
    dropped, trunc = jax.lax.fori_loop(
        0, TW, slot_step, (acc[0, 0], acc[0, 1]))
    acc_ref[...] = jnp.stack([dropped, trunc])[None, :]
    dropped_ref[0, 0] = dropped
    trunc_ref[0, 0] = trunc


@functools.partial(
    jax.jit,
    static_argnames=("J", "L", "K", "Qcap", "A_max", "work_steps", "window",
                     "interpret"))
def vqs_bf_pallas(n: jax.Array, sizes: jax.Array, durs: jax.Array,
                  J: int, L: int, K: int, Qcap: int, A_max: int,
                  work_steps: int, window: int | None = None,
                  interpret: bool = False):
    """Run the fused VQS-BF slot engine on an ensemble of clusters.

    n (G, T) int32, sizes (G, T, A_max) f32, durs (G, T, D) int32 with the
    per-arrival durations in the last A_max lanes — one pre-generated
    stream set per ensemble member.  Returns per-slot (queue_len,
    occupancy, departures) of shape (G, T) plus (dropped, truncated) of
    shape (G,).  ``window`` splits the horizon into VMEM-sized chunks
    exactly as for the VQS kernel (must divide T)."""
    from repro.core.engine.ops import k_red_jnp

    G, T = n.shape
    TW, NW = resolve_windows(T, window)
    D = durs.shape[-1]
    confs = k_red_jnp(J)
    C = confs.shape[0]
    nvq = 2 * J
    kernel = functools.partial(
        _vqs_bf_kernel, J=J, L=L, K=K, Qcap=Qcap, A_max=A_max,
        W=work_steps, TW=TW)
    qlen, occ, ndep, dropped, trunc = pl.pallas_call(
        kernel,
        grid=(G, NW),
        out_shape=(jax.ShapeDtypeStruct((G, T), jnp.int32),
                   jax.ShapeDtypeStruct((G, T), jnp.float32),
                   jax.ShapeDtypeStruct((G, T), jnp.int32),
                   jax.ShapeDtypeStruct((G, 1), jnp.int32),
                   jax.ShapeDtypeStruct((G, 1), jnp.int32)),
        in_specs=[pl.BlockSpec((1, TW), lambda g, w: (g, w)),
                  pl.BlockSpec((1, TW, A_max), lambda g, w: (g, w, 0)),
                  pl.BlockSpec((1, TW, D), lambda g, w: (g, w, 0)),
                  pl.BlockSpec((C, nvq), lambda g, w: (0, 0))],
        out_specs=(pl.BlockSpec((1, TW), lambda g, w: (g, w)),
                   pl.BlockSpec((1, TW), lambda g, w: (g, w)),
                   pl.BlockSpec((1, TW), lambda g, w: (g, w)),
                   pl.BlockSpec((1, 1), lambda g, w: (g, 0)),
                   pl.BlockSpec((1, 1), lambda g, w: (g, 0))),
        scratch_shapes=[pltpu.VMEM((L, K), jnp.int32),
                        pltpu.VMEM((L, K), jnp.int32),
                        pltpu.VMEM((L, K), jnp.int32),
                        pltpu.VMEM((nvq, Qcap), jnp.int32),
                        pltpu.VMEM((nvq, Qcap), jnp.int32),
                        pltpu.VMEM((nvq, Qcap), jnp.int32),
                        pltpu.VMEM((2, nvq), jnp.int32),
                        pltpu.VMEM((5, L), jnp.int32),
                        pltpu.VMEM((L, nvq), jnp.int32),
                        pltpu.VMEM((1, 2), jnp.int32)],
        interpret=interpret,
    )(n, sizes, durs, confs)
    return qlen, occ, ndep, dropped[:, 0], trunc[:, 0]
