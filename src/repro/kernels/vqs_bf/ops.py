"""Public entry point: Pallas on TPU, interpret-mode elsewhere."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.engine.streams import PolicyResult, SchedStreams, \
    resolve_work_steps
from repro.kernels.common import interpret_default

from .ref import vqs_bf_ref
from .vqs_bf import vqs_bf_pallas


def vqs_bf_scratch_bytes(J: int, L: int, K: int, Qcap: int) -> int:
    """Estimated per-core VMEM scratch of the fused VQS-BF kernel: three
    (L,K) planes, THREE (2J,Qcap) bucket planes (effective size, duration,
    sequence stamp — one more than VQS, the price of largest-fit-first
    FIFO tie-breaking), (2,2J) counts block, (5,L) per-server block,
    (L,2J) subscription block and a (1,2) scalar block — all int32.
    Checked against ``kernels.common.vmem_budget_bytes`` by the engine
    dispatch before launching (DESIGN.md §8/§13)."""
    nvq = 2 * J
    return 4 * (3 * L * K + 3 * nvq * Qcap + 2 * nvq + 5 * L + L * nvq + 2)


def vqs_bf_simulate(streams: SchedStreams, J: int, L: int, K: int,
                    Qcap: int, A_max: int, work_steps: int | None = None,
                    window: int | None = None,
                    use_pallas: bool = True) -> PolicyResult:
    """Fused-kernel Monte-Carlo VQS-BF: one grid cell per ensemble member.

    streams holds (G, ...)-shaped pre-generated randomness
    (engine.streams.make_streams vmapped over the ensemble keys)."""
    work_steps = resolve_work_steps(work_steps, A_max)
    if not use_pallas:
        return vqs_bf_ref(streams.n, streams.sizes, streams.durs, J=J, L=L,
                          K=K, Qcap=Qcap, A_max=A_max,
                          work_steps=work_steps)
    qlen, occ, ndep, dropped, trunc = vqs_bf_pallas(
        streams.n, streams.sizes, streams.durs, J=J, L=L, K=K, Qcap=Qcap,
        A_max=A_max, work_steps=work_steps, window=window,
        interpret=interpret_default())
    z = jnp.zeros_like(dropped)  # kernels simulate fault-free clusters
    return PolicyResult(qlen, occ, jnp.cumsum(ndep, axis=1), dropped, trunc,
                        z, z, z)
