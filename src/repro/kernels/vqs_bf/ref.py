"""Pure-jnp oracle for the fused VQS-BF slot-step kernel.

The oracle IS the production scan engine (engine.vqs_bf.run_vqs_bf_streams)
vmapped over the ensemble dimension — the kernel must reproduce its
trajectories exactly (and that engine is itself equivalence-tested against
the nested-loop reference engine and, on trace streams, the event-driven
numpy engine)."""
from __future__ import annotations

import jax

from repro.core.engine.streams import PolicyResult, SchedStreams
from repro.core.engine.vqs_bf import run_vqs_bf_streams


def vqs_bf_ref(n, sizes, durs, J: int, L: int, K: int, Qcap: int,
               A_max: int,
               work_steps: int | None = None) -> PolicyResult:
    """n (G, T) int32, sizes (G, T, A_max) f32, durs (G, T, D) int32 ->
    PolicyResult with (G, ...)-shaped fields."""

    def one(n1, s1, d1):
        return run_vqs_bf_streams(SchedStreams(n1, s1, d1), J=J, L=L, K=K,
                                  Qcap=Qcap, A_max=A_max,
                                  work_steps=work_steps)

    return jax.vmap(one)(n, sizes, durs)
