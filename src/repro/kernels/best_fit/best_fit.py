"""Pallas TPU kernel: sequential Best-Fit placement (the paper's BF inner loop).

Jobs are placed one at a time into the feasible server with least residual
capacity (BF-J, Section IV).  The sequential dependence across jobs lives in
a ``fori_loop`` INSIDE the kernel while the per-job candidate search is a
masked min-reduction over the residual vector held in VMEM — residuals never
round-trip to HBM between placements.  (On GPU this would be a warp-shuffle
argmin; the VMEM-resident loop is the TPU-idiomatic equivalent — see
DESIGN.md §3.  The fused slot-step engine kernel in kernels/bfjs
generalizes this pattern to whole cluster simulations, DESIGN.md §4.)

Shapes: residuals (L,), sizes (N,) -> assignment (N,) int32 (-1 = rejected),
updated residuals (L,).  The batched entry point grids over independent
(queue, cluster) pairs — one serving cell per program instance.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.4e38  # ~f32 max; sentinel for infeasible servers


def _best_fit_kernel(resid_ref, sizes_ref, assign_ref, out_resid_ref):
    out_resid_ref[...] = resid_ref[...]
    L = out_resid_ref.shape[-1]
    n = sizes_ref.shape[-1]
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, L), 1)

    def body(i, _):
        size = sizes_ref[0, i]
        r = out_resid_ref[...]                                # (1, L)
        feasible = r >= size
        masked = jnp.where(feasible, r, BIG)
        best = jnp.min(masked)
        # tightest server, lowest index tie-break
        is_best = (masked == best) & feasible
        srv = jnp.min(jnp.where(is_best, lane, L))
        ok = (srv < L) & (size > 0)
        take = ok & (lane == srv)
        out_resid_ref[...] = jnp.where(take, r - size, r)
        assign_ref[0, i] = jnp.where(ok, srv, -1)
        return 0

    jax.lax.fori_loop(0, n, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def best_fit_pallas(residuals: jax.Array, sizes: jax.Array,
                    interpret: bool = False):
    """Single-cluster Best-Fit. residuals (L,) f32, sizes (N,) f32."""
    L, = residuals.shape
    N, = sizes.shape
    assign, new_resid = pl.pallas_call(
        _best_fit_kernel,
        out_shape=(jax.ShapeDtypeStruct((1, N), jnp.int32),
                   jax.ShapeDtypeStruct((1, L), residuals.dtype)),
        in_specs=[pl.BlockSpec((1, L), lambda: (0, 0)),
                  pl.BlockSpec((1, N), lambda: (0, 0))],
        out_specs=(pl.BlockSpec((1, N), lambda: (0, 0)),
                   pl.BlockSpec((1, L), lambda: (0, 0))),
        interpret=interpret,
    )(residuals[None], sizes[None])
    return assign[0], new_resid[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def best_fit_pallas_batched(residuals: jax.Array, sizes: jax.Array,
                            interpret: bool = False):
    """Batched Best-Fit: residuals (G, L), sizes (G, N) — one grid cell per
    independent scheduling problem (e.g. per serving replica group)."""
    G, L = residuals.shape
    _, N = sizes.shape
    assign, new_resid = pl.pallas_call(
        _best_fit_kernel,
        grid=(G,),
        out_shape=(jax.ShapeDtypeStruct((G, N), jnp.int32),
                   jax.ShapeDtypeStruct((G, L), residuals.dtype)),
        in_specs=[pl.BlockSpec((1, L), lambda g: (g, 0)),
                  pl.BlockSpec((1, N), lambda g: (g, 0))],
        out_specs=(pl.BlockSpec((1, N), lambda g: (g, 0)),
                   pl.BlockSpec((1, L), lambda g: (g, 0))),
        interpret=interpret,
    )(residuals, sizes)
    return assign, new_resid
