"""Pure-jnp oracle for the Best-Fit placement kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def best_fit_ref(residuals: jax.Array, sizes: jax.Array):
    """Sequential Best-Fit: each job -> feasible server with least residual
    (lowest index tie-break). Returns (assignment (N,), new residuals (L,))."""
    L, = residuals.shape

    def body(resid, size):
        feasible = resid >= size
        masked = jnp.where(feasible, resid, jnp.inf)
        best = jnp.min(masked)
        is_best = (masked == best) & feasible
        srv = jnp.argmax(is_best)  # lowest index among ties
        ok = feasible.any() & (size > 0)
        resid = jnp.where(ok, resid.at[srv].add(-size), resid)
        return resid, jnp.where(ok, srv, -1).astype(jnp.int32)

    new_resid, assign = jax.lax.scan(body, residuals, sizes)
    return assign, new_resid


def best_fit_ref_batched(residuals: jax.Array, sizes: jax.Array):
    return jax.vmap(best_fit_ref)(residuals, sizes)
