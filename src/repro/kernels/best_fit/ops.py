"""Public entry point: Pallas on TPU, interpret-mode elsewhere."""
from __future__ import annotations

import jax

from .best_fit import best_fit_pallas, best_fit_pallas_batched
from .ref import best_fit_ref, best_fit_ref_batched


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def best_fit(residuals, sizes, use_pallas: bool = True):
    if use_pallas:
        return best_fit_pallas(residuals, sizes, interpret=_interpret())
    return best_fit_ref(residuals, sizes)


def best_fit_batched(residuals, sizes, use_pallas: bool = True):
    if use_pallas:
        return best_fit_pallas_batched(residuals, sizes,
                                       interpret=_interpret())
    return best_fit_ref_batched(residuals, sizes)
