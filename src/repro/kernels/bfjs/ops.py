"""Public entry point: Pallas on TPU, interpret-mode elsewhere."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.engine.streams import PolicyResult, SchedStreams, \
    resolve_work_steps
from repro.kernels.common import interpret_default

from .bfjs import bfjs_pallas
from .ref import bfjs_ref


def bfjs_scratch_bytes(L: int, K: int, Qcap: int, A_max: int) -> int:
    """Estimated per-core VMEM scratch of the fused BF-J/S kernel: the
    persistent simulation state — srv (L,K) f32, dep (L,K) i32, queue
    (1,Qcap) f32, scalar block (1,4) i32 — all 4-byte lanes.  Checked
    against ``kernels.common.vmem_budget_bytes`` by the engine dispatch
    before launching (graceful-degradation rule, DESIGN.md §8/§9)."""
    del A_max
    return 4 * (2 * L * K + Qcap + 4)


def bfjs_simulate(streams: SchedStreams, L: int, K: int, Qcap: int,
                  A_max: int, work_steps: int | None = None,
                  window: int | None = None,
                  use_pallas: bool = True) -> PolicyResult:
    """Fused-kernel Monte-Carlo BF-J/S: one grid cell per ensemble member.

    streams holds (G, ...)-shaped pre-generated randomness
    (engine.streams.make_streams vmapped over the ensemble keys)."""
    work_steps = resolve_work_steps(work_steps, A_max)
    if not use_pallas:
        return bfjs_ref(streams.n, streams.sizes, streams.durs, L=L, K=K,
                        Qcap=Qcap, A_max=A_max, work_steps=work_steps)
    qlen, occ, ndep, dropped, trunc = bfjs_pallas(
        streams.n, streams.sizes, streams.durs, L=L, K=K, Qcap=Qcap,
        A_max=A_max, work_steps=work_steps, window=window,
        interpret=interpret_default())
    z = jnp.zeros_like(dropped)  # kernels simulate fault-free clusters
    return PolicyResult(qlen, occ, jnp.cumsum(ndep, axis=1), dropped, trunc,
                        z, z, z)
