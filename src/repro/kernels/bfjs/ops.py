"""Public entry point: Pallas on TPU, interpret-mode elsewhere."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.engine.streams import PolicyResult, SchedStreams, \
    resolve_work_steps
from repro.kernels.common import interpret_default

from .bfjs import bfjs_pallas
from .ref import bfjs_ref


def bfjs_simulate(streams: SchedStreams, L: int, K: int, Qcap: int,
                  A_max: int, work_steps: int | None = None,
                  window: int | None = None,
                  use_pallas: bool = True) -> PolicyResult:
    """Fused-kernel Monte-Carlo BF-J/S: one grid cell per ensemble member.

    streams holds (G, ...)-shaped pre-generated randomness
    (engine.streams.make_streams vmapped over the ensemble keys)."""
    work_steps = resolve_work_steps(work_steps, A_max)
    if not use_pallas:
        return bfjs_ref(streams.n, streams.sizes, streams.durs, L=L, K=K,
                        Qcap=Qcap, A_max=A_max, work_steps=work_steps)
    qlen, occ, ndep, dropped, trunc = bfjs_pallas(
        streams.n, streams.sizes, streams.durs, L=L, K=K, Qcap=Qcap,
        A_max=A_max, work_steps=work_steps, window=window,
        interpret=interpret_default())
    return PolicyResult(qlen, occ, jnp.cumsum(ndep, axis=1), dropped, trunc)
