"""Public entry point: Pallas on TPU, interpret-mode elsewhere."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.jax_sched import (BFJSResult, BFJSStreams,
                                  _resolve_work_steps)

from .bfjs import bfjs_pallas
from .ref import bfjs_ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def bfjs_simulate(streams: BFJSStreams, L: int, K: int, Qcap: int,
                  A_max: int, work_steps: int | None = None,
                  window: int | None = None,
                  use_pallas: bool = True) -> BFJSResult:
    """Fused-kernel Monte-Carlo BF-J/S: one grid cell per ensemble member.

    streams holds (G, ...)-shaped pre-generated randomness
    (jax_sched.make_streams vmapped over the ensemble keys)."""
    work_steps = _resolve_work_steps(work_steps, A_max)
    if not use_pallas:
        return bfjs_ref(streams.n, streams.sizes, streams.durs, L=L, K=K,
                        Qcap=Qcap, A_max=A_max, work_steps=work_steps)
    qlen, occ, ndep, dropped, trunc = bfjs_pallas(
        streams.n, streams.sizes, streams.durs, L=L, K=K, Qcap=Qcap,
        A_max=A_max, work_steps=work_steps, window=window,
        interpret=_interpret())
    return BFJSResult(qlen, occ, jnp.cumsum(ndep, axis=1), dropped, trunc)
