"""Pure-jnp oracle for the fused BF-J/S slot-step kernel.

The oracle IS the production pure-JAX engine (engine.bfjs.run_bfjs_streams)
vmapped over the ensemble dimension — the kernel must reproduce its
trajectories exactly (and that engine is itself equivalence-tested against
the original nested-loop reference engine)."""
from __future__ import annotations

import jax

from repro.core.engine.bfjs import run_bfjs_streams
from repro.core.engine.streams import PolicyResult, SchedStreams


def bfjs_ref(n, sizes, durs, L: int, K: int, Qcap: int, A_max: int,
             work_steps: int | None = None) -> PolicyResult:
    """n (G, T) int32, sizes (G, T, A_max) f32, durs (G, T, L*K+A_max)
    int32 -> PolicyResult with (G, ...)-shaped fields."""

    def one(n1, s1, d1):
        return run_bfjs_streams(SchedStreams(n1, s1, d1), L=L, K=K,
                                Qcap=Qcap, A_max=A_max,
                                work_steps=work_steps)

    return jax.vmap(one)(n, sizes, durs)
