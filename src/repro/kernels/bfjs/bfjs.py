"""Pallas TPU kernel: fused BF-J/S slot-step engine (DESIGN.md §4).

One program instance simulates one independent cluster of the Monte-Carlo
ensemble: the grid is ``(G, NW)`` — ensemble member x time window — and the
whole mutable simulation state (per-slot job sizes, departure slots, the
queue buffer and the running counters) lives in VMEM scratch that persists
across the sequentially-executed time windows of a member.  Every slot step
(departures -> enqueue -> BF-S refill -> BF-J placement) runs inside the
kernel with no HBM round-trips; only the pre-generated randomness streams
(arrival counts, job sizes, service durations) are streamed in per window
and only the per-slot outputs (queue length, occupancy, departures) are
streamed out.

The placement logic is a transcription of the bounded masked-select work
list of ``repro.core.jax_sched.run_bfjs_streams`` (see DESIGN.md §2): no
``cond``, no data-dependent trip counts, every dynamic index expressed as a
broadcasted-iota mask + reduction so the body is pure vector ops.
Trajectories are bit-compatible with the pure-JAX engine (and therefore
with the reference engine) whenever the ``truncated`` counter stays 0 —
asserted by the interpret-mode parity tests in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import resolve_windows

INF_SLOT = jnp.iinfo(jnp.int32).max
BIG = 3.4e38  # ~f32 max; infeasibility sentinel (matches kernels/best_fit)


def _bfjs_kernel(n_ref, sizes_ref, durs_ref,
                 qlen_ref, occ_ref, ndep_ref, dropped_ref, trunc_ref,
                 srv_ref, dep_ref, queue_ref, acc_ref,
                 *, L, K, Qcap, A_max, W, TW):
    w = pl.program_id(1)
    D = L * K + A_max

    @pl.when(w == 0)
    def _init():
        srv_ref[...] = jnp.zeros((L, K), jnp.float32)
        dep_ref[...] = jnp.full((L, K), INF_SLOT, jnp.int32)
        queue_ref[...] = jnp.zeros((1, Qcap), jnp.float32)
        acc_ref[...] = jnp.zeros((1, 4), jnp.int32)

    l_iota = jax.lax.broadcasted_iota(jnp.int32, (L, 1), 0)
    k_iota = jax.lax.broadcasted_iota(jnp.int32, (L, K), 1)
    q_iota = jax.lax.broadcasted_iota(jnp.int32, (1, Qcap), 1)
    a_iota = jax.lax.broadcasted_iota(jnp.int32, (1, A_max), 1)
    d_iota = jax.lax.broadcasted_iota(jnp.int32, (1, D), 1)
    r_iota = jax.lax.broadcasted_iota(jnp.int32, (A_max, A_max), 0)

    def slot_step(tt, carry):
        q_cnt, dropped, trunc = carry
        t = w * TW + tt

        # 1. departures
        dep = dep_ref[...]
        srv = srv_ref[...]
        leaving = dep == t
        freed = leaving.any(axis=1, keepdims=True)          # (L, 1)
        n_dep = leaving.sum()
        srv = jnp.where(leaving, 0.0, srv)
        srv_ref[...] = srv
        dep_ref[...] = jnp.where(leaving, INF_SLOT, dep)

        # 2. arrivals -> first empty queue slots (sequential masked insert:
        # identical landing positions to the engine's cumsum/searchsorted)
        n_t = n_ref[0, tt]
        queue = queue_ref[...]
        new_pos = jnp.full((1, A_max), -1, jnp.int32)
        for a in range(A_max):
            empty = queue == 0.0
            first = jnp.min(jnp.where(empty, q_iota, Qcap))
            valid = a < n_t
            land = valid & (first < Qcap)
            size_a = sizes_ref[0, tt, a]
            queue = jnp.where(land & (q_iota == first), size_a, queue)
            new_pos = jnp.where(land & (a_iota == a), first, new_pos)
            dropped = dropped + jnp.where(valid & ~land, 1, 0)
            q_cnt = q_cnt + jnp.where(land, 1, 0)
        queue_ref[...] = queue
        landed = new_pos >= 0                                # (1, A_max)
        n_landed = landed.sum()
        # landed arrival indices, compacted ascending, + their positions
        rank = jnp.cumsum(landed.astype(jnp.int32), axis=1) - 1
        comp = landed & (rank == r_iota)                     # (A, A)
        landed_list = jnp.min(jnp.where(comp, a_iota, A_max - 1),
                              axis=1)[None, :]               # (1, A_max)
        pos_list = jnp.max(jnp.where(comp, new_pos, -1), axis=1)[None, :]

        durs_t = durs_ref[0, tt][None, :]                    # (1, D)

        # 3+4. BF-S then BF-J as one bounded placement work list: each step
        # does the BF-S placement for the lowest-index freed server that
        # still has a fitting job, else attempts the next landed arrival.
        def work(_, wcarry):
            dc, a_ptr, n_placed = wcarry
            srv = srv_ref[...]
            queue = queue_ref[...]
            resid = 1.0 - jnp.sum(srv, axis=1, keepdims=True)  # (L, 1)
            occupied = queue > 0.0
            qmin = jnp.min(jnp.where(occupied, queue, BIG))
            fits = freed & (resid >= qmin) & (qmin < BIG)
            cur = jnp.min(jnp.where(fits, l_iota, L))
            any_bfs = cur < L

            # BF-S candidate: largest fitting job for server `cur`
            resid_cur = jnp.max(jnp.where(l_iota == cur, resid, -BIG))
            fitq = jnp.where(occupied & (queue <= resid_cur), queue, -BIG)
            size_bfs = jnp.max(fitq)
            j_bfs = jnp.min(jnp.where((fitq == size_bfs) & occupied,
                                      q_iota, Qcap))

            # BF-J candidate: next landed arrival, one attempt each
            is_bfj = (~any_bfs) & (a_ptr < n_landed)
            ap = jnp.minimum(a_ptr, A_max - 1)
            a = jnp.max(jnp.where(a_iota == ap, landed_list, -1))
            pos = jnp.max(jnp.where(a_iota == ap, pos_list, -1))
            size_bfj = jnp.max(jnp.where(q_iota == pos, queue, -BIG))
            size_bfj = jnp.where(pos >= 0, size_bfj, 0.0)
            feasible = (resid >= size_bfj) & (size_bfj > 0)
            best_r = jnp.min(jnp.where(feasible, resid, BIG))
            s_bfj = jnp.min(jnp.where(feasible & (resid == best_r),
                                      l_iota, L))
            ok_bfj = is_bfj & (s_bfj < L)

            do = any_bfs | ok_bfj
            tgt = jnp.where(any_bfs, cur, s_bfj)
            qidx = jnp.where(do, jnp.where(any_bfs, j_bfs,
                                           jnp.maximum(pos, 0)), Qcap)
            size = jnp.where(any_bfs, size_bfs, size_bfj)
            didx = jnp.where(any_bfs, jnp.minimum(dc, D - 1),
                             jnp.minimum(L * K + a, D - 1))
            dur = jnp.max(jnp.where(d_iota == didx, durs_t, -1))

            # first empty slot of the target server (slot 0 when full,
            # replicating the reference engine's argmax-of-all-False)
            row_m = l_iota == tgt
            slot = jnp.min(jnp.where(row_m & (srv == 0.0), k_iota, K))
            slot = jnp.where(slot == K, 0, slot)
            wmask = row_m & (k_iota == slot) & do
            srv_ref[...] = jnp.where(wmask, size, srv)
            dep_ref[...] = jnp.where(wmask, t + dur, dep_ref[...])
            queue_ref[...] = jnp.where(q_iota == qidx, 0.0, queue)
            return (dc + any_bfs.astype(jnp.int32),
                    a_ptr + is_bfj.astype(jnp.int32),
                    n_placed + do.astype(jnp.int32))

        _, a_ptr, n_placed = jax.lax.fori_loop(
            0, W, work, (jnp.int32(0), jnp.int32(0), jnp.int32(0)))
        q_cnt = q_cnt - n_placed

        # saturation check (same rule as the pure-JAX engine): a placement
        # the reference engine would still make => divergence this slot.
        srv = srv_ref[...]
        queue = queue_ref[...]
        resid = 1.0 - jnp.sum(srv, axis=1, keepdims=True)
        qmin = jnp.min(jnp.where(queue > 0.0, queue, BIG))
        pend_bfs = (freed & (resid >= qmin) & (qmin < BIG)).any()
        left = (a_iota >= a_ptr) & (a_iota < n_landed)
        sz_left = jnp.max(
            jnp.where(q_iota.T == pos_list, queue.T, -BIG), axis=0,
            keepdims=True)                                    # (1, A_max)
        pend_bfj = (left & (sz_left > 0)
                    & (sz_left <= jnp.max(resid))).any()
        trunc = trunc + (pend_bfs | pend_bfj).astype(jnp.int32)

        qlen_ref[0, tt] = q_cnt
        occ_ref[0, tt] = jnp.sum(srv)
        ndep_ref[0, tt] = n_dep.astype(jnp.int32)
        return q_cnt, dropped, trunc

    acc = acc_ref[...]
    q_cnt, dropped, trunc = jax.lax.fori_loop(
        0, TW, slot_step, (acc[0, 0], acc[0, 1], acc[0, 2]))
    acc_ref[...] = jnp.stack(
        [q_cnt, dropped, trunc, jnp.int32(0)])[None, :]
    dropped_ref[0, 0] = dropped
    trunc_ref[0, 0] = trunc


@functools.partial(
    jax.jit,
    static_argnames=("L", "K", "Qcap", "A_max", "work_steps", "window",
                     "interpret"))
def bfjs_pallas(n: jax.Array, sizes: jax.Array, durs: jax.Array,
                L: int, K: int, Qcap: int, A_max: int,
                work_steps: int, window: int | None = None,
                interpret: bool = False):
    """Run the fused BF-J/S slot engine on an ensemble of clusters.

    n (G, T) int32, sizes (G, T, A_max) f32, durs (G, T, L*K+A_max) int32 —
    one pre-generated stream set per ensemble member (jax_sched.make_streams
    vmapped over keys).  Returns per-slot (queue_len, occupancy, departures)
    of shape (G, T) plus (dropped, truncated) of shape (G,).

    ``window`` splits the horizon into VMEM-sized chunks: the grid is
    (G, T//window) and simulation state persists in scratch across a
    member's sequentially-executed windows.  Must divide T (default: whole
    horizon in one window).
    """
    G, T = n.shape
    TW, NW = resolve_windows(T, window)
    D = L * K + A_max
    kernel = functools.partial(
        _bfjs_kernel, L=L, K=K, Qcap=Qcap, A_max=A_max, W=work_steps, TW=TW)
    qlen, occ, ndep, dropped, trunc = pl.pallas_call(
        kernel,
        grid=(G, NW),
        out_shape=(jax.ShapeDtypeStruct((G, T), jnp.int32),
                   jax.ShapeDtypeStruct((G, T), jnp.float32),
                   jax.ShapeDtypeStruct((G, T), jnp.int32),
                   jax.ShapeDtypeStruct((G, 1), jnp.int32),
                   jax.ShapeDtypeStruct((G, 1), jnp.int32)),
        in_specs=[pl.BlockSpec((1, TW), lambda g, w: (g, w)),
                  pl.BlockSpec((1, TW, A_max), lambda g, w: (g, w, 0)),
                  pl.BlockSpec((1, TW, D), lambda g, w: (g, w, 0))],
        out_specs=(pl.BlockSpec((1, TW), lambda g, w: (g, w)),
                   pl.BlockSpec((1, TW), lambda g, w: (g, w)),
                   pl.BlockSpec((1, TW), lambda g, w: (g, w)),
                   pl.BlockSpec((1, 1), lambda g, w: (g, 0)),
                   pl.BlockSpec((1, 1), lambda g, w: (g, 0))),
        scratch_shapes=[pltpu.VMEM((L, K), jnp.float32),
                        pltpu.VMEM((L, K), jnp.int32),
                        pltpu.VMEM((1, Qcap), jnp.float32),
                        pltpu.VMEM((1, 4), jnp.int32)],
        interpret=interpret,
    )(n, sizes, durs)
    return qlen, occ, ndep, dropped[:, 0], trunc[:, 0]
