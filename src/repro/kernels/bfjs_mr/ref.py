"""Pure-jnp oracle for the fused multi-resource BF-J/S slot-step kernel.

The oracle IS the production scan engine (engine.bfjs_mr.run_bfjs_mr_streams)
vmapped over the ensemble dimension — the kernel must reproduce its
trajectories exactly (and that engine is itself bit-parity-tested against
the event-driven ``MultiResourceBFJS`` numpy oracle)."""
from __future__ import annotations

import jax

from repro.core.engine.bfjs_mr import run_bfjs_mr_streams
from repro.core.engine.streams import PolicyResult, SchedStreams


def bfjs_mr_ref(n, sizes, durs, L: int, K: int, Qcap: int, A_max: int,
                work_steps: int | None = None,
                capacity: tuple[float, ...] = (1.0,)) -> PolicyResult:
    """n (G, T) int32, sizes (G, T, A_max, R) f32, durs (G, T, D) int32 ->
    PolicyResult with (G, ...)-shaped fields."""

    def one(n1, s1, d1):
        return run_bfjs_mr_streams(SchedStreams(n1, s1, d1), L=L, K=K,
                                   Qcap=Qcap, A_max=A_max,
                                   work_steps=work_steps, capacity=capacity)

    return jax.vmap(one)(n, sizes, durs)
