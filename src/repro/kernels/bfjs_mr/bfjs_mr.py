"""Pallas TPU kernel: fused multi-resource BF-J/S slot-step engine
(DESIGN.md §8).

One program instance simulates one independent cluster of the Monte-Carlo
ensemble: the grid is ``(G, NW)`` — ensemble member x time window — and the
whole mutable simulation state (the ``(L, K, R)`` per-slot demand vectors,
departure slots, the ``(Qcap, R)`` queued-demand buffer with its
duration/seq metadata and the running counters) lives in VMEM scratch that
persists across the sequentially-executed time windows of a member.  Every
slot step (departures -> enqueue -> BF-S refill -> alignment BF-J) runs
inside the kernel with no HBM round-trips; only the pre-generated
randomness streams are streamed in per window and only the per-slot
outputs (queue length, per-resource occupancy, departures) stream out.

The placement logic transcribes the bounded early-exit work list of
``repro.core.engine.bfjs_mr.run_bfjs_mr_streams`` with broadcasted-iota
masks and reductions in place of every dynamic index, and the resource
axis STATICALLY UNROLLED: vector state is stored as R stacked 2D planes
(demands ``(L, R*K)`` — plane r in columns ``[r*K, (r+1)*K)`` — and queue
demands ``(R, Qcap)``), so every per-resource feasibility comparison is a
plain 2D vector op.  The Tetris alignment score is exact integer
arithmetic compared as a normalized int32 ``(hi, lo)`` pair — the same
scheme as ``engine.ops.alignment_score_pair_jnp`` — so argmin tie-breaks
bit-match the scan engine (and, through it, the event-driven
``MultiResourceBFJS`` oracle) on every backend and lowering.  Trajectories
are bit-compatible with the scan engine whenever ``truncated`` stays 0 —
asserted by the interpret-mode parity + hypothesis suites in
tests/test_mr_kernel.py and tests/test_engine_parity_matrix.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quantize import RES
from repro.kernels.common import resolve_windows

INF_SLOT = jnp.iinfo(jnp.int32).max
INT32_MAX = jnp.iinfo(jnp.int32).max


def _bfjs_mr_kernel(n_ref, sizes_ref, durs_ref,
                    qlen_ref, occ_out_ref, ndep_ref, dropped_ref, trunc_ref,
                    dem_ref, dep_ref, occ_ref, qdem_ref, qmeta_ref, acc_ref,
                    *, L, K, R, Qcap, A_max, W, TW, CAP, D, EARLY_EXIT):
    w = pl.program_id(1)

    @pl.when(w == 0)
    def _init():
        dem_ref[...] = jnp.zeros((L, R * K), jnp.int32)
        dep_ref[...] = jnp.full((L, K), INF_SLOT, jnp.int32)
        occ_ref[...] = jnp.zeros((L, R), jnp.int32)
        qdem_ref[...] = jnp.zeros((R, Qcap), jnp.int32)
        meta = jnp.ones((2, Qcap), jnp.int32)       # row 0: qdur (init 1)
        qmeta_ref[...] = meta.at[1].set(-1)         # row 1: qseq (init -1)
        acc_ref[...] = jnp.zeros((1, 4), jnp.int32)

    l_col = jax.lax.broadcasted_iota(jnp.int32, (L, 1), 0)
    k_row = jax.lax.broadcasted_iota(jnp.int32, (1, K), 1)
    q_row = jax.lax.broadcasted_iota(jnp.int32, (1, Qcap), 1)
    a_row = jax.lax.broadcasted_iota(jnp.int32, (1, A_max), 1)
    aa = jax.lax.broadcasted_iota(jnp.int32, (A_max, A_max), 0)
    aq = jax.lax.broadcasted_iota(jnp.int32, (A_max, Qcap), 1)

    def slot_step(tt, carry):
        q_cnt, seq0, dropped, trunc = carry
        t = w * TW + tt

        # 1. departures free their demand vectors
        dep = dep_ref[...]
        dem = dem_ref[...]
        occ = occ_ref[...]
        leaving = dep == t                                   # (L, K)
        freed = leaving.any(axis=1, keepdims=True)           # (L, 1)
        n_dep = leaving.sum()
        occ = occ - jnp.concatenate(
            [jnp.sum(jnp.where(leaving, dem[:, r * K:(r + 1) * K], 0),
                     axis=1, keepdims=True) for r in range(R)], axis=1)
        dem = jnp.where(jnp.concatenate([leaving] * R, axis=1), 0, dem)
        dem_ref[...] = dem
        occ_ref[...] = occ
        dep_ref[...] = jnp.where(leaving, INF_SLOT, dep)

        # 2. arrivals -> first empty queue positions (sequential masked
        # insert: identical landing positions to the engine's
        # cumsum/searchsorted; arrival a gets seq id seq0 + a)
        n_t = n_ref[0, tt]
        qdem = qdem_ref[...]
        qmeta = qmeta_ref[...]
        qdur, qseq = qmeta[0:1], qmeta[1:2]                  # (1, Qcap)
        new_pos = jnp.full((1, A_max), -1, jnp.int32)
        for a in range(A_max):
            empty = qseq < 0
            first = jnp.min(jnp.where(empty, q_row, Qcap))
            valid = a < n_t
            land = valid & (first < Qcap)
            wm = land & (q_row == first)                     # (1, Qcap)
            qdem = jnp.concatenate(
                [jnp.where(wm, jnp.maximum(
                    jnp.round(sizes_ref[0, tt, a * R + r] * RES),
                    1.0).astype(jnp.int32), qdem[r:r + 1])
                 for r in range(R)], axis=0)
            qdur = jnp.where(wm, durs_ref[0, tt, D - A_max + a], qdur)
            qseq = jnp.where(wm, seq0 + a, qseq)
            new_pos = jnp.where(land & (a_row == a), first, new_pos)
            dropped = dropped + jnp.where(valid & ~land, 1, 0)
            q_cnt = q_cnt + jnp.where(land, 1, 0)
        seq0 = seq0 + n_t
        qdem_ref[...] = qdem
        qmeta_ref[...] = jnp.concatenate([qdur, qseq], axis=0)
        landed = new_pos >= 0                                # (1, A_max)
        n_landed = landed.sum()
        # landed arrival indices, compacted ascending, + their positions
        rank = jnp.cumsum(landed.astype(jnp.int32), axis=1) - 1
        comp = landed & (rank == aa)                         # (A, A)
        pos_list = jnp.max(jnp.where(comp, new_pos, -1),
                           axis=1)[None, :]                  # (1, A_max)

        # 3+4. BF-S then BF-J as one bounded placement work list: each step
        # does the BF-S placement for the lowest-index freed, unblocked
        # server that still has a fitting queued job (job = largest total
        # demand, earliest seq), else attempts the next landed arrival on
        # the min-alignment feasible server.
        def work(wcarry):
            step, a_ptr, blocked, q_cnt, trunc, _ = wcarry
            dem = dem_ref[...]
            dep = dep_ref[...]
            occ = occ_ref[...]
            qdem = qdem_ref[...]
            qmeta = qmeta_ref[...]
            qdur, qseq = qmeta[0:1], qmeta[1:2]
            avail = [CAP[r] - occ[:, r:r + 1] for r in range(R)]  # (L, 1)

            # BF-S candidate
            fits = (freed & ~blocked) & (qseq >= 0)          # (L, Qcap)
            for r in range(R):
                fits = fits & (qdem[r:r + 1] <= avail[r])
            has_fit = fits.any(axis=1, keepdims=True)
            cur = jnp.min(jnp.where(has_fit, l_col, L))
            any_bfs = cur < L
            fit_cur = ((l_col == cur) & fits).any(axis=0,
                                                  keepdims=True)  # (1, Qcap)
            tot = jnp.zeros((1, Qcap), jnp.int32)
            for r in range(R):
                tot = tot + qdem[r:r + 1]
            best_tot = jnp.max(jnp.where(fit_cur, tot, -1))
            cand = fit_cur & (tot == best_tot)
            best_seq = jnp.min(jnp.where(cand, qseq, INT32_MAX))
            j_bfs = jnp.min(jnp.where(cand & (qseq == best_seq), q_row,
                                      Qcap))
            j_bfs = jnp.minimum(j_bfs, Qcap - 1)

            # BF-J candidate: next landed arrival still in the queue, on
            # the min-alignment feasible server (any server, not just
            # freed — the oracle's _best_server scans all L).
            is_bfj = (~any_bfs) & (a_ptr < n_landed)
            # The scan engine's early-exit rule: with no BF-S fit left and
            # every landed arrival consumed, no later step can do work
            # (queues only shrink, avail only shrinks, freed&~blocked only
            # shrinks), so remaining steps are no-ops.
            done = (~any_bfs) & (a_ptr >= n_landed)
            ap = jnp.minimum(a_ptr, A_max - 1)
            pos = jnp.max(jnp.where(a_row == ap, pos_list, -1))
            posc = jnp.maximum(pos, 0)
            seq_pos = jnp.sum(jnp.where(q_row == posc, qseq, 0))
            present = is_bfj & (pos >= 0) & (seq_pos >= 0)
            d_bfj = [jnp.sum(jnp.where(q_row == posc, qdem[r:r + 1], 0))
                     for r in range(R)]
            feas = jnp.ones((L, 1), bool)
            for r in range(R):
                feas = feas & (d_bfj[r] <= avail[r])
            # exact alignment score as a normalized int32 (hi, lo) pair —
            # same scheme as engine.ops.alignment_score_pair_jnp, so the
            # lexicographic argmin equals the oracle's exact float64
            # argmin on every backend and lowering
            s_hi = avail[0] * (d_bfj[0] >> 8)
            s_lo = avail[0] * (d_bfj[0] & 255)
            for r in range(1, R):
                s_hi = s_hi + avail[r] * (d_bfj[r] >> 8)
                s_lo = s_lo + avail[r] * (d_bfj[r] & 255)
            s_hi = s_hi + (s_lo >> 8)
            s_lo = s_lo & 255
            best_hi = jnp.min(jnp.where(feas, s_hi, INT32_MAX))
            cand_j = feas & (s_hi == best_hi)
            best_lo = jnp.min(jnp.where(cand_j, s_lo, INT32_MAX))
            s_bfj = jnp.min(jnp.where(cand_j & (s_lo == best_lo), l_col,
                                      L))
            s_bfj = jnp.minimum(s_bfj, L - 1)
            ok_bfj = present & feas.any()

            do = any_bfs | ok_bfj
            tgt = jnp.where(any_bfs, jnp.minimum(cur, L - 1), s_bfj)
            qidx = jnp.where(any_bfs, j_bfs, posc)
            d_place = [jnp.sum(jnp.where(q_row == qidx, qdem[r:r + 1], 0))
                       for r in range(R)]
            dur = jnp.sum(jnp.where(q_row == qidx, qdur, 0))

            # first empty slot of the target server
            dep_row = jnp.sum(jnp.where(l_col == tgt, dep, 0),
                              axis=0, keepdims=True)         # (1, K)
            slot = jnp.min(jnp.where(dep_row == INF_SLOT, k_row, K))
            ok_slot = slot < K
            place = do & ok_slot
            wm = (l_col == tgt) & (k_row == jnp.minimum(slot, K - 1)) \
                & place                                      # (L, K)
            dem_ref[...] = jnp.concatenate(
                [jnp.where(wm, d_place[r], dem[:, r * K:(r + 1) * K])
                 for r in range(R)], axis=1)
            dep_ref[...] = jnp.where(wm, t + dur, dep)
            add_vec = jnp.concatenate(
                [d.reshape(1, 1) for d in d_place], axis=1)  # (1, R)
            occ_ref[...] = occ + jnp.where((l_col == tgt) & place,
                                           add_vec, 0)
            clr = (q_row == qidx) & place
            qdem_ref[...] = jnp.concatenate(
                [jnp.where(clr, 0, qdem[r:r + 1]) for r in range(R)],
                axis=0)
            qmeta_ref[...] = jnp.concatenate(
                [qdur, jnp.where(clr, -1, qseq)], axis=0)
            q_cnt = q_cnt - place.astype(jnp.int32)
            # K-full server: the oracle would place; count, don't spin.
            trunc = trunc + (do & ~ok_slot).astype(jnp.int32)
            blocked = blocked | (any_bfs & ~ok_slot)
            a_ptr = a_ptr + is_bfj.astype(jnp.int32)
            return step + 1, a_ptr, blocked, q_cnt, trunc, done

        winit = (jnp.int32(0), jnp.int32(0), jnp.zeros((L, 1), bool),
                 q_cnt, trunc, jnp.bool_(False))
        if EARLY_EXIT:
            # Same body, but stop as soon as a step reports done — the
            # scan engine exits here too, and post-done steps are no-ops,
            # so the trajectory is bit-identical by construction.
            _, a_ptr, blocked, q_cnt, trunc, _ = jax.lax.while_loop(
                lambda c: (c[0] < W) & jnp.logical_not(c[-1]), work, winit)
        else:
            _, a_ptr, blocked, q_cnt, trunc, _ = jax.lax.fori_loop(
                0, W, lambda _, c: work(c), winit)

        # saturation check (same rule as the scan engine): work the oracle
        # would still do => the bounded list diverged this slot.
        occ = occ_ref[...]
        qdem = qdem_ref[...]
        qseq = qmeta_ref[...][1:2]
        avail = [CAP[r] - occ[:, r:r + 1] for r in range(R)]
        fits = (freed & ~blocked) & (qseq >= 0)
        for r in range(R):
            fits = fits & (qdem[r:r + 1] <= avail[r])
        pend_bfs = fits.any()
        left = (a_row >= a_ptr) & (a_row < n_landed)
        gmask = aq == jnp.maximum(pos_list, 0).T             # (A_max, Qcap)
        seq_at = jnp.sum(jnp.where(gmask, qseq, 0), axis=1)[None, :]
        present_l = left & (pos_list >= 0) & (seq_at >= 0)
        feas_l = jnp.ones((A_max, L), bool)
        for r in range(R):
            d_l = jnp.sum(jnp.where(gmask, qdem[r:r + 1], 0),
                          axis=1)[:, None]                   # (A_max, 1)
            feas_l = feas_l & (d_l <= avail[r].T)
        pend_bfj = (present_l & feas_l.any(axis=1)[None, :]).any()
        trunc = trunc + (pend_bfs | pend_bfj).astype(jnp.int32)

        qlen_ref[0, tt] = q_cnt
        occ_out_ref[0, tt] = occ_ref[...].sum(axis=0).astype(
            jnp.float32) / RES
        ndep_ref[0, tt] = n_dep.astype(jnp.int32)
        return q_cnt, seq0, dropped, trunc

    acc = acc_ref[...]
    q_cnt, seq0, dropped, trunc = jax.lax.fori_loop(
        0, TW, slot_step, (acc[0, 0], acc[0, 1], acc[0, 2], acc[0, 3]))
    acc_ref[...] = jnp.stack([q_cnt, seq0, dropped, trunc])[None, :]
    dropped_ref[0, 0] = dropped
    trunc_ref[0, 0] = trunc


@functools.partial(
    jax.jit,
    static_argnames=("L", "K", "Qcap", "A_max", "work_steps", "capacity",
                     "window", "interpret", "early_exit"))
def bfjs_mr_pallas(n: jax.Array, sizes: jax.Array, durs: jax.Array,
                   L: int, K: int, Qcap: int, A_max: int,
                   work_steps: int, capacity: tuple[float, ...],
                   window: int | None = None, interpret: bool = False,
                   early_exit: bool = True):
    """Run the fused multi-resource BF-J/S slot engine on an ensemble.

    n (G, T) int32, sizes (G, T, A_max, R) f32, durs (G, T, D) int32 with
    the per-arrival durations in the last A_max lanes (D = A_max for
    streams_from_trace, D = L*K+A_max for make_streams) — one pre-generated
    stream set per ensemble member.  ``capacity`` is the per-resource
    server capacity tuple (length R).  Returns per-slot (queue_len (G, T),
    occupancy (G, T, R), departures (G, T)) plus (dropped, truncated) of
    shape (G,).

    ``window`` splits the horizon into VMEM-sized chunks: the grid is
    (G, T//window) and simulation state persists in scratch across a
    member's sequentially-executed windows.  Must divide T (default: whole
    horizon in one window).
    """
    G, T, A_sz, R = sizes.shape
    if A_sz != A_max:
        raise ValueError(f"sizes carry A_max={A_sz}, expected {A_max}")
    if len(capacity) != R:
        raise ValueError(
            f"capacity has {len(capacity)} entries for R={R} resources")
    TW, NW = resolve_windows(T, window)
    D = durs.shape[-1]
    CAP = tuple(round(c * RES) for c in capacity)
    kernel = functools.partial(
        _bfjs_mr_kernel, L=L, K=K, R=R, Qcap=Qcap, A_max=A_max,
        W=work_steps, TW=TW, CAP=CAP, D=D, EARLY_EXIT=early_exit)
    qlen, occ, ndep, dropped, trunc = pl.pallas_call(
        kernel,
        grid=(G, NW),
        out_shape=(jax.ShapeDtypeStruct((G, T), jnp.int32),
                   jax.ShapeDtypeStruct((G, T, R), jnp.float32),
                   jax.ShapeDtypeStruct((G, T), jnp.int32),
                   jax.ShapeDtypeStruct((G, 1), jnp.int32),
                   jax.ShapeDtypeStruct((G, 1), jnp.int32)),
        in_specs=[pl.BlockSpec((1, TW), lambda g, w: (g, w)),
                  pl.BlockSpec((1, TW, A_max * R), lambda g, w: (g, w, 0)),
                  pl.BlockSpec((1, TW, D), lambda g, w: (g, w, 0))],
        out_specs=(pl.BlockSpec((1, TW), lambda g, w: (g, w)),
                   pl.BlockSpec((1, TW, R), lambda g, w: (g, w, 0)),
                   pl.BlockSpec((1, TW), lambda g, w: (g, w)),
                   pl.BlockSpec((1, 1), lambda g, w: (g, 0)),
                   pl.BlockSpec((1, 1), lambda g, w: (g, 0))),
        scratch_shapes=[pltpu.VMEM((L, R * K), jnp.int32),
                        pltpu.VMEM((L, K), jnp.int32),
                        pltpu.VMEM((L, R), jnp.int32),
                        pltpu.VMEM((R, Qcap), jnp.int32),
                        pltpu.VMEM((2, Qcap), jnp.int32),
                        pltpu.VMEM((1, 4), jnp.int32)],
        interpret=interpret,
    )(n, sizes.reshape(G, T, A_max * R), durs)
    return qlen, occ, ndep, dropped[:, 0], trunc[:, 0]
