"""Public entry point: Pallas on TPU, interpret-mode elsewhere."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.engine.bfjs_mr import _norm_capacity
from repro.core.engine.streams import PolicyResult, SchedStreams, \
    resolve_work_steps
from repro.kernels.common import interpret_default

from .bfjs_mr import bfjs_mr_pallas
from .ref import bfjs_mr_ref


def bfjs_mr_scratch_bytes(L: int, K: int, Qcap: int, R: int) -> int:
    """Estimated per-core VMEM scratch of the fused multi-resource BF-J/S
    kernel (the DESIGN.md §8 budget formula): demand (L,R·K), dep (L,K),
    occupancy (L,R), queue demand (R,Qcap), queue meta (2,Qcap) and the
    (1,4) scalar block — all int32.  Checked against
    ``kernels.common.vmem_budget_bytes`` by the engine dispatch before
    launching (DESIGN.md §8/§9)."""
    return 4 * (2 * L * K * R + L * K + L * R + 3 * Qcap + 4)


def _lift_batched_sizes(streams: SchedStreams) -> SchedStreams:
    """The kernel consumes (G, T, A_max, R) sizes; lift squeezed R=1
    ensemble streams (same contract as engine.bfjs_mr._lift_sizes)."""
    if streams.sizes.ndim == streams.durs.ndim:
        return streams._replace(sizes=streams.sizes[..., None])
    return streams


def bfjs_mr_simulate(streams: SchedStreams, L: int, K: int, Qcap: int,
                     A_max: int, work_steps: int | None = None,
                     capacity: tuple[float, ...] | float = 1.0,
                     window: int | None = None,
                     use_pallas: bool = True,
                     early_exit: bool = True) -> PolicyResult:
    """Fused-kernel Monte-Carlo multi-resource BF-J/S: one grid cell per
    ensemble member.

    streams holds (G, ...)-shaped pre-generated randomness
    (engine.streams.make_streams vmapped over the ensemble keys, or a
    trace-built stream batched with a leading axis).  ``early_exit=False``
    forces the kernel's placement work list to run its full
    ``work_steps`` bound every slot (the pre-optimization behaviour, kept
    for benchmarking the early-exit win — trajectories are identical)."""
    streams = _lift_batched_sizes(streams)
    R = int(streams.sizes.shape[-1])
    capacity = _norm_capacity(capacity, R)
    work_steps = resolve_work_steps(work_steps, A_max)
    if not use_pallas:
        return bfjs_mr_ref(streams.n, streams.sizes, streams.durs, L=L,
                           K=K, Qcap=Qcap, A_max=A_max,
                           work_steps=work_steps, capacity=capacity)
    qlen, occ, ndep, dropped, trunc = bfjs_mr_pallas(
        streams.n, streams.sizes, streams.durs, L=L, K=K, Qcap=Qcap,
        A_max=A_max, work_steps=work_steps, capacity=capacity,
        window=window, interpret=interpret_default(),
        early_exit=early_exit)
    z = jnp.zeros_like(dropped)  # kernels simulate fault-free clusters
    return PolicyResult(qlen, occ, jnp.cumsum(ndep, axis=1), dropped, trunc,
                        z, z, z)
