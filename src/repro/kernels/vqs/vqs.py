"""Pallas TPU kernel: fused VQS slot-step engine (DESIGN.md §6).

One program instance simulates one independent cluster of the Monte-Carlo
ensemble: the grid is ``(G, NW)`` — ensemble member x time window — and the
whole mutable simulation state (per-slot job sizes / departure slots / VQ
types, the 2J virtual-queue rings, per-server active configurations, the
``_empty`` membership and the subscription matrix) lives in VMEM scratch
that persists across the sequentially-executed time windows of a member.

Every slot step (departures -> classify + ring-push arrivals -> visit-set ->
bounded serve work list) runs inside the kernel with no HBM round-trips;
only the pre-generated randomness streams are streamed in per window and
only the per-slot outputs (queue length, occupancy, departures) stream out.

The serve pass is the branch-free work list of
``repro.core.engine.vqs.run_vqs_streams`` (advance past non-placing visited
servers under the shared max-weight renewal, then prefix-fit-pack the first
placer) transcribed with broadcasted-iota masks and reductions in place of
every dynamic index, unrolled to the fixed ``work_steps + 1`` bound (the
kernel pays the bound; the host scan engine early-exits — same trajectory).
Trajectories are bit-compatible with the scan engine (and, through it, with
the event-driven numpy engine on trace streams) whenever ``truncated`` stays
0 — asserted by the interpret-mode parity tests in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quantize import RES, TWO_THIRDS
from repro.kernels.common import resolve_windows

INF_SLOT = jnp.iinfo(jnp.int32).max
CAP = RES
RESERVE = TWO_THIRDS


def _vqs_kernel(n_ref, sizes_ref, durs_ref, confs_ref,
                qlen_ref, occ_ref, ndep_ref, dropped_ref, trunc_ref,
                srv_ref, dep_ref, vqof_ref, reff_ref, rdur_ref,
                hq_ref, cfg_ref, want_ref, acc_ref,
                *, J, L, K, Qcap, A_max, W, P, TW):
    w = pl.program_id(1)
    nvq = 2 * J
    C = confs_ref.shape[0]

    @pl.when(w == 0)
    def _init():
        srv_ref[...] = jnp.zeros((L, K), jnp.int32)
        dep_ref[...] = jnp.full((L, K), INF_SLOT, jnp.int32)
        vqof_ref[...] = jnp.full((L, K), -1, jnp.int32)
        reff_ref[...] = jnp.zeros((nvq, Qcap), jnp.int32)
        rdur_ref[...] = jnp.ones((nvq, Qcap), jnp.int32)
        hq_ref[...] = jnp.zeros((2, nvq), jnp.int32)
        cfg = jnp.zeros((4, L), jnp.int32)
        cfg = cfg.at[1].set(-1)      # cfg_js = -1 (no active configuration)
        cfg = cfg.at[3].set(1)       # in_empty: all servers start empty
        cfg_ref[...] = cfg
        want_ref[...] = jnp.zeros((L, nvq), jnp.int32)
        acc_ref[...] = jnp.zeros((1, 2), jnp.int32)

    l_col = jax.lax.broadcasted_iota(jnp.int32, (L, 1), 0)
    j_row = jax.lax.broadcasted_iota(jnp.int32, (1, nvq), 1)
    q_jq = jax.lax.broadcasted_iota(jnp.int32, (nvq, Qcap), 1)
    j_jq = jax.lax.broadcasted_iota(jnp.int32, (nvq, Qcap), 0)
    p_row = jax.lax.broadcasted_iota(jnp.int32, (1, P), 1)
    q_pq = jax.lax.broadcasted_iota(jnp.int32, (P, Qcap), 1)
    c_col = jax.lax.broadcasted_iota(jnp.int32, (C, 1), 0)
    c_flat = jax.lax.broadcasted_iota(jnp.int32, (C, nvq), 0)
    confs = confs_ref[...]

    def slot_step(tt, carry):
        dropped, trunc = carry
        t = w * TW + tt

        # 1. departures
        dep = dep_ref[...]
        srv = srv_ref[...]
        vqof = vqof_ref[...]
        leaving = dep == t
        freed = leaving.any(axis=1, keepdims=True)            # (L, 1)
        n_dep = leaving.sum()
        srv = jnp.where(leaving, 0, srv)
        vqof = jnp.where(leaving, -1, vqof)
        srv_ref[...] = srv
        vqof_ref[...] = vqof
        dep_ref[...] = jnp.where(leaving, INF_SLOT, dep)
        empty_now = (srv > 0).sum(axis=1, keepdims=True) == 0  # (L, 1)

        # 2. arrivals: classify on the integer grid, push to ring tails
        n_t = n_ref[0, tt]
        hq = hq_ref[...]
        head, qcnt = hq[0:1], hq[1:2]                          # (1, nvq)
        reff = reff_ref[...]
        rdur = rdur_ref[...]
        arrived = jnp.zeros((1, nvq), bool)
        for a in range(A_max):
            valid = a < n_t
            g = jnp.maximum(jnp.round(sizes_ref[0, tt, a] * RES),
                            1.0).astype(jnp.int32)
            m_h = jnp.int32(0)
            for kk in range(1, J + 1):
                m_h = m_h + (g <= (RES >> kk)).astype(jnp.int32)
            m_h = jnp.minimum(m_h, J - 1)
            upper = jnp.right_shift(jnp.int32(RES), m_h)
            vq_a = jnp.where(3 * g > 2 * upper, 2 * m_h, 2 * m_h + 1)
            vq_a = jnp.where(g <= (RES >> J), nvq - 1, vq_a)
            eff_a = jnp.where(vq_a == nvq - 1, jnp.maximum(g, RES >> J), g)
            oh = j_row == vq_a                                 # (1, nvq)
            cnt_a = jnp.sum(jnp.where(oh, qcnt, 0))
            head_a = jnp.sum(jnp.where(oh, head, 0))
            land = valid & (cnt_a < Qcap)
            pos = jnp.remainder(head_a + cnt_a, Qcap)
            wm = (j_jq == vq_a) & (q_jq == pos) & land         # (nvq, Qcap)
            reff = jnp.where(wm, eff_a, reff)
            rdur = jnp.where(wm, durs_ref[0, tt, durs_ref.shape[-1]
                                          - A_max + a], rdur)
            qcnt = qcnt + jnp.where(oh & land, 1, 0)
            dropped = dropped + jnp.where(valid & ~land, 1, 0)
            arrived = arrived | (oh & valid)
        reff_ref[...] = reff
        rdur_ref[...] = rdur
        hq_ref[...] = jnp.concatenate([head, qcnt], axis=0)

        # 3. visit set
        want = want_ref[...] != 0                              # (L, nvq)
        woken = (want & arrived).any(axis=1, keepdims=True)
        want_ref[...] = (want & ~arrived).astype(jnp.int32)
        cfgm = cfg_ref[...]
        has_cfg0 = (cfgm[2:3] != 0).T                          # (L, 1)
        in_empty0 = (cfgm[3:4] != 0).T
        visit = freed | woken | (in_empty0 & (qcnt.sum() > 0))
        renew_needed = visit & (empty_now | ~has_cfg0)

        # 4. work list: W placement steps + 1 drain pass (fixed unroll —
        # each iteration is the scan engine's masked-select step verbatim)
        def work(_, wcarry):
            touched, advanced, trunc = wcarry
            hq = hq_ref[...]
            head, qcnt = hq[0:1], hq[1:2]
            reff = reff_ref[...]
            rdur = rdur_ref[...]
            srv = srv_ref[...]
            vqof = vqof_ref[...]
            cfgm = cfg_ref[...]
            cfg_k1 = (cfgm[0:1] != 0).T                        # (L, 1)
            cfg_js = cfgm[1:2].T
            has_cfg = (cfgm[2:3] != 0).T
            in_empty = (cfgm[3:4] != 0).T
            want = want_ref[...] != 0

            pending = visit & ~advanced
            hx = qcnt > 0
            hmask = q_jq == jnp.remainder(head, Qcap).T        # (nvq, Qcap)
            head_effs = jnp.sum(jnp.where(hmask, reff, 0), axis=1)[None, :]

            # shared max-weight renewal candidate (first-index argmax)
            w_c = jnp.sum(confs * qcnt, axis=1)                # (C,)
            ci = jnp.min(jnp.where(w_c == w_c.max(),
                                   c_flat[:, 0], C))
            row = jnp.sum(jnp.where(c_col == ci, confs, 0),
                          axis=0)[None, :]                     # (1, nvq)
            r_k1 = jnp.sum(jnp.where(j_row == 1, row, 0)) > 0
            r_js = jnp.min(jnp.where((row > 0) & (j_row != 1), j_row, nvq))
            r_js = jnp.where(r_js == nvq, -1, r_js)
            ren = renew_needed & ~touched
            eff_k1 = jnp.where(ren, r_k1, cfg_k1)
            eff_js = jnp.where(ren, r_js, cfg_js)              # (L, 1)

            occ = srv.sum(axis=1, keepdims=True)
            is1 = (vqof == 1) & (srv > 0)
            vq1_occ = jnp.where(is1, srv, 0).sum(axis=1, keepdims=True)
            has_vq1 = is1.any(axis=1, keepdims=True)
            resid = CAP - occ
            other_occ = occ - vq1_occ
            other_cap = jnp.where(eff_k1, CAP - RESERVE, CAP)
            ex1 = (hx & (j_row == 1)).any()
            he1 = jnp.sum(jnp.where(j_row == 1, head_effs, 0))
            k1_can = eff_k1 & ~has_vq1 & ex1 & (he1 <= resid)
            js_oh = eff_js == j_row                            # (L, nvq)
            js_head = jnp.sum(jnp.where(js_oh, head_effs, 0),
                              axis=1, keepdims=True)
            js_ex = (js_oh & hx).any(axis=1, keepdims=True)
            js_can = (eff_js >= 0) & js_ex \
                & (other_occ + js_head <= other_cap)
            would = pending & (k1_can | js_can)

            placer = jnp.min(jnp.where(would, l_col, L))
            tch = pending & (l_col <= placer)
            adv = pending & (l_col < placer)
            do_ren = tch & ren
            new_k1 = jnp.where(do_ren, r_k1, cfg_k1)
            new_js = jnp.where(do_ren, r_js, cfg_js)
            new_has = has_cfg | tch
            # first touch only — see engine/vqs.py (stale empty_now mask)
            new_empty = in_empty | (tch & ~touched & empty_now)
            touched = touched | tch
            advanced = advanced | adv

            sub1 = adv & eff_k1 & ~has_vq1 & ~ex1
            subj = adv & (eff_js >= 0) & ~js_ex
            want = want | (sub1 & (j_row == 1)) | (subj & js_oh)
            want_ref[...] = want.astype(jnp.int32)

            # serve the placer: 1 reserved VQ_1 job or a prefix-fit batch
            any_p = placer < L
            rowmask = l_col == placer                          # (L, 1)
            do_k1 = (rowmask & k1_can).any()
            js_s = jnp.max(jnp.where(rowmask, eff_js, -1))
            j_sel = jnp.where(do_k1, 1, jnp.maximum(js_s, 0))
            head_sel = jnp.sum(jnp.where(j_row == j_sel, head, 0))
            qcnt_sel = jnp.sum(jnp.where(j_row == j_sel, qcnt, 0))
            rrow_e = jnp.sum(jnp.where(j_jq == j_sel, reff, 0),
                             axis=0)[None, :]                  # (1, Qcap)
            rrow_d = jnp.sum(jnp.where(j_jq == j_sel, rdur, 0),
                             axis=0)[None, :]
            wsel = q_pq == jnp.remainder(head_sel + p_row, Qcap).T  # (P, Qcap)
            effs_w = jnp.sum(jnp.where(wsel, rrow_e, 0), axis=1)[None, :]
            durs_w = jnp.sum(jnp.where(wsel, rrow_d, 0), axis=1)[None, :]
            in_q = p_row < qcnt_sel
            budget = jnp.max(jnp.where(rowmask, other_cap - other_occ, -1))
            fit = in_q & (jnp.cumsum(effs_w, axis=1) <= budget)
            m = jnp.where(do_k1, 1, fit.sum())
            m = jnp.where(any_p, m, 0)

            row_srv = jnp.sum(jnp.where(rowmask, srv, 0),
                              axis=0)[None, :]                 # (1, K)
            es = row_srv == 0
            free_cnt = es.sum()
            slotrank = jnp.cumsum(es.astype(jnp.int32), axis=1) - 1
            sel = es.T & (slotrank.T == p_row) & (p_row < m)   # (K, P)
            val_k = jnp.sum(jnp.where(sel, effs_w, 0), axis=1)[None, :]
            dur_k = jnp.sum(jnp.where(sel, durs_w, 0), axis=1)[None, :]
            placed_k = sel.any(axis=1)[None, :]                # (1, K)
            lk = rowmask & placed_k                            # (L, K)
            srv_ref[...] = jnp.where(lk, val_k, srv)
            dep_ref[...] = jnp.where(lk, t + dur_k, dep_ref[...])
            vqof_ref[...] = jnp.where(lk, j_sel, vqof)
            dm = jnp.where((j_row == j_sel) & any_p, m, 0)
            hq_ref[...] = jnp.concatenate([head + dm, qcnt - dm], axis=0)
            new_empty = new_empty & ~(rowmask & (m > 0))
            cfg_ref[...] = jnp.concatenate(
                [new_k1.astype(jnp.int32).T, new_js.T,
                 new_has.astype(jnp.int32).T, new_empty.astype(jnp.int32).T],
                axis=0)
            trunc = trunc + jnp.maximum(m - free_cnt, 0)       # K-overflow
            return touched, advanced, trunc

        false_col = jnp.zeros((L, 1), bool)
        _, advanced, trunc = jax.lax.fori_loop(
            0, W + 1, work, (false_col, false_col, trunc))
        # bound hit with servers still unserved: slot finished lazily
        trunc = trunc + (visit & ~advanced).any().astype(jnp.int32)

        qcnt = hq_ref[1:2, :]
        qlen_ref[0, tt] = qcnt.sum()
        occ_ref[0, tt] = srv_ref[...].sum().astype(jnp.float32) / RES
        ndep_ref[0, tt] = n_dep.astype(jnp.int32)
        return dropped, trunc

    acc = acc_ref[...]
    dropped, trunc = jax.lax.fori_loop(
        0, TW, slot_step, (acc[0, 0], acc[0, 1]))
    acc_ref[...] = jnp.stack([dropped, trunc])[None, :]
    dropped_ref[0, 0] = dropped
    trunc_ref[0, 0] = trunc


@functools.partial(
    jax.jit,
    static_argnames=("J", "L", "K", "Qcap", "A_max", "work_steps", "drain",
                     "window", "interpret"))
def vqs_pallas(n: jax.Array, sizes: jax.Array, durs: jax.Array,
               J: int, L: int, K: int, Qcap: int, A_max: int,
               work_steps: int, drain: int, window: int | None = None,
               interpret: bool = False):
    """Run the fused VQS slot engine on an ensemble of clusters.

    n (G, T) int32, sizes (G, T, A_max) f32, durs (G, T, D) int32 with the
    per-arrival durations in the last A_max lanes (D = L*K+A_max for
    make_streams, D = A_max for streams_from_trace) — one pre-generated
    stream set per ensemble member.  Returns per-slot (queue_len,
    occupancy, departures) of shape (G, T) plus (dropped, truncated) of
    shape (G,).

    ``window`` splits the horizon into VMEM-sized chunks: the grid is
    (G, T//window) and simulation state persists in scratch across a
    member's sequentially-executed windows.  Must divide T (default: whole
    horizon in one window).
    """
    from repro.core.engine.ops import k_red_jnp

    G, T = n.shape
    TW, NW = resolve_windows(T, window)
    D = durs.shape[-1]
    confs = k_red_jnp(J)
    C = confs.shape[0]
    nvq = 2 * J
    kernel = functools.partial(
        _vqs_kernel, J=J, L=L, K=K, Qcap=Qcap, A_max=A_max,
        W=work_steps, P=drain, TW=TW)
    qlen, occ, ndep, dropped, trunc = pl.pallas_call(
        kernel,
        grid=(G, NW),
        out_shape=(jax.ShapeDtypeStruct((G, T), jnp.int32),
                   jax.ShapeDtypeStruct((G, T), jnp.float32),
                   jax.ShapeDtypeStruct((G, T), jnp.int32),
                   jax.ShapeDtypeStruct((G, 1), jnp.int32),
                   jax.ShapeDtypeStruct((G, 1), jnp.int32)),
        in_specs=[pl.BlockSpec((1, TW), lambda g, w: (g, w)),
                  pl.BlockSpec((1, TW, A_max), lambda g, w: (g, w, 0)),
                  pl.BlockSpec((1, TW, D), lambda g, w: (g, w, 0)),
                  pl.BlockSpec((C, nvq), lambda g, w: (0, 0))],
        out_specs=(pl.BlockSpec((1, TW), lambda g, w: (g, w)),
                   pl.BlockSpec((1, TW), lambda g, w: (g, w)),
                   pl.BlockSpec((1, TW), lambda g, w: (g, w)),
                   pl.BlockSpec((1, 1), lambda g, w: (g, 0)),
                   pl.BlockSpec((1, 1), lambda g, w: (g, 0))),
        scratch_shapes=[pltpu.VMEM((L, K), jnp.int32),
                        pltpu.VMEM((L, K), jnp.int32),
                        pltpu.VMEM((L, K), jnp.int32),
                        pltpu.VMEM((nvq, Qcap), jnp.int32),
                        pltpu.VMEM((nvq, Qcap), jnp.int32),
                        pltpu.VMEM((2, nvq), jnp.int32),
                        pltpu.VMEM((4, L), jnp.int32),
                        pltpu.VMEM((L, nvq), jnp.int32),
                        pltpu.VMEM((1, 2), jnp.int32)],
        interpret=interpret,
    )(n, sizes, durs, confs)
    return qlen, occ, ndep, dropped[:, 0], trunc[:, 0]
