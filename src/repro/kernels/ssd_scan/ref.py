"""Oracle: naive sequential state-space recurrence (no chunking).

h_t = exp(a_t) * h_{t-1} + B_t (dt*x)_t^T ;  y_t = C_t h_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(xdt, Bm, Cm, a):
    """xdt: (B,H,nc,Lc,hd); Bm/Cm: (B,H,nc,Lc,N); a: (B,H,nc,Lc)."""
    B, H, nc, Lc, hd = xdt.shape
    N = Bm.shape[-1]
    S = nc * Lc
    x = xdt.reshape(B, H, S, hd).astype(jnp.float32)
    Bf = Bm.reshape(B, H, S, N).astype(jnp.float32)
    Cf = Cm.reshape(B, H, S, N).astype(jnp.float32)
    af = a.reshape(B, H, S).astype(jnp.float32)

    def step(h, inp):
        x_t, b_t, c_t, a_t = inp
        h = h * jnp.exp(a_t)[..., None, None] + jnp.einsum(
            "bhp,bhn->bhpn", x_t, b_t)
        y_t = jnp.einsum("bhpn,bhn->bhp", h, c_t)
        return h, y_t

    h0 = jnp.zeros((B, H, hd, N), jnp.float32)
    xs = (x.transpose(2, 0, 1, 3), Bf.transpose(2, 0, 1, 3),
          Cf.transpose(2, 0, 1, 3), af.transpose(2, 0, 1))
    _, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 2, 0, 3).reshape(B, H, nc, Lc, hd)
    return y.astype(xdt.dtype)
