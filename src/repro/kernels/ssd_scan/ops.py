"""Entry point: Pallas on TPU, interpret-mode validation elsewhere."""
from __future__ import annotations

import jax

from .ref import ssd_ref
from .ssd_scan import ssd_scan


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def ssd(xdt, Bm, Cm, a, use_pallas: bool = True):
    if use_pallas:
        return ssd_scan(xdt, Bm, Cm, a, interpret=_interpret())
    return ssd_ref(xdt, Bm, Cm, a)
