"""Pallas TPU kernel: Mamba2 SSD chunk scan (state-space duality core).

Grid (B, nh, nc) with the chunk axis innermost: the inter-chunk state
(hd, N) lives in VMEM scratch and is carried across sequential chunk steps,
so the recurrence never leaves the chip.  Within a chunk the dual quadratic
form runs on the MXU: (Lc x N)@(N x Lc) score-like matrix, masked by the
cumulative-decay lower triangle, then (Lc x Lc)@(Lc x hd).

Inputs are pre-chunked per head: xdt (B,nh,nc,Lc,hd) = dt*x, B/C
(B,nh,nc,Lc,N) broadcast to heads, a (B,nh,nc,Lc) = dt*A.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xdt_ref, b_ref, c_ref, a_ref, y_ref, state_ref, *,
                Lc: int, hd: int, N: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = xdt_ref[0, 0, 0].astype(jnp.float32)               # (Lc, hd)
    Bm = b_ref[0, 0, 0].astype(jnp.float32)                # (Lc, N)
    Cm = c_ref[0, 0, 0].astype(jnp.float32)                # (Lc, N)
    a = a_ref[0, 0, 0].astype(jnp.float32)[None, :]        # (1, Lc) row

    cs = jnp.cumsum(a, axis=-1)                            # (1, Lc)
    # pairwise decay L[i, j] = exp(cs_i - cs_j) for i >= j
    di = jnp.transpose(cs)                                  # (Lc, 1)
    seg = di - cs                                           # (Lc, Lc): cs_i - cs_j
    tri = jax.lax.broadcasted_iota(jnp.int32, (Lc, Lc), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Lc, Lc), 1)
    Lmat = jnp.where(tri, jnp.exp(seg), 0.0)

    # intra-chunk: y_diag = ((C @ B^T) * L) @ x
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y_diag = jax.lax.dot_general(cb * Lmat, x, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    # inter-chunk: y_off = (C @ S^T) * exp(cs)^T     with S: (hd, N)
    S = state_ref[...]
    y_off = jax.lax.dot_general(Cm, S, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_off = y_off * jnp.transpose(jnp.exp(cs))             # (Lc, hd)

    y_ref[0, 0, 0] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: S' = S * exp(cs_last) + x^T @ (B * exp(cs_last - cs)^T)
    last = cs[0, Lc - 1]
    decay_state = jnp.exp(last - jnp.transpose(cs))        # (Lc, 1)
    new_contrib = jax.lax.dot_general(
        x, Bm * decay_state, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                # (hd, N)
    state_ref[...] = S * jnp.exp(last) + new_contrib


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_scan(xdt: jax.Array, Bm: jax.Array, Cm: jax.Array, a: jax.Array,
             interpret: bool = False) -> jax.Array:
    """xdt: (B,H,nc,Lc,hd); Bm/Cm: (B,H,nc,Lc,N); a: (B,H,nc,Lc).
    Returns y: (B,H,nc,Lc,hd) (no D-skip / gating — those stay in jnp)."""
    B, H, nc, Lc, hd = xdt.shape
    N = Bm.shape[-1]
    kernel = functools.partial(_ssd_kernel, Lc=Lc, hd=hd, N=N)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Lc, hd), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Lc, N), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Lc, N), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Lc), lambda b, h, c: (b, h, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, Lc, hd),
                               lambda b, h, c: (b, h, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nc, Lc, hd), xdt.dtype),
        scratch_shapes=[pltpu.VMEM((hd, N), jnp.float32)],
        interpret=interpret,
    )(xdt, Bm, Cm, a)
