"""Shared plumbing for the per-policy scheduler kernels.

Every scheduler kernel family (``kernels/bfjs``, ``kernels/vqs``, ...)
follows the same layout — ``<policy>.py`` holds the fused Pallas kernel,
``ref.py`` the pure-jnp oracle (the production scan engine vmapped over the
ensemble), ``ops.py`` the public entry point that dispatches Pallas on TPU
and interpret mode elsewhere.  The pieces they share live here.
"""
from __future__ import annotations

import jax

#: f32 infeasibility sentinel used by the float kernels (~f32 max).
BIG = 3.4e38


def interpret_default() -> bool:
    """Pallas interpret mode everywhere but real TPUs (correctness-grade)."""
    return jax.default_backend() != "tpu"
