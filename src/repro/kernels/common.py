"""Shared plumbing for the per-policy scheduler kernels.

Every scheduler kernel family (``kernels/bfjs``, ``kernels/vqs``, ...)
follows the same layout — ``<policy>.py`` holds the fused Pallas kernel,
``ref.py`` the pure-jnp oracle (the production scan engine vmapped over the
ensemble), ``ops.py`` the public entry point that dispatches Pallas on TPU
and interpret mode elsewhere.  The pieces they share live here.
"""
from __future__ import annotations

import jax

#: f32 infeasibility sentinel used by the float kernels (~f32 max).
BIG = 3.4e38


def interpret_default() -> bool:
    """Pallas interpret mode everywhere but real TPUs (correctness-grade)."""
    return jax.default_backend() != "tpu"


def resolve_windows(T: int, window: int | None) -> tuple[int, int]:
    """Split a horizon into equal VMEM-sized time windows.

    Every fused slot-step kernel runs on a ``(G, NW)`` grid — ensemble
    member x time window — with simulation state persisting in VMEM scratch
    across a member's sequentially-executed windows.  Returns ``(TW, NW)``
    (window length, window count); ``window=None`` means the whole horizon
    in one window, and a window that does not divide the horizon is an
    error (a ragged tail would replay slots twice)."""
    TW = T if window is None else window
    if T % TW:
        raise ValueError(f"window {TW} must divide horizon {T}")
    return TW, T // TW
