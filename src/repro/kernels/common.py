"""Shared plumbing for the per-policy scheduler kernels.

Every scheduler kernel family (``kernels/bfjs``, ``kernels/vqs``, ...)
follows the same layout — ``<policy>.py`` holds the fused Pallas kernel,
``ref.py`` the pure-jnp oracle (the production scan engine vmapped over the
ensemble), ``ops.py`` the public entry point that dispatches Pallas on TPU
and interpret mode elsewhere.  The pieces they share live here.
"""
from __future__ import annotations

import os
import warnings

import jax

#: f32 infeasibility sentinel used by the float kernels (~f32 max).
BIG = 3.4e38

#: Default VMEM scratch budget for the fused kernels (bytes).  TPU cores
#: have ~16 MiB of VMEM; the default leaves headroom for the per-window
#: stream blocks and compiler spills.  Override with the
#: REPRO_VMEM_BUDGET_BYTES environment variable (read at call time, so
#: tests can monkeypatch the environment).
VMEM_BUDGET_BYTES = 14 * 1024 * 1024

#: Default per-device HBM budget for the ensemble-resident planes of a
#: Monte-Carlo kernel launch (pre-generated streams in + per-slot
#: trajectories out, all scaled by the ensemble dimension G).  Unlike the
#: VMEM scratch — which is per grid cell and independent of G — this
#: footprint grows with the ensemble, and SHARDING divides it: a mesh over
#: D devices holds G/D members per device.  Override with the
#: REPRO_HBM_BUDGET_BYTES environment variable (read at call time).
HBM_BUDGET_BYTES = 16 * 1024 ** 3


class GracefulDegradationWarning(UserWarning):
    """A ``engine="pallas"`` request was served by the scan engine instead.

    Raised as a *warning* (never silently) when the fused kernel cannot run
    the request — VMEM scratch estimate over budget, or a feature the kernel
    does not implement (fault planes).  The scan engine is bit-identical, so
    results are unaffected; pass ``strict=True`` to get a hard error
    instead."""


def vmem_budget_bytes() -> int:
    """The enforced VMEM scratch budget (env-overridable, read per call)."""
    return int(os.environ.get("REPRO_VMEM_BUDGET_BYTES", VMEM_BUDGET_BYTES))


def hbm_budget_bytes() -> int:
    """The enforced per-device ensemble-plane budget (env-overridable)."""
    return int(os.environ.get("REPRO_HBM_BUDGET_BYTES", HBM_BUDGET_BYTES))


def pallas_precheck(kernel: str, *, nbytes: int, hbm_bytes: int = 0,
                    num_devices: int = 1, fault_plane: bool = False,
                    streaming_carry: bool = False,
                    strict: bool = False) -> bool:
    """Gate an ``engine="pallas"`` dispatch (DESIGN.md §8/§9/§11).

    Returns True when the fused kernel may run.  On a violation — estimated
    VMEM scratch ``nbytes`` over :func:`vmem_budget_bytes`, the PER-DEVICE
    share of the ensemble planes ``hbm_bytes / num_devices`` over
    :func:`hbm_budget_bytes`, a fault-plane request (the kernels simulate
    fault-free clusters only), or a streaming-carry request (the kernels'
    state lives in VMEM scratch for the launch only and cannot be threaded
    across chunks of a stream) — either raises ``ValueError``
    (``strict=True``) or emits a loud :class:`GracefulDegradationWarning`
    and returns False so the caller falls back to the bit-identical scan
    engine.  Never fail silently.

    ``hbm_bytes`` is the GLOBAL ensemble footprint (streams in + per-slot
    trajectories out, all carrying the full G axis) and ``num_devices`` the
    mesh size it is sharded over, so a request that overflows one device
    can still dispatch when the ensemble spans a mesh — the sharded path
    is checked per device, never against global G."""
    budget = vmem_budget_bytes()
    reason = None
    per_device = -(-hbm_bytes // max(num_devices, 1))
    if streaming_carry:
        reason = (f"kernel {kernel!r} keeps its simulation state in VMEM "
                  "scratch and cannot export/import the cross-chunk carry "
                  "a streaming run threads between chunks")
    elif fault_plane:
        reason = (f"kernel {kernel!r} does not implement fault-plane "
                  "preemption")
    elif nbytes > budget:
        reason = (f"kernel {kernel!r} needs ~{nbytes} bytes of VMEM "
                  f"scratch, over the {budget}-byte budget "
                  "(REPRO_VMEM_BUDGET_BYTES)")
    elif per_device > hbm_budget_bytes():
        reason = (f"kernel {kernel!r} needs ~{per_device} bytes of "
                  f"ensemble streams/trajectories per device "
                  f"({hbm_bytes} over {num_devices} device(s)), over the "
                  f"{hbm_budget_bytes()}-byte budget "
                  "(REPRO_HBM_BUDGET_BYTES); shard the ensemble over more "
                  "devices (mesh=/devices=) or shrink G")
    if reason is None:
        return True
    if strict:
        raise ValueError(
            f"{reason}; engine=\"pallas\" cannot honour this request "
            "(strict=True — rerun with engine=\"scan\" or strict=False)")
    warnings.warn(f"{reason}; falling back to the bit-identical scan "
                  "engine", GracefulDegradationWarning, stacklevel=3)
    return False


def interpret_default() -> bool:
    """Pallas interpret mode everywhere but real TPUs (correctness-grade)."""
    return jax.default_backend() != "tpu"


def ensemble_plane_bytes(G: int, T: int, *, stream_lanes: int,
                         out_lanes: int) -> int:
    """Global HBM footprint of one Monte-Carlo kernel launch: the (G, T,
    lanes) pre-generated stream planes in plus the (G, T, lanes) per-slot
    trajectory planes out (all 4-byte dtypes), plus the per-member scalar
    counters.  Divided by the mesh size in :func:`pallas_precheck` — the
    per-DEVICE share is what gets gated, so sharding the ensemble grows
    the feasible G envelope instead of tripping a global-G check."""
    return 4 * G * (T * (stream_lanes + out_lanes) + 2)


def resolve_windows(T: int, window: int | None) -> tuple[int, int]:
    """Split a horizon into equal VMEM-sized time windows.

    Every fused slot-step kernel runs on a ``(G, NW)`` grid — ensemble
    member x time window — with simulation state persisting in VMEM scratch
    across a member's sequentially-executed windows.  Returns ``(TW, NW)``
    (window length, window count); ``window=None`` means the whole horizon
    in one window, and a window that does not divide the horizon is an
    error (a ragged tail would replay slots twice)."""
    TW = T if window is None else window
    if T % TW:
        raise ValueError(f"window {TW} must divide horizon {T}")
    return TW, T // TW
