"""Shared plumbing for the per-policy scheduler kernels.

Every scheduler kernel family (``kernels/bfjs``, ``kernels/vqs``, ...)
follows the same layout — ``<policy>.py`` holds the fused Pallas kernel,
``ref.py`` the pure-jnp oracle (the production scan engine vmapped over the
ensemble), ``ops.py`` the public entry point that dispatches Pallas on TPU
and interpret mode elsewhere.  The pieces they share live here.
"""
from __future__ import annotations

import os
import warnings

import jax

#: f32 infeasibility sentinel used by the float kernels (~f32 max).
BIG = 3.4e38

#: Default VMEM scratch budget for the fused kernels (bytes).  TPU cores
#: have ~16 MiB of VMEM; the default leaves headroom for the per-window
#: stream blocks and compiler spills.  Override with the
#: REPRO_VMEM_BUDGET_BYTES environment variable (read at call time, so
#: tests can monkeypatch the environment).
VMEM_BUDGET_BYTES = 14 * 1024 * 1024


class GracefulDegradationWarning(UserWarning):
    """A ``engine="pallas"`` request was served by the scan engine instead.

    Raised as a *warning* (never silently) when the fused kernel cannot run
    the request — VMEM scratch estimate over budget, or a feature the kernel
    does not implement (fault planes).  The scan engine is bit-identical, so
    results are unaffected; pass ``strict=True`` to get a hard error
    instead."""


def vmem_budget_bytes() -> int:
    """The enforced VMEM scratch budget (env-overridable, read per call)."""
    return int(os.environ.get("REPRO_VMEM_BUDGET_BYTES", VMEM_BUDGET_BYTES))


def pallas_precheck(kernel: str, *, nbytes: int, fault_plane: bool = False,
                    strict: bool = False) -> bool:
    """Gate an ``engine="pallas"`` dispatch (DESIGN.md §8/§9 enforcement).

    Returns True when the fused kernel may run.  On a violation — estimated
    VMEM scratch ``nbytes`` over :func:`vmem_budget_bytes`, or a fault-plane
    request (the kernels simulate fault-free clusters only) — either raises
    ``ValueError`` (``strict=True``) or emits a loud
    :class:`GracefulDegradationWarning` and returns False so the caller
    falls back to the bit-identical scan engine.  Never fail silently."""
    budget = vmem_budget_bytes()
    reason = None
    if fault_plane:
        reason = (f"kernel {kernel!r} does not implement fault-plane "
                  "preemption")
    elif nbytes > budget:
        reason = (f"kernel {kernel!r} needs ~{nbytes} bytes of VMEM "
                  f"scratch, over the {budget}-byte budget "
                  "(REPRO_VMEM_BUDGET_BYTES)")
    if reason is None:
        return True
    if strict:
        raise ValueError(
            f"{reason}; engine=\"pallas\" cannot honour this request "
            "(strict=True — rerun with engine=\"scan\" or strict=False)")
    warnings.warn(f"{reason}; falling back to the bit-identical scan "
                  "engine", GracefulDegradationWarning, stacklevel=3)
    return False


def interpret_default() -> bool:
    """Pallas interpret mode everywhere but real TPUs (correctness-grade)."""
    return jax.default_backend() != "tpu"


def resolve_windows(T: int, window: int | None) -> tuple[int, int]:
    """Split a horizon into equal VMEM-sized time windows.

    Every fused slot-step kernel runs on a ``(G, NW)`` grid — ensemble
    member x time window — with simulation state persisting in VMEM scratch
    across a member's sequentially-executed windows.  Returns ``(TW, NW)``
    (window length, window count); ``window=None`` means the whole horizon
    in one window, and a window that does not divide the horizon is an
    error (a ragged tail would replay slots twice)."""
    TW = T if window is None else window
    if T % TW:
        raise ValueError(f"window {TW} must divide horizon {T}")
    return TW, T // TW
