"""Roofline analysis over the dry-run artifacts (§Roofline of the brief).

For each (arch x shape x mesh) JSON produced by repro.launch.dryrun:
    compute term    = HLO_FLOPs_per_chip / 197e12
    memory term     = HLO_bytes_per_chip / 819e9
    collective term = collective_bytes_per_chip / 50e9
(cost_analysis reports the per-partition SPMD program, i.e. per-chip.)

Also: MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) for train cells,
2*N_active*tokens for decode/prefill forward-only cells, the useful-compute
ratio MODEL_FLOPS / (chips * HLO_FLOPs), the dominant term, and a one-line
"what would move it" note.
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config  # noqa: E402
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16  # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def model_flops(arch: str, kind: str, seq_len: int, batch: int) -> float:
    cfg = get_config(arch)
    counts = cfg.param_counts()
    n_active = counts["active"]
    if kind == "train":
        return 6.0 * n_active * seq_len * batch
    if kind == "prefill":
        return 2.0 * n_active * seq_len * batch
    # decode: one token per sequence + attention cache read-derived flops
    flops = 2.0 * n_active * batch
    # attention over the cache: 2 * 2 * H*hd * S per attn layer per seq
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    n_attn = sum(1 for i in range(cfg.num_layers)
                 if cfg.mixer_kind(i) in ("attn", "mla"))
    eff_s = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    flops += 4.0 * n_attn * H * hd * eff_s * batch
    return flops


def dominant_note(which: str, rec: dict) -> str:
    src = rec.get("cost_exact") or rec
    ag = src["collectives"]["bytes_by_op"].get("all-gather", 0)
    notes = {
        "compute": "compute-bound: better MXU utilization (larger fused "
                   "matmuls, bf16 accum) or fewer remat recomputes",
        "memory": "HBM-bound: cut activation traffic (fused kernels, "
                  "smaller remat policy, bf16 master weights)",
        "collective": f"ICI-bound (all-gather {ag/1e9:.1f} GB): coarser FSDP "
                      "axis / overlap collectives with compute / 8-bit "
                      "gradient compression",
    }
    return notes[which]


def analyze(path: str) -> dict | None:
    with open(path) as f:
        rec = json.load(f)
    # prefer the trip-count-exact cost model (see launch/dryrun.py); the
    # production compile prices while-loop bodies once.
    exact = rec.get("cost_exact")
    if exact and "flops" in exact.get("cost_analysis", {}):
        ca = exact["cost_analysis"]
        coll_rec = exact["collectives"]
        coll = coll_rec["total_bytes"]      # already per-chip (SPMD program)
    else:
        ca = rec.get("cost_analysis", {})
        coll_rec = rec["collectives"]
        coll = coll_rec["total_bytes"]
    flops = ca.get("flops", 0.0)
    bytes_acc = ca.get("bytes accessed", 0.0)
    if not flops:
        return None
    chips = rec["chips"]
    t_c = flops / PEAK_FLOPS_BF16
    t_m = bytes_acc / HBM_BW
    t_x = coll / ICI_BW
    which = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
                key=lambda kv: kv[1])[0]
    mf = model_flops(rec["arch"], rec["kind"], rec["seq_len"],
                     rec["global_batch"])
    useful = mf / (chips * flops) if flops else 0.0
    bound = max(t_c, t_m, t_x)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": which,
        "model_flops": mf,
        "useful_ratio": useful,
        "roofline_frac": (mf / PEAK_FLOPS_BF16 / chips) / bound if bound else 0,
        "note": dominant_note(which, rec),
        "collective_count": coll_rec["total_count"],
    }


def run(out_csv: str | None = None, mesh_filter: str = "pod") -> list[dict]:
    rows = []
    if not os.path.isdir(RESULTS):
        print("no dry-run results; run python -m repro.launch.dryrun --all")
        return rows
    for name in sorted(os.listdir(RESULTS)):
        if not name.endswith(".json"):
            continue
        if mesh_filter and not name.endswith(f"__{mesh_filter}.json"):
            continue
        row = analyze(os.path.join(RESULTS, name))
        if row:
            rows.append(row)
    if out_csv:
        import csv
        with open(out_csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
    return rows


def format_table(rows: list[dict]) -> str:
    hdr = (f"{'arch':<24}{'shape':<13}{'compute_s':>10}{'memory_s':>10}"
           f"{'coll_s':>9}{'dom':>6}{'useful':>8}{'roof%':>7}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:<24}{r['shape']:<13}{r['compute_s']:>10.4f}"
            f"{r['memory_s']:>10.4f}{r['collective_s']:>9.4f}"
            f"{r['dominant'][:4]:>6}{r['useful_ratio']:>8.2f}"
            f"{100*r['roofline_frac']:>6.1f}%")
    return "\n".join(lines)


def main():
    out = os.path.join(os.path.dirname(__file__), "results", "roofline.csv")
    rows = run(out)
    print(format_table(rows))
    print(f"\n{len(rows)} cells -> {out}")


if __name__ == "__main__":
    main()
