"""Paper Figure 5: Google-trace experiment.

1000 servers, ~10^6 tasks over ~1.5 days (paper setup; CI default runs a
100k-task / 250-server slice — REPRO_BENCH_FULL=1 for the full scale).
Jobs sized as max(cpu, mem) per the paper's preprocessing; traffic scaling
1/beta in {1.0, 1.3, 1.6}.  Reproduced claim: BF-J/S and VQS-BF clearly beat
FIFO-FF as scaling grows, VQS-BF edging out BF-J/S at the highest load.
"""
from __future__ import annotations

from common import FULL, row, timed

from repro.core import (BFJS, FIFOFF, VQSBF, collapse_resources,
                        empirical_size_stats, scale_arrivals,
                        simulate_trace, synthesize_google_like_trace)


def main():
    # L is calibrated so offered load ~0.8 at scaling 1.0 (the real trace's
    # long task durations set this; the synthetic trace uses mean_duration
    # to hit the same operating point): offered work/slot =
    # (n/horizon) * E[size] * E[dur] ~= 0.77 * 0.136 * E[dur].
    if FULL:
        n_tasks, horizon, L, dur = 1_000_000, 1_300_000, 640, 6000.0
    else:
        n_tasks, horizon, L, dur = 100_000, 130_000, 64, 600.0
    trace = synthesize_google_like_trace(n_tasks, horizon, seed=4,
                                         mean_duration=dur)
    sizes = collapse_resources(trace)
    stats = empirical_size_stats(sizes)
    row("fig5/trace", 0.0,
        f"tasks={len(trace)};distinct={stats['distinct_values']};"
        f"mean={stats['mean']:.3f}")

    for scaling in (1.0, 1.3, 1.6):
        scaled = scale_arrivals(trace, scaling)
        for name, mk in (("bf-js", BFJS), ("vqs-bf", lambda: VQSBF(J=7)),
                         ("fifo-ff", FIFOFF)):
            res, us = timed(
                simulate_trace, mk(), L=L,
                arrival_slots=scaled.arrival_slots, sizes=sizes,
                durations=scaled.durations,
                horizon=int(horizon / scaling) + 1000, seed=1)
            row(f"fig5/x{scaling}/{name}", us / max(res.horizon, 1),
                f"mean_Q={res.mean_queue:.1f};util={res.utilization:.3f}")


if __name__ == "__main__":
    main()
