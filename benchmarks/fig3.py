"""Paper Figure 3: the two instability examples.

3a: 1 server, sizes {0.4, 0.6} equal prob, Poisson 0.014, geometric mean 100
    -> VQS unstable (rate > (2/3)*0.02), BF-J/S & VQS-BF stable.
3b: capacity 10, sizes {2, 5} probs (2/3, 1/3), rate 0.0306, FIXED 100
    -> VQS stable, BF-J/S & VQS-BF drift (mixed-packing lock-in).

Derived value: tail-queue ratio unstable/stable (>> 1 reproduces the figure).
"""
from __future__ import annotations

from common import FULL, row, timed

from repro.core import (BFJS, Discrete, ServiceModel, VQS, VQSBF, simulate)


def fig3a(horizon=None):
    horizon = horizon or (1_000_000 if FULL else 200_000)
    dist = Discrete([0.4, 0.6], [0.5, 0.5])
    svc = ServiceModel("geometric", 100.0)
    out = {}
    for name, mk in (("bf-js", BFJS), ("vqs", lambda: VQS(J=2)),
                     ("vqs-bf", lambda: VQSBF(J=2))):
        res, us = timed(simulate, mk(), L=1, lam=0.014, dist=dist,
                        service=svc, horizon=horizon, seed=11)
        out[name] = res
        row(f"fig3a/{name}", us / horizon,
            f"tail_Q={res.mean_queue_tail:.1f}")
    ratio = out["vqs"].mean_queue_tail / max(out["bf-js"].mean_queue_tail, 1e-9)
    row("fig3a/instability_ratio", 0.0, f"vqs_over_bfjs={ratio:.1f}")
    return out


def fig3b(horizon=None):
    horizon = horizon or (2_000_000 if FULL else 400_000)
    dist = Discrete([0.2, 0.5], [2 / 3, 1 / 3])
    svc = ServiceModel("fixed", 100.0)
    out = {}
    for name, mk in (("bf-js", BFJS), ("vqs", lambda: VQS(J=3)),
                     ("vqs-bf", lambda: VQSBF(J=3))):
        res, us = timed(simulate, mk(), L=1, lam=0.0306, dist=dist,
                        service=svc, horizon=horizon, seed=7)
        out[name] = res
        row(f"fig3b/{name}", us / horizon,
            f"tail_Q={res.mean_queue_tail:.1f}")
    ratio = out["bf-js"].mean_queue_tail / max(out["vqs"].mean_queue_tail, 1e-9)
    row("fig3b/instability_ratio", 0.0, f"bfjs_over_vqs={ratio:.1f}")
    return out


def main():
    fig3a()
    fig3b()


if __name__ == "__main__":
    main()
