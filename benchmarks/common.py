"""Shared benchmark plumbing: CSV rows `name,us_per_call,derived` plus a
machine-readable record registry dumped to BENCH_sched.json by run.py."""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"
SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"

#: every row() call lands here as {"name", "us", "meta"}; run.py (or any
#: caller) serializes it with write_json() so perf is tracked across PRs.
RECORDS: list[dict] = []


def row(name: str, us_per_call: float, derived: str = "") -> str:
    line = f"{name},{us_per_call:.2f},{derived}"
    print(line, flush=True)
    RECORDS.append({"name": name, "us": round(us_per_call, 3),
                    "meta": derived})
    return line


def timed(fn, *args, repeat: int = 1, **kw):
    t0 = time.time()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.time() - t0) / repeat
    return out, dt * 1e6


def timed_best(fn, *args, repeat: int = 5, **kw):
    """Best-of-N wall clock in microseconds (noise-robust micro timing).
    The first (compile) call is excluded from the measurement."""
    fn(*args, **kw)
    best = float("inf")
    out = None
    for _ in range(1 if SMOKE else repeat):
        t0 = time.time()
        out = fn(*args, **kw)
        best = min(best, time.time() - t0)
    return out, best * 1e6


def timed_interleaved(variants: dict, rounds: int = 7) -> dict:
    """Round-robin best-of-N over named thunks, in microseconds.

    Engine comparisons must be timed INTERLEAVED so machine-load drift hits
    every variant equally — on shared hosts the wall clock of a single
    variant can swing +-50% between back-to-back runs, which would make a
    sequential comparison meaningless.  Each thunk runs once for warm-up /
    compile (excluded), then ``rounds`` timed passes (2 under SMOKE)."""
    for fn in variants.values():
        fn()
    best = {name: float("inf") for name in variants}
    for _ in range(2 if SMOKE else rounds):
        for name, fn in variants.items():
            t0 = time.time()
            fn()
            best[name] = min(best[name], time.time() - t0)
    return {name: b * 1e6 for name, b in best.items()}


def write_json(path: str) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump({"schema": "bench.v1", "benchmarks": RECORDS}, f, indent=1)
        f.write("\n")
    print(f"wrote {len(RECORDS)} records -> {path}", flush=True)
    return path
