"""Shared benchmark plumbing: CSV rows `name,us_per_call,derived`."""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"


def row(name: str, us_per_call: float, derived: str = "") -> str:
    line = f"{name},{us_per_call:.2f},{derived}"
    print(line, flush=True)
    return line


def timed(fn, *args, repeat: int = 1, **kw):
    t0 = time.time()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.time() - t0) / repeat
    return out, dt * 1e6
