"""Paper Figure 4: mean queue size vs traffic intensity, uniform job sizes.

4a: U[0.01, 0.19] (mean 0.1);  4b: U[0.1, 0.9] (mean 0.5); L = 5 servers,
geometric service mean 100, lambda = alpha * L * mu / mean(R).
Reproduced claims: VQS worst everywhere; BF-J/S and VQS-BF comparable, with
BF-J/S ahead at the highest intensities in 4b.
"""
from __future__ import annotations

from common import FULL, row, timed

from repro.core import BFJS, FIFOFF, ServiceModel, Uniform, VQS, VQSBF, simulate

ALPHAS = (0.85, 0.9, 0.95, 0.99) if not FULL else \
    (0.85, 0.87, 0.89, 0.91, 0.93, 0.95, 0.97, 0.99)


def run_panel(tag: str, dist: Uniform, J: int, horizon=None):
    horizon = horizon or (500_000 if FULL else 120_000)
    L, mu = 5, 0.01
    svc = ServiceModel("geometric", 1 / mu)
    out = {}
    for alpha in ALPHAS:
        lam = alpha * L * mu / dist.mean()
        for name, mk in (("bf-js", BFJS), ("vqs", lambda: VQS(J=J)),
                         ("vqs-bf", lambda: VQSBF(J=J)),
                         ("fifo-ff", FIFOFF)):
            res, us = timed(simulate, mk(), L=L, lam=lam, dist=dist,
                            service=svc, horizon=horizon, seed=5)
            out[(alpha, name)] = res
            row(f"{tag}/a{alpha}/{name}", us / horizon,
                f"mean_Q={res.mean_queue:.1f}")
    return out


def main():
    run_panel("fig4a", Uniform(0.01, 0.19), J=7)
    run_panel("fig4b", Uniform(0.1, 0.9), J=4)


if __name__ == "__main__":
    main()
