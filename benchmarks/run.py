# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows and dumps every row to a machine-readable BENCH_sched.json so the
# perf trajectory is tracked across PRs.
#
#   REPRO_BENCH_FULL=1   paper-scale horizons (Fig 5: 10^6 tasks on 1000
#                        servers); default is a CI-sized slice.
#   REPRO_BENCH_SMOKE=1  tiny shapes everywhere (CI smoke).
#   REPRO_BENCH_ONLY=a,b run only the named modules
#                        (fig3,fig4,fig5,stability_bench,sched_micro,roofline)
#   REPRO_BENCH_JSON=p   where to write the JSON (default: repo-root
#                        BENCH_sched.json)
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _selected(name: str) -> bool:
    only = os.environ.get("REPRO_BENCH_ONLY", "")
    return not only or name in only.split(",")


def main() -> None:
    print("name,us_per_call,derived")
    if _selected("fig3"):
        import fig3
        fig3.main()
    if _selected("fig4"):
        import fig4
        fig4.main()
    if _selected("fig5"):
        import fig5
        fig5.main()
    if _selected("stability_bench"):
        import stability_bench
        stability_bench.main()
    if _selected("sched_micro"):
        import sched_micro
        sched_micro.main()
    if _selected("roofline"):
        # roofline table from the dry-run artifacts (if generated)
        import roofline
        rows = roofline.run(os.path.join(os.path.dirname(__file__), "results",
                                         "roofline.csv"))
        for r in rows:
            from common import row
            row(f"roofline/{r['arch']}/{r['shape']}", 0.0,
                f"dom={r['dominant']};useful={r['useful_ratio']:.2f};"
                f"roof={100 * r['roofline_frac']:.1f}%")

    from common import write_json
    json_path = os.environ.get("REPRO_BENCH_JSON")
    if json_path is None and os.environ.get("REPRO_BENCH_ONLY"):
        # a subset run must not clobber the committed full-trajectory file
        print("REPRO_BENCH_ONLY set and no REPRO_BENCH_JSON: "
              "skipping BENCH_sched.json write", flush=True)
        return
    write_json(json_path or os.path.join(
        os.path.dirname(__file__), "..", "BENCH_sched.json"))


if __name__ == "__main__":
    main()
