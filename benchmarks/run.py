# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows.  REPRO_BENCH_FULL=1 runs paper-scale horizons (Fig 5: 10^6 tasks
# on 1000 servers); the default is a CI-sized slice of every experiment.
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    print("name,us_per_call,derived")
    import fig3
    fig3.main()
    import fig4
    fig4.main()
    import fig5
    fig5.main()
    import stability_bench
    stability_bench.main()
    import sched_micro
    sched_micro.main()
    # roofline table from the dry-run artifacts (if generated)
    import roofline
    rows = roofline.run(os.path.join(os.path.dirname(__file__), "results",
                                     "roofline.csv"))
    for r in rows:
        from common import row
        row(f"roofline/{r['arch']}/{r['shape']}", 0.0,
            f"dom={r['dominant']};useful={r['useful_ratio']:.2f};"
            f"roof={100 * r['roofline_frac']:.1f}%")


if __name__ == "__main__":
    main()
