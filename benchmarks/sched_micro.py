"""Scheduler microbenchmarks.

Placement throughput of the BF-J/S and VQS engines (event-driven numpy; the
nested-loop jax "reference" oracles; the branch-free "scan" rewrites; the
fused Pallas kernels in interpret mode for correctness), the best-fit
placement kernels, and rho* LP timing.

The headline rows compare the engines at the historical bench config
(L=16, K=24, Qcap=512, horizon=5000) and verify IN-PROCESS that the fast
engines reproduce their oracle trajectories bit-for-bit (bitmatch=1,
trunc=0) — every speedup is for identical output.  The VQS rows time the
event-driven numpy engine against the scan engine on the same workload
parameters (micro/vqs_slot_numpy vs micro/vqs_slot: the scan-vs-numpy
slots/sec comparison tracked across PRs).

REPRO_BENCH_SMOKE=1 shrinks every shape to a CI-sized smoke test.
"""
from __future__ import annotations


import numpy as np

from common import SMOKE, row, timed, timed_best, timed_interleaved

import jax
import jax.numpy as jnp

from repro.core import (BFJS, ServiceModel, Uniform, VQS, VQSBF, simulate,
                        rho_star_discrete)
from repro.core.engine import (Workload, best_fit_place, make_streams,
                               monte_carlo_bfjs, monte_carlo_policy,
                               run_bfjs, run_bfjs_mr_streams,
                               run_vqs_bf_streams, run_vqs_streams)
from repro.core.engine.bfjs_mr import _run_bfjs_mr_reference
from repro.core.engine.tuning import apply_tuned
from repro.core.engine.vqs import _run_vqs_reference_streams
from repro.core.engine.vqs_bf import _run_vqs_bf_reference_streams
from repro.kernels.best_fit.best_fit import best_fit_pallas
from repro.kernels.bfjs.ops import bfjs_simulate


def sampler(key, n):
    return jax.random.uniform(key, (n,), minval=0.05, maxval=0.5)


def _bench_engines():
    """Seed engine vs rewritten engine, same key, same config, same output.

    The variants are timed INTERLEAVED (round-robin, best-of-N per variant)
    so machine-load drift hits every engine equally — on shared hosts the
    wall clock of a single variant can swing +-50% between back-to-back
    runs, which would make a sequential comparison meaningless."""
    if SMOKE:
        kw = dict(L=4, K=6, Qcap=64, A_max=6, horizon=200)
    else:
        kw = dict(L=16, K=24, Qcap=512, A_max=8, horizon=5_000)
    T = kw["horizon"]
    key = jax.random.PRNGKey(0)

    def run(engine, work_steps=None):
        return run_bfjs(key, 1.5, 0.01, sampler, engine=engine,
                        work_steps=work_steps, **kw)

    variants = {"ref": ("reference", None), "default": ("scan", None),
                "tuned": ("scan", 5)}
    best = timed_interleaved({
        name: (lambda eng=eng, ws=ws:
               run(eng, ws).queue_len.block_until_ready())
        for name, (eng, ws) in variants.items()})

    us_ref = best["ref"]
    row("micro/jax_bfjs_slot_ref", us_ref / T,
        f"engine=reference;slots_per_sec={T / (us_ref / 1e6):.0f}")
    ref = run("reference")
    for label, name in (("", "default"), ("_tuned", "tuned")):
        eng, ws = variants[name]
        us = best[name]
        res = run(eng, ws)
        match = int((res.queue_len == ref.queue_len).all()
                    & (res.departed == ref.departed).all()
                    & (res.occupancy == ref.occupancy).all()
                    & (res.dropped == ref.dropped).all())
        row(f"micro/jax_bfjs_slot{label}", us / T,
            f"engine=scan;work_steps={ws};slots_per_sec={T / (us / 1e6):.0f};"
            f"speedup_vs_ref={us_ref / us:.2f}x;bitmatch={match};"
            f"trunc={int(res.truncated)}")


def _bench_ensemble():
    """Monte-Carlo ensemble throughput (slots/sec x ensembles), old vs new."""
    if SMOKE:
        G, kw = 2, dict(L=4, K=6, Qcap=64, A_max=6, horizon=120)
    else:
        G, kw = 8, dict(L=16, K=24, Qcap=512, A_max=8, horizon=2_000)
    T = kw["horizon"]
    keys = jax.random.split(jax.random.PRNGKey(0), G)
    us_by_engine = {}
    for engine in ("reference", "scan"):
        fn = lambda: monte_carlo_bfjs(
            keys, 1.5, 0.01, sampler, engine=engine,
            **kw).queue_len.block_until_ready()
        _, us = timed_best(fn, repeat=2)
        us_by_engine[engine] = us
        speed = "" if engine == "reference" else \
            f";speedup_vs_ref={us_by_engine['reference'] / us:.2f}x"
        row(f"micro/bfjs_mc_{engine}", us / (G * T),
            f"ensembles={G};ensemble_slots_per_sec={G * T / (us / 1e6):.0f}"
            + speed + ";devices=1;tuned=0;cache_hit=0")


def _bench_vqs_engines():
    """VQS: event-driven numpy engine vs the scan + reference jax engines,
    same workload parameters, timed INTERLEAVED (round-robin best-of-N, see
    _bench_engines) at the historical bench config.

    The scan engine's trajectory is asserted bit-identical to the jax
    reference oracle on shared streams in the same process (bitmatch=1,
    trunc=0); the numpy engine runs its own RNG realization of the same
    workload, so its row is a throughput baseline, not a trajectory twin.
    """
    J = 4
    if SMOKE:
        L, K, Qcap, A_max, T, lam = 4, 6, 256, 6, 200, 1.5
    else:
        L, K, Qcap, A_max, T, lam = 16, 24, 8192, 8, 5_000, 1.5
    mu = 0.01
    streams = make_streams(jax.random.PRNGKey(0), lam, mu, sampler,
                           L=L, K=K, A_max=A_max, horizon=T)
    kw = dict(J=J, L=L, K=K, Qcap=Qcap, A_max=A_max)

    def run_numpy():
        return simulate(VQS(J=J), L=L, lam=lam, dist=Uniform(0.05, 0.5),
                        service=ServiceModel("geometric", 1.0 / mu),
                        horizon=T, seed=0)

    def run_scan():
        return run_vqs_streams(streams, **kw).queue_len.block_until_ready()

    def run_ref():
        return _run_vqs_reference_streams(
            streams, **kw).queue_len.block_until_ready()

    best = timed_interleaved(
        {"numpy": run_numpy, "scan": run_scan, "ref": run_ref})

    us_np = best["numpy"]
    row("micro/vqs_slot_numpy", us_np / T,
        f"engine=numpy-event-driven;J={J};L={L};"
        f"slots_per_sec={T / (us_np / 1e6):.0f}")
    scan_res = run_vqs_streams(streams, **kw)
    ref_res = _run_vqs_reference_streams(streams, **kw)
    match = int((scan_res.queue_len == ref_res.queue_len).all()
                & (scan_res.departed == ref_res.departed).all()
                & (scan_res.occupancy == ref_res.occupancy).all()
                & (scan_res.dropped == ref_res.dropped).all())
    for name, label in (("scan", "micro/vqs_slot"),
                        ("ref", "micro/vqs_slot_ref")):
        us = best[name]
        meta = (f"engine={'scan' if name == 'scan' else 'reference'};J={J};"
                f"slots_per_sec={T / (us / 1e6):.0f};"
                f"speedup_vs_numpy={us_np / us:.2f}x")
        if name == "scan":
            meta += (f";bitmatch_vs_ref={match};"
                     f"trunc={int(scan_res.truncated)}")
        row(label, us / T, meta)


def _bench_vqs_bf_engines():
    """VQS-BF: event-driven numpy engine vs the scan + reference jax
    engines, interleaved exactly like ``_bench_vqs_engines`` — the tracked
    ``micro/vqsbf_slot`` vs ``micro/vqsbf_slot_numpy`` pair.

    The scan trajectory is asserted bit-identical to the jax reference
    oracle on shared streams in-process (bitmatch_vs_ref=1, trunc=0); the
    numpy engine runs its own RNG realization of the same workload, so its
    row is a throughput baseline, not a trajectory twin.  The work bound
    is sized to the burst (one placement per step), not to A_max.
    """
    J = 4
    if SMOKE:
        L, K, Qcap, A_max, T, lam = 4, 6, 256, 6, 200, 1.5
    else:
        L, K, Qcap, A_max, T, lam = 16, 24, 8192, 8, 5_000, 1.5
    mu = 0.01
    streams = make_streams(jax.random.PRNGKey(0), lam, mu, sampler,
                           L=L, K=K, A_max=A_max, horizon=T)
    kw = dict(J=J, L=L, K=K, Qcap=Qcap, A_max=A_max)

    def run_numpy():
        return simulate(VQSBF(J=J), L=L, lam=lam, dist=Uniform(0.05, 0.5),
                        service=ServiceModel("geometric", 1.0 / mu),
                        horizon=T, seed=0)

    def run_scan():
        return run_vqs_bf_streams(streams, work_steps=64,
                                  **kw).queue_len.block_until_ready()

    def run_ref():
        return _run_vqs_bf_reference_streams(
            streams, **kw).queue_len.block_until_ready()

    best = timed_interleaved(
        {"numpy": run_numpy, "scan": run_scan, "ref": run_ref})

    us_np = best["numpy"]
    row("micro/vqsbf_slot_numpy", us_np / T,
        f"engine=numpy-event-driven;J={J};L={L};"
        f"slots_per_sec={T / (us_np / 1e6):.0f}")
    scan_res = run_vqs_bf_streams(streams, work_steps=64, **kw)
    ref_res = _run_vqs_bf_reference_streams(streams, **kw)
    match = int((scan_res.queue_len == ref_res.queue_len).all()
                & (scan_res.departed == ref_res.departed).all()
                & (scan_res.occupancy == ref_res.occupancy).all()
                & (scan_res.dropped == ref_res.dropped).all())
    for name, label in (("scan", "micro/vqsbf_slot"),
                        ("ref", "micro/vqsbf_slot_ref")):
        us = best[name]
        meta = (f"engine={'scan' if name == 'scan' else 'reference'};J={J};"
                f"slots_per_sec={T / (us / 1e6):.0f};"
                f"speedup_vs_numpy={us_np / us:.2f}x")
        if name == "scan":
            meta += (f";bitmatch_vs_ref={match};"
                     f"trunc={int(scan_res.truncated)}")
        row(label, us / T, meta)


def _bench_vqs_ensemble():
    """VQS Monte-Carlo ensemble throughput (vmapped scan vs reference)."""
    J = 4
    if SMOKE:
        G, kw = 2, dict(L=4, K=6, Qcap=256, A_max=6, horizon=120)
    else:
        G, kw = 8, dict(L=16, K=24, Qcap=8192, A_max=8, horizon=2_000)
    T = kw["horizon"]
    keys = jax.random.split(jax.random.PRNGKey(0), G)
    wl = Workload(lam=1.5, mu=0.01, sampler=sampler)
    us_ref = None
    for engine in ("reference", "scan"):
        fn = lambda: monte_carlo_policy(
            wl, keys, policy="vqs", engine=engine, J=J,
            **kw).queue_len.block_until_ready()
        _, us = timed_best(fn, repeat=2)
        # monte_carlo_policy consults the tuning cache: probe what it
        # injected for this launch so the row is attributable
        t = apply_tuned("vqs", engine, dict(J=J, **kw), 1)
        meta = (f"ensembles={G};"
                f"ensemble_slots_per_sec={G * T / (us / 1e6):.0f}")
        if engine == "reference":
            us_ref = us
        else:
            meta += f";speedup_vs_ref={us_ref / us:.2f}x"
        meta += f";devices=1;tuned={t['tuned']};cache_hit={t['cache_hit']}"
        row(f"micro/vqs_mc_{engine}", us / (G * T), meta)


def _mr_sampler(key, n):
    """Anti-correlated (cpu, mem) demands: the workload where alignment
    packing beats the paper's max-collapse (cf. tests/test_extensions)."""
    kh, kl, kf = jax.random.split(key, 3)
    heavy = jax.random.uniform(kh, (n,), minval=0.45, maxval=0.55)
    light = jax.random.uniform(kl, (n,), minval=0.05, maxval=0.1)
    flip = jax.random.uniform(kf, (n,)) < 0.5
    cpu = jnp.where(flip, heavy, light)
    mem = jnp.where(flip, light, heavy)
    return jnp.stack([cpu, mem], axis=1)


def _bench_mr_engines():
    """Multi-resource BF-J/S (policy="bfjs-mr"): the event-driven numpy
    oracle vs the scan engine on the SAME streams — the tracked
    micro/mr_slot vs micro/mr_slot_numpy speedup pair.

    Timed INTERLEAVED (round-robin best-of-N, see _bench_engines — per the
    bench-noise note, single-variant wall clocks swing on shared hosts)
    and verified IN-PROCESS: the scan trajectory must be bit-identical to
    the oracle (bitmatch_vs_ref=1, trunc=0) for the speedup to count.
    """
    if SMOKE:
        L, K, Qcap, A_max, T, lam, mu = 4, 8, 64, 5, 150, 0.3, 0.05
    else:
        L, K, Qcap, A_max, T, lam, mu = 16, 16, 512, 8, 3_000, 1.2, 0.05
    streams = make_streams(jax.random.PRNGKey(0), lam, mu, _mr_sampler,
                           L=L, K=K, A_max=A_max, horizon=T,
                           num_resources=2)
    kw = dict(L=L, K=K, Qcap=Qcap, A_max=A_max, work_steps=24)
    # outputs are deterministic for fixed streams: capture the last run of
    # each timed variant instead of paying an extra oracle pass afterwards
    results = {}

    def run_scan():
        results["scan"] = run_bfjs_mr_streams(streams, **kw)
        return results["scan"].queue_len.block_until_ready()

    def run_numpy():
        results["numpy"] = _run_bfjs_mr_reference(streams, L=L)
        return results["numpy"]

    best = timed_interleaved({"numpy": run_numpy, "scan": run_scan})

    us_np = best["numpy"]
    row("micro/mr_slot_numpy", us_np / T,
        f"engine=numpy-event-driven;R=2;L={L};"
        f"slots_per_sec={T / (us_np / 1e6):.0f}")
    scan_res, ref_res = results["scan"], results["numpy"]
    match = int((scan_res.queue_len == ref_res.queue_len).all()
                & (scan_res.departed == ref_res.departed).all()
                & (scan_res.occupancy == ref_res.occupancy).all()
                & (scan_res.dropped == ref_res.dropped).all())
    us = best["scan"]
    row("micro/mr_slot", us / T,
        f"engine=scan;R=2;L={L};slots_per_sec={T / (us / 1e6):.0f};"
        f"speedup_vs_numpy={us_np / us:.2f}x;bitmatch_vs_ref={match};"
        f"trunc={int(scan_res.truncated)}")


def _bench_mr_ensemble():
    """Multi-resource Monte-Carlo ensemble: the fused kernels/bfjs_mr
    Pallas kernel (interpret mode off-TPU: correctness-grade wall clock)
    vs the vmapped scan engine on the SAME pre-generated streams — the
    tracked micro/mr_ensemble vs micro/mr_ensemble_scan pair — plus the
    kernel with its early-exit work list DISABLED (micro/mr_ensemble_noexit
    = the pre-optimization launch, kept as the before/after record of the
    while_loop early-exit fix).

    Timed INTERLEAVED (see _bench_engines) and verified IN-PROCESS: both
    kernel trajectories must be bit-identical to the vmapped scan engine
    (bitmatch_vs_ref=1, trunc=0) for the comparison to count — early exit
    is bit-identical by construction (post-done work steps are no-ops).
    """
    from repro.kernels.bfjs_mr.ops import bfjs_mr_simulate

    if SMOKE:
        G, L, K, Qcap, A_max, T = 2, 4, 8, 64, 5, 120
    else:
        G, L, K, Qcap, A_max, T = 4, 8, 16, 256, 6, 600
    keys = jax.random.split(jax.random.PRNGKey(3), G)
    streams = jax.vmap(lambda k: make_streams(
        k, 0.5, 0.05, _mr_sampler, L=L, K=K, A_max=A_max, horizon=T,
        num_resources=2))(keys)
    kw = dict(L=L, K=K, Qcap=Qcap, A_max=A_max, work_steps=24)
    launch = "devices=1;tuned=0;cache_hit=0"  # direct kernel entry point
    results = {}

    def run_pallas():
        results["pallas"] = bfjs_mr_simulate(streams, **kw)
        return results["pallas"].queue_len.block_until_ready()

    def run_noexit():
        results["noexit"] = bfjs_mr_simulate(streams, early_exit=False,
                                             **kw)
        return results["noexit"].queue_len.block_until_ready()

    def run_scan():
        results["scan"] = bfjs_mr_simulate(streams, use_pallas=False, **kw)
        return results["scan"].queue_len.block_until_ready()

    best = timed_interleaved({"scan": run_scan, "pallas": run_pallas,
                              "noexit": run_noexit})

    us_scan = best["scan"]
    row("micro/mr_ensemble_scan", us_scan / (G * T),
        f"engine=scan-vmap;R=2;ensembles={G};"
        f"ensemble_slots_per_sec={G * T / (us_scan / 1e6):.0f};{launch}")
    ref = results["scan"]

    def bitmatch(res):
        return int(all(
            (np.asarray(getattr(res, f)) == np.asarray(getattr(ref, f)))
            .all() for f in res._fields))

    us_ne = best["noexit"]
    row("micro/mr_ensemble_noexit", us_ne / (G * T),
        f"engine=pallas-interp;R=2;ensembles={G};early_exit=0;"
        f"ensemble_slots_per_sec={G * T / (us_ne / 1e6):.0f};"
        f"bitmatch_vs_ref={bitmatch(results['noexit'])};"
        f"trunc={int(np.asarray(results['noexit'].truncated).sum())};"
        + launch)
    us = best["pallas"]
    row("micro/mr_ensemble", us / (G * T),
        f"engine=pallas-interp;R=2;ensembles={G};early_exit=1;"
        f"ensemble_slots_per_sec={G * T / (us / 1e6):.0f};"
        f"speedup_from_early_exit={us_ne / us:.2f}x;"
        f"bitmatch_vs_ref={bitmatch(results['pallas'])};"
        f"trunc={int(np.asarray(results['pallas'].truncated).sum())};"
        + launch)


#: set by _bench_live_admission; main() fails loudly on a placement
#: divergence — a faster-but-wrong admission path must not record a row
_LIVE_ADMISSION_MISMATCH: list[str] = []


def _bench_live_admission():
    """Device-resident admission (serving/live.py) vs the host
    AdmissionController on the SAME scripted tick sequence — the
    micro/live_admission vs micro/live_admission_host pair.

    The script (arrivals + completions per tick) is generated once by an
    un-timed host pass, then both paths replay it: the host as the
    ServingEngine's historical per-event release/refill Python loop, the
    device as one fused ``tick_step`` dispatch per tick.  Arrival pressure
    exceeds capacity so the queue stays long — the regime the fused path
    exists for (the host refill is a Python ``max()`` scan of the queue
    per placement).  Timed INTERLEAVED (see _bench_engines); the two
    placement sequences must be identical for the row to count
    (bitmatch_vs_host, gated by main())."""
    from repro.cluster.admission import AdmissionController, PendingJob
    from repro.serving.live import LiveAdmission

    # burst fills the replicas and leaves a deep standing queue (well
    # under Qcap: queue overflow would make the device path drop — a real
    # divergence the bitmatch gate would rightly flag); the per-tick
    # completion probability p is sized so departures track arrivals and
    # the backlog neither drains nor overflows across the run
    if SMOKE:
        L, Qcap, ticks, width, burst, p = 8, 128, 40, 6, 80, 0.12
    else:
        L, Qcap, ticks, width, burst, p = 64, 512, 200, 8, 480, 0.02
    rng = np.random.default_rng(0)

    # -- script generation (un-timed): one host pass drives the arrival /
    # completion sequence both timed replays will follow verbatim
    gen = AdmissionController(L)
    script, active, size_of, rid = [], {}, {}, 0
    for t in range(ticks):
        jobs = []
        for _ in range(burst if t == 0 else int(rng.integers(1, width))):
            jobs.append((rid, float(rng.uniform(0.05, 0.6))))
            rid += 1
        placed = gen.admit([PendingJob(rid=r, frac=f) for r, f in jobs])
        for r, rep in placed:
            active[r] = rep
        size_of.update(
            {r: PendingJob(rid=r, frac=f).size for r, f in jobs})
        done = [r for r in list(active) if rng.uniform() < p][:width]
        events = [(active.pop(r), size_of[r]) for r in done]
        for rep, size in events:
            gen.release(rep, size)
        for rep in sorted({rep for rep, _ in events}):
            for r, rep2 in gen.refill(rep):
                active[r] = rep2
        script.append((jobs, events))
        assert gen.queue_len() < Qcap, "script overflowed the device Qcap"
    assert gen.queue_len() > 0, "script never backlogged the queue"

    def drive_host():
        ctrl, out = AdmissionController(L), []
        for jobs, events in script:
            out += ctrl.admit([PendingJob(rid=r, frac=f)
                               for r, f in jobs])
            for rep, size in events:
                ctrl.release(rep, size)
            for rep in sorted({rep for rep, _ in events}):
                out += ctrl.refill(rep)
        return out

    def drive_live():
        ctrl, out = LiveAdmission(L, Qcap=Qcap, tick_width=width), []
        for jobs, events in script:
            out += ctrl.admit([PendingJob(rid=r, frac=f)
                               for r, f in jobs])
            out += ctrl.tick(events)
        ctrl.queue_len()   # sync + surface any invalid-release count
        return out

    best = timed_interleaved({"host": drive_host, "live": drive_live},
                             rounds=3)
    match = int(drive_host() == drive_live())
    if not match:
        _LIVE_ADMISSION_MISMATCH.append(
            "live placement sequence diverged from the host controller")
    us_h, us_l = best["host"], best["live"]
    row("micro/live_admission_host", us_h / ticks,
        f"admission=host-python;L={L};Qcap={Qcap};"
        f"ticks_per_sec={ticks / (us_h / 1e6):.0f}")
    row("micro/live_admission", us_l / ticks,
        f"admission=device-jit;L={L};Qcap={Qcap};"
        f"ticks_per_sec={ticks / (us_l / 1e6):.0f};"
        f"speedup_vs_host={us_h / us_l:.2f}x;bitmatch_vs_host={match};"
        "trunc=0;devices=1")


def _bench_pallas_vqs():
    """Fused VQS slot-step kernel, interpret mode: correctness-grade
    timing."""
    from repro.kernels.vqs.ops import vqs_simulate
    G, J, kw = 2, 3, dict(L=4, K=8, Qcap=64, A_max=5)
    T = 120
    keys = jax.random.split(jax.random.PRNGKey(2), G)
    streams = jax.vmap(lambda k: make_streams(
        k, 1.0, 0.03, sampler, L=kw["L"], K=kw["K"], A_max=kw["A_max"],
        horizon=T))(keys)
    fn = lambda: vqs_simulate(streams, J=J, Qcap=kw["Qcap"],
                              **{k: kw[k] for k in ("L", "K", "A_max")}
                              ).queue_len.block_until_ready()
    _, us = timed_best(fn, repeat=1)
    row("micro/vqs_pallas_interp", us / (G * T),
        "per_slot;interpret-mode(correctness-only)")


def _bench_pallas_bfjs():
    """Fused slot-step kernel, interpret mode: correctness-grade timing."""
    G, kw = 2, dict(L=4, K=6, Qcap=64, A_max=6)
    T = 120
    keys = jax.random.split(jax.random.PRNGKey(1), G)
    streams = jax.vmap(lambda k: make_streams(
        k, 1.2, 0.02, sampler, L=kw["L"], K=kw["K"], A_max=kw["A_max"],
        horizon=T))(keys)
    fn = lambda: bfjs_simulate(streams, Qcap=kw["Qcap"],
                               **{k: kw[k] for k in ("L", "K", "A_max")}
                               ).queue_len.block_until_ready()
    _, us = timed_best(fn, repeat=1)
    row("micro/bfjs_pallas_interp", us / (G * T),
        "per_slot;interpret-mode(correctness-only)")


def main():
    # numpy event-driven engine: jobs/sec at trace-like load
    dist = Uniform(0.05, 0.5)
    svc = ServiceModel("geometric", 100.0)
    horizon = 2_000 if SMOKE else 50_000
    res, us = timed(simulate, BFJS(), L=100, lam=2.0, dist=dist, service=svc,
                    horizon=horizon, seed=0)
    row("micro/numpy_bfjs", us / horizon,
        f"jobs_per_sec={res.departed / (us / 1e6):.0f}")

    _bench_engines()
    _bench_ensemble()
    _bench_pallas_bfjs()
    _bench_vqs_engines()
    _bench_vqs_bf_engines()
    _bench_vqs_ensemble()
    _bench_pallas_vqs()
    _bench_mr_engines()
    _bench_mr_ensemble()
    _bench_live_admission()

    # best-fit placement kernels: jnp scan vs Pallas(interpret)
    Lbf, Nbf = (128, 32) if SMOKE else (1024, 256)
    resid = jax.random.uniform(jax.random.PRNGKey(1), (Lbf,))
    sizes = jax.random.uniform(jax.random.PRNGKey(2), (Nbf,), minval=0.01,
                               maxval=0.3)
    jp = jax.jit(best_fit_place)
    jp(resid, sizes)[0].block_until_ready()
    _, us = timed(lambda: jp(resid, sizes)[0].block_until_ready(), repeat=5)
    row("micro/best_fit_jnp", us / Nbf, f"per_job;L={Lbf}")
    best_fit_pallas(resid, sizes, interpret=True)
    _, us = timed(lambda: best_fit_pallas(resid, sizes, interpret=True)[0]
                  .block_until_ready(), repeat=2)
    row("micro/best_fit_pallas_interp", us / Nbf,
        "per_job;interpret-mode(correctness-only)")

    # rho* LP
    sizes_t = np.array([0.15, 0.23, 0.31, 0.47, 0.62])
    probs = np.full(5, 0.2)
    _, us = timed(rho_star_discrete, sizes_t, probs, 4)
    r = rho_star_discrete(sizes_t, probs, 4)
    row("micro/rho_star_lp_5types", us, f"rho*={r:.3f}")

    if _LIVE_ADMISSION_MISMATCH:
        import sys
        print(f"ERROR: live admission diverged from the host controller: "
              f"{_LIVE_ADMISSION_MISMATCH}", file=sys.stderr, flush=True)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
