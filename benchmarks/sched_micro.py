"""Scheduler microbenchmarks: placement throughput of the three engines
(event-driven numpy, pure-JAX, Pallas interpret) + rho* LP timing."""
from __future__ import annotations

import numpy as np

from common import row, timed

import jax
import jax.numpy as jnp

from repro.core import (BFJS, ServiceModel, Uniform, simulate,
                        rho_star_discrete)
from repro.core.jax_sched import best_fit_place, run_bfjs
from repro.kernels.best_fit.best_fit import best_fit_pallas


def main():
    # numpy event-driven engine: jobs/sec at trace-like load
    dist = Uniform(0.05, 0.5)
    svc = ServiceModel("geometric", 100.0)
    horizon = 50_000
    res, us = timed(simulate, BFJS(), L=100, lam=2.0, dist=dist, service=svc,
                    horizon=horizon, seed=0)
    row("micro/numpy_bfjs", us / horizon,
        f"jobs_per_sec={res.departed / (us / 1e6):.0f}")

    # JAX scan engine (jit, CPU)
    def sampler(key, n):
        return jax.random.uniform(key, (n,), minval=0.05, maxval=0.5)

    fn = lambda: run_bfjs(jax.random.PRNGKey(0), lam=1.5, mu=0.01,
                          sampler=sampler, L=16, K=24, Qcap=512, A_max=8,
                          horizon=5_000).queue_len.block_until_ready()
    fn()  # compile
    _, us = timed(fn)
    row("micro/jax_bfjs_slot", us / 5_000, "engine=lax.scan")

    # best-fit placement kernels: jnp scan vs Pallas(interpret)
    resid = jax.random.uniform(jax.random.PRNGKey(1), (1024,))
    sizes = jax.random.uniform(jax.random.PRNGKey(2), (256,), minval=0.01,
                               maxval=0.3)
    jp = jax.jit(best_fit_place)
    jp(resid, sizes)[0].block_until_ready()
    _, us = timed(lambda: jp(resid, sizes)[0].block_until_ready(), repeat=5)
    row("micro/best_fit_jnp", us / 256, "per_job;L=1024")
    best_fit_pallas(resid, sizes, interpret=True)
    _, us = timed(lambda: best_fit_pallas(resid, sizes, interpret=True)[0]
                  .block_until_ready(), repeat=2)
    row("micro/best_fit_pallas_interp", us / 256,
        "per_job;interpret-mode(correctness-only)")

    # rho* LP
    sizes_t = np.array([0.15, 0.23, 0.31, 0.47, 0.62])
    probs = np.full(5, 0.2)
    _, us = timed(rho_star_discrete, sizes_t, probs, 4)
    r = rho_star_discrete(sizes_t, probs, 4)
    row("micro/rho_star_lp_5types", us, f"rho*={r:.3f}")


if __name__ == "__main__":
    main()
