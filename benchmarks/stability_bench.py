"""Theorem-1 machinery: rho-bar*/rho-lower* convergence table + the
Proposition-2 2/3-tightness example, as a benchmark artifact."""
from __future__ import annotations

import numpy as np

from common import row, timed

from repro.core import Uniform, rho_bounds, rho_star_discrete


def main():
    d = Uniform(0.2, 0.9)
    for n in (0, 1, 2):
        (up, lo), us = timed(rho_bounds, d, n, 1)
        row(f"stability/theorem1_n{n}", us,
            f"rho_bar={up:.4f};rho_lower={lo:.4f};gap={lo-up:.4f}")

    eps = 0.01
    r_true = rho_star_discrete(np.array([0.5 - eps, 0.5 + eps]),
                               np.array([0.5, 0.5]), L=1)
    r_obl = rho_star_discrete(np.array([0.5, 0.5 + eps]),
                              np.array([0.5, 0.5]), L=1)
    row("stability/prop2_tightness", 0.0,
        f"rho*={r_true:.3f};oblivious={r_obl:.3f};"
        f"ratio={r_obl / r_true:.4f}(=2/3)")


if __name__ == "__main__":
    main()
