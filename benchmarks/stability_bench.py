"""Theorem-1 machinery: rho-bar*/rho-lower* convergence table + the
Proposition-2 2/3-tightness example, as a benchmark artifact — plus the
Monte-Carlo ensemble throughput of the accelerator engines (BF-J/S and
VQS, via the policy-generic Workload/run_policy stack) at a
stability-study operating point (the workload the jax engines exist for).

An engine comparison whose scan member reports ``truncated != 0`` is a
bogus speedup (the trajectories diverged); main() FAILS LOUDLY (nonzero
exit) instead of silently recording it."""
from __future__ import annotations

import sys

import numpy as np

from common import SMOKE, row, timed, timed_best

import jax

from repro.core import Uniform, rho_bounds, rho_star_discrete
from repro.core.engine import Workload, monte_carlo_policy

#: (row name, truncated count) per scan-engine comparison; checked by
#: main() — any nonzero count aborts the benchmark run with exit code 1.
_TRUNCATIONS: list[tuple[str, int]] = []

#: (row name, violation) fault-accounting failures — scan ``lost``
#: diverging from the reference oracle, or a broken ``preempted ==
#: requeued + lost`` invariant; same nonzero-exit treatment.
_FAULT_VIOLATIONS: list[tuple[str, str]] = []


def _mc_ensemble_throughput(policy: str, Qcap: int | None = None,
                            workload: Workload | None = None,
                            engines: tuple[str, ...] = ("reference", "scan"),
                            **policy_kw):
    """Reference vs accelerator engines on a stable (rho < rho*) ensemble
    study.  ``workload`` overrides the default scalar U(0.1, 0.6) workload
    (multi-resource policies pass their vector sampler); every
    non-reference engine's trunc count feeds the loud exit-code gate."""
    if SMOKE:
        G, kw = 2, dict(L=4, K=8, Qcap=64, A_max=6, horizon=150)
    else:
        G, kw = 8, dict(L=8, K=16, Qcap=256, A_max=6, horizon=1_500)
    if Qcap is not None:
        kw["Qcap"] = Qcap if not SMOKE else max(64, Qcap // 8)
    T = kw["horizon"]
    lam, mu = 0.4, 0.02        # rho ~ 0.9 of capacity for U(0.1, 0.6) sizes

    def sampler(key, n):
        return jax.random.uniform(key, (n,), minval=0.1, maxval=0.6)

    keys = jax.random.split(jax.random.PRNGKey(7), G)
    wl = workload if workload is not None \
        else Workload(lam=lam, mu=mu, sampler=sampler)
    us_ref = None
    for engine in engines:
        def fn():
            r = monte_carlo_policy(wl, keys, policy=policy,
                                   engine=engine, **policy_kw, **kw)
            r.queue_len.block_until_ready()
            return r
        res, us = timed_best(fn, repeat=2)
        tail_q = float(np.asarray(res.queue_len)[:, -T // 4:].mean())
        meta = (f"ensembles={G};ensemble_slots_per_sec="
                f"{G * T / (us / 1e6):.0f};tail_queue={tail_q:.2f};"
                f"dropped={int(np.asarray(res.dropped).sum())}")
        suffix = "" if policy == "bfjs" else f"_{policy}"
        name = f"stability/mc_ensemble{suffix}_{engine}"
        if engine == "reference":
            us_ref = us
        else:
            trunc = int(np.asarray(res.truncated).sum())
            meta += f";speedup_vs_ref={us_ref / us:.2f}x;trunc={trunc}"
            _TRUNCATIONS.append((name, trunc))
        row(name, us / (G * T), meta)


def _faulted_mc_throughput():
    """Fault-injected Monte-Carlo ensemble (DESIGN.md §9): reference vs
    scan under a two-state Markov capacity-shock plane.  Beyond the trunc
    gate, the fault accounting itself is gated — scan ``lost`` must equal
    the oracle's and every engine must satisfy ``preempted == requeued +
    lost`` — so a silently-dropped preemption fails the benchmark run."""
    if SMOKE:
        G, kw = 2, dict(L=4, K=8, Qcap=64, A_max=6, horizon=150)
    else:
        G, kw = 8, dict(L=8, K=16, Qcap=256, A_max=6, horizon=1_500)
    T = kw["horizon"]

    def sampler(key, n):
        return jax.random.uniform(key, (n,), minval=0.1, maxval=0.6)

    wl = Workload(lam=0.4, mu=0.02, sampler=sampler)
    keys = jax.random.split(jax.random.PRNGKey(11), G)
    fault = dict(fault_rate=0.01, repair_rate=0.2)
    lost_by_engine = {}
    us_ref = None
    for engine in ("reference", "scan"):
        def fn():
            r = monte_carlo_policy(wl, keys, policy="bfjs", engine=engine,
                                   **fault, **kw)
            r.queue_len.block_until_ready()
            return r
        res, us = timed_best(fn, repeat=2)
        pre = int(np.asarray(res.preempted).sum())
        req = int(np.asarray(res.requeued).sum())
        lost = int(np.asarray(res.lost).sum())
        lost_by_engine[engine] = lost
        name = f"stability/faulted_mc_{engine}"
        meta = (f"ensembles={G};ensemble_slots_per_sec="
                f"{G * T / (us / 1e6):.0f};preempted={pre};requeued={req};"
                f"lost={lost}")
        if engine == "reference":
            us_ref = us
        else:
            trunc = int(np.asarray(res.truncated).sum())
            meta += f";speedup_vs_ref={us_ref / us:.2f}x;trunc={trunc}"
            _TRUNCATIONS.append((name, trunc))
        if pre != req + lost:
            _FAULT_VIOLATIONS.append(
                (name, f"preempted {pre} != requeued {req} + lost {lost}"))
        row(name, us / (G * T), meta)
    if lost_by_engine["scan"] != lost_by_engine["reference"]:
        _FAULT_VIOLATIONS.append(
            ("stability/faulted_mc_scan",
             f"lost {lost_by_engine['scan']} != reference lost "
             f"{lost_by_engine['reference']}"))


def _mr_workload() -> Workload:
    """Vector (cpu, mem) workload at the same operating point: U(0.1, 0.6)
    per-resource demands, rho ~ 0.9 of capacity on the binding resource."""
    def sampler(key, n):
        return jax.random.uniform(key, (n, 2), minval=0.1, maxval=0.6)
    return Workload(lam=0.4, mu=0.02, sampler=sampler, num_resources=2)


def main():
    d = Uniform(0.2, 0.9)
    for n in (0, 1, 2):
        (up, lo), us = timed(rho_bounds, d, n, 1)
        row(f"stability/theorem1_n{n}", us,
            f"rho_bar={up:.4f};rho_lower={lo:.4f};gap={lo-up:.4f}")

    eps = 0.01
    r_true = rho_star_discrete(np.array([0.5 - eps, 0.5 + eps]),
                               np.array([0.5, 0.5]), L=1)
    r_obl = rho_star_discrete(np.array([0.5, 0.5 + eps]),
                              np.array([0.5, 0.5]), L=1)
    row("stability/prop2_tightness", 0.0,
        f"rho*={r_true:.3f};oblivious={r_obl:.3f};"
        f"ratio={r_obl / r_true:.4f}(=2/3)")

    _mc_ensemble_throughput("bfjs")
    # VQS: sizes in U(0.1, 0.6) live above 2^-3, K=16 >= 2^3 packing bound
    _mc_ensemble_throughput("vqs", Qcap=2048, J=3)
    # multi-resource BF-J/S: the scan engine AND the fused Pallas kernel
    # (interpret off-TPU) against the event-driven oracle — both trunc
    # counts feed the exit-code gate, so a diverging kernel fails the run
    _mc_ensemble_throughput("bfjs-mr", workload=_mr_workload(),
                            engines=("reference", "scan", "pallas"),
                            work_steps=24)
    _faulted_mc_throughput()

    bad = [(name, t) for name, t in _TRUNCATIONS if t != 0]
    if bad:
        print("ERROR: engine comparisons reported truncation (trajectories "
              f"diverged from the reference): {bad}", file=sys.stderr,
              flush=True)
        raise SystemExit(1)
    if _FAULT_VIOLATIONS:
        print("ERROR: fault accounting violated (scan vs reference lost, "
              f"or preempted != requeued + lost): {_FAULT_VIOLATIONS}",
              file=sys.stderr, flush=True)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
