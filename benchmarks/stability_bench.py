"""Theorem-1 machinery: rho-bar*/rho-lower* convergence table + the
Proposition-2 2/3-tightness example, as a benchmark artifact — plus the
Monte-Carlo ensemble throughput of the accelerator engines (BF-J/S and
VQS, via the policy-generic Workload/run_policy stack) at a
stability-study operating point (the workload the jax engines exist for).

Beyond the single-device engine comparison, the ensemble study is tracked
mesh-sharded (``stability/mc_ensemble*_sharded_d{N}``: the same run with G
split over N devices — bit-identical by contract) and autotuned
(``stability/mc_ensemble*_scan_tuned``: the shape's cached ``work_steps``
winner vs the signature default, bit-match verified in-process).  Every
ensemble row carries ``devices=``/``tuned=``/``cache_hit=`` so a recorded
throughput is attributable to its exact launch configuration.

An engine comparison whose scan member reports ``truncated != 0`` is a
bogus speedup (the trajectories diverged); main() FAILS LOUDLY (nonzero
exit) instead of silently recording it.  The same loud-exit treatment
covers sharded/tuned runs that fail their bit-match, and a fused bfjs-mr
Pallas ensemble row that falls behind the event-driven reference oracle
(where the pre-early-exit kernel sat)."""
from __future__ import annotations

import os
import sys
import tempfile

import numpy as np

from common import RECORDS, SMOKE, row, timed, timed_best, timed_interleaved

import jax

from repro.core import Uniform, rho_bounds, rho_star_discrete
from repro.core.engine import Workload, autotune, monte_carlo_policy
from repro.core.engine.tuning import _bitmatch, apply_tuned

#: (row name, truncated count) per scan-engine comparison; checked by
#: main() — any nonzero count aborts the benchmark run with exit code 1.
_TRUNCATIONS: list[tuple[str, int]] = []

#: (row name, violation) fault-accounting failures — scan ``lost``
#: diverging from the reference oracle, or a broken ``preempted ==
#: requeued + lost`` invariant; same nonzero-exit treatment.
_FAULT_VIOLATIONS: list[tuple[str, str]] = []

#: (row name, violation) gate failures from the sharded/tuned rows — a
#: sharded or tuned trajectory that is not bit-identical to its unsharded/
#: untuned reference, or the bfjs-mr Pallas row trailing the event-driven
#: oracle; same nonzero-exit treatment.
_GATE_VIOLATIONS: list[tuple[str, str]] = []


def _tuning_fields(policy: str, engine: str, config: dict,
                   num_resources: int = 1) -> str:
    """``tuned=``/``cache_hit=`` meta fields for one launch: what the
    tuning cache would inject for this exact (policy, engine, shape) —
    probed on a copy, so the timed config itself is untouched."""
    t = apply_tuned(policy, engine, dict(config), num_resources)
    return f"tuned={t['tuned']};cache_hit={t['cache_hit']}"


def _mc_ensemble_throughput(policy: str, Qcap: int | None = None,
                            workload: Workload | None = None,
                            engines: tuple[str, ...] = ("reference", "scan"),
                            **policy_kw):
    """Reference vs accelerator engines on a stable (rho < rho*) ensemble
    study.  ``workload`` overrides the default scalar U(0.1, 0.6) workload
    (multi-resource policies pass their vector sampler); every
    non-reference engine's trunc count feeds the loud exit-code gate."""
    if SMOKE:
        G, kw = 2, dict(L=4, K=8, Qcap=64, A_max=6, horizon=150)
    else:
        G, kw = 8, dict(L=8, K=16, Qcap=256, A_max=6, horizon=1_500)
    if Qcap is not None:
        kw["Qcap"] = Qcap if not SMOKE else max(64, Qcap // 8)
    T = kw["horizon"]
    lam, mu = 0.4, 0.02        # rho ~ 0.9 of capacity for U(0.1, 0.6) sizes

    def sampler(key, n):
        return jax.random.uniform(key, (n,), minval=0.1, maxval=0.6)

    keys = jax.random.split(jax.random.PRNGKey(7), G)
    wl = workload if workload is not None \
        else Workload(lam=lam, mu=mu, sampler=sampler)
    us_ref = None
    for engine in engines:
        def fn():
            r = monte_carlo_policy(wl, keys, policy=policy,
                                   engine=engine, **policy_kw, **kw)
            r.queue_len.block_until_ready()
            return r
        res, us = timed_best(fn, repeat=2)
        tail_q = float(np.asarray(res.queue_len)[:, -T // 4:].mean())
        meta = (f"ensembles={G};ensemble_slots_per_sec="
                f"{G * T / (us / 1e6):.0f};tail_queue={tail_q:.2f};"
                f"dropped={int(np.asarray(res.dropped).sum())};devices=1;"
                + _tuning_fields(policy, engine, dict(kw, **policy_kw),
                                 wl.num_resources))
        suffix = "" if policy == "bfjs" else f"_{policy}"
        name = f"stability/mc_ensemble{suffix}_{engine}"
        if engine == "reference":
            us_ref = us
        else:
            trunc = int(np.asarray(res.truncated).sum())
            meta += f";speedup_vs_ref={us_ref / us:.2f}x;trunc={trunc}"
            _TRUNCATIONS.append((name, trunc))
        row(name, us / (G * T), meta)


def _faulted_mc_throughput():
    """Fault-injected Monte-Carlo ensemble (DESIGN.md §9): reference vs
    scan under a two-state Markov capacity-shock plane.  Beyond the trunc
    gate, the fault accounting itself is gated — scan ``lost`` must equal
    the oracle's and every engine must satisfy ``preempted == requeued +
    lost`` — so a silently-dropped preemption fails the benchmark run."""
    if SMOKE:
        G, kw = 2, dict(L=4, K=8, Qcap=64, A_max=6, horizon=150)
    else:
        G, kw = 8, dict(L=8, K=16, Qcap=256, A_max=6, horizon=1_500)
    T = kw["horizon"]

    def sampler(key, n):
        return jax.random.uniform(key, (n,), minval=0.1, maxval=0.6)

    wl = Workload(lam=0.4, mu=0.02, sampler=sampler)
    keys = jax.random.split(jax.random.PRNGKey(11), G)
    fault = dict(fault_rate=0.01, repair_rate=0.2)
    lost_by_engine = {}
    us_ref = None
    for engine in ("reference", "scan"):
        def fn():
            r = monte_carlo_policy(wl, keys, policy="bfjs", engine=engine,
                                   **fault, **kw)
            r.queue_len.block_until_ready()
            return r
        res, us = timed_best(fn, repeat=2)
        pre = int(np.asarray(res.preempted).sum())
        req = int(np.asarray(res.requeued).sum())
        lost = int(np.asarray(res.lost).sum())
        lost_by_engine[engine] = lost
        name = f"stability/faulted_mc_{engine}"
        meta = (f"ensembles={G};ensemble_slots_per_sec="
                f"{G * T / (us / 1e6):.0f};preempted={pre};requeued={req};"
                f"lost={lost};devices=1;"
                + _tuning_fields("bfjs", engine, dict(kw, **fault), 1))
        if engine == "reference":
            us_ref = us
        else:
            trunc = int(np.asarray(res.truncated).sum())
            meta += f";speedup_vs_ref={us_ref / us:.2f}x;trunc={trunc}"
            _TRUNCATIONS.append((name, trunc))
        if pre != req + lost:
            _FAULT_VIOLATIONS.append(
                (name, f"preempted {pre} != requeued {req} + lost {lost}"))
        row(name, us / (G * T), meta)
    if lost_by_engine["scan"] != lost_by_engine["reference"]:
        _FAULT_VIOLATIONS.append(
            ("stability/faulted_mc_scan",
             f"lost {lost_by_engine['scan']} != reference lost "
             f"{lost_by_engine['reference']}"))


def _sharded_mc_throughput(policy: str = "bfjs",
                           workload: Workload | None = None, **policy_kw):
    """Mesh-sharded scaling of the tracked ensemble study: the SAME scan
    run with the G dimension sharded over 1, 2, 4, ... devices
    (``monte_carlo_policy(..., devices=D)`` — core.engine.sharding).

    Every sharded run must be bit-identical to the unsharded run
    (``bitmatch_vs_ref``) and truncation-free; both feed the loud exit
    gates.  On a 1-device host only the d=1 row appears — the ``devices>=4``
    family comes from CI's forced-multi-device smoke job
    (``XLA_FLAGS=--xla_force_host_platform_device_count=4``) and from full
    bench runs launched the same way."""
    if SMOKE:
        G, kw = 4, dict(L=4, K=8, Qcap=64, A_max=6, horizon=150)
    else:
        G, kw = 8, dict(L=8, K=16, Qcap=256, A_max=6, horizon=1_500)
    T = kw["horizon"]

    def sampler(key, n):
        return jax.random.uniform(key, (n,), minval=0.1, maxval=0.6)

    wl = workload if workload is not None \
        else Workload(lam=0.4, mu=0.02, sampler=sampler)
    keys = jax.random.split(jax.random.PRNGKey(7), G)
    suffix = "" if policy == "bfjs" else f"_{policy}"
    tfields = _tuning_fields(policy, "scan", dict(kw, **policy_kw),
                             wl.num_resources)

    ref = monte_carlo_policy(wl, keys, policy=policy, engine="scan",
                             **policy_kw, **kw)
    ref.queue_len.block_until_ready()
    counts = [d for d in (1, 2, 4, 8, 16)
              if d <= jax.device_count() and G % d == 0]
    for d in counts:
        def fn(d=d):
            r = monte_carlo_policy(wl, keys, policy=policy, engine="scan",
                                   devices=d, **policy_kw, **kw)
            r.queue_len.block_until_ready()
            return r
        res, us = timed_best(fn, repeat=2)
        match = int(_bitmatch(res, ref))
        trunc = int(np.asarray(res.truncated).sum())
        name = f"stability/mc_ensemble{suffix}_sharded_d{d}"
        _TRUNCATIONS.append((name, trunc))
        if not match:
            _GATE_VIOLATIONS.append(
                (name, f"sharded run (devices={d}) diverged from the "
                       "unsharded scan run"))
        row(name, us / (G * T),
            f"engine=scan;devices={d};ensembles={G};"
            f"ensemble_slots_per_sec={G * T / (us / 1e6):.0f};"
            f"per_device_slots_per_sec={G * T / d / (us / 1e6):.0f};"
            f"bitmatch_vs_ref={match};trunc={trunc};{tfields}")


def _tuned_mc_pair(policy: str = "bfjs",
                   workload: Workload | None = None, **policy_kw):
    """Autotuned vs default launch of the tracked ensemble study.

    Runs the shape-keyed autotuner (core.engine.tuning) into a THROWAWAY
    cache — never the user's — then times the signature-default launch
    against the cached ``work_steps`` winner INTERLEAVED (see
    timed_interleaved's bench-noise note).  The tuned trajectory must be
    bit-identical to the default (``bitmatch_vs_ref``) and truncation-free;
    both feed the loud exit gates — a faster-but-divergent "tuned" config
    fails the benchmark run, same as it is rejected by the autotuner."""
    if SMOKE:
        G, kw = 2, dict(L=4, K=8, Qcap=64, A_max=6, horizon=150)
        grid, rounds = (2, 4, 8), 1
    else:
        G, kw = 8, dict(L=8, K=16, Qcap=256, A_max=6, horizon=1_500)
        grid, rounds = None, 3
    T = kw["horizon"]

    def sampler(key, n):
        return jax.random.uniform(key, (n,), minval=0.1, maxval=0.6)

    wl = workload if workload is not None \
        else Workload(lam=0.4, mu=0.02, sampler=sampler)
    keys = jax.random.split(jax.random.PRNGKey(7), G)
    suffix = "" if policy == "bfjs" else f"_{policy}"

    tmp = tempfile.mkdtemp(prefix="repro-bench-tune-")
    old = os.environ.get("REPRO_TUNING_CACHE")
    os.environ["REPRO_TUNING_CACHE"] = os.path.join(tmp, "cache.json")
    try:
        tune = autotune(wl, keys, policy=policy, engine="scan",
                        work_steps_grid=grid, rounds=rounds,
                        **policy_kw, **kw)
        probe = dict(kw, **policy_kw)
        tfields = _tuning_fields(policy, "scan", probe, wl.num_resources)
    finally:
        if old is None:
            del os.environ["REPRO_TUNING_CACHE"]
        else:
            os.environ["REPRO_TUNING_CACHE"] = old
    ws = tune["work_steps"]  # None = the default won the sweep

    results = {}

    def run(label, work_steps):
        extra = {} if work_steps is None else {"work_steps": work_steps}
        results[label] = monte_carlo_policy(
            wl, keys, policy=policy, engine="scan", **extra,
            **policy_kw, **kw)
        return results[label].queue_len.block_until_ready()

    best = timed_interleaved({"default": lambda: run("default", None),
                              "tuned": lambda: run("tuned", ws)})
    match = int(_bitmatch(results["tuned"], results["default"]))
    trunc = int(np.asarray(results["tuned"].truncated).sum())
    us_d, us_t = best["default"], best["tuned"]
    row(f"stability/mc_ensemble{suffix}_scan_default", us_d / (G * T),
        f"engine=scan;devices=1;ensembles={G};work_steps=default;"
        f"ensemble_slots_per_sec={G * T / (us_d / 1e6):.0f};"
        "tuned=0;cache_hit=0")
    name = f"stability/mc_ensemble{suffix}_scan_tuned"
    _TRUNCATIONS.append((name, trunc))
    if not match:
        _GATE_VIOLATIONS.append(
            (name, f"tuned run (work_steps={ws}) diverged from the "
                   "default launch"))
    row(name, us_t / (G * T),
        f"engine=scan;devices=1;ensembles={G};work_steps={ws};"
        f"ensemble_slots_per_sec={G * T / (us_t / 1e6):.0f};"
        f"speedup_vs_default={us_d / us_t:.2f}x;bitmatch_vs_ref={match};"
        f"trunc={trunc};{tfields}")


#: trajectory fields compared by the streaming bit-match gate — the
#: backpressure counters (chunks_behind/host_stall_us) are timing
#: measurements, excluded by the streaming contract
_STREAM_TRAJ = ("queue_len", "occupancy", "departed", "dropped",
                "truncated", "preempted", "requeued", "lost")


def _streaming_mc_throughput():
    """Sustained streaming throughput of the tracked ensemble study: the
    SAME pre-generated ensemble streams fed chunk-by-chunk through
    ``core.engine.stream_policy`` with carried state
    (``stability/stream_mc_scan``), plus the ``engine="pallas"`` launch
    (``stability/stream_mc_pallas``), which degrades — loudly, by the
    streaming-carry precheck — to the bit-identical scan path: the fused
    kernels keep their simulation state in VMEM scratch and cannot export
    a cross-chunk carry (the row records ``fallback=scan``).

    Both rows are gated: the streamed trajectory must be bit-identical to
    the one-shot run (``bitmatch_vs_ref``) and truncation-free.
    ``chunks_behind``/``host_stall_us`` record the double-buffer balance —
    how often device compute finished before host chunk prep, and how
    long the driver sat blocked on the device."""
    import warnings

    from repro.core.engine import (ensemble_streams, iter_stream_chunks,
                                   run_policy_streams, stream_policy)
    from repro.kernels.common import GracefulDegradationWarning

    if SMOKE:
        G, chunk, kw = 2, 32, dict(L=4, K=8, Qcap=64, A_max=6, horizon=150)
    else:
        G, chunk, kw = 8, 128, dict(L=8, K=16, Qcap=256, A_max=6,
                                    horizon=1_500)
    T = kw["horizon"]

    def sampler(key, n):
        return jax.random.uniform(key, (n,), minval=0.1, maxval=0.6)

    wl = Workload(lam=0.4, mu=0.02, sampler=sampler)
    keys = jax.random.split(jax.random.PRNGKey(7), G)
    streams = ensemble_streams(
        wl, keys, **{k: kw[k] for k in ("L", "K", "A_max", "horizon")})
    cfg = {k: kw[k] for k in ("L", "K", "Qcap", "A_max")}
    ref = run_policy_streams(streams, policy="bfjs", engine="scan",
                             chunk=T, **cfg)
    ref.queue_len.block_until_ready()

    for engine in ("scan", "pallas"):
        def fn(engine=engine):
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", GracefulDegradationWarning)
                r = stream_policy(iter_stream_chunks(streams, chunk),
                                  policy="bfjs", engine=engine, **cfg)
            r.queue_len.block_until_ready()
            return r
        res, us = timed_best(fn, repeat=2)
        match = int(all(
            (np.asarray(getattr(res, f)) == np.asarray(getattr(ref, f)))
            .all() for f in _STREAM_TRAJ))
        trunc = int(np.asarray(res.truncated).sum())
        name = f"stability/stream_mc_{engine}"
        _TRUNCATIONS.append((name, trunc))
        if not match:
            _GATE_VIOLATIONS.append(
                (name, f"streamed trajectory (chunk={chunk}) diverged "
                       "from the one-shot run"))
        # §14 runtime auditor over the tracked trajectory: conservation,
        # occupancy <= capacity, preempted-split — a violation here is a
        # gate failure, not a footnote
        from repro.core.engine import InvariantViolation, audit_result
        try:
            audit_result(streams, res, policy="bfjs", config=dict(cfg))
            audit = "ok"
        except InvariantViolation as e:
            audit = f"VIOLATION:{e.invariant}"
            _GATE_VIOLATIONS.append((name, f"invariant audit: {e}"))
        meta = (f"ensembles={G};chunk_slots={chunk};"
                f"chunks={-(-T // chunk)};"
                f"sustained_slots_per_sec={G * T / (us / 1e6):.0f};"
                f"chunks_behind={int(res.chunks_behind)};"
                f"host_stall_us={float(res.host_stall_us):.0f};"
                f"bitmatch_vs_ref={match};trunc={trunc};"
                f"audit={audit};devices=1;"
                + _tuning_fields("bfjs", "scan", dict(cfg)))
        if engine == "pallas":
            meta += ";fallback=scan(streaming-carry-precheck)"
        row(name, us / (G * T), meta)


def _mr_workload() -> Workload:
    """Vector (cpu, mem) workload at the same operating point: U(0.1, 0.6)
    per-resource demands, rho ~ 0.9 of capacity on the binding resource."""
    def sampler(key, n):
        return jax.random.uniform(key, (n, 2), minval=0.1, maxval=0.6)
    return Workload(lam=0.4, mu=0.02, sampler=sampler, num_resources=2)


def main():
    d = Uniform(0.2, 0.9)
    for n in (0, 1, 2):
        (up, lo), us = timed(rho_bounds, d, n, 1)
        row(f"stability/theorem1_n{n}", us,
            f"rho_bar={up:.4f};rho_lower={lo:.4f};gap={lo-up:.4f}")

    eps = 0.01
    r_true = rho_star_discrete(np.array([0.5 - eps, 0.5 + eps]),
                               np.array([0.5, 0.5]), L=1)
    r_obl = rho_star_discrete(np.array([0.5, 0.5 + eps]),
                              np.array([0.5, 0.5]), L=1)
    row("stability/prop2_tightness", 0.0,
        f"rho*={r_true:.3f};oblivious={r_obl:.3f};"
        f"ratio={r_obl / r_true:.4f}(=2/3)")

    _mc_ensemble_throughput("bfjs")
    # VQS: sizes in U(0.1, 0.6) live above 2^-3, K=16 >= 2^3 packing bound
    _mc_ensemble_throughput("vqs", Qcap=2048, J=3)
    # multi-resource BF-J/S: the scan engine AND the fused Pallas kernel
    # (interpret off-TPU) against the event-driven oracle — both trunc
    # counts feed the exit-code gate, so a diverging kernel fails the run
    _mc_ensemble_throughput("bfjs-mr", workload=_mr_workload(),
                            engines=("reference", "scan", "pallas"),
                            work_steps=24)
    # VQS-BF (Theorem 4): one placement per work step, so the bound is
    # sized to the burst; trunc counts feed the same exit-code gate
    _mc_ensemble_throughput("vqs-bf", Qcap=2048, J=3,
                            engines=("reference", "scan", "pallas"),
                            work_steps=48)
    _faulted_mc_throughput()
    _streaming_mc_throughput()
    # mesh-sharded scaling + autotuned-vs-default pairs (both bit-match
    # gated); on a 1-device host the sharded family collapses to d=1
    _sharded_mc_throughput("bfjs")
    _sharded_mc_throughput("bfjs-mr", workload=_mr_workload(),
                           work_steps=24)
    _tuned_mc_pair("bfjs")
    _tuned_mc_pair("bfjs-mr", workload=_mr_workload())

    # the regression gate the early-exit work list answers: the fused
    # bfjs-mr Pallas ensemble row must beat the event-driven oracle.
    # (Gating against the vmapped scan engine turned out host-dependent:
    # XLA scan tracks raw host speed while interpret-mode Pallas is
    # dominated by Python stepping overhead, so that ratio swings several
    # x between machines.  The oracle shares the overhead profile, making
    # this floor stable — the pre-early-exit kernel sat ~1.6x ABOVE it.)
    # Skipped under SMOKE: tiny shapes time dispatch, not the kernel.
    us_by = {r["name"]: r["us"] for r in RECORDS}
    pal = us_by.get("stability/mc_ensemble_bfjs-mr_pallas")
    ref = us_by.get("stability/mc_ensemble_bfjs-mr_reference")
    if not SMOKE and pal is not None and ref is not None and pal > ref:
        _GATE_VIOLATIONS.append(
            ("stability/mc_ensemble_bfjs-mr_pallas",
             f"Pallas ensemble row trails the event-driven oracle "
             f"({pal:.0f}us vs {ref:.0f}us per slot)"))

    bad = [(name, t) for name, t in _TRUNCATIONS if t != 0]
    if bad:
        print("ERROR: engine comparisons reported truncation (trajectories "
              f"diverged from the reference): {bad}", file=sys.stderr,
              flush=True)
        raise SystemExit(1)
    if _FAULT_VIOLATIONS:
        print("ERROR: fault accounting violated (scan vs reference lost, "
              f"or preempted != requeued + lost): {_FAULT_VIOLATIONS}",
              file=sys.stderr, flush=True)
        raise SystemExit(1)
    if _GATE_VIOLATIONS:
        print("ERROR: sharded/tuned/kernel-ordering gates violated: "
              f"{_GATE_VIOLATIONS}", file=sys.stderr, flush=True)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
