"""Section-VIII extensions: multi-resource Best-Fit (Tetris alignment) and
the stalling technique for general service times."""
import numpy as np
import pytest

from repro.core import BFJS, Discrete, ServiceModel, simulate
from repro.core.multi_resource import (CollapsedMaxBFJS, MultiResourceBFJS,
                                       simulate_mr)


def anti_correlated_sampler(rng, n):
    """cpu-heavy or mem-heavy jobs: the workload where max-collapse wastes
    ~half of every server and alignment packing shines."""
    heavy = rng.uniform(0.45, 0.55, size=n)
    light = rng.uniform(0.05, 0.1, size=n)
    flip = rng.uniform(size=n) < 0.5
    cpu = np.where(flip, heavy, light)
    mem = np.where(flip, light, heavy)
    return np.stack([cpu, mem], axis=1)


def test_mr_bfjs_invariants():
    pol = MultiResourceBFJS(L=8, num_resources=2)
    res = simulate_mr(pol, lam=0.5, demand_sampler=anti_correlated_sampler,
                      mean_service=20.0, horizon=2000, seed=0)
    assert (pol.occupied <= 1.0 + 1e-9).all()
    assert (pol.occupied >= -1e-9).all()
    assert res.departed > 0
    in_service = sum(len(s) for s in pol.jobs)
    assert res.arrived == res.departed + in_service + res.final_queue


def test_alignment_beats_max_collapse():
    """Paper §VIII: the inner-product score packs complementary jobs
    together; max-collapse treats every job as its largest dimension and
    cannot, so its queue blows up at loads alignment sustains."""
    # offered load per resource ~0.54 for alignment; max-collapse reserves
    # max(cpu, mem) in BOTH dims, so its effective load is ~0.94 — the
    # regime the paper's preprocessing wastes and Section VIII recovers.
    lam, svc, H = 0.3, 25.0, 10_000
    align = simulate_mr(MultiResourceBFJS(L=4, num_resources=2), lam,
                        anti_correlated_sampler, svc, H, seed=3)
    collapse = simulate_mr(CollapsedMaxBFJS(L=4, num_resources=2), lam,
                           anti_correlated_sampler, svc, H, seed=3)
    assert align.mean_queue_tail < 0.5 * collapse.mean_queue_tail, (
        align.mean_queue_tail, collapse.mean_queue_tail)
    assert align.mean_queue_tail < 50  # genuinely stable, not just better


def test_stalling_under_fixed_service():
    """Fig-3b regime (fixed service; plain BF-J/S locks into a mixed
    packing and drifts): stalling forces drain epochs — queues must not be
    (much) worse, and the stall path must preserve all invariants."""
    dist = Discrete([0.2, 0.5], [2 / 3, 1 / 3])
    svc = ServiceModel("fixed", 100.0)
    plain = simulate(BFJS(), L=1, lam=0.0306, dist=dist, service=svc,
                     horizon=150_000, seed=7, check_invariants=True)
    stall = simulate(BFJS(stall=True), L=1, lam=0.0306, dist=dist,
                     service=svc, horizon=150_000, seed=7,
                     check_invariants=True)
    assert stall.departed > 0
    # stalling trades short-term utilization for renewal epochs; it must
    # keep the system within the same order of magnitude at worst
    assert stall.mean_queue_tail < 10 * max(plain.mean_queue_tail, 1.0)


def test_stall_flag_name():
    assert BFJS().name == "bf-js"
    assert BFJS(stall=True).name == "bf-js-stall"
