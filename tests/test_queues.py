"""Fenwick / SegTree / SortedJobQueue / VirtualQueues exactness."""
import numpy as np
import pytest

# deselected by the fast tier-1 lane (-m "not slow"); CI runs
# the full suite
pytestmark = pytest.mark.slow

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.fenwick import Fenwick, SegTreeMax
from repro.core.partition import PartitionI
from repro.core.queues import Job, SortedJobQueue, VirtualQueues
from repro.core.quantize import RES


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 1023), st.sampled_from([1, -1])),
                min_size=1, max_size=200))
def test_fenwick_vs_naive(ops):
    fen = Fenwick(1024)
    counts = np.zeros(1024, dtype=int)
    for key, delta in ops:
        if delta < 0 and counts[key] == 0:
            continue
        fen.add(key, delta)
        counts[key] += delta
        present = np.nonzero(counts)[0]
        for probe in (0, key, 511, 1023):
            exp_leq = present[present <= probe]
            assert fen.max_leq(probe) == (exp_leq[-1] if len(exp_leq) else -1)
            exp_geq = present[present >= probe]
            assert fen.min_geq(probe) == (exp_geq[0] if len(exp_geq) else -1)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 100), min_size=1, max_size=64),
       st.lists(st.tuples(st.integers(0, 63), st.integers(0, 100)),
                max_size=32),
       st.integers(0, 100))
def test_segtree_first_fit(init, updates, probe):
    vals = np.asarray(init, dtype=np.int64)
    seg = SegTreeMax(vals)
    for idx, v in updates:
        if idx < len(vals):
            vals[idx] = v
            seg.update(idx, v)
    hits = np.nonzero(vals >= probe)[0]
    assert seg.first_fit(probe) == (hits[0] if len(hits) else -1)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(1, RES), min_size=1, max_size=80),
       st.lists(st.integers(1, RES), min_size=1, max_size=40))
def test_sorted_queue_pop_largest(pushes, caps):
    q = SortedJobQueue()
    naive: list[int] = []
    for i, s in enumerate(pushes):
        q.push(Job(i, s, s, -1, 0))
        naive.append(s)
    for cap in caps:
        got = q.pop_largest_leq(cap)
        fits = [s for s in naive if s <= cap]
        if not fits:
            assert got is None
        else:
            expect = max(fits)
            assert got is not None and got.eff_size == expect
            naive.remove(expect)
    assert len(q) == len(naive)


def test_virtual_queues_fifo_and_sorted_views():
    vqs = VirtualQueues(3)
    part = PartitionI(3)
    sizes = [30000, 28000, 32000, 29000]  # all in I_2 = (1/3, 1/2]
    t = part.type_of_scalar(sizes[0])
    jobs = []
    for i, s in enumerate(sizes):
        assert part.type_of_scalar(s) == t
        j = Job(i, s, s, t, 0)
        jobs.append(j)
        vqs.push(j)
    assert vqs.sizes[t] == 4
    # FIFO head is the first pushed
    assert vqs.head(t).jid == 0
    # largest-fit pops 33000 first
    got = vqs.pop_largest_leq(t, RES)
    assert got.jid == 2
    # FIFO view skips the lazily-deleted job
    assert vqs.pop_head(t).jid == 0
    assert vqs.head(t).jid == 1
    # global sweep finds the remaining largest
    got = vqs.pop_largest_leq_any(RES)
    assert got.jid == 3
    assert len(vqs) == 1
