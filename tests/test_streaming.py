"""Streaming driver (core/engine/streaming.py): bit-match invariant, the
unbounded-generator path, checkpoint round-trips, double-buffer
determinism and backpressure counters.

The tentpole invariant: streaming replay of any finite trace equals the
one-shot ``run_policy_streams`` run BIT-FOR-BIT, for every policy x
engine, under any chunking.  The trace matrix covers every registered
policy on its trace-legal stream shapes — vqs on the collapsed fixture,
bfjs-mr on both the collapsed (R=1) and uncollapsed (cpu, mem) fixtures —
and bfjs on synthetic ``make_streams`` streams (the single-resource
BF-J/S engines statically reject trace-shaped streams everywhere, one-shot
included: a trace has no sequential-duration region, see
``core.engine.streams``).  ``engine="pallas"`` goes through the
streaming-carry precheck: loud GracefulDegradationWarning, then the
bit-identical scan path.
"""
import os
import time

import jax
import numpy as np
import pytest

from repro.core import load_trace_csv
from repro.core.engine import (make_streams, run_policy_streams,
                               streams_from_trace)
from repro.core.engine.streaming import (iter_stream_chunks, stream_policy,
                                         stream_chunks_from_trace)
from repro.kernels.common import GracefulDegradationWarning

FIXTURE = os.path.join(os.path.dirname(__file__), "data",
                       "google_like_50.csv")

#: trajectory fields compared bit-for-bit (the backpressure counters are
#: timing measurements, excluded by contract)
_TRAJ = ("queue_len", "occupancy", "departed", "dropped", "truncated",
         "preempted", "requeued", "lost")


def assert_bitmatch(a, b, ctx=""):
    for f in _TRAJ:
        x, y = getattr(a, f), getattr(b, f)
        assert (x is None) == (y is None), (ctx, f)
        if x is not None:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=f"{ctx}: {f}")


def _trace_streams(collapse):
    trace = load_trace_csv(FIXTURE, slot_seconds=10.0)
    return streams_from_trace(trace, collapse=collapse)


def _synth_streams(horizon=40, fault_rate=0.0):
    return make_streams(
        jax.random.PRNGKey(7), lam=1.3, mu=0.08,
        sampler=lambda k, s: jax.random.uniform(k, s, minval=0.1,
                                                maxval=0.7),
        L=4, K=5, A_max=4, horizon=horizon,
        **({"fault_rate": fault_rate, "repair_rate": 0.3}
           if fault_rate else {}))


_CFG = dict(L=4, K=5, Qcap=48)


def _chunk_sizes(T):
    # 1, a prime, exactly T, and past T (single chunk)
    return (1, 7, T, T + 13)


@pytest.mark.parametrize("policy,collapse,extra", [
    ("vqs", True, {"J": 3}),
    ("bfjs-mr", True, {}),
    ("bfjs-mr", False, {}),
])
@pytest.mark.parametrize("engine", ["scan", "pallas"])
def test_trace_replay_bitmatch_all_chunkings(policy, collapse, extra,
                                             engine):
    """google_like_50.csv (collapsed and uncollapsed): streaming == one-
    shot for every trace-legal policy, both engines, 4 chunk sizes."""
    streams = _trace_streams(collapse)
    T = int(streams.n.shape[0])
    A_max = int(streams.sizes.shape[1])
    cfg = dict(_CFG, A_max=A_max, **extra)
    one = run_policy_streams(streams, policy=policy, engine="scan", **cfg)
    for chunk in _chunk_sizes(T):
        if engine == "pallas":
            with pytest.warns(GracefulDegradationWarning,
                              match="streaming|carry"):
                res = stream_policy(iter_stream_chunks(streams, chunk),
                                    policy=policy, engine="pallas", **cfg)
        else:
            res = stream_policy(iter_stream_chunks(streams, chunk),
                                policy=policy, **cfg)
        assert_bitmatch(one, res, f"{policy}/{engine}/chunk={chunk}")
        assert res.chunks_behind is not None
        assert res.host_stall_us is not None


@pytest.mark.parametrize("fault_rate", [0.0, 0.05])
def test_bfjs_synthetic_bitmatch_all_chunkings(fault_rate):
    """bfjs needs make_streams-shaped streams (sequential duration lanes);
    parity holds chunked, including through a fault plane carried in the
    streaming state."""
    streams = _synth_streams(fault_rate=fault_rate)
    T = int(streams.n.shape[0])
    cfg = dict(_CFG, A_max=4)
    one = run_policy_streams(streams, policy="bfjs", engine="scan", **cfg)
    for chunk in (1, 7, T):
        res = stream_policy(iter_stream_chunks(streams, chunk),
                            policy="bfjs", **cfg)
        assert_bitmatch(one, res, f"bfjs/chunk={chunk}/fault={fault_rate}")


def test_pallas_strict_refuses_instead_of_degrading():
    streams = _synth_streams()
    with pytest.raises(ValueError, match="carry"):
        stream_policy(iter_stream_chunks(streams, 10), policy="bfjs",
                      engine="pallas", strict=True, **dict(_CFG, A_max=4))


def test_reference_engine_rejected():
    streams = _synth_streams()
    with pytest.raises(ValueError, match="host-side state"):
        stream_policy(iter_stream_chunks(streams, 10), policy="bfjs",
                      engine="reference", **dict(_CFG, A_max=4))


def test_stream_chunks_from_trace_rebuckets_rows_to_slots():
    """Row-chunked Trace pieces (the CSV reader's natural chunking)
    re-bucket into fixed-slot SchedStreams windows that slice-match the
    one-shot streams — empty windows included (slot gaps must advance
    time)."""
    trace = load_trace_csv(FIXTURE, slot_seconds=10.0)
    one = streams_from_trace(trace, collapse=False)
    T = int(one.n.shape[0])
    A_max = int(one.sizes.shape[1])

    def row_chunks(rows):
        from repro.core import Trace
        for lo in range(0, len(trace), rows):
            sl = slice(lo, lo + rows)
            yield Trace(trace.arrival_slots[sl], trace.cpu[sl],
                        trace.mem[sl], trace.durations[sl])

    for rows, chunk_slots in [(3, 5), (10, 1), (50, 11), (7, 64)]:
        got = list(stream_chunks_from_trace(
            row_chunks(rows), chunk_slots=chunk_slots, A_max=A_max,
            collapse=False))
        want = list(iter_stream_chunks(one, chunk_slots))
        assert len(got) == len(want), (rows, chunk_slots)
        for i, (g, w) in enumerate(zip(got, want)):
            np.testing.assert_array_equal(np.asarray(g.n), np.asarray(w.n))
            np.testing.assert_array_equal(np.asarray(g.sizes),
                                          np.asarray(w.sizes),
                                          err_msg=f"{rows}/{chunk_slots}/"
                                                  f"window {i}")
            np.testing.assert_array_equal(np.asarray(g.durs),
                                          np.asarray(w.durs))


def test_infinite_generator_bounded_memory_and_checkpoint_roundtrip(
        tmp_path):
    """An endless chunk generator: stop after N chunks, round-trip the
    carried state through checkpoint_dir=, resume for N more — equal to a
    straight 2N-chunk run.  trajectory="tail" keeps only the newest
    chunk's planes (bounded host memory)."""
    CHUNK_T, N = 8, 5
    cfg = dict(_CFG, A_max=4)

    # deterministic endless source: a long sliced prefix, then fresh
    # synthetic chunks forever (every call replays the same sequence)
    def chunks_forever():
        base = _synth_streams(horizon=CHUNK_T * (2 * N + 3))
        for piece in iter_stream_chunks(base, CHUNK_T):
            yield piece
        while True:  # pad on forever with fresh synthetic chunks
            yield _synth_streams(horizon=CHUNK_T)

    ck = tmp_path / "stream_ck"
    first = stream_policy(chunks_forever(), policy="bfjs",
                          checkpoint_dir=str(ck), stop_after_chunks=N,
                          **cfg)
    assert int(np.asarray(first.queue_len).shape[0]) == N * CHUNK_T
    resumed = stream_policy(chunks_forever(), policy="bfjs",
                            checkpoint_dir=str(ck), resume=True,
                            stop_after_chunks=N, **cfg)
    straight = stream_policy(chunks_forever(), policy="bfjs",
                             stop_after_chunks=2 * N, **cfg)
    assert_bitmatch(straight, resumed, "resume-vs-straight")
    # >= 20 chunks with tail trajectory: per-slot planes stay one chunk
    # wide no matter how long the run
    tail = stream_policy(chunks_forever(), policy="bfjs",
                         stop_after_chunks=22, trajectory="tail", **cfg)
    assert int(np.asarray(tail.queue_len).shape[0]) == CHUNK_T
    # cumulative counters survive the tail cut: departed keeps its global
    # offset, matching the straight run's final value at the same chunk
    straight22 = stream_policy(chunks_forever(), policy="bfjs",
                               stop_after_chunks=22, **cfg)
    assert int(tail.departed[-1]) == int(straight22.departed[-1])
    assert int(tail.dropped) == int(straight22.dropped)


def test_resume_rejects_a_different_stream(tmp_path):
    cfg = dict(_CFG, A_max=4)
    streams = _synth_streams()
    ck = tmp_path / "ck"
    stream_policy(iter_stream_chunks(streams, 10), policy="bfjs",
                  checkpoint_dir=str(ck), stop_after_chunks=2, **cfg)
    other = make_streams(
        jax.random.PRNGKey(99), lam=1.3, mu=0.08,
        sampler=lambda k, s: jax.random.uniform(k, s, minval=0.1,
                                                maxval=0.7),
        L=4, K=5, A_max=4, horizon=40)
    with pytest.raises(ValueError, match="different stream"):
        stream_policy(iter_stream_chunks(other, 10), policy="bfjs",
                      checkpoint_dir=str(ck), resume=True, **cfg)


def test_double_buffer_determinism_slow_vs_fast_host():
    """Results are independent of host prep timing: a source that stalls
    between chunks (device finishes first every time) bit-matches an
    instant one (host finishes first) — only the backpressure counters may
    differ."""
    streams = _synth_streams()
    cfg = dict(_CFG, A_max=4)

    def slow_chunks():
        for piece in iter_stream_chunks(streams, 8):
            time.sleep(0.02)
            yield piece

    fast = stream_policy(iter_stream_chunks(streams, 8), policy="bfjs",
                         **cfg)
    slow = stream_policy(slow_chunks(), policy="bfjs", **cfg)
    assert_bitmatch(fast, slow, "slow-vs-fast host")
    for res in (fast, slow):
        assert int(res.chunks_behind) >= 0
        assert float(res.host_stall_us) >= 0.0


def test_backpressure_counters_only_on_streaming_results():
    streams = _synth_streams()
    one = run_policy_streams(streams, policy="bfjs", engine="scan",
                             **dict(_CFG, A_max=4))
    assert one.chunks_behind is None and one.host_stall_us is None


def test_streaming_error_paths():
    streams = _synth_streams()
    cfg = dict(_CFG, A_max=4)
    with pytest.raises(ValueError, match="empty"):
        stream_policy(iter([]), policy="bfjs", **cfg)
    with pytest.raises(ValueError, match="trajectory"):
        stream_policy(iter_stream_chunks(streams, 8), policy="bfjs",
                      trajectory="middle", **cfg)
    with pytest.raises(ValueError, match="no stateful scan engine"):
        stream_policy(iter_stream_chunks(streams, 8), policy="nope",
                      **cfg)
    # chunks must keep one shape for the life of the stream
    wider = streams._replace(
        sizes=np.concatenate([np.asarray(streams.sizes),
                              np.zeros_like(streams.sizes[:, :1])], axis=1),
        durs=np.concatenate([np.asarray(streams.durs),
                             np.ones_like(streams.durs[:, :1])], axis=1))
    def mixed():
        yield next(iter_stream_chunks(streams, 8))
        yield next(iter_stream_chunks(wider, 8))
    with pytest.raises(ValueError, match="changed shape mid-stream"):
        stream_policy(mixed(), policy="bfjs", **cfg)


def test_ensemble_streams_stream_chunked():
    """Ensemble-batched chunks (leading G axis) stream with the vmapped
    stateful runner and bit-match the one-shot ensemble run."""
    from repro.core.engine.sharding import ensemble_streams
    from repro.core.engine import Workload
    keys = jax.random.split(jax.random.PRNGKey(3), 3)
    wl = Workload(lam=1.3, mu=0.08,
                  sampler=lambda k, s: jax.random.uniform(k, s, minval=0.1,
                                                          maxval=0.7))
    streams = ensemble_streams(wl, keys, L=4, K=5, A_max=4, horizon=24)
    cfg = dict(_CFG, A_max=4)
    one = run_policy_streams(streams, policy="bfjs", engine="scan",
                             chunk=24, **cfg)
    res = stream_policy(iter_stream_chunks(streams, 8), policy="bfjs",
                        **cfg)
    assert_bitmatch(one, res, "ensemble")
