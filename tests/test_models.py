"""Per-arch smoke tests (reduced configs) + decode/forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.registry import applicable_shapes, supports_long_context
from repro.models import model as M


def _batch(cfg, B, S, key):
    if cfg.input_mode == "tokens":
        return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
                "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    return {"embeds": jax.random.normal(key, (B, S, cfg.d_model),
                                        jnp.float32) * 0.02,
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one SGD-free grad step on CPU: shapes + no NaNs."""
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    B, S = 2, 32
    batch = _batch(cfg, B, S, jax.random.PRNGKey(1))
    logits, aux = jax.jit(lambda p, b: M.forward(
        p, cfg, tokens=b.get("tokens"), embeds=b.get("embeds")))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert jnp.isfinite(logits.astype(jnp.float32)).all()
    (loss, _), grads = jax.jit(jax.value_and_grad(
        lambda p, b: M.loss_fn(p, cfg, b), has_aux=True))(params, batch)
    assert jnp.isfinite(loss)
    gnorm = sum(jnp.sum(jnp.abs(g)) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, C = 2, 32
    caches = M.init_cache(cfg, B, C)
    if cfg.input_mode == "tokens":
        tok = jnp.ones((B, 1), jnp.int32)
    else:
        tok = jnp.ones((B, 1, cfg.d_model), jnp.float32) * 0.01
    step = jax.jit(lambda p, t, pos, c: M.decode_step(p, cfg, t, pos, c))
    for pos in range(3):
        logits, caches = step(params, tok, jnp.asarray(pos, jnp.int32), caches)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert jnp.isfinite(logits.astype(jnp.float32)).all()


@pytest.mark.parametrize("arch", ["llama3-8b", "h2o-danube-3-4b",
                                  "deepseek-v2-lite-16b", "mamba2-130m"])
def test_decode_matches_forward(arch):
    """Token-by-token decode must reproduce the full forward logits —
    this validates KV/latent/SSM cache correctness end to end.
    capacity_factor is raised so MoE capacity dropping (a batched-prefill
    training-time behaviour) cannot diverge from dropless decode."""
    cfg = get_smoke_config(arch).with_(dtype="float32", capacity_factor=16.0)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 16
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S), 1,
                              cfg.vocab_size)
    full_logits, _ = M.forward(params, cfg, tokens=toks)

    caches = M.init_cache(cfg, B, S)
    step = jax.jit(lambda p, t, pos, c: M.decode_step(p, cfg, t, pos, c))
    outs = []
    for i in range(S):
        logits, caches = step(params, toks[:, i : i + 1],
                              jnp.asarray(i, jnp.int32), caches)
        outs.append(logits[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits),
                               atol=2e-3, rtol=2e-3)


def test_param_counts_match_brief():
    expected = {
        "mamba2-130m": 0.13, "jamba-1.5-large-398b": 398.0,
        "deepseek-v2-lite-16b": 16.0, "dbrx-132b": 132.0,
        "mistral-large-123b": 123.0, "llama3-8b": 8.0,
        "h2o-danube-3-4b": 4.0, "qwen2-72b": 72.0,
        "llava-next-mistral-7b": 7.0, "musicgen-medium": 1.5,
    }
    for arch, target in expected.items():
        n = get_config(arch).param_counts()["total"] / 1e9
        assert abs(n - target) / target < 0.25, (arch, n, target)


def test_long_context_applicability():
    runs_long = {a for a in ARCH_IDS
                 if supports_long_context(get_config(a))}
    assert runs_long == {"mamba2-130m", "jamba-1.5-large-398b",
                         "h2o-danube-3-4b"}
    for a in ARCH_IDS:
        shapes = {s.name for s in applicable_shapes(get_config(a))}
        assert {"train_4k", "prefill_32k", "decode_32k"} <= shapes


def test_chunked_attention_matches_ref():
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.models.attention import chunked_attention
    B, H, KV, S, hd = 2, 4, 2, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    # chunked_attention is MHA-only by design: GQA callers expand K/V
    ke = jnp.repeat(k, H // KV, axis=2)
    ve = jnp.repeat(v, H // KV, axis=2)
    out = chunked_attention(q, ke, ve, causal=True, q_chunk=32, kv_chunk=32)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=True)
    np.testing.assert_allclose(out.transpose(0, 2, 1, 3), ref,
                               atol=2e-5, rtol=1e-4)


def test_moe_capacity_drop_rate_reported():
    from repro.models.moe import moe_apply, moe_init
    cfg = get_smoke_config("dbrx-132b")
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    y, aux = moe_apply(params, x, cfg)
    assert y.shape == x.shape
    assert 0.0 <= float(aux["moe_drop_rate"]) <= 1.0
    assert float(aux["moe_aux_loss"]) > 0
