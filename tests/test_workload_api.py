"""Workload-first engine API: spec validation, registry error paths, and
the bit-match contract of the deprecated loose-argument shims."""
import warnings

import jax
import numpy as np
import pytest

from repro.core.engine import (PolicySpec, Workload, available_policies,
                               get_policy, monte_carlo_policy,
                               register_policy, run_policy)


def _uniform_sampler(lo, hi):
    def sampler(key, n):
        return jax.random.uniform(key, (n,), minval=lo, maxval=hi)
    return sampler


def _vec_sampler(lo, hi, R):
    def sampler(key, n):
        return jax.random.uniform(key, (n, R), minval=lo, maxval=hi)
    return sampler


# ---------------------------------------------------------------------------
# Workload validation
# ---------------------------------------------------------------------------
def test_workload_normalizes_capacity():
    wl = Workload(lam=1.0, mu=0.01, sampler=_uniform_sampler(0.1, 0.5))
    assert wl.capacity == (1.0,)
    wl2 = Workload(lam=1.0, mu=0.01, sampler=_vec_sampler(0.1, 0.5, 2),
                   num_resources=2, capacity=0.5)
    assert wl2.capacity == (0.5, 0.5)
    assert wl2.mean_service == 100.0


@pytest.mark.parametrize("kw,match", [
    (dict(lam=-1.0), "lam"),
    (dict(mu=0.0), "mu"),
    (dict(mu=1.5), "mu"),
    (dict(num_resources=0), "num_resources"),
    (dict(capacity=(1.0, 1.0)), "capacity"),
    (dict(capacity=0.0), "capacity"),
    (dict(capacity=-2.0), "capacity"),
])
def test_workload_rejects_bad_fields(kw, match):
    base = dict(lam=1.0, mu=0.01, sampler=_uniform_sampler(0.1, 0.5))
    base.update(kw)
    with pytest.raises(ValueError, match=match):
        Workload(**base)


def test_workload_sampler_shape_mismatch_caught():
    """A scalar sampler on an R=2 workload (and vice versa) fails at the
    API boundary with a shape message, not deep inside a scan."""
    wl = Workload(lam=1.0, mu=0.01, sampler=_uniform_sampler(0.1, 0.5),
                  num_resources=2, capacity=(1.0, 1.0))
    with pytest.raises(ValueError, match="does not match num_resources"):
        wl.check_sampler()
    wl2 = Workload(lam=1.0, mu=0.01, sampler=_vec_sampler(0.1, 0.5, 2))
    with pytest.raises(ValueError, match="does not match num_resources"):
        wl2.check_sampler()
    # the entry points call check_sampler themselves
    with pytest.raises(ValueError, match="does not match num_resources"):
        run_policy(wl2, policy="bfjs", key=jax.random.PRNGKey(0),
                   L=2, K=4, Qcap=16, A_max=3, horizon=10)


def test_single_resource_policies_reject_vector_workloads():
    wl = Workload(lam=1.0, mu=0.01, sampler=_vec_sampler(0.1, 0.5, 2),
                  num_resources=2)
    for policy in ("bfjs", "vqs"):
        with pytest.raises(ValueError, match="bfjs-mr"):
            run_policy(wl, policy=policy, key=jax.random.PRNGKey(0),
                       L=2, K=4, Qcap=16, A_max=3, horizon=10)


# ---------------------------------------------------------------------------
# registry error paths
# ---------------------------------------------------------------------------
def test_register_policy_rejects_duplicates():
    spec = get_policy("bfjs")
    with pytest.raises(ValueError, match="already registered"):
        register_policy(spec)
    # a fresh name registers fine and is then also a duplicate
    tmp = PolicySpec(name="_test_tmp_policy", run=spec.run,
                     run_streams=spec.run_streams,
                     monte_carlo=spec.monte_carlo)
    register_policy(tmp)
    try:
        assert "_test_tmp_policy" in available_policies()
        with pytest.raises(ValueError, match="already registered"):
            register_policy(tmp)
    finally:
        from repro.core.engine.api import _POLICIES
        del _POLICIES["_test_tmp_policy"]


def test_unknown_policy_and_engine_names():
    wl = Workload(lam=1.0, mu=0.01, sampler=_uniform_sampler(0.1, 0.5))
    with pytest.raises(ValueError, match="unknown policy"):
        run_policy(wl, policy="nope", key=jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="unknown engine"):
        run_policy(wl, engine="nope", key=jax.random.PRNGKey(0))
    assert set(available_policies()) >= {"bfjs", "bfjs-mr", "vqs"}


def test_run_policy_rejects_mixed_forms():
    wl = Workload(lam=1.0, mu=0.01, sampler=_uniform_sampler(0.1, 0.5))
    with pytest.raises(TypeError, match="positional"):
        run_policy(wl, 1.0, 0.01, wl.sampler)
    with pytest.raises(TypeError, match="keys"):
        monte_carlo_policy(wl, policy="bfjs")


def test_run_policy_positional_key_mirrors_keyword():
    """run_policy(wl, key, ...) and run_policy(wl, key=key, ...) are the
    same call — positional key parity with monte_carlo_policy(wl, keys)."""
    wl = Workload(lam=1.0, mu=0.02, sampler=_uniform_sampler(0.1, 0.6))
    key = jax.random.PRNGKey(13)
    kw = dict(L=4, K=6, Qcap=48, A_max=5, horizon=100)
    a = run_policy(wl, key, policy="bfjs", **kw)
    b = run_policy(wl, policy="bfjs", key=key, **kw)
    for field in a._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, field)),
                                      np.asarray(getattr(b, field)))
    with pytest.raises(TypeError, match="exactly one"):
        run_policy(wl, key, key=key)


# ---------------------------------------------------------------------------
# deprecation shims: warn AND bit-match
# ---------------------------------------------------------------------------
def test_legacy_run_policy_warns_and_bitmatches():
    sampler = _uniform_sampler(0.1, 0.6)
    key = jax.random.PRNGKey(11)
    kw = dict(L=4, K=6, Qcap=48, A_max=5, horizon=150)
    with pytest.warns(DeprecationWarning, match="Workload"):
        old = run_policy(key, 1.0, 0.02, sampler, policy="bfjs",
                         engine="scan", **kw)
    wl = Workload(lam=1.0, mu=0.02, sampler=sampler)
    new = run_policy(wl, policy="bfjs", engine="scan", key=key, **kw)
    for field in old._fields:
        np.testing.assert_array_equal(np.asarray(getattr(old, field)),
                                      np.asarray(getattr(new, field)))


def test_legacy_monte_carlo_policy_warns_and_bitmatches():
    sampler = _uniform_sampler(0.1, 0.6)
    keys = jax.random.split(jax.random.PRNGKey(7), 3)
    kw = dict(J=2, L=3, K=8, Qcap=64, A_max=4, horizon=100)
    with pytest.warns(DeprecationWarning, match="Workload"):
        old = monte_carlo_policy(keys, 0.8, 0.02, sampler, policy="vqs",
                                 engine="scan", **kw)
    wl = Workload(lam=0.8, mu=0.02, sampler=sampler)
    new = monte_carlo_policy(wl, keys, policy="vqs", engine="scan", **kw)
    for field in old._fields:
        np.testing.assert_array_equal(np.asarray(getattr(old, field)),
                                      np.asarray(getattr(new, field)))


# ---------------------------------------------------------------------------
# serving planner: engine= knob mirrors policy=
# ---------------------------------------------------------------------------
def test_estimate_capacity_engine_knob():
    from repro.serving.engine import estimate_capacity

    kw = dict(ensembles=2, horizon=200, K=8, Qcap=64, A_max=4)
    scan = estimate_capacity(3, 0.2, 20.0, engine="scan", seed=5, **kw)
    ref = estimate_capacity(3, 0.2, 20.0, engine="reference", seed=5, **kw)
    assert scan["engine"] == "scan" and ref["engine"] == "reference"
    # same seed, same streams contract: the planner's numbers agree
    assert scan["mean_tail_queue"] == ref["mean_tail_queue"]
    assert scan["mean_occupancy"] == ref["mean_occupancy"]
    assert scan["dropped"] == ref["dropped"] == 0
    assert scan["truncated"] == 0


def test_estimate_capacity_explicit_workload():
    from repro.serving.engine import estimate_capacity

    wl = Workload(lam=0.4, mu=0.02, sampler=_vec_sampler(0.05, 0.4, 2),
                  num_resources=2)
    out = estimate_capacity(3, lam=999.0, mean_service_slots=1.0,
                            workload=wl, policy="bfjs-mr", ensembles=2,
                            horizon=150, K=8, Qcap=64, A_max=4)
    assert out["policy"] == "bfjs-mr"
    assert out["slots_simulated"] == 300
