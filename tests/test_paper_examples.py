"""The paper's Section VII empirical claims, as assertions.

Horizons are scaled down for CI but chosen so the stable/unstable gap is
unambiguous (queue ratios >> 2x).  Full-horizon runs live in benchmarks/.
"""
import pytest

from repro.core import (BFJS, Discrete, ServiceModel, Uniform, VQS, VQSBF,
                        simulate)

H = 150_000


@pytest.fixture(scope="module")
def fig3a_results():
    dist = Discrete([0.4, 0.6], [0.5, 0.5])
    svc = ServiceModel("geometric", 100.0)
    out = {}
    for mk, name in ((BFJS, "bf-js"), (lambda: VQS(J=2), "vqs"),
                     (lambda: VQSBF(J=2), "vqs-bf")):
        out[name] = simulate(mk() if callable(mk) else mk, L=1, lam=0.014,
                             dist=dist, service=svc, horizon=H, seed=11)
    return out


def test_fig3a_vqs_unstable_bf_stable(fig3a_results):
    """Fig 3a: rate 0.014 > (2/3)*0.02 => VQS diverges; BF-J/S and VQS-BF
    support it (rho = 1.4 < 2 = rho*)."""
    r = fig3a_results
    assert r["vqs"].mean_queue_tail > 5 * r["bf-js"].mean_queue_tail
    assert r["vqs"].mean_queue_tail > 5 * r["vqs-bf"].mean_queue_tail
    assert r["bf-js"].final_queue < 40
    assert r["vqs-bf"].final_queue < 40
    # VQS queue keeps growing (first-half mean << second-half mean)
    q = r["vqs"].queue_lens
    assert q[-len(q) // 4:].mean() > 1.5 * q[: len(q) // 4].mean()


def test_fig3b_vqs_stable_bf_unstable():
    """Fig 3b: fixed service 100, sizes 0.2/0.5 (2:1), rate 0.0306: VQS
    stays stable; BF-J/S drifts (lock-in to the (2,1) mixed packing)."""
    dist = Discrete([0.2, 0.5], [2 / 3, 1 / 3])
    svc = ServiceModel("fixed", 100.0)
    vqs = simulate(VQS(J=3), L=1, lam=0.0306, dist=dist, service=svc,
                   horizon=400_000, seed=7)
    bf = simulate(BFJS(), L=1, lam=0.0306, dist=dist, service=svc,
                  horizon=400_000, seed=7)
    assert vqs.mean_queue_tail < 60
    # BF-J/S queue grows roughly linearly once locked in
    q = bf.queue_lens
    assert q[-len(q) // 4:].mean() > 2.0 * q[: len(q) // 4].mean()
    assert bf.mean_queue_tail > 2 * vqs.mean_queue_tail


def test_bfjs_meets_half_guarantee_uniform():
    """Theorem 2 sanity: BF-J/S stable at rho = 0.9 * (rho*/2) for a
    continuous distribution (uniform [0.1, 0.9], L=3)."""
    dist = Uniform(0.1, 0.9)
    svc = ServiceModel("geometric", 50.0)
    # rho* <= L/mean = 6; run at rho = 2.7 = 0.9 * 3
    lam = 2.7 / 50.0
    res = simulate(BFJS(), L=3, lam=lam, dist=dist, service=svc,
                   horizon=60_000, seed=13)
    assert res.final_queue < 60
    assert res.mean_queue_tail < 60


def test_vqsbf_beats_vqs_delay_uniform():
    """Section VII.A.3: VQS has clearly worse delay than VQS-BF on
    uniform [0.1, 0.9] at high traffic."""
    dist = Uniform(0.1, 0.9)
    svc = ServiceModel("geometric", 100.0)
    lam = 0.88 * 5 / 0.5 / 100.0     # alpha = 0.88, L = 5
    vqs = simulate(VQS(J=4), L=5, lam=lam, dist=dist, service=svc,
                   horizon=60_000, seed=3)
    vqsbf = simulate(VQSBF(J=4), L=5, lam=lam, dist=dist, service=svc,
                     horizon=60_000, seed=3)
    assert vqsbf.mean_queue_tail < vqs.mean_queue_tail
