"""Checkpoint, trainer fault-tolerance, sharding rules, HLO analysis,
data-pipeline determinism."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, batch_at
from repro.distributed.hlo_analysis import collective_stats, shape_bytes
from repro.train.trainer import PreemptionError, Trainer, TrainerConfig

CKPT_DIR = "/tmp/repro_test_ckpt"


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_bitwise(tmp_path):
    state = {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
             "nested": {"b": np.float32(3.5),
                        "c": np.arange(5, dtype=np.int64)}}
    ckpt.save(str(tmp_path), 7, state)
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(np.shape(a),
                                                       np.asarray(a).dtype),
                        state)
    out = ckpt.restore(str(tmp_path), 7, like)
    for x, y in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    m = ckpt.read_manifest(str(tmp_path), 7)
    assert m["step"] == 7 and m["num_arrays"] == 3


def test_checkpoint_gc_and_latest(tmp_path):
    cp = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        cp.save(s, {"x": np.full(4, s, np.float32)})
        cp.wait()
    assert ckpt.list_steps(str(tmp_path)) == [3, 4]
    assert ckpt.latest_step(str(tmp_path)) == 4


def test_checkpoint_restore_with_sharding(tmp_path):
    """Elastic path: stored logical arrays restore under any sharding."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    state = {"w": np.arange(16, dtype=np.float32).reshape(4, 4)}
    ckpt.save(str(tmp_path), 1, state)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    like = {"w": jax.ShapeDtypeStruct((4, 4), np.float32)}
    out = ckpt.restore(str(tmp_path), 1, like, sh)
    np.testing.assert_array_equal(np.asarray(out["w"]), state["w"])


# ---------------------------------------------------------------------------
# trainer fault tolerance
# ---------------------------------------------------------------------------
def test_preempt_resume_is_bitwise_deterministic():
    shutil.rmtree(CKPT_DIR, ignore_errors=True)
    cfg = get_smoke_config("llama3-8b")
    base = dict(seq_len=32, global_batch=2, steps=8, ckpt_every=4,
                log_every=100)
    # uninterrupted run
    t_ref = Trainer(cfg, TrainerConfig(**base, ckpt_dir=CKPT_DIR + "_ref"))
    ref_state = t_ref.run(t_ref.init_state())
    # preempted at 6 + resumed
    t1 = Trainer(cfg, TrainerConfig(**base, ckpt_dir=CKPT_DIR,
                                    preempt_at_step=6))
    with pytest.raises(PreemptionError):
        t1.run(t1.init_state())
    t2 = Trainer(cfg, TrainerConfig(**base, ckpt_dir=CKPT_DIR))
    state = t2.run()     # restores step 4
    assert state.step == 8
    # loss histories agree on the overlapping tail (deterministic resume)
    np.testing.assert_allclose(state.metrics["loss_history"][-2:],
                               ref_state.metrics["loss_history"][-2:],
                               rtol=1e-4)
    shutil.rmtree(CKPT_DIR, ignore_errors=True)
    shutil.rmtree(CKPT_DIR + "_ref", ignore_errors=True)


def test_grad_accumulation_matches_single_batch():
    cfg = get_smoke_config("llama3-8b")
    t1 = Trainer(cfg, TrainerConfig(seq_len=32, global_batch=4, steps=1,
                                    microbatches=1, ckpt_every=100,
                                    ckpt_dir="/tmp/na1", log_every=100))
    t2 = Trainer(cfg, TrainerConfig(seq_len=32, global_batch=4, steps=1,
                                    microbatches=2, ckpt_every=100,
                                    ckpt_dir="/tmp/na2", log_every=100))
    s1 = t1.run(t1.init_state())
    s2 = t2.run(t2.init_state())
    l1 = jax.tree.leaves(s1.params)
    l2 = jax.tree.leaves(s2.params)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-3, rtol=5e-3)


def test_adamw_bf16_moments():
    """Half-precision optimizer moments (the jamba-398B memory lever) track
    the fp32 moments closely and halve state bytes."""
    import jax.numpy as jnp
    from repro.train.optimizer import AdamW, constant_schedule
    params = {"w": jnp.ones((32, 32), jnp.float32)}
    grads = {"w": jnp.full((32, 32), 0.01, jnp.float32)}
    full = AdamW(schedule=constant_schedule(1e-2))
    half = AdamW(schedule=constant_schedule(1e-2), moment_dtype="bfloat16")
    sf, sh = full.init(params), half.init(params)
    assert sh.mu["w"].dtype == jnp.bfloat16
    pf, ph = dict(params), dict(params)
    for _ in range(5):
        pf, sf, _ = full.update(grads, sf, pf)
        ph, sh, _ = half.update(grads, sh, ph)
    np.testing.assert_allclose(np.asarray(pf["w"]), np.asarray(ph["w"]),
                               rtol=2e-2)


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------
def test_param_specs_divisibility():
    """Every sharded dim must divide its mesh axis (this is what makes the
    512-chip dry-run lower)."""
    from jax.sharding import Mesh
    from repro.distributed.sharding import param_specs
    from repro.models import model as M
    devs = np.asarray(jax.devices()[:1]).reshape(1, 1)
    # fake a 16x16 mesh shape via a Mesh of 1 device but checking with the
    # rule table requires real axis sizes; emulate using mesh of shape (1,1)
    mesh = Mesh(devs, ("data", "model"))
    for arch in ("llama3-8b", "jamba-1.5-large-398b", "musicgen-medium"):
        cfg = get_smoke_config(arch)
        abstract = jax.eval_shape(lambda k, c=cfg: M.init_params(c, k),
                                  jax.ShapeDtypeStruct((2,), "uint32"))
        specs = param_specs(abstract, cfg, mesh)
        for leaf, spec in zip(jax.tree.leaves(abstract),
                              jax.tree.leaves(
                                  specs, is_leaf=lambda x: hasattr(x, "_normalized_spec") or str(type(x).__name__) == "PartitionSpec")):
            assert len(spec) <= len(leaf.shape)


def test_dryrun_results_exist_and_are_complete():
    """The committed dry-run artifacts cover every applicable cell x mesh."""
    from repro.configs import ARCH_IDS, get_config
    from repro.configs.registry import applicable_shapes
    d = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                     "results", "dryrun")
    if not os.path.isdir(d):
        pytest.skip("dry-run results not generated yet")
    missing = []
    for arch in ARCH_IDS:
        for shape in applicable_shapes(get_config(arch)):
            for mesh in ("pod", "multipod"):
                f = os.path.join(d, f"{arch}__{shape.name}__{mesh}.json")
                if not os.path.exists(f):
                    missing.append(f)
    assert not missing, f"missing {len(missing)} dry-run cells"


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------
HLO_FIXTURE = """
HloModule test
  %p0 = bf16[16,1024]{1,0} parameter(0)
  %p1 = f32[8,128]{1,0} parameter(1)
  %ag = bf16[16,16384]{1,0} all-gather(%p0), replica_groups={}, dimensions={1}
  %ar = f32[8,128]{1,0} all-reduce(%p1), to_apply=%add
  %rs = f32[1,128]{1,0} reduce-scatter(%p1), dimensions={0}
  %cp = f32[8,128]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
  ROOT %t = (bf16[16,16384]{1,0}) tuple(%ag)
"""


def test_collective_stats_parser():
    st = collective_stats(HLO_FIXTURE)
    assert st.count_by_op == {"all-gather": 1, "all-reduce": 1,
                              "reduce-scatter": 1, "collective-permute": 1}
    assert st.bytes_by_op["all-gather"] == 16 * 1024 * 2
    assert st.bytes_by_op["all-reduce"] == 8 * 128 * 4
    assert st.bytes_by_op["reduce-scatter"] == 8 * 128 * 4
    assert st.bytes_by_op["collective-permute"] == 8 * 128 * 4


def test_shape_bytes():
    assert shape_bytes("bf16[16,1024]{1,0}") == 32768
    assert shape_bytes("(f32[8]{0}, s32[2,2]{1,0})") == 32 + 16
    assert shape_bytes("token[]") == 0


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_data_determinism_and_sharding():
    dc = DataConfig(vocab_size=100, seq_len=16, global_batch=8, seed=3)
    b1 = batch_at(dc, step=5)
    b2 = batch_at(dc, step=5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # shard recomputation: shards partition the batch deterministically
    s0 = batch_at(dc, step=5, shard=0, num_shards=2)
    s0b = batch_at(dc, step=5, shard=0, num_shards=2)
    np.testing.assert_array_equal(s0["tokens"], s0b["tokens"])
    assert s0["tokens"].shape == (4, 16)
    assert (b1["labels"] < 100).all() and (b1["labels"] >= 0).all()
    assert set(np.unique(b1["mask"])) <= {0.0, 1.0}
