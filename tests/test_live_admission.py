"""Device-resident admission (serving/live.py) vs the host
AdmissionController: placement-for-placement parity, counter semantics,
and the ServingEngine admission="live" integration."""
import numpy as np
import pytest

from repro.cluster.admission import AdmissionController, PendingJob
from repro.core.quantize import RES, to_grid
from repro.serving.live import LiveAdmission


def _job(rid, frac):
    return PendingJob(rid=rid, frac=frac)


# ---------------------------------------------------------------------------
# op-for-op parity with the host controller
# ---------------------------------------------------------------------------

def test_admit_best_fit_order_matches_host():
    """BF-J: minimum feasible residual, lowest replica index on ties —
    identical placement sequence to the host argmin."""
    host, live = AdmissionController(3), LiveAdmission(3, Qcap=16)
    jobs = [_job(0, 0.5), _job(1, 0.3), _job(2, 0.4), _job(3, 0.9),
            _job(4, 0.2)]
    assert host.admit(list(jobs)) == live.admit(list(jobs))
    np.testing.assert_array_equal(host.residual, live.residual)
    assert host.queue_len() == live.queue_len()


def test_refill_largest_first_earliest_on_ties():
    """BF-S: largest fitting job first; among equal sizes, the one queued
    earliest (Python max() returns the first maximum; the device argmax
    over FIFO-compacted lanes returns the same lane)."""
    host, live = AdmissionController(1), LiveAdmission(1, Qcap=16)
    # fill the single replica, then queue jobs incl. a size tie
    fill = [_job(0, 1.0)]
    host.admit(list(fill)), live.admit(list(fill))
    queued = [_job(1, 0.3), _job(2, 0.5), _job(3, 0.5), _job(4, 0.2)]
    host.admit(list(queued)), live.admit(list(queued))
    full = int(to_grid([1.0])[0])
    host.release(0, full)
    live.release(0, full)
    ph, pl = host.refill(0), live.refill(0)
    assert ph == pl
    # rid 2 (the EARLIER 0.5) must precede rid 3
    rids = [r for r, _ in pl]
    assert rids.index(2) < rids.index(3)
    np.testing.assert_array_equal(host.residual, live.residual)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_randomized_tick_parity(seed):
    """200 randomized ticks of arrivals + completions: every placement,
    residual and queue length identical between host and device."""
    rng = np.random.default_rng(seed)
    L = int(rng.integers(2, 8))
    host, live = AdmissionController(L), LiveAdmission(L, Qcap=64,
                                                       tick_width=16)
    size_of, active, rid = {}, {}, 0
    for t in range(200):
        jobs = []
        for _ in range(int(rng.integers(0, 4))):
            j = _job(rid, float(rng.uniform(0.05, 0.95)))
            size_of[rid] = j.size
            jobs.append(j)
            rid += 1
        ph, pl = host.admit(list(jobs)), live.admit(list(jobs))
        assert ph == pl, t
        active.update(ph)
        done = [r for r in list(active) if rng.uniform() < 0.3]
        events = [(active.pop(r), size_of[r]) for r in done]
        # host tick = release everything, then refill freed replicas in
        # ascending order (order-equivalent to the engine's interleaving)
        ph2 = []
        for rep, size in events:
            host.release(rep, size)
        for rep in sorted({rep for rep, _ in events}):
            ph2 += host.refill(rep)
        pl2 = live.tick(events)
        assert ph2 == pl2, t
        active.update(pl2)
        assert host.queue_len() == live.queue_len(), t
        np.testing.assert_array_equal(host.residual, live.residual)
    assert live.dropped == 0


def test_push_front_outranks_queue_and_counts_tail_drop():
    host, live = AdmissionController(1), LiveAdmission(1, Qcap=2)
    fill = [_job(0, 1.0)]
    host.admit(list(fill)), live.admit(list(fill))
    q1, q2 = _job(1, 0.4), _job(2, 0.3)
    host.admit([q1]), live.admit([q1])
    host.push_front(q2), live.push_front(q2)
    assert host.queue[0].rid == 2
    assert int(np.asarray(live.state.q_rid[0])) == 2
    assert host.queue_len() == live.queue_len() == 2
    # a head insert on a FULL device queue drops the tail (and counts it)
    live.push_front(_job(3, 0.2))
    assert live.queue_len() == 2 and live.dropped == 1
    assert int(np.asarray(live.state.q_rid[0])) == 3


def test_queue_overflow_counts_dropped():
    live = LiveAdmission(1, Qcap=2)
    live.admit([_job(0, 1.0)])            # occupy the replica
    placed = live.admit([_job(1, 0.5), _job(2, 0.5), _job(3, 0.5)])
    assert placed == []
    assert live.queue_len() == 2 and live.dropped == 1


def test_invalid_release_counted_then_raised_on_sync():
    live = LiveAdmission(2, Qcap=4)
    live.release(0, RES + 1)              # over-release
    live.release(5, 10)                   # unknown replica
    live.release(1, -3)                   # negative size
    with pytest.raises(ValueError, match="3 invalid release"):
        live.queue_len()
    # the host controller raises eagerly on the same inputs
    host = AdmissionController(2)
    with pytest.raises(ValueError, match="exceeds capacity"):
        host.release(0, RES + 1)
    with pytest.raises(ValueError, match="unknown replica"):
        host.release(5, 10)
    with pytest.raises(ValueError, match="negative size"):
        host.release(1, -3)


def test_tick_width_guard():
    live = LiveAdmission(2, Qcap=4, tick_width=2)
    with pytest.raises(ValueError, match="tick_width"):
        live.tick([(0, 1), (0, 1), (1, 1)])


# ---------------------------------------------------------------------------
# ServingEngine integration
# ---------------------------------------------------------------------------

def _tiny_engine(admission):
    import jax
    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.serving.engine import ServingEngine

    cfg = get_smoke_config("llama3-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return ServingEngine(cfg, params, num_replicas=2, b_slots=2,
                         c_max=32, admission=admission)


def test_serving_engine_live_matches_host():
    """The full engine under admission="live" reproduces the host path:
    same completions, same admission/queue trajectories."""
    from repro.serving.engine import Request

    def drive(admission):
        eng = _tiny_engine(admission)
        rng = np.random.default_rng(0)
        rid = 0
        for step in range(12):
            reqs = []
            for _ in range(int(rng.integers(0, 3))):
                prompt = np.arange(1 + int(rng.integers(0, 4)),
                                   dtype=np.int32)
                reqs.append(Request(rid=rid, prompt=prompt,
                                    max_new=int(rng.integers(1, 6))))
                rid += 1
            eng.submit(reqs)
            eng.step()
        eng.run(max_steps=64)
        return eng

    host_eng = drive("host")
    live_eng = drive("live")
    assert [r.rid for r in host_eng.completed] == \
        [r.rid for r in live_eng.completed]
    assert [(r.replica, r.slot) for r in host_eng.completed] == \
        [(r.replica, r.slot) for r in live_eng.completed]
    assert host_eng.stats["queue_len"] == live_eng.stats["queue_len"]
    assert host_eng.stats["admitted"] == live_eng.stats["admitted"]
    np.testing.assert_array_equal(host_eng.admission.residual,
                                  live_eng.admission.residual)


def test_serving_engine_rejects_unknown_admission():
    with pytest.raises(ValueError, match="unknown admission"):
        _tiny_engine("gpu")


def test_cluster_alias():
    from repro.serving.engine import Cluster, ServingEngine
    assert Cluster is ServingEngine
