"""Property-based parity: the fused bfjs-mr Pallas kernel (interpret mode)
vs the scan engine on hypothesis-generated workloads.

Random ``(lam, mu, R, capacity, Qcap)`` draws build real stream ensembles
and assert BIT-EXACT occupancy/queue/departure trajectories between
``kernels/bfjs_mr`` and ``run_bfjs_mr_streams`` — plus ``truncated == 0``
under the deliberately conservative bounds (ample K and work list), so the
bit-match contract extends through the scan engine to the event-driven
oracle.  Settings are derandomized and bounded (CI pins
``--hypothesis-seed=0`` on top), so tier-1 stays deterministic."""
import numpy as np
import pytest

# deselected by the fast tier-1 lane (-m "not slow"); CI runs
# the full suite
pytestmark = pytest.mark.slow

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax

from repro.core.engine import SchedStreams, make_streams, streams_from_trace
from repro.kernels.bfjs_mr.ops import bfjs_mr_simulate

#: bounded deterministic profile — a handful of examples is enough because
#: every example is itself a (G=2) x 80-slot trajectory sweep.
MR_SETTINGS = settings(max_examples=12, deadline=None, derandomize=True)


def _sampler(R, hi):
    def sampler(key, n):
        u = jax.random.uniform(key, (n, R), minval=0.05, maxval=hi)
        return u[:, 0] if R == 1 else u
    return sampler


def _assert_bitmatch(pal, ref):
    for f in pal._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(pal, f)), np.asarray(getattr(ref, f)),
            err_msg=f"kernel diverged from the scan engine on {f!r}")


@MR_SETTINGS
@given(data=st.data(),
       R=st.integers(1, 3),
       lam=st.floats(0.1, 1.0),
       mu=st.floats(0.2, 0.9),
       L=st.integers(2, 4),
       A_max=st.integers(2, 4),
       Qcap=st.sampled_from([16, 48]),
       window=st.sampled_from([None, 40]),
       seed=st.integers(0, 2 ** 16))
def test_mr_kernel_bitmatches_scan_on_random_workloads(
        data, R, lam, mu, L, A_max, Qcap, window, seed):
    """Interpret-mode kernel == scan engine, slot by slot, and the
    conservative bounds keep every deviation counter at zero."""
    capacity = tuple(data.draw(st.sampled_from([0.75, 1.0]),
                               label=f"cap[{r}]") for r in range(R))
    K, T, G = 16, 80, 2
    # sizes stay below min(capacity) so the workload is placeable and the
    # ample K/work bounds guarantee truncated == 0 by construction
    keys = jax.random.split(jax.random.PRNGKey(seed), G)
    streams = jax.vmap(lambda k: make_streams(
        k, lam, mu, _sampler(R, 0.6), L=L, K=K, A_max=A_max, horizon=T,
        num_resources=R))(keys)
    kw = dict(L=L, K=K, Qcap=Qcap, A_max=A_max, work_steps=A_max + 8,
              capacity=capacity)
    pal = bfjs_mr_simulate(streams, window=window, **kw)
    ref = bfjs_mr_simulate(streams, use_pallas=False, **kw)
    _assert_bitmatch(pal, ref)
    assert int(np.asarray(pal.truncated).sum()) == 0


@MR_SETTINGS
@given(R=st.integers(1, 3),
       n_jobs=st.integers(1, 60),
       horizon=st.sampled_from([40, 80]),
       seed=st.integers(0, 2 ** 16))
def test_mr_kernel_bitmatches_scan_on_random_traces(R, n_jobs, horizon,
                                                    seed):
    """Trace-built streams (per-arrival duration lanes only, the
    streams_from_trace layout) replay identically through kernel and scan
    engine — including the D = A_max duration-stream shape."""
    rng = np.random.default_rng(seed)
    slots = rng.integers(0, horizon, n_jobs)
    sizes = rng.integers(1, int(0.7 * 64), (n_jobs, R)) / 64.0
    durs = rng.integers(1, 20, n_jobs)
    streams = streams_from_trace(slots, sizes if R > 1 else sizes[:, 0],
                                 durs, horizon=horizon, num_resources=R)
    A_max = int(streams.sizes.shape[1])
    batched = jax.tree.map(lambda x: x[None], streams)
    kw = dict(L=3, K=16, Qcap=64, A_max=A_max, work_steps=A_max + 8,
              capacity=(1.0,) * R)
    pal = bfjs_mr_simulate(batched, **kw)
    ref = bfjs_mr_simulate(batched, use_pallas=False, **kw)
    _assert_bitmatch(pal, ref)
    assert int(np.asarray(pal.truncated).sum()) == 0
