"""Mesh-sharded Monte-Carlo + the shape-keyed tuning cache (DESIGN.md §11).

Sharding contract: ``monte_carlo_policy(..., mesh=|devices=)`` is
BIT-IDENTICAL to the unsharded run for every registered policy x engine —
each device consumes exactly its own key shard, so the per-member chains
never change.  Verified in-process on a devices=1 mesh (shard_map active,
same partitioning code path) for the full matrix, and in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` for real
multi-device placement — including a sweep checkpointed on 4 devices and
resumed on 2 (checkpoints never pin a device count).

Tuning contract: the persistent JSON cache round-trips winners keyed by
launch shape, ignores corrupt/stale files loudly, only fills knobs the
caller left unset, and ``autotune`` never caches a winner whose trajectory
is not bit-identical to the untuned baseline.  The suite runs under
``REPRO_TUNING_CACHE=off`` (conftest) so these tests opt in explicitly via
monkeypatched paths — a user's real cache is never read or written.
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

# deselected by the fast tier-1 lane (-m "not slow"); CI runs
# the full suite
pytestmark = pytest.mark.slow

from repro.core.engine import (TuningCache, Workload, apply_tuned, autotune,
                               make_streams, monte_carlo_policy,
                               resolve_mesh, run_policy_streams, shape_key,
                               tuning_enabled)
from repro.core.engine.sharding import ENSEMBLE_AXIS
from repro.serving.engine import estimate_capacity

G = 4


def _scalar_sampler(key, n):
    return jax.random.uniform(key, (n,), minval=0.05, maxval=0.5)


def _vec_sampler(key, n):
    return jax.random.uniform(key, (n, 2), minval=0.05, maxval=0.5)


#: policy -> (Workload, config): the parity-matrix shapes, shrunk to a
#: 96-slot horizon so the pallas cells stay fast in interpret mode.
MATRIX = {
    "bfjs": (Workload(lam=1.2, mu=0.05, sampler=_scalar_sampler),
             dict(L=4, K=6, Qcap=64, A_max=5, horizon=96)),
    "vqs": (Workload(lam=1.0, mu=0.05, sampler=_scalar_sampler),
            dict(L=4, K=8, Qcap=64, A_max=5, horizon=96, J=3)),
    "bfjs-mr": (Workload(lam=0.5, mu=0.05, sampler=_vec_sampler,
                         num_resources=2, capacity=(1.0, 0.75)),
                dict(L=4, K=8, Qcap=64, A_max=5, horizon=96,
                     work_steps=24)),
}


def _keys(n=G):
    return jax.random.split(jax.random.PRNGKey(5), n)


def _assert_bitmatch(res, ref, msg):
    for f in ref._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(res, f)), np.asarray(getattr(ref, f)),
            err_msg=f"{msg}: field {f!r}")


# ---------------------------------------------------------------------------
# sharded == unsharded, full policy x engine matrix (1-device mesh:
# shard_map active, identical partitioning code path)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("engine", ("reference", "scan", "pallas"))
@pytest.mark.parametrize("policy", sorted(MATRIX))
def test_mesh_parity_every_policy_engine(policy, engine):
    wl, cfg = MATRIX[policy]
    ref = monte_carlo_policy(wl, _keys(), policy=policy, engine=engine,
                             **cfg)
    res = monte_carlo_policy(wl, _keys(), policy=policy, engine=engine,
                             devices=1, **cfg)
    assert int(np.asarray(res.truncated).sum()) == 0
    _assert_bitmatch(res, ref, f"{policy}/{engine}: mesh != unsharded")


def test_chunked_mesh_parity_and_resume(tmp_path):
    """chunk= + mesh= composes: the chunked sharded sweep equals the
    straight Monte-Carlo, and a checkpoint taken mid-sweep resumes to the
    exact same trajectory (device count re-chosen at resume time)."""
    wl, cfg = MATRIX["bfjs"]
    full = monte_carlo_policy(wl, _keys(), policy="bfjs", engine="scan",
                              **cfg)
    chunked = monte_carlo_policy(wl, _keys(), policy="bfjs", engine="scan",
                                 devices=1, chunk=32, **cfg)
    _assert_bitmatch(chunked, full, "chunked+mesh != straight MC")
    d = str(tmp_path)
    monte_carlo_policy(wl, _keys(), policy="bfjs", engine="scan", devices=1,
                       chunk=32, checkpoint_dir=d, stop_after_chunks=1,
                       **cfg)
    res = monte_carlo_policy(wl, _keys(), policy="bfjs", engine="scan",
                             chunk=32, checkpoint_dir=d, resume=True, **cfg)
    _assert_bitmatch(res, full, "resume (mesh -> no mesh) diverged")


# ---------------------------------------------------------------------------
# mesh resolution / validation
# ---------------------------------------------------------------------------
def test_resolve_mesh_validation():
    assert resolve_mesh() is None
    m = resolve_mesh(devices=1)
    assert m.axis_names == (ENSEMBLE_AXIS,) and m.devices.size == 1
    assert resolve_mesh(mesh=m) is m
    with pytest.raises(ValueError, match="not both"):
        resolve_mesh(mesh=m, devices=1)
    from jax.sharding import Mesh
    mesh2d = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("a", "b"))
    with pytest.raises(ValueError, match="1-D mesh"):
        resolve_mesh(mesh=mesh2d)
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        resolve_mesh(devices=4096)


def test_streams_mesh_needs_chunk():
    streams = make_streams(jax.random.PRNGKey(0), 1.2, 0.05,
                           _scalar_sampler, L=4, K=6, A_max=5, horizon=96)
    with pytest.raises(ValueError, match="chunk"):
        run_policy_streams(streams, policy="bfjs", engine="scan", devices=1,
                           L=4, K=6, Qcap=64, A_max=5)
    from repro.core.engine.chunked import run_chunked
    with pytest.raises(ValueError, match="ensemble-batched"):
        run_chunked(streams, policy="bfjs", chunk=32,
                    mesh=resolve_mesh(devices=1), L=4, K=6, Qcap=64,
                    A_max=5)


# ---------------------------------------------------------------------------
# real multi-device placement (forced 4-device CPU subprocess: XLA_FLAGS
# must be set before jax imports, so this cannot run in-process)
# ---------------------------------------------------------------------------
_CHILD = """
import tempfile
import jax
import numpy as np
assert jax.device_count() >= 4, jax.devices()
from repro.core.engine import Workload, monte_carlo_policy

def scalar(key, n):
    return jax.random.uniform(key, (n,), minval=0.05, maxval=0.5)

def vec(key, n):
    return jax.random.uniform(key, (n, 2), minval=0.05, maxval=0.5)

MATRIX = {
    "bfjs": (Workload(lam=1.2, mu=0.05, sampler=scalar),
             dict(L=4, K=6, Qcap=64, A_max=5, horizon=96)),
    "vqs": (Workload(lam=1.0, mu=0.05, sampler=scalar),
            dict(L=4, K=8, Qcap=64, A_max=5, horizon=96, J=3)),
    "bfjs-mr": (Workload(lam=0.5, mu=0.05, sampler=vec, num_resources=2,
                         capacity=(1.0, 0.75)),
                dict(L=4, K=8, Qcap=64, A_max=5, horizon=96,
                     work_steps=24)),
}
keys = jax.random.split(jax.random.PRNGKey(5), 4)

def bitmatch(a, b, msg):
    for f in a._fields:
        assert (np.asarray(getattr(a, f))
                == np.asarray(getattr(b, f))).all(), (msg, f)

for policy, (wl, cfg) in MATRIX.items():
    for engine in ("reference", "scan", "pallas"):
        ref = monte_carlo_policy(wl, keys, policy=policy, engine=engine,
                                 **cfg)
        res = monte_carlo_policy(wl, keys, policy=policy, engine=engine,
                                 devices=4, **cfg)
        bitmatch(res, ref, f"{policy}/{engine}")

# a key batch that does not divide the mesh is rejected loudly
wl, cfg = MATRIX["bfjs"]
try:
    monte_carlo_policy(wl, jax.random.split(jax.random.PRNGKey(1), 6),
                       policy="bfjs", engine="scan", devices=4, **cfg)
except ValueError as e:
    assert "divide evenly" in str(e), e
else:
    raise SystemExit("non-dividing G was not rejected")

# checkpoint on 4 devices -> resume on 2: bit-exact vs straight-through
d = tempfile.mkdtemp()
full = monte_carlo_policy(wl, keys, policy="bfjs", engine="scan", **cfg)
monte_carlo_policy(wl, keys, policy="bfjs", engine="scan", devices=4,
                   chunk=32, checkpoint_dir=d, stop_after_chunks=1, **cfg)
res = monte_carlo_policy(wl, keys, policy="bfjs", engine="scan", devices=2,
                         chunk=32, checkpoint_dir=d, resume=True, **cfg)
bitmatch(res, full, "4-device checkpoint resumed on 2 devices")
print("OK")
"""


def test_multi_device_parity_and_cross_device_resume():
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep \
        + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["REPRO_TUNING_CACHE"] = "off"
    proc = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert proc.stdout.strip().endswith("OK"), proc.stdout


# ---------------------------------------------------------------------------
# tuning cache: round-trip, corruption, fill semantics
# ---------------------------------------------------------------------------
def test_cache_round_trip(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(tmp_path / "c.json"))
    assert tuning_enabled()
    key = shape_key("bfjs", "scan", L=4, K=8, R=1, Qcap=64, A_max=6)
    TuningCache().put(key, {"work_steps": 5, "window": None})
    assert TuningCache().get(key)["work_steps"] == 5
    # atomic writes leave no tmp droppings, and the file is valid JSON
    assert [p for p in os.listdir(tmp_path) if p.endswith(".tmp")] == []
    with open(tmp_path / "c.json") as f:
        assert json.load(f)["entries"][key]["work_steps"] == 5


def test_cache_off_disables_everything(monkeypatch):
    monkeypatch.setenv("REPRO_TUNING_CACHE", "off")
    assert not tuning_enabled()
    cfg = dict(L=4, K=8, Qcap=64, A_max=6)
    assert apply_tuned("bfjs", "scan", cfg) \
        == {"tuned": 0, "cache_hit": 0}
    assert "work_steps" not in cfg
    with pytest.raises(ValueError, match="disabled"):
        autotune(Workload(lam=1.0, mu=0.05, sampler=_scalar_sampler),
                 _keys(2), policy="bfjs", engine="scan", **cfg)


def test_corrupt_and_stale_caches_ignored(tmp_path, monkeypatch):
    path = tmp_path / "c.json"
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(path))
    path.write_text("{definitely not json")
    with pytest.warns(UserWarning, match="corrupt"):
        assert TuningCache().load() == {}
    path.write_text(json.dumps(
        {"schema": "tuning.v0", "entries": {"k": {"work_steps": 1}}}))
    with pytest.warns(UserWarning, match="schema"):
        assert TuningCache().load() == {}
    # the next store overwrites the bad file with a fresh valid cache
    with pytest.warns(UserWarning, match="schema"):
        TuningCache().put("k", {"work_steps": 3})
    assert TuningCache().get("k")["work_steps"] == 3


def test_apply_tuned_fill_semantics(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(tmp_path / "c.json"))
    shape = dict(L=4, K=8, R=1, Qcap=64, A_max=6)
    entry = {"work_steps": 4, "window": 48}
    for engine in ("scan", "pallas"):
        TuningCache().put(shape_key("bfjs", engine, **shape), entry)
    # scan: work_steps filled, window never (not a scan knob)
    cfg = dict(L=4, K=8, Qcap=64, A_max=6)
    assert apply_tuned("bfjs", "scan", cfg) \
        == {"tuned": 1, "cache_hit": 1}
    assert cfg["work_steps"] == 4 and "window" not in cfg
    # pallas: both knobs filled
    cfg = dict(L=4, K=8, Qcap=64, A_max=6)
    assert apply_tuned("bfjs", "pallas", cfg) \
        == {"tuned": 1, "cache_hit": 1}
    assert cfg["work_steps"] == 4 and cfg["window"] == 48
    # an explicit value always wins over the cache
    cfg = dict(L=4, K=8, Qcap=64, A_max=6, work_steps=9)
    assert apply_tuned("bfjs", "scan", cfg) \
        == {"tuned": 0, "cache_hit": 1}
    assert cfg["work_steps"] == 9
    # reference has no launch knobs: bypassed entirely
    cfg = dict(L=4, K=8, Qcap=64, A_max=6)
    assert apply_tuned("bfjs", "reference", cfg) \
        == {"tuned": 0, "cache_hit": 0}
    # a different shape misses
    cfg = dict(L=16, K=8, Qcap=64, A_max=6)
    assert apply_tuned("bfjs", "scan", cfg) \
        == {"tuned": 0, "cache_hit": 0}


# ---------------------------------------------------------------------------
# autotune: verified winners only, picked up end-to-end
# ---------------------------------------------------------------------------
def test_autotune_caches_verified_winner_end_to_end(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(tmp_path / "c.json"))
    wl, cfg = MATRIX["bfjs"]
    keys = _keys(2)
    out = autotune(wl, keys, policy="bfjs", engine="scan",
                   work_steps_grid=(1, 3, 24), rounds=1, **cfg)
    assert out["key"] == shape_key(
        "bfjs", "scan", L=4, K=6, R=1, Qcap=64, A_max=5)
    entry = TuningCache().get(out["key"])
    assert entry is not None and entry["speedup"] >= 1.0
    # the cached winner reproduces the default trajectory bit-for-bit
    # when injected by the normal monte_carlo_policy path
    tuned = monte_carlo_policy(wl, keys, policy="bfjs", engine="scan",
                               **cfg)
    monkeypatch.setenv("REPRO_TUNING_CACHE", "off")
    default = monte_carlo_policy(wl, keys, policy="bfjs", engine="scan",
                                 **cfg)
    _assert_bitmatch(tuned, default, "tuned run != default run")


def test_autotune_refusals(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(tmp_path / "c.json"))
    wl, cfg = MATRIX["bfjs"]
    with pytest.raises(ValueError, match="no launch knobs"):
        autotune(wl, _keys(2), policy="bfjs", engine="reference", **cfg)
    from repro.kernels.common import interpret_default
    if interpret_default():    # off-TPU: interpret timings refused
        with pytest.raises(ValueError, match="interpret"):
            autotune(wl, _keys(2), policy="bfjs", engine="pallas", **cfg)


# ---------------------------------------------------------------------------
# kernel early exit + serving telemetry
# ---------------------------------------------------------------------------
def test_mr_kernel_early_exit_bit_parity():
    """The bfjs-mr work-list early exit is bit-identical to the full
    fori_loop launch (post-done steps are no-ops by construction)."""
    from repro.kernels.bfjs_mr.ops import bfjs_mr_simulate
    keys = _keys(2)
    streams = jax.vmap(lambda k: make_streams(
        k, 0.5, 0.05, _vec_sampler, L=4, K=8, A_max=5, horizon=96,
        num_resources=2))(keys)
    kw = dict(L=4, K=8, Qcap=64, A_max=5, work_steps=24)
    on = bfjs_mr_simulate(streams, **kw)
    off = bfjs_mr_simulate(streams, early_exit=False, **kw)
    assert int(np.asarray(on.truncated).sum()) == 0
    _assert_bitmatch(on, off, "early_exit=True != early_exit=False")


def test_estimate_capacity_reports_launch_fields():
    out = estimate_capacity(4, 0.5, 20.0, ensembles=2, horizon=64, K=6,
                            Qcap=64, A_max=5)
    assert out["devices"] == 1
    # conftest pins REPRO_TUNING_CACHE=off: attributably untuned
    assert out["tuned"] == 0 and out["cache_hit"] == 0
    assert out["truncated"] == 0
