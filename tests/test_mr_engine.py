"""Multi-resource BF-J/S scan engine: bit-parity with the event-driven
MultiResourceBFJS oracle (random streams and the uncollapsed synthesized
Google-like trace), counted truncation, R-dimensional stream layout."""
import jax
import numpy as np
import pytest

from repro.core import synthesize_google_like_trace
from repro.core.engine import (Workload, make_streams, run_policy,
                               run_policy_streams, streams_from_trace)
from repro.core.engine.bfjs_mr import run_bfjs_mr_streams
from repro.core.multi_resource import (MultiResourceBFJS, alignment_scores,
                                       simulate_mr_trace)


def _vec_sampler(lo, hi, R):
    def sampler(key, n):
        return jax.random.uniform(key, (n, R), minval=lo, maxval=hi)
    return sampler


def _assert_bitmatch(res, ref, trunc_free=True):
    if trunc_free:
        assert int(res.truncated) == 0
        assert int(res.dropped) == 0
    np.testing.assert_array_equal(np.asarray(res.queue_len),
                                  np.asarray(ref.queue_len))
    np.testing.assert_array_equal(np.asarray(res.occupancy),
                                  np.asarray(ref.occupancy))
    np.testing.assert_array_equal(np.asarray(res.departed),
                                  np.asarray(ref.departed))


# ---------------------------------------------------------------------------
# stream layout: (T, A_max, R) with R = 1 squeezing to the legacy plane
# ---------------------------------------------------------------------------
def test_streams_r_dimension():
    st1 = make_streams(jax.random.PRNGKey(0), 1.0, 0.02,
                       lambda k, n: jax.random.uniform(k, (n,)),
                       L=2, K=4, A_max=3, horizon=20)
    assert st1.sizes.shape == (20, 3) and st1.num_resources == 1
    st2 = make_streams(jax.random.PRNGKey(0), 1.0, 0.02,
                       _vec_sampler(0.1, 0.5, 2), L=2, K=4, A_max=3,
                       horizon=20, num_resources=2)
    assert st2.sizes.shape == (20, 3, 2) and st2.num_resources == 2
    # non-size streams share the key chain across R — bitwise equal
    np.testing.assert_array_equal(np.asarray(st1.n), np.asarray(st2.n))
    np.testing.assert_array_equal(np.asarray(st1.durs), np.asarray(st2.durs))
    with pytest.raises(ValueError, match="expected"):
        make_streams(jax.random.PRNGKey(0), 1.0, 0.02,
                     _vec_sampler(0.1, 0.5, 2), L=2, K=4, A_max=3,
                     horizon=20, num_resources=3)


def test_streams_from_trace_collapse_modes():
    trace = synthesize_google_like_trace(300, 300, seed=1)
    st_c = streams_from_trace(trace)
    st_u = streams_from_trace(trace, collapse=False)
    assert st_c.num_resources == 1 and st_c.sizes.ndim == 2
    assert st_u.num_resources == 2 and st_u.sizes.shape[-1] == 2
    np.testing.assert_array_equal(np.asarray(st_c.n), np.asarray(st_u.n))
    # collapsed sizes == elementwise max of the uncollapsed planes (both on
    # the quantization grid)
    np.testing.assert_array_equal(
        np.asarray(st_c.sizes),
        np.asarray(st_u.sizes).max(axis=-1))


# ---------------------------------------------------------------------------
# alignment score: canonical f32 agrees between numpy and XLA
# ---------------------------------------------------------------------------
def test_alignment_score_numpy_jnp_agree():
    from repro.core.quantize import RES
    from repro.core.engine.ops import alignment_scores_jnp
    rng = np.random.default_rng(0)
    for R in (2, 3, 5):
        avail = rng.integers(0, RES + 1, size=(17, R))
        dem = rng.integers(1, RES + 1, size=(R,))
        a = alignment_scores(avail.astype(np.float64),
                             dem.astype(np.float64))
        b = np.asarray(alignment_scores_jnp(jax.numpy.asarray(avail),
                                            jax.numpy.asarray(dem)))
        np.testing.assert_array_equal(a, b)
        # the oracle scores on normalized dyadics (k/RES), the engine on
        # grid integers: exactly a 2^-32 rescale (power of two => identical
        # mantissas and rounding), so comparison order is identical too
        an = alignment_scores(avail / RES, dem / RES)
        np.testing.assert_array_equal(an.astype(np.float64) * 2.0 ** 32,
                                      a.astype(np.float64))


# ---------------------------------------------------------------------------
# parity with the oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed,lam,R", [(0, 0.2, 2), (1, 0.35, 2),
                                        (2, 0.25, 3)])
def test_mr_scan_bitmatches_oracle_on_random_streams(seed, lam, R):
    wl = Workload(lam=lam, mu=0.05, sampler=_vec_sampler(0.05, 0.5, R),
                  num_resources=R)
    key = jax.random.PRNGKey(seed)
    kw = dict(L=4, K=8, Qcap=256, A_max=5, horizon=500)
    scan = run_policy(wl, policy="bfjs-mr", engine="scan", key=key,
                      work_steps=24, **kw)
    ref = run_policy(wl, policy="bfjs-mr", engine="reference", key=key, **kw)
    _assert_bitmatch(scan, ref)


def test_mr_scan_bitmatches_oracle_nonunit_capacity():
    wl = Workload(lam=0.25, mu=0.05, sampler=_vec_sampler(0.05, 0.45, 2),
                  num_resources=2, capacity=(1.0, 0.75))
    key = jax.random.PRNGKey(4)
    kw = dict(L=4, K=8, Qcap=256, A_max=5, horizon=400)
    scan = run_policy(wl, policy="bfjs-mr", engine="scan", key=key,
                      work_steps=24, **kw)
    ref = run_policy(wl, policy="bfjs-mr", engine="reference", key=key, **kw)
    _assert_bitmatch(scan, ref)


def test_mr_google_like_trace_uncollapsed_bitmatch():
    """The ISSUE acceptance path: the synthesized Google-like (cpu, mem)
    trace replays UNCOLLAPSED through run_policy_streams(policy="bfjs-mr",
    engine="scan") and bit-matches the event-driven oracle, truncated == 0.
    """
    trace = synthesize_google_like_trace(1200, 1200, seed=4)
    streams = streams_from_trace(trace, collapse=False, horizon=2000)
    scan = run_policy_streams(streams, policy="bfjs-mr", engine="scan",
                              L=24, K=24, Qcap=512, work_steps=48)
    ref = run_policy_streams(streams, policy="bfjs-mr", engine="reference",
                             L=24)
    _assert_bitmatch(scan, ref)
    assert int(scan.departed[-1]) > 0
    assert scan.occupancy.shape == (2000, 2)

    # the same replay agrees with the simulate_mr_trace bridge (quantized
    # demands, record_every=1) — oracle, bridge and engine tell one story
    dem = np.stack([trace.cpu, trace.mem], axis=1)
    bridge = simulate_mr_trace(MultiResourceBFJS(24, 2),
                               trace.arrival_slots, dem, trace.durations,
                               horizon=2000)
    np.testing.assert_array_equal(np.asarray(scan.queue_len),
                                  bridge.queue_lens)
    np.testing.assert_array_equal(
        np.asarray(scan.occupancy),
        bridge.extras["occupancy"].astype(np.float32))
    np.testing.assert_array_equal(np.asarray(scan.departed),
                                  bridge.extras["departed_cum"])


def test_mr_truncation_counted_not_silent():
    """A starved work list and an undersized K must both show up in
    `truncated`, and ample bounds must restore the exact trajectory."""
    wl = Workload(lam=1.2, mu=0.1, sampler=_vec_sampler(0.05, 0.25, 2),
                  num_resources=2)
    key = jax.random.PRNGKey(9)
    kw = dict(L=3, Qcap=256, A_max=6, horizon=300)
    tiny = run_policy(wl, policy="bfjs-mr", engine="scan", key=key,
                      K=16, work_steps=1, **kw)
    small_k = run_policy(wl, policy="bfjs-mr", engine="scan", key=key,
                         K=2, work_steps=32, **kw)
    ample = run_policy(wl, policy="bfjs-mr", engine="scan", key=key,
                       K=16, work_steps=32, **kw)
    ref = run_policy(wl, policy="bfjs-mr", engine="reference", key=key,
                     K=16, **kw)
    assert int(tiny.truncated) > 0
    assert int(small_k.truncated) > 0
    _assert_bitmatch(ample, ref)


def test_mr_engine_lifts_scalar_streams():
    """R=1 streams replay through bfjs-mr (trivially vector-valued) — the
    squeeze/lift contract of the (T, A_max, R) layout."""
    rng = np.random.default_rng(3)
    slots = np.sort(rng.integers(0, 120, 80))
    sizes = rng.integers(1, 64, 80) / 64.0
    durs = rng.integers(1, 30, 80)
    st = streams_from_trace(slots, sizes, durs, horizon=160)
    assert st.num_resources == 1
    res = run_bfjs_mr_streams(st, L=4, K=8, Qcap=128,
                              A_max=int(st.sizes.shape[1]), work_steps=24,
                              capacity=(1.0,))
    ref = run_policy_streams(st, policy="bfjs-mr", engine="reference", L=4)
    _assert_bitmatch(res, ref)


def test_mr_pallas_engine_rejected_loudly():
    st = streams_from_trace(np.array([0, 1]), np.array([[0.3, 0.2],
                                                        [0.4, 0.1]]),
                            np.array([5, 5]), horizon=10)
    with pytest.raises(ValueError, match="no Pallas kernel"):
        run_policy_streams(st, policy="bfjs-mr", engine="pallas", L=2)
