"""Multi-resource BF-J/S scan engine: bit-parity with the event-driven
MultiResourceBFJS oracle (random streams and the uncollapsed synthesized
Google-like trace), counted truncation, R-dimensional stream layout."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import synthesize_google_like_trace
from repro.core.engine import (SchedStreams, Workload, make_streams,
                               run_policy, run_policy_streams,
                               streams_from_trace)
from repro.core.engine.bfjs_mr import run_bfjs_mr_streams
from repro.core.multi_resource import (MultiResourceBFJS, alignment_scores,
                                       simulate_mr_trace)


def _vec_sampler(lo, hi, R):
    def sampler(key, n):
        return jax.random.uniform(key, (n, R), minval=lo, maxval=hi)
    return sampler


def _assert_bitmatch(res, ref, trunc_free=True):
    if trunc_free:
        assert int(res.truncated) == 0
        assert int(res.dropped) == 0
    np.testing.assert_array_equal(np.asarray(res.queue_len),
                                  np.asarray(ref.queue_len))
    np.testing.assert_array_equal(np.asarray(res.occupancy),
                                  np.asarray(ref.occupancy))
    np.testing.assert_array_equal(np.asarray(res.departed),
                                  np.asarray(ref.departed))


# ---------------------------------------------------------------------------
# stream layout: (T, A_max, R) with R = 1 squeezing to the legacy plane
# ---------------------------------------------------------------------------
def test_streams_r_dimension():
    st1 = make_streams(jax.random.PRNGKey(0), 1.0, 0.02,
                       lambda k, n: jax.random.uniform(k, (n,)),
                       L=2, K=4, A_max=3, horizon=20)
    assert st1.sizes.shape == (20, 3) and st1.num_resources == 1
    st2 = make_streams(jax.random.PRNGKey(0), 1.0, 0.02,
                       _vec_sampler(0.1, 0.5, 2), L=2, K=4, A_max=3,
                       horizon=20, num_resources=2)
    assert st2.sizes.shape == (20, 3, 2) and st2.num_resources == 2
    # non-size streams share the key chain across R — bitwise equal
    np.testing.assert_array_equal(np.asarray(st1.n), np.asarray(st2.n))
    np.testing.assert_array_equal(np.asarray(st1.durs), np.asarray(st2.durs))
    with pytest.raises(ValueError, match="expected"):
        make_streams(jax.random.PRNGKey(0), 1.0, 0.02,
                     _vec_sampler(0.1, 0.5, 2), L=2, K=4, A_max=3,
                     horizon=20, num_resources=3)


def test_streams_from_trace_collapse_modes():
    trace = synthesize_google_like_trace(300, 300, seed=1)
    st_c = streams_from_trace(trace)
    st_u = streams_from_trace(trace, collapse=False)
    assert st_c.num_resources == 1 and st_c.sizes.ndim == 2
    assert st_u.num_resources == 2 and st_u.sizes.shape[-1] == 2
    np.testing.assert_array_equal(np.asarray(st_c.n), np.asarray(st_u.n))
    # collapsed sizes == elementwise max of the uncollapsed planes (both on
    # the quantization grid)
    np.testing.assert_array_equal(
        np.asarray(st_c.sizes),
        np.asarray(st_u.sizes).max(axis=-1))


# ---------------------------------------------------------------------------
# alignment score: exact arithmetic agrees between numpy and XLA
# ---------------------------------------------------------------------------
def test_alignment_score_numpy_jnp_agree():
    from repro.core.quantize import RES
    from repro.core.engine.ops import alignment_score_pair_jnp
    rng = np.random.default_rng(0)
    for R in (2, 3, 5):
        avail = rng.integers(0, RES + 1, size=(17, R))
        dem = rng.integers(1, RES + 1, size=(R,))
        a = alignment_scores(avail.astype(np.float64),
                             dem.astype(np.float64))
        # oracle f64 score is the exact integer inner product
        exact = (avail.astype(np.int64) * dem.astype(np.int64)).sum(axis=1)
        np.testing.assert_array_equal(a, exact.astype(np.float64))
        # engine (hi, lo) pair reassembles to the same exact integer, with
        # lo normalized to [0, 256) so lexicographic compare == exact
        # compare
        hi, lo = alignment_score_pair_jnp(jax.numpy.asarray(avail),
                                          jax.numpy.asarray(dem))
        hi, lo = np.asarray(hi).astype(np.int64), np.asarray(lo)
        assert ((0 <= lo) & (lo < 256)).all()
        np.testing.assert_array_equal(hi * 256 + lo, exact)
        # the oracle scores on normalized dyadics (k/RES): a 2^-32 rescale
        # (power of two => exact), so comparison order is identical too
        an = alignment_scores(avail / RES, dem / RES)
        np.testing.assert_array_equal(an * 2.0 ** 32, a)


# ---------------------------------------------------------------------------
# parity with the oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed,lam,R", [(0, 0.2, 2), (1, 0.35, 2),
                                        (2, 0.25, 3)])
def test_mr_scan_bitmatches_oracle_on_random_streams(seed, lam, R):
    wl = Workload(lam=lam, mu=0.05, sampler=_vec_sampler(0.05, 0.5, R),
                  num_resources=R)
    key = jax.random.PRNGKey(seed)
    kw = dict(L=4, K=8, Qcap=256, A_max=5, horizon=500)
    scan = run_policy(wl, policy="bfjs-mr", engine="scan", key=key,
                      work_steps=24, **kw)
    ref = run_policy(wl, policy="bfjs-mr", engine="reference", key=key, **kw)
    _assert_bitmatch(scan, ref)


def test_mr_scan_bitmatches_oracle_nonunit_capacity():
    wl = Workload(lam=0.25, mu=0.05, sampler=_vec_sampler(0.05, 0.45, 2),
                  num_resources=2, capacity=(1.0, 0.75))
    key = jax.random.PRNGKey(4)
    kw = dict(L=4, K=8, Qcap=256, A_max=5, horizon=400)
    scan = run_policy(wl, policy="bfjs-mr", engine="scan", key=key,
                      work_steps=24, **kw)
    ref = run_policy(wl, policy="bfjs-mr", engine="reference", key=key, **kw)
    _assert_bitmatch(scan, ref)


def test_mr_google_like_trace_uncollapsed_bitmatch():
    """The ISSUE acceptance path: the synthesized Google-like (cpu, mem)
    trace replays UNCOLLAPSED through run_policy_streams(policy="bfjs-mr",
    engine="scan") and bit-matches the event-driven oracle, truncated == 0.
    """
    trace = synthesize_google_like_trace(1200, 1200, seed=4)
    streams = streams_from_trace(trace, collapse=False, horizon=2000)
    scan = run_policy_streams(streams, policy="bfjs-mr", engine="scan",
                              L=24, K=24, Qcap=512, work_steps=48)
    ref = run_policy_streams(streams, policy="bfjs-mr", engine="reference",
                             L=24)
    _assert_bitmatch(scan, ref)
    assert int(scan.departed[-1]) > 0
    assert scan.occupancy.shape == (2000, 2)

    # the same replay agrees with the simulate_mr_trace bridge (quantized
    # demands, record_every=1) — oracle, bridge and engine tell one story
    dem = np.stack([trace.cpu, trace.mem], axis=1)
    bridge = simulate_mr_trace(MultiResourceBFJS(24, 2),
                               trace.arrival_slots, dem, trace.durations,
                               horizon=2000)
    np.testing.assert_array_equal(np.asarray(scan.queue_len),
                                  bridge.queue_lens)
    np.testing.assert_array_equal(
        np.asarray(scan.occupancy),
        bridge.extras["occupancy"].astype(np.float32))
    np.testing.assert_array_equal(np.asarray(scan.departed),
                                  bridge.extras["departed_cum"])


def test_mr_truncation_counted_not_silent():
    """A starved work list and an undersized K must both show up in
    `truncated`, and ample bounds must restore the exact trajectory."""
    wl = Workload(lam=1.2, mu=0.1, sampler=_vec_sampler(0.05, 0.25, 2),
                  num_resources=2)
    key = jax.random.PRNGKey(9)
    kw = dict(L=3, Qcap=256, A_max=6, horizon=300)
    tiny = run_policy(wl, policy="bfjs-mr", engine="scan", key=key,
                      K=16, work_steps=1, **kw)
    small_k = run_policy(wl, policy="bfjs-mr", engine="scan", key=key,
                         K=2, work_steps=32, **kw)
    ample = run_policy(wl, policy="bfjs-mr", engine="scan", key=key,
                       K=16, work_steps=32, **kw)
    ref = run_policy(wl, policy="bfjs-mr", engine="reference", key=key,
                     K=16, **kw)
    assert int(tiny.truncated) > 0
    assert int(small_k.truncated) > 0
    _assert_bitmatch(ample, ref)


def test_mr_engine_lifts_scalar_streams():
    """R=1 streams replay through bfjs-mr (trivially vector-valued) — the
    squeeze/lift contract of the (T, A_max, R) layout."""
    rng = np.random.default_rng(3)
    slots = np.sort(rng.integers(0, 120, 80))
    sizes = rng.integers(1, 64, 80) / 64.0
    durs = rng.integers(1, 30, 80)
    st = streams_from_trace(slots, sizes, durs, horizon=160)
    assert st.num_resources == 1
    res = run_bfjs_mr_streams(st, L=4, K=8, Qcap=128,
                              A_max=int(st.sizes.shape[1]), work_steps=24,
                              capacity=(1.0,))
    ref = run_policy_streams(st, policy="bfjs-mr", engine="reference", L=4)
    _assert_bitmatch(res, ref)


def test_mr_pallas_engine_bitmatches_scan_on_trace_streams():
    """engine="pallas" (interpret off-TPU) replays trace streams with the
    exact scan-engine trajectory — the PR 3 NotImplementedError is gone."""
    st = streams_from_trace(np.array([0, 1, 1, 3]),
                            np.array([[0.3, 0.2], [0.4, 0.1],
                                      [0.2, 0.6], [0.5, 0.5]]),
                            np.array([5, 3, 4, 2]), horizon=12)
    kw = dict(L=2, K=4, Qcap=8, work_steps=12)
    pal = run_policy_streams(st, policy="bfjs-mr", engine="pallas", **kw)
    scan = run_policy_streams(st, policy="bfjs-mr", engine="scan", **kw)
    _assert_bitmatch(pal, scan)
    with pytest.raises(ValueError, match="unknown engine"):
        run_policy_streams(st, policy="bfjs-mr", engine="cuda", L=2)


def test_mr_pallas_window_must_divide_horizon():
    st = streams_from_trace(np.array([0, 1]), np.array([[0.3, 0.2],
                                                        [0.4, 0.1]]),
                            np.array([5, 5]), horizon=10)
    with pytest.raises(ValueError, match="divide"):
        run_policy_streams(st, policy="bfjs-mr", engine="pallas", L=2,
                           window=3)


def test_mr_monte_carlo_pallas_grid_matches_scan_vmap():
    """monte_carlo_policy(engine="pallas"): the ensemble is the kernel
    grid; trajectories equal the vmapped scan engine member by member."""
    from repro.core.engine import monte_carlo_policy

    wl = Workload(lam=0.4, mu=0.1, sampler=_vec_sampler(0.05, 0.5, 2),
                  num_resources=2)
    keys = jax.random.split(jax.random.PRNGKey(2), 3)
    kw = dict(L=3, K=8, Qcap=64, A_max=4, horizon=120, work_steps=16)
    pal = monte_carlo_policy(wl, keys, policy="bfjs-mr", engine="pallas",
                             window=40, **kw)
    scan = monte_carlo_policy(wl, keys, policy="bfjs-mr", engine="scan",
                              **kw)
    _assert_bitmatch(pal, scan, trunc_free=False)
    np.testing.assert_array_equal(np.asarray(pal.dropped),
                                  np.asarray(scan.dropped))
    np.testing.assert_array_equal(np.asarray(pal.truncated),
                                  np.asarray(scan.truncated))
    assert int(np.asarray(scan.truncated).sum()) == 0


# ---------------------------------------------------------------------------
# edge-case regressions (exact counters, scan and pallas in lockstep)
# ---------------------------------------------------------------------------
def _both_engines(streams, **kw):
    window = kw.pop("window", None)
    scan = run_policy_streams(streams, policy="bfjs-mr", engine="scan", **kw)
    pal = run_policy_streams(streams, policy="bfjs-mr", engine="pallas",
                             window=window, **kw)
    _assert_bitmatch(pal, scan, trunc_free=False)
    assert int(pal.dropped) == int(scan.dropped)
    assert int(pal.truncated) == int(scan.truncated)
    return scan


def test_mr_r1_squeeze_path_equals_plain_bfjs():
    """With R = 1 the alignment score degenerates to Best-Fit: on streams
    with globally distinct grid sizes (no tie-breaks to disagree on) and a
    constant service duration (so the sequential-draw vs attach-at-arrival
    duration layouts coincide), bfjs-mr reproduces plain bfjs exactly."""
    from repro.core.engine import run_bfjs_streams

    T, A_max, L, K, Qcap = 80, 3, 3, 8, 64
    rng = np.random.default_rng(0)
    n = rng.integers(0, A_max + 1, T).astype(np.int32)
    sizes = (rng.permutation(np.arange(1, T * A_max + 1))
             .reshape(T, A_max) / 512.0).astype(np.float32)
    durs = np.full((T, L * K + A_max), 7, np.int32)
    streams = SchedStreams(jnp.asarray(n), jnp.asarray(sizes),
                           jnp.asarray(durs))
    bfjs = run_bfjs_streams(streams, L=L, K=K, Qcap=Qcap, A_max=A_max,
                            work_steps=24)
    mr = _both_engines(streams, L=L, K=K, Qcap=Qcap, A_max=A_max,
                       work_steps=24)
    assert int(mr.truncated) == 0 and int(bfjs.truncated) == 0
    np.testing.assert_array_equal(np.asarray(mr.queue_len),
                                  np.asarray(bfjs.queue_len))
    np.testing.assert_array_equal(np.asarray(mr.departed),
                                  np.asarray(bfjs.departed))
    np.testing.assert_array_equal(np.asarray(mr.occupancy)[:, 0],
                                  np.asarray(bfjs.occupancy))
    assert int(mr.departed[-1]) > 0


def test_mr_zero_arrival_windows():
    """All-empty slots: every per-slot output and counter is exactly 0."""
    T, A_max = 40, 3
    streams = SchedStreams(jnp.zeros(T, jnp.int32),
                           jnp.full((T, A_max, 2), 0.3, jnp.float32),
                           jnp.ones((T, A_max), jnp.int32))
    res = _both_engines(streams, L=2, K=4, Qcap=8, work_steps=8,
                        window=20)
    assert np.asarray(res.queue_len).tolist() == [0] * T
    np.testing.assert_array_equal(np.asarray(res.occupancy),
                                  np.zeros((T, 2), np.float32))
    assert np.asarray(res.departed).tolist() == [0] * T
    assert int(res.dropped) == 0 and int(res.truncated) == 0


def test_mr_all_jobs_oversized_everything_queues():
    """Demands infeasible on one resource (cpu 0.8 > capacity 0.5): no job
    ever places — the queue grows by exactly one per slot until Qcap, the
    overflow is counted as dropped, and nothing departs or truncates."""
    T, Qcap = 20, 8
    streams = SchedStreams(
        jnp.ones(T, jnp.int32),
        jnp.tile(jnp.asarray([[0.8, 0.1]], jnp.float32)[None], (T, 1, 1)),
        jnp.full((T, 1), 5, jnp.int32))
    res = _both_engines(streams, L=4, K=4, Qcap=Qcap, work_steps=8,
                        capacity=(0.5, 1.0))
    np.testing.assert_array_equal(
        np.asarray(res.queue_len), np.minimum(np.arange(1, T + 1), Qcap))
    assert int(res.dropped) == T - Qcap
    assert int(res.departed[-1]) == 0
    np.testing.assert_array_equal(np.asarray(res.occupancy),
                                  np.zeros((T, 2), np.float32))
    assert int(res.truncated) == 0


def test_mr_qcap_overflow_counted_as_dropped():
    """A burst beyond Qcap drops the excess arrivals, counted exactly —
    landed jobs keep first-empty positions and still place in order."""
    T, A_max, Qcap = 4, 6, 5
    n = jnp.asarray([6, 6, 0, 0], jnp.int32)
    sizes = jnp.full((T, A_max, 2), 0.9, jnp.float32)  # 1 job per server
    durs = jnp.full((T, A_max), 50, jnp.int32)         # nothing departs
    res = _both_engines(SchedStreams(n, sizes, durs), L=2, K=4, Qcap=Qcap,
                        work_steps=16)
    # slot 0: 6 arrive, 5 land (1 dropped), 2 place -> 3 queued;
    # slot 1: 6 arrive, 2 land in the freed buffer slots (4 dropped)
    assert np.asarray(res.queue_len).tolist() == [3, 5, 5, 5]
    assert int(res.dropped) == 1 + 4
    assert int(res.departed[-1]) == 0
    assert int(res.truncated) == 0


def test_streams_from_trace_num_resources_mismatch_raises():
    """An R=2 trace must not broadcast into an R=3 (or collapsed R=1)
    engine config: both shapes are named in the error."""
    trace = synthesize_google_like_trace(60, 60, seed=0)
    with pytest.raises(ValueError, match=r"R=2.*num_resources=3"):
        streams_from_trace(trace, collapse=False, num_resources=3)
    with pytest.raises(ValueError, match=r"R=1.*num_resources=2"):
        streams_from_trace(trace, collapse=True, num_resources=2)
    # matching R passes through untouched
    st = streams_from_trace(trace, collapse=False, num_resources=2)
    assert st.num_resources == 2
