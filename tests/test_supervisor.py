"""Supervised streaming (core/engine/supervisor.py): retry/backoff,
watchdog timeouts, checkpoint rollback, poison-chunk quarantine and the
runtime invariant auditor — plus the checkpoint-integrity layer in
repro/checkpoint/ckpt.py and the ResumableTraceReader retry seam.

The load-bearing contract: transient-fault recovery is BIT-EXACT — a
supervised run through flaky ingestion/staging/checkpoint paths produces
the same trajectory as the unperturbed run; only a QUARANTINED chunk
(deterministic poison, always counted) changes the trajectory, and then
exactly by that chunk's absence.
"""
import json
import os
import signal
import subprocess
import sys
import time
import warnings

import jax
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.core import trace as trace_mod
from repro.core.engine import (CheckpointRollbackWarning, InvariantViolation,
                               RetryPolicy, Supervisor, SupervisorError,
                               SupervisorTimeout, SupervisorWarning,
                               audit_result, iter_stream_chunks,
                               make_streams, run_policy_streams,
                               stream_chunks_from_trace, stream_policy)
from repro.core.engine.streams import streams_from_trace

FIXTURE = os.path.join(os.path.dirname(__file__), "data",
                       "google_like_50.csv")

_TRAJ = ("queue_len", "occupancy", "departed", "dropped", "truncated",
         "preempted", "requeued", "lost")

_CFG = dict(L=4, K=5, Qcap=48)


def assert_bitmatch(a, b, ctx=""):
    for f in _TRAJ:
        x, y = getattr(a, f), getattr(b, f)
        assert (x is None) == (y is None), (ctx, f)
        if x is not None:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=f"{ctx}: {f}")


def _synth_streams(horizon=40, fault_rate=0.0):
    return make_streams(
        jax.random.PRNGKey(7), lam=1.3, mu=0.08,
        sampler=lambda k, s: jax.random.uniform(k, s, minval=0.1,
                                                maxval=0.7),
        L=4, K=5, A_max=4, horizon=horizon,
        **({"fault_rate": fault_rate, "repair_rate": 0.3}
           if fault_rate else {}))


def _sup(**kw):
    kw.setdefault("sleep", lambda s: None)  # no wall-clock in tests
    return Supervisor(**kw)


class ChunkSource:
    """Index-addressed, idempotent-on-failure chunk source with the
    optional ``skip()`` quarantine protocol — the supervised-source
    contract ``ResumableTraceReader`` implements for CSV files."""

    def __init__(self, chunks, poison=(), transient=None):
        self.chunks = list(chunks)
        self.i = 0
        self.poison = set(poison)                # fail forever
        self.transient = dict(transient or {})   # fail n times, then work

    def __iter__(self):
        return self

    def skip(self):
        self.i += 1

    def __next__(self):
        if self.i in self.poison:
            raise OSError(f"poison chunk {self.i}")
        n = self.transient.get(self.i, 0)
        if n:
            self.transient[self.i] = n - 1
            raise OSError(f"transient fault on chunk {self.i}")
        if self.i >= len(self.chunks):
            raise StopIteration
        out = self.chunks[self.i]
        self.i += 1
        return out


# ---------------------------------------------------------------------------
# RetryPolicy / Supervisor.call mechanics
# ---------------------------------------------------------------------------

def test_backoff_schedule_is_seeded_capped_and_jittered():
    import random
    p = RetryPolicy(base_delay=0.1, max_delay=0.5, jitter=0.5, seed=3)
    d1 = [p.delay(k, random.Random(3)) for k in range(1, 7)][0]
    d2 = [p.delay(k, random.Random(3)) for k in range(1, 7)][0]
    assert d1 == d2  # seeded => reproducible
    rng = random.Random(3)
    delays = [p.delay(k, rng) for k in range(1, 8)]
    for k, d in enumerate(delays, start=1):
        base = min(0.5, 0.1 * 2.0 ** (k - 1))
        assert base * 0.5 <= d <= base  # jitter shrinks, never grows
    assert max(delays) <= 0.5  # capped


def test_call_retries_then_reraises_and_counts():
    sup = _sup(retry=RetryPolicy(max_retries=3))
    calls = []

    def flaky():
        calls.append(1)
        raise OSError("always")

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", SupervisorWarning)
        with pytest.raises(OSError):
            sup.call("ingest", flaky)
    assert len(calls) == 4          # 1 attempt + 3 retries
    assert sup.retries == 3


def test_call_does_not_retry_non_retryable():
    sup = _sup(retry=RetryPolicy(max_retries=3))
    calls = []

    def broken():
        calls.append(1)
        raise ValueError("logic bug")

    with pytest.raises(ValueError):
        sup.call("stage", broken)
    assert len(calls) == 1 and sup.retries == 0


def test_call_warns_loudly_per_retry():
    attempts = [2]
    sup = _sup(retry=RetryPolicy(max_retries=5))

    def flaky():
        if attempts[0]:
            attempts[0] -= 1
            raise OSError("transient")
        return "ok"

    with pytest.warns(SupervisorWarning, match="retry"):
        assert sup.call("ingest", flaky, chunk_index=7) == "ok"
    assert sup.retries == 2


def test_watchdog_times_out_with_typed_escalation():
    sup = Supervisor(compute_timeout=0.05)
    with pytest.raises(SupervisorTimeout) as e:
        sup.watch("device compute", lambda: time.sleep(1.0), 0.05,
                  chunk_index=3)
    assert e.value.phase == "device compute"
    assert e.value.chunk_index == 3
    assert sup.timeouts == 1


def test_watchdog_timeout_is_not_retried():
    sup = _sup(retry=RetryPolicy(max_retries=5))
    with pytest.raises(SupervisorTimeout):
        sup.call("stage", lambda: time.sleep(1.0), timeout=0.05)
    assert sup.retries == 0


# ---------------------------------------------------------------------------
# Supervised streaming: transient recovery is bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy,extra", [("bfjs", {}), ("vqs", {"J": 3})])
def test_transient_ingestion_faults_recover_bit_exact(policy, extra):
    streams = _synth_streams()
    cfg = dict(_CFG, A_max=4, **extra)
    chunks = list(iter_stream_chunks(streams, 7))
    ref = stream_policy(iter(chunks), policy=policy, **cfg)
    sup = _sup(retry=RetryPolicy(max_retries=3))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", SupervisorWarning)
        res = stream_policy(ChunkSource(chunks, transient={1: 2, 3: 1}),
                            policy=policy, supervisor=sup, audit=True,
                            **cfg)
    assert_bitmatch(ref, res, f"{policy}-transient")
    assert res.retries == 3
    assert res.quarantined == 0 and res.rollbacks == 0


def test_unsupervised_result_has_no_supervision_counters():
    streams = _synth_streams()
    res = stream_policy(iter_stream_chunks(streams, 7), policy="bfjs",
                        **dict(_CFG, A_max=4))
    assert res.retries is None
    assert res.quarantined is None
    assert res.rollbacks is None


def test_dead_plain_generator_is_detected_not_truncated():
    streams = _synth_streams()
    chunks = list(iter_stream_chunks(streams, 7))

    def dying():
        for i, c in enumerate(chunks):
            if i == 2:
                raise OSError("die once")
            yield c

    sup = _sup(retry=RetryPolicy(max_retries=2))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", SupervisorWarning)
        with pytest.raises(SupervisorError, match="ResumableTraceReader"):
            stream_policy(dying(), policy="bfjs", supervisor=sup,
                          **dict(_CFG, A_max=4))


# ---------------------------------------------------------------------------
# Poison-chunk quarantine
# ---------------------------------------------------------------------------

def test_quarantine_skips_with_manifest_and_exact_accounting(tmp_path):
    streams = _synth_streams()
    cfg = dict(_CFG, A_max=4)
    chunks = list(iter_stream_chunks(streams, 7))
    # ground truth: the same stream with the poison chunk simply absent
    ref = stream_policy(iter(chunks[:2] + chunks[3:]), policy="bfjs", **cfg)
    qdir = tmp_path / "quarantine"
    sup = _sup(retry=RetryPolicy(max_retries=2), quarantine_dir=str(qdir))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", SupervisorWarning)
        res = stream_policy(ChunkSource(chunks, poison={2}), policy="bfjs",
                            supervisor=sup, **cfg)
    assert res.quarantined == 1
    assert res.retries == 2           # the poison exhausted its retries
    assert_bitmatch(ref, res, "poison-minus-chunk")
    man = json.loads((qdir / "chunk_00000002" / "manifest.json")
                     .read_text())
    assert man["chunk_index"] == 2
    assert man["error_type"] == "OSError"
    assert man["policy"] == "bfjs"
    assert "poison" in man["error"]


def test_quarantine_refused_without_a_quarantine_dir():
    streams = _synth_streams()
    chunks = list(iter_stream_chunks(streams, 7))
    sup = _sup(retry=RetryPolicy(max_retries=1))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", SupervisorWarning)
        with pytest.raises(SupervisorError, match="quarantine_dir"):
            stream_policy(ChunkSource(chunks, poison={2}), policy="bfjs",
                          supervisor=sup, **dict(_CFG, A_max=4))


def test_consecutive_quarantines_abort_a_broken_source(tmp_path):
    streams = _synth_streams()
    chunks = list(iter_stream_chunks(streams, 7))
    sup = _sup(retry=RetryPolicy(max_retries=0),
               quarantine_dir=str(tmp_path / "q"),
               max_consecutive_quarantines=2)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", SupervisorWarning)
        with pytest.raises(SupervisorError, match="consecutive"):
            stream_policy(ChunkSource(chunks, poison={1, 2, 3}),
                          policy="bfjs", supervisor=sup,
                          **dict(_CFG, A_max=4))
    assert sup.quarantined == 3


def test_staging_poison_preserves_planes(tmp_path):
    """A chunk that ingests but fails staging (mid-stream shape change) is
    quarantined WITH its stream planes for forensics."""
    streams = _synth_streams()
    chunks = list(iter_stream_chunks(streams, 7))
    # widen the arrival lanes of chunk 2: staging rejects the shape change
    bad = chunks[2]._replace(
        sizes=np.concatenate([np.asarray(chunks[2].sizes)] * 2, axis=1))
    seq = chunks[:2] + [bad] + chunks[3:]
    ref = stream_policy(iter(chunks[:2] + chunks[3:]), policy="bfjs",
                        **dict(_CFG, A_max=4))
    qdir = tmp_path / "q"
    sup = _sup(quarantine_dir=str(qdir))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", SupervisorWarning)
        res = stream_policy(iter(seq), policy="bfjs", supervisor=sup,
                            **dict(_CFG, A_max=4))
    assert res.quarantined == 1
    assert_bitmatch(ref, res, "staging-poison")
    man = json.loads((qdir / "chunk_00000002" / "manifest.json")
                     .read_text())
    assert man["has_planes"] is True
    saved = np.load(qdir / "chunk_00000002" / "chunk.npz")
    assert saved["sizes"].shape[1] == 8  # the corrupt width, preserved


# ---------------------------------------------------------------------------
# Checkpoint integrity + rollback
# ---------------------------------------------------------------------------

def _corrupt(path, mode):
    if mode == "garbage":
        with open(path, "r+b") as f:
            f.seek(0)
            f.write(b"\x00garbage\x00garbage\x00")
    elif mode == "truncate":
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(max(size // 2, 1))
    else:
        raise AssertionError(mode)


@pytest.mark.parametrize("mode", ["garbage", "truncate"])
def test_load_arrays_raises_typed_error_naming_path(tmp_path, mode):
    ckpt.save(str(tmp_path), 1, {"x": np.arange(5)})
    victim = tmp_path / "step_00000001" / "arrays.npz"
    _corrupt(victim, mode)
    with pytest.raises(ckpt.CheckpointCorruptError) as e:
        ckpt.load_arrays(str(tmp_path), 1)
    assert str(victim) in str(e.value)


def test_corrupt_manifest_raises_typed_error(tmp_path):
    ckpt.save(str(tmp_path), 1, {"x": np.arange(5)})
    (tmp_path / "step_00000001" / "manifest.json").write_text("{not json")
    with pytest.raises(ckpt.CheckpointCorruptError):
        ckpt.read_manifest(str(tmp_path), 1)


def test_latest_valid_step_walks_back_over_corruption(tmp_path):
    for step in (1, 2, 3):
        ckpt.save(str(tmp_path), step, {"x": np.arange(step)})
    _corrupt(tmp_path / "step_00000003" / "arrays.npz", "garbage")
    _corrupt(tmp_path / "step_00000002" / "arrays.npz", "truncate")
    latest, corrupt = ckpt.latest_valid_step(str(tmp_path))
    assert latest == 1
    assert sorted(corrupt) == [2, 3]


def test_no_checkpoint_survives(tmp_path):
    ckpt.save(str(tmp_path), 1, {"x": np.arange(3)})
    _corrupt(tmp_path / "step_00000001" / "arrays.npz", "garbage")
    latest, corrupt = ckpt.latest_valid_step(str(tmp_path))
    assert latest is None and corrupt == [1]


@pytest.mark.parametrize("mode", ["garbage", "truncate"])
def test_rollback_resume_is_bit_exact(tmp_path, mode):
    streams = _synth_streams()
    cfg = dict(_CFG, A_max=4)
    ck = tmp_path / "ck"
    ref = stream_policy(iter_stream_chunks(streams, 7), policy="bfjs",
                        **cfg)
    stream_policy(iter_stream_chunks(streams, 7), policy="bfjs",
                  checkpoint_dir=str(ck), **cfg)
    steps = ckpt.list_steps(str(ck))
    _corrupt(ck / f"step_{steps[-1]:08d}" / "arrays.npz", mode)

    # unsupervised resume surfaces the damage as a typed error (satellite:
    # never a raw zipfile/numpy error)
    with pytest.raises(ckpt.CheckpointCorruptError):
        stream_policy(iter_stream_chunks(streams, 7), policy="bfjs",
                      checkpoint_dir=str(ck), resume=True, **cfg)

    # supervised resume rolls back to the last good boundary, warns,
    # counts — and the result is bit-identical to the unperturbed run
    sup = _sup()
    with pytest.warns(CheckpointRollbackWarning, match="corrupt"):
        res = stream_policy(iter_stream_chunks(streams, 7), policy="bfjs",
                            checkpoint_dir=str(ck), resume=True,
                            supervisor=sup, **cfg)
    assert res.rollbacks == 1
    assert_bitmatch(ref, res, f"rollback-{mode}")


def test_rollback_to_nothing_restarts_from_scratch(tmp_path):
    streams = _synth_streams()
    cfg = dict(_CFG, A_max=4)
    ck = tmp_path / "ck"
    ref = stream_policy(iter_stream_chunks(streams, 7), policy="bfjs",
                        **cfg)
    stream_policy(iter_stream_chunks(streams, 7), policy="bfjs",
                  checkpoint_dir=str(ck), stop_after_chunks=2, **cfg)
    for step in ckpt.list_steps(str(ck)):
        _corrupt(ck / f"step_{step:08d}" / "arrays.npz", "garbage")
    sup = _sup()
    with pytest.warns(CheckpointRollbackWarning):
        res = stream_policy(iter_stream_chunks(streams, 7), policy="bfjs",
                            checkpoint_dir=str(ck), resume=True,
                            supervisor=sup, **cfg)
    assert res.rollbacks == 2
    assert_bitmatch(ref, res, "rollback-all")


def test_fully_cached_supervised_resume_reports_counters(tmp_path):
    """Satellite pin: a fully-cached resume returns the checkpointed
    result with the BACKPRESSURE COUNTERS RESET TO ZERO — they measure
    this call's host/device overlap, and this call did no pipelining —
    and, under supervision, the supervision counters attached."""
    streams = _synth_streams()
    cfg = dict(_CFG, A_max=4)
    ck = tmp_path / "ck"
    stream_policy(iter_stream_chunks(streams, 7), policy="bfjs",
                  checkpoint_dir=str(ck), **cfg)
    # unsupervised: counters reset, supervision fields stay None
    res = stream_policy(iter_stream_chunks(streams, 7), policy="bfjs",
                        checkpoint_dir=str(ck), resume=True, **cfg)
    assert int(res.chunks_behind) == 0
    assert float(res.host_stall_us) == 0.0
    assert res.retries is None and res.quarantined is None \
        and res.rollbacks is None
    # supervised: same reset plus zeroed supervision accounting
    res2 = stream_policy(iter_stream_chunks(streams, 7), policy="bfjs",
                         checkpoint_dir=str(ck), resume=True,
                         supervisor=_sup(), **cfg)
    assert int(res2.chunks_behind) == 0
    assert float(res2.host_stall_us) == 0.0
    assert (res2.retries, res2.quarantined, res2.rollbacks) == (0, 0, 0)


def test_supervised_checkpoint_write_retries(tmp_path, monkeypatch):
    from repro.core.engine import streaming as streaming_mod
    streams = _synth_streams()
    cfg = dict(_CFG, A_max=4)
    ref = stream_policy(iter_stream_chunks(streams, 7), policy="bfjs",
                        **cfg)
    real = streaming_mod._save_step
    fails = {2: 2}  # step 2's save fails twice, then lands

    def flaky_save(checkpoint_dir, step, payload, extra):
        if fails.get(step, 0):
            fails[step] -= 1
            raise OSError(f"disk hiccup at step {step}")
        return real(checkpoint_dir, step, payload, extra)

    monkeypatch.setattr(streaming_mod, "_save_step", flaky_save)
    sup = _sup(retry=RetryPolicy(max_retries=3))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", SupervisorWarning)
        res = stream_policy(iter_stream_chunks(streams, 7), policy="bfjs",
                            checkpoint_dir=str(tmp_path / "ck"),
                            supervisor=sup, **cfg)
    assert res.retries == 2
    assert_bitmatch(ref, res, "flaky-ckpt-write")


# ---------------------------------------------------------------------------
# Runtime invariant auditor
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy,extra", [
    ("bfjs", {}), ("vqs", {"J": 3}), ("vqs-bf", {"J": 3}),
])
def test_audit_passes_on_healthy_runs(policy, extra):
    streams = _synth_streams(fault_rate=0.05 if policy == "bfjs" else 0.0)
    cfg = dict(_CFG, A_max=4, **extra)
    res = stream_policy(iter_stream_chunks(streams, 7), policy=policy,
                        audit=True, **cfg)
    assert res.truncated is not None  # ran to completion


def test_audit_passes_on_bfjs_mr_multi_resource():
    tr = trace_mod.synthesize_google_like_trace(120, 60, seed=3)
    st = streams_from_trace(tr.arrival_slots,
                            np.stack([tr.cpu, tr.mem], 1),
                            np.minimum(tr.durations, 20), A_max=8)
    stream_policy(iter_stream_chunks(st, 13), policy="bfjs-mr",
                  audit=True, L=4, K=6, Qcap=64)


def test_audit_result_detects_tampered_occupancy():
    streams = _synth_streams()
    cfg = dict(_CFG, A_max=4)
    res = run_policy_streams(streams, policy="bfjs", engine="scan", **cfg)
    audit_result(streams, res, policy="bfjs", config=_CFG)  # healthy
    evil = res._replace(occupancy=np.asarray(res.occupancy) + 100.0)
    with pytest.raises(InvariantViolation, match="occupancy_capacity"):
        audit_result(streams, evil, policy="bfjs", config=_CFG)


def test_audit_result_detects_conservation_break():
    streams = _synth_streams()
    cfg = dict(_CFG, A_max=4)
    res = run_policy_streams(streams, policy="bfjs", engine="scan", **cfg)
    evil = res._replace(departed=np.asarray(res.departed) + 50)
    with pytest.raises(InvariantViolation, match="in_flight_nonneg"):
        audit_result(streams, evil, policy="bfjs", config=_CFG)


def test_audit_names_chunk_and_invariant(monkeypatch):
    """Tamper with the engine output mid-stream: the violation names the
    chunk index and the failed counter."""
    from repro.core.engine import chunked as chunked_mod
    from repro.core.engine import streaming as streaming_mod
    streams = _synth_streams()
    real = chunked_mod._STATEFUL["bfjs"]

    def tampered(s, st, config):
        res, new_st = real(s, st, config)
        return res._replace(queue_len=res.queue_len - 1000), new_st

    monkeypatch.setitem(streaming_mod._STATEFUL, "bfjs", tampered)
    with pytest.raises(InvariantViolation) as e:
        stream_policy(iter_stream_chunks(streams, 7), policy="bfjs",
                      audit=True, **dict(_CFG, A_max=4))
    assert e.value.invariant == "queue_nonneg"
    assert e.value.chunk_index == 0
    assert isinstance(e.value, ValueError)


def test_audit_requires_explicit_L_and_K():
    streams = _synth_streams()
    with pytest.raises(ValueError, match="L= and K="):
        stream_policy(iter_stream_chunks(streams, 7), policy="bfjs",
                      audit=True, A_max=4, Qcap=48)


def test_api_audit_knob():
    streams = _synth_streams()
    cfg = dict(_CFG, A_max=4)
    run_policy_streams(streams, policy="bfjs", engine="scan", audit=True,
                      **cfg)
    run_policy_streams(streams, policy="bfjs", engine="scan", chunk=13,
                       audit=True, **cfg)


# ---------------------------------------------------------------------------
# Host-side invariant raises (satellite: asserts -> typed raises)
# ---------------------------------------------------------------------------

def test_cluster_state_invariants_raise_not_assert():
    from repro.core.cluster_state import Cluster
    cs = Cluster(L=3)
    cs.check_invariants()  # healthy
    cs.residual[1] -= 5    # corrupt the books
    with pytest.raises(InvariantViolation, match="residual mismatch"):
        cs.check_invariants()
    cs.residual[1] -= cs.capacity[1] * 2  # now negative too
    with pytest.raises(ValueError):       # documented base type preserved
        cs.check_invariants()
    # and the checks survive python -O (no assert statements left)
    import inspect
    src = inspect.getsource(Cluster.check_invariants)
    assert "assert " not in src


def test_serving_engine_audit_catches_corruption():
    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.serving.engine import Request, ServingEngine

    cfg = get_smoke_config("llama3-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, num_replicas=2, b_slots=2, c_max=64,
                        audit=True)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, 100, size=4).astype(np.int32),
                    max_new=3) for i in range(4)]
    eng.submit(reqs)
    for _ in range(12):
        eng.step()  # audited every tick
    eng.check_invariants()
    # corrupt the books: lose a completed request from the ledger
    if eng.completed:
        eng.completed.pop()
        with pytest.raises(InvariantViolation,
                           match="request conservation"):
            eng.check_invariants()


# ---------------------------------------------------------------------------
# ResumableTraceReader
# ---------------------------------------------------------------------------

def _reader_kwargs():
    cc, mc = trace_mod.scan_trace_maxima(FIXTURE)
    return dict(chunk_rows=13, slot_seconds=10.0, cpu_capacity=cc,
                mem_capacity=mc)


def test_resumable_reader_matches_plain_reader():
    kw = _reader_kwargs()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        plain = list(trace_mod.iter_trace_csv(FIXTURE, **kw))
        resum = list(trace_mod.ResumableTraceReader(FIXTURE, **kw))
    assert len(plain) == len(resum) > 0
    for a, b in zip(plain, resum):
        for f in ("arrival_slots", "cpu", "mem", "durations"):
            np.testing.assert_array_equal(getattr(a, f), getattr(b, f))


class _FlakyReader(trace_mod.ResumableTraceReader):
    """Transport that dies on its 3rd chunk for the first two passes."""

    def __init__(self, *a, **k):
        super().__init__(*a, **k)
        self.passes = 0

    def _open(self):
        self.passes += 1
        gen = super()._open()
        if self.passes <= 2:
            def wrap(g=gen):
                for i, c in enumerate(g):
                    if i == 2:
                        raise OSError("flaky NFS")
                    yield c
            return wrap()
        return gen


def test_resumable_reader_recovers_bit_identical_chunks():
    kw = _reader_kwargs()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        plain = list(trace_mod.iter_trace_csv(FIXTURE, **kw))
        fl = _FlakyReader(FIXTURE, **kw)
        got = []
        while True:
            try:
                got.append(next(fl))
            except StopIteration:
                break
            except OSError:
                continue  # the supervisor's retry, minimally
    assert fl.reopens == 2
    assert len(got) == len(plain)
    for a, b in zip(plain, got):
        np.testing.assert_array_equal(a.arrival_slots, b.arrival_slots)


def test_supervised_trace_stream_end_to_end_bit_exact():
    kw = _reader_kwargs()
    cfg = dict(L=4, K=5, Qcap=48, J=3)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        clean = stream_policy(
            stream_chunks_from_trace(trace_mod.iter_trace_csv(FIXTURE,
                                                              **kw),
                                     chunk_slots=16, A_max=12),
            policy="vqs", **cfg)
        res = stream_policy(
            stream_chunks_from_trace(_FlakyReader(FIXTURE, **kw),
                                     chunk_slots=16, A_max=12),
            policy="vqs", supervisor=_sup(), audit=True, **cfg)
    assert_bitmatch(clean, res, "flaky-trace-e2e")
    assert res.retries == 2 and res.quarantined == 0


def test_resumable_reader_detects_shrinking_file(tmp_path):
    src = open(FIXTURE).read()
    p = tmp_path / "t.csv"
    p.write_text(src)
    kw = _reader_kwargs()
    r = trace_mod.ResumableTraceReader(str(p), **kw)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        next(r)
        next(r)
        # fail the live generator, then shrink the file under it
        r._gen = None
        lines = src.splitlines()
        p.write_text("\n".join(lines[:3]) + "\n")
        with pytest.raises(OSError, match="shrank"):
            next(r)


# ---------------------------------------------------------------------------
# SIGKILL + corruption end-to-end (subprocess)
# ---------------------------------------------------------------------------

_CHILD = r"""
import sys
import jax
from repro.core.engine import make_streams, stream_policy, \
    iter_stream_chunks
from repro.core.engine import streaming as streaming_mod

ckdir = sys.argv[1]
kills_after = int(sys.argv[2])

streams = make_streams(
    jax.random.PRNGKey(7), lam=1.3, mu=0.08,
    sampler=lambda k, s: jax.random.uniform(k, s, minval=0.1, maxval=0.7),
    L=4, K=5, A_max=4, horizon=40)

saves = [0]
real = streaming_mod._save_step

def killing_save(checkpoint_dir, step, payload, extra):
    real(checkpoint_dir, step, payload, extra)
    saves[0] += 1
    if saves[0] >= kills_after:
        import os, signal
        os.kill(os.getpid(), signal.SIGKILL)

streaming_mod._save_step = killing_save
stream_policy(iter_stream_chunks(streams, 7), policy="bfjs",
              checkpoint_dir=ckdir, L=4, K=5, Qcap=48, A_max=4)
"""


@pytest.mark.slow
def test_sigkill_then_corruption_then_supervised_resume(tmp_path):
    """The full chaos sequence: SIGKILL mid-stream, corrupt the newest
    surviving checkpoint, supervised resume — bit-exact recovery."""
    ck = tmp_path / "ck"
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD, str(ck), "3"],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, capture_output=True, timeout=600)
    assert proc.returncode == -signal.SIGKILL, proc.stderr.decode()
    steps = ckpt.list_steps(str(ck))
    assert steps, "no checkpoint survived the kill"
    _corrupt(ck / f"step_{steps[-1]:08d}" / "arrays.npz", "truncate")

    streams = _synth_streams()
    cfg = dict(_CFG, A_max=4)
    ref = stream_policy(iter_stream_chunks(streams, 7), policy="bfjs",
                        **cfg)
    with pytest.warns(CheckpointRollbackWarning):
        res = stream_policy(iter_stream_chunks(streams, 7), policy="bfjs",
                            checkpoint_dir=str(ck), resume=True,
                            supervisor=_sup(), audit=True, **cfg)
    assert res.rollbacks == 1
    assert_bitmatch(ref, res, "sigkill-corrupt-resume")
