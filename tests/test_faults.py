"""Fault-injected cluster simulation (DESIGN.md §9).

Three contracts, per policy:
  * the fault plane is a pure overlay — attaching it never perturbs the
    job streams, and fault-free streams keep their exact trajectories;
  * faulted scan trajectories bit-match the event-driven reference oracle
    (queue/occupancy/departures AND the preempted/requeued/lost counters);
  * preemption accounting never loses a job silently:
    ``preempted == requeued + lost`` always.

Plus the §9 enforced-graceful-degradation half: ``engine="pallas"``
requests the fused kernels cannot honour (fault planes, VMEM budget) fall
back to the bit-identical scan engine with a loud
``GracefulDegradationWarning`` — or raise under ``strict=True``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import (Workload, fault_plane_from_events,
                               make_fault_plane, make_streams, run_policy,
                               run_policy_streams, with_fault_plane)
from repro.kernels.common import GracefulDegradationWarning


def _scalar_sampler(key, n):
    return jax.random.uniform(key, (n,), minval=0.05, maxval=0.5)


def _vec_sampler(key, n):
    return jax.random.uniform(key, (n, 2), minval=0.05, maxval=0.5)


#: Shock plane hot enough that every policy sees real preemptions in 200
#: slots (stationary availability 0.4 / 0.43 ~ 93%) — but mild enough,
#: with the generous Qcap below, that no queue overflows: the bit-match
#: contract needs ``dropped == 0`` (the oracle queue is unbounded).
FAULT = dict(fault_rate=0.03, repair_rate=0.4)

#: policy -> (Workload, engine-agnostic config); shapes follow the parity
#: matrix (tests/test_engine_parity_matrix.py) with a longer horizon so
#: requeued jobs get preempted AGAIN and the lost path exercises too.
MATRIX = {
    "bfjs": (Workload(lam=1.2, mu=0.05, sampler=_scalar_sampler),
             dict(L=4, K=6, Qcap=256, A_max=5, horizon=200)),
    "vqs": (Workload(lam=1.0, mu=0.05, sampler=_scalar_sampler),
            dict(L=4, K=8, Qcap=256, A_max=5, horizon=200, J=3)),
    "bfjs-mr": (Workload(lam=0.5, mu=0.05, sampler=_vec_sampler,
                         num_resources=2, capacity=(1.0, 0.75)),
                dict(L=4, K=8, Qcap=256, A_max=5, horizon=200,
                     work_steps=24)),
}


# ---------------------------------------------------------------------------
# the fault plane itself
# ---------------------------------------------------------------------------
def test_fault_plane_shape_and_determinism():
    key = jax.random.PRNGKey(0)
    up = make_fault_plane(key, L=6, horizon=300, fault_rate=0.1,
                          repair_rate=0.3)
    assert up.shape == (300, 6) and up.dtype == jnp.bool_
    down_frac = 1.0 - float(np.asarray(up).mean())
    assert 0.05 < down_frac < 0.6          # shocks actually happen
    np.testing.assert_array_equal(
        np.asarray(up),
        np.asarray(make_fault_plane(key, L=6, horizon=300, fault_rate=0.1,
                                    repair_rate=0.3)))


def test_faults_never_perturb_job_streams():
    """Attaching the plane must not shift a single RNG draw: n/sizes/durs
    are bitwise identical with and without fault_rate."""
    key = jax.random.PRNGKey(7)
    kw = dict(L=4, K=6, A_max=5, horizon=120)
    clean = make_streams(key, 1.2, 0.05, _scalar_sampler, **kw)
    faulted = make_streams(key, 1.2, 0.05, _scalar_sampler, **kw, **FAULT)
    assert clean.up is None and faulted.up is not None
    for f in ("n", "sizes", "durs"):
        np.testing.assert_array_equal(np.asarray(getattr(clean, f)),
                                      np.asarray(getattr(faulted, f)))


def test_fault_plane_from_events_and_validation():
    plane = fault_plane_from_events(
        [(5, 1, False), (10, 1, True), (3, 0, False)], horizon=20, L=2)
    up = np.asarray(plane)
    assert up[:3, 0].all() and not up[3:, 0].any()     # 0 down from slot 3
    assert up[:5, 1].all() and not up[5:10, 1].any() and up[10:, 1].all()
    with pytest.raises(ValueError, match="outside horizon"):
        fault_plane_from_events([(20, 0, False)], horizon=20, L=2)
    with pytest.raises(ValueError, match="outside"):
        fault_plane_from_events([(0, 2, False)], horizon=20, L=2)
    streams = make_streams(jax.random.PRNGKey(1), 0.5, 0.1, _scalar_sampler,
                           L=2, K=4, A_max=3, horizon=20)
    with pytest.raises(ValueError, match=r"must be \(T=20, L\)"):
        with_fault_plane(streams, np.ones((19, 2), bool))
    assert with_fault_plane(streams, plane).up is not None


# ---------------------------------------------------------------------------
# faulted scan == reference oracle, per policy
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", sorted(MATRIX))
def test_faulted_scan_matches_reference(policy):
    wl, cfg = MATRIX[policy]
    key = jax.random.PRNGKey(42)
    ref_cfg = {k: v for k, v in cfg.items() if k != "work_steps"}
    ref = run_policy(wl, key, policy=policy, engine="reference",
                     **ref_cfg, **FAULT)
    res = run_policy(wl, key, policy=policy, engine="scan", **cfg, **FAULT)
    assert int(res.truncated) == 0 and int(res.dropped) == 0
    pre, req, lost = (int(res.preempted), int(res.requeued), int(res.lost))
    assert pre > 0, "fault config produced no preemptions — test is vacuous"
    assert pre == req + lost
    for f in ref._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(res, f)), np.asarray(getattr(ref, f)),
            err_msg=f"{policy}: faulted scan diverged from oracle on {f!r}")


@pytest.mark.parametrize("policy", sorted(MATRIX))
def test_fault_free_counters_are_zero(policy):
    wl, cfg = MATRIX[policy]
    res = run_policy(wl, jax.random.PRNGKey(42), policy=policy,
                     engine="scan", **cfg)
    assert int(res.preempted) == int(res.requeued) == int(res.lost) == 0


def test_max_requeue_zero_loses_every_preemption():
    wl, cfg = MATRIX["bfjs"]
    key = jax.random.PRNGKey(42)
    res = run_policy(wl, key, policy="bfjs", engine="scan", **cfg, **FAULT,
                     max_requeue=0)
    ref = run_policy(wl, key, policy="bfjs", engine="reference", **cfg,
                     **FAULT, max_requeue=0)
    assert int(res.preempted) > 0
    assert int(res.requeued) == 0
    assert int(res.lost) == int(res.preempted)
    np.testing.assert_array_equal(np.asarray(res.queue_len),
                                  np.asarray(ref.queue_len))
    assert int(res.lost) == int(ref.lost)


def test_event_plane_scan_matches_reference():
    """Deterministic downtime from an explicit event trace (the
    machine-events ingestion path): scan == oracle on bfjs-mr streams."""
    key = jax.random.PRNGKey(3)
    wl, cfg = MATRIX["bfjs-mr"]
    streams = make_streams(key, wl.lam, wl.mu, wl.sampler, L=cfg["L"],
                           K=cfg["K"], A_max=cfg["A_max"],
                           horizon=cfg["horizon"], num_resources=2)
    events = [(40, 0, False), (60, 0, True), (80, 1, False), (81, 2, False),
              (120, 1, True), (120, 2, True)]
    streams = with_fault_plane(
        streams, fault_plane_from_events(events, cfg["horizon"], cfg["L"]))
    run_kw = dict(Qcap=cfg["Qcap"], capacity=wl.capacity)
    res = run_policy_streams(streams, policy="bfjs-mr", engine="scan",
                             L=cfg["L"], K=cfg["K"], A_max=cfg["A_max"],
                             work_steps=cfg["work_steps"], **run_kw)
    ref = run_policy_streams(streams, policy="bfjs-mr", engine="reference",
                             L=cfg["L"], capacity=wl.capacity)
    assert int(res.truncated) == 0 and int(res.dropped) == 0
    assert int(res.preempted) > 0
    for f in ref._fields:
        np.testing.assert_array_equal(np.asarray(getattr(res, f)),
                                      np.asarray(getattr(ref, f)),
                                      err_msg=f"event-plane mismatch on {f!r}")


# ---------------------------------------------------------------------------
# enforced graceful degradation (pallas -> scan)
# ---------------------------------------------------------------------------
@pytest.fixture
def faulted_bfjs_streams():
    return make_streams(jax.random.PRNGKey(5), 1.2, 0.05, _scalar_sampler,
                        L=4, K=6, A_max=5, horizon=120, **FAULT)


BFJS_KW = dict(L=4, K=6, Qcap=64, A_max=5)


def test_pallas_fault_plane_degrades_to_scan(faulted_bfjs_streams):
    scan = run_policy_streams(faulted_bfjs_streams, policy="bfjs",
                              engine="scan", **BFJS_KW)
    with pytest.warns(GracefulDegradationWarning, match="fault-plane"):
        res = run_policy_streams(faulted_bfjs_streams, policy="bfjs",
                                 engine="pallas", **BFJS_KW)
    for f in scan._fields:
        np.testing.assert_array_equal(np.asarray(getattr(res, f)),
                                      np.asarray(getattr(scan, f)))


def test_pallas_fault_plane_strict_raises(faulted_bfjs_streams):
    with pytest.raises(ValueError, match="strict=True"):
        run_policy_streams(faulted_bfjs_streams, policy="bfjs",
                           engine="pallas", strict=True, **BFJS_KW)


def test_pallas_vmem_budget_degrades_to_scan(monkeypatch):
    """A 1-byte budget fails every scratch estimate: the dispatch must warn
    (naming the budget env var) and serve the scan trajectory instead."""
    streams = make_streams(jax.random.PRNGKey(5), 1.2, 0.05,
                           _scalar_sampler, L=4, K=6, A_max=5, horizon=120)
    scan = run_policy_streams(streams, policy="bfjs", engine="scan",
                              **BFJS_KW)
    monkeypatch.setenv("REPRO_VMEM_BUDGET_BYTES", "1")
    with pytest.warns(GracefulDegradationWarning,
                      match="REPRO_VMEM_BUDGET_BYTES"):
        res = run_policy_streams(streams, policy="bfjs", engine="pallas",
                                 **BFJS_KW)
    np.testing.assert_array_equal(np.asarray(res.queue_len),
                                  np.asarray(scan.queue_len))
    with pytest.raises(ValueError, match="VMEM"):
        run_policy_streams(streams, policy="bfjs", engine="pallas",
                           strict=True, **BFJS_KW)
