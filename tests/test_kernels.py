"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.jax_sched import make_streams
from repro.kernels.best_fit.best_fit import (best_fit_pallas,
                                             best_fit_pallas_batched)
from repro.kernels.best_fit.ref import best_fit_ref, best_fit_ref_batched
from repro.kernels.bfjs.bfjs import bfjs_pallas
from repro.kernels.bfjs.ref import bfjs_ref
from repro.kernels.decode_attention.decode_attention import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan


# ---------------------------------------------------------------------------
# best_fit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("L,N,seed", [(8, 4, 0), (64, 32, 1), (256, 128, 2),
                                      (128, 200, 3)])
def test_best_fit_sweep(L, N, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    resid = jax.random.uniform(k1, (L,))
    sizes = jax.random.uniform(k2, (N,), minval=0.01, maxval=0.8)
    a1, r1 = best_fit_pallas(resid, sizes, interpret=True)
    a2, r2 = best_fit_ref(resid, sizes)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_allclose(r1, r2, rtol=1e-6)


def test_best_fit_exact_fit_and_rejects():
    resid = jnp.array([0.5, 0.3])
    sizes = jnp.array([0.3, 0.5, 0.2, 0.9])
    a, r = best_fit_pallas(resid, sizes, interpret=True)
    # 0.3 -> server 1 (tightest), 0.5 -> server 0, 0.2 -> nothing fits? 0 left
    assert list(np.asarray(a)) == [1, 0, -1, -1]
    np.testing.assert_allclose(r, [0.0, 0.0], atol=1e-7)


def test_best_fit_batched_matches_ref():
    k = jax.random.PRNGKey(0)
    resid = jax.random.uniform(k, (5, 32))
    sizes = jax.random.uniform(jax.random.PRNGKey(1), (5, 16), minval=0.05,
                               maxval=0.6)
    a1, r1 = best_fit_pallas_batched(resid, sizes, interpret=True)
    a2, r2 = best_fit_ref_batched(resid, sizes)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_allclose(r1, r2, rtol=1e-6)


# ---------------------------------------------------------------------------
# fused BF-J/S slot-step kernel
# ---------------------------------------------------------------------------
def _bfjs_streams(G, L, K, A_max, T, lam=1.2, mu=0.02, seed=0):
    def sampler(key, n):
        return jax.random.uniform(key, (n,), minval=0.05, maxval=0.5)

    keys = jax.random.split(jax.random.PRNGKey(seed), G)
    return jax.vmap(lambda k: make_streams(
        k, lam, mu, sampler, L=L, K=K, A_max=A_max, horizon=T))(keys)


@pytest.mark.parametrize("G,L,K,Qcap,A_max,T,window", [
    (2, 4, 6, 64, 6, 120, None),
    (3, 4, 6, 64, 6, 240, 80),      # windowed grid: state persists in VMEM
    (1, 8, 4, 32, 4, 96, 32),
])
def test_bfjs_kernel_matches_jnp_engine(G, L, K, Qcap, A_max, T, window):
    """Fused slot-step kernel (interpret) == branch-free pure-JAX engine,
    slot by slot, on shared pre-generated streams."""
    st = _bfjs_streams(G, L, K, A_max, T)
    W = A_max + 4
    ref = bfjs_ref(st.n, st.sizes, st.durs, L=L, K=K, Qcap=Qcap,
                   A_max=A_max, work_steps=W)
    qlen, occ, ndep, dropped, trunc = bfjs_pallas(
        st.n, st.sizes, st.durs, L=L, K=K, Qcap=Qcap, A_max=A_max,
        work_steps=W, window=window, interpret=True)
    np.testing.assert_array_equal(np.asarray(qlen),
                                  np.asarray(ref.queue_len))
    np.testing.assert_array_equal(np.asarray(np.cumsum(ndep, axis=1)),
                                  np.asarray(ref.departed))
    np.testing.assert_allclose(np.asarray(occ), np.asarray(ref.occupancy),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(dropped),
                                  np.asarray(ref.dropped))
    np.testing.assert_array_equal(np.asarray(trunc),
                                  np.asarray(ref.truncated))


def test_bfjs_kernel_overload_drops_match():
    """Saturated regime: the fixed-size buffer drops arrivals identically in
    kernel and engine (and the trunc flag stays in lockstep)."""
    G, L, K, Qcap, A_max, T = 2, 3, 4, 16, 6, 200
    st = _bfjs_streams(G, L, K, A_max, T, lam=4.0, mu=0.01, seed=3)
    ref = bfjs_ref(st.n, st.sizes, st.durs, L=L, K=K, Qcap=Qcap,
                   A_max=A_max, work_steps=A_max + 4)
    qlen, occ, ndep, dropped, trunc = bfjs_pallas(
        st.n, st.sizes, st.durs, L=L, K=K, Qcap=Qcap, A_max=A_max,
        work_steps=A_max + 4, window=50, interpret=True)
    assert int(np.asarray(ref.dropped).sum()) > 0
    np.testing.assert_array_equal(np.asarray(dropped),
                                  np.asarray(ref.dropped))
    np.testing.assert_array_equal(np.asarray(qlen),
                                  np.asarray(ref.queue_len))
    np.testing.assert_array_equal(np.asarray(trunc),
                                  np.asarray(ref.truncated))


# ---------------------------------------------------------------------------
# fused VQS slot-step kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("G,J,L,K,Qcap,A_max,T,window", [
    (2, 3, 4, 8, 48, 5, 120, None),
    (2, 2, 3, 6, 32, 4, 180, 60),   # windowed grid: state persists in VMEM
    (1, 4, 6, 16, 64, 6, 90, 30),
])
def test_vqs_kernel_matches_scan_engine(G, J, L, K, Qcap, A_max, T, window):
    """Fused VQS kernel (interpret) == branch-free scan engine, slot by
    slot, on shared pre-generated streams — rings, configurations and
    subscriptions all evolve identically."""
    from repro.kernels.vqs.ops import vqs_simulate
    from repro.kernels.vqs.ref import vqs_ref
    from repro.core.engine import SchedStreams

    st = _bfjs_streams(G, L, K, A_max, T, lam=1.0, mu=0.03, seed=9)
    ref = vqs_ref(st.n, st.sizes, st.durs, J=J, L=L, K=K, Qcap=Qcap,
                  A_max=A_max)
    pal = vqs_simulate(SchedStreams(st.n, st.sizes, st.durs), J=J, L=L,
                       K=K, Qcap=Qcap, A_max=A_max, window=window)
    np.testing.assert_array_equal(np.asarray(pal.queue_len),
                                  np.asarray(ref.queue_len))
    np.testing.assert_array_equal(np.asarray(pal.departed),
                                  np.asarray(ref.departed))
    np.testing.assert_array_equal(np.asarray(pal.occupancy),
                                  np.asarray(ref.occupancy))
    np.testing.assert_array_equal(np.asarray(pal.dropped),
                                  np.asarray(ref.dropped))
    np.testing.assert_array_equal(np.asarray(pal.truncated),
                                  np.asarray(ref.truncated))


def test_vqs_kernel_overload_counters_match():
    """Saturated regime: ring drops and lazy-finish truncation counters stay
    in lockstep between kernel and scan engine."""
    from repro.kernels.vqs.ops import vqs_simulate
    from repro.kernels.vqs.ref import vqs_ref
    from repro.core.engine import SchedStreams

    G, J, L, K, Qcap, A_max, T = 2, 3, 3, 8, 8, 6, 150
    st = _bfjs_streams(G, L, K, A_max, T, lam=4.0, mu=0.01, seed=4)
    ref = vqs_ref(st.n, st.sizes, st.durs, J=J, L=L, K=K, Qcap=Qcap,
                  A_max=A_max, work_steps=2)
    pal = vqs_simulate(SchedStreams(st.n, st.sizes, st.durs), J=J, L=L,
                       K=K, Qcap=Qcap, A_max=A_max, work_steps=2, window=50)
    assert int(np.asarray(ref.dropped).sum()) > 0
    np.testing.assert_array_equal(np.asarray(pal.dropped),
                                  np.asarray(ref.dropped))
    np.testing.assert_array_equal(np.asarray(pal.truncated),
                                  np.asarray(ref.truncated))
    np.testing.assert_array_equal(np.asarray(pal.queue_len),
                                  np.asarray(ref.queue_len))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("S,hd,dtype,window", [
    (128, 64, jnp.float32, 0),
    (256, 64, jnp.float32, 0),
    (256, 128, jnp.float32, 64),
    (256, 32, jnp.bfloat16, 0),
    (512, 64, jnp.bfloat16, 128),
])
def test_flash_attention_sweep(S, hd, dtype, window):
    B, H, KV = 2, 4, 2
    ks = jax.random.split(jax.random.PRNGKey(S + hd), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, hd), dtype)
    out = flash_attention(q, k, v, causal=True, window=window,
                          bq=128, bk=128, interpret=True)
    ref = attention_ref(q, k, v, causal=True, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_mha_equals_gqa_with_repeated_kv():
    B, H, S, hd = 1, 4, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, 1, S, hd))
    v = jax.random.normal(ks[2], (B, 1, S, hd))
    gqa = flash_attention(q, k, v, interpret=True, bq=64, bk=64)
    mha = flash_attention(q, jnp.repeat(k, H, 1), jnp.repeat(v, H, 1),
                          interpret=True, bq=64, bk=64)
    np.testing.assert_allclose(gqa, mha, atol=1e-6)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("C,pos,window,dtype", [
    (256, 0, 0, jnp.float32),
    (256, 255, 0, jnp.float32),
    (512, 300, 0, jnp.bfloat16),
    (512, 300, 128, jnp.float32),
])
def test_decode_attention_sweep(C, pos, window, dtype):
    B, H, KV, hd = 2, 8, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(C + pos), 3)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, KV, C, hd), dtype)
    v = jax.random.normal(ks[2], (B, KV, C, hd), dtype)
    out = decode_attention(q, k, v, jnp.asarray(pos, jnp.int32), bc=128,
                           window=window, interpret=True)
    ref = decode_attention_ref(q, k, v, jnp.asarray(pos), window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("nc,Lc,hd,N,dtype", [
    (2, 32, 16, 8, jnp.float32),
    (4, 64, 32, 16, jnp.float32),
    (4, 64, 64, 32, jnp.bfloat16),
])
def test_ssd_scan_sweep(nc, Lc, hd, N, dtype):
    B, H = 2, 3
    ks = jax.random.split(jax.random.PRNGKey(nc * Lc), 4)
    xdt = (jax.random.normal(ks[0], (B, H, nc, Lc, hd)) * 0.5).astype(dtype)
    Bm = (jax.random.normal(ks[1], (B, H, nc, Lc, N)) * 0.5).astype(dtype)
    Cm = (jax.random.normal(ks[2], (B, H, nc, Lc, N)) * 0.5).astype(dtype)
    a = -jax.nn.softplus(jax.random.normal(ks[3], (B, H, nc, Lc)))
    y1 = ssd_scan(xdt, Bm, Cm, a.astype(dtype), interpret=True)
    y2 = ssd_ref(xdt, Bm, Cm, a.astype(dtype))
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32),
                               atol=tol, rtol=1e-2)


def test_ssd_state_continuity_across_chunks():
    """Chunked output must equal the unchunked recurrence exactly —
    the inter-chunk state pass is the core of SSD."""
    B, H, nc, Lc, hd, N = 1, 1, 8, 16, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    xdt = jax.random.normal(ks[0], (B, H, nc, Lc, hd)) * 0.3
    Bm = jax.random.normal(ks[1], (B, H, nc, Lc, N)) * 0.3
    Cm = jax.random.normal(ks[2], (B, H, nc, Lc, N)) * 0.3
    a = -jax.nn.softplus(jax.random.normal(ks[3], (B, H, nc, Lc)))
    y_kernel = ssd_scan(xdt, Bm, Cm, a, interpret=True)
    y_ref = ssd_ref(xdt, Bm, Cm, a)
    np.testing.assert_allclose(y_kernel, y_ref, atol=1e-5, rtol=1e-4)
