"""Partition I (Eq. 6) and K_RED^(J) (Eq. 7) properties."""
import numpy as np
import pytest

# deselected by the fast tier-1 lane (-m "not slow"); CI runs
# the full suite
pytestmark = pytest.mark.slow

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.partition import PartitionI, k_red, k_red_is_feasible
from repro.core.quantize import RES, to_grid


@pytest.mark.parametrize("J", [2, 3, 4, 6, 8, 12])
def test_k_red_cardinality_and_feasibility(J):
    confs = k_red(J)
    assert confs.shape == (4 * J - 4, 2 * J)          # Definition 5
    assert k_red_is_feasible(J)                        # capacity-respecting
    # each configuration has at most one type other than type 1 (paper note)
    for row in confs:
        nz = np.nonzero(row)[0]
        others = [j for j in nz if j != 1]
        assert len(others) <= 1
        assert row[1] in (0, 1)


def test_partition_boundaries_exact():
    p = PartitionI(3)
    assert p.type_of_scalar(RES) == 0          # size 1.0 -> I_0 = (2/3, 1]
    assert p.type_of_scalar(RES // 2) == 2     # 0.5 -> I_2 = (1/3, 1/2]
    assert p.type_of_scalar(RES // 2 + 1) == 1  # just above 1/2 -> I_1


def test_partition_known_sizes():
    p = PartitionI(3)
    sizes = to_grid([0.9, 0.6, 0.45, 0.3, 0.22, 0.14, 0.05])
    types = p.type_of(sizes)
    assert list(types) == [0, 1, 2, 3, 4, 5, 5]
    # last VQ rounding
    eff = p.effective_size(sizes)
    assert eff[-1] == p.min_grid_size
    assert (eff[:-1] == sizes[:-1]).all()


@settings(max_examples=200, deadline=None)
@given(st.integers(min_value=1, max_value=RES), st.integers(2, 10))
def test_type_membership(size, J):
    """Every size lands in exactly the interval its type claims."""
    p = PartitionI(J)
    t = p.type_of_scalar(size)
    assert 0 <= t < 2 * J
    if size <= (RES >> J):
        assert t == 2 * J - 1
        return
    m, odd = divmod(t, 2)
    upper = RES >> m
    if odd == 0:  # I_2m = (2/3 * 2^-m, 2^-m]
        assert 3 * size > 2 * upper and size <= upper
    else:         # I_2m+1 = (2^-(m+1), 2/3 * 2^-m]
        assert size > (upper >> 1) and 3 * size <= 2 * upper


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 8), st.lists(st.integers(0, 10_000), min_size=4,
                                   max_size=24))
def test_max_weight_is_argmax(J, qs):
    from repro.core.partition import max_weight_config
    q = np.zeros(2 * J, dtype=np.int64)
    for i, v in enumerate(qs[: 2 * J]):
        q[i] = v
    idx, conf = max_weight_config(J, q)
    w = k_red(J) @ q
    assert w[idx] == w.max()
    assert (conf == k_red(J)[idx]).all()


def test_upper_bounds_match_classification():
    """sup I_j on the grid is classified as type j (boundary exactness)."""
    for J in (2, 4, 8):
        p = PartitionI(J)
        for j in range(2 * J - 1):  # last VQ has the round-up rule
            ub = p.upper_bound_int(j)
            assert p.type_of_scalar(ub) == j, (J, j, ub)
