"""VQS accelerator engines: bit-parity with the event-driven numpy engine
(trace streams), scan-vs-reference equivalence (random streams), counted
truncation, and the policy-generic run_policy API (incl. the PR 1
run_bfjs back-compat contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import VQS, PartitionI, RES, simulate_trace
from repro.core.engine import (available_policies, make_streams,
                               monte_carlo_policy, run_bfjs, run_policy,
                               run_policy_streams, run_vqs_streams,
                               streams_from_trace, vq_type_of_grid)
from repro.core.engine.vqs import _run_vqs_reference_streams


# ---------------------------------------------------------------------------
# exact integer-grid classification
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("J", [2, 3, 6, 10])
def test_vq_type_of_grid_matches_partition_exactly(J):
    part = PartitionI(J)
    g = np.arange(1, RES + 1, dtype=np.int64)
    expect = part.type_of(g)
    got = np.asarray(vq_type_of_grid(jnp.asarray(g, jnp.int32), J))
    np.testing.assert_array_equal(got, expect)


# ---------------------------------------------------------------------------
# trace-driven parity with the event-driven engine (the oracle bridge)
# ---------------------------------------------------------------------------
def _random_trace(seed, T, N, grid=64):
    rng = np.random.default_rng(seed)
    slots = np.sort(rng.integers(0, T, N))
    sizes = rng.integers(1, grid, N) / float(grid)
    durs = rng.integers(1, 60, N)
    return slots, sizes, durs


@pytest.mark.parametrize("engine", ["reference", "scan"])
@pytest.mark.parametrize("seed,J,L", [(0, 3, 5), (7, 5, 12), (3, 2, 1)])
def test_vqs_engine_bitmatches_numpy_on_trace(engine, seed, J, L):
    """run_policy_streams(policy="vqs") == simulate_trace(VQS(J)) queue
    trajectory, slot for slot, on grid-sized jobs."""
    T, N = 400, 60 * L
    slots, sizes, durs = _random_trace(seed, T, N)
    ref = simulate_trace(VQS(J=J), L=L, arrival_slots=slots, sizes=sizes,
                         durations=durs, horizon=T, seed=0, record_every=1)
    st = streams_from_trace(slots, sizes, durs, horizon=T)
    res = run_policy_streams(st, policy="vqs", engine=engine, J=J, L=L,
                             K=1 << J, Qcap=2048,
                             A_max=int(st.sizes.shape[1]))
    assert int(res.truncated) == 0
    assert int(res.dropped) == 0
    np.testing.assert_array_equal(np.asarray(res.queue_len),
                                  ref.queue_lens)
    assert int(res.departed[-1]) == ref.departed


# ---------------------------------------------------------------------------
# scan vs reference on random streams (all regimes share the RNG hoist)
# ---------------------------------------------------------------------------
def _uniform_sampler(lo, hi):
    def sampler(key, n):
        return jax.random.uniform(key, (n,), minval=lo, maxval=hi)
    return sampler


@pytest.mark.parametrize("seed,lam,J", [(0, 0.3, 2), (1, 1.0, 4),
                                        (2, 2.5, 5)])
def test_vqs_scan_bitmatches_reference_engine(seed, lam, J):
    sampler = _uniform_sampler(0.05, 0.9)
    kw = dict(L=6, K=40, Qcap=512, A_max=6)
    st = make_streams(jax.random.PRNGKey(seed), lam, 0.02, sampler,
                      L=6, K=40, A_max=6, horizon=600)
    ref = _run_vqs_reference_streams(st, J=J, **kw)
    scn = run_vqs_streams(st, J=J, **kw)
    assert int(scn.truncated) == 0
    for field in ("queue_len", "occupancy", "departed", "dropped"):
        np.testing.assert_array_equal(np.asarray(getattr(scn, field)),
                                      np.asarray(getattr(ref, field)))


def test_vqs_scan_empty_membership_not_resurrected():
    """Regression: a server that was empty at slot start, placed jobs over
    several work-list steps and was then advanced past must NOT be re-added
    to the _empty set from the stale slot-start mask — that spurious
    membership made later slots visit (and pack) servers the reference
    engine leaves alone, diverging with truncated == 0."""
    sampler = _uniform_sampler(0.05, 0.95)
    st = make_streams(jax.random.PRNGKey(8), 3.5, 0.05, sampler,
                      L=5, K=32, A_max=6, horizon=400)
    kw = dict(J=4, L=5, K=32, Qcap=256, A_max=6)
    ref = _run_vqs_reference_streams(st, **kw)
    scn = run_vqs_streams(st, **kw)
    assert int(scn.truncated) == 0
    np.testing.assert_array_equal(np.asarray(scn.queue_len),
                                  np.asarray(ref.queue_len))
    np.testing.assert_array_equal(np.asarray(scn.departed),
                                  np.asarray(ref.departed))


def test_vqs_truncation_is_counted_not_silent():
    """A too-small work-step bound must be reported via `truncated` while
    an ample bound reproduces the numpy engine exactly — including the
    departure count, so laziness is visible, never silent."""
    seed, J, L, T = 5, 3, 8, 300
    slots, sizes, durs = _random_trace(seed, T, 14 * L, grid=32)
    st = streams_from_trace(slots, sizes, durs, horizon=T)
    A = int(st.sizes.shape[1])
    kw = dict(J=J, L=L, K=1 << J, Qcap=1024, A_max=A)
    tiny = run_vqs_streams(st, work_steps=1, **kw)
    ample = run_vqs_streams(st, **kw)
    assert int(tiny.truncated) > 0
    assert int(ample.truncated) == 0
    ref = simulate_trace(VQS(J=J), L=L, arrival_slots=slots, sizes=sizes,
                         durations=durs, horizon=T, seed=0, record_every=1)
    np.testing.assert_array_equal(np.asarray(ample.queue_len),
                                  ref.queue_lens)


def test_vqs_server_slot_overflow_is_counted():
    """K below the per-server packing bound: the placement the unbounded
    model would make is flagged in `truncated` instead of silently
    reshaping the trajectory."""
    # every job is the smallest type: a whole server packs 2**J of them
    J, L, T = 3, 1, 120
    slots = np.arange(40) % T
    sizes = np.full(40, 1.0 / (1 << J))
    durs = np.full(40, 100)
    st = streams_from_trace(np.sort(slots), sizes, durs, horizon=T)
    res = run_vqs_streams(st, J=J, L=L, K=2, Qcap=64,
                          A_max=int(st.sizes.shape[1]))
    assert int(res.truncated) > 0


# ---------------------------------------------------------------------------
# policy-generic API + PR 1 back-compat contract
# ---------------------------------------------------------------------------
def test_bfjs_rejects_trace_streams():
    """Trace streams carry per-arrival durations only; the BF-J/S engines
    need the sequential-draw region, so replaying a trace through
    policy="bfjs" must fail loudly instead of running with detached
    durations."""
    slots, sizes, durs = _random_trace(1, 50, 30)
    st = streams_from_trace(slots, sizes, durs, horizon=50)
    with pytest.raises(ValueError, match="sequential-draw region"):
        run_policy_streams(st, policy="bfjs", L=4, K=6, Qcap=32,
                           A_max=int(st.sizes.shape[1]))


def test_policy_registry_contents():
    assert "bfjs" in available_policies()
    assert "vqs" in available_policies()
    with pytest.raises(ValueError, match="unknown policy"):
        run_policy(jax.random.PRNGKey(0), 1.0, 0.01,
                   _uniform_sampler(0.1, 0.5), policy="nope")
    with pytest.raises(ValueError, match="unknown engine"):
        run_policy(jax.random.PRNGKey(0), 1.0, 0.01,
                   _uniform_sampler(0.1, 0.5), engine="nope")


def test_run_policy_bfjs_equals_run_bfjs_shim():
    """The refactor kept the PR 1 contract: the `repro.core.jax_sched`
    shim's run_bfjs and the registry's policy="bfjs" produce identical
    trajectories on the same key, for both engines."""
    from repro.core.jax_sched import run_bfjs as shim_run_bfjs
    from repro.core.jax_sched import BFJSStreams, SchedStreams

    assert BFJSStreams is SchedStreams  # alias, not a copy
    sampler = _uniform_sampler(0.1, 0.6)
    kw = dict(L=4, K=6, Qcap=48, A_max=5, horizon=200)
    key = jax.random.PRNGKey(11)
    for engine in ("reference", "scan"):
        old = shim_run_bfjs(key, 1.0, 0.02, sampler, engine=engine, **kw)
        new = run_policy(key, 1.0, 0.02, sampler, policy="bfjs",
                         engine=engine, **kw)
        for field in ("queue_len", "occupancy", "departed", "dropped",
                      "truncated"):
            np.testing.assert_array_equal(
                np.asarray(getattr(old, field)),
                np.asarray(getattr(new, field)))
    assert run_bfjs is shim_run_bfjs


def test_run_policy_vqs_all_engines_agree():
    """reference == scan == pallas(interpret) member-for-member through the
    public entry points."""
    sampler = _uniform_sampler(0.08, 0.7)
    kw = dict(J=3, L=4, K=8, Qcap=64, A_max=5, horizon=120)
    key = jax.random.PRNGKey(2)
    ref = run_policy(key, 1.0, 0.03, sampler, policy="vqs",
                     engine="reference", **kw)
    scn = run_policy(key, 1.0, 0.03, sampler, policy="vqs",
                     engine="scan", **kw)
    pal = run_policy(key, 1.0, 0.03, sampler, policy="vqs",
                     engine="pallas", **kw)
    assert int(scn.truncated) == 0
    for res in (scn, pal):
        np.testing.assert_array_equal(np.asarray(res.queue_len),
                                      np.asarray(ref.queue_len))
        np.testing.assert_array_equal(np.asarray(res.departed),
                                      np.asarray(ref.departed))


def test_monte_carlo_policy_vqs_vmaps():
    keys = jax.random.split(jax.random.PRNGKey(3), 3)
    res = monte_carlo_policy(keys, 0.8, 0.02, _uniform_sampler(0.1, 0.6),
                             policy="vqs", engine="scan", J=2, L=3, K=8,
                             Qcap=64, A_max=4, horizon=100)
    assert res.queue_len.shape == (3, 100)
    assert res.truncated.shape == (3,)


def test_estimate_capacity_policy_knob():
    from repro.serving.engine import estimate_capacity
    out = estimate_capacity(3, 0.5, 50.0, ensembles=2, horizon=300,
                            policy="vqs", J=2, K=8, Qcap=64, A_max=4)
    assert out["policy"] == "vqs"
    assert out["slots_simulated"] == 600
    assert out["truncated"] == 0
