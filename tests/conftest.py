import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Tests must see the real (1-device) CPU platform — the 512-device override
# belongs to the dry-run subprocesses only.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Hermetic tuning: never read or write a developer's real tuning cache.
# Tests that exercise the cache opt in by monkeypatching this variable.
os.environ.setdefault("REPRO_TUNING_CACHE", "off")
