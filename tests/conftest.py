import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Tests must see the real (1-device) CPU platform — the 512-device override
# belongs to the dry-run subprocesses only.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Hermetic tuning: never read or write a developer's real tuning cache.
# Tests that exercise the cache opt in by monkeypatching this variable.
os.environ.setdefault("REPRO_TUNING_CACHE", "off")

# CI-pinned hypothesis profile: bound example counts globally so property
# suites can't silently creep the tier-1 runtime (per-test @settings with
# tighter explicit caps still win).  Select with HYPOTHESIS_PROFILE; "ci"
# is the default everywhere.
try:
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile("ci", max_examples=25, deadline=None)
    _hyp_settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ImportError:  # property suites importorskip hypothesis themselves
    pass


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: hypothesis-heavy or subprocess-spawning suite; the fast "
        'tier-1 lane deselects these with -m "not slow" (CI still runs '
        "the full suite)")
